//! Cluster scaling sweep: one d=21504 GEMM (the paper's largest
//! problem) sharded over N = 1..8 simulated 520N cards.
//!
//! For each fleet size the auto-planner picks the best of the 1D-row,
//! 2D-grid and 2.5D/SUMMA partitioners by simulated makespan; the table
//! reports effective TFLOPS, scaling efficiency vs. the N=1 run, bytes
//! moved, and the per-device utilization band. A second section shows
//! the communication bill per strategy at N=8, a third compares the
//! ring and torus fabrics on the same 2.5D plan, and a fourth runs a
//! deliberately heterogeneous fleet to exercise work-stealing.
//!
//! ```sh
//! cargo run --release --example cluster_scaling [-- --d2 21504 --design G --json OUT.json]
//! ```
//!
//! `--json FILE` additionally writes the headline metrics (makespans at
//! N ∈ {1, 2, 4, 8}, the N=2 speedup, N=8 TFLOPS) as a flat JSON
//! object for the CI perf gate.

use std::collections::BTreeMap;
use systo3d::cli::Args;
use systo3d::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::Topology;
use systo3d::perfmodel::scaling_efficiency;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let d2 = args.get_u64("d2", 21504).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let mut metrics: BTreeMap<String, f64> = BTreeMap::new();

    println!("=== cluster scaling: {d2}^3 GEMM over N x design-{id} 520N cards ===\n");
    println!(
        "{:>2} {:>11} {:>10} {:>9} {:>10} {:>9} {:>13} {:>7}",
        "N", "strategy", "makespan", "TFLOPS", "eff vs N=1", "GB moved", "util min-max", "steals"
    );

    let mut t1 = None;
    let mut n2_speedup = None;
    for n in 1..=8usize {
        let sim = ClusterSim::builder(Fleet::homogeneous(n, &id).map_err(anyhow::Error::msg)?)
            .build();
        let (plan, r) = sim
            .plan_and_report(d2, d2, d2)
            .ok_or_else(|| anyhow::anyhow!("no plan for {d2} on {n} device(s)"))?;
        let t1_s = *t1.get_or_insert(r.makespan_seconds);
        let eff = scaling_efficiency(n as u64, t1_s, r.makespan_seconds);
        if n == 2 {
            n2_speedup = Some(t1_s / r.makespan_seconds);
        }
        if matches!(n, 1 | 2 | 4 | 8) {
            metrics.insert(format!("cluster_makespan_n{n}"), r.makespan_seconds);
        }
        if n == 8 {
            metrics.insert("cluster_tflops_n8".into(), r.effective_gflops / 1e3);
        }
        let (umin, umax) = r
            .per_device
            .iter()
            .map(|d| d.utilization)
            .fold((1.0f64, 0.0f64), |(lo, hi), u| (lo.min(u), hi.max(u)));
        println!(
            "{:>2} {:>11} {:>9.3}s {:>9.2} {:>10.3} {:>9.2} {:>6.1}%-{:>5.1}% {:>7}",
            n,
            r.strategy,
            r.makespan_seconds,
            r.effective_gflops / 1e3,
            eff,
            plan.total_bytes_moved() as f64 / 1e9,
            umin * 100.0,
            umax * 100.0,
            r.steals,
        );
    }

    let speedup = n2_speedup.expect("N=2 ran");
    println!("\nN=2 speedup over N=1: {speedup:.2}x");
    anyhow::ensure!(speedup > 1.8, "expected >1.8x at N=2, measured {speedup:.2}x");
    metrics.insert("cluster_n2_speedup".into(), speedup);

    // --- communication bill per strategy at N=8 -------------------------
    println!("\n=== bytes moved per strategy (N=8, d2={d2}) ===");
    let strategies = [
        PartitionStrategy::Row1D { devices: 8 },
        PartitionStrategy::auto_grid2d(8),
        PartitionStrategy::auto_summa25d(8),
    ];
    let mut volumes = Vec::new();
    for s in strategies {
        let plan = PartitionPlan::new(s, d2, d2, d2).map_err(anyhow::Error::msg)?;
        println!(
            "{:>11}: {:>7.2} GB host->dev, {:>6.2} GB dev<->dev, {:>6.2} GB dev->host \
             ({:.2} FLOP/byte)",
            s.name(),
            plan.host_to_device_bytes as f64 / 1e9,
            plan.device_to_device_bytes as f64 / 1e9,
            plan.device_to_host_bytes as f64 / 1e9,
            plan.flops_per_byte(),
        );
        volumes.push((s.name(), plan.total_bytes_moved()));
    }
    let row1d = volumes[0].1;
    let summa = volumes[2].1;
    anyhow::ensure!(
        summa < row1d,
        "2.5D should move fewer bytes than 1D-row ({summa} vs {row1d})"
    );
    println!(
        "2.5D moves {:.1}% of 1D-row's traffic",
        100.0 * summa as f64 / row1d as f64
    );

    // --- fabric: ring vs torus at N=8 -----------------------------------
    println!("\n=== fabric topology at N=8: ring vs torus (2.5D plan) ===");
    let summa = PartitionPlan::new(PartitionStrategy::auto_summa25d(8), d2, d2, d2)
        .map_err(anyhow::Error::msg)?;
    let mut ring_vs_torus = Vec::new();
    for topo in [Topology::ring(8), Topology::torus_near_square(8)] {
        let sim = ClusterSim::builder(Fleet::homogeneous(8, &id).map_err(anyhow::Error::msg)?)
            .topology(topo)
            .build();
        let r = sim.simulate(&summa);
        println!(
            "{:>6}: makespan {:.4} s, link util {:.1}% mean / {:.1}% peak, \
             reduction {:.4} s ({:.0}% overlapped)",
            r.topology,
            r.makespan_seconds,
            r.link_utilization() * 100.0,
            r.max_link_utilization() * 100.0,
            r.reduction_seconds,
            r.reduction_overlap() * 100.0,
        );
        ring_vs_torus.push(r.makespan_seconds);
    }
    anyhow::ensure!(
        ring_vs_torus[1] <= ring_vs_torus[0],
        "the torus must not lose to the ring at N=8 ({} vs {})",
        ring_vs_torus[1],
        ring_vs_torus[0]
    );

    // --- heterogeneous rack: work-stealing in action --------------------
    println!("\n=== mixed Table-I fleet (N=4, work-stealing) ===");
    let sim = ClusterSim::builder(Fleet::mixed_table1(4)).build();
    let (_, report) = sim
        .plan_and_report(d2, d2, d2)
        .ok_or_else(|| anyhow::anyhow!("no plan for the mixed fleet"))?;
    println!("{}", report.render());

    if let Some(path) = args.get("json") {
        systo3d::util::json::write_metrics(path, &metrics)?;
        println!("wrote {} metric(s) to {path}", metrics.len());
    }

    println!("cluster_scaling OK");
    Ok(())
}
