//! Design-space exploration — the paper's §III-C workflow, automated.
//!
//! Sweeps (d_i0, d_j0, d_k0, d_p) candidates through the calibrated
//! fitter and f_max models, reproduces Table I, and then goes beyond the
//! paper: it ranks everything by *sustained* throughput at a target
//! problem size and prints the Pareto view of peak-vs-sustained —
//! exactly the trade the paper's third dimension exists to navigate.
//!
//! ```sh
//! cargo run --release --example design_space [-- --eval-d2 8192]
//! ```

use systo3d::cli::Args;
use systo3d::dse::Explorer;
use systo3d::reports;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let eval_d2 = args.get_u64("eval-d2", 8192).map_err(anyhow::Error::msg)?;

    // Table I through the models.
    println!("{}", reports::table1());
    println!("{}", reports::table1_residuals());

    // Beyond the paper: a broad sweep ranked by sustained throughput.
    let ex = Explorer { eval_d2, ..Default::default() };
    let points = ex.sweep(&[16, 28, 32, 48, 64, 70, 72, 96], &[8, 16, 28, 32], &[1, 2, 4, 6, 8]);
    let fitted = points.iter().filter(|p| p.outcome.fits()).count();
    println!("swept {} candidates; {} fit", points.len(), fitted);

    let mut ranked: Vec<_> = points
        .iter()
        .filter(|p| p.sustained_gflops.is_some_and(|g| g.is_finite()))
        .collect();
    ranked.sort_by(|a, b| {
        b.sustained_gflops.unwrap().total_cmp(&a.sustained_gflops.unwrap())
    });
    println!("top 10 by sustained GFLOPS at d2={eval_d2}:");
    println!(
        "{:>4} {:>12} {:>6} {:>6} {:>9} {:>11}",
        "rank", "(di,dj,dk,dp)", "#DSP", "fmax", "Tpeak", "sustained"
    );
    for (i, p) in ranked.iter().take(10).enumerate() {
        println!(
            "{:>4} ({:>3},{:>2},{:>2},{:>2}) {:>6} {:>6.0} {:>9.0} {:>11.0}",
            i + 1,
            p.array.di0,
            p.array.dj0,
            p.array.dk0,
            p.array.dp,
            p.array.dsps(),
            p.fmax_mhz.unwrap(),
            p.tpeak_gflops.unwrap(),
            p.sustained_gflops.unwrap()
        );
    }

    // The paper's headline claim, checked against the sweep: a fitted
    // design using ≥99% of available DSPs exists and exceeds 3 TFLOPS.
    let headline = points.iter().filter(|p| p.outcome.fits()).find(|p| {
        p.array.dsps() >= 4700 && p.tpeak_gflops.unwrap_or(0.0) > 3000.0
    });
    match headline {
        Some(p) => println!(
            "headline reproduced: ({},{},{},dp={}) uses {} DSPs at {:.0} MHz -> {:.0} GFLOPS peak",
            p.array.di0, p.array.dj0, p.array.dk0, p.array.dp,
            p.array.dsps(), p.fmax_mhz.unwrap(), p.tpeak_gflops.unwrap()
        ),
        None => anyhow::bail!("no 99%-DSP design above 3 TFLOPS — calibration regressed"),
    }
    Ok(())
}
