//! Elastic-fleet acceptance sweep: drain-to-spare versus
//! requeue-on-survivors, and watermark growth versus a fixed fleet.
//!
//! Scenario (the PR-5 acceptance criterion): a 2.5D plan over 16
//! design-G cards on a 4 × 4 torus, with one hot spare spliced into
//! the fabric (the 4-port budget holds). Card 0 dies halfway through
//! its first compute window. Two recoveries are compared:
//!
//! * **drain-to-spare** — the elastic scheduler activates the spare,
//!   drains the victim's queued and in-flight shards onto it (spare
//!   choice scored by replaying the remaining reduction sends under
//!   the link-contention model), and re-homes the victim's reduction
//!   state there;
//! * **requeue-on-survivors** — the PR-2 baseline: the same death on
//!   the same torus with no spare, the lost shard requeued on the
//!   least-loaded survivor.
//!
//! The example asserts the drain **strictly** beats the requeue
//! makespan, that the spare activated exactly once, and that the
//! `DrainCompleted` event fires before the final barrier. A second
//! section overloads a 4-card fleet (8 shards per card against a 2.0
//! watermark) and asserts watermark growth strictly shortens the
//! makespan versus the fixed fleet.
//!
//! ```sh
//! cargo run --release --example elastic_fleet [-- --d2 21504 --design G --json OUT.json]
//! ```
//!
//! `--json FILE` additionally writes the gains as a flat JSON object
//! for the CI perf gate.

use std::collections::BTreeMap;
use systo3d::cli::Args;
use systo3d::cluster::{
    ClusterSim, FaultPlan, Fleet, FleetEvent, PartitionPlan, PartitionStrategy,
};
use systo3d::fabric::Topology;
use systo3d::placement::PlacementStrategy;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let d2 = args.get_u64("d2", 21504).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let mut metrics: BTreeMap<String, f64> = BTreeMap::new();

    println!("=== elastic fleet: drain-to-spare vs requeue-on-survivors ===\n");
    let n = 16usize;
    let plan = PartitionPlan::new(PartitionStrategy::auto_summa25d(n as u64), d2, d2, d2)
        .map_err(anyhow::Error::msg)?;

    // 16 actives on a 4x4 torus, one hot spare spliced in.
    let spared = ClusterSim::builder(Fleet::homogeneous(n + 1, &id).map_err(anyhow::Error::msg)?)
        .topology(Topology::torus2d(4, 4))
        .spares(1)
        .build();
    let first = plan
        .shards
        .iter()
        .find(|s| s.device == 0)
        .ok_or_else(|| anyhow::anyhow!("plan has no shard on card 0"))?;
    let t_die = spared.host.seconds_for_bytes(first.input_bytes())
        + 0.5 * spared.shard_seconds(0, first);
    let drained = spared
        .simulate_elastic(&plan, &FaultPlan::kill(0, t_die))
        .map_err(anyhow::Error::msg)?;

    // The PR-2 baseline: same torus, same death, no spare.
    let fixed = ClusterSim::builder(Fleet::homogeneous(n, &id).map_err(anyhow::Error::msg)?)
        .topology(Topology::torus2d(4, 4))
        .placement(PlacementStrategy::Identity)
        .build();
    let requeue = fixed
        .simulate_with_failures(&plan, &[Some(t_die)])
        .map_err(anyhow::Error::msg)?;

    let drain_makespan = drained.schedule.makespan_seconds;
    let drain_gain = requeue.makespan_seconds / drain_makespan;
    println!(
        "{:>2} torus  kill card 0 at {t_die:.4} s:\n\
         \x20  drain-to-spare       {drain_makespan:.4} s  ({} spare activated, \
         drain {:.4} s)\n\
         \x20  requeue-on-survivors {:.4} s\n\
         \x20  gain {drain_gain:.3}x",
        n, drained.spare_activations, drained.drain_seconds, requeue.makespan_seconds,
    );
    for e in &drained.events {
        println!("    event: {e:?}");
    }

    // Acceptance: the drain strictly beats the requeue makespan.
    anyhow::ensure!(
        drain_makespan < requeue.makespan_seconds,
        "drain-to-spare must strictly beat requeue-on-survivors: {} vs {}",
        drain_makespan,
        requeue.makespan_seconds
    );
    anyhow::ensure!(drained.spare_activations == 1, "exactly one spare activates");
    anyhow::ensure!(drained.drains_completed == 1, "the drain completes");
    for e in &drained.events {
        anyhow::ensure!(
            e.seconds() <= drain_makespan,
            "event after the final barrier: {e:?}"
        );
    }
    anyhow::ensure!(
        drained
            .events
            .iter()
            .any(|e| matches!(e, FleetEvent::DrainCompleted { .. })),
        "DrainCompleted must fire"
    );
    metrics.insert("elastic_drain_gain_torus_n16".into(), drain_gain);
    metrics.insert("elastic_drain_seconds_torus_n16".into(), drained.drain_seconds);

    println!("\n=== elastic fleet: watermark growth vs fixed fleet ===\n");
    // 32 row bands over 4 cards: 8 pending shards per card against a
    // 2.0 watermark — the controller attaches its growth budget.
    let load = PartitionPlan::new(PartitionStrategy::Row1D { devices: 32 }, d2, d2, d2)
        .map_err(anyhow::Error::msg)?;
    let small = ClusterSim::builder(Fleet::homogeneous(4, &id).map_err(anyhow::Error::msg)?)
        .watermark(Some(2.0))
        .build();
    let grown = small.simulate_elastic(&load, &FaultPlan::none()).map_err(anyhow::Error::msg)?;
    let fixed4 = ClusterSim::builder(Fleet::homogeneous(4, &id).map_err(anyhow::Error::msg)?)
        .build()
        .simulate(&load);
    let grow_gain = fixed4.makespan_seconds / grown.schedule.makespan_seconds;
    println!(
        "4 cards + watermark 2.0: grew {} card(s), makespan {:.4} s vs fixed {:.4} s \
         ({grow_gain:.3}x, queued hop-bytes {} -> {})",
        grown.grown_cards,
        grown.schedule.makespan_seconds,
        fixed4.makespan_seconds,
        grown.post_grow_identity_hop_bytes,
        grown.post_grow_placed_hop_bytes,
    );
    anyhow::ensure!(grown.grown_cards > 0, "the watermark must trigger growth");
    anyhow::ensure!(
        grown.schedule.makespan_seconds < fixed4.makespan_seconds,
        "growth must strictly shorten the makespan: {} vs {}",
        grown.schedule.makespan_seconds,
        fixed4.makespan_seconds
    );
    metrics.insert("elastic_grow_gain_n4".into(), grow_gain);
    metrics.insert("elastic_grown_cards_n4".into(), grown.grown_cards as f64);

    if let Some(path) = args.get("json") {
        systo3d::util::json::write_metrics(path, &metrics)?;
        println!("\nwrote {} metric(s) to {path}", metrics.len());
    }

    println!("\nelastic_fleet OK");
    Ok(())
}
