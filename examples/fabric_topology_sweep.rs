//! Fabric topology sweep: the same GEMM sharded over N = 4..32 cards
//! wired as a ring, a near-square torus, a port-budget mesh, and a
//! switched fat tree.
//!
//! For every (N, topology) the auto-planner re-prices the 1D/2D/2.5D
//! partitioners *on that fabric* — the 2.5D reduction is multi-hop
//! traffic now, so narrow topologies punish it — and the table shows
//! where topology choice changes the winning partitioner. Two checks
//! are asserted so CI enforces the fabric story end to end:
//!
//! (a) a 2D torus strictly beats a ring on total simulated time for
//!     the same 2.5D plan at N >= 16 (the plane-major combine is
//!     2-hop disjoint flows on the torus, ~N/2-hop congested flows on
//!     the ring), and
//! (b) overlapping the collective reduction with leaf compute shaves
//!     at least 10% off the non-overlapped schedule's makespan on at
//!     least one swept configuration.
//!
//! ```sh
//! cargo run --release --example fabric_topology_sweep [-- --d2 21504 --design G --json OUT.json]
//! ```
//!
//! `--json FILE` additionally writes the headline metrics (ring/torus
//! makespans and win ratios at N ∈ {16, 32}, the best overlap saving)
//! as a flat JSON object for the CI perf gate.

use std::collections::BTreeMap;
use systo3d::cli::Args;
use systo3d::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::{ReduceAlgo, Topology};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let d2 = args.get_u64("d2", 21504).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let mut metrics: BTreeMap<String, f64> = BTreeMap::new();

    println!("=== fabric sweep: {d2}^3 GEMM over N x design-{id} 520N cards ===\n");
    println!(
        "{:>2} {:>9} {:>11} {:>10} {:>9} {:>12} {:>13} {:>9}",
        "N", "fabric", "best plan", "makespan", "TFLOPS", "bisect GB/s", "link util", "red s"
    );

    let sizes = [4usize, 8, 16, 32];
    let mut winners: Vec<(usize, &'static str, &'static str)> = Vec::new();
    for &n in &sizes {
        for topology in [
            Topology::ring(n),
            Topology::torus_near_square(n),
            Topology::full_mesh(n),
            Topology::fat_tree(n),
        ] {
            let bisect = topology
                .bisection_bytes_per_s(&systo3d::cluster::Link::qsfp28_100g())
                / 1e9;
            let sim = ClusterSim::builder(Fleet::homogeneous(n, &id).map_err(anyhow::Error::msg)?)
                .topology(topology)
                .build();
            let (_, r) = sim
                .plan_and_report(d2, d2, d2)
                .ok_or_else(|| anyhow::anyhow!("no plan for {d2} on {n} card(s)"))?;
            println!(
                "{:>2} {:>9} {:>11} {:>9.3}s {:>9.2} {:>12.1} {:>12.1}% {:>9.4}",
                n,
                r.topology,
                r.strategy,
                r.makespan_seconds,
                r.effective_gflops / 1e3,
                bisect,
                r.link_utilization() * 100.0,
                r.reduction_seconds,
            );
            winners.push((n, r.topology, r.strategy));
        }
    }

    // Where does topology choice change the best partitioner?
    let mut crossover = None;
    for &n in &sizes {
        let at_n: Vec<&'static str> =
            winners.iter().filter(|(m, _, _)| *m == n).map(|&(_, _, s)| s).collect();
        if at_n.windows(2).any(|w| w[0] != w[1]) {
            crossover.get_or_insert(n);
            println!(
                "\nat N={n} the best partitioner depends on the fabric: {:?}",
                winners
                    .iter()
                    .filter(|(m, _, _)| *m == n)
                    .map(|&(_, t, s)| format!("{t}:{s}"))
                    .collect::<Vec<_>>()
            );
        }
    }
    match crossover {
        Some(n) => println!("first topology-driven crossover at N={n}"),
        None => println!("\nno topology-driven partitioner crossover in this sweep"),
    }

    // --- (a) torus strictly beats ring for the 2.5D plan at N >= 16 ----
    println!("\n=== same 2.5D plan, ring vs torus ===");
    for n in [16usize, 32] {
        let plan = PartitionPlan::new(
            PartitionStrategy::auto_summa25d(n as u64),
            d2,
            d2,
            d2,
        )
        .map_err(anyhow::Error::msg)?;
        let fleet = Fleet::homogeneous(n, &id).map_err(anyhow::Error::msg)?;
        let ring = ClusterSim::builder(fleet.clone())
            .topology(Topology::ring(n))
            .build()
            .simulate(&plan);
        let torus =
            ClusterSim::builder(fleet)
                .topology(Topology::torus_near_square(n))
                .build()
                .simulate(&plan);
        println!(
            "N={n:>2} {}: ring {:.4} s (hot link {:.0}%), torus {:.4} s (hot link {:.0}%), \
             torus wins by {:.1}%",
            plan.strategy.name(),
            ring.makespan_seconds,
            ring.max_link_utilization() * 100.0,
            torus.makespan_seconds,
            torus.max_link_utilization() * 100.0,
            (1.0 - torus.makespan_seconds / ring.makespan_seconds) * 100.0,
        );
        anyhow::ensure!(
            torus.makespan_seconds < ring.makespan_seconds,
            "expected the torus to strictly beat the ring at N={n}: torus {} vs ring {}",
            torus.makespan_seconds,
            ring.makespan_seconds
        );
        metrics.insert(format!("fabric_ring_makespan_n{n}"), ring.makespan_seconds);
        metrics.insert(format!("fabric_torus_makespan_n{n}"), torus.makespan_seconds);
        metrics.insert(
            format!("fabric_torus_win_n{n}"),
            ring.makespan_seconds / torus.makespan_seconds,
        );
    }

    // --- (b) reduction overlap saves >= 10% somewhere -------------------
    println!("\n=== compute-overlapped reduction vs barrier schedule (d=8192, N=8) ===");
    let mut max_saving = 0.0f64;
    for topology in [Topology::ring(8), Topology::torus2d(4, 2)] {
        for c in [4u64, 8] {
            let plan = PartitionPlan::new(
                PartitionStrategy::Summa25D { p: 2, q: 2, c },
                8192,
                8192,
                8192,
            )
            .map_err(anyhow::Error::msg)?;
            let sim = ClusterSim::builder(Fleet::homogeneous(8, &id).map_err(anyhow::Error::msg)?)
                .topology(topology.clone())
                .build();
            let rep = sim.overlap_report(&plan, Some(ReduceAlgo::Direct));
            println!(
                "{:>6} c={c}: overlapped {:.4} s vs barrier {:.4} s -> {:.1}% saved \
                 (reduction {:.4} s)",
                topology.name(),
                rep.overlapped_makespan_seconds,
                rep.barrier_makespan_seconds,
                rep.saving_fraction() * 100.0,
                rep.reduction_seconds,
            );
            max_saving = max_saving.max(rep.saving_fraction());
        }
    }
    println!("best overlap saving: {:.1}%", max_saving * 100.0);
    anyhow::ensure!(
        max_saving >= 0.10,
        "expected >= 10% makespan saving from reduction overlap, best {:.1}%",
        max_saving * 100.0
    );
    metrics.insert("fabric_overlap_saving".into(), max_saving);

    if let Some(path) = args.get("json") {
        systo3d::util::json::write_metrics(path, &metrics)?;
        println!("wrote {} metric(s) to {path}", metrics.len());
    }

    println!("\nfabric_topology_sweep OK");
    Ok(())
}
