//! Fleet observatory acceptance sweep: chaos-validated anomaly
//! localization and SLO burn-rate growth versus a sleeping watermark.
//!
//! Two sections, mirroring the `observe` test suite but sized for CI:
//!
//! * **localization** — seeded slow-link / queue-spike fault plans are
//!   injected into a fixed 8-card fleet across ring, torus and
//!   fat-tree fabrics; the anomaly localizer must name the offending
//!   cable or card from the trace alone. Recall and precision are
//!   computed against the injected plan and **hard-asserted at 1.0**
//!   (the perf-gate floors exist so a regression shows up as a metric,
//!   not just a red example).
//! * **SLO burn vs watermark** — an overload trace (a 3 s background
//!   tenant on card 0) on which pending depth never crosses the armed
//!   watermark, so queue-depth elasticity does nothing; the p99
//!   burn-rate monitor alerts, grows the fleet, and must strictly
//!   shorten the makespan. The gain is emitted as `observe_slo_gain`.
//!
//! ```sh
//! cargo run --release --example fleet_observatory [-- --seeds 8 --json OUT.json]
//! ```
//!
//! `--json FILE` writes the detector scores and the SLO gain as a flat
//! JSON object for the CI perf gate.

use std::collections::{BTreeMap, BTreeSet};
use systo3d::cli::Args;
use systo3d::cluster::{
    run_elastic_schedule_traced, ElasticConfig, Fault, FaultPlan, FleetEvent, Link,
    PartitionPlan, PartitionStrategy, Shard, SloPolicy,
};
use systo3d::fabric::Topology;
use systo3d::observe::{anomaly, Observatory};
use systo3d::trace::Tracer;

const HORIZON: f64 = 10.0;
const CARDS: usize = 8;

/// Ground truth from the injected plan: slow links whose cable exists
/// on this fabric (normalized a <= b), and spiked cards.
fn injected(faults: &FaultPlan, topo: &Topology) -> (BTreeSet<(usize, usize)>, BTreeSet<usize>) {
    let mut links = BTreeSet::new();
    let mut cards = BTreeSet::new();
    for f in &faults.faults {
        match *f {
            Fault::SlowLink { a, b, .. } => {
                if topo.edges.iter().any(|e| (e.a, e.b) == (a, b) || (e.a, e.b) == (b, a)) {
                    links.insert(if a <= b { (a, b) } else { (b, a) });
                }
            }
            Fault::SpikeQueue { card, .. } => {
                cards.insert(card);
            }
            Fault::Kill { .. } => {}
        }
    }
    (links, cards)
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let fast = std::env::var("SYSTO3D_BENCH_FAST").as_deref() == Ok("1");
    let default_seeds = if fast { 8 } else { 16 };
    let seeds = args.get_u64("seeds", default_seeds).map_err(anyhow::Error::msg)?;
    let mut metrics: BTreeMap<String, f64> = BTreeMap::new();

    println!("=== fleet observatory: chaos-validated anomaly localization ===\n");
    // 256 row-shards over 8 cards at 0.5 s flat compute: every lane is
    // busy wall to wall, so a stall has nowhere to hide and a healthy
    // lane's interior gaps are ~one DMA.
    let plan = PartitionPlan::new(PartitionStrategy::Row1D { devices: 256 }, 4096, 4096, 4096)
        .map_err(anyhow::Error::msg)?;
    let host = Link::pcie_gen3_x8();
    let fixed = ElasticConfig { hot_spares: 0, scale_watermark: None, max_growth: 0, slo: None };
    let gap_threshold = 0.1 * HORIZON;
    // tp: anomalies both injected and flagged; fn_: injected but
    // missed; fp: flagged but never injected.
    let (mut tp, mut fn_, mut fp) = (0usize, 0usize, 0usize);
    for topo in [Topology::ring(CARDS), Topology::torus2d(4, 2), Topology::fat_tree(CARDS)] {
        for seed in 0..seeds {
            // Keep the slow-link / spike faults, drop the kills: deaths
            // are chaos.rs territory and a healed fabric removes the
            // very cable a slow-link fault would have degraded.
            let seeded = FaultPlan::seeded(seed, CARDS, HORIZON);
            let faults = FaultPlan {
                faults: seeded
                    .faults
                    .into_iter()
                    .filter(|f| !matches!(f, Fault::Kill { .. }))
                    .collect(),
            };
            let (want_links, want_cards) = injected(&faults, &topo);
            let tracer = Tracer::recording();
            run_elastic_schedule_traced(
                &plan,
                CARDS,
                &host,
                &topo,
                &faults,
                fixed,
                &tracer,
                |_: usize, _: &Shard| 0.5,
            )
            .map_err(anyhow::Error::msg)?;
            let found = anomaly::localize(&tracer.take(), gap_threshold);
            let found_links: BTreeSet<(usize, usize)> =
                found.slow_links.iter().map(|l| (l.a, l.b)).collect();
            let found_cards: BTreeSet<usize> =
                found.stalled_cards.iter().map(|c| c.card).collect();
            tp += found_links.intersection(&want_links).count()
                + found_cards.intersection(&want_cards).count();
            fn_ += want_links.difference(&found_links).count()
                + want_cards.difference(&found_cards).count();
            fp += found_links.difference(&want_links).count()
                + found_cards.difference(&want_cards).count();
        }
        println!(
            "  {:<8} {seeds} seed(s): cumulative tp {tp}, missed {fn_}, spurious {fp}",
            topo.name()
        );
    }
    anyhow::ensure!(tp > 0, "the sweep never injected an observable fault");
    let recall = tp as f64 / (tp + fn_) as f64;
    let precision = tp as f64 / (tp + fp) as f64;
    println!("\n  detector recall {recall:.3}, precision {precision:.3} over {tp} anomaly(ies)");
    anyhow::ensure!(recall == 1.0, "localizer missed {fn_} injected fault(s)");
    anyhow::ensure!(precision == 1.0, "localizer flagged {fp} spurious anomaly(ies)");
    metrics.insert("observe_detector_recall".into(), recall);
    metrics.insert("observe_detector_precision".into(), precision);

    println!("\n=== fleet observatory: SLO burn-rate growth vs a sleeping watermark ===\n");
    // 32 row-shards at 1 s flat compute over 2 cards: steady shard
    // latency is ~2 s, so the 2.5 s p99 target is healthy until a 3 s
    // background tenant lands on card 0 — a latency burn that never
    // pushes pending depth past the watermark.
    let load = PartitionPlan::new(PartitionStrategy::Row1D { devices: 32 }, 1024, 1024, 1024)
        .map_err(anyhow::Error::msg)?;
    let topo = Topology::ring(2);
    let faults =
        FaultPlan { faults: vec![Fault::SpikeQueue { card: 0, busy_seconds: 3.0, seconds: 0.01 }] };
    let policy = SloPolicy {
        p99_latency_s: 2.5,
        window_s: 2.0,
        long_windows: 2,
        burn_threshold: 0.25,
        max_growth: 2,
    };
    let control_cfg =
        ElasticConfig { hot_spares: 0, scale_watermark: Some(20.0), max_growth: 2, slo: None };
    let flat = |_: usize, _: &Shard| 1.0;
    let control = run_elastic_schedule_traced(
        &load,
        2,
        &host,
        &topo,
        &faults,
        control_cfg,
        &Tracer::off(),
        flat,
    )
    .map_err(anyhow::Error::msg)?;
    anyhow::ensure!(control.grown_cards == 0, "the watermark must sleep through this trace");

    let slo_cfg = ElasticConfig { slo: Some(policy), ..control_cfg };
    let slo_trace = Tracer::recording();
    let slo = run_elastic_schedule_traced(&load, 2, &host, &topo, &faults, slo_cfg, &slo_trace, flat)
        .map_err(anyhow::Error::msg)?;
    let gain = control.schedule.makespan_seconds / slo.schedule.makespan_seconds;
    println!(
        "  watermark-only makespan {:.4} s (grew {})\n\
         \x20 SLO-armed      makespan {:.4} s (burn grew {}, {} alert(s))  gain {gain:.3}x",
        control.schedule.makespan_seconds,
        control.grown_cards,
        slo.schedule.makespan_seconds,
        slo.slo_grown_cards,
        slo.slo_alerts.len(),
    );
    for e in slo.events.iter().filter(|e| matches!(e, FleetEvent::SloGrown { .. })) {
        println!("    event: {e:?}");
    }
    anyhow::ensure!(slo.slo_grown_cards >= 1, "the burn must grow the fleet");
    anyhow::ensure!(
        slo.schedule.makespan_seconds < control.schedule.makespan_seconds,
        "SLO growth must strictly beat queue-depth-only elasticity: {} vs {}",
        slo.schedule.makespan_seconds,
        control.schedule.makespan_seconds
    );
    metrics.insert("observe_slo_gain".into(), gain);
    metrics.insert("observe_slo_alerts".into(), slo.slo_alerts.len() as f64);

    let log = slo_trace.take();
    let obs = Observatory::from_trace(&log, 1.0);
    println!("\n{}", obs.render_dashboard(48));

    if let Some(path) = args.get("json") {
        systo3d::util::json::write_metrics(path, &metrics)?;
        println!("wrote {} metric(s) to {path}", metrics.len());
    }

    println!("\nfleet_observatory OK");
    Ok(())
}
