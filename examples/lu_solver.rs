//! Numerical-solver example — the paper's §VII future work, implemented:
//! blocked LU factorization and Newton–Schulz inversion whose O(n³)
//! work runs as systolic-engine GEMMs.
//!
//! Prints, for growing problem sizes, the share of FLOPs that lands on
//! the (simulated) accelerator and the simulated FPGA time of the GEMM
//! stream — the quantitative case for "solvers entirely in FPGA logic".
//!
//! ```sh
//! cargo run --release --example lu_solver
//! ```

use systo3d::blocked::{Level1Blocking, OffchipDesign};
use systo3d::gemm::{matmul_blocked, Matrix};
use systo3d::memory::layout::transpose_f32;
use systo3d::solver::{blocked_lu, invert};
use systo3d::systolic::ArraySize;

fn dd_matrix(n: usize, seed: u64) -> Matrix {
    let mut m = Matrix::random(n, n, seed);
    for i in 0..n {
        let v = m.at(i, i);
        m.set(i, i, v + n as f32);
    }
    m
}

fn main() -> anyhow::Result<()> {
    // A scaled design with the G-geometry so small trailing blocks
    // conform to the blocking (the full design needs d1=512 multiples).
    let design = OffchipDesign {
        blocking: Level1Blocking::new(ArraySize::new(16, 16, 4, 2), 64, 64),
        fmax_mhz: 398.0,
        controller_efficiency: 0.97,
    };

    println!("=== blocked LU (panel on host, trailing update on accelerator) ===");
    println!(
        "{:>6} {:>5} | {:>12} {:>12} {:>8} | {:>10} {:>12}",
        "n", "nb", "GEMM FLOPs", "host FLOPs", "accel%", "recon err", "sim FPGA (s)"
    );
    for n in [64usize, 128, 256, 512] {
        let a = dd_matrix(n, n as u64);
        let rep = blocked_lu(&a, 64.min(n / 2), Some(design));
        let err = rep.reconstruct().rel_fro_error(&a);
        anyhow::ensure!(err < 1e-3, "LU reconstruction failed at n={n}: {err}");
        println!(
            "{:>6} {:>5} | {:>12} {:>12} {:>7.1}% | {:>10.2e} {:>12.6}",
            n,
            rep.nb,
            rep.gemm_flops,
            rep.host_flops,
            rep.accel_share() * 100.0,
            err,
            rep.sim_fpga_seconds
        );
    }

    println!("\n=== Newton–Schulz inversion (pure chained GEMMs) ===");
    println!(
        "{:>6} | {:>5} {:>12} {:>12} {:>12}",
        "n", "iters", "residual", "GEMM FLOPs", "sim FPGA (s)"
    );
    for n in [64usize, 128, 256] {
        // SPD + n·I: safely inside the convergence region.
        let m = Matrix::random(n, n, 7 + n as u64);
        let mt = Matrix::from_vec(n, n, transpose_f32(&m.data, n, n));
        let mut a = matmul_blocked(&m, &mt);
        for i in 0..n {
            let v = a.at(i, i) + n as f32;
            a.set(i, i, v);
        }
        let rep = invert(&a, 1e-5, 80, Some(design));
        anyhow::ensure!(rep.residual < 1e-4, "inversion stalled at n={n}");
        println!(
            "{:>6} | {:>5} {:>12.2e} {:>12} {:>12.6}",
            n, rep.iterations, rep.residual, rep.gemm_flops, rep.sim_fpga_seconds
        );
    }

    println!("\nlu_solver OK — the O(n³) work rides the systolic engine, as §VII envisions");
    Ok(())
}
