//! Off-chip matrix multiplication, end to end through the simulator
//! stack — with cross-validation between the three model tiers:
//!
//! * tier 1: the cycle-accurate 3D array (`systolic::Array3dSim`),
//! * tier 2: the event-level phase simulator (`blocked::OffchipSim`)
//!   in functional mode (bitwise-identical accumulation),
//! * tier 3: the closed-form model (eq. 19).
//!
//! Then it runs the full Table II/V sweeps and prints the phase
//! timeline (Figure 3) for the chosen design.
//!
//! ```sh
//! cargo run --release --example offchip_sim [-- --design G --d2 4096]
//! ```

use systo3d::blocked::{OffchipDesign, OffchipSim};
use systo3d::cli::Args;
use systo3d::dse::paper_catalog;
use systo3d::gemm::Matrix;
use systo3d::reports;
use systo3d::systolic::{Array3dSim, ArraySize};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let d2 = args.get_u64("d2", 4096).map_err(anyhow::Error::msg)?;

    // --- cross-validation on a scaled-down geometry ---------------------
    let small = ArraySize::new(8, 4, 4, 2);
    let blocking = systo3d::blocked::Level1Blocking::new(small, 16, 16);
    let design = OffchipDesign { blocking, fmax_mhz: 400.0, controller_efficiency: 0.97 };
    let a = Matrix::random(32, 16, 11);
    let b = Matrix::random(16, 32, 12);
    let ev = OffchipSim::new(design).simulate_functional(&a, &b);
    let want = systo3d::gemm::matmul(&a, &b);
    let err = ev.c.as_ref().unwrap().rel_fro_error(&want);
    println!("tier-2 functional vs GEMM oracle: rel err {err:.2e}");
    assert!(err < 1e-5);

    // Tier 1 vs tier 2 on one level-1 block: bitwise agreement.
    let a1 = Matrix::random(8, 8, 13);
    let b1 = Matrix::random(8, 4, 14);
    let cy = Array3dSim::new(small).multiply(&a1, &b1);
    let blocking1 = systo3d::blocked::Level1Blocking::new(small, 8, 4);
    let ev1 = OffchipSim::new(OffchipDesign { blocking: blocking1, ..design })
        .simulate_functional(&a1, &b1);
    assert_eq!(cy.c.data, ev1.c.unwrap().data, "tier 1 and tier 2 accumulation differ");
    println!("tier-1 (cycle) vs tier-2 (event) accumulation: bitwise identical");

    // --- the requested design at the requested size ---------------------
    let spec = paper_catalog()
        .into_iter()
        .find(|d| d.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown design {id}"))?;
    let blocking = spec
        .level1()
        .ok_or_else(|| anyhow::anyhow!("design {id} failed the fitter"))?;
    anyhow::ensure!(
        d2 % blocking.di1 as u64 == 0,
        "d2 must be a multiple of {} for design {id}",
        blocking.di1
    );
    let sim = OffchipSim::new(OffchipDesign {
        blocking,
        fmax_mhz: spec.fmax_mhz.unwrap(),
        controller_efficiency: 0.97,
    });
    let dj2 = blocking.scale_dj2(d2);
    let r = sim.simulate(d2, dj2, d2);
    println!(
        "design {id} @ {d2}: {:.0} GFLOPS, e_D {:.3}, {:.4} s kernel time, c% {:.3}",
        r.gflops, r.e_d, r.seconds, r.compute_fraction
    );

    // --- the design's full published sweep ------------------------------
    if let Some(t) = reports::table_design_sweep(&id) {
        println!("{t}");
    } else {
        println!("{}", reports::table5());
    }

    // --- Figure 3 timeline ----------------------------------------------
    println!("{}", reports::figure3(d2.min(4096)));
    Ok(())
}
