//! Placement gain sweep: the 2.5D plan's devices mapped onto ring and
//! torus cards by the topology-aware placement optimizer, versus the
//! identity (plane-major) layout.
//!
//! For each (topology, N) the optimizer replays the plan's partial-C
//! reduction sends under the link-contention model — shared links
//! serialize, disjoint links parallelize — and searches device→card
//! maps with the greedy plane-packer plus the seeded local search. Two
//! things are asserted so CI enforces the placement story end to end:
//!
//! (a) the local-search placement **strictly** reduces the
//!     contention-priced reduction cost vs identity placement on ring
//!     and torus at N = 16 and N = 32 (the acceptance criterion), and
//! (b) its hop-bytes never exceed identity's (the dominance the
//!     property tests also pin down).
//!
//! A second pair of columns shows the end-to-end simulated makespans
//! of the identity vs placed plan on the same fleet.
//!
//! ```sh
//! cargo run --release --example placement_gain [-- --d2 21504 --design G --json OUT.json]
//! ```
//!
//! `--json FILE` additionally writes the gains as a flat JSON object
//! for the CI perf gate.

use std::collections::BTreeMap;
use systo3d::cli::Args;
use systo3d::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::Topology;
use systo3d::placement::{optimize, PlacementStrategy};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let d2 = args.get_u64("d2", 21504).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let mut metrics: BTreeMap<String, f64> = BTreeMap::new();

    println!("=== placement gain: 2.5D plan, optimizer vs identity layout ===\n");
    println!(
        "{:>2} {:>6} {:>12} {:>12} {:>7} {:>9} {:>11} {:>11}",
        "N", "fabric", "identity s", "placed s", "gain", "hops -%", "id span s", "placed s"
    );

    for &n in &[16usize, 32] {
        let plan = PartitionPlan::new(
            PartitionStrategy::auto_summa25d(n as u64),
            d2,
            d2,
            d2,
        )
        .map_err(anyhow::Error::msg)?;
        for topology in [Topology::ring(n), Topology::torus_near_square(n)] {
            let tname = topology.name();
            let rep = optimize(&plan, &topology, PlacementStrategy::default());
            let packed = optimize(&plan, &topology, PlacementStrategy::PlanePacked);

            // End-to-end makespans: same fleet, identity vs placed plan.
            let fleet = Fleet::homogeneous(n, &id).map_err(anyhow::Error::msg)?;
            let sim = ClusterSim::builder(fleet)
                .topology(topology)
                .placement(PlacementStrategy::Identity)
                .build();
            let identity_span = sim.simulate(&plan).makespan_seconds;
            let placed_plan = rep.placement.apply_to(&plan);
            let placed_span = sim.simulate(&placed_plan).makespan_seconds;

            println!(
                "{:>2} {:>6} {:>12.4} {:>12.4} {:>6.2}x {:>8.0}% {:>11.4} {:>11.4}",
                n,
                tname,
                rep.identity_cost_seconds,
                rep.placed_cost_seconds,
                rep.gain(),
                rep.hop_byte_saving() * 100.0,
                identity_span,
                placed_span,
            );

            // (a) the acceptance criterion: strict contention-cost win.
            anyhow::ensure!(
                rep.placed_cost_seconds < rep.identity_cost_seconds,
                "local search must strictly beat identity on {tname} at N={n}: \
                 placed {} vs identity {}",
                rep.placed_cost_seconds,
                rep.identity_cost_seconds
            );
            // (b) hop-byte dominance, for the search and the greedy pass.
            anyhow::ensure!(rep.placed_hop_bytes <= rep.identity_hop_bytes);
            anyhow::ensure!(packed.placed_cost_seconds <= packed.identity_cost_seconds);
            anyhow::ensure!(packed.placed_hop_bytes <= packed.identity_hop_bytes);

            metrics.insert(format!("placement_gain_{tname}_n{n}"), rep.gain());
            metrics
                .insert(format!("placement_hop_saving_{tname}_n{n}"), rep.hop_byte_saving());
            metrics.insert(format!("placement_makespan_{tname}_n{n}"), placed_span);
        }
    }

    if let Some(path) = args.get("json") {
        systo3d::util::json::write_metrics(path, &metrics)?;
        println!("\nwrote {} metric(s) to {path}", metrics.len());
    }

    println!("\nplacement_gain OK");
    Ok(())
}
