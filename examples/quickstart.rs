//! Quickstart: the 60-second tour of the public API.
//!
//! 1. Evaluate a systolic design through the synthesis models.
//! 2. Run the cycle-accurate 3D array on a small on-chip multiply.
//! 3. Simulate a full off-chip multiply (a Table-V cell).
//! 4. If `make artifacts` has run, execute the same math through the
//!    AOT-compiled XLA artifact via PJRT and check it.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use systo3d::blocked::{Level1Blocking, OffchipDesign, OffchipSim};
use systo3d::dse::Explorer;
use systo3d::gemm::{matmul, Matrix};
use systo3d::runtime::Engine;
use systo3d::systolic::{Array3dSim, ArraySize};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // --- 1. synthesis models -------------------------------------------
    let array = ArraySize::new(64, 32, 2, 2); // the paper's design G
    let point = Explorer::default().evaluate(array);
    println!(
        "design G: {} DSPs, fits={}, fmax={:?} MHz, Tpeak={:?} GFLOPS",
        array.dsps(),
        point.outcome.fits(),
        point.fmax_mhz,
        point.tpeak_gflops.map(|t| t.round())
    );

    // --- 2. cycle-accurate on-chip multiply ----------------------------
    let small = ArraySize::new(8, 8, 4, 2);
    let a = Matrix::random(8, 32, 1);
    let b = Matrix::random(32, 8, 2);
    let run = Array3dSim::new(small).multiply(&a, &b);
    let err = run.c.rel_fro_error(&matmul(&a, &b));
    println!(
        "cycle sim: {} MACs in {} cycles across {} wave steps/call, rel err {err:.2e}",
        run.total_macs, run.cycles, run.wave_steps_per_call
    );
    assert!(err < 1e-5);

    // --- 3. off-chip simulation (Table V, design G, d2=4096) -----------
    let design = OffchipDesign {
        blocking: Level1Blocking::new(array, 512, 512),
        fmax_mhz: point.fmax_mhz.unwrap(),
        controller_efficiency: 0.97,
    };
    let report = OffchipSim::new(design).simulate(4096, 4096, 4096);
    println!(
        "off-chip sim 4096³: {:.0} GFLOPS, e_D = {:.2} (paper: 2912, 0.89)",
        report.gflops, report.e_d
    );

    // --- 4. PJRT artifact execution ------------------------------------
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let mut engine = Engine::new(dir)?;
        let a = Matrix::random(64, 64, 3);
        let b = Matrix::random(64, 64, 4);
        let (c, stats) = engine.execute("mm_h_64", &[&a, &b])?;
        let err = c.rel_fro_error(&matmul(&a, &b));
        println!(
            "PJRT ({}): mm_h_64 in {:.2} ms, rel err {err:.2e}",
            engine.platform(),
            stats.exec_seconds * 1e3
        );
        assert!(err < 1e-4);
    } else {
        println!("(artifacts/ not built — run `make artifacts` for the PJRT leg)");
    }

    println!("quickstart OK");
    Ok(())
}
