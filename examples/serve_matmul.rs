//! END-TO-END DRIVER — the full system on a real workload.
//!
//! Streams a mixed batch of GEMM jobs (the inference-style workload the
//! paper's introduction motivates: repeated medium-size SGEMMs, some
//! chained A·B·C) through the L3 coordinator:
//!
//!   client stream → batcher → router → PJRT engine thread
//!                                        (AOT artifacts from L2/L1)
//!                              ↘ per-request FPGA timing simulation
//!
//! proving all layers compose: the Pallas kernel (L1) lowered through
//! the JAX model (L2) executes under the Rust coordinator (L3) with
//! Python nowhere on the request path. Every result is checked against
//! the GEMM oracle, and the run reports serving latency/throughput plus
//! the simulated-FPGA aggregate — the paper's headline metric — for the
//! same stream. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_matmul [-- --requests 48]
//! ```

use systo3d::cli::Args;
use systo3d::coordinator::{GemmRequest, GemmService, Route, ServiceConfig, WorkloadGen};
use systo3d::gemm::{matmul_blocked, Matrix};
use systo3d::perfmodel::flop_count;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let n_requests = args.get_u64("requests", 48).map_err(anyhow::Error::msg)?;
    let artifact_dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
    anyhow::ensure!(
        artifact_dir.join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );

    let svc = GemmService::start(ServiceConfig {
        artifact_dir: Some(artifact_dir),
        max_batch: 8,
        batch_window: Duration::from_millis(2),
        ..Default::default()
    })?;

    // Workload: the default serving trace — artifact-backed 256³/512³/64³
    // jobs, chained (A·B)·C jobs, and odd 96³ fallback shapes, with
    // Poisson arrivals (run open-loop here; the trace records the
    // offered load).
    let trace = WorkloadGen::serving_default(2026, 50.0).trace(n_requests);
    let offered = WorkloadGen::offered_flops(&trace) / 1e9;
    let mut inflight = Vec::new();
    let t0 = Instant::now();
    for e in &trace {
        let id = e.id;
        let a = Matrix::random(e.m, e.k, id * 3 + 1);
        let b = Matrix::random(e.k, e.n, id * 3 + 2);
        let c = e.chained.then(|| Matrix::random(e.n, e.n, id * 3 + 3));
        // Keep copies for verification.
        let (va, vb, vc) = (a.clone(), b.clone(), c.clone());
        let mut req = GemmRequest::new(a, b).id(id);
        req.chain = c;
        let rx = svc.submit(req);
        inflight.push((id, rx, va, vb, vc));
    }

    let mut artifact_jobs = 0u64;
    let mut fallback_jobs = 0u64;
    let mut sharded_jobs = 0u64;
    let mut strassen_jobs = 0u64;
    let mut sim_fpga_seconds = 0.0;
    let mut sim_fpga_flops = 0u64;
    let mut checked = 0u64;
    for (id, rx, va, vb, vc) in inflight {
        let resp = rx.recv()?;
        let got = resp.result.map_err(anyhow::Error::msg)?;
        match resp.route {
            Route::Artifact(_) => artifact_jobs += 1,
            Route::Fallback => fallback_jobs += 1,
            Route::Sharded => sharded_jobs += 1,
            Route::Strassen => strassen_jobs += 1,
        }
        // Verify every result against the oracle.
        let mut want = matmul_blocked(&va, &vb);
        if let Some(c) = &vc {
            want = matmul_blocked(&want, c);
        }
        let err = got.rel_fro_error(&want);
        anyhow::ensure!(err < 1e-4, "request {id}: rel err {err}");
        checked += 1;
        if let Some(sim) = resp.fpga_sim {
            sim_fpga_seconds += sim.seconds;
            sim_fpga_flops += flop_count(sim.di2, sim.dj2, sim.dk2);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = svc.metrics.snapshot();
    let lat = svc.metrics.latency_report_line();

    println!("=== serve_matmul end-to-end report ===");
    println!("requests:           {n_requests} ({checked} verified against oracle)");
    println!("offered load:       {offered:.2} GFLOPS at the trace's 50 req/s arrival rate");
    println!("wall time:          {wall:.3} s  ({:.1} req/s)", n_requests as f64 / wall);
    println!(
        "routes:             {artifact_jobs} artifact (PJRT), {fallback_jobs} fallback (CPU GEMM), \
         {sharded_jobs} sharded (cluster), {strassen_jobs} strassen"
    );
    println!("batches:            {}", snap.batches);
    println!("host throughput:    {:.2} GFLOPS functional", snap.flops as f64 / wall / 1e9);
    println!("latency:            {lat}");
    if sim_fpga_seconds > 0.0 {
        println!(
            "simulated FPGA:     {:.4} s for the conforming subset -> {:.0} GFLOPS \
             (the paper's headline metric on this stream)",
            sim_fpga_seconds,
            sim_fpga_flops as f64 / sim_fpga_seconds / 1e9
        );
    }
    anyhow::ensure!(snap.errors == 0, "service reported errors");
    anyhow::ensure!(artifact_jobs > 0, "no artifact-backed jobs ran — is the manifest stale?");
    println!("serve_matmul OK");
    Ok(())
}
