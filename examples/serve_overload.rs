//! Serving-front-end acceptance drill: deadline-aware admission
//! versus FIFO/fixed-window under sustained overload.
//!
//! Section 1 (the PR-9 acceptance criterion) replays a seeded
//! three-tenant trace (gold w3/High/50 ms, silver w2/Normal/100 ms,
//! bronze w1/Low/200 ms) at 3x the capacity of a 2-card fleet with
//! one hot spare, through both pipelines on the same trace:
//!
//! * **deadline-aware** — bounded ingress with lane-aware doomed
//!   shedding, priority lanes, DRR weighted fair share, and batch
//!   closes pulled by the oldest member's deadline slack;
//! * **FIFO baseline** — one strict arrival-order queue, fixed
//!   window, everything admitted runs however late.
//!
//! The example asserts that in the same run the aware pipeline
//! strictly beats FIFO on goodput (deadline-met FLOP/s), sheds under
//! overload instead of letting p99 collapse (aware p99 strictly below
//! FIFO's bufferbloat p99), and that sustained queue pressure burns
//! the SLO monitor into growing the fleet (hot spare first, then a
//! new card). Section 2 saturates three same-priority tenants
//! weighted 3:2:1 with equal job sizes and checks the DRR drain holds
//! served shares to the weights while every tenant is backlogged.
//!
//! ```sh
//! cargo run --release --example serve_overload [-- --requests 80000 --factor 3.0 --json OUT.json]
//! ```
//!
//! `--json FILE` additionally writes the gains as a flat JSON object
//! for the CI perf gate.

use std::collections::BTreeMap;
use systo3d::cli::Args;
use systo3d::coordinator::{
    simulate_serve, simulate_serve_trace, AdmissionPolicy, Priority, ServeConfig, TenantSpec,
    WorkloadGen,
};
use systo3d::observe::slo::SloPolicy;
use systo3d::perfmodel::flop_count;

/// Mean request rate hitting `factor` times the fleet's closed-form
/// capacity (the multi-tenant mix serves fixed 256^3 jobs).
fn overload_rate_hz(cfg: &ServeConfig, factor: f64) -> f64 {
    let per_job_s = flop_count(256, 256, 256) as f64 / (cfg.card_gflops * 1e9)
        + cfg.dispatch_overhead_s / cfg.max_batch as f64;
    factor * cfg.servers as f64 / per_job_s
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let requests = args.get_u64("requests", 80_000).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 7).map_err(anyhow::Error::msg)?;
    let factor: f64 = match args.get("factor") {
        None => 3.0,
        Some(v) => {
            v.parse().map_err(|_| anyhow::anyhow!("--factor expects a float, got {v:?}"))?
        }
    };
    let mut metrics: BTreeMap<String, f64> = BTreeMap::new();

    println!("=== serve: deadline-aware admission vs FIFO at {factor:.1}x overload ===\n");
    let cfg = ServeConfig {
        servers: 2,
        hot_spares: 1,
        policy: AdmissionPolicy {
            // Deep enough that FIFO's backlog is never clipped by
            // drop-tail: its collapse must come from bufferbloat.
            queue_capacity: 65_536,
            shed_doomed: true,
            latency_target_s: Some(0.05),
            ..Default::default()
        },
        pressure_watermark: Some(0.002),
        slo: SloPolicy {
            window_s: 0.005,
            long_windows: 4,
            burn_threshold: 0.5,
            max_growth: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    let gen = WorkloadGen::multi_tenant(seed, overload_rate_hz(&cfg, factor));
    let aware = simulate_serve(&gen, requests, &cfg);
    println!("deadline-aware (lanes + DRR + doomed shed + SLO-pulled closes):");
    print!("{}", aware.render());
    let fifo = simulate_serve(&gen, requests, &ServeConfig { deadline_aware: false, ..cfg });
    println!("\nFIFO / fixed-window baseline (same trace, same fleet):");
    print!("{}", fifo.render());

    let goodput_gain = aware.goodput_flops_per_s / fifo.goodput_flops_per_s.max(1.0);
    println!(
        "\ngoodput gain {goodput_gain:.2}x; shed rate {:.1}% vs {:.1}%; \
         p99 {:.2} ms vs {:.2} ms",
        100.0 * aware.shed_rate(),
        100.0 * fifo.shed_rate(),
        aware.p99_s * 1e3,
        fifo.p99_s * 1e3,
    );

    // Acceptance: strictly more goodput, shed instead of bufferbloat,
    // and pressure-driven growth, all in the same aware run.
    anyhow::ensure!(
        aware.goodput_flops_per_s > fifo.goodput_flops_per_s,
        "deadline-aware must strictly beat FIFO on goodput: {:.3e} vs {:.3e}",
        aware.goodput_flops_per_s,
        fifo.goodput_flops_per_s
    );
    anyhow::ensure!(!aware.shed.is_empty(), "overload must shed at the door");
    anyhow::ensure!(
        aware.p99_s < fifo.p99_s,
        "shedding must hold p99 below FIFO bufferbloat: {:.4} vs {:.4}",
        aware.p99_s,
        fifo.p99_s
    );
    anyhow::ensure!(
        aware.spare_activations == 1,
        "sustained queue pressure must activate the hot spare first"
    );
    anyhow::ensure!(
        aware.grown_cards >= 1,
        "pressure past the spare must grow a new card: {:?}",
        aware.events
    );

    // The run scrapes like live traffic.
    let m = systo3d::coordinator::Metrics::new();
    aware.record_into(&m);
    let scrape = systo3d::observe::prometheus_text(&m.snapshot());
    anyhow::ensure!(
        scrape.contains("systo3d_admitted_total") && scrape.contains("systo3d_shed_total"),
        "admission gauges must land in the scrape"
    );

    metrics.insert("serve_goodput_gain".into(), goodput_gain);
    metrics.insert("serve_shed_rate".into(), aware.shed_rate());
    metrics.insert("serve_aware_p99_ms".into(), aware.p99_s * 1e3);
    metrics.insert("serve_fifo_p99_ms".into(), fifo.p99_s * 1e3);
    metrics.insert(
        "serve_grown_cards".into(),
        (aware.spare_activations + aware.grown_cards) as f64,
    );

    println!("\n=== serve: DRR weighted fair share under saturation ===\n");
    // Three tenants in one lane, weighted 3:2:1, all permanently
    // backlogged at 3x capacity on a fixed 2-card fleet: while the
    // queue is saturated, served service seconds must track the
    // weights — that is the deficit-round-robin guarantee.
    let fair_cfg = ServeConfig {
        servers: 2,
        policy: AdmissionPolicy { queue_capacity: 65_536, ..Default::default() },
        ..Default::default()
    };
    let mut fair_gen = WorkloadGen::multi_tenant(seed, overload_rate_hz(&fair_cfg, factor));
    fair_gen.tenants = vec![
        TenantSpec::new("w3", 3, Priority::Normal, None),
        TenantSpec::new("w2", 2, Priority::Normal, None),
        TenantSpec::new("w1", 1, Priority::Normal, None),
    ];
    let trace = fair_gen.trace(30_000);
    let cutoff = trace.last().expect("non-empty trace").arrival_s;
    let fair = simulate_serve_trace(&trace, &fair_gen.tenants, &fair_cfg);

    // Shares among requests finishing before the last arrival — the
    // window in which every tenant is still backlogged.
    let mut served_flops = [0.0f64; 3];
    for r in fair.served.iter().filter(|r| r.finish_s <= cutoff) {
        served_flops[r.tenant.min(2)] += r.flops as f64;
    }
    let total: f64 = served_flops.iter().sum();
    anyhow::ensure!(total > 0.0, "the saturated window must serve work");
    let mut fairness_bound = 0.0f64;
    for (t, w) in [(0usize, 3.0f64), (1, 2.0), (2, 1.0)] {
        let share = served_flops[t] / total;
        let fair_share = w / 6.0;
        let dev = (share - fair_share).abs() / fair_share;
        println!(
            "  tenant w{w} — saturated share {share:.3} vs fair {fair_share:.3} \
             (deviation {dev:.3})"
        );
        fairness_bound = fairness_bound.max(dev);
    }
    println!("  fairness bound {fairness_bound:.3} (whole run {:.3})", fair.fairness_bound());
    anyhow::ensure!(
        fairness_bound < 0.2,
        "DRR must hold saturated shares within 20% of the weights: {fairness_bound:.3}"
    );
    anyhow::ensure!(
        fair.tenants.iter().all(|t| t.completed > 0),
        "no tenant may be starved outright"
    );
    metrics.insert("serve_fairness_bound".into(), fairness_bound);

    if let Some(path) = args.get("json") {
        systo3d::util::json::write_metrics(path, &metrics)?;
        println!("\nwrote {} metric(s) to {path}", metrics.len());
    }

    println!("\nserve_overload OK");
    Ok(())
}
