//! Strassen crossover sweep: where does the recursion start beating
//! the classical schedule, and where does *effective* throughput pass
//! the DSP-bound eq. 5 peak?
//!
//! For each problem size the planner prices depths 0..=3 on one
//! Table-I design (leaves through the event-level off-chip simulator,
//! 18·d add/sub passes at aggregate DDR bandwidth) and picks the
//! fastest depth inside the default error budget. Effective GFLOPS
//! always uses the classical FLOP count, so ratios above 1.0 mean the
//! DSP ceiling was beaten algorithmically — the acceptance claim of
//! the Strassen subsystem. A second section runs the winning depth's
//! leaves over a 7-card fleet to show the recursion composing with the
//! cluster scheduler.
//!
//! ```sh
//! cargo run --release --example strassen_crossover [-- --design G --json OUT.json]
//! ```
//!
//! `--json FILE` additionally writes the headline metrics (best
//! effective-vs-peak ratio, crossover size, 7-card fleet GFLOPS) as a
//! flat JSON object for the CI perf gate.

use std::collections::BTreeMap;
use systo3d::blocked::OffchipDesign;
use systo3d::cli::Args;
use systo3d::cluster::{ClusterSim, Fleet};
use systo3d::dse::paper_catalog;
use systo3d::perfmodel::flop_count;
use systo3d::strassen::{self, StrassenConfig, TaskDag};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let spec = paper_catalog()
        .into_iter()
        .find(|d| d.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown design {id}"))?;
    let design = OffchipDesign {
        blocking: spec
            .level1()
            .ok_or_else(|| anyhow::anyhow!("design {id} failed the fitter"))?,
        fmax_mhz: spec.fmax_mhz.unwrap(),
        controller_efficiency: 0.97,
    };
    let peak = design.peak_gflops();
    let config = StrassenConfig::default();

    println!("=== strassen crossover: design {id}, eq. 5 peak {peak:.0} GFLOPS ===\n");
    println!(
        "{:>6} {:>12} {:>8} | {:>5} {:>12} {:>8} {:>8} {:>9}",
        "d", "classical s", "GFLOPS", "depth", "strassen s", "GFLOPS", "vs peak", "speedup"
    );

    let mut crossover = None;
    let mut best_ratio = 0.0f64;
    for d in [512u64, 1024, 2048, 4096, 8192, 16384, 21504, 32768] {
        let plan = strassen::plan(design, d, d, d, &config);
        let (cls, chosen) = (plan.classical(), plan.chosen());
        if plan.depth >= 1 && crossover.is_none() {
            crossover = Some(d);
        }
        best_ratio = best_ratio.max(plan.effective_vs_peak());
        println!(
            "{:>6} {:>12.4} {:>8.0} | {:>5} {:>12.4} {:>8.0} {:>8.3} {:>9.3}",
            d,
            cls.seconds,
            cls.effective_gflops,
            plan.depth,
            chosen.seconds,
            chosen.effective_gflops,
            plan.effective_vs_peak(),
            plan.speedup_vs_classical(),
        );
    }

    let crossover =
        crossover.ok_or_else(|| anyhow::anyhow!("no crossover found anywhere in the sweep"))?;
    println!("\nclassical/Strassen crossover at d = {crossover}");
    anyhow::ensure!(
        best_ratio > 1.0,
        "expected effective throughput past the eq. 5 peak somewhere in the sweep \
         (best ratio {best_ratio:.4})"
    );
    println!(
        "effective/peak maximum: {best_ratio:.3} — the DSP-bound ceiling is exceeded \
         algorithmically"
    );

    // --- composition: the winning depth's leaves over a 7-card fleet ---
    let d = 21504u64;
    let plan = strassen::plan(design, d, d, d, &config);
    let dag = TaskDag::build(d, d, d, plan.depth);
    let sim = ClusterSim::builder(Fleet::homogeneous(7, &id).map_err(anyhow::Error::msg)?).build();
    let (report, total) = dag
        .fleet_seconds(&sim)
        .ok_or_else(|| anyhow::anyhow!("no leaf plan for d={d}"))?;
    let eff = flop_count(d, d, d) as f64 / total / 1e9;
    println!(
        "\n=== composition: depth-{} leaves of the {d}^3 problem over 7 cards ===\n\
         end-to-end {total:.4} s -> {eff:.0} effective GFLOPS ({:.2}x one card's peak)\n",
        plan.depth,
        eff / peak,
    );
    println!("{}", report.render());
    anyhow::ensure!(total < plan.chosen().seconds, "the fleet should beat one card");

    if let Some(path) = args.get("json") {
        let mut metrics: BTreeMap<String, f64> = BTreeMap::new();
        metrics.insert("strassen_best_eff_vs_peak".into(), best_ratio);
        metrics.insert("strassen_crossover_d".into(), crossover as f64);
        metrics.insert("strassen_fleet7_gflops".into(), eff);
        systo3d::util::json::write_metrics(path, &metrics)?;
        println!("wrote {} metric(s) to {path}", metrics.len());
    }

    println!("strassen_crossover OK");
    Ok(())
}
