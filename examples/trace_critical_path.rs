//! FLIGHT-RECORDER DRIVER — what the critical path says about overlap.
//!
//! Replays one 2.5D plan through the pipelined fabric schedule twice —
//! reductions overlapped with compute, then the barrier baseline — with
//! a recording tracer on each replay, runs the critical-path analyzer
//! over both event streams, and checks the observability claim end to
//! end:
//!
//! * each trace's critical-path buckets sum to that replay's makespan
//!   (the analyzer's coverage invariant, to fp rounding);
//! * overlapping the reduction **shrinks the fabric category's share**
//!   of the critical path — the trace shows *where* the saved time
//!   came from, not just that the makespan dropped.
//!
//! ```sh
//! cargo run --release --example trace_critical_path [-- --d2 8192 --json OUT.json]
//! ```
//!
//! `--json FILE` writes the shares as a flat JSON object for the CI
//! perf gate.

use std::collections::BTreeMap;
use systo3d::cli::Args;
use systo3d::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::{pipeline_schedule_traced, ReduceAlgo, Topology};
use systo3d::trace::{critical_path, Tracer};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let d2 = args.get_u64("d2", 8192).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();

    // The overlap story needs partials to combine: a c=8 stacked 2.5D
    // carve on a ring keeps every reduction on the fabric.
    let plan = PartitionPlan::new(PartitionStrategy::Summa25D { p: 2, q: 2, c: 8 }, d2, d2, d2)
        .map_err(anyhow::Error::msg)?;
    let fleet = Fleet::homogeneous(8, &id).map_err(anyhow::Error::msg)?;
    let sim = ClusterSim::builder(fleet).topology(Topology::ring(8)).build();

    let over = Tracer::recording();
    let barr = Tracer::recording();
    let report = pipeline_schedule_traced(
        &plan,
        &sim.topology,
        Some(ReduceAlgo::Direct),
        &over,
        &barr,
        |d, s| sim.shard_seconds(d, s),
    );
    let co = critical_path(&over.take());
    let cb = critical_path(&barr.take());

    println!("=== trace_critical_path report (d2 = {d2}, ring of 8) ===");
    println!(
        "overlapped {:.4} s vs barrier {:.4} s ({:.1}% saved)\n",
        report.overlapped_makespan_seconds,
        report.barrier_makespan_seconds,
        report.saving_fraction() * 100.0
    );
    println!("--- overlapped replay ---");
    print!("{}", co.render(6));
    println!("--- barrier replay ---");
    print!("{}", cb.render(6));

    // Coverage: each trace's buckets sum to its replay's makespan.
    anyhow::ensure!(
        (co.makespan - report.overlapped_makespan_seconds).abs() < 1e-9
            && (co.total_seconds() - co.makespan).abs() < 1e-6,
        "overlapped trace does not cover its makespan"
    );
    anyhow::ensure!(
        (cb.makespan - report.barrier_makespan_seconds).abs() < 1e-9
            && (cb.total_seconds() - cb.makespan).abs() < 1e-6,
        "barrier trace does not cover its makespan"
    );
    // Attribution: the overlap hides fabric time from the critical path.
    let drop = cb.share("fabric") - co.share("fabric");
    anyhow::ensure!(
        drop > 0.0,
        "overlap must shrink the fabric share: overlapped {:.3} vs barrier {:.3}",
        co.share("fabric"),
        cb.share("fabric")
    );
    println!(
        "fabric share of the critical path: {:.1}% barrier -> {:.1}% overlapped \
         ({:.1} point drop)",
        cb.share("fabric") * 100.0,
        co.share("fabric") * 100.0,
        drop * 100.0
    );

    if let Some(path) = args.get("json") {
        let mut metrics: BTreeMap<String, f64> = BTreeMap::new();
        metrics.insert("trace_fabric_share_drop".into(), drop);
        metrics.insert("trace_barrier_fabric_share".into(), cb.share("fabric"));
        metrics.insert("trace_overlap_saving".into(), report.saving_fraction());
        systo3d::util::json::write_metrics(path, &metrics)?;
        println!("\nwrote {} metric(s) to {path}", metrics.len());
    }

    println!("\ntrace_critical_path OK");
    Ok(())
}
