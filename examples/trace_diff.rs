//! DIFFERENTIAL-OBSERVABILITY DRIVER — blame a regression, then name
//! the hot loop.
//!
//! Three acts, each an acceptance claim of PR 8:
//!
//! 1. **Determinism floor.** Two same-seed chaos replays produce
//!    byte-identical Chrome traces and an *empty* diff — the differ
//!    reports no noise on no change.
//! 2. **Regression attribution.** A clean run against the same run
//!    with its busiest cable degraded 16x: the diff must charge ≥90%
//!    of the makespan delta to fabric spans, name grown circuits on
//!    exactly that cable, and flag the `link_rate` counter track —
//!    with both attribution partitions (bucket and track) summing to
//!    the delta by construction.
//! 3. **Host profiler.** An armed placement search must rank the
//!    candidate-pricing inner loop as self-time top-1 and export it in
//!    the folded-stack format speedscope/inferno read.
//!
//! ```sh
//! cargo run --release --example trace_diff [-- --d2 8192 --factor 16 --json OUT.json]
//! ```
//!
//! Side artifacts for the CI failure path: `trace_baseline.json`,
//! `trace_candidate.json` (Chrome traces), `diff_blame.txt` (the blame
//! report), `profile_folded.txt` (folded stacks).

use std::collections::BTreeMap;
use systo3d::cli::Args;
use systo3d::cluster::{ClusterSim, Fault, FaultPlan, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::Topology;
use systo3d::placement::{optimize, PlacementStrategy};
use systo3d::trace::{
    chrome_trace_json, diff, profile, BlameEntry, DeltaKind, TraceLog, Tracer, Track,
};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let d2 = args.get_u64("d2", 8192).map_err(anyhow::Error::msg)?;
    let factor = args.get_str("factor", "16").parse::<f64>().unwrap_or(16.0);

    // Big shards keep the reduction sends visible on the wire: at
    // d2 = 8192 each partial is ~67 MB, so a slowed cable cannot hide
    // in scheduling slack.
    let plan = PartitionPlan::new(PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 }, d2, d2, d2)
        .map_err(anyhow::Error::msg)?;
    let run = |faults: &FaultPlan| -> anyhow::Result<TraceLog> {
        let fleet = Fleet::homogeneous(8, "G").map_err(anyhow::Error::msg)?;
        let sim = ClusterSim::builder(fleet)
            .topology(Topology::ring(8))
            .trace(Tracer::recording())
            .build();
        sim.simulate_elastic(&plan, faults).map_err(anyhow::Error::msg)?;
        Ok(sim.trace.snapshot())
    };

    println!("=== trace_diff report (d2 = {d2}, ring of 8, design G) ===\n");

    // --- Act 1: same-seed replays diff empty -------------------------
    let clean = run(&FaultPlan::none())?;
    let replay = run(&FaultPlan::none())?;
    let d0 = diff(&clean, &replay);
    anyhow::ensure!(
        d0.is_empty(),
        "same-seed replays must diff empty: delta {} s, {} blame entries",
        d0.makespan_delta(),
        d0.blame.len()
    );
    anyhow::ensure!(
        chrome_trace_json(&clean) == chrome_trace_json(&replay),
        "same-seed replays must serialize byte-identically"
    );
    println!(
        "act 1: replay determinism — {} spans matched, zero delta, byte-identical traces",
        d0.matched_spans
    );

    // --- Act 2: degrade the busiest cable, attribute the delta -------
    let mut cable_busy: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    for s in &clean.spans {
        if let Track::Link(a, b) = s.track {
            *cable_busy.entry((a.min(b), a.max(b))).or_insert(0.0) += s.end - s.start;
        }
    }
    let mut cable = (0, 0);
    let mut busiest = -1.0;
    for (&c, &busy) in &cable_busy {
        if busy > busiest {
            cable = c;
            busiest = busy;
        }
    }
    anyhow::ensure!(busiest > 0.0, "the clean replay must carry fabric traffic");
    let (la, lb) = cable;
    let degraded = run(&FaultPlan {
        faults: vec![Fault::SlowLink { a: la, b: lb, factor, seconds: 0.0 }],
    })?;

    let d = diff(&clean, &degraded);
    println!("\nact 2: cable {la}<->{lb} degraded {factor}x");
    print!("{}", d.render(10));
    anyhow::ensure!(d.makespan_delta() > 0.0, "a slowed cable must cost makespan");
    anyhow::ensure!(
        d.attribution_residual() < 1e-6 && d.track_attribution_residual() < 1e-6,
        "attribution must sum to the delta (residuals {} / {})",
        d.attribution_residual(),
        d.track_attribution_residual()
    );
    let fabric_share = d.attribution_share("fabric");
    anyhow::ensure!(
        fabric_share >= 0.9,
        "fabric must explain >=90% of the delta, got {:.1}%",
        fabric_share * 100.0
    );
    anyhow::ensure!(
        d.blame[0].category.bucket() == "fabric",
        "top blame entry must be fabric work, got {}",
        d.blame[0].name
    );
    let grown_on_cable = |e: &BlameEntry| {
        e.kind == DeltaKind::Grew
            && matches!(e.track, Track::Link(x, y) if (x.min(y), x.max(y)) == (la, lb))
    };
    anyhow::ensure!(
        d.blame.iter().any(grown_on_cable),
        "the blame list must name a grown circuit on cable {la}<->{lb}"
    );
    anyhow::ensure!(
        d.changed_counters.contains(&format!("link_rate {la}<->{lb}")),
        "the link_rate counter track must be flagged as changed"
    );
    println!(
        "fabric explains {:.1}% of the {:.4} s delta; top blame: {}",
        fabric_share * 100.0,
        d.makespan_delta(),
        d.blame[0].name
    );

    // CI's failure-path artifacts: the two traces and the blame report.
    std::fs::write("trace_baseline.json", chrome_trace_json(&clean))?;
    std::fs::write("trace_candidate.json", chrome_trace_json(&degraded))?;
    std::fs::write("diff_blame.txt", d.render(12))?;
    println!("wrote trace_baseline.json, trace_candidate.json, diff_blame.txt");

    // --- Act 3: the host profiler names the placement inner loop -----
    // A 64-device carve folded onto a 16-card ring gives each candidate
    // 48 reduction sends to price — a realistic inner-loop workload.
    let search_plan =
        PartitionPlan::new(PartitionStrategy::Summa25D { p: 4, q: 4, c: 4 }, d2, d2, d2)
            .map_err(anyhow::Error::msg)?;
    let _ = profile::take_report(); // clean slate for this thread
    profile::arm();
    let placed = optimize(&search_plan, &Topology::ring(16), PlacementStrategy::default());
    profile::disarm();
    let report = profile::take_report();

    println!("\nact 3: host profiler over the placement search");
    print!("{}", report.render(5));
    let top = report.top_self(1);
    anyhow::ensure!(!top.is_empty(), "the armed search must record scopes");
    anyhow::ensure!(
        top[0].path == "placement.optimize;placement.candidate",
        "self-time top-1 must be the candidate replay loop, got {}",
        top[0].path
    );
    let folded = report.folded();
    anyhow::ensure!(
        folded.contains("placement.optimize;placement.candidate "),
        "the folded-stack export must carry the inner-loop path"
    );
    std::fs::write("profile_folded.txt", &folded)?;
    println!(
        "top self-time: {} ({} calls across {} evaluations); wrote profile_folded.txt",
        top[0].path,
        top[0].calls,
        placed.evaluations
    );

    if let Some(path) = args.get("json") {
        let mut metrics: BTreeMap<String, f64> = BTreeMap::new();
        metrics.insert("diff_zero_same_seed".into(), f64::from(u8::from(d0.is_empty())));
        metrics.insert("diff_fabric_attribution".into(), fabric_share);
        metrics.insert("diff_attribution_residual".into(), d.attribution_residual());
        metrics.insert("diff_makespan_delta_s".into(), d.makespan_delta());
        metrics.insert(
            "profiler_top1_is_placement_candidate".into(),
            f64::from(u8::from(top[0].path == "placement.optimize;placement.candidate")),
        );
        systo3d::util::json::write_metrics(path, &metrics)?;
        println!("\nwrote {} metric(s) to {path}", metrics.len());
    }

    println!("\ntrace_diff OK");
    Ok(())
}
