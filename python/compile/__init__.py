"""Build-time compile path: L1 Pallas kernels + L2 JAX model + AOT lowering.

Never imported at request time — the Rust binary is self-contained once
``make artifacts`` has produced ``artifacts/*.hlo.txt``.
"""
