"""AOT entry point: lower the L2 model to HLO **text** artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
emitted ``artifacts/*.hlo.txt`` through ``HloModuleProto::from_text_file``
and Python never runs again.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. Lowering goes through stablehlo → XlaComputation with
``return_tuple=True``; the Rust side unwraps with ``to_tuple1()``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.kernels.systolic_mm import SystolicConfig
from compile.model import OffchipConfig, chained_matmul, offchip_matmul

# ~16 MiB of VMEM per TensorCore on current TPUs; keep headroom for Mosaic.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

# FPGA-faithful tile (paper design H) — used for the small functional
# artifact so the request path exercises the exact paper geometry.
CFG_FPGA_H = OffchipConfig(SystolicConfig(di0=32, dj0=32, dk0=4, dp=4),
                           di1=64, dj1=64)

# TPU-retuned tile (DESIGN.md §Hardware-Adaptation): 128-lane blocks fill
# the 128x128 MXU systolic array exactly (estimated MXU utilization 100%
# vs 25% for 64-lane tiles — EXPERIMENTS.md §Perf L1-1); two layers along
# the third dimension (dk0/dp = 2) keep the layered accumulation path
# exercised at serving sizes.
CFG_TPU = OffchipConfig(SystolicConfig(di0=128, dj0=128, dk0=128, dp=64),
                        di1=256, dj1=256)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _assert_vmem(cfg: OffchipConfig, name: str) -> None:
    fp = cfg.systolic.vmem_footprint_bytes()
    if fp > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"artifact {name}: VMEM footprint {fp} B exceeds budget "
            f"{VMEM_BUDGET_BYTES} B — shrink the BlockSpec tiles")


def _spec(shape: tuple[int, ...]) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_artifacts() -> list[dict]:
    """Return the artifact catalog: (name, jitted fn, example specs, meta)."""
    arts: list[dict] = []

    def mm_entry(name: str, n: int, cfg: OffchipConfig, tag: str) -> dict:
        def fn(a, b):
            return (offchip_matmul(a, b, cfg, interpret=True),)

        return dict(
            name=name,
            kind="matmul",
            fn=fn,
            specs=[_spec((n, n)), _spec((n, n))],
            meta=dict(
                m=n, k=n, n=n, tile=dataclass_dict(cfg), family=tag,
            ),
            cfg=cfg,
        )

    arts.append(mm_entry("mm_h_64", 64, CFG_FPGA_H, "fpga_h"))
    arts.append(mm_entry("mm_tpu_256", 256, CFG_TPU, "tpu"))
    arts.append(mm_entry("mm_tpu_512", 512, CFG_TPU, "tpu"))

    def chain_fn(a, b, c):
        return (chained_matmul(a, b, c, CFG_TPU, interpret=True),)

    arts.append(dict(
        name="chain_tpu_256",
        kind="chain",
        fn=chain_fn,
        specs=[_spec((256, 256))] * 3,
        meta=dict(m=256, k=256, n=256, tile=dataclass_dict(CFG_TPU),
                  family="tpu"),
        cfg=CFG_TPU,
    ))
    return arts


def dataclass_dict(cfg: OffchipConfig) -> dict:
    s = cfg.systolic
    return dict(di0=s.di0, dj0=s.dj0, dk0=s.dk0, dp=s.dp,
                di1=cfg.di1, dj1=cfg.dj1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text-v1", "artifacts": []}
    for art in build_artifacts():
        _assert_vmem(art["cfg"], art["name"])
        lowered = jax.jit(art["fn"]).lower(*art["specs"])
        text = to_hlo_text(lowered)
        fname = f"{art['name']}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"].append(dict(
            name=art["name"],
            file=fname,
            kind=art["kind"],
            inputs=[list(s.shape) for s in art["specs"]],
            dtype="f32",
            sha256_16=digest,
            **art["meta"],
        ))
        print(f"wrote {path} ({len(text)} chars, sha {digest})")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
