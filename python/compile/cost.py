"""L2 performance accounting: XLA cost analysis of the lowered graphs.

Used at build time (and by pytest) to enforce the L2 optimization
criteria of DESIGN.md §8:

* **no redundant recomputation** — compiled FLOPs must match the
  theoretical 2·m·n·k within tolerance (fusion may add elementwise ops,
  never another matmul's worth);
* **traffic sanity** — bytes accessed must stay within a small factor of
  the operands + result (the blocked schedule must not spill tiles);
* **VMEM-tile feasibility** — delegated to SystolicConfig.vmem_footprint.
"""

from __future__ import annotations

import jax


def analyze(fn, specs) -> dict:
    """Compile ``fn`` for ``specs`` and return XLA's cost analysis.

    Returns a dict with at least ``flops`` and ``bytes accessed`` when the
    backend reports them (the CPU backend does).
    """
    compiled = jax.jit(fn).lower(*specs).compile()
    analyses = compiled.cost_analysis()
    # cost_analysis returns one dict per computation (newer jax: a dict).
    if isinstance(analyses, (list, tuple)):
        analyses = analyses[0]
    return dict(analyses)


def matmul_theoretical_flops(m: int, k: int, n: int) -> float:
    """2·m·n·k MACs-as-FLOPs (XLA's counting convention)."""
    return 2.0 * m * n * k


def check_no_recompute(fn, specs, theoretical_flops: float,
                       slack: float = 1.25) -> dict:
    """Assert the compiled graph does at most ``slack``× the theoretical
    FLOPs. Returns the analysis for further inspection."""
    a = analyze(fn, specs)
    flops = float(a.get("flops", 0.0))
    if flops <= 0.0:
        raise AssertionError("backend reported no flops — analysis unusable")
    ratio = flops / theoretical_flops
    if ratio > slack:
        raise AssertionError(
            f"compiled flops {flops:.3e} exceed {slack}x theoretical "
            f"{theoretical_flops:.3e} (ratio {ratio:.2f}) — redundant recompute?")
    return a


def check_traffic(fn, specs, operand_bytes: float, slack: float = 6.0) -> dict:
    """Assert bytes accessed stay within ``slack``× the operand+result
    footprint (the blocked schedule re-reads tiles, but boundedly)."""
    a = analyze(fn, specs)
    accessed = float(a.get("bytes accessed", 0.0))
    if accessed <= 0.0:
        raise AssertionError("backend reported no bytes accessed")
    ratio = accessed / operand_bytes
    if ratio > slack:
        raise AssertionError(
            f"bytes accessed {accessed:.3e} exceed {slack}x operands "
            f"{operand_bytes:.3e} (ratio {ratio:.2f}) — tile spill?")
    return a
