"""L1 — the *classical* (Definition 1, Okuda–Song) systolic matmul as a
Pallas kernel: the baseline architecture the paper's 3D design improves.

On the FPGA the classical array is a (d_i0 × d_j0) grid of single-MAC
PEs: each C element stays resident while ALL of K streams through — so
one pass of the array computes one (d_i0 × d_j0) C block with a
K-sequential accumulation of rank-1 updates.

TPU mapping: the k axis becomes the sequential grid dimension with tile
depth 1 — every grid step performs one rank-1 update (outer product),
exactly the per-cycle work of the classical array. This is deliberately
MXU-hostile (contraction depth 1) the same way the classical array is
DSP-chain-hostile; comparing its grid length against the 3D kernel's
(K vs K/d_k0 steps) reproduces Definition 1-vs-2's latency ratio at the
kernel-structure level (asserted in tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _classical_kernel(a_ref, b_ref, c_ref):
    """One grid step: a rank-1 update C += A[:, k] ⊗ B[k, :]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    a_col = a_ref[...]  # (di0, 1)
    b_row = b_ref[...]  # (1, dj0)
    c_ref[...] += a_col * b_row  # outer product via broadcasting


def classical_matmul(a: jnp.ndarray, b: jnp.ndarray, di0: int, dj0: int,
                     interpret: bool = True) -> jnp.ndarray:
    """C = A @ B through the classical 2D systolic dataflow.

    Grid = (m/d_i0, n/d_j0, K): K sequential rank-1 updates per C tile —
    one per classical-array cycle.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {k} vs {k2}")
    if m % di0 or n % dj0:
        raise ValueError(f"({m},{n}) not tileable by ({di0},{dj0})")
    grid = (m // di0, n // dj0, k)
    return pl.pallas_call(
        _classical_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((di0, 1), lambda i, j, t: (i, t)),
            pl.BlockSpec((1, dj0), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((di0, dj0), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


@functools.lru_cache(maxsize=None)
def grid_steps_classical(m: int, n: int, k: int, di0: int, dj0: int) -> int:
    """Sequential k-steps of the classical kernel (Definition 1: K)."""
    return (m // di0) * (n // dj0) * k


def grid_steps_3d(m: int, n: int, k: int, di0: int, dj0: int, dk0: int) -> int:
    """Sequential k-steps of the 3D kernel (Definition 2: K/d_k0)."""
    return (m // di0) * (n // dj0) * (k // dk0)
