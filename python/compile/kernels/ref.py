"""Pure-jnp correctness oracles for the systolic matmul kernels.

These are the ground truth the Pallas kernels (and, transitively, the HLO
artifacts executed by the Rust runtime) are validated against in
``python/tests/``.

Two oracles are provided:

* :func:`matmul_ref` — plain ``jnp.dot``; the numerical reference.
* :func:`blocked_matmul_ref` — the *order-of-operations* reference: it
  accumulates exactly like the paper's two-level blocked algorithm
  (Definition 4: cyclical accumulation of outer products between block
  columns of A and block rows of B, k slowest), so it reproduces the same
  floating-point rounding as the FPGA design and the Pallas kernel.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A @ B with default XLA accumulation (float32)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def dot_unit_ref(z: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference for a single Stratix-10 chained dot-product unit (paper eq. 6).

    ``r = z + sum_i v_i * w_i`` over the last axis.
    """
    return z + jnp.sum(v * w, axis=-1)


def blocked_matmul_ref(
    a: jnp.ndarray,
    b: jnp.ndarray,
    dk0: int,
    dp: int | None = None,
) -> jnp.ndarray:
    """Definition-4-ordered matmul: accumulate (dk2/dk0) outer-product slabs.

    Within each slab of ``dk0`` contraction steps, the dot products are
    computed in ``dk0/dp`` sequential segments of size ``dp`` (the paper's
    third systolic dimension / Listing 2 line 21). This mirrors the exact
    accumulation order of both the FPGA design and the Pallas kernel.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert k % dk0 == 0, f"dk2={k} not a multiple of dk0={dk0}"
    if dp is None:
        dp = dk0
    assert dk0 % dp == 0, f"dk0={dk0} not a multiple of dp={dp}"

    acc = jnp.zeros((m, n), dtype=jnp.float32)
    for t in range(k // dk0):  # k slowest: the anti-hazard ordering of Def. 4
        a_blk = a[:, t * dk0 : (t + 1) * dk0].astype(jnp.float32)
        b_blk = b[t * dk0 : (t + 1) * dk0, :].astype(jnp.float32)
        for layer in range(dk0 // dp):  # the third (L) systolic dimension
            lo, hi = layer * dp, (layer + 1) * dp
            acc = acc + a_blk[:, lo:hi] @ b_blk[lo:hi, :]
    return acc
