"""L1 — Pallas kernel: the paper's 3D systolic on-chip matmul, TPU-adapted.

The paper (Gorlani & Plessl 2021) builds a three-dimensional systolic array
on a Stratix 10: a ``(d_i0, d_j0, d_k0/d_p)`` grid of dot-product units of
size ``d_p``. Its insight is *throughput balancing between memory levels via
the third grid dimension*: ``d_k0`` scales FLOP/cycle linearly (paper eq. 9)
but also the on-chip data throughput (eq. 10), and ``d_p`` trades dot-unit
depth against placement feasibility.

TPU adaptation (DESIGN.md §Hardware-Adaptation):

* the DSP dot-product unit of size ``d_p``  →  an MXU contraction over a
  ``d_p``-wide slice of the k tile. The kernel body splits the ``d_k0`` tile
  into ``d_k0/d_p`` *sequential* partial contractions whose partial sums are
  carried forward — the exact dataflow of Listing 2 line 21, where the
  partial C value is sent up the L dimension.
* M20K mapped partitions feeding the PEs  →  VMEM tiles staged by
  ``BlockSpec``; the paper's on-chip block shapes (d_i0×d_k0), (d_k0×d_j0)
  are literally the BlockSpec block shapes.
* the paper's "k slowest" outer-product ordering (Definition 4), which on
  the FPGA dodges the II>1 accumulation hazard of the Variable-Precision
  DSPs, maps to k as the *sequential innermost grid axis* with a resident
  accumulator tile: on TPU the hazard does not exist, but the same ordering
  minimizes C-tile HBM traffic. (Grid axes in Pallas iterate row-major, so
  "innermost sequential" means the *last* grid axis.)

The kernel MUST run with ``interpret=True`` here: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Real-TPU efficiency
is estimated analytically in EXPERIMENTS.md §Perf from VMEM footprint and
MXU utilization of the chosen block shapes.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclasses.dataclass(frozen=True)
class SystolicConfig:
    """Sizes of the systolic array (superscript-0 sizes in the paper).

    ``di0 x dj0`` is the 2D footprint (PE grid), ``dk0`` the contraction
    tile, ``dp`` the dot-product-unit size; ``dk0/dp`` is the number of
    layers stacked along the third dimension.
    """

    di0: int
    dj0: int
    dk0: int
    dp: int

    def __post_init__(self) -> None:
        if self.dk0 % self.dp != 0:
            raise ValueError(f"dk0={self.dk0} must be a multiple of dp={self.dp}")
        for name in ("di0", "dj0", "dk0", "dp"):
            v = getattr(self, name)
            if v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")

    @property
    def layers(self) -> int:
        """Number of bi-dimensional layers, d_k0 / d_p (paper Def. 2)."""
        return self.dk0 // self.dp

    @property
    def num_pes(self) -> int:
        """#PE = d_i0 * d_j0 * d_k0/d_p (paper eq. 12)."""
        return self.di0 * self.dj0 * self.layers

    @property
    def num_dsps(self) -> int:
        """#DSP = d_i0 * d_j0 * d_k0 (paper eq. 11)."""
        return self.di0 * self.dj0 * self.dk0

    @property
    def flop_per_cycle(self) -> int:
        """T_flop = 2 d_i0 d_j0 d_k0 [FLOP/cycle] (paper eq. 9)."""
        return 2 * self.num_dsps

    def vmem_footprint_bytes(self) -> int:
        """Bytes of VMEM held resident by one kernel instance (f32).

        A tile + B tile + C accumulator tile. Double-buffering headroom
        (factor 2) on the input tiles, which Pallas pipelines HBM→VMEM.
        Used by aot.py to assert the config fits a ~16 MiB/core budget.
        """
        a = self.di0 * self.dk0 * 4
        b = self.dk0 * self.dj0 * 4
        c = self.di0 * self.dj0 * 4
        return 2 * (a + b) + c


def _systolic_mm_kernel(a_ref, b_ref, c_ref, *, cfg: SystolicConfig,
                        k_steps: int):
    """Pallas kernel body: one (i, j, k) grid step.

    Grid = (d_i1/d_i0, d_j1/d_j0, d_k2/d_k0); k is the last (sequential)
    axis. The C output tile's index map ignores k, so the same VMEM tile
    stays resident across all k steps of one (i, j) block — it plays the
    role of the FPGA design's on-chip C FIFO system.

    The layer loop reproduces the third systolic dimension: ``dk0/dp``
    partial dot products of width ``dp``, accumulated sequentially exactly
    like Listing 2 passes partial sums up the L direction.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():  # Phase-1 "Initialize C to zero" of §V
        c_ref[...] = jnp.zeros_like(c_ref)

    a_tile = a_ref[...]  # (di0, dk0) — an M20K-partition-fed A block
    b_tile = b_ref[...]  # (dk0, dj0) — an M20K-partition-fed B block

    # The L dimension: dk0/dp sequential dot-product segments of width dp.
    acc = c_ref[...]
    for layer in range(cfg.layers):
        lo = layer * cfg.dp
        a_seg = jax.lax.slice_in_dim(a_tile, lo, lo + cfg.dp, axis=1)
        b_seg = jax.lax.slice_in_dim(b_tile, lo, lo + cfg.dp, axis=0)
        # One MXU contraction per layer == one plane of dot-product units.
        acc = acc + jax.lax.dot_general(
            a_seg, b_seg,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    c_ref[...] = acc


def systolic_matmul(a: jnp.ndarray, b: jnp.ndarray, cfg: SystolicConfig,
                    interpret: bool = True) -> jnp.ndarray:
    """On-chip-style matmul C = A @ B through the 3D systolic Pallas kernel.

    ``a``: (d_i1, d_k2), ``b``: (d_k2, d_j1); every dimension must be a
    multiple of the corresponding systolic size. This is the paper's
    Definition 4 *second level*: the systolic array sweeps the
    (d_i1/d_i0 × d_j1/d_j0 × d_k2/d_k0) block grid, accumulating over k.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: A has k={k}, B has k={k2}")
    if m % cfg.di0 or n % cfg.dj0 or k % cfg.dk0:
        raise ValueError(
            f"shape ({m},{k})x({k2},{n}) not tileable by "
            f"(di0,dj0,dk0)=({cfg.di0},{cfg.dj0},{cfg.dk0})"
        )
    k_steps = k // cfg.dk0
    grid = (m // cfg.di0, n // cfg.dj0, k_steps)

    kernel = functools.partial(_systolic_mm_kernel, cfg=cfg, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # A block column Ā^{Ii}_{0k}: i from grid-i, k from grid-k.
            pl.BlockSpec((cfg.di0, cfg.dk0), lambda i, j, t: (i, t)),
            # B block row B̄^{0k}_{Jj}: k from grid-k, j from grid-j.
            pl.BlockSpec((cfg.dk0, cfg.dj0), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((cfg.di0, cfg.dj0), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


# Catalog of the paper's synthesizable designs (Table I). Keys are the
# paper's design IDs; these are the FPGA sizes, used as Pallas tile sizes
# for functional artifacts (TPU-optimal retunes live in aot.py).
PAPER_DESIGNS: dict[str, SystolicConfig] = {
    "C": SystolicConfig(di0=28, dj0=28, dk0=6, dp=1),
    "E": SystolicConfig(di0=72, dj0=32, dk0=2, dp=1),
    "F": SystolicConfig(di0=70, dj0=32, dk0=2, dp=2),
    "G": SystolicConfig(di0=64, dj0=32, dk0=2, dp=2),
    "H": SystolicConfig(di0=32, dj0=32, dk0=4, dp=4),
    "I": SystolicConfig(di0=32, dj0=32, dk0=4, dp=2),
    "L": SystolicConfig(di0=32, dj0=16, dk0=8, dp=8),
    "M": SystolicConfig(di0=32, dj0=16, dk0=8, dp=4),
    "N": SystolicConfig(di0=32, dj0=16, dk0=8, dp=2),
}
