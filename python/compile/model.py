"""L2 — JAX model: the paper's two-level blocked off-chip matmul (Def. 4).

This is the compute graph the Rust coordinator executes at request time
(via its AOT-compiled HLO artifact — Python is never on the request path).

Definition 4 structure:

* **First level** — C̄ is computed block-by-block: C̄^I_J = Ā^I_0 · B̄^0_J for
  a (d_i1 × d_j1) grid of C blocks. On the FPGA each C̄^I_J is one pass of
  the four-phase Read/Compute/Write schedule; here each block is one call
  into the L1 systolic Pallas kernel, and the I/J sweep is laid out at
  trace time so XLA sees one fused program.
* **Second level** — inside a block, the systolic array sweeps
  (d_i1/d_i0 × d_j1/d_j0 × d_k2/d_k0) tiles with k slowest (the
  anti-accumulation-hazard outer-product ordering); that level lives in
  the Pallas kernel's grid.

The reuse ratios r_A = B_A/B_gA and r_B = B_B/B_gB (paper eq. 14) fix
d_i1 = r_B·d_i0 and d_j1 = r_A·d_j0 (eq. 18); `OffchipConfig.validate`
checks them the same way the Rust `blocked` module does.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels.systolic_mm import SystolicConfig, systolic_matmul


@dataclasses.dataclass(frozen=True)
class OffchipConfig:
    """Level-1 blocking of Definition 4 (superscript-1 sizes)."""

    systolic: SystolicConfig
    di1: int
    dj1: int

    def __post_init__(self) -> None:
        if self.di1 % self.systolic.di0:
            raise ValueError(f"di1={self.di1} not a multiple of di0={self.systolic.di0}")
        if self.dj1 % self.systolic.dj0:
            raise ValueError(f"dj1={self.dj1} not a multiple of dj0={self.systolic.dj0}")

    @property
    def reuse_a(self) -> int:
        """r_A — how often an A element is reused (eq. 18: d_j1 = r_A d_j0)."""
        return self.dj1 // self.systolic.dj0

    @property
    def reuse_b(self) -> int:
        """r_B — how often a B element is reused (eq. 18: d_i1 = r_B d_i0)."""
        return self.di1 // self.systolic.di0

    def validate_offchip(self, di2: int, dj2: int, dk2: int) -> None:
        """The paper's matrix-size constraints (captions of Tables II–V)."""
        if di2 % self.di1:
            raise ValueError(f"d_i2={di2} must be a multiple of d_i1={self.di1}")
        if dj2 % self.dj1:
            raise ValueError(f"d_j2={dj2} must be a multiple of d_j1={self.dj1}")
        if dk2 % self.systolic.dk0:
            raise ValueError(
                f"d_k2={dk2} must be a multiple of d_k0={self.systolic.dk0}")


def offchip_matmul(a: jnp.ndarray, b: jnp.ndarray, cfg: OffchipConfig,
                   interpret: bool = True) -> jnp.ndarray:
    """C = A·B through the two-level blocked schedule of Definition 4.

    a: (d_i2, d_k2) — the FPGA stores this column-major; layout here is
    XLA's concern and is pinned at AOT time.
    b: (d_k2, d_j2) row-major.
    """
    di2, dk2 = a.shape
    _, dj2 = b.shape
    cfg.validate_offchip(di2, dj2, dk2)

    n_i = di2 // cfg.di1
    n_j = dj2 // cfg.dj1

    # First level: sweep C̄ blocks. Trace-time loop => one fused HLO.
    rows = []
    for bi in range(n_i):
        cols = []
        for bj in range(n_j):
            a_blk = jax.lax.slice(a, (bi * cfg.di1, 0), ((bi + 1) * cfg.di1, dk2))
            b_blk = jax.lax.slice(b, (0, bj * cfg.dj1), (dk2, (bj + 1) * cfg.dj1))
            cols.append(systolic_matmul(a_blk, b_blk, cfg.systolic,
                                        interpret=interpret))
        rows.append(jnp.concatenate(cols, axis=1))
    return jnp.concatenate(rows, axis=0)


def chained_matmul(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
                   cfg: OffchipConfig, interpret: bool = True) -> jnp.ndarray:
    """(A·B)·C — the paper's §VI selling point.

    Unlike the Intel SDK baseline, this design's result matrix keeps the
    row-major operand format, so a product can feed the next multiply with
    no host-side reordering. This graph is what the coordinator's
    `chain` requests execute.
    """
    ab = offchip_matmul(a, b, cfg, interpret=interpret)
    return offchip_matmul(ab, c, cfg, interpret=interpret)
