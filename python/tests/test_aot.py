"""AOT pipeline tests: HLO text emission, manifest integrity, VMEM budget."""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels.systolic_mm import SystolicConfig
from compile.model import OffchipConfig

jax.config.update("jax_platform_name", "cpu")

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestHloEmission:
    def test_to_hlo_text_roundtrips_entry(self):
        def fn(x):
            return (x * 2.0,)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_artifact_catalog_is_wellformed(self):
        arts = aot.build_artifacts()
        names = [a["name"] for a in arts]
        assert len(names) == len(set(names)), "duplicate artifact names"
        for art in arts:
            assert art["kind"] in ("matmul", "chain")
            # each fn must lower without error
            lowered = jax.jit(art["fn"]).lower(*art["specs"])
            assert "ENTRY" in aot.to_hlo_text(lowered)

    def test_vmem_budget_enforced(self):
        huge = OffchipConfig(SystolicConfig(2048, 2048, 512, 512),
                             di1=2048, dj1=2048)
        with pytest.raises(ValueError, match="VMEM"):
            aot._assert_vmem(huge, "huge")

    def test_catalog_configs_fit_vmem(self):
        for art in aot.build_artifacts():
            aot._assert_vmem(art["cfg"], art["name"])


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="run `make artifacts` first")
class TestEmittedArtifacts:
    def _manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_files_exist(self):
        man = self._manifest()
        assert man["format"] == "hlo-text-v1"
        for art in man["artifacts"]:
            path = os.path.join(ART_DIR, art["file"])
            assert os.path.exists(path), art["file"]
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), art["file"]

    def test_manifest_shapes_square(self):
        man = self._manifest()
        for art in man["artifacts"]:
            for shape in art["inputs"]:
                assert len(shape) == 2

    def test_mm_h_64_numerics_via_jax_reexec(self):
        """Execute the emitted artifact's source graph and compare to dot —
        the same check the Rust integration test performs via PJRT."""
        arts = {a["name"]: a for a in aot.build_artifacts()}
        art = arts["mm_h_64"]
        a = jax.random.normal(jax.random.PRNGKey(0), (64, 64), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (64, 64), jnp.float32)
        (got,) = jax.jit(art["fn"])(a, b)
        np.testing.assert_allclose(got, a @ b, rtol=2e-5, atol=2e-5)
