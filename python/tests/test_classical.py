"""Definition-1 (classical) Pallas kernel vs oracle, and the Def-1 vs
Def-2 structural comparison at the kernel level."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.classical_mm import (
    classical_matmul,
    grid_steps_3d,
    grid_steps_classical,
)
from compile.kernels.ref import matmul_ref
from compile.kernels.systolic_mm import SystolicConfig, systolic_matmul

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestClassicalKernel:
    @pytest.mark.parametrize("m,k,n,di,dj", [
        (8, 4, 8, 4, 4), (16, 8, 8, 8, 4), (12, 16, 12, 4, 6),
    ])
    def test_matches_oracle(self, m, k, n, di, dj):
        a, b = _rand(m, (m, k)), _rand(n, (k, n))
        got = classical_matmul(a, b, di, dj)
        np.testing.assert_allclose(got, matmul_ref(a, b), rtol=2e-5, atol=2e-5)

    def test_identity(self):
        a = _rand(1, (8, 8))
        got = classical_matmul(a, jnp.eye(8, dtype=jnp.float32), 4, 4)
        np.testing.assert_allclose(got, a, rtol=1e-6, atol=1e-6)

    def test_shape_errors(self):
        with pytest.raises(ValueError, match="contraction"):
            classical_matmul(jnp.zeros((4, 4)), jnp.zeros((8, 4)), 4, 4)
        with pytest.raises(ValueError, match="tileable"):
            classical_matmul(jnp.zeros((6, 4)), jnp.zeros((4, 4)), 4, 4)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]),
           st.integers(1, 3))
    def test_random_geometry(self, seed, tile, kk):
        m = n = tile * 2
        k = 4 * kk
        a = jax.random.normal(jax.random.PRNGKey(seed), (m, k), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n), jnp.float32)
        got = classical_matmul(a, b, tile, tile)
        np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)),
                                   rtol=5e-5, atol=5e-5)


class TestDef1VsDef2:
    def test_same_numerics_different_structure(self):
        """Both architectures compute the same product; the 3D one does it
        in K/d_k0 sequential steps instead of K (Definition 2 vs 1)."""
        m, k, n = 16, 32, 16
        a, b = _rand(3, (m, k)), _rand(4, (k, n))
        c1 = classical_matmul(a, b, 8, 8)
        cfg = SystolicConfig(8, 8, 8, 4)
        c3 = systolic_matmul(a, b, cfg)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c3),
                                   rtol=5e-5, atol=5e-5)

        s1 = grid_steps_classical(m, n, k, 8, 8)
        s3 = grid_steps_3d(m, n, k, 8, 8, 8)
        assert s1 == 8 * s3, "the 3D array compresses k by d_k0"

    def test_step_compression_scales_with_dk0(self):
        k = 64
        base = grid_steps_classical(32, 32, k, 8, 8)
        for dk0 in (2, 4, 8, 16):
            assert grid_steps_3d(32, 32, k, 8, 8, dk0) * dk0 == base
