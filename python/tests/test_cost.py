"""L2 performance criteria: XLA cost analysis of the lowered artifacts."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from compile import aot, cost
from compile.kernels.systolic_mm import SystolicConfig, systolic_matmul
from compile.model import OffchipConfig, offchip_matmul

jax.config.update("jax_platform_name", "cpu")


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


class TestCostAnalysis:
    def test_plain_matmul_flops_counted(self):
        def fn(a, b):
            return (jnp.dot(a, b),)

        a = cost.analyze(fn, [_spec((64, 64)), _spec((64, 64))])
        assert float(a.get("flops", 0)) > 0

    def test_kernel_no_recompute_small(self):
        cfg = SystolicConfig(8, 8, 4, 2)

        def fn(a, b):
            return (systolic_matmul(a, b, cfg),)

        # interpret-mode pallas adds loop scaffolding; allow 1.6x.
        cost.check_no_recompute(
            fn,
            [_spec((16, 8)), _spec((8, 16))],
            cost.matmul_theoretical_flops(16, 8, 16),
            slack=1.6,
        )

    def test_artifact_catalog_flop_budgets(self):
        """Every emitted artifact's compiled FLOPs stay within budget.

        Note: XLA's cost analysis counts a while-loop body ONCE, and
        interpret-mode Pallas lowers the grid to while-loops, so the
        reported figure is a lower-bound-less upper check only (the
        faithful per-iteration count is exercised by the pure-jnp test
        below)."""
        for art in aot.build_artifacts():
            m = art["meta"]["m"]
            k = art["meta"]["k"]
            n = art["meta"]["n"]
            theo = cost.matmul_theoretical_flops(m, k, n)
            if art["kind"] == "chain":
                theo *= 2  # two multiplies
            a = cost.check_no_recompute(art["fn"], art["specs"], theo, slack=1.6)
            assert float(a["flops"]) > 0, art["name"]

    def test_pure_jnp_model_flops_exact(self):
        """The un-pallas'd blocked schedule compiles to exactly the
        theoretical FLOP count (no recompute, full count visible)."""
        from compile.kernels.ref import blocked_matmul_ref

        def fn(a, b):
            return (blocked_matmul_ref(a, b, dk0=16, dp=8),)

        m = k = n = 64
        a = cost.analyze(fn, [_spec((m, k)), _spec((k, n))])
        theo = cost.matmul_theoretical_flops(m, k, n)
        ratio = float(a["flops"]) / theo
        assert 0.95 < ratio < 1.3, f"ratio {ratio}"

    def test_offchip_traffic_bounded(self):
        cfg = OffchipConfig(SystolicConfig(8, 8, 4, 2), di1=16, dj1=16)

        def fn(a, b):
            return (offchip_matmul(a, b, cfg, interpret=True),)

        # Small shapes carry large constant overheads in the interpret
        # path (loop state, tile copies); the bound is generous but
        # still catches quadratic-in-blocks spill regressions.
        m = k = n = 32
        operand_bytes = 4.0 * (m * k + k * n + m * n)
        cost.check_traffic(fn, [_spec((m, k)), _spec((k, n))], operand_bytes,
                           slack=16.0)

    def test_recompute_detector_fires(self):
        """A deliberately redundant graph must be rejected."""

        def bad(a, b):
            # Two distinct products (different lhs) — CSE cannot merge.
            return (jnp.dot(a, b) + jnp.dot(a * 1.0000001, b),)

        with pytest.raises(AssertionError, match="redundant|exceed"):
            cost.check_no_recompute(
                bad,
                [_spec((64, 64)), _spec((64, 64))],
                cost.matmul_theoretical_flops(64, 64, 64),
                slack=1.25,
            )
