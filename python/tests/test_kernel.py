"""L1 kernel correctness: Pallas systolic kernel vs pure-jnp oracles.

This is the CORE correctness signal for the compute path: everything the
Rust runtime executes was lowered from these kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import blocked_matmul_ref, dot_unit_ref, matmul_ref
from compile.kernels.systolic_mm import (
    PAPER_DESIGNS,
    SystolicConfig,
    systolic_matmul,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Config invariants (paper equations 9, 11, 12)
# ---------------------------------------------------------------------------

class TestSystolicConfig:
    def test_dsp_count_eq11(self):
        cfg = SystolicConfig(28, 28, 6, 3)
        assert cfg.num_dsps == 28 * 28 * 6 == 4704

    def test_pe_count_eq12(self):
        # Table I rows: same DSPs, different PE granularity.
        assert SystolicConfig(28, 28, 6, 3).num_pes == 1568
        assert SystolicConfig(28, 28, 6, 2).num_pes == 2352
        assert SystolicConfig(28, 28, 6, 1).num_pes == 4704

    def test_flop_per_cycle_eq9(self):
        cfg = SystolicConfig(64, 32, 2, 2)
        assert cfg.flop_per_cycle == 2 * 64 * 32 * 2

    def test_layers(self):
        assert SystolicConfig(32, 16, 8, 2).layers == 4

    def test_dp_must_divide_dk0(self):
        with pytest.raises(ValueError):
            SystolicConfig(8, 8, 6, 4)

    def test_positive_dims(self):
        with pytest.raises(ValueError):
            SystolicConfig(0, 8, 4, 4)

    def test_vmem_footprint_monotone_in_tiles(self):
        small = SystolicConfig(32, 32, 32, 32).vmem_footprint_bytes()
        big = SystolicConfig(64, 64, 64, 32).vmem_footprint_bytes()
        assert big > small

    @pytest.mark.parametrize("name,cfg", sorted(PAPER_DESIGNS.items()))
    def test_paper_catalog_dsps_match_table1(self, name, cfg):
        expected = {
            "C": 4704, "E": 4608, "F": 4480, "G": 4096, "H": 4096,
            "I": 4096, "L": 4096, "M": 4096, "N": 4096,
        }
        assert cfg.num_dsps == expected[name]

    @pytest.mark.parametrize("name,cfg", sorted(PAPER_DESIGNS.items()))
    def test_paper_catalog_pes_match_table1(self, name, cfg):
        expected = {
            "C": 4704, "E": 4608, "F": 2240, "G": 2048, "H": 1024,
            "I": 2048, "L": 512, "M": 1024, "N": 2048,
        }
        assert cfg.num_pes == expected[name]


# ---------------------------------------------------------------------------
# Oracle self-consistency
# ---------------------------------------------------------------------------

class TestOracles:
    def test_blocked_ref_matches_dot(self):
        a, b = _rand(0, (48, 24)), _rand(1, (24, 36))
        got = blocked_matmul_ref(a, b, dk0=8, dp=4)
        np.testing.assert_allclose(got, matmul_ref(a, b), rtol=1e-5, atol=1e-5)

    def test_dot_unit_ref(self):
        z = jnp.float32(2.0)
        v = jnp.arange(4, dtype=jnp.float32)
        w = jnp.ones(4, dtype=jnp.float32)
        assert float(dot_unit_ref(z, v, w)) == pytest.approx(8.0)

    def test_blocked_ref_dp_independent_result(self):
        a, b = _rand(2, (32, 16)), _rand(3, (16, 32))
        r1 = blocked_matmul_ref(a, b, dk0=8, dp=8)
        r2 = blocked_matmul_ref(a, b, dk0=8, dp=2)
        np.testing.assert_allclose(r1, r2, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Kernel vs oracle — fixed design points
# ---------------------------------------------------------------------------

KERNEL_CASES = [
    # (cfg, m, k, n)
    (SystolicConfig(8, 8, 4, 4), 16, 8, 16),
    (SystolicConfig(8, 8, 4, 2), 16, 16, 24),
    (SystolicConfig(16, 8, 8, 4), 32, 24, 16),
    (SystolicConfig(32, 32, 4, 4), 64, 64, 64),   # design-H geometry
    (SystolicConfig(32, 16, 8, 2), 64, 32, 48),   # design-N geometry
    (SystolicConfig(64, 64, 64, 32), 128, 128, 128),  # TPU-retuned tile
]


class TestKernelVsRef:
    @pytest.mark.parametrize("cfg,m,k,n", KERNEL_CASES)
    def test_allclose_to_dot(self, cfg, m, k, n):
        a, b = _rand(m * 7 + k, (m, k)), _rand(n * 13 + k, (k, n))
        got = systolic_matmul(a, b, cfg)
        np.testing.assert_allclose(got, matmul_ref(a, b), rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("cfg,m,k,n", KERNEL_CASES)
    def test_bit_identical_to_blocked_ref(self, cfg, m, k, n):
        """The kernel must reproduce Definition 4's accumulation order
        exactly — same slab order, same layer segmentation. Bitwise
        equality is asserted for multi-layer configs (where the explicit
        dp-segmentation pins the order); single-layer dots may be
        re-bracketed by XLA codegen and get a 1-ulp tolerance."""
        a, b = _rand(m, (m, k)), _rand(n, (k, n))
        got = systolic_matmul(a, b, cfg)
        want = blocked_matmul_ref(a, b, cfg.dk0, cfg.dp)
        if cfg.dp > 1 and cfg.dk0 > cfg.dp:
            assert jnp.array_equal(got, want), "accumulation order diverged"
        else:
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6, atol=1e-6)

    def test_identity(self):
        cfg = SystolicConfig(8, 8, 8, 4)
        eye = jnp.eye(16, dtype=jnp.float32)
        a = _rand(5, (16, 16))
        np.testing.assert_allclose(systolic_matmul(a, eye, cfg), a,
                                   rtol=1e-6, atol=1e-6)

    def test_zeros(self):
        cfg = SystolicConfig(8, 8, 4, 2)
        a = _rand(6, (8, 8))
        z = jnp.zeros((8, 8), jnp.float32)
        assert float(jnp.abs(systolic_matmul(a, z, cfg)).max()) == 0.0

    def test_shape_mismatch_raises(self):
        cfg = SystolicConfig(8, 8, 4, 2)
        with pytest.raises(ValueError, match="contraction mismatch"):
            systolic_matmul(jnp.zeros((8, 8)), jnp.zeros((12, 8)), cfg)

    def test_untileable_raises(self):
        cfg = SystolicConfig(8, 8, 4, 2)
        with pytest.raises(ValueError, match="not tileable"):
            systolic_matmul(jnp.zeros((12, 8)), jnp.zeros((8, 8)), cfg)

    def test_special_values_inf(self):
        cfg = SystolicConfig(8, 8, 4, 4)
        a = jnp.full((8, 8), jnp.inf, jnp.float32)
        b = jnp.eye(8, dtype=jnp.float32)
        out = systolic_matmul(a, b, cfg)
        # inf * 1 + 0*inf => nan on off-diagonal contributions? No: b is
        # identity so each dot is inf*1 + inf*0 = nan (inf*0). Just check
        # the kernel matches the oracle on non-finite inputs.
        want = blocked_matmul_ref(a, b, cfg.dk0, cfg.dp)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------------------------------------
# Property-based sweep (hypothesis): shapes, dtypes, dp splits
# ---------------------------------------------------------------------------

@st.composite
def kernel_problem(draw):
    di0 = draw(st.sampled_from([4, 8, 16]))
    dj0 = draw(st.sampled_from([4, 8, 16]))
    dp = draw(st.sampled_from([1, 2, 4]))
    layers = draw(st.integers(1, 3))
    dk0 = dp * layers
    m = di0 * draw(st.integers(1, 3))
    n = dj0 * draw(st.integers(1, 3))
    k = dk0 * draw(st.integers(1, 4))
    seed = draw(st.integers(0, 2**31 - 1))
    return SystolicConfig(di0, dj0, dk0, dp), m, k, n, seed


class TestKernelProperties:
    @settings(max_examples=30, deadline=None)
    @given(kernel_problem())
    def test_matches_oracle_over_random_geometry(self, prob):
        cfg, m, k, n, seed = prob
        a = jax.random.normal(jax.random.PRNGKey(seed), (m, k), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n), jnp.float32)
        got = systolic_matmul(a, b, cfg)
        want = blocked_matmul_ref(a, b, cfg.dk0, cfg.dp)
        # Bitwise equality with the eager oracle is NOT a stable property
        # over arbitrary shapes: XLA re-brackets small dot reductions
        # (unrolled tree vs loop) and FMA-fuses k=1 contractions, both
        # context-dependent. The deterministic fixed-shape cases in
        # TestKernelVsRef assert bitwise identity where it is stable;
        # here we assert the near-ulp bound that is shape-independent.
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(kernel_problem(), st.sampled_from([jnp.bfloat16, jnp.float32]))
    def test_dtype_sweep(self, prob, dtype):
        """bf16 inputs must still accumulate in f32 (MXU semantics)."""
        cfg, m, k, n, seed = prob
        a = jax.random.normal(jax.random.PRNGKey(seed), (m, k)).astype(dtype)
        b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n)).astype(dtype)
        got = systolic_matmul(a.astype(jnp.float32), b.astype(jnp.float32), cfg)
        assert got.dtype == jnp.float32
        want = matmul_ref(a.astype(jnp.float32), b.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=5e-5)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_dp_split_invariance_bitwise(self, seed):
        """Splitting dk0 into more layers changes only the accumulation
        bracketing; with matching oracle bracketing the result is bitwise
        stable for every dp."""
        a = jax.random.normal(jax.random.PRNGKey(seed), (16, 8), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 16), jnp.float32)
        for dp in (2, 4, 8):  # dp=1 is FMA-fused by XLA, see test above
            cfg = SystolicConfig(8, 8, 8, dp)
            got = systolic_matmul(a, b, cfg)
            want = blocked_matmul_ref(a, b, 8, dp)
            assert jnp.array_equal(got, want), f"dp={dp}"
