"""L2 model correctness: two-level blocked off-chip matmul (Definition 4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import blocked_matmul_ref, matmul_ref
from compile.kernels.systolic_mm import SystolicConfig
from compile.model import OffchipConfig, chained_matmul, offchip_matmul

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


CFG_SMALL = OffchipConfig(SystolicConfig(8, 8, 4, 2), di1=16, dj1=16)


class TestOffchipConfig:
    def test_reuse_ratios_eq18(self):
        # d_i1 = r_B d_i0, d_j1 = r_A d_j0
        cfg = OffchipConfig(SystolicConfig(32, 32, 4, 4), di1=512, dj1=512)
        assert cfg.reuse_b == 16
        assert cfg.reuse_a == 16

    def test_paper_design_g_blocking(self):
        # Table V caption: designs G–N require d1 = 512.
        cfg = OffchipConfig(SystolicConfig(64, 32, 2, 2), di1=512, dj1=512)
        assert cfg.reuse_b == 8 and cfg.reuse_a == 16

    def test_invalid_di1(self):
        with pytest.raises(ValueError):
            OffchipConfig(SystolicConfig(8, 8, 4, 2), di1=12, dj1=16)

    def test_offchip_constraint_check(self):
        with pytest.raises(ValueError, match="d_i2"):
            CFG_SMALL.validate_offchip(24, 16, 8)
        with pytest.raises(ValueError, match="d_k2"):
            CFG_SMALL.validate_offchip(16, 16, 6)
        CFG_SMALL.validate_offchip(32, 48, 12)  # ok


class TestOffchipMatmul:
    @pytest.mark.parametrize("m,k,n", [(16, 8, 16), (32, 16, 16),
                                       (32, 12, 48), (48, 20, 32)])
    def test_matches_dot(self, m, k, n):
        a, b = _rand(m + k, (m, k)), _rand(n + k, (k, n))
        got = offchip_matmul(a, b, CFG_SMALL)
        np.testing.assert_allclose(got, matmul_ref(a, b), rtol=2e-5, atol=2e-5)

    def test_bit_identical_to_blocked_ref(self):
        a, b = _rand(1, (32, 16)), _rand(2, (16, 32))
        got = offchip_matmul(a, b, CFG_SMALL)
        want_blocks = []
        for bi in range(2):
            row = []
            for bj in range(2):
                ab = a[bi * 16:(bi + 1) * 16, :]
                bb = b[:, bj * 16:(bj + 1) * 16]
                row.append(blocked_matmul_ref(ab, bb, 4, 2))
            want_blocks.append(jnp.concatenate(row, axis=1))
        want = jnp.concatenate(want_blocks, axis=0)
        assert jnp.array_equal(got, want)

    def test_chained_matmul_no_reorder(self):
        """(A·B)·C in one artifact — the paper's chained-multiply property."""
        cfg = OffchipConfig(SystolicConfig(8, 8, 8, 4), di1=16, dj1=16)
        a, b, c = _rand(3, (16, 16)), _rand(4, (16, 16)), _rand(5, (16, 16))
        got = chained_matmul(a, b, c, cfg)
        want = matmul_ref(matmul_ref(a, b), c)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_rejects_unblocked_shapes(self):
        with pytest.raises(ValueError):
            offchip_matmul(jnp.zeros((20, 8)), jnp.zeros((8, 16)), CFG_SMALL)


@st.composite
def offchip_problem(draw):
    di0 = draw(st.sampled_from([4, 8]))
    dj0 = draw(st.sampled_from([4, 8]))
    dp = draw(st.sampled_from([2, 4]))
    dk0 = dp * draw(st.integers(1, 2))
    rb = draw(st.integers(1, 2))
    ra = draw(st.integers(1, 2))
    cfg = OffchipConfig(SystolicConfig(di0, dj0, dk0, dp),
                        di1=rb * di0, dj1=ra * dj0)
    m = cfg.di1 * draw(st.integers(1, 2))
    n = cfg.dj1 * draw(st.integers(1, 2))
    k = dk0 * draw(st.integers(1, 3))
    seed = draw(st.integers(0, 2**31 - 1))
    return cfg, m, k, n, seed


class TestOffchipProperties:
    @settings(max_examples=20, deadline=None)
    @given(offchip_problem())
    def test_random_geometry_matches_dot(self, prob):
        cfg, m, k, n, seed = prob
        a = jax.random.normal(jax.random.PRNGKey(seed), (m, k), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n), jnp.float32)
        got = offchip_matmul(a, b, cfg)
        want = matmul_ref(a, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=5e-5)
