//! Shared helpers for the custom bench harness (criterion is not in the
//! offline registry; `[[bench]] harness = false` binaries use this).

use systo3d::util::stats::{Bench, Summary};

/// Standard bench configuration: honours `SYSTO3D_BENCH_FAST=1` for CI.
pub fn bench() -> Bench {
    if std::env::var("SYSTO3D_BENCH_FAST").as_deref() == Ok("1") {
        Bench::quick()
    } else {
        Bench::default()
    }
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a bench summary line.
pub fn report(s: &Summary) {
    println!("{}", s.report_line());
}

/// Throughput helper: ops/sec from a summary's median.
pub fn per_second(s: &Summary, ops_per_iter: f64) -> f64 {
    ops_per_iter / s.median()
}
