//! Bench: the cluster layer on a d=21504-class problem.
//!
//! Times the planner + event-level cluster simulation for N = 1, 2, 4, 8
//! devices (host-side cost of the sharded route's timing path) and
//! reports the *simulated* TFLOPS and scaling efficiency each fleet
//! achieves — the numbers the ROADMAP's multi-device story is judged by.
//!
//! ```sh
//! cargo bench --bench cluster_scaling
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::perfmodel::scaling_efficiency;

fn main() {
    let b = common::bench();
    let d2 = 21504u64;

    common::section("cluster: planner + event simulation host cost");
    for n in [1usize, 2, 4, 8] {
        let sim = ClusterSim::builder(Fleet::homogeneous(n, "G").expect("design G")).build();
        let s = b.run(&format!("plan_and_report n={n} d2={d2}"), || {
            sim.plan_and_report(d2, d2, d2).expect("plan").1.makespan_seconds
        });
        common::report(&s);
    }

    common::section("cluster: simulated TFLOPS and scaling efficiency");
    let mut t1 = None;
    for n in [1usize, 2, 4, 8] {
        let sim = ClusterSim::builder(Fleet::homogeneous(n, "G").expect("design G")).build();
        let (_, r) = sim.plan_and_report(d2, d2, d2).expect("plan");
        let t1_s = *t1.get_or_insert(r.makespan_seconds);
        println!(
            "n={n}: {:>9} {:.3} s makespan, {:.2} simulated TFLOPS, \
             scaling eff {:.3}, {} steals",
            r.strategy,
            r.makespan_seconds,
            r.effective_gflops / 1e3,
            scaling_efficiency(n as u64, t1_s, r.makespan_seconds),
            r.steals,
        );
    }

    common::section("cluster: partitioner cost per strategy (n=8)");
    for strategy in [
        PartitionStrategy::Row1D { devices: 8 },
        PartitionStrategy::auto_grid2d(8),
        PartitionStrategy::auto_summa25d(8),
    ] {
        let s = b.run(&format!("partition {} d2={d2}", strategy.name()), || {
            PartitionPlan::new(strategy, d2, d2, d2).expect("plan").total_bytes_moved()
        });
        common::report(&s);
    }
}
