//! Bench: the elastic-fleet controller — host cost of a kill + drain
//! replay (spare scoring included), and the post-growth makespan the
//! watermark buys under backlog.
//!
//! The drain path re-prices the remaining reduction sends per
//! candidate spare under the link-contention model, so its host cost
//! scales with spares × queued sends; this bench keeps that honest
//! while printing the simulated drain and growth numbers the
//! controller is judged by.
//!
//! ```sh
//! cargo bench --bench elastic_fleet
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::cluster::{ClusterSim, FaultPlan, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::Topology;

fn main() {
    let b = common::bench();
    let d2 = 21504u64;

    common::section("elastic: drain-to-spare on a 4x4 torus + 1 spare (n=16)");
    let plan =
        PartitionPlan::new(PartitionStrategy::auto_summa25d(16), d2, d2, d2).expect("plan");
    let sim = ClusterSim::builder(Fleet::homogeneous(17, "G").expect("design G"))
        .topology(Topology::torus2d(4, 4))
        .spares(1)
        .build();
    let first = plan.shards.iter().find(|s| s.device == 0).expect("shard on card 0");
    let t_die =
        sim.host.seconds_for_bytes(first.input_bytes()) + 0.5 * sim.shard_seconds(0, first);
    let faults = FaultPlan::kill(0, t_die);
    let s = b.run("simulate_elastic kill+drain n=16", || {
        sim.simulate_elastic(&plan, &faults)
            .expect("survivors remain")
            .schedule
            .makespan_seconds
    });
    common::report(&s);
    let out = sim.simulate_elastic(&plan, &faults).expect("survivors remain");
    println!(
        "  drain {:.4} s over {} spare(s), makespan {:.4} s, spare-pick gain {:.2}x",
        out.drain_seconds,
        out.spare_activations,
        out.schedule.makespan_seconds,
        out.drain_placement_gain(),
    );

    common::section("elastic: watermark growth under backlog (4 cards, watermark 2.0)");
    let load = PartitionPlan::new(PartitionStrategy::Row1D { devices: 32 }, d2, d2, d2)
        .expect("plan");
    let small = ClusterSim::builder(Fleet::homogeneous(4, "G").expect("design G"))
        .watermark(Some(2.0))
        .build();
    let s = b.run("simulate_elastic grow n=4", || {
        small.simulate_elastic(&load, &FaultPlan::none()).expect("healthy").grown_cards
    });
    common::report(&s);
    let grown = small.simulate_elastic(&load, &FaultPlan::none()).expect("healthy");
    let fixed =
        ClusterSim::builder(Fleet::homogeneous(4, "G").expect("design G")).build().simulate(&load);
    println!(
        "  grew {} card(s): post-grow makespan {:.4} s vs fixed {:.4} s",
        grown.grown_cards,
        grown.schedule.makespan_seconds,
        fixed.makespan_seconds,
    );
}
