//! Bench: the card-fabric layer — route-table construction, congested
//! collective pricing, and the full topology-aware cluster simulation.
//!
//! Times the host-side cost of the fabric machinery (the sharded
//! route's planner now prices plans per topology) and prints the
//! simulated numbers the fabric story is judged by: the same 2.5D plan
//! on ring vs torus vs fat tree, and the overlap saving of the
//! pipelined reduction.
//!
//! ```sh
//! cargo bench --bench fabric_topologies
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::{CollectiveSchedule, FabricState, ReduceAlgo, RouteTable, Topology};

fn main() {
    let b = common::bench();
    let d2 = 21504u64;

    common::section("fabric: route-table construction (host cost)");
    for n in [8usize, 16, 32] {
        let topo = Topology::torus_near_square(n);
        let s = b.run(&format!("route_table torus n={n}"), || {
            RouteTable::new(&topo).hops(0, n - 1).unwrap() as u64
        });
        common::report(&s);
    }

    common::section("fabric: collective pricing on a congested ring (host cost)");
    let mut ring = FabricState::new(Topology::ring(16));
    let others: Vec<usize> = (1..16).collect();
    for algo in [ReduceAlgo::Direct, ReduceAlgo::Tree, ReduceAlgo::Ring] {
        let sched = CollectiveSchedule::build(algo, 0, &others, 256 << 20);
        let s = b.run(&format!("price {} c=16", algo.name()), || {
            sched.price(&mut ring, &[0.0; 16]).unwrap()
        });
        common::report(&s);
    }

    common::section("fabric: simulated 2.5D makespan per topology (n=16)");
    let plan = PartitionPlan::new(PartitionStrategy::auto_summa25d(16), d2, d2, d2)
        .expect("plan");
    for topo in [
        Topology::ring(16),
        Topology::torus_near_square(16),
        Topology::fat_tree(16),
    ] {
        let name = topo.name();
        let sim = ClusterSim::builder(Fleet::homogeneous(16, "G").expect("design G"))
            .topology(topo)
            .build();
        let s = b.run(&format!("simulate {} {} n=16", plan.strategy.name(), name), || {
            sim.simulate(&plan).makespan_seconds
        });
        common::report(&s);
        let r = sim.simulate(&plan);
        println!(
            "  {name}: {:.4} s makespan, link util {:.1}% mean / {:.1}% peak, \
             reduction {:.4} s ({:.0}% overlapped)",
            r.makespan_seconds,
            r.link_utilization() * 100.0,
            r.max_link_utilization() * 100.0,
            r.reduction_seconds,
            r.reduction_overlap() * 100.0,
        );
    }

    common::section("fabric: overlapped vs barrier reduction (n=8, d=8192)");
    let plan = PartitionPlan::new(
        PartitionStrategy::Summa25D { p: 2, q: 2, c: 8 },
        8192,
        8192,
        8192,
    )
    .expect("plan");
    let sim =
        ClusterSim::builder(Fleet::homogeneous(8, "G").expect("design G"))
            .topology(Topology::ring(8))
            .build();
    let s = b.run("overlap_report ring n=8", || {
        sim.overlap_report(&plan, Some(ReduceAlgo::Direct)).saving_fraction()
    });
    common::report(&s);
    let rep = sim.overlap_report(&plan, Some(ReduceAlgo::Direct));
    println!(
        "  overlapped {:.4} s vs barrier {:.4} s ({:.1}% saved)",
        rep.overlapped_makespan_seconds,
        rep.barrier_makespan_seconds,
        rep.saving_fraction() * 100.0,
    );
}
