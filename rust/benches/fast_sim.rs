//! Bench: the fast-sim core — what the incremental machinery actually
//! buys, measured against the exact same work done the slow way.
//!
//! Two perfgate floors come from here:
//! * `sim_speedup_placement_n256` — 256-card torus placement search,
//!   incremental [`optimize`] vs the full-replay
//!   [`optimize_reference`] oracle (floor ≥ 10×). The reports are
//!   asserted bit-identical first; a speedup that changed an answer
//!   is not a speedup.
//! * `chaos_suite_speedup` — a 64-seed elastic chaos sweep, serial
//!   loop vs `util::par::run_seeds` fan-out (floor ≥ 4×), with every
//!   per-seed trace asserted byte-identical across the two runs.
//!
//! Metrics land in `SYSTO3D_FASTSIM_JSON` for `tools/perfgate.py`.
//!
//! ```sh
//! cargo bench --bench fast_sim
//! ```

#[path = "bench_common.rs"]
mod common;

use std::collections::BTreeMap;
use std::time::Instant;

use systo3d::blocked::{Level1Blocking, OffchipDesign};
use systo3d::cluster::{ClusterSim, FaultPlan, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::Topology;
use systo3d::placement::{optimize, optimize_reference, PlacementStrategy};
use systo3d::systolic::ArraySize;
use systo3d::trace::{chrome_trace_json, Tracer};
use systo3d::util::par::{run_seeds, test_threads};

fn chaos_sim(topology: &Topology) -> ClusterSim {
    let design = OffchipDesign {
        blocking: Level1Blocking::new(ArraySize::new(4, 4, 2, 2), 8, 8),
        fmax_mhz: 400.0,
        controller_efficiency: 0.97,
    };
    ClusterSim::builder(Fleet::uniform(10, "mini", design))
        .topology(topology.clone())
        .spares(2)
        .watermark(Some(0.75))
        .trace(Tracer::recording())
        .build()
}

/// Best-of-two wall-clock for a sweep too long to sample repeatedly;
/// returns the second run's output (both runs are asserted identical
/// downstream anyway).
fn best_of_two<T>(mut f: impl FnMut() -> T) -> (f64, T) {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let first = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let out = f();
    (first.min(t1.elapsed().as_secs_f64()), out)
}

fn main() {
    let b = common::bench();
    let mut metrics: BTreeMap<String, f64> = BTreeMap::new();

    common::section("fast-sim: placement search, 256-card torus (host cost)");
    let cards = 256usize;
    let plan = PartitionPlan::new(
        PartitionStrategy::Summa25D { p: 8, q: 8, c: 4 },
        4096,
        4096,
        4096,
    )
    .expect("plan");
    let topology = Topology::torus_near_square(cards);
    let strategy = PlacementStrategy::LocalSearch { seed: 7 };

    // Equivalence before speed: the incremental scorer must return the
    // oracle's exact report on the very configuration being timed.
    let fast_rep = optimize(&plan, &topology, strategy);
    let slow_rep = optimize_reference(&plan, &topology, strategy);
    assert_eq!(fast_rep.placement, slow_rep.placement, "maps diverged");
    assert_eq!(
        fast_rep.placed_cost_seconds.to_bits(),
        slow_rep.placed_cost_seconds.to_bits(),
        "cost bits diverged"
    );
    assert_eq!(fast_rep.evaluations, slow_rep.evaluations, "evaluations diverged");

    let fast = b.run("optimize incremental n=256", || {
        optimize(&plan, &topology, strategy).placed_cost_seconds
    });
    common::report(&fast);
    let slow = b.run("optimize full-replay n=256", || {
        optimize_reference(&plan, &topology, strategy).placed_cost_seconds
    });
    common::report(&slow);
    let placement_speedup = slow.median() / fast.median().max(1e-12);
    println!(
        "  incremental vs full replay: {placement_speedup:.1}x \
         (gain {:.3}x, {} evaluations, identical reports)",
        fast_rep.gain(),
        fast_rep.evaluations,
    );
    metrics.insert("sim_speedup_placement_n256".into(), placement_speedup);

    common::section("fast-sim: 64-seed chaos sweep, serial vs parallel (host cost)");
    let seeds = 64u64;
    let topo = Topology::torus2d(4, 2);
    let cplan =
        PartitionPlan::new(PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 }, 96, 96, 96)
            .expect("plan");
    let horizon = chaos_sim(&topo).simulate(&cplan).makespan_seconds;
    let one = |seed: u64| {
        let sim = chaos_sim(&topo);
        let out =
            sim.simulate_elastic(&cplan, &FaultPlan::seeded(seed, 10, horizon)).unwrap();
        (chrome_trace_json(&sim.trace.snapshot()), out.schedule.makespan_seconds.to_bits())
    };
    // Warm both paths once, then take the better of two timed passes
    // each — a sweep is too long for the sampled harness.
    let _ = one(0);
    let (serial_s, serial_out) = best_of_two(|| (0..seeds).map(one).collect::<Vec<_>>());
    let (parallel_s, parallel_out) = best_of_two(|| run_seeds(0..seeds, one));
    assert_eq!(serial_out, parallel_out, "parallel sweep must be byte-identical");
    let chaos_speedup = serial_s / parallel_s.max(1e-12);
    println!(
        "  serial {serial_s:.3} s vs parallel {parallel_s:.3} s on {} workers: \
         {chaos_speedup:.1}x, {seeds} seeds byte-identical",
        test_threads(),
    );
    metrics.insert("chaos_suite_speedup".into(), chaos_speedup);

    if let Ok(path) = std::env::var("SYSTO3D_FASTSIM_JSON") {
        systo3d::util::json::write_metrics(&path, &metrics).expect("write fast-sim metrics");
        println!("\nwrote {} metrics to {path}", metrics.len());
    }
}
