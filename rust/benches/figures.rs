//! Bench: regenerate the paper's **figures** — the 3D activation
//! wavefront (Fig. 1), the design wiring diagram (Fig. 2), the
//! four-phase timeline (Fig. 3) — plus the eq. 19 model-vs-simulation
//! curve the evaluation leans on.
//!
//! ```sh
//! cargo bench --bench figures
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::blocked::PhaseKind;
use systo3d::dse::paper_catalog;
use systo3d::perfmodel::eq19_compute_fraction;
use systo3d::reports;
use systo3d::systolic::{Array3dSim, ArraySize};

fn main() {
    common::section("FIGURE 1 — activation wavefront (3x3x3, dp=1)");
    print!("{}", reports::figure1());
    // Invariants of the figure: wave covers each PE exactly dk0 steps.
    let trace = Array3dSim::new(ArraySize::new(3, 3, 3, 1)).activation_trace();
    assert_eq!(trace.len(), 7);
    assert_eq!(trace.iter().map(|s| s.len()).sum::<usize>(), 27); // 9 PEs x 3 steps

    common::section("FIGURE 2 — design wiring (d=(4,3,3), B_gA=2, B_gB=1)");
    print!("{}", reports::figure2());

    common::section("FIGURE 3 — four-phase schedule (design G)");
    for dk2 in [512u64, 2048, 8192] {
        print!("{}", reports::figure3(dk2));
    }
    // Invariant: the Write span shrinks relative to total as dk2 grows.
    let spec = paper_catalog().into_iter().find(|d| d.id == "G").unwrap();
    let design = systo3d::blocked::OffchipDesign {
        blocking: spec.level1().unwrap(),
        fmax_mhz: spec.fmax_mhz.unwrap(),
        controller_efficiency: 0.97,
    };
    let frac = |dk2: u64| {
        let tl = design.schedule().timeline(dk2);
        let total = tl.last().unwrap().2 as f64;
        let write: u64 = tl.iter().filter(|s| s.0 == PhaseKind::Write).map(|s| s.2 - s.1).sum();
        write as f64 / total
    };
    assert!(frac(512) > frac(2048) && frac(2048) > frac(8192));

    common::section("eq. 19 — compute fraction, model vs schedule vs e_D");
    print!("{}", reports::eq19_curve());
    for d2 in [512u64, 2048, 8192] {
        let model = eq19_compute_fraction(d2, 2, 64, 32, 8);
        let tl = design.schedule().counts(d2);
        assert!((model - tl.compute_fraction()).abs() < 0.01, "eq19 drifted at {d2}");
    }
    println!("eq. 19 and the schedule agree within 0.01 across the sweep");

    common::section("figure-generation throughput");
    let b = common::bench();
    let s = b.run("activation_trace 32x32x8", || {
        Array3dSim::new(ArraySize::new(32, 32, 8, 2)).activation_trace()
    });
    common::report(&s);
    let s = b.run("figure3 timeline dk2=16384", || reports::figure3(16384));
    common::report(&s);
}
