//! Bench: the L3 hot paths — the profile targets of EXPERIMENTS.md §Perf.
//!
//! * cycle-accurate systolic simulator (PE-event throughput),
//! * event-level off-chip simulator (table-cell latency),
//! * blocked CPU GEMM (the functional fallback),
//! * PJRT artifact execution (when `make artifacts` has run),
//! * coordinator round-trip latency (queue → engine → response),
//! * armed host-profiler overhead on the placement search (gated:
//!   median paired ratio < 1.03; exported via `SYSTO3D_PROFILE_JSON`).
//!
//! ```sh
//! cargo bench --bench hotpath
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::blocked::{Level1Blocking, OffchipDesign, OffchipSim};
use systo3d::cluster::{PartitionPlan, PartitionStrategy};
use systo3d::coordinator::{GemmRequest, GemmService, ServiceConfig};
use systo3d::fabric::Topology;
use systo3d::gemm::{matmul_blocked, Matrix};
use systo3d::placement::{optimize, PlacementStrategy};
use systo3d::runtime::Engine;
use systo3d::systolic::{Array3dSim, ArraySize};
use systo3d::trace::profile;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

fn main() {
    let b = common::bench();

    common::section("cycle-accurate systolic simulator");
    // Design-H-shaped block: 32x32x4, K=64 -> 65536 MACs per multiply.
    let size = ArraySize::new(32, 32, 4, 4);
    let a = Matrix::random(32, 64, 1);
    let bm = Matrix::random(64, 32, 2);
    let sim = Array3dSim::new(size);
    let s = b.run("array3d 32x32x4 K=64 (65536 MACs)", || sim.multiply(&a, &bm));
    common::report(&s);
    let macs_per_s = common::per_second(&s, 65536.0);
    println!("  -> {:.1} M MAC-events/s", macs_per_s / 1e6);

    common::section("event-level off-chip simulator");
    let design = OffchipDesign {
        blocking: Level1Blocking::new(ArraySize::new(64, 32, 2, 2), 512, 512),
        fmax_mhz: 398.0,
        controller_efficiency: 0.97,
    };
    let osim = OffchipSim::new(design);
    let s = b.run("offchip sim 16384³ (timing only)", || osim.simulate(16384, 16384, 16384));
    common::report(&s);

    common::section("blocked CPU GEMM (functional fallback)");
    let ga = Matrix::random(256, 256, 3);
    let gb = Matrix::random(256, 256, 4);
    let s = b.run("matmul_blocked 256³", || matmul_blocked(&ga, &gb));
    common::report(&s);
    println!(
        "  -> {:.2} GFLOPS",
        common::per_second(&s, 2.0 * 256.0f64.powi(3)) / 1e9
    );

    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        common::section("PJRT artifact execution");
        let mut engine = Engine::new(dir).expect("engine");
        let a64 = Matrix::random(64, 64, 5);
        let b64 = Matrix::random(64, 64, 6);
        // Warm the compile cache first.
        engine.execute("mm_h_64", &[&a64, &b64]).unwrap();
        let s = b.run("mm_h_64 execute (cached)", || {
            engine.execute("mm_h_64", &[&a64, &b64]).unwrap()
        });
        common::report(&s);
        let a256 = Matrix::random(256, 256, 7);
        let b256 = Matrix::random(256, 256, 8);
        engine.execute("mm_tpu_256", &[&a256, &b256]).unwrap();
        let s = b.run("mm_tpu_256 execute (cached)", || {
            engine.execute("mm_tpu_256", &[&a256, &b256]).unwrap()
        });
        common::report(&s);
        println!(
            "  -> {:.2} GFLOPS through PJRT",
            common::per_second(&s, 2.0 * 256.0f64.powi(3)) / 1e9
        );
    } else {
        println!("(skipping PJRT benches — run `make artifacts`)");
    }

    common::section("coordinator round-trip");
    let svc = GemmService::start(ServiceConfig {
        artifact_dir: if dir.join("manifest.json").exists() {
            Some(dir.to_path_buf())
        } else {
            None
        },
        max_batch: 8,
        batch_window: Duration::from_micros(200),
        ..Default::default()
    })
    .expect("service");
    let s = b.run("submit_sync 64³", || {
        let a = Matrix::random(64, 64, 9);
        let b = Matrix::random(64, 64, 10);
        svc.submit_sync(GemmRequest::new(a, b).id(0))
    });
    common::report(&s);
    let snap = svc.metrics.snapshot();
    println!("  metrics: {} requests, {} errors", snap.requests, snap.errors);
    assert_eq!(snap.errors, 0);

    common::section("host profiler: armed-vs-disarmed overhead on the placement search");
    // A 64-device 2.5D carve folded onto a 16-card ring prices 48
    // reduction sends per candidate, so the per-scope cost amortizes
    // the way it does in real searches. Alternating pairs so machine
    // drift cancels; gate on the median ratio like trace_overhead.
    let plan =
        PartitionPlan::new(PartitionStrategy::Summa25D { p: 4, q: 4, c: 4 }, 8192, 8192, 8192)
            .expect("plan");
    let topology = Topology::ring(16);
    let time_one = |armed: bool| {
        if armed {
            profile::arm();
        }
        let t = Instant::now();
        let rep = optimize(&plan, &topology, PlacementStrategy::default());
        let dt = t.elapsed().as_secs_f64();
        profile::disarm();
        assert!(rep.placed_cost_seconds.is_finite());
        dt
    };
    let fast = std::env::var("SYSTO3D_BENCH_FAST").as_deref() == Ok("1");
    let (warmup, pairs) = if fast { (1, 5) } else { (2, 15) };
    let mut attempt = 0;
    let ratio = loop {
        attempt += 1;
        for _ in 0..warmup {
            time_one(true);
            time_one(false);
        }
        let mut ratios: Vec<f64> = (0..pairs)
            .map(|i| {
                // Alternate the order within each pair so drift cancels.
                if i % 2 == 0 {
                    let a = time_one(true);
                    let d = time_one(false);
                    a / d
                } else {
                    let d = time_one(false);
                    let a = time_one(true);
                    a / d
                }
            })
            .collect();
        ratios.sort_by(|a, b| a.total_cmp(b));
        let median = ratios[ratios.len() / 2];
        println!("  attempt {attempt}: armed/disarmed median ratio {median:.4} ({pairs} pairs)");
        if median < 1.03 || attempt >= 3 {
            break median;
        }
        println!("  noisy sample, retrying");
    };
    assert!(ratio < 1.03, "armed profiler costs more than 3%: median ratio {ratio:.4}");
    let overhead = (ratio - 1.0).max(0.0);
    println!("  PASS: armed profiler overhead {:.2}% < 3%", overhead * 100.0);

    // One clean armed pass for the report itself: the inner loop must
    // rank self-time top-1 (the acceptance claim of the profiler).
    let _ = profile::take_report();
    profile::arm();
    let rep = optimize(&plan, &topology, PlacementStrategy::default());
    profile::disarm();
    let report = profile::take_report();
    let top = report.top_self(1);
    assert_eq!(top[0].path, "placement.optimize;placement.candidate");
    print!("{}", report.render(4));
    println!("  -> top self-time across {} evaluations: {}", rep.evaluations, top[0].path);

    if let Ok(path) = std::env::var("SYSTO3D_PROFILE_JSON") {
        let mut metrics: BTreeMap<String, f64> = BTreeMap::new();
        metrics.insert("profiler_overhead".into(), overhead);
        systo3d::util::json::write_metrics(&path, &metrics).expect("write profile metrics");
        println!("  wrote profiler_overhead to {path}");
    }
}
