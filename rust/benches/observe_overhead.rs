//! Bench: the SLO burn monitor's cost when it is **armed but quiet**.
//!
//! Arming an SLO makes the controller record every shard latency into
//! the burn monitor and evaluate two sliding windows at every
//! scheduling instant. The promise is that watching costs almost
//! nothing: pruning keeps the sample deque bounded by the long window,
//! and evaluation is a linear scan of what remains. This bench replays
//! the 16-card torus SUMMA schedule with an SLO whose target is
//! unreachably high (the monitor samples and evaluates but never
//! alerts or grows, so both arms run the identical schedule) against
//! the unsampled fleet, in alternating pairs so machine drift cancels,
//! and **asserts the median paired ratio stays under 1.03** — less
//! than 3% makespan wall-time cost for always-on observability.
//!
//! ```sh
//! cargo bench --bench observe_overhead
//! ```

#[path = "bench_common.rs"]
mod common;

use std::time::Instant;
use systo3d::cluster::{
    ClusterSim, FaultPlan, Fleet, PartitionPlan, PartitionStrategy, SloPolicy,
};
use systo3d::fabric::Topology;
use systo3d::trace::Tracer;

fn main() {
    let d2 = 21504u64;
    common::section("observe: armed-but-quiet SLO monitor overhead (n=16 torus)");
    let plan =
        PartitionPlan::new(PartitionStrategy::auto_summa25d(16), d2, d2, d2).expect("plan");
    // An SLO no run can burn: the monitor records and evaluates at
    // every instant, but the schedule stays bit-identical to the
    // unsampled arm's.
    let quiet = SloPolicy {
        p99_latency_s: f64::MAX,
        window_s: 1.0,
        long_windows: 4,
        burn_threshold: 0.25,
        max_growth: 2,
    };
    let build = |slo: Option<SloPolicy>| {
        ClusterSim::builder(Fleet::homogeneous(16, "G").expect("design G"))
            .topology(Topology::torus2d(4, 4))
            .slo(slo)
            .trace(Tracer::off())
            .build()
    };
    let unsampled = build(None);
    let sampled = build(Some(quiet));
    let faults = FaultPlan::none();

    let time_one = |sim: &ClusterSim| {
        let t = Instant::now();
        let out = sim.simulate_elastic(&plan, &faults).expect("fleet survives");
        assert!(out.schedule.makespan_seconds > 0.0);
        assert_eq!(out.slo_grown_cards, 0, "the quiet SLO must never grow");
        t.elapsed().as_secs_f64()
    };

    let fast = std::env::var("SYSTO3D_BENCH_FAST").as_deref() == Ok("1");
    let (warmup, pairs) = if fast { (1, 5) } else { (2, 15) };
    let mut attempt = 0;
    let ratio = loop {
        attempt += 1;
        for _ in 0..warmup {
            time_one(&unsampled);
            time_one(&sampled);
        }
        let mut ratios: Vec<f64> = (0..pairs)
            .map(|i| {
                // Alternate the order within each pair so drift cancels.
                if i % 2 == 0 {
                    let s = time_one(&sampled);
                    let u = time_one(&unsampled);
                    s / u
                } else {
                    let u = time_one(&unsampled);
                    let s = time_one(&sampled);
                    s / u
                }
            })
            .collect();
        ratios.sort_by(|a, b| a.total_cmp(b));
        let median = ratios[ratios.len() / 2];
        println!("  attempt {attempt}: sampled/unsampled median ratio {median:.4} ({pairs} pairs)");
        if median < 1.03 || attempt >= 3 {
            break median;
        }
        println!("  noisy sample, retrying");
    };
    assert!(ratio < 1.03, "armed SLO monitor costs more than 3%: median ratio {ratio:.4}");
    println!("  PASS: armed-but-quiet monitor overhead {:.2}% < 3%", (ratio - 1.0) * 100.0);
}
