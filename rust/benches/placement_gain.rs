//! Bench: the topology-aware placement optimizer — host-side search
//! cost, and the contention-priced gain it buys over identity
//! placement on ring and torus fabrics.
//!
//! The search replays the 2.5D plan's reduction sends under the
//! link-contention model per candidate map, so its host cost scales
//! with cards² × sends; this bench keeps that honest while printing
//! the simulated numbers the optimizer is judged by.
//!
//! ```sh
//! cargo bench --bench placement_gain
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::Topology;
use systo3d::placement::{optimize, PlacementStrategy};

fn main() {
    let b = common::bench();
    let d2 = 21504u64;

    for n in [16usize, 32] {
        let plan = PartitionPlan::new(PartitionStrategy::auto_summa25d(n as u64), d2, d2, d2)
            .expect("plan");
        common::section(&format!("placement: local search over {n} cards (host cost)"));
        for topo in [Topology::ring(n), Topology::torus_near_square(n)] {
            let name = topo.name();
            let s = b.run(&format!("optimize {name} n={n}"), || {
                optimize(&plan, &topo, PlacementStrategy::default()).evaluations
            });
            common::report(&s);
            let rep = optimize(&plan, &topo, PlacementStrategy::default());
            println!(
                "  {name}: reduction drain {:.4} s -> {:.4} s ({:.2}x), \
                 hop-bytes -{:.0}%, {} candidate(s) priced",
                rep.identity_cost_seconds,
                rep.placed_cost_seconds,
                rep.gain(),
                rep.hop_byte_saving() * 100.0,
                rep.evaluations,
            );
        }
    }

    common::section("placement: end-to-end makespan, identity vs placed (n=16, ring)");
    let plan = PartitionPlan::new(PartitionStrategy::auto_summa25d(16), d2, d2, d2)
        .expect("plan");
    let topo = Topology::ring(16);
    let rep = optimize(&plan, &topo, PlacementStrategy::default());
    let placed = rep.placement.apply_to(&plan);
    let sim = ClusterSim::builder(Fleet::homogeneous(16, "G").expect("design G"))
        .topology(topo)
        .placement(PlacementStrategy::Identity)
        .build();
    let s = b.run("simulate placed 2.5d ring n=16", || {
        sim.simulate(&placed).makespan_seconds
    });
    common::report(&s);
    println!(
        "  identity {:.4} s vs placed {:.4} s",
        sim.simulate(&plan).makespan_seconds,
        sim.simulate(&placed).makespan_seconds,
    );
}
