//! Bench: the Strassen layer — planner host cost, functional recursion
//! vs the blocked GEMM on the host CPU, and the simulated effective
//! throughput the subsystem is judged by.
//!
//! ```sh
//! cargo bench --bench strassen_speedup
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::blocked::{Level1Blocking, OffchipDesign};
use systo3d::gemm::{matmul_blocked, Matrix};
use systo3d::strassen::{self, strassen_matmul, StrassenConfig};
use systo3d::systolic::ArraySize;

fn design_g() -> OffchipDesign {
    OffchipDesign {
        blocking: Level1Blocking::new(ArraySize::new(64, 32, 2, 2), 512, 512),
        fmax_mhz: 398.0,
        controller_efficiency: 0.97,
    }
}

fn main() {
    let b = common::bench();
    let config = StrassenConfig::default();

    common::section("strassen: planner host cost (pure arithmetic, 4 depths)");
    let s = b.run("plan d2=21504 design G", || {
        strassen::plan(design_g(), 21504, 21504, 21504, &config).depth
    });
    common::report(&s);

    common::section("strassen: functional recursion vs blocked GEMM (768^3, host CPU)");
    let a = Matrix::random(768, 768, 1);
    let m = Matrix::random(768, 768, 2);
    let s0 = b.run("matmul_blocked", || matmul_blocked(&a, &m).at(0, 0));
    common::report(&s0);
    for depth in [1u32, 2] {
        let s1 = b.run(&format!("strassen depth {depth}"), || {
            strassen_matmul(&a, &m, depth).at(0, 0)
        });
        common::report(&s1);
        println!("  host time vs blocked: {:.2}x", s0.median() / s1.median());
    }

    common::section("strassen: simulated effective GFLOPS vs eq. 5 peak (design G)");
    let peak = design_g().peak_gflops();
    for d2 in [8192u64, 16384, 21504, 32768] {
        let p = strassen::plan(design_g(), d2, d2, d2, &config);
        println!(
            "d2={d2:>6}: depth {} -> {:.0} effective GFLOPS of {peak:.0} peak \
             ({:.3}x, speedup {:.3}x vs classical)",
            p.depth,
            p.chosen().effective_gflops,
            p.effective_vs_peak(),
            p.speedup_vs_classical(),
        );
    }
}
