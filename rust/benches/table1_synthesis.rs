//! Bench: regenerate **Table I** (synthesis results A–N) and time the
//! synthesis-model hot paths.
//!
//! ```sh
//! cargo bench --bench table1_synthesis
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::dse::{paper_catalog, Explorer};
use systo3d::reports;

fn main() {
    common::section("TABLE I reproduction");
    print!("{}", reports::table1());
    print!("{}", reports::table1_residuals());

    common::section("paper-vs-model verdict");
    let ex = Explorer::default();
    let mut agree = 0;
    let mut total = 0;
    for spec in paper_catalog() {
        let p = ex.evaluate(spec.array);
        total += 1;
        if p.outcome.fits() == spec.fmax_mhz.is_some() {
            agree += 1;
        }
    }
    println!("fit/fail agreement: {agree}/{total}");
    assert_eq!(agree, total, "fitter model regressed vs Table I");

    common::section("synthesis-model throughput");
    let b = common::bench();
    let s = b.run("explorer.evaluate (1 design)", || {
        let ex = Explorer::default();
        std::hint::black_box(ex.evaluate(systo3d::systolic::ArraySize::new(64, 32, 2, 2)))
    });
    common::report(&s);
    let s = b.run("explorer.sweep (360 candidates)", || {
        let ex = Explorer::default();
        std::hint::black_box(ex.sweep(
            &[16, 28, 32, 48, 64, 70, 72, 96],
            &[8, 16, 28, 32],
            &[2, 4, 6, 8],
        ))
    });
    common::report(&s);
}
