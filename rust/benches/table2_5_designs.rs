//! Bench: regenerate **Tables II–V** — the per-design d² sweeps with
//! CPU and GPU reference rows.
//!
//! For every fitted design (C, E, F, G–N) and every published d², the
//! event-level simulator produces (GFLOPS, e_D); alongside we print the
//! paper's measured value, the deviation, the paper's CPU/GPU rows, our
//! GPU roofline model, and a **measured CPU** column (this testbed's
//! blocked SGEMM, sizes ≤ 1344 to keep bench time bounded).
//!
//! ```sh
//! cargo bench --bench table2_5_designs
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::baselines::cpu::measure_blocked_sgemm;
use systo3d::baselines::gpu::GpuRoofline;
use systo3d::baselines::published::{lookup, CPU_ROWS, GPU_ROWS};
use systo3d::blocked::{OffchipDesign, OffchipSim};
use systo3d::dse::paper_catalog;

/// The paper's measured (T_flops, e_D) per design per sweep index.
fn paper_rows(id: &str) -> Option<&'static [(f64, f64)]> {
    Some(match id {
        "C" => &[(1789.0, 0.51), (2333.0, 0.67), (2715.0, 0.78), (2907.0, 0.84), (3019.0, 0.87), (3083.0, 0.89)],
        "E" => &[(1622.0, 0.47), (2409.0, 0.71), (2787.0, 0.82), (3043.0, 0.90), (3221.0, 0.95), (3301.0, 0.97)],
        "F" => &[(1704.0, 0.46), (2513.0, 0.68), (3003.0, 0.81), (3270.0, 0.89), (3445.0, 0.94), (3536.0, 0.96)],
        "G" => &[(1486.0, 0.45), (2150.0, 0.65), (2625.0, 0.80), (2912.0, 0.89), (3070.0, 0.94), (3159.0, 0.97)],
        "H" => &[(1588.0, 0.47), (2192.0, 0.65), (2687.0, 0.80), (2954.0, 0.88), (3157.0, 0.94), (3248.0, 0.97)],
        "I" => &[(1560.0, 0.48), (2160.0, 0.66), (2622.0, 0.80), (2904.0, 0.89), (3065.0, 0.94), (3152.0, 0.97)],
        "L" => &[(1513.0, 0.47), (2105.0, 0.65), (2579.0, 0.80), (2830.0, 0.88), (3015.0, 0.94), (3104.0, 0.97)],
        "M" => &[(1469.0, 0.49), (2015.0, 0.67), (2427.0, 0.81), (2649.0, 0.89), (2815.0, 0.94), (2890.0, 0.97)],
        "N" => &[(1552.0, 0.49), (2078.0, 0.66), (2533.0, 0.81), (2801.0, 0.89), (2951.0, 0.94), (3036.0, 0.97)],
        _ => return None,
    })
}

fn main() {
    let gpu = GpuRoofline::rtx_2080_ti();
    let fast = std::env::var("SYSTO3D_BENCH_FAST").as_deref() == Ok("1");
    let cpu_cap = if fast { 512 } else { 1344 };

    let mut worst_rel: f64 = 0.0;
    let mut worst_ed: f64 = 0.0;
    for spec in paper_catalog() {
        let (Some(blocking), Some(fmax)) = (spec.level1(), spec.fmax_mhz) else { continue };
        let table_no = match spec.id {
            "C" => "II",
            "E" => "III",
            "F" => "IV",
            _ => "V",
        };
        common::section(&format!(
            "TABLE {table_no} — design {} ({},{},{},dp={}) @ {fmax} MHz",
            spec.id, spec.array.di0, spec.array.dj0, spec.array.dk0, spec.array.dp
        ));
        println!(
            "{:>7} | {:>8} {:>6} | {:>8} {:>6} | {:>8} | {:>9} {:>9} | {:>9} {:>9}",
            "d2", "sim", "e_D", "paper", "e_D", "dev%", "paperCPU", "measCPU", "paperGPU", "modelGPU"
        );
        let sim = OffchipSim::new(OffchipDesign {
            blocking,
            fmax_mhz: fmax,
            controller_efficiency: 0.97,
        });
        let cpu_key = if ["G", "H", "I", "L", "M", "N"].contains(&spec.id) { "G-N" } else { spec.id };
        let rows = paper_rows(spec.id).unwrap();
        let dj2s = spec.sweep_dj2();
        for (i, &d2) in spec.sweep.iter().enumerate() {
            let dj2 = dj2s[i];
            let r = sim.simulate(d2, dj2, d2);
            let (paper_g, paper_e) = rows[i];
            let dev = (r.gflops - paper_g) / paper_g * 100.0;
            worst_rel = worst_rel.max(dev.abs());
            worst_ed = worst_ed.max((r.e_d - paper_e).abs());
            let meas_cpu = if d2 <= cpu_cap {
                format!("{:>9.1}", measure_blocked_sgemm(d2, 42 + d2).gflops)
            } else {
                format!("{:>9}", "-")
            };
            let pc = lookup(CPU_ROWS, cpu_key, d2).map(|g| format!("{g:>9.0}")).unwrap_or_else(|| format!("{:>9}", "-"));
            let pg = lookup(GPU_ROWS, cpu_key, d2).map(|g| format!("{g:>9.0}")).unwrap_or_else(|| format!("{:>9}", "-"));
            println!(
                "{:>7} | {:>8.0} {:>6.2} | {:>8.0} {:>6.2} | {:>+7.1}% | {} {} | {} {:>9.0}",
                d2, r.gflops, r.e_d, paper_g, paper_e, dev, pc, meas_cpu, pg,
                gpu.gflops(d2, d2, dj2)
            );
        }
    }

    common::section("verdict");
    println!("worst |deviation| vs paper GFLOPS: {worst_rel:.1}%");
    println!("worst |e_D error| vs paper:        {worst_ed:.3}");
    println!(
        "note: the worst residual is design C's large-d² tail (sim 0.97 vs paper 0.89).\n\
         eq. 19 — the PAPER'S OWN model — also predicts 0.97 there, so the residual is\n\
         internal to the paper (§VI text vs Table II); see EXPERIMENTS.md."
    );
    assert!(worst_rel < 12.0, "simulator drifted from the paper's shape");
    assert!(worst_ed < 0.09, "efficiency curve drifted");

    common::section("event-simulator throughput");
    let b = common::bench();
    let spec = paper_catalog().into_iter().find(|d| d.id == "G").unwrap();
    let sim = OffchipSim::new(OffchipDesign {
        blocking: spec.level1().unwrap(),
        fmax_mhz: 398.0,
        controller_efficiency: 0.97,
    });
    let s = b.run("all Tables II–V cells (54 sims)", || {
        let mut acc = 0.0;
        for spec in paper_catalog() {
            let (Some(bl), Some(f)) = (spec.level1(), spec.fmax_mhz) else { continue };
            let sim = OffchipSim::new(OffchipDesign { blocking: bl, fmax_mhz: f, controller_efficiency: 0.97 });
            let djs = spec.sweep_dj2();
            for (i, &d2) in spec.sweep.iter().enumerate() {
                acc += sim.simulate(d2, djs[i], d2).gflops;
            }
        }
        acc
    });
    common::report(&s);
    let s = b.run("single 21504³ cell", || sim.simulate(16384, 16384, 16384).gflops);
    common::report(&s);
}
