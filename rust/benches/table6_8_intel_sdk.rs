//! Bench: regenerate **Tables VI–VIII** — the Intel SDK 2D systolic
//! baseline: synthesis outcomes, performance sweeps, and the host
//! reordering tax the paper charges against it.
//!
//! ```sh
//! cargo bench --bench table6_8_intel_sdk
//! ```

#[path = "bench_common.rs"]
mod common;

use systo3d::baselines::intel_sdk::{table6_attempts, IntelSdkSim};
use systo3d::fpga::Fitter;
use systo3d::memory::layout::{block_reorder_f32, block_unorder_f32, transpose_f32};
use systo3d::reports;

fn main() {
    common::section("TABLE VI reproduction");
    print!("{}", reports::table6());
    let fitter = Fitter::default();
    for (cfg, paper) in table6_attempts() {
        let fits = fitter.place(&cfg.placement()).fits();
        assert_eq!(fits, paper.is_some(), "Table VI outcome regressed: {cfg:?}");
    }
    println!("fit/fail agreement: 6/6");

    common::section("TABLES VII & VIII reproduction");
    print!("{}", reports::table7_8());
    // Check the efficiency curves against the paper's rows.
    let meas14 = [0.46, 0.74, 0.92, 0.97, 0.98];
    let meas16 = [0.48, 0.78, 0.95, 0.98, 0.99];
    for (sim, meas) in [
        (IntelSdkSim::config_32x14(), &meas14),
        (IntelSdkSim::config_32x16(), &meas16),
    ] {
        for (i, want) in meas.iter().enumerate() {
            let got = sim.efficiency(512 << i);
            assert!((got - want).abs() < 0.04, "SDK e_D regressed at {}", 512 << i);
        }
    }
    println!("efficiency curves within ±0.04 of the paper on all 10 points");

    common::section("crossover claim (§VI)");
    let sdk = IntelSdkSim::config_32x16();
    let ours = {
        use systo3d::blocked::{OffchipDesign, OffchipSim};
        let spec = systo3d::dse::paper_catalog().into_iter().find(|d| d.id == "G").unwrap();
        OffchipSim::new(OffchipDesign {
            blocking: spec.level1().unwrap(),
            fmax_mhz: spec.fmax_mhz.unwrap(),
            controller_efficiency: 0.97,
        })
    };
    for d2 in [1024u64, 2048, 4096, 8192] {
        let sdk_e = sdk.efficiency(d2);
        let our_e = ours.simulate(d2, d2, d2).e_d;
        println!("  d2={d2}: SDK e_D {sdk_e:.2} vs 3D design e_D {our_e:.2}");
    }
    assert!(sdk.efficiency(2048) > 0.9 && ours.simulate(2048, 2048, 2048).e_d < 0.9);
    assert!(ours.simulate(8192, 8192, 8192).e_d > 0.9);
    println!("SDK crosses e_D=0.9 one octave earlier — reproduced");

    common::section("host-reorder tax (the 3D design's advantage)");
    let (m, k, n) = (4096u64, 4096u64, 4096u64);
    let kernel = sdk.seconds(m, k, n);
    let with_tax = sdk.seconds_with_reorders(m, k, n);
    println!(
        "  SDK 4096³: kernel {kernel:.4} s, with host reorders {with_tax:.4} s (+{:.1}%)",
        (with_tax / kernel - 1.0) * 100.0
    );
    println!("  3D design: A transposed once, C stays row-major -> chained multiplies free");

    common::section("reorder-kernel microbenches (measured on this host)");
    let b = common::bench();
    let n_el = 1024usize;
    let src: Vec<f32> = (0..n_el * n_el).map(|x| x as f32).collect();
    let s = b.run("transpose 1024x1024 f32", || transpose_f32(&src, n_el, n_el));
    common::report(&s);
    println!(
        "  -> {:.2} GB/s effective",
        2.0 * (n_el * n_el * 4) as f64 / s.median() / 1e9
    );
    let s = b.run("block_reorder 1024x1024 (32x8 blocks)", || {
        block_reorder_f32(&src, n_el, n_el, 32, 8)
    });
    common::report(&s);
    let blocked = block_reorder_f32(&src, n_el, n_el, 32, 8);
    let s = b.run("block_unorder 1024x1024", || {
        block_unorder_f32(&blocked, n_el, n_el, 32, 8)
    });
    common::report(&s);
}
