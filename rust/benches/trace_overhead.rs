//! Bench: the flight recorder's cost when it is **off**.
//!
//! Tracing is opt-in, and the promise is near-zero cost for everyone
//! who never opts in: every emit site guards on the sink and takes its
//! span name as a closure, so a no-op sink must evaluate no format
//! strings and touch no buffers. This bench replays the elastic-fleet
//! kill+drain scenario (n = 16 torus, 1 spare) through the default
//! cluster (recorder absent) and through one with an explicitly
//! attached no-op sink, in alternating pairs so machine drift cancels,
//! and **asserts the median paired ratio stays under 1.02** — less
//! than 2% makespan wall-time cost. The recording sink's cost is
//! reported alongside for scale but not gated (opting in buys the
//! trace with the tokens it costs).
//!
//! ```sh
//! cargo bench --bench trace_overhead
//! ```

#[path = "bench_common.rs"]
mod common;

use std::time::Instant;
use systo3d::cluster::{ClusterSim, FaultPlan, Fleet, PartitionPlan, PartitionStrategy};
use systo3d::fabric::Topology;
use systo3d::trace::Tracer;

fn main() {
    let d2 = 21504u64;
    common::section("trace: no-op sink overhead on the elastic kill+drain replay (n=16)");
    let plan =
        PartitionPlan::new(PartitionStrategy::auto_summa25d(16), d2, d2, d2).expect("plan");
    let build = || {
        ClusterSim::builder(Fleet::homogeneous(17, "G").expect("design G"))
            .topology(Topology::torus2d(4, 4))
            .spares(1)
            .build()
    };
    let default_sim = build();
    let mut noop_sim = build();
    noop_sim.trace = Tracer::off();
    let first = plan.shards.iter().find(|s| s.device == 0).expect("shard on card 0");
    let t_die = default_sim.host.seconds_for_bytes(first.input_bytes())
        + 0.5 * default_sim.shard_seconds(0, first);
    let faults = FaultPlan::kill(0, t_die);

    let time_one = |sim: &ClusterSim| {
        let t = Instant::now();
        let out = sim.simulate_elastic(&plan, &faults).expect("survivors remain");
        assert!(out.schedule.makespan_seconds > 0.0);
        t.elapsed().as_secs_f64()
    };

    let fast = std::env::var("SYSTO3D_BENCH_FAST").as_deref() == Ok("1");
    let (warmup, pairs) = if fast { (1, 5) } else { (2, 15) };
    let mut attempt = 0;
    let ratio = loop {
        attempt += 1;
        for _ in 0..warmup {
            time_one(&default_sim);
            time_one(&noop_sim);
        }
        let mut ratios: Vec<f64> = (0..pairs)
            .map(|i| {
                // Alternate the order within each pair so drift cancels.
                if i % 2 == 0 {
                    let n = time_one(&noop_sim);
                    let d = time_one(&default_sim);
                    n / d
                } else {
                    let d = time_one(&default_sim);
                    let n = time_one(&noop_sim);
                    n / d
                }
            })
            .collect();
        ratios.sort_by(|a, b| a.total_cmp(b));
        let median = ratios[ratios.len() / 2];
        println!("  attempt {attempt}: no-op/default median ratio {median:.4} ({pairs} pairs)");
        if median < 1.02 || attempt >= 3 {
            break median;
        }
        println!("  noisy sample, retrying");
    };
    assert!(ratio < 1.02, "no-op trace sink costs more than 2%: median ratio {ratio:.4}");
    println!("  PASS: no-op sink overhead {:.2}% < 2%", (ratio - 1.0) * 100.0);

    common::section("trace: recording sink, for scale (not gated)");
    let mut rec_sim = build();
    rec_sim.trace = Tracer::recording();
    let t_rec = time_one(&rec_sim);
    let spans = rec_sim.trace.snapshot().spans.len();
    let t_off = time_one(&default_sim);
    println!(
        "  recording: {:.4} s vs off {:.4} s ({:.2}x) for {} span(s)",
        t_rec,
        t_off,
        t_rec / t_off,
        spans
    );
}
