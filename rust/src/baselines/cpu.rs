//! CPU SGEMM measured on *this* testbed.
//!
//! The paper's CPU rows come from MKL on a 20-core Xeon Gold 6148; this
//! environment has neither. We measure the in-tree blocked kernel (and,
//! at the coordinator level, the PJRT/XLA path) and report both our
//! measured numbers and the paper's constants, clearly labelled — the
//! tables keep the published shape while the measured column proves the
//! code path end to end.

use crate::gemm::{matmul_blocked, Matrix};
use crate::perfmodel::flop_count;
use std::time::Instant;

/// One CPU measurement.
#[derive(Clone, Copy, Debug)]
pub struct CpuMeasurement {
    pub d2: u64,
    pub seconds: f64,
    pub gflops: f64,
}

/// Measure blocked SGEMM on a d²-cube problem (single-threaded).
pub fn measure_blocked_sgemm(d2: u64, seed: u64) -> CpuMeasurement {
    let n = d2 as usize;
    let a = Matrix::random(n, n, seed);
    let b = Matrix::random(n, n, seed + 1);
    let t0 = Instant::now();
    let c = matmul_blocked(&a, &b);
    let seconds = t0.elapsed().as_secs_f64();
    std::hint::black_box(&c);
    CpuMeasurement {
        d2,
        seconds,
        gflops: flop_count(d2, d2, d2) as f64 / seconds / 1e9,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_is_sane() {
        let m = measure_blocked_sgemm(128, 42);
        assert!(m.seconds > 0.0);
        // A scalar blocked kernel lands between 0.01 (debug build) and
        // 100 GFLOPS (any plausible host, release).
        assert!(m.gflops > 0.01 && m.gflops < 100.0, "{}", m.gflops);
    }

    #[test]
    fn throughput_grows_with_size_until_cache() {
        // 64³ underutilizes the pipeline vs 256³ (both fit L2-ish); the
        // larger problem should not be drastically slower per FLOP.
        let small = measure_blocked_sgemm(64, 1);
        let big = measure_blocked_sgemm(256, 2);
        assert!(big.gflops > small.gflops * 0.5);
    }
}
