//! RTX 2080 Ti roofline stand-in (no GPU in this environment).
//!
//! The GPU rows in Tables II–V only serve as an upper reference line, so
//! a two-parameter roofline suffices: fp32 peak 13.45 TFLOPS, 616 GB/s
//! GDDR6, with a launch/occupancy ramp `d²/(d²+c_ramp)` calibrated on
//! the paper's cuBLAS rows (c_ramp = 650 keeps all 23 published points
//! within ±18.5%; the worst residual is the C-table d²=10752 row, which
//! the paper itself shows dipping below its smaller sibling).

use crate::perfmodel::flop_count;

/// GPU model parameters.
#[derive(Clone, Copy, Debug)]
pub struct GpuRoofline {
    pub peak_gflops: f64,
    pub mem_gb_s: f64,
    pub c_ramp: f64,
}

impl GpuRoofline {
    pub fn rtx_2080_ti() -> Self {
        Self { peak_gflops: 13_450.0, mem_gb_s: 616.0, c_ramp: 650.0 }
    }

    /// Occupancy/launch ramp for a d²-cube SGEMM.
    pub fn ramp(&self, d2: u64) -> f64 {
        d2 as f64 / (d2 as f64 + self.c_ramp)
    }

    /// Roofline-sustained GFLOPS for an (m, k, n) SGEMM.
    pub fn gflops(&self, m: u64, k: u64, n: u64) -> f64 {
        // Arithmetic intensity of blocked SGEMM is high enough that the
        // compute roof dominates for every size in the tables; keep the
        // bandwidth roof anyway for tiny shapes.
        let flops = flop_count(m, n, k) as f64;
        let bytes = 4.0 * (m * k + k * n + m * n) as f64;
        let compute_bound = self.peak_gflops * self.ramp(m.min(n).min(k));
        let mem_bound = flops / (bytes / (self.mem_gb_s * 1e9)) / 1e9;
        compute_bound.min(mem_bound)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::published::{lookup, GPU_ROWS};

    #[test]
    fn tracks_paper_cublas_rows_within_noise() {
        // cuBLAS + thermals are noisy; ±18.5% band on the paper's rows.
        let g = GpuRoofline::rtx_2080_ti();
        for (table, vals) in GPU_ROWS {
            for &(d2, paper) in vals.iter() {
                let model = g.gflops(d2, d2, d2);
                let rel = (model - paper).abs() / paper;
                assert!(rel < 0.185, "{table} d2={d2}: model {model:.0} vs paper {paper:.0}");
            }
        }
    }

    #[test]
    fn gpu_dominates_fpga_rows() {
        // The paper's conclusion: "GPUs deliver easily higher performance".
        let g = GpuRoofline::rtx_2080_ti();
        for d2 in [1024u64, 4096, 16384] {
            assert!(g.gflops(d2, d2, d2) > 3673.0 * 1.5, "d2={d2}");
        }
    }

    #[test]
    fn ramp_monotone() {
        let g = GpuRoofline::rtx_2080_ti();
        assert!(g.ramp(512) < g.ramp(4096));
        assert!(g.ramp(1 << 20) > 0.999);
    }

    #[test]
    fn tiny_shapes_hit_bandwidth_roof() {
        let g = GpuRoofline::rtx_2080_ti();
        // A rank-deficient (skinny) product is memory-bound.
        let skinny = g.gflops(16384, 1, 16384);
        assert!(skinny < 2000.0, "{skinny}");
    }

    #[test]
    fn lookup_sanity_against_model_usage() {
        assert!(lookup(GPU_ROWS, "G-N", 512).unwrap() > 5000.0);
    }
}
