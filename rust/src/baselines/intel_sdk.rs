//! The Intel FPGA SDK for OpenCL matrix-multiplication example — the
//! paper's principal baseline (§VI, Tables VI–VIII).
//!
//! A bi-dimensional PE_ROWS × PE_COLS systolic array of dot-product
//! units (size 4, 8 or 16; optionally split into two size-4 units),
//! built from multiple kernels connected by channels. Differences from
//! the paper's 3D design that the model captures:
//!
//! * **Broadcast-style interconnect** → the per-PE routing pressure term
//!   in the fitter model (`InterconnectStyle::Broadcast`), which is what
//!   makes its 4096-DSP dot-8 configurations fail where the 3D design's
//!   fit.
//! * **Fully overlapped writes** → efficiency rises one octave earlier
//!   (e_D > 0.9 from d_k2 ≥ 2048 vs 4096); modelled with a fill/drain
//!   overhead calibrated on the published rows.
//! * **Host-side reordering tax** → A block-reordered, B transposed +
//!   block-reordered, C two-level reverse-reordered; the end-to-end
//!   comparison in the coordinator charges these through
//!   [`crate::memory::layout`].

use crate::fpga::{InterconnectStyle, PlacementRequest};
use crate::memory::layout::{HostReorder, Layout};
use crate::perfmodel::{eq5_peak_flops, flop_count};

/// One synthesis configuration of the SDK example.
#[derive(Clone, Copy, Debug)]
pub struct IntelSdkConfig {
    pub pe_rows: u32,
    pub pe_cols: u32,
    /// DOT_PROD_VECTOR_SIZE (4, 8 or 16).
    pub dot_size: u32,
    /// FORCE_DOT_4: split into two size-4 units per PE.
    pub force_dot_4: bool,
}

impl IntelSdkConfig {
    /// DSPs per PE (dot units × size).
    pub fn dsps_per_pe(&self) -> u32 {
        self.dot_size // splitting doesn't change the DSP count
    }

    pub fn pes(&self) -> u32 {
        self.pe_rows * self.pe_cols
    }

    pub fn dsps(&self) -> u32 {
        self.pes() * self.dsps_per_pe()
    }

    /// Effective dot-unit size for placement (4 when split).
    pub fn placement_dot(&self) -> u32 {
        if self.force_dot_4 {
            4
        } else {
            self.dot_size
        }
    }

    /// Matrix-size constraints (§VI): d_i2 multiple of 1024; d_j2
    /// multiple of 32·PE_COLS (448 for 32×14, 512 for 32×16).
    pub fn di2_multiple(&self) -> u64 {
        1024
    }

    pub fn dj2_multiple(&self) -> u64 {
        32 * self.pe_cols as u64
    }

    /// Placement request for the fitter model.
    pub fn placement(&self) -> PlacementRequest {
        PlacementRequest {
            dsps: self.dsps(),
            dp: self.placement_dot(),
            pes: self.pes(),
            style: InterconnectStyle::Broadcast,
        }
    }

    /// Host reorders needed before/after one multiplication (§VI).
    pub fn host_reorders(&self, m: u64, k: u64, n: u64) -> Vec<HostReorder> {
        let blk = Layout::Blocked { bi: self.pe_rows, bj: self.dot_size };
        vec![
            // A: block-wise reorder.
            HostReorder { from: Layout::RowMajor, to: blk, m, n: k },
            // B: transpose + block-wise reorder.
            HostReorder { from: Layout::RowMajor, to: Layout::ColMajor, m: k, n },
            HostReorder { from: Layout::ColMajor, to: blk, m: k, n },
            // C: two-level reverse reorder back to row-major.
            HostReorder {
                from: Layout::TwoLevelBlocked { bi: self.pe_rows, bj: self.pe_cols },
                to: Layout::RowMajor,
                m,
                n,
            },
        ]
    }
}

/// The calibrated performance model of the SDK design.
#[derive(Clone, Debug)]
pub struct IntelSdkSim {
    pub config: IntelSdkConfig,
    pub fmax_mhz: f64,
    /// Fill/drain overhead constant: e_D = d_k2² / (d_k2² + c_fill).
    /// Calibrated on the d²=512 row of Tables VII/VIII.
    pub c_fill: f64,
}

impl IntelSdkSim {
    /// The 32×14 dot-8 configuration (README-optimal; Table VII).
    pub fn config_32x14() -> Self {
        Self {
            config: IntelSdkConfig { pe_rows: 32, pe_cols: 14, dot_size: 8, force_dot_4: false },
            fmax_mhz: 412.0,
            c_fill: 3.07e5,
        }
    }

    /// The 32×16 2×dot-4 configuration (best found; Table VIII).
    pub fn config_32x16() -> Self {
        Self {
            config: IntelSdkConfig { pe_rows: 32, pe_cols: 16, dot_size: 8, force_dot_4: true },
            fmax_mhz: 407.0,
            c_fill: 2.84e5,
        }
    }

    pub fn peak_gflops(&self) -> f64 {
        eq5_peak_flops(self.config.dsps(), self.fmax_mhz) / 1e9
    }

    /// DSP efficiency at contraction size d_k2.
    ///
    /// The SDK design overlaps Read, Compute and Write completely; what
    /// remains is the per-block pipeline fill/drain of its channel-
    /// connected kernel chain, amortized quadratically in d_k2 (fill is
    /// linear in d_k2 per block row while work grows as d_k2²).
    pub fn efficiency(&self, dk2: u64) -> f64 {
        let k2 = (dk2 * dk2) as f64;
        k2 / (k2 + self.c_fill)
    }

    /// Sustained GFLOPS for an (m, k, n) problem (kernel time only, like
    /// the paper's measurement).
    pub fn gflops(&self, m: u64, k: u64, n: u64) -> f64 {
        self.peak_gflops() * self.efficiency(k) * flop_count(m, n, k) as f64
            / (2.0 * m as f64 * n as f64 * k as f64)
    }

    /// Kernel seconds for an (m, k, n) problem.
    pub fn seconds(&self, m: u64, k: u64, n: u64) -> f64 {
        flop_count(m, n, k) as f64 / (self.gflops(m, k, n) * 1e9)
    }

    /// End-to-end seconds including the host reorder tax — the cost the
    /// paper argues makes the SDK design unusable for chained multiplies.
    pub fn seconds_with_reorders(&self, m: u64, k: u64, n: u64) -> f64 {
        let reorder: f64 =
            self.config.host_reorders(m, k, n).iter().map(|r| r.seconds()).sum();
        self.seconds(m, k, n) + reorder
    }
}

/// All Table VI synthesis attempts with their published outcomes.
pub fn table6_attempts() -> Vec<(IntelSdkConfig, Option<f64>)> {
    let cfg = |r, c, d, f4| IntelSdkConfig { pe_rows: r, pe_cols: c, dot_size: d, force_dot_4: f4 };
    vec![
        (cfg(32, 18, 8, false), None),
        (cfg(32, 18, 8, true), None),
        (cfg(32, 16, 8, false), None),
        (cfg(32, 16, 8, true), Some(407.0)),
        (cfg(32, 32, 4, false), None),
        (cfg(32, 14, 8, false), Some(412.0)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_fit_outcomes_via_fitter() {
        let fitter = crate::fpga::Fitter::default();
        for (cfg, fmax) in table6_attempts() {
            let fits = fitter.place(&cfg.placement()).fits();
            assert_eq!(fits, fmax.is_some(), "{cfg:?}");
        }
    }

    #[test]
    fn table6_dsp_counts() {
        let s14 = IntelSdkSim::config_32x14();
        assert_eq!(s14.config.dsps(), 3584);
        let s16 = IntelSdkSim::config_32x16();
        assert_eq!(s16.config.dsps(), 4096);
    }

    #[test]
    fn table6_peak_gflops() {
        assert!((IntelSdkSim::config_32x14().peak_gflops() - 2953.0).abs() < 1.0);
        assert!((IntelSdkSim::config_32x16().peak_gflops() - 3334.0).abs() < 1.0);
    }

    #[test]
    fn table7_efficiency_curve() {
        // Table VII: e_D = .46 .74 .92 .97 .98 at d2=512..8192.
        let s = IntelSdkSim::config_32x14();
        let meas = [0.46, 0.74, 0.92, 0.97, 0.98];
        for (i, d2) in [512u64, 1024, 2048, 4096, 8192].iter().enumerate() {
            let e = s.efficiency(*d2);
            assert!((e - meas[i]).abs() < 0.04, "d2={d2}: {e:.3} vs {}", meas[i]);
        }
    }

    #[test]
    fn table8_efficiency_curve() {
        // Table VIII: e_D = .48 .78 .95 .98 .99.
        let s = IntelSdkSim::config_32x16();
        let meas = [0.48, 0.78, 0.95, 0.98, 0.99];
        for (i, d2) in [512u64, 1024, 2048, 4096, 8192].iter().enumerate() {
            let e = s.efficiency(*d2);
            assert!((e - meas[i]).abs() < 0.04, "d2={d2}: {e:.3} vs {}", meas[i]);
        }
    }

    #[test]
    fn crossover_one_octave_before_3d_design() {
        // §VI: SDK reaches e_D > 0.9 at d_k2 >= 2048; the 3D designs only
        // at d_k2 > 4096 (checked in blocked::offchip tests).
        let s = IntelSdkSim::config_32x16();
        assert!(s.efficiency(2048) > 0.9);
        assert!(s.efficiency(1024) < 0.9);
    }

    #[test]
    fn matrix_constraints() {
        let s14 = IntelSdkSim::config_32x14().config;
        assert_eq!(s14.dj2_multiple(), 448);
        let s16 = IntelSdkSim::config_32x16().config;
        assert_eq!(s16.dj2_multiple(), 512);
    }

    #[test]
    fn reorder_tax_positive_and_chargeable() {
        let s = IntelSdkSim::config_32x16();
        let (m, k, n) = (4096, 4096, 4096);
        let with = s.seconds_with_reorders(m, k, n);
        let without = s.seconds(m, k, n);
        assert!(with > without);
        // Four full-matrix permutation passes: a visible, not dominant, tax.
        let tax = (with - without) / without;
        assert!(tax > 0.05, "tax {tax}");
    }

    #[test]
    fn gflops_accounts_paper_flop_convention() {
        // gflops uses (2k-1) FLOP like the paper: slightly below
        // peak·e_D which assumes 2k.
        let s = IntelSdkSim::config_32x14();
        let g = s.gflops(1024, 512, 448);
        assert!(g < s.peak_gflops() * s.efficiency(512));
        assert!(g > s.peak_gflops() * s.efficiency(512) * 0.99);
    }
}
