//! The paper's comparison baselines (§VI).
//!
//! * [`intel_sdk`] — the Intel FPGA SDK matrix-multiply example: a 2D
//!   systolic array with channel-connected kernels; reproduces Tables
//!   VI–VIII and the host-reordering tax the paper charges it.
//! * [`published`] — fixed published reference points: FBLAS and the
//!   authors' earlier Cannon implementation (both non-Hyperflex), and
//!   the paper's CPU (MKL / Xeon 6148) and GPU (cuBLAS / RTX 2080 Ti)
//!   rows.
//! * [`cpu`] — SGEMM measured on *this* testbed through the same code
//!   paths the coordinator serves (blocked Rust kernel and the PJRT
//!   runtime).
//! * [`gpu`] — an RTX 2080 Ti roofline stand-in (no GPU in this
//!   environment; DESIGN.md §2 documents the substitution).

pub mod cpu;
pub mod gpu;
pub mod intel_sdk;
pub mod published;

pub use intel_sdk::{IntelSdkConfig, IntelSdkSim};
pub use published::{PublishedPoint, CPU_ROWS, FBLAS, CANNON, GPU_ROWS};
