//! Published reference points quoted by §VI: FBLAS, the authors' Cannon
//! implementation, and the paper's CPU/GPU measurement rows. These are
//! *recorded constants* (clearly labelled in every table we print) that
//! preserve the published comparison shape alongside our measured and
//! modelled numbers.

/// A published (externally measured) design point.
#[derive(Clone, Copy, Debug)]
pub struct PublishedPoint {
    pub name: &'static str,
    pub dsps: u32,
    pub fmax_mhz: f64,
    /// Approximate sustained GFLOPS reported.
    pub gflops: f64,
    pub hyperflex: bool,
}

/// FBLAS systolic SGEMM on the GX2800 (de Matteis et al., SC20).
pub const FBLAS: PublishedPoint = PublishedPoint {
    name: "FBLAS SGEMM",
    dsps: 3270,
    fmax_mhz: 216.0,
    gflops: 1413.0, // 2·3270·216e6 = "just below 1.5 TFLOPS" peak
    hyperflex: false,
};

/// Cannon's algorithm on the GX2800 (Gorlani et al., ICFPT'19).
pub const CANNON: PublishedPoint = PublishedPoint {
    name: "Cannon (ICFPT'19)",
    dsps: 3323,
    fmax_mhz: 294.0,
    gflops: 1450.0, // "similar to FBLAS", below 1.5 TFLOPS
    hyperflex: false,
};

/// The paper's CPU rows (MKL 20.2 on a Xeon Gold 6148), keyed by the
/// d² sweep of each table. `(d2, gflops)`.
pub const CPU_ROWS: &[(&str, &[(u64, f64)])] = &[
    ("C", &[(672, 1226.0), (1344, 2116.0), (2688, 2073.0), (5376, 2332.0), (10752, 2445.0), (21504, 2302.0)]),
    ("E", &[(576, 1107.0), (1152, 1986.0), (2304, 2181.0), (4608, 2257.0), (9216, 2427.0), (18432, 2311.0)]),
    ("F", &[(560, 1589.0), (1120, 2037.0), (2240, 2182.0), (4480, 2261.0), (8960, 2440.0), (17920, 2309.0)]),
    ("G-N", &[(512, 1281.0), (1024, 1913.0), (2048, 2135.0), (4096, 2200.0), (8192, 2361.0), (16384, 2267.0)]),
];

/// The paper's GPU rows (cuBLAS 11.2 on an RTX 2080 Ti).
pub const GPU_ROWS: &[(&str, &[(u64, f64)])] = &[
    ("C", &[(672, 7603.0), (1344, 9986.0), (2688, 11046.0), (5376, 11808.0), (10752, 10752.0)]),
    ("E", &[(576, 6735.0), (1152, 10288.0), (2304, 10375.0), (4608, 11618.0), (9216, 13113.0), (18432, 12977.0)]),
    ("F", &[(560, 7133.0), (1120, 9432.0), (2240, 11040.0), (4480, 11477.0), (8960, 12993.0), (17920, 12587.0)]),
    ("G-N", &[(512, 5281.0), (1024, 9887.0), (2048, 10921.0), (4096, 11288.0), (8192, 12835.0), (16384, 12867.0)]),
];

/// Look up a published row value.
pub fn lookup(rows: &[(&str, &[(u64, f64)])], table: &str, d2: u64) -> Option<f64> {
    rows.iter()
        .find(|(t, _)| *t == table)
        .and_then(|(_, vals)| vals.iter().find(|(d, _)| *d == d2).map(|&(_, g)| g))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::eq5_peak_flops;

    #[test]
    fn legacy_points_below_1_5_tflops() {
        for p in [FBLAS, CANNON] {
            let peak = eq5_peak_flops(p.dsps, p.fmax_mhz) / 1e9;
            assert!(peak < 2000.0, "{}: {peak}", p.name);
            assert!(p.gflops <= peak + 1.0);
            assert!(!p.hyperflex);
        }
    }

    #[test]
    fn lookup_works() {
        assert_eq!(lookup(CPU_ROWS, "G-N", 4096), Some(2200.0));
        assert_eq!(lookup(GPU_ROWS, "C", 672), Some(7603.0));
        assert_eq!(lookup(CPU_ROWS, "G-N", 999), None);
        assert_eq!(lookup(CPU_ROWS, "zzz", 512), None);
    }

    #[test]
    fn paper_narrative_holds_in_rows() {
        // GPU always above FPGA's ~3 TFLOPS; CPU below beyond warmup sizes.
        for (_, vals) in GPU_ROWS {
            for (_, g) in vals.iter().skip(1) {
                assert!(*g > 9000.0, "GPU row {g}");
            }
        }
        for (_, vals) in CPU_ROWS {
            for (_, g) in vals.iter() {
                assert!(*g < 2500.0, "CPU row {g}");
            }
        }
    }
}
