//! Definition 3 block-matrix representation and the level-1 blocking of
//! Definition 4 (eqs. 14–18).

use crate::gemm::Matrix;
use crate::systolic::ArraySize;

/// The level-1 blocking (superscript-1 sizes): `d_i1 × d_j1` C blocks,
/// each computed by sweeping the systolic array over second-level blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Level1Blocking {
    pub array: ArraySize,
    pub di1: u32,
    pub dj1: u32,
}

impl Level1Blocking {
    pub fn new(array: ArraySize, di1: u32, dj1: u32) -> Self {
        let b = Self { array, di1, dj1 };
        b.validate().expect("invalid Level1Blocking");
        b
    }

    pub fn validate(&self) -> Result<(), String> {
        self.array.validate()?;
        if self.di1 % self.array.di0 != 0 {
            return Err(format!("di1={} not a multiple of di0={}", self.di1, self.array.di0));
        }
        if self.dj1 % self.array.dj0 != 0 {
            return Err(format!("dj1={} not a multiple of dj0={}", self.dj1, self.array.dj0));
        }
        Ok(())
    }

    /// r_A — reuse of each A element (eq. 18: d_j1 = r_A·d_j0).
    pub fn reuse_a(&self) -> u32 {
        self.dj1 / self.array.dj0
    }

    /// r_B — reuse of each B element (eq. 18: d_i1 = r_B·d_i0).
    pub fn reuse_b(&self) -> u32 {
        self.di1 / self.array.di0
    }

    /// Pipeline iterations per second-level slab: one iteration per
    /// (i, j) second-level block pair = r_A·r_B.
    pub fn iterations_per_slab(&self) -> u64 {
        self.reuse_a() as u64 * self.reuse_b() as u64
    }

    /// Global-memory read rates (floats/cycle) implied by the blocking:
    /// `𝓑_gA = 𝓑_A/r_A`, `𝓑_gB = 𝓑_B/r_B` (inverting eq. 14).
    pub fn implied_global_rates(&self) -> (f64, f64) {
        let (ba, bb) = self.array.face_throughputs();
        (ba as f64 / self.reuse_a() as f64, bb as f64 / self.reuse_b() as f64)
    }

    /// Derive the minimum valid blocking for a channel delivering
    /// `global_floats_per_cycle` (eq. 14 + eq. 18, rounding reuse up).
    pub fn derive_min(array: ArraySize, global_floats_per_cycle: u32) -> Self {
        let (ba, bb) = array.face_throughputs();
        let g = global_floats_per_cycle as u64;
        let ra = crate::util::div_ceil(ba, g) as u32;
        let rb = crate::util::div_ceil(bb, g) as u32;
        Self::new(array, rb * array.di0, ra * array.dj0)
    }

    /// Validate off-chip sizes against the table-caption constraints:
    /// d_i2 % d_i1 == 0, d_j2 % d_j1 == 0, d_k2 % d_k0 == 0.
    pub fn validate_offchip(&self, di2: u64, dj2: u64, dk2: u64) -> Result<(), String> {
        if di2 % self.di1 as u64 != 0 {
            return Err(format!("d_i2={di2} must be a multiple of d_i1={}", self.di1));
        }
        if dj2 % self.dj1 as u64 != 0 {
            return Err(format!("d_j2={dj2} must be a multiple of d_j1={}", self.dj1));
        }
        if dk2 % self.array.dk0 as u64 != 0 {
            return Err(format!("d_k2={dk2} must be a multiple of d_k0={}", self.array.dk0));
        }
        Ok(())
    }

    /// The d_j2 that keeps a sweep aspect-true for this blocking:
    /// rectangular blockings (d_i1 ≠ d_j1, design F) scale the column
    /// extent by d_j1/d_i1, square ones keep it at `d2` — the idiom the
    /// CLI `simulate`, `perfgate`, and the off-chip example all share.
    pub fn scale_dj2(&self, d2: u64) -> u64 {
        if self.di1 != self.dj1 {
            d2 * self.dj1 as u64 / self.di1 as u64
        } else {
            d2
        }
    }

    /// Round off-chip extents *up* to the nearest sizes this blocking
    /// accepts (multiples of d_i1, d_j1, d_k0). The cluster scheduler
    /// times irregular shards as if zero-padded to the padded extents —
    /// exactly what the HLS kernel would do with a partial edge block.
    pub fn pad_offchip(&self, di2: u64, dj2: u64, dk2: u64) -> (u64, u64, u64) {
        let up = |v: u64, m: u64| crate::util::div_ceil(v.max(1), m) * m;
        (
            up(di2, self.di1 as u64),
            up(dj2, self.dj1 as u64),
            up(dk2, self.array.dk0 as u64),
        )
    }

    /// On-chip bytes needed: double-buffered A/B staging plus the C
    /// block (for the M20K budget check).
    pub fn onchip_floats(&self) -> u64 {
        let a = 2 * self.di1 as u64 * self.array.dk0 as u64;
        let b = 2 * self.array.dk0 as u64 * self.dj1 as u64;
        let c = self.di1 as u64 * self.dj1 as u64;
        a + b + c
    }
}

/// A matrix stored with Definition-3 block structure metadata (row-major
/// payload; the views do the index math).
#[derive(Clone, Debug)]
pub struct BlockedLayout<'m> {
    pub matrix: &'m Matrix,
    pub bi: usize,
    pub bj: usize,
}

impl<'m> BlockedLayout<'m> {
    pub fn new(matrix: &'m Matrix, bi: usize, bj: usize) -> Self {
        assert!(matrix.rows % bi == 0, "rows {} not divisible by {}", matrix.rows, bi);
        assert!(matrix.cols % bj == 0, "cols {} not divisible by {}", matrix.cols, bj);
        Self { matrix, bi, bj }
    }

    /// Number of block rows / cols.
    pub fn grid(&self) -> (usize, usize) {
        (self.matrix.rows / self.bi, self.matrix.cols / self.bj)
    }

    /// Copy out block (I, J) — `M̄^I_J` of Definition 3.
    pub fn block(&self, bi_idx: usize, bj_idx: usize) -> Matrix {
        let (gi, gj) = self.grid();
        assert!(bi_idx < gi && bj_idx < gj, "block index out of range");
        let mut out = Matrix::zeros(self.bi, self.bj);
        for i in 0..self.bi {
            let src_row = bi_idx * self.bi + i;
            let src = &self.matrix.data
                [src_row * self.matrix.cols + bj_idx * self.bj..][..self.bj];
            out.data[i * self.bj..(i + 1) * self.bj].copy_from_slice(src);
        }
        out
    }

    /// Write a block back into a target matrix at position (I, J).
    pub fn write_block(target: &mut Matrix, bi: usize, bj: usize,
                       bi_idx: usize, bj_idx: usize, block: &Matrix) {
        assert_eq!((block.rows, block.cols), (bi, bj));
        for i in 0..bi {
            let dst_row = bi_idx * bi + i;
            target.data[dst_row * target.cols + bj_idx * bj..][..bj]
                .copy_from_slice(&block.data[i * bj..(i + 1) * bj]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g_array() -> ArraySize {
        ArraySize::new(64, 32, 2, 2)
    }

    #[test]
    fn design_g_blocking_matches_table5_caption() {
        // Designs G–N: d1 = 512 (Table V caption); at 8 floats/cycle.
        let b = Level1Blocking::derive_min(g_array(), 8);
        assert_eq!((b.di1, b.dj1), (512, 512));
        assert_eq!(b.reuse_a(), 16);
        assert_eq!(b.reuse_b(), 8);
        assert_eq!(b.iterations_per_slab(), 128);
    }

    #[test]
    fn design_c_blocking_compatible_with_table2_caption() {
        // Design C: paper uses d1 = 672 (= 24·28); the minimum at 8
        // floats/cycle is 588 (= 21·28). 672 must validate.
        let c = ArraySize::new(28, 28, 6, 1);
        let min = Level1Blocking::derive_min(c, 8);
        assert_eq!(min.di1, 21 * 28);
        let paper = Level1Blocking::new(c, 672, 672);
        assert!(paper.di1 >= min.di1 && paper.dj1 >= min.dj1);
        // 672 = 24·28 -> implied global rate 7 floats/cycle <= 8.
        let (ga, gb) = paper.implied_global_rates();
        assert!(ga <= 8.0 && gb <= 8.0, "({ga},{gb})");
    }

    #[test]
    fn design_f_rectangular_blocking() {
        // Design F (70, 32, 2, 2): Table IV caption d_i1=560, d_j1=640.
        let f = ArraySize::new(70, 32, 2, 2);
        let b = Level1Blocking::new(f, 560, 640);
        assert_eq!(b.reuse_b(), 8);
        assert_eq!(b.reuse_a(), 20);
        let (ga, gb) = b.implied_global_rates();
        assert!(ga <= 8.0 && gb <= 8.0, "({ga},{gb})");
        // Aspect-true column extent: 8/7 of d2 for F, identity for
        // square blockings.
        assert_eq!(b.scale_dj2(560), 640);
        assert_eq!(b.scale_dj2(17920), 20480);
        let g = Level1Blocking::derive_min(g_array(), 8);
        assert_eq!(g.scale_dj2(8192), 8192);
    }

    #[test]
    fn implied_rates_invert_eq14() {
        let b = Level1Blocking::new(g_array(), 512, 512);
        let (ga, gb) = b.implied_global_rates();
        assert_eq!(ga, 128.0 / 16.0);
        assert_eq!(gb, 64.0 / 8.0);
    }

    #[test]
    fn offchip_validation() {
        let b = Level1Blocking::new(g_array(), 512, 512);
        assert!(b.validate_offchip(512, 512, 512).is_ok());
        assert!(b.validate_offchip(512, 512, 511).is_err());
        assert!(b.validate_offchip(513, 512, 512).is_err());
        assert!(b.validate_offchip(21504, 16384, 4096).is_ok());
    }

    #[test]
    fn invalid_blocking_rejected() {
        assert!(Level1Blocking { array: g_array(), di1: 100, dj1: 512 }
            .validate()
            .is_err());
    }

    #[test]
    fn block_view_roundtrip() {
        let m = Matrix::random(8, 12, 42);
        let v = BlockedLayout::new(&m, 4, 6);
        assert_eq!(v.grid(), (2, 2));
        let mut rebuilt = Matrix::zeros(8, 12);
        for bi in 0..2 {
            for bj in 0..2 {
                let blk = v.block(bi, bj);
                BlockedLayout::write_block(&mut rebuilt, 4, 6, bi, bj, &blk);
            }
        }
        assert_eq!(rebuilt.data, m.data);
    }

    #[test]
    fn block_view_content() {
        // M̄^I_J (i,j) == M(d_i1·I + i, d_j1·J + j) — Definition 3.
        let m = Matrix::random(6, 6, 7);
        let v = BlockedLayout::new(&m, 3, 2);
        let blk = v.block(1, 2);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(blk.at(i, j), m.at(3 + i, 4 + j));
            }
        }
    }

    #[test]
    fn onchip_footprint() {
        let b = Level1Blocking::new(g_array(), 512, 512);
        // 2·512·2 + 2·2·512 + 512·512 floats.
        assert_eq!(b.onchip_floats(), 2048 + 2048 + 262144);
    }
}
