//! The two-level blocked off-chip matrix multiplication (paper §IV–V).
//!
//! * [`blocking`] — Definition 3 block-matrix views and the level-1
//!   blocking derived from reuse ratios (eqs. 14–18).
//! * [`phases`] — the four-phase Read/Compute/Write schedule of §V with
//!   Read–Compute overlap, and the compute-fraction model (eq. 19).
//! * [`offchip`] — the event-level simulator: full Tables II–V runs in
//!   microseconds by walking phases instead of MACs, with an optional
//!   functional mode (exact accumulation order) for small sizes.

pub mod blocking;
pub mod offchip;
pub mod phases;

pub use blocking::{BlockedLayout, Level1Blocking};
pub use offchip::{OffchipDesign, OffchipSim, SimReport};
pub use phases::{PhaseCounts, PhaseKind, PhaseSchedule};
