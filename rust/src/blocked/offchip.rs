//! Event-level simulator of the full off-chip matrix multiplication —
//! the engine behind the Table II–V reproductions.
//!
//! Timing walks the four-phase schedule per C̄ block (microseconds for a
//! d²=21504 problem instead of the 2·10¹³ simulated MACs a per-cycle
//! simulation would need). Its per-phase iteration counts are validated
//! against the cycle-accurate [`crate::systolic::Array3dSim`] on small
//! sizes (see `rust/tests/`), and its compute fraction against eq. 19.
//!
//! The optional functional mode executes the same block schedule with
//! the same accumulation order (outer products over k slabs) to produce
//! the actual C matrix for correctness checks.

use super::blocking::{BlockedLayout, Level1Blocking};
use super::phases::PhaseSchedule;
use crate::gemm::Matrix;
use crate::hls::lsu::max_floats_per_cycle;
use crate::perfmodel::{dsp_efficiency, eq5_peak_flops, flop_count};
use crate::systolic::latency::eq13_l_body;

/// A complete synthesized design: array + blocking + timing.
#[derive(Clone, Copy, Debug)]
pub struct OffchipDesign {
    pub blocking: Level1Blocking,
    pub fmax_mhz: f64,
    /// Memory-controller efficiency for burst-coalesced access.
    pub controller_efficiency: f64,
}

impl OffchipDesign {
    /// Global read/write rates implied by the design (floats/cycle),
    /// capped by the eq. 4 LSU ceiling and the DDR channel rate.
    pub fn global_rates(&self) -> (f64, f64, f64) {
        let lsu_cap = max_floats_per_cycle(self.fmax_mhz) as f64;
        // One DDR4-2400 channel at e, in floats per kernel cycle.
        let chan = crate::memory::DdrChannel::ddr4_2400()
            .floats_per_cycle(self.controller_efficiency, self.fmax_mhz);
        let (ga_want, gb_want) = self.blocking.implied_global_rates();
        let ga = ga_want.min(lsu_cap).min(chan);
        let gb = gb_want.min(lsu_cap).min(chan);
        // Write: d_j0-wide store capped the same way (stalls are benign
        // in Phase 4 but still pace the drain).
        let w = (self.blocking.array.dj0 as f64).min(lsu_cap).min(chan);
        (ga, gb, w)
    }

    pub fn schedule(&self) -> PhaseSchedule {
        let (ga, gb, w) = self.global_rates();
        PhaseSchedule { blocking: self.blocking, b_ga: ga, b_gb: gb, b_w: w }
    }

    /// Peak throughput (eq. 5) in GFLOPS.
    pub fn peak_gflops(&self) -> f64 {
        eq5_peak_flops(self.blocking.array.dsps() as u32, self.fmax_mhz) / 1e9
    }
}

/// Simulation output for one problem size — one table cell.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub di2: u64,
    pub dj2: u64,
    pub dk2: u64,
    /// Total kernel cycles (l_body + II·Σ iterations).
    pub cycles: u64,
    pub seconds: f64,
    /// Measured-style throughput (paper FLOP count / time), GFLOPS.
    pub gflops: f64,
    /// DSP efficiency e_D = T_flops / T_peak.
    pub e_d: f64,
    /// Compute fraction c_% (eq. 19 analogue from the schedule).
    pub compute_fraction: f64,
    /// Functional result (functional mode only).
    pub c: Option<Matrix>,
}

/// The event-level off-chip simulator.
#[derive(Clone, Debug)]
pub struct OffchipSim {
    pub design: OffchipDesign,
    /// Extra loop-body latency for the global-memory access stages
    /// (§III-C notes the real l_body exceeds eq. 13). One pipeline fill
    /// per kernel launch; calibrated to ~400 cycles of LSU/arbitration
    /// depth.
    pub memory_pipeline_depth: u64,
}

impl OffchipSim {
    pub fn new(design: OffchipDesign) -> Self {
        Self { design, memory_pipeline_depth: 400 }
    }

    /// Timing-only run.
    pub fn simulate(&self, di2: u64, dj2: u64, dk2: u64) -> SimReport {
        self.run(di2, dj2, dk2, None)
    }

    /// Functional + timing run (small sizes only: O(d_i2·d_j2·d_k2)).
    pub fn simulate_functional(&self, a: &Matrix, b: &Matrix) -> SimReport {
        self.run(a.rows as u64, b.cols as u64, a.cols as u64, Some((a, b)))
    }

    fn run(&self, di2: u64, dj2: u64, dk2: u64, data: Option<(&Matrix, &Matrix)>) -> SimReport {
        let b = &self.design.blocking;
        b.validate_offchip(di2, dj2, dk2)
            .expect("matrix sizes violate the design's blocking constraints");

        let schedule = self.design.schedule();
        let counts = schedule.counts(dk2);
        let blocks = (di2 / b.di1 as u64) * (dj2 / b.dj1 as u64);
        let iterations = counts.total() * blocks;
        let l_body = eq13_l_body(b.array.di0, b.array.dj0, b.array.dk0, b.array.dp)
            + self.memory_pipeline_depth;
        let cycles = l_body + iterations; // II = 1 across the fused loop
        let seconds = cycles as f64 / (self.design.fmax_mhz * 1e6);
        let gflops = flop_count(di2, dj2, dk2) as f64 / seconds / 1e9;
        let e_d = dsp_efficiency(gflops, self.design.peak_gflops());

        let c = data.map(|(a, bm)| self.functional_multiply(a, bm));

        SimReport {
            di2,
            dj2,
            dk2,
            cycles,
            seconds,
            gflops,
            e_d,
            compute_fraction: counts.compute_fraction(),
            c,
        }
    }

    /// The exact block schedule, functionally: for each C̄ block, sweep k
    /// slabs (slowest) accumulating outer products of second-level
    /// blocks — the accumulation order of Definition 4 and of the Pallas
    /// kernel (python/compile/kernels/systolic_mm.py).
    fn functional_multiply(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let blk = &self.design.blocking;
        let (di1, dj1) = (blk.di1 as usize, blk.dj1 as usize);
        let (di0, dj0, dk0, dp) =
            (blk.array.di0 as usize, blk.array.dj0 as usize, blk.array.dk0 as usize,
             blk.array.dp as usize);
        let mut c = Matrix::zeros(a.rows, b.cols);
        let a_view = BlockedLayout::new(a, di1, a.cols);
        let b_view = BlockedLayout::new(b, b.rows, dj1);
        let (gi, _) = a_view.grid();
        let (_, gj) = b_view.grid();
        for bi in 0..gi {
            let a1 = a_view.block(bi, 0); // Ā^I_0: (d_i1 × d_k2)
            for bj in 0..gj {
                let b1 = b_view.block(0, bj); // B̄^0_J: (d_k2 × d_j1)
                let mut c1 = Matrix::zeros(di1, dj1);
                for t in 0..a.cols / dk0 {
                    // slab t: outer product of A column-block and B row-block
                    for i0 in (0..di1).step_by(di0) {
                        for j0 in (0..dj1).step_by(dj0) {
                            for i in i0..i0 + di0 {
                                for j in j0..j0 + dj0 {
                                    let mut acc = c1.at(i, j);
                                    // dot in d_p segments (layer order)
                                    for seg in 0..dk0 / dp {
                                        for kk in 0..dp {
                                            let k = t * dk0 + seg * dp + kk;
                                            acc += a1.at(i, k) * b1.at(k, j);
                                        }
                                    }
                                    c1.set(i, j, acc);
                                }
                            }
                        }
                    }
                }
                BlockedLayout::write_block(&mut c, di1, dj1, bi, bj, &c1);
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::ArraySize;

    fn design_g() -> OffchipDesign {
        OffchipDesign {
            blocking: Level1Blocking::new(ArraySize::new(64, 32, 2, 2), 512, 512),
            fmax_mhz: 398.0,
            controller_efficiency: 0.97,
        }
    }

    #[test]
    fn design_g_rates() {
        let (ga, gb, w) = design_g().global_rates();
        // LSU ceiling at 398 MHz is 8 floats/cycle; channel supplies ~11.7.
        assert_eq!(ga, 8.0);
        assert_eq!(gb, 8.0);
        assert_eq!(w, 8.0);
    }

    #[test]
    fn table5_design_g_efficiency_shape() {
        // Table V row G: e_D = .45 .65 .80 .89 .94 .97 across the sweep.
        let sim = OffchipSim::new(design_g());
        let meas = [0.45, 0.65, 0.80, 0.89, 0.94, 0.97];
        for (i, d2) in [512u64, 1024, 2048, 4096, 8192, 16384].iter().enumerate() {
            let r = sim.simulate(*d2, *d2, *d2);
            assert!(
                (r.e_d - meas[i]).abs() < 0.06,
                "d2={d2}: sim e_D={:.3} vs paper {:.3}",
                r.e_d,
                meas[i]
            );
        }
    }

    #[test]
    fn table5_design_g_gflops_magnitude() {
        // Paper: 1486 GFLOPS at 512, 3159 at 16384 (±10% band for shape).
        let sim = OffchipSim::new(design_g());
        let small = sim.simulate(512, 512, 512);
        let large = sim.simulate(16384, 16384, 16384);
        assert!((small.gflops - 1486.0).abs() / 1486.0 < 0.12, "{}", small.gflops);
        assert!((large.gflops - 3159.0).abs() / 3159.0 < 0.05, "{}", large.gflops);
    }

    #[test]
    fn efficiency_monotone_in_k() {
        let sim = OffchipSim::new(design_g());
        let mut last = 0.0;
        for d2 in [512u64, 1024, 2048, 4096] {
            let r = sim.simulate(d2, d2, d2);
            assert!(r.e_d > last);
            last = r.e_d;
        }
    }

    #[test]
    fn functional_mode_matches_gemm() {
        // A scaled-down design with the same structure.
        let d = OffchipDesign {
            blocking: Level1Blocking::new(ArraySize::new(8, 4, 2, 2), 16, 16),
            fmax_mhz: 400.0,
            controller_efficiency: 0.97,
        };
        let sim = OffchipSim::new(d);
        let a = Matrix::random(32, 8, 77);
        let b = Matrix::random(8, 32, 78);
        let r = sim.simulate_functional(&a, &b);
        let want = crate::gemm::matmul(&a, &b);
        let got = r.c.unwrap();
        assert!(got.rel_fro_error(&want) < 1e-5);
    }

    #[test]
    fn functional_accumulation_matches_cycle_sim() {
        // The event-level functional path and the cycle-accurate array
        // must produce bitwise-identical C for a single level-1 block
        // (same slab order, same in-slab accumulation).
        let array = ArraySize::new(4, 4, 4, 2);
        let d = OffchipDesign {
            blocking: Level1Blocking::new(array, 4, 4),
            fmax_mhz: 400.0,
            controller_efficiency: 0.97,
        };
        let a = Matrix::random(4, 8, 5);
        let b = Matrix::random(8, 4, 6);
        let ev = OffchipSim::new(d).simulate_functional(&a, &b).c.unwrap();
        let cy = crate::systolic::Array3dSim::new(array).multiply(&a, &b).c;
        assert_eq!(ev.data, cy.data, "event vs cycle accumulation order");
    }

    #[test]
    #[should_panic(expected = "blocking constraints")]
    fn rejects_noncompliant_sizes() {
        OffchipSim::new(design_g()).simulate(500, 512, 512);
    }
}
