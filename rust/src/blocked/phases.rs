//! The four-phase schedule of §V (Figure 3) and the compute-fraction
//! model (eq. 19).
//!
//! Computing one C̄ block:
//!
//! 1. **Read₀** — fetch the first A block column and B block row into
//!    the on-chip mapped systems; initialize the C FIFOs.
//! 2. **Read‖Compute** — for each interior slab k, fetch slab k+1 while
//!    the array consumes slab k (double buffering).
//! 3. **Compute** — the last slab computes with nothing left to read.
//! 4. **Write** — drain C̄ to global memory, *not* overlapped (the
//!    paper's acknowledged efficiency gap vs. the Intel SDK design).
//!
//! All counts are in pipeline *iterations* (II = 1 ⇒ cycles) of the
//! single fused loop.

use super::blocking::Level1Blocking;

/// Phase kinds for timeline rendering (Figure 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    InitialRead,
    ReadCompute,
    ComputeOnly,
    Write,
}

/// Iteration counts for one C̄ block.
#[derive(Clone, Copy, Debug)]
pub struct PhaseCounts {
    pub initial_read: u64,
    /// Per-slab iterations while reading the next slab (max of compute
    /// and read streams — whichever dominates paces the pipeline).
    pub per_overlapped_slab: u64,
    /// Number of overlapped slabs (d_k2/d_k0 − 1).
    pub overlapped_slabs: u64,
    /// Iterations of the final, compute-only slab.
    pub final_compute: u64,
    pub write: u64,
}

impl PhaseCounts {
    pub fn total(&self) -> u64 {
        self.initial_read
            + self.per_overlapped_slab * self.overlapped_slabs
            + self.final_compute
            + self.write
    }

    /// Iterations during which the dot-product units compute.
    pub fn compute_iterations(&self) -> u64 {
        self.per_overlapped_slab.min(self.final_compute) * self.overlapped_slabs
            + self.final_compute
    }

    /// Measured compute fraction c_% = #it_comp / #it_tot.
    pub fn compute_fraction(&self) -> f64 {
        self.compute_iterations() as f64 / self.total() as f64
    }
}

/// The schedule generator for a design.
#[derive(Clone, Copy, Debug)]
pub struct PhaseSchedule {
    pub blocking: Level1Blocking,
    /// Global read rates for A and B in floats/cycle (≤ eq. 4 ceiling).
    pub b_ga: f64,
    pub b_gb: f64,
    /// Effective write rate in floats/cycle (LSU ceiling / stalls
    /// included; §V: Write stalls harmlessly in Phase 4).
    pub b_w: f64,
}

impl PhaseSchedule {
    /// Counts for one C̄ block of a (d_i2, d_j2, d_k2) problem.
    pub fn counts(&self, dk2: u64) -> PhaseCounts {
        let b = &self.blocking;
        let dk0 = b.array.dk0 as u64;
        assert!(dk2 % dk0 == 0);
        let slabs = dk2 / dk0;
        let compute_per_slab = b.iterations_per_slab();
        let read_a = (b.di1 as u64 * dk0) as f64 / self.b_ga;
        let read_b = (b.array.dk0 as u64 * b.dj1 as u64) as f64 / self.b_gb;
        let read_per_slab = read_a.max(read_b).ceil() as u64;
        let write = ((b.di1 as u64 * b.dj1 as u64) as f64 / self.b_w).ceil() as u64;
        PhaseCounts {
            initial_read: read_per_slab,
            per_overlapped_slab: compute_per_slab.max(read_per_slab),
            overlapped_slabs: slabs.saturating_sub(1),
            final_compute: compute_per_slab,
            write,
        }
    }

    /// Figure-3-style timeline: (kind, start, end) iteration spans for
    /// one C̄ block.
    pub fn timeline(&self, dk2: u64) -> Vec<(PhaseKind, u64, u64)> {
        let c = self.counts(dk2);
        let mut spans = Vec::new();
        let mut t = 0u64;
        spans.push((PhaseKind::InitialRead, t, t + c.initial_read));
        t += c.initial_read;
        for _ in 0..c.overlapped_slabs {
            spans.push((PhaseKind::ReadCompute, t, t + c.per_overlapped_slab));
            t += c.per_overlapped_slab;
        }
        spans.push((PhaseKind::ComputeOnly, t, t + c.final_compute));
        t += c.final_compute;
        spans.push((PhaseKind::Write, t, t + c.write));
        spans
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::eq19_compute_fraction;
    use crate::systolic::ArraySize;

    fn design_g_schedule() -> PhaseSchedule {
        let b = Level1Blocking::new(ArraySize::new(64, 32, 2, 2), 512, 512);
        PhaseSchedule { blocking: b, b_ga: 8.0, b_gb: 8.0, b_w: 8.0 }
    }

    #[test]
    fn perfect_overlap_at_design_point() {
        // eq. 18 sizing makes per-slab read exactly match per-slab
        // compute: 128 iterations each for design G.
        let s = design_g_schedule();
        let c = s.counts(512);
        assert_eq!(c.initial_read, 128);
        assert_eq!(c.per_overlapped_slab, 128);
        assert_eq!(c.final_compute, 128);
    }

    #[test]
    fn counts_match_eq19_model() {
        // c_% from the schedule ≈ eq. 19 for design G across sizes.
        let s = design_g_schedule();
        for d2 in [512u64, 1024, 2048, 4096, 8192, 16384] {
            let c = s.counts(d2);
            let model = eq19_compute_fraction(d2, 2, 64, 32, 8);
            let got = c.compute_fraction();
            assert!(
                (got - model).abs() < 0.01,
                "d2={d2}: schedule {got:.4} vs eq19 {model:.4}"
            );
        }
    }

    #[test]
    fn write_phase_dominates_small_k() {
        let s = design_g_schedule();
        let c = s.counts(512);
        // At d2 = d1 the exposed write is as large as all compute.
        assert_eq!(c.write, 512 * 512 / 8);
        assert!(c.write as f64 / c.total() as f64 > 0.4);
    }

    #[test]
    fn timeline_is_contiguous_and_ordered() {
        let s = design_g_schedule();
        let tl = s.timeline(2048);
        assert_eq!(tl.first().unwrap().0, PhaseKind::InitialRead);
        assert_eq!(tl.last().unwrap().0, PhaseKind::Write);
        for w in tl.windows(2) {
            assert_eq!(w[0].2, w[1].1, "gap in timeline");
        }
        let n_rc = tl.iter().filter(|s| s.0 == PhaseKind::ReadCompute).count();
        assert_eq!(n_rc as u64, 2048 / 2 - 1);
    }

    #[test]
    fn slower_read_paces_the_slab() {
        // Halving the A read rate doubles the overlapped-slab length:
        // the pipeline stalls on memory exactly as eq. 2/3 predict.
        let b = Level1Blocking::new(ArraySize::new(64, 32, 2, 2), 512, 512);
        let s = PhaseSchedule { blocking: b, b_ga: 4.0, b_gb: 8.0, b_w: 8.0 };
        let c = s.counts(512);
        assert_eq!(c.per_overlapped_slab, 256);
        assert!(c.compute_fraction() < design_g_schedule().counts(512).compute_fraction());
    }
}
