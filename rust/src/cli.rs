//! Minimal argument parser (no `clap` in the offline registry).
//!
//! Grammar: `systo3d <subcommand> [--flag] [--key value] ...`

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.get_u64(name, default as u64).map(|v| v as usize)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("tables --residuals --design G");
        assert_eq!(a.subcommand.as_deref(), Some("tables"));
        assert!(a.flag("residuals"));
        assert_eq!(a.get("design"), Some("G"));
    }

    #[test]
    fn equals_form() {
        let a = parse("simulate --d2=4096 --design=F");
        assert_eq!(a.get_u64("d2", 0).unwrap(), 4096);
        assert_eq!(a.get("design"), Some("F"));
    }

    #[test]
    fn trailing_flag_not_eating_subarg() {
        let a = parse("serve --verbose");
        assert!(a.flag("verbose"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn positional_args() {
        let a = parse("verify mm_h_64 other");
        assert_eq!(a.positional, vec!["mm_h_64", "other"]);
    }

    #[test]
    fn defaults() {
        let a = parse("simulate");
        assert_eq!(a.get_u64("d2", 4096).unwrap(), 4096);
        assert_eq!(a.get_str("design", "G"), "G");
        assert!(a.get_u64("d2", 1).is_ok());
    }

    #[test]
    fn bad_int_reported() {
        let a = parse("simulate --d2 xyz");
        assert!(a.get_u64("d2", 0).is_err());
    }

    #[test]
    fn strassen_subcommand_options() {
        let a = parse("strassen --design G --d2 32768 --depth 2 --budget 1e-4 --devices 7");
        assert_eq!(a.subcommand.as_deref(), Some("strassen"));
        assert_eq!(a.get_u64("d2", 0).unwrap(), 32768);
        assert_eq!(a.get_str("depth", "auto"), "2");
        assert_eq!(a.get("budget"), Some("1e-4"));
        assert_eq!(a.get_usize("devices", 1).unwrap(), 7);
    }

    #[test]
    fn fabric_subcommand_options() {
        let a = parse("fabric --devices 16 --topology torus --d2 21504 --overlap");
        assert_eq!(a.subcommand.as_deref(), Some("fabric"));
        assert_eq!(a.get_usize("devices", 8).unwrap(), 16);
        assert_eq!(a.get_str("topology", "all"), "torus");
        assert!(a.flag("overlap"));
    }

    #[test]
    fn cluster_subcommand_options() {
        let a = parse("cluster --devices 8 --d2 21504 --strategy 2.5d --mix");
        assert_eq!(a.subcommand.as_deref(), Some("cluster"));
        assert_eq!(a.get_usize("devices", 4).unwrap(), 8);
        assert_eq!(a.get_u64("d2", 0).unwrap(), 21504);
        assert_eq!(a.get_str("strategy", "auto"), "2.5d");
        assert!(a.flag("mix"));
    }
}
