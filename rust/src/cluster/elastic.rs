//! Elastic fleets: hot-spare draining, load-triggered fabric growth,
//! and the deterministic fault plans the chaos harness replays.
//!
//! The PR-1..4 fleet was fixed at service start: a dying card's queue
//! could only drain onto survivors by work-stealing, and a backlog had
//! nowhere to go. This module makes the fleet elastic along two axes:
//!
//! * **Hot spares.** [`FleetController`] keeps K spare cards wired
//!   into the topology (attached with
//!   [`crate::fabric::Topology::attach_card`], so the 4-port budget
//!   holds) but excluded from placement — plan devices fold onto the
//!   active cards only. When an active card dies, the controller
//!   replays the PR-3 heal path (kill the card, reroute around it) and
//!   then **drains** the victim's queued and in-flight shards onto a
//!   spare instead of blindly requeueing on survivors: every live
//!   spare is scored by replaying the remaining partial-C reduction
//!   sends under the PR-4 link-contention model with the victim's
//!   devices substituted by the candidate — a placement search over
//!   the amended device→card map — and the cheapest spare wins (ties
//!   toward the lowest id). The victim's reduction homes move to the
//!   spare (checkpointed partials replay there), and a
//!   [`FleetEvent::DrainCompleted`] fires when the last drained shard
//!   has re-executed — always before the final barrier.
//! * **Growth.** When the queue-depth watermark is crossed (pending
//!   shards per live card above [`ElasticConfig::scale_watermark`]),
//!   the fabric grows: `attach_card` splices a new card in (only
//!   routes that crossed the spliced cable are invalidated), and the
//!   queued work — exactly the k-slices that have not started — is
//!   re-carved over the grown fleet, balancing queue depth first and
//!   reduction hop-bytes second. [`PartitionPlan::recarve`] is the
//!   same boundary for whole plans: jobs planned after a growth carve
//!   to the new N.
//!
//! Faults are data, not randomness: a [`FaultPlan`] is an explicit
//! list of kill / slow-link / spike-queue events at scheduled times,
//! and [`FaultPlan::seeded`] derives one deterministically from a seed
//! — the chaos harness in `rust/tests/chaos.rs` replays seeds 0..N
//! across topologies and asserts no shard is lost, results stay
//! bit-exact, and every drain completes.
//!
//! Determinism: every choice (DMA pick, steal victim, spare pick,
//! rebalance target) breaks ties on explicit ids, and fault
//! application order is fixed by (time, plan order) — the same plan
//! and fault plan replay to a bit-identical [`ElasticOutcome`].

use super::interconnect::Link;
use super::partition::{PartitionPlan, Shard};
use super::scheduler::{overlap_seconds, DeviceTrace, ScheduleOutcome};
use crate::fabric::{FabricState, Topology};
use crate::observe::slo::{BurnMonitor, SloPolicy};
use crate::trace::{Category, Tracer, Track};
use crate::util::rng::Xoshiro256;
use std::collections::{BTreeMap, VecDeque};

/// One scheduled fault of a [`FaultPlan`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Card `card` dies at `seconds` (in-flight work is lost, its
    /// queue drains to a spare or survivors, the fabric heals).
    Kill { card: usize, seconds: f64 },
    /// The cable between `a` and `b` degrades by `factor` (≥ 1) from
    /// `seconds` on — a flapping QSFP renegotiating a lower rate. A
    /// pair with no cable is a no-op.
    SlowLink { a: usize, b: usize, factor: f64, seconds: f64 },
    /// Card `card`'s compute engine is held by a background tenant for
    /// `busy_seconds` starting at `seconds` — a queue-latency spike
    /// that can push the fleet over the growth watermark.
    SpikeQueue { card: usize, busy_seconds: f64, seconds: f64 },
}

impl Fault {
    /// When the fault fires.
    pub fn seconds(&self) -> f64 {
        match *self {
            Fault::Kill { seconds, .. }
            | Fault::SlowLink { seconds, .. }
            | Fault::SpikeQueue { seconds, .. } => seconds,
        }
    }
}

/// A deterministic schedule of faults to replay against one elastic
/// run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// No faults at all.
    pub fn none() -> Self {
        Self::default()
    }

    /// A single card death.
    pub fn kill(card: usize, seconds: f64) -> Self {
        Self { faults: vec![Fault::Kill { card, seconds }] }
    }

    /// Derive a fault schedule from a seed: 1–2 kills on distinct
    /// cards (never enough to take the whole fleet), up to 2 slow
    /// links and up to 2 queue spikes, all inside `horizon_seconds`.
    /// The same (seed, cards, horizon) always yields the same plan.
    pub fn seeded(seed: u64, cards: usize, horizon_seconds: f64) -> Self {
        assert!(cards >= 2, "chaos needs at least two cards");
        assert!(horizon_seconds > 0.0, "empty horizon");
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut faults = Vec::new();
        let kills = (1 + rng.next_below(2) as usize).min(cards - 1).min(2);
        let mut victims: Vec<usize> = Vec::with_capacity(kills);
        while victims.len() < kills {
            let c = rng.next_below(cards as u64) as usize;
            if !victims.contains(&c) {
                victims.push(c);
            }
        }
        for card in victims {
            let seconds = (0.05 + 0.90 * rng.next_f64()) * horizon_seconds;
            faults.push(Fault::Kill { card, seconds });
        }
        for _ in 0..rng.next_below(3) {
            let a = rng.next_below(cards as u64) as usize;
            faults.push(Fault::SlowLink {
                a,
                b: (a + 1) % cards,
                factor: 1.5 + 3.0 * rng.next_f64(),
                seconds: 0.8 * horizon_seconds * rng.next_f64(),
            });
        }
        for _ in 0..rng.next_below(3) {
            faults.push(Fault::SpikeQueue {
                card: rng.next_below(cards as u64) as usize,
                busy_seconds: (0.2 + rng.next_f64()) * horizon_seconds,
                seconds: 0.8 * horizon_seconds * rng.next_f64(),
            });
        }
        Self { faults }
    }

    /// Per-card death times over `cards` cards (earliest kill wins).
    pub fn deaths(&self, cards: usize) -> Vec<Option<f64>> {
        let mut deaths: Vec<Option<f64>> = vec![None; cards];
        for f in &self.faults {
            if let Fault::Kill { card, seconds } = *f {
                if card < cards {
                    let d = &mut deaths[card];
                    *d = Some(d.map_or(seconds, |t: f64| t.min(seconds)));
                }
            }
        }
        deaths
    }
}

/// Knobs of one elastic run.
#[derive(Clone, Copy, Debug)]
pub struct ElasticConfig {
    /// Spare cards wired into the topology but excluded from
    /// placement; the topology must wire `active + hot_spares` cards.
    pub hot_spares: usize,
    /// Queue-depth watermark: when pending shards per live card exceed
    /// it, the fabric grows by one card (None disables growth).
    pub scale_watermark: Option<f64>,
    /// Cards the controller may attach across the run.
    pub max_growth: usize,
    /// Latency SLO whose burn rate drives growth independently of the
    /// queue-depth watermark (None disables SLO-driven growth). Burn
    /// is evaluated at every scheduling instant over a short and a
    /// long sliding window; sustained burn in both activates a pooled
    /// spare or attaches a card even when raw depth looks healthy.
    pub slo: Option<SloPolicy>,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        Self { hot_spares: 1, scale_watermark: None, max_growth: 2, slo: None }
    }
}

/// What the controller did, when (simulated seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FleetEvent {
    /// `spare` left the pool to absorb dead card `replaces`.
    SpareActivated { seconds: f64, spare: usize, replaces: usize },
    /// The last of `shards` shards drained from `replaces` finished
    /// re-executing. Fires before the final barrier by construction.
    DrainCompleted { seconds: f64, spare: usize, replaces: usize, shards: usize },
    /// The fabric grew by `card` because queue depth per live card hit
    /// `queue_depth`.
    FleetGrown { seconds: f64, card: usize, queue_depth: f64 },
    /// The fleet gained `card` (a pooled spare or a fresh attach)
    /// because the latency SLO burned at `short_burn` / `long_burn`
    /// over the short / long window — queue depth alone did not
    /// justify it.
    SloGrown { seconds: f64, card: usize, short_burn: f64, long_burn: f64 },
}

impl FleetEvent {
    /// When the event happened.
    pub fn seconds(&self) -> f64 {
        match *self {
            FleetEvent::SpareActivated { seconds, .. }
            | FleetEvent::DrainCompleted { seconds, .. }
            | FleetEvent::FleetGrown { seconds, .. }
            | FleetEvent::SloGrown { seconds, .. } => seconds,
        }
    }
}

/// Outcome of one elastic run: the plain schedule numbers plus the
/// controller's event log and gauges.
#[derive(Clone, Debug)]
pub struct ElasticOutcome {
    /// The usual schedule accounting over every card the run ended
    /// with (actives, spares — activated or not — and grown cards).
    pub schedule: ScheduleOutcome,
    /// Controller events in simulation order.
    pub events: Vec<FleetEvent>,
    /// Spares that left the pool for a dead card.
    pub spare_activations: usize,
    /// Drains whose last shard re-executed (one per activation unless
    /// the run ended first — asserted equal in the chaos suite).
    pub drains_completed: usize,
    /// Σ (drain-complete − spare-activation) spans.
    pub drain_seconds: f64,
    /// Contention-priced drain of the remaining reduction sends had
    /// each death taken the first available spare.
    pub drain_identity_cost_seconds: f64,
    /// Same drain under the spare the search chose (≤ identity).
    pub drain_placed_cost_seconds: f64,
    /// Cards attached by watermark growth.
    pub grown_cards: usize,
    /// Cards gained through SLO burn-rate alerts (spares activated or
    /// cards attached — disjoint from `grown_cards`).
    pub slo_grown_cards: usize,
    /// Instants at which the SLO burn monitor raised an alert (both
    /// windows over threshold), in simulation order.
    pub slo_alerts: Vec<f64>,
    /// (short, long) window burn fractions at the end of the run —
    /// (0, 0) when no SLO policy was configured or the burn cleared.
    pub slo_final_burn: (f64, f64),
    /// Remaining reduction hop-bytes just before each growth rebalance
    /// (summed over growths).
    pub post_grow_identity_hop_bytes: u64,
    /// Same, just after the rebalance placed the queued shards.
    pub post_grow_placed_hop_bytes: u64,
    /// Cards the run ended with (active + spares + grown).
    pub final_cards: usize,
}

impl ElasticOutcome {
    /// identity/placed drain cost across all spare picks (1.0 when no
    /// drain priced, > 1 when the search beat the first-spare policy).
    pub fn drain_placement_gain(&self) -> f64 {
        if self.drain_placed_cost_seconds <= 0.0 {
            return 1.0;
        }
        self.drain_identity_cost_seconds / self.drain_placed_cost_seconds
    }

    /// Fraction of pre-growth reduction hop-bytes the rebalance
    /// removed (negative when balancing depth cost hops).
    pub fn post_grow_hop_saving(&self) -> f64 {
        if self.post_grow_identity_hop_bytes == 0 {
            return 0.0;
        }
        1.0 - self.post_grow_placed_hop_bytes as f64 / self.post_grow_identity_hop_bytes as f64
    }

    /// Multi-line human-readable summary (CLI / examples).
    pub fn render(&self) -> String {
        let mut out = format!(
            "elastic run over {} card(s): makespan {:.4} s, {} retried, {} rerouted\n\
             spares: {} activated, {} drain(s) completed in {:.4} s total \
             (spare-pick gain {:.2}x)\n\
             growth: {} card(s) attached, queued hop-bytes {:.1} -> {:.1} MB\n\
             slo: {} card(s) via burn alerts, {} alert instant(s), \
             final burn {:.2}/{:.2}\n",
            self.final_cards,
            self.schedule.makespan_seconds,
            self.schedule.retries,
            self.schedule.reroutes,
            self.spare_activations,
            self.drains_completed,
            self.drain_seconds,
            self.drain_placement_gain(),
            self.grown_cards,
            self.post_grow_identity_hop_bytes as f64 / 1e6,
            self.post_grow_placed_hop_bytes as f64 / 1e6,
            self.slo_grown_cards,
            self.slo_alerts.len(),
            self.slo_final_burn.0,
            self.slo_final_burn.1,
        );
        for e in &self.events {
            out.push_str(&match *e {
                FleetEvent::SpareActivated { seconds, spare, replaces } => {
                    format!(
                        "  {seconds:>10.4} s  spare {spare} activated for dead card {replaces}\n"
                    )
                }
                FleetEvent::DrainCompleted { seconds, spare, replaces, shards } => format!(
                    "  {seconds:>10.4} s  drain of {shards} shard(s) {replaces} -> {spare} done\n"
                ),
                FleetEvent::FleetGrown { seconds, card, queue_depth } => format!(
                    "  {seconds:>10.4} s  fabric grew card {card} (queue depth {queue_depth:.2})\n"
                ),
                FleetEvent::SloGrown { seconds, card, short_burn, long_burn } => format!(
                    "  {seconds:>10.4} s  slo burn grew card {card} \
                     (burn {short_burn:.2}/{long_burn:.2})\n"
                ),
            });
        }
        out
    }
}

/// A drain in flight: shards moved off `replaces` that have not yet
/// re-executed.
#[derive(Clone, Copy, Debug)]
struct DrainState {
    spare: usize,
    replaces: usize,
    started: f64,
    remaining: usize,
    shards: usize,
}

/// Per-tile reduction bookkeeping (the elastic twin of the scheduler's
/// tile state).
struct TileState {
    remaining: usize,
    home: usize,
    ready: f64,
    c_bytes: u64,
}

/// Run `plan` over `active` cards plus the config's hot spares, with
/// `faults` injected; `topology` must wire `active + hot_spares` cards
/// and `compute_seconds(card, shard)` prices a shard on a card (cards
/// grown past the initial count are the caller's to map onto a
/// design). Errors only when every card is dead with shards
/// outstanding.
pub fn run_elastic_schedule(
    plan: &PartitionPlan,
    active: usize,
    host: &Link,
    topology: &Topology,
    faults: &FaultPlan,
    config: ElasticConfig,
    compute_seconds: impl Fn(usize, &Shard) -> f64,
) -> Result<ElasticOutcome, String> {
    FleetController::new(plan, active, host, topology, faults, config, compute_seconds)?.run()
}

/// As [`run_elastic_schedule`], recording spans into `tracer`: DMA /
/// compute / reduction / writeback lanes per card, per-link circuit
/// holds, drain spans on the control track, and death / spare /
/// watermark instants.
#[allow(clippy::too_many_arguments)]
pub fn run_elastic_schedule_traced(
    plan: &PartitionPlan,
    active: usize,
    host: &Link,
    topology: &Topology,
    faults: &FaultPlan,
    config: ElasticConfig,
    tracer: &Tracer,
    compute_seconds: impl Fn(usize, &Shard) -> f64,
) -> Result<ElasticOutcome, String> {
    FleetController::new(plan, active, host, topology, faults, config, compute_seconds)?
        .with_trace(tracer.clone())
        .run()
}

/// The elastic scheduler: the PR-2 work-stealing loop with a spare
/// pool, drain-on-death, and watermark growth wrapped around it.
pub struct FleetController<'a, F: Fn(usize, &Shard) -> f64> {
    host: &'a Link,
    compute_seconds: F,
    config: ElasticConfig,
    cards: usize,
    fabric: FabricState,
    enabled: Vec<bool>,
    dead: Vec<bool>,
    /// Activated spares: their queues hold drained work, pinned — not
    /// steal targets while the spare lives (otherwise idle survivors
    /// whose links freed earlier would steal the drain right back and
    /// the recovery would degenerate to requeue-on-survivors). A dead
    /// spare's leftover queue becomes stealable like any other.
    sticky: Vec<bool>,
    deaths: Vec<Option<f64>>,
    spare_pool: VecDeque<usize>,
    queues: Vec<VecDeque<Shard>>,
    link_free: Vec<f64>,
    out_free: Vec<f64>,
    card_free: Vec<f64>,
    compute_free: Vec<f64>,
    compute_ends: Vec<Vec<f64>>,
    traces: Vec<DeviceTrace>,
    tiles: BTreeMap<(u64, u64), TileState>,
    attempts: BTreeMap<(u64, u64, u64), usize>,
    pending: usize,
    steals: usize,
    retries: usize,
    compute_intervals: Vec<(f64, f64)>,
    send_intervals: Vec<(f64, f64)>,
    pending_faults: VecDeque<Fault>,
    events: Vec<FleetEvent>,
    drains: Vec<DrainState>,
    drain_of: BTreeMap<(u64, u64, u64), Vec<usize>>,
    drain_seconds: f64,
    drain_identity_cost_seconds: f64,
    drain_placed_cost_seconds: f64,
    grown: usize,
    post_grow_identity_hop_bytes: u64,
    post_grow_placed_hop_bytes: u64,
    slo_monitor: Option<BurnMonitor>,
    slo_grown: usize,
    slo_last_grow: f64,
    slo_alerts: Vec<f64>,
    tracer: Tracer,
}

impl<'a, F: Fn(usize, &Shard) -> f64> FleetController<'a, F> {
    pub fn new(
        plan: &'a PartitionPlan,
        active: usize,
        host: &'a Link,
        topology: &Topology,
        faults: &FaultPlan,
        config: ElasticConfig,
        compute_seconds: F,
    ) -> Result<Self, String> {
        if active == 0 {
            return Err("empty active fleet".into());
        }
        let cards = active + config.hot_spares;
        if topology.cards != cards {
            return Err(format!(
                "topology wires {} card(s) but active {active} + spares {} need {cards}",
                topology.cards, config.hot_spares
            ));
        }
        let mut queues: Vec<VecDeque<Shard>> = vec![VecDeque::new(); cards];
        for s in &plan.shards {
            queues[s.device % active].push_back(*s);
        }
        let homes = plan.tile_homes();
        let mut tiles: BTreeMap<(u64, u64), TileState> = BTreeMap::new();
        for s in &plan.shards {
            let t = tiles.entry(s.tile()).or_insert_with(|| TileState {
                remaining: 0,
                home: homes[&s.tile()].1 % active,
                ready: 0.0,
                c_bytes: s.c_bytes(),
            });
            t.remaining += 1;
        }
        // Non-kill faults fire in (time, plan-order) sequence; kills
        // become the per-card death schedule.
        let mut timed: Vec<(usize, Fault)> = faults
            .faults
            .iter()
            .filter(|f| !matches!(f, Fault::Kill { .. }))
            .copied()
            .enumerate()
            .collect();
        timed.sort_by(|(i, a), (j, b)| a.seconds().total_cmp(&b.seconds()).then(i.cmp(j)));
        let mut enabled = vec![true; cards];
        for e in enabled.iter_mut().take(cards).skip(active) {
            *e = false;
        }
        Ok(Self {
            host,
            compute_seconds,
            config,
            cards,
            fabric: FabricState::new(topology.clone()),
            enabled,
            dead: vec![false; cards],
            sticky: vec![false; cards],
            deaths: faults.deaths(cards),
            spare_pool: (active..cards).collect(),
            queues,
            link_free: vec![0.0; cards],
            out_free: vec![0.0; cards],
            card_free: vec![0.0; cards],
            compute_free: vec![0.0; cards],
            compute_ends: vec![Vec::new(); cards],
            traces: vec![DeviceTrace::default(); cards],
            tiles,
            attempts: BTreeMap::new(),
            pending: plan.shards.len(),
            steals: 0,
            retries: 0,
            compute_intervals: Vec::with_capacity(plan.shards.len()),
            send_intervals: Vec::new(),
            pending_faults: timed.into_iter().map(|(_, f)| f).collect(),
            events: Vec::new(),
            drains: Vec::new(),
            drain_of: BTreeMap::new(),
            drain_seconds: 0.0,
            drain_identity_cost_seconds: 0.0,
            drain_placed_cost_seconds: 0.0,
            grown: 0,
            post_grow_identity_hop_bytes: 0,
            post_grow_placed_hop_bytes: 0,
            slo_monitor: config.slo.map(BurnMonitor::new),
            slo_grown: 0,
            slo_last_grow: f64::NEG_INFINITY,
            slo_alerts: Vec::new(),
            tracer: Tracer::off(),
        })
    }

    /// Record this run's spans and instants into `tracer` (the
    /// default controller carries a no-op sink).
    pub fn with_trace(mut self, tracer: Tracer) -> Self {
        self.tracer = tracer;
        self
    }

    fn death(&self, card: usize) -> Option<f64> {
        self.deaths.get(card).copied().flatten()
    }

    /// Can `card` still start work at `now`?
    fn live_at(&self, card: usize, now: f64) -> bool {
        self.enabled[card]
            && !self.dead[card]
            && self.death(card).map_or(true, |td| td > now)
    }

    /// The next scheduling instant: the earliest link-free time over
    /// cards that can still start a DMA.
    fn observe_now(&self) -> f64 {
        (0..self.cards)
            .filter(|&c| {
                self.enabled[c]
                    && !self.dead[c]
                    && self.death(c).map_or(true, |td| self.link_free[c] < td)
            })
            .map(|c| self.link_free[c])
            .fold(f64::INFINITY, f64::min)
    }

    /// Fire every non-kill fault scheduled at or before `now`.
    fn apply_faults(&mut self, now: f64) {
        while self.pending_faults.front().map_or(false, |f| f.seconds() <= now) {
            match self.pending_faults.pop_front().expect("front checked") {
                Fault::SlowLink { a, b, factor, seconds } => {
                    if self.fabric.slow_link(a, b, factor) && self.tracer.is_recording() {
                        // Sample the degraded cable's relative rate so
                        // the anomaly localizer can name the link.
                        let rate = 1.0 / self.fabric.cable_slow(a, b).unwrap_or(1.0);
                        self.tracer.counter(&format!("link_rate {a}<->{b}"), seconds, rate);
                    }
                }
                Fault::SpikeQueue { card, busy_seconds, seconds } => {
                    if card < self.cards && self.enabled[card] && !self.dead[card] {
                        self.compute_free[card] =
                            self.compute_free[card].max(seconds) + busy_seconds;
                    }
                }
                // Kills live in the death schedule, not the cursor.
                Fault::Kill { .. } => {}
            }
        }
    }

    /// Mark cards whose death has passed their last possible DMA start
    /// as dead, heal the fabric around them, and drain their queues —
    /// heal-then-drain, in ascending card order, so the ordering is
    /// deterministic even for simultaneous deaths.
    fn sweep_dead(&mut self) {
        for d in 0..self.cards {
            if !self.enabled[d] || self.dead[d] {
                continue;
            }
            let Some(td) = self.death(d) else { continue };
            if td > self.link_free[d] {
                continue;
            }
            self.dead[d] = true;
            self.fabric.kill(d);
            self.tracer.instant(Track::Control, Category::Drain, || format!("death card {d}"), td);
            self.drain_to_spare(d, None, td);
        }
    }

    /// The partial-C sends still owed by queued (and the just-lost)
    /// shards, with every occurrence of `victim` — as sender or as
    /// reduction home — substituted by `substitute`.
    fn remaining_reduction_sends(
        &self,
        victim: usize,
        substitute: usize,
        lost: Option<&Shard>,
    ) -> Vec<(usize, usize, u64)> {
        let sub = |c: usize| if c == victim { substitute } else { c };
        let mut sends = Vec::new();
        for (card, q) in self.queues.iter().enumerate() {
            for s in q {
                let home = self.tiles[&s.tile()].home;
                sends.push((sub(card), sub(home), s.c_bytes()));
            }
        }
        if let Some(s) = lost {
            let home = self.tiles[&s.tile()].home;
            sends.push((substitute, sub(home), s.c_bytes()));
        }
        sends
    }

    /// Reduction hop-bytes still queued: Σ c_bytes · hops(queue card,
    /// tile home) over shards that have not started.
    fn queued_hop_bytes(&self) -> u64 {
        let mut total = 0u64;
        for (card, q) in self.queues.iter().enumerate() {
            for s in q {
                let home = self.tiles[&s.tile()].home;
                if card != home {
                    total += s.c_bytes() * u64::from(self.fabric.hops(card, home).unwrap_or(0));
                }
            }
        }
        total
    }

    /// Drain dead card `victim`'s queued shards (plus `lost`, the
    /// in-flight shard it just dropped) onto the best live spare:
    /// candidates are scored by replaying the remaining reduction
    /// sends under the link-contention model with the victim
    /// substituted — the placement search over the amended device→card
    /// map — and the victim's reduction homes move with the work.
    /// Returns the activated spare, or None when there is nothing to
    /// drain or no live spare remains (callers fall back to
    /// requeue-on-survivors).
    fn drain_to_spare(&mut self, victim: usize, lost: Option<Shard>, now: f64) -> Option<usize> {
        if self.queues[victim].is_empty() && lost.is_none() {
            return None;
        }
        // Spare scoring replays routes on a scratch fabric per
        // candidate — one of the host profiler's watched loops.
        let _scope = crate::trace::profile::scope("elastic.drain_to_spare");
        let pool: Vec<usize> = self
            .spare_pool
            .iter()
            .copied()
            .filter(|&s| self.death(s).map_or(true, |td| td > now))
            .collect();
        if pool.is_empty() {
            return None;
        }
        let mut scratch = FabricState::new(self.fabric.topology.clone());
        for c in 0..self.cards {
            if self.dead[c] {
                scratch.kill(c);
            }
        }
        scratch.kill(victim);
        let mut first_cost = f64::INFINITY;
        let mut best: Option<(f64, usize)> = None;
        for (i, &s) in pool.iter().enumerate() {
            // Each candidate replays from the clean scratch occupancy;
            // the O(1) checkpoint + O(links touched) rollback replaces
            // the old per-candidate O(edges) reset.
            let cp = scratch.checkpoint();
            let mut last = 0.0f64;
            let mut cost = f64::INFINITY;
            let mut routable = true;
            for (src, dst, bytes) in self.remaining_reduction_sends(victim, s, lost.as_ref()) {
                if src == dst {
                    continue;
                }
                match scratch.send(src, dst, bytes, 0.0) {
                    Some((_, end)) => last = last.max(end),
                    None => {
                        routable = false;
                        break;
                    }
                }
            }
            scratch.rollback(cp);
            if routable {
                cost = last;
            }
            if i == 0 {
                first_cost = cost;
            }
            let better = match best {
                None => true,
                Some((bc, bs)) => cost < bc || (cost == bc && s < bs),
            };
            if better {
                best = Some((cost, s));
            }
        }
        let (best_cost, spare) = best.expect("pool is nonempty");
        if first_cost.is_finite() && best_cost.is_finite() {
            self.drain_identity_cost_seconds += first_cost;
            self.drain_placed_cost_seconds += best_cost;
        }
        self.spare_pool.retain(|&s| s != spare);
        self.enabled[spare] = true;
        self.sticky[spare] = true;
        self.link_free[spare] = self.link_free[spare].max(now);
        self.events.push(FleetEvent::SpareActivated { seconds: now, spare, replaces: victim });
        self.tracer.instant(
            Track::Control,
            Category::Drain,
            || format!("spare {spare} activated for card {victim}"),
            now,
        );
        let idx = self.drains.len();
        let moved: Vec<Shard> = self.queues[victim].drain(..).chain(lost).collect();
        for s in &moved {
            self.drain_of.entry((s.row0, s.col0, s.k0)).or_default().push(idx);
        }
        let count = moved.len();
        for s in moved {
            self.queues[spare].push_back(s);
        }
        self.drains.push(DrainState {
            spare,
            replaces: victim,
            started: now,
            remaining: count,
            shards: count,
        });
        // The victim's reduction homes re-home onto the spare: its
        // checkpointed partials replay there, so surviving senders
        // target a live card again.
        for t in self.tiles.values_mut() {
            if t.home == victim {
                t.home = spare;
            }
        }
        Some(spare)
    }

    /// A drained shard finished (re-)executing at `seconds`: settle
    /// every drain that was waiting on it and emit
    /// [`FleetEvent::DrainCompleted`] for drains that just emptied.
    fn settle_drains(&mut self, key: (u64, u64, u64), seconds: f64) {
        let Some(idxs) = self.drain_of.remove(&key) else { return };
        for i in idxs {
            self.drains[i].remaining -= 1;
            if self.drains[i].remaining == 0 {
                let d = self.drains[i];
                self.events.push(FleetEvent::DrainCompleted {
                    seconds,
                    spare: d.spare,
                    replaces: d.replaces,
                    shards: d.shards,
                });
                self.drain_seconds += seconds - d.started;
                self.tracer.span(
                    Track::Control,
                    Category::Drain,
                    || format!("drain card{} -> card{}", d.replaces, d.spare),
                    d.started,
                    seconds,
                );
            }
        }
    }

    /// Splice one fresh card into the fabric and extend every per-card
    /// vector for it; returns the new card id. Shared by watermark and
    /// SLO-burn growth.
    fn grow_one(&mut self, now: f64) -> usize {
        let report = self.fabric.attach_card();
        let card = report.card;
        self.cards += 1;
        self.enabled.push(true);
        self.dead.push(false);
        self.sticky.push(false);
        self.deaths.push(None);
        self.queues.push(VecDeque::new());
        self.link_free.push(now.max(0.0));
        self.out_free.push(0.0);
        self.card_free.push(0.0);
        self.compute_free.push(0.0);
        self.compute_ends.push(Vec::new());
        self.traces.push(DeviceTrace::default());
        card
    }

    /// SLO burn-rate growth: when the p99 latency objective burns over
    /// threshold in both the short and the long window, add capacity —
    /// activating the lowest-id live pooled spare when one exists (it
    /// is already wired), attaching a fresh card otherwise. This fires
    /// even when raw queue depth sits below the watermark: sustained
    /// burn, not backlog, is the trigger. One action per cooldown
    /// window so the added capacity has a window to land before the
    /// monitor re-evaluates.
    fn maybe_grow_slo(&mut self, now: f64) {
        let Some(monitor) = self.slo_monitor.as_mut() else { return };
        if !now.is_finite() {
            return;
        }
        let policy = monitor.policy();
        let Some((short_burn, long_burn)) = monitor.evaluate(now) else { return };
        if self.slo_alerts.last() != Some(&now) {
            self.slo_alerts.push(now);
        }
        if self.slo_grown >= policy.max_growth || now < self.slo_last_grow + policy.window_s {
            return;
        }
        let pooled = self
            .spare_pool
            .iter()
            .copied()
            .filter(|&s| !self.dead[s] && self.death(s).map_or(true, |td| td > now))
            .min();
        let card = match pooled {
            Some(s) => {
                // An SLO activation is ordinary capacity, not a drain
                // target: the spare stays non-sticky so rebalance and
                // stealing treat it like any live card.
                self.spare_pool.retain(|&x| x != s);
                self.enabled[s] = true;
                self.link_free[s] = self.link_free[s].max(now);
                s
            }
            None => self.grow_one(now),
        };
        self.slo_grown += 1;
        self.slo_last_grow = now;
        self.events.push(FleetEvent::SloGrown { seconds: now, card, short_burn, long_burn });
        self.tracer.instant(
            Track::Control,
            Category::Drain,
            || format!("slo burn: fleet grew card {card}"),
            now,
        );
        self.rebalance_queues(now);
    }

    /// Attach cards while the queue-depth watermark is exceeded and
    /// growth budget remains, rebalancing queued work after each.
    fn maybe_grow(&mut self, now: f64) {
        let Some(watermark) = self.config.scale_watermark else { return };
        if !now.is_finite() {
            return;
        }
        while self.grown < self.config.max_growth {
            let live = (0..self.cards).filter(|&c| self.live_at(c, now)).count();
            if live == 0 {
                return;
            }
            let depth = self.pending as f64 / live as f64;
            if depth <= watermark {
                return;
            }
            let card = self.grow_one(now);
            self.grown += 1;
            self.events.push(FleetEvent::FleetGrown { seconds: now, card, queue_depth: depth });
            self.tracer.instant(
                Track::Control,
                Category::Drain,
                || format!("watermark: fleet grew card {card}"),
                now,
            );
            self.rebalance_queues(now);
        }
    }

    /// Re-carve the queued (not-yet-started) shards over the live
    /// fleet: balance queue depth first, reduction hop-bytes to each
    /// shard's tile home second, lowest card id last. In-flight shards
    /// are untouched — this is the k-slice boundary — and so is work
    /// pinned to a living spare: a drain is a commitment, and growth
    /// redistributing it would silently degenerate the recovery into
    /// requeue-on-survivors mid-drain.
    fn rebalance_queues(&mut self, now: f64) {
        let _scope = crate::trace::profile::scope("elastic.rebalance");
        let live: Vec<usize> = (0..self.cards).filter(|&c| self.live_at(c, now)).collect();
        if live.is_empty() {
            return;
        }
        let pre = self.queued_hop_bytes();
        let mut all: Vec<Shard> = Vec::new();
        for (c, q) in self.queues.iter_mut().enumerate() {
            if !self.sticky[c] || self.dead[c] {
                all.extend(q.drain(..));
            }
        }
        for s in all {
            let home = self.tiles[&s.tile()].home;
            let best = live
                .iter()
                .copied()
                .min_by_key(|&c| {
                    let hop_bytes = if c == home {
                        0
                    } else {
                        self.fabric
                            .hops(c, home)
                            .map_or(u64::MAX / 2, |h| s.c_bytes() * u64::from(h))
                    };
                    (self.queues[c].len(), hop_bytes, c)
                })
                .expect("live is nonempty");
            self.queues[best].push_back(s);
        }
        self.post_grow_identity_hop_bytes += pre;
        self.post_grow_placed_hop_bytes += self.queued_hop_bytes();
    }

    /// Run the schedule to completion.
    pub fn run(mut self) -> Result<ElasticOutcome, String> {
        // One scope per seed execution: chaos sweeps replaying many
        // seeds show up as call count here, with the drain / heal /
        // rebalance children attributing the self time.
        let _scope = crate::trace::profile::scope("elastic.run");
        while self.pending > 0 {
            self.sweep_dead();
            let now = self.observe_now();
            if now.is_finite() {
                self.apply_faults(now);
                self.maybe_grow(now);
                self.maybe_grow_slo(now);
                self.tracer.counter("queue_depth", now, self.pending as f64);
            }
            // The live card whose host link frees first starts the
            // next DMA; every tie breaks on the card id. A card with
            // an empty queue only qualifies when some queue is
            // stealable — drained work pinned to a living spare is not
            // (the spare itself qualifies through its own queue).
            let stealable_exists = (0..self.cards)
                .any(|v| !self.queues[v].is_empty() && (!self.sticky[v] || self.dead[v]));
            let pick = (0..self.cards)
                .filter(|&c| {
                    self.enabled[c]
                        && !self.dead[c]
                        && self.death(c).map_or(true, |td| self.link_free[c] < td)
                        && (!self.queues[c].is_empty() || stealable_exists)
                })
                .min_by(|&a, &b| {
                    self.link_free[a].total_cmp(&self.link_free[b]).then(a.cmp(&b))
                });
            let Some(d) = pick else {
                return Err(format!(
                    "all {} card(s) dead with {} shard(s) outstanding",
                    self.cards, self.pending
                ));
            };
            // Own queue first; otherwise steal from the longest
            // stealable queue (ties toward the lowest card id) — dead
            // cards' leftover queues drain this way when no spare was
            // available.
            let (shard, stolen_from) = match self.queues[d].pop_front() {
                Some(s) => (s, None),
                None => {
                    let victim = (0..self.cards)
                        .filter(|&v| {
                            !self.queues[v].is_empty() && (!self.sticky[v] || self.dead[v])
                        })
                        .max_by(|&a, &b| {
                            self.queues[a].len().cmp(&self.queues[b].len()).then(b.cmp(&a))
                        })
                        .expect("the pick required a stealable queue");
                    (self.queues[victim].pop_back().expect("victim queue nonempty"), Some(victim))
                }
            };
            self.pending -= 1;
            if stolen_from.is_some() {
                self.steals += 1;
                self.traces[d].stolen += 1;
            }

            // Double-buffered staging: task i waits for task i-2's
            // compute (same gate as the fixed-fleet scheduler).
            let i = self.traces[d].shards;
            let gate = if i >= 2 { self.compute_ends[d][i - 2] } else { 0.0 };
            let xfer = self.host.seconds_for_bytes(shard.input_bytes());
            let t_start = self.link_free[d].max(gate);
            let t_end = t_start + xfer;
            let comp = (self.compute_seconds)(d, &shard);
            let c_start = self.compute_free[d].max(t_end);
            let c_end = c_start + comp;

            if let Some(v) = stolen_from {
                self.tracer.instant(
                    Track::CardCompute(d),
                    Category::Steal,
                    || format!("steal r{} k{} <- card{v}", shard.row0, shard.k0),
                    t_start,
                );
            }

            if let Some(td) = self.death(d) {
                if c_end > td {
                    // The card dies with this shard in flight: heal the
                    // fabric, then drain queue + shard to a spare, or
                    // fall back to the least-loaded survivor.
                    self.dead[d] = true;
                    self.fabric.kill(d);
                    self.traces[d].lost += 1;
                    self.traces[d].transfer_seconds += (td.min(t_end) - t_start).max(0.0);
                    self.traces[d].compute_seconds += (td - c_start).clamp(0.0, comp);
                    self.tracer.instant(
                        Track::Control,
                        Category::Drain,
                        || format!("death card {d}"),
                        td,
                    );
                    if td.min(t_end) > t_start {
                        self.tracer.span(
                            Track::CardDma(d),
                            Category::Host,
                            || {
                                format!(
                                    "dma r{} c{} k{} (lost)",
                                    shard.row0, shard.col0, shard.k0
                                )
                            },
                            t_start,
                            td.min(t_end),
                        );
                    }
                    if td > c_start {
                        self.tracer.span(
                            Track::CardCompute(d),
                            Category::Compute,
                            || {
                                format!(
                                    "shard r{} c{} k{} (lost)",
                                    shard.row0, shard.col0, shard.k0
                                )
                            },
                            c_start,
                            td,
                        );
                    }
                    self.link_free[d] = td;
                    self.compute_free[d] = self.compute_free[d].min(td);
                    self.retries += 1;
                    let key = (shard.row0, shard.col0, shard.k0);
                    let tries = self.attempts.entry(key).or_insert(1);
                    *tries += 1;
                    if *tries > self.cards + 1 {
                        return Err(format!("shard {key:?} failed {tries} times"));
                    }
                    // The queued shards are still counted in
                    // `pending`; only the lost shard re-enters it.
                    if self.drain_to_spare(d, Some(shard), td).is_some() {
                        self.pending += 1;
                        continue;
                    }
                    let survivor = (0..self.cards)
                        .filter(|&v| {
                            self.enabled[v]
                                && !self.dead[v]
                                && self.death(v).map_or(true, |tv| self.link_free[v] < tv)
                        })
                        .min_by_key(|&v| (self.queues[v].len(), v));
                    match survivor {
                        Some(v) => {
                            self.queues[v].push_back(shard);
                            self.pending += 1;
                        }
                        None => {
                            return Err(format!(
                                "all {} card(s) dead with {} shard(s) outstanding",
                                self.cards,
                                self.pending + 1
                            ))
                        }
                    }
                    continue;
                }
            }

            self.link_free[d] = t_end;
            self.traces[d].transfer_seconds += xfer;
            self.compute_free[d] = c_end;
            self.compute_ends[d].push(c_end);
            self.traces[d].compute_seconds += comp;
            self.traces[d].shards += 1;
            self.compute_intervals.push((c_start, c_end));
            // Shard latency = DMA start to compute end: the window the
            // SLO monitor burns against and the dashboards quantile.
            if let Some(m) = self.slo_monitor.as_mut() {
                m.record(c_end, c_end - t_start);
            }
            self.tracer.counter("shard_latency_s", c_end, c_end - t_start);
            self.tracer.span(
                Track::CardDma(d),
                Category::Host,
                || format!("dma r{} c{} k{}", shard.row0, shard.col0, shard.k0),
                t_start,
                t_end,
            );
            self.tracer.span(
                Track::CardCompute(d),
                Category::Compute,
                || format!("shard r{} c{} k{}", shard.row0, shard.col0, shard.k0),
                c_start,
                c_end,
            );

            // Tile bookkeeping: fabric reduction and final writeback.
            let tkey = shard.tile();
            let (home0, c_bytes) = {
                let t = &self.tiles[&tkey];
                (t.home, t.c_bytes)
            };
            let home_doomed =
                self.dead[home0] || self.death(home0).map_or(false, |td| td <= c_end);
            let home = if home_doomed && home0 != d { d } else { home0 };
            if home != home0 {
                self.tiles.get_mut(&tkey).expect("tile exists").home = home;
            }
            let mut ready = c_end;
            if d != home {
                match self.fabric.send_with_deaths(d, home, c_bytes, c_end, &self.deaths) {
                    Some((s_start, s_end)) => {
                        self.traces[d].card_seconds += s_end - s_start;
                        self.card_free[d] = self.card_free[d].max(s_end);
                        self.send_intervals.push((s_start, s_end));
                        ready = ready.max(s_end);
                        self.tracer.span(
                            Track::CardFabric(d),
                            Category::Fabric,
                            || format!("reduce r{} c{} -> card{home}", shard.row0, shard.col0),
                            s_start,
                            s_end,
                        );
                        if self.tracer.is_recording() {
                            if let Some(path) = self.fabric.route_nodes(d, home) {
                                for w in path.windows(2) {
                                    self.tracer.span(
                                        Track::Link(w[0], w[1]),
                                        Category::Fabric,
                                        || format!("circuit card{d} -> card{home}"),
                                        s_start,
                                        s_end,
                                    );
                                }
                            }
                        }
                    }
                    None => {
                        // Fabric partitioned: bounce via the host at
                        // 2x PCIe, serialized with this card's other
                        // reduction sends.
                        let bounce = 2.0 * self.host.seconds_for_bytes(c_bytes);
                        let s_start = self.card_free[d].max(c_end);
                        let s_end = s_start + bounce;
                        self.traces[d].card_seconds += bounce;
                        self.card_free[d] = s_end;
                        self.send_intervals.push((s_start, s_end));
                        ready = ready.max(s_end);
                        self.tracer.span(
                            Track::CardFabric(d),
                            Category::Host,
                            || format!("bounce r{} c{} via host", shard.row0, shard.col0),
                            s_start,
                            s_end,
                        );
                    }
                }
            }
            let (tile_done, tile_ready, tile_home) = {
                let t = self.tiles.get_mut(&tkey).expect("tile exists");
                t.remaining -= 1;
                t.ready = t.ready.max(ready);
                (t.remaining == 0, t.ready, t.home)
            };
            if tile_done {
                let wb = self.host.seconds_for_bytes(c_bytes);
                let mut wb_home = tile_home;
                let doomed = self.dead[wb_home]
                    || self
                        .death(wb_home)
                        .map_or(false, |td| self.out_free[wb_home].max(tile_ready) + wb > td);
                if wb_home != d && doomed {
                    wb_home = d;
                }
                let wb_start = self.out_free[wb_home].max(tile_ready);
                self.out_free[wb_home] = wb_start + wb;
                self.traces[wb_home].transfer_seconds += wb;
                self.tracer.span(
                    Track::CardWriteback(wb_home),
                    Category::Host,
                    || format!("writeback tile r{} c{}", shard.row0, shard.col0),
                    wb_start,
                    wb_start + wb,
                );
            }
            self.settle_drains((shard.row0, shard.col0, shard.k0), c_end);
        }
        Ok(self.finish())
    }

    fn finish(self) -> ElasticOutcome {
        let mut traces = self.traces;
        let mut makespan = 0.0f64;
        for d in 0..self.cards {
            let finish = self.link_free[d]
                .max(self.out_free[d])
                .max(self.compute_free[d])
                .max(self.card_free[d]);
            traces[d].finish_seconds = finish;
            makespan = makespan.max(finish);
        }
        let reduction_seconds: f64 = self.send_intervals.iter().map(|&(s, e)| e - s).sum();
        let reduction_overlap_seconds =
            overlap_seconds(self.compute_intervals, &self.send_intervals);
        let spare_activations = self
            .events
            .iter()
            .filter(|e| matches!(e, FleetEvent::SpareActivated { .. }))
            .count();
        let drains_completed = self
            .events
            .iter()
            .filter(|e| matches!(e, FleetEvent::DrainCompleted { .. }))
            .count();
        let slo_final_burn =
            self.slo_monitor.as_ref().map_or((0.0, 0.0), |m| m.burn_at(makespan));
        ElasticOutcome {
            schedule: ScheduleOutcome {
                per_device: traces,
                makespan_seconds: makespan,
                steals: self.steals,
                retries: self.retries,
                reroutes: self.fabric.reroutes,
                reduction_seconds,
                reduction_overlap_seconds,
                link_busy_seconds: self.fabric.busy_seconds_total(),
                max_link_busy_seconds: self.fabric.max_busy_seconds(),
                directed_links: self.fabric.directed_links(),
            },
            events: self.events,
            spare_activations,
            drains_completed,
            drain_seconds: self.drain_seconds,
            drain_identity_cost_seconds: self.drain_identity_cost_seconds,
            drain_placed_cost_seconds: self.drain_placed_cost_seconds,
            grown_cards: self.grown,
            slo_grown_cards: self.slo_grown,
            slo_alerts: self.slo_alerts,
            slo_final_burn,
            post_grow_identity_hop_bytes: self.post_grow_identity_hop_bytes,
            post_grow_placed_hop_bytes: self.post_grow_placed_hop_bytes,
            final_cards: self.cards,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::PartitionStrategy;
    use crate::cluster::scheduler::{run_schedule, run_schedule_with_failures};

    fn plan(strategy: PartitionStrategy, d: u64) -> PartitionPlan {
        PartitionPlan::new(strategy, d, d, d).unwrap()
    }

    fn host() -> Link {
        Link::pcie_gen3_x8()
    }

    fn flat(_: usize, s: &Shard) -> f64 {
        s.flops() as f64 / 3.0e12
    }

    fn spares(n: usize) -> ElasticConfig {
        ElasticConfig { hot_spares: n, scale_watermark: None, max_growth: 0, slo: None }
    }

    /// A ring over `active` cards with `k` spares spliced in.
    fn ring_with_spares(active: usize, k: usize) -> Topology {
        let mut t = Topology::ring(active);
        for _ in 0..k {
            t.attach_card();
        }
        t
    }

    #[test]
    fn healthy_run_matches_the_fixed_scheduler_bit_for_bit() {
        let p = plan(PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 }, 8192);
        let topo = Topology::ring(8);
        let a = run_schedule(&p, 8, &host(), &topo, flat);
        let b = run_elastic_schedule(&p, 8, &host(), &topo, &FaultPlan::none(), spares(0), flat)
            .unwrap();
        assert_eq!(a.makespan_seconds.to_bits(), b.schedule.makespan_seconds.to_bits());
        assert_eq!(a.steals, b.schedule.steals);
        assert_eq!(a.reduction_seconds.to_bits(), b.schedule.reduction_seconds.to_bits());
        assert_eq!(a.link_busy_seconds.to_bits(), b.schedule.link_busy_seconds.to_bits());
        for (x, y) in a.per_device.iter().zip(&b.schedule.per_device) {
            assert_eq!(x.shards, y.shards);
            assert_eq!(x.compute_seconds.to_bits(), y.compute_seconds.to_bits());
            assert_eq!(x.finish_seconds.to_bits(), y.finish_seconds.to_bits());
        }
        assert!(b.events.is_empty());
        assert_eq!(b.final_cards, 8);
    }

    #[test]
    fn spares_stay_idle_on_a_healthy_fleet() {
        let p = plan(PartitionStrategy::Row1D { devices: 2 }, 4096);
        let topo = ring_with_spares(2, 1);
        let out =
            run_elastic_schedule(&p, 2, &host(), &topo, &FaultPlan::none(), spares(1), flat)
                .unwrap();
        assert_eq!(out.schedule.per_device[2].shards, 0, "spare must not be placed");
        assert!(out.events.is_empty());
        assert_eq!(out.spare_activations, 0);
    }

    #[test]
    fn midflight_death_drains_to_the_spare_and_beats_requeue() {
        let p = plan(PartitionStrategy::Row1D { devices: 2 }, 4096);
        let dma = host().seconds_for_bytes(p.shards[0].input_bytes());
        let faults = FaultPlan::kill(0, dma + 0.5);
        let topo = ring_with_spares(2, 1);
        let out =
            run_elastic_schedule(&p, 2, &host(), &topo, &faults, spares(1), |_, _| 1.0).unwrap();
        assert_eq!(out.spare_activations, 1);
        assert_eq!(out.drains_completed, 1);
        assert_eq!(out.schedule.retries, 1);
        assert_eq!(out.schedule.per_device[0].lost, 1);
        assert!(out.schedule.per_device[2].shards >= 1, "spare re-executed the loss");
        assert!(out.drain_seconds > 0.0);
        // Every event — drain completion included — precedes the barrier.
        for e in &out.events {
            assert!(e.seconds() <= out.schedule.makespan_seconds + 1e-12, "{e:?}");
        }
        // Drain-to-spare strictly beats requeue-on-survivors: the
        // spare re-executes the loss while the survivor runs its own.
        let requeue = run_schedule_with_failures(
            &p,
            2,
            &host(),
            &Topology::ring(2),
            &[Some(dma + 0.5), None],
            |_, _| 1.0,
        )
        .unwrap();
        assert!(
            out.schedule.makespan_seconds < requeue.makespan_seconds,
            "drain {} vs requeue {}",
            out.schedule.makespan_seconds,
            requeue.makespan_seconds
        );
    }

    #[test]
    fn dead_from_start_drains_its_whole_queue() {
        let p = plan(PartitionStrategy::Row1D { devices: 4 }, 4096);
        let topo = ring_with_spares(2, 1);
        let out = run_elastic_schedule(
            &p,
            2,
            &host(),
            &topo,
            &FaultPlan::kill(0, 0.0),
            spares(1),
            flat,
        )
        .unwrap();
        assert_eq!(out.schedule.retries, 0, "nothing was in flight at t=0");
        assert_eq!(out.spare_activations, 1);
        assert_eq!(out.drains_completed, 1);
        assert_eq!(out.schedule.per_device[0].shards, 0);
        assert!(out.schedule.per_device[2].shards >= 1);
        let done: usize = out.schedule.per_device.iter().map(|t| t.shards).sum();
        assert_eq!(done, p.shards.len());
        // The drain event log names the victim and the spare.
        assert!(matches!(
            out.events[0],
            FleetEvent::SpareActivated { spare: 2, replaces: 0, .. }
        ));
    }

    #[test]
    fn watermark_growth_attaches_cards_and_shortens_the_tail() {
        let p = plan(PartitionStrategy::Row1D { devices: 8 }, 8192);
        let topo = Topology::ring(2);
        let config =
            ElasticConfig { hot_spares: 0, scale_watermark: Some(1.5), max_growth: 2, slo: None };
        let out =
            run_elastic_schedule(&p, 2, &host(), &topo, &FaultPlan::none(), config, flat)
                .unwrap();
        assert_eq!(out.grown_cards, 2, "depth 4.0 > 1.5 twice under the budget");
        assert_eq!(out.final_cards, 4);
        let grown: Vec<_> = out
            .events
            .iter()
            .filter(|e| matches!(e, FleetEvent::FleetGrown { .. }))
            .collect();
        assert_eq!(grown.len(), 2);
        assert!(
            out.schedule.per_device[2].shards + out.schedule.per_device[3].shards > 0,
            "grown cards took work: {:?}",
            out.schedule.per_device
        );
        let done: usize = out.schedule.per_device.iter().map(|t| t.shards).sum();
        assert_eq!(done, p.shards.len());
        let fixed = run_schedule(&p, 2, &host(), &topo, flat);
        assert!(
            out.schedule.makespan_seconds < fixed.makespan_seconds,
            "grown {} vs fixed {}",
            out.schedule.makespan_seconds,
            fixed.makespan_seconds
        );
    }

    #[test]
    fn seeded_fault_plans_are_deterministic_and_bounded() {
        let a = FaultPlan::seeded(3, 8, 10.0);
        assert_eq!(a, FaultPlan::seeded(3, 8, 10.0));
        assert_ne!(a, FaultPlan::seeded(4, 8, 10.0));
        let kills: Vec<usize> = a
            .faults
            .iter()
            .filter_map(|f| match f {
                Fault::Kill { card, .. } => Some(*card),
                _ => None,
            })
            .collect();
        assert!((1..=2).contains(&kills.len()));
        let mut distinct = kills.clone();
        distinct.dedup();
        assert_eq!(distinct.len(), kills.len(), "kills hit distinct cards");
        for f in &a.faults {
            assert!(f.seconds() > 0.0 && f.seconds() < 10.0, "{f:?}");
        }
        let deaths = a.deaths(8);
        assert_eq!(deaths.iter().flatten().count(), kills.len());
        // Two kills on one card keep the earliest.
        let twice = FaultPlan {
            faults: vec![
                Fault::Kill { card: 1, seconds: 5.0 },
                Fault::Kill { card: 1, seconds: 2.0 },
            ],
        };
        assert_eq!(twice.deaths(4)[1], Some(2.0));
    }

    #[test]
    fn chaotic_runs_replay_bit_identically() {
        let p = plan(PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 }, 4096);
        let topo = {
            let mut t = Topology::torus2d(4, 2);
            t.attach_card();
            t
        };
        let faults = FaultPlan::seeded(7, 8, 2.0);
        let config =
            ElasticConfig { hot_spares: 1, scale_watermark: Some(4.0), max_growth: 1, slo: None };
        let run = || {
            run_elastic_schedule(&p, 8, &host(), &topo, &faults, config, |_, _| 0.5).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.events, b.events);
        assert_eq!(
            a.schedule.makespan_seconds.to_bits(),
            b.schedule.makespan_seconds.to_bits()
        );
        assert_eq!(a.schedule.retries, b.schedule.retries);
        assert_eq!(a.drain_seconds.to_bits(), b.drain_seconds.to_bits());
        assert!(a.drain_placement_gain() >= 1.0);
        let done: usize = a.schedule.per_device.iter().map(|t| t.shards).sum();
        assert_eq!(done, p.shards.len(), "no shard lost under chaos");
        assert!(a.render().contains("elastic run"));
    }

    #[test]
    fn traced_run_records_the_recovery_and_perturbs_nothing() {
        let p = plan(PartitionStrategy::Row1D { devices: 2 }, 4096);
        let dma = host().seconds_for_bytes(p.shards[0].input_bytes());
        let faults = FaultPlan::kill(0, dma + 0.5);
        let topo = ring_with_spares(2, 1);
        let tracer = Tracer::recording();
        let traced = run_elastic_schedule_traced(
            &p,
            2,
            &host(),
            &topo,
            &faults,
            spares(1),
            &tracer,
            |_, _| 1.0,
        )
        .unwrap();
        let plain =
            run_elastic_schedule(&p, 2, &host(), &topo, &faults, spares(1), |_, _| 1.0).unwrap();
        assert_eq!(
            traced.schedule.makespan_seconds.to_bits(),
            plain.schedule.makespan_seconds.to_bits(),
            "recording must not perturb the schedule"
        );
        let log = tracer.take();
        assert_eq!(log.open_spans(), 0);
        assert!(log.makespan() <= traced.schedule.makespan_seconds + 1e-12);
        assert!(log.instants.iter().any(|i| i.name.starts_with("death card")));
        assert!(log.instants.iter().any(|i| i.name.contains("spare")));
        assert!(log.spans.iter().any(|s| s.name.starts_with("drain card")));
        assert!(log.spans.iter().any(|s| s.name.ends_with("(lost)")));
        assert!(!log.counters.is_empty(), "queue depth sampled");
    }
}
