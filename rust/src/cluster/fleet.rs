//! A fleet of simulated 520N cards and the cluster-level simulator.
//!
//! Each device is one [`OffchipDesign`] — fleets may mix Table-I
//! designs (a heterogeneous rack), and the scheduler's work-stealing
//! naturally shifts shards toward the faster cards. Shard timing runs
//! through the same [`OffchipSim`] event model as single-card requests,
//! on extents padded up to the device's blocking (a partial edge shard
//! is timed as its zero-padded block, like the HLS kernel would run it).
//!
//! The fleet's card↔card wiring is an explicit
//! [`crate::fabric::Topology`]: [`ClusterSim::builder`] defaults to
//! [`Topology::auto`], `ClusterSimBuilder::topology` pins a specific
//! fabric, and the resulting [`ClusterReport`] carries link-utilization
//! and reduction-overlap gauges alongside the compute numbers.

use super::elastic::{run_elastic_schedule_traced, ElasticConfig, ElasticOutcome, Fault, FaultPlan};
use crate::observe::slo::SloPolicy;
use super::interconnect::Link;
use super::partition::{PartitionPlan, PartitionStrategy, Shard};
use super::scheduler::{run_schedule_traced, run_schedule_with_failures_traced, ScheduleOutcome};
use crate::blocked::{OffchipDesign, OffchipSim};
use crate::dse::configs::fitted_designs;
use crate::fabric::{pipeline_schedule_traced, OverlapReport, ReduceAlgo, Topology};
use crate::gemm::Matrix;
use crate::perfmodel::flop_count;
use crate::placement::{optimize_traced, PlacementReport, PlacementStrategy};
use crate::trace::Tracer;

/// One card of the fleet.
#[derive(Clone, Debug)]
pub struct ClusterDevice {
    pub id: String,
    pub design: OffchipDesign,
}

/// The rack: N simulated 520N cards.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub devices: Vec<ClusterDevice>,
}

impl Fleet {
    /// N identical cards running one Table-I design (by catalog id).
    pub fn homogeneous(n: usize, design_id: &str) -> Result<Self, String> {
        let spec = fitted_designs()
            .into_iter()
            .find(|d| d.id == design_id)
            .ok_or_else(|| format!("unknown or unfitted design {design_id}"))?;
        let design = OffchipDesign {
            blocking: spec.level1().ok_or_else(|| format!("design {design_id} has no blocking"))?,
            fmax_mhz: spec.fmax_mhz.unwrap(),
            controller_efficiency: 0.97,
        };
        Ok(Self::uniform(n, design_id, design))
    }

    /// N identical cards from an explicit design.
    pub fn uniform(n: usize, tag: &str, design: OffchipDesign) -> Self {
        let devices = (0..n)
            .map(|i| ClusterDevice { id: format!("{tag}{i}"), design })
            .collect();
        Self { devices }
    }

    /// N cards cycling through the fitted Table-I designs, highest peak
    /// first — a deliberately heterogeneous rack.
    pub fn mixed_table1(n: usize) -> Self {
        let mut specs: Vec<(&'static str, OffchipDesign)> = fitted_designs()
            .into_iter()
            .filter_map(|d| {
                let design = OffchipDesign {
                    blocking: d.level1()?,
                    fmax_mhz: d.fmax_mhz?,
                    controller_efficiency: 0.97,
                };
                Some((d.id, design))
            })
            .collect();
        // Catalog peaks are finite today; total_cmp keeps a future
        // degenerate entry from panicking the whole fleet build.
        specs.sort_by(|a, b| b.1.peak_gflops().total_cmp(&a.1.peak_gflops()));
        let devices = (0..n)
            .map(|i| {
                let (id, design) = specs[i % specs.len()];
                ClusterDevice { id: format!("{id}{i}"), design }
            })
            .collect();
        Self { devices }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Sum of eq. 5 peaks over the rack, in GFLOPS.
    pub fn aggregate_peak_gflops(&self) -> f64 {
        self.devices.iter().map(|d| d.design.peak_gflops()).sum()
    }
}

/// Per-device slice of a [`ClusterReport`].
#[derive(Clone, Debug)]
pub struct DeviceReport {
    pub id: String,
    pub shards: usize,
    pub stolen: usize,
    /// Shards lost in flight when this device died.
    pub lost: usize,
    pub transfer_seconds: f64,
    pub compute_seconds: f64,
    pub card_seconds: f64,
    pub finish_seconds: f64,
    /// Compute-busy fraction of the makespan.
    pub utilization: f64,
    pub peak_gflops: f64,
}

/// Aggregate outcome of one sharded GEMM.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub strategy: &'static str,
    /// Fabric family the reductions routed over.
    pub topology: &'static str,
    pub devices: usize,
    pub m: u64,
    pub k: u64,
    pub n: u64,
    pub shards: usize,
    pub steals: usize,
    /// Shard attempts lost to device deaths and re-executed on
    /// survivors (0 on a healthy fleet).
    pub retries: usize,
    /// Reduction steps that re-routed around a dying transit card.
    pub reroutes: usize,
    pub makespan_seconds: f64,
    /// Paper-convention throughput over the whole problem.
    pub effective_gflops: f64,
    /// N·single-card peak for this rack.
    pub aggregate_peak_gflops: f64,
    /// effective / aggregate peak — the cluster analogue of e_D.
    pub cluster_efficiency: f64,
    pub host_to_device_bytes: u64,
    pub device_to_device_bytes: u64,
    pub device_to_host_bytes: u64,
    /// Circuit-hold seconds of the partial-C reduction steps.
    pub reduction_seconds: f64,
    /// Of those, seconds hidden under some device's compute.
    pub reduction_overlap_seconds: f64,
    /// Busy seconds summed over all directed fabric links.
    pub link_busy_seconds: f64,
    /// Busy seconds of the hottest directed fabric link.
    pub max_link_busy_seconds: f64,
    /// Directed fabric links (two per cable/trunk).
    pub directed_links: usize,
    /// Device→card placement strategy the run's plan came from
    /// ("identity" when the plan was simulated exactly as given).
    pub placement: &'static str,
    /// Reduction hop-bytes the plan would pay under identity placement.
    pub placement_identity_hop_bytes: u64,
    /// Reduction hop-bytes of the plan as simulated (≤ identity when a
    /// placement search ran).
    pub placement_placed_hop_bytes: u64,
    /// Contention-priced reduction drain under identity placement
    /// (0 when no search ran).
    pub placement_identity_cost_seconds: f64,
    /// Same drain under the chosen placement (0 when no search ran).
    pub placement_placed_cost_seconds: f64,
    /// Host wall-clock the placement search spent (gauge only).
    pub placement_search_seconds: f64,
    /// Device bounding the critical path.
    pub critical_device: usize,
    pub per_device: Vec<DeviceReport>,
}

impl ClusterReport {
    /// Mean directed-link utilization over the makespan.
    pub fn link_utilization(&self) -> f64 {
        if self.makespan_seconds <= 0.0 || self.directed_links == 0 {
            return 0.0;
        }
        self.link_busy_seconds / (self.makespan_seconds * self.directed_links as f64)
    }

    /// Utilization of the hottest directed link over the makespan.
    pub fn max_link_utilization(&self) -> f64 {
        if self.makespan_seconds <= 0.0 {
            return 0.0;
        }
        self.max_link_busy_seconds / self.makespan_seconds
    }

    /// Fraction of the reduction time hidden under compute.
    pub fn reduction_overlap(&self) -> f64 {
        if self.reduction_seconds <= 0.0 {
            return 0.0;
        }
        self.reduction_overlap_seconds / self.reduction_seconds
    }

    /// identity/placed contention-priced reduction drain (1.0 when no
    /// placement search ran or there was nothing to reduce).
    pub fn placement_gain(&self) -> f64 {
        if self.placement_placed_cost_seconds <= 0.0 {
            return 1.0;
        }
        self.placement_identity_cost_seconds / self.placement_placed_cost_seconds
    }

    /// Fraction of identity hop-bytes the placement removed.
    pub fn placement_hop_saving(&self) -> f64 {
        if self.placement_identity_hop_bytes == 0 {
            return 0.0;
        }
        1.0 - self.placement_placed_hop_bytes as f64 / self.placement_identity_hop_bytes as f64
    }

    /// Multi-line human-readable summary (CLI / examples).
    pub fn render(&self) -> String {
        let mut out = format!(
            "cluster {} on {} device(s): ({} x {}) * ({} x {})\n\
             shards: {} ({} stolen, {} retried)  makespan: {:.4} s\n\
             effective: {:.0} GFLOPS of {:.0} aggregate peak (e_C = {:.3})\n\
             bytes: {:.1} MB host->dev, {:.1} MB dev<->dev, {:.1} MB dev->host\n\
             fabric {}: {} directed links, util {:.1}% mean / {:.1}% peak; \
             reduction {:.4} s ({:.0}% overlapped, {} rerouted)\n",
            self.strategy,
            self.devices,
            self.m,
            self.k,
            self.k,
            self.n,
            self.shards,
            self.steals,
            self.retries,
            self.makespan_seconds,
            self.effective_gflops,
            self.aggregate_peak_gflops,
            self.cluster_efficiency,
            self.host_to_device_bytes as f64 / 1e6,
            self.device_to_device_bytes as f64 / 1e6,
            self.device_to_host_bytes as f64 / 1e6,
            self.topology,
            self.directed_links,
            self.link_utilization() * 100.0,
            self.max_link_utilization() * 100.0,
            self.reduction_seconds,
            self.reduction_overlap() * 100.0,
            self.reroutes,
        );
        if self.placement != "identity" {
            out.push_str(&format!(
                "placement {}: hop-bytes {:.1} MB -> {:.1} MB (-{:.0}%), reduction drain \
                 {:.4} s -> {:.4} s ({:.2}x), search {:.1} ms\n",
                self.placement,
                self.placement_identity_hop_bytes as f64 / 1e6,
                self.placement_placed_hop_bytes as f64 / 1e6,
                self.placement_hop_saving() * 100.0,
                self.placement_identity_cost_seconds,
                self.placement_placed_cost_seconds,
                self.placement_gain(),
                self.placement_search_seconds * 1e3,
            ));
        }
        for (i, d) in self.per_device.iter().enumerate() {
            out.push_str(&format!(
                "  {:<4} {:>2} shard(s) {:>2} stolen  xfer {:>8.4} s  compute {:>8.4} s  \
                 util {:>5.1}%{}\n",
                d.id,
                d.shards,
                d.stolen,
                d.transfer_seconds,
                d.compute_seconds,
                d.utilization * 100.0,
                if i == self.critical_device { "  <- critical path" } else { "" },
            ));
        }
        out
    }
}

/// The cluster simulator: a fleet plus its fabric.
#[derive(Clone, Debug)]
pub struct ClusterSim {
    pub fleet: Fleet,
    /// PCIe host link of each card.
    pub host: Link,
    /// The card↔card fabric the reductions route over.
    pub topology: Topology,
    /// How the planner maps plan devices onto cards
    /// ([`Self::plan_and_report`] places every candidate before
    /// simulating it; [`Self::simulate`] prices a plan exactly as
    /// given). Defaults to the seeded local search.
    pub placement: PlacementStrategy,
    /// Trailing fleet cards held as hot spares: wired into the
    /// topology but excluded from placement — plans carve over
    /// [`Self::active_devices`] cards and [`Self::simulate_elastic`]
    /// drains dead cards' work onto the spares.
    pub hot_spares: usize,
    /// Queue-depth watermark for elastic growth (pending shards per
    /// live card; None disables growth).
    pub scale_watermark: Option<f64>,
    /// Latency SLO for burn-rate-driven growth during
    /// [`Self::simulate_elastic`]: sustained p99 burn activates a
    /// spare or attaches a card even below the queue-depth watermark
    /// (None disables it).
    pub slo: Option<SloPolicy>,
    /// The flight recorder every simulate path threads through
    /// ([`crate::trace`]). Defaults to the no-op sink; attach a
    /// [`Tracer::recording`] with [`Self::with_trace`] to capture
    /// spans. Cloning the sim shares the recording buffer.
    pub trace: Tracer,
}

/// Builder for [`ClusterSim`] — the one construction path
/// (`ClusterSim::builder(fleet).topology(..).spares(..).build()`
/// replaced the old `new`/`with_topology`/`with_spares`/
/// `with_topology_and_spares` constructor family and their chained
/// setters).
#[derive(Clone, Debug)]
pub struct ClusterSimBuilder {
    fleet: Fleet,
    topology: Option<Topology>,
    hot_spares: usize,
    placement: PlacementStrategy,
    scale_watermark: Option<f64>,
    slo: Option<SloPolicy>,
    trace: Tracer,
}

impl ClusterSimBuilder {
    /// Fabric of the **active** cards (the fleet minus spares); each
    /// spare is spliced in on top with [`Topology::attach_card`].
    /// Default: [`Topology::auto`] over the active cards.
    pub fn topology(mut self, topology: Topology) -> Self {
        self.topology = Some(topology);
        self
    }

    /// Trailing fleet cards held as hot spares: wired into the fabric
    /// but excluded from placement.
    pub fn spares(mut self, hot_spares: usize) -> Self {
        self.hot_spares = hot_spares;
        self
    }

    /// Device→card placement strategy (default: seeded local search).
    pub fn placement(mut self, placement: PlacementStrategy) -> Self {
        self.placement = placement;
        self
    }

    /// Queue-depth watermark for elastic growth (pending shards per
    /// live card above it grow the fabric during
    /// [`ClusterSim::simulate_elastic`]).
    pub fn watermark(mut self, scale_watermark: impl Into<Option<f64>>) -> Self {
        self.scale_watermark = scale_watermark.into();
        self
    }

    /// Latency SLO for burn-rate-driven growth: sustained burn grows
    /// the fleet even when queue depth sits below the watermark.
    pub fn slo(mut self, slo: impl Into<Option<SloPolicy>>) -> Self {
        self.slo = slo.into();
        self
    }

    /// Record every simulated run into `tracer`: per-card DMA /
    /// compute / reduction / writeback spans, per-link circuit holds,
    /// and elastic control events, all in deterministic simulated
    /// time. See [`crate::trace`].
    pub fn trace(mut self, tracer: Tracer) -> Self {
        self.trace = tracer;
        self
    }

    /// Assemble the sim. Panics when the spare count leaves no active
    /// card, or when an explicit topology does not wire exactly the
    /// fleet's active cards.
    pub fn build(self) -> ClusterSim {
        let cards = self.fleet.len().max(1);
        assert!(self.hot_spares < cards, "at least one card must stay active");
        let active = cards - self.hot_spares;
        let mut topology = self.topology.unwrap_or_else(|| Topology::auto(active));
        assert_eq!(
            topology.cards, active,
            "topology must wire exactly the fleet's active cards"
        );
        for _ in 0..self.hot_spares {
            topology.attach_card();
        }
        ClusterSim {
            fleet: self.fleet,
            host: Link::pcie_gen3_x8(),
            topology,
            placement: self.placement,
            hot_spares: self.hot_spares,
            scale_watermark: self.scale_watermark,
            slo: self.slo,
            trace: self.trace,
        }
    }
}

impl ClusterSim {
    /// Start building a sim over `fleet`. With no other calls,
    /// `build()` gives the default fabric ([`Topology::auto`]: a full
    /// mesh while the 4-port budget lasts, a near-square torus
    /// beyond), no spares, the seeded-local-search placement, no
    /// growth, and the no-op trace sink.
    pub fn builder(fleet: Fleet) -> ClusterSimBuilder {
        ClusterSimBuilder {
            fleet,
            topology: None,
            hot_spares: 0,
            placement: PlacementStrategy::default(),
            scale_watermark: None,
            slo: None,
            trace: Tracer::off(),
        }
    }

    /// Cards plans carve over (the fleet minus its hot spares).
    pub fn active_devices(&self) -> usize {
        self.fleet.len().saturating_sub(self.hot_spares).max(1)
    }

    /// Optimize the device→card placement of `plan` for this sim's
    /// fabric under the sim's strategy. Returns the re-homed plan plus
    /// the search report — or the plan untouched and `None` when the
    /// strategy is identity, the plan has no reduction traffic to
    /// optimize, or the sim holds hot spares (the bijective search
    /// would move live work onto the spare cards; spared sims instead
    /// re-place on drain, see [`Self::simulate_elastic`]). Card deaths
    /// during a later run re-home reductions through the scheduler's
    /// existing path, placed or not.
    pub fn place_plan(&self, plan: &PartitionPlan) -> (PartitionPlan, Option<PlacementReport>) {
        if matches!(self.placement, PlacementStrategy::Identity)
            || plan.device_to_device_bytes == 0
            || self.hot_spares > 0
        {
            return (plan.clone(), None);
        }
        let report = optimize_traced(plan, &self.topology, self.placement, &self.trace);
        let placed = report.placement.apply_to(plan);
        (placed, Some(report))
    }

    /// Seconds for `shard` on fleet device `d`: the shard's extents are
    /// padded up to the device's blocking and run through the same
    /// event-level simulator as single-card requests.
    pub fn shard_seconds(&self, d: usize, shard: &Shard) -> f64 {
        let design = self.fleet.devices[d].design;
        let (pi, pj, pk) = design.blocking.pad_offchip(shard.rows, shard.cols, shard.ks);
        OffchipSim::new(design).simulate(pi, pj, pk).seconds
    }

    /// Timing-only run of a plan, exactly as given (identity placement).
    pub fn simulate(&self, plan: &PartitionPlan) -> ClusterReport {
        self.simulate_placed(plan, None)
    }

    /// Timing-only run of an (already placed) plan, carrying the
    /// placement search's numbers into the report's gauges.
    pub fn simulate_placed(
        &self,
        plan: &PartitionPlan,
        placement: Option<&PlacementReport>,
    ) -> ClusterReport {
        assert!(!self.fleet.is_empty(), "empty fleet");
        let outcome = if self.hot_spares == 0 {
            run_schedule_traced(
                plan,
                self.fleet.len(),
                &self.host,
                &self.topology,
                &self.trace,
                |d, s| self.shard_seconds(d, s),
            )
        } else {
            // Spares are wired but must not take planned work: the
            // elastic scheduler keeps them out of the queues (growth
            // off for parity with the fixed schedule).
            let config = ElasticConfig {
                hot_spares: self.hot_spares,
                scale_watermark: None,
                max_growth: 0,
                slo: None,
            };
            run_elastic_schedule_traced(
                plan,
                self.active_devices(),
                &self.host,
                &self.topology,
                &FaultPlan::none(),
                config,
                &self.trace,
                |d, s| self.shard_seconds(d % self.fleet.len(), s),
            )
            .expect("a healthy fleet cannot run out of cards")
            .schedule
        };
        self.report(plan, outcome, placement)
    }

    /// Replay a plan's compute and reductions with and without the
    /// compute-overlapped collective pipeline (see
    /// [`crate::fabric::overlap`]); `algo` None picks the cheapest
    /// collective per tile.
    pub fn overlap_report(
        &self,
        plan: &PartitionPlan,
        algo: Option<ReduceAlgo>,
    ) -> OverlapReport {
        assert!(!self.fleet.is_empty(), "empty fleet");
        pipeline_schedule_traced(plan, &self.topology, algo, &self.trace, &Tracer::off(), |d, s| {
            self.shard_seconds(d, s)
        })
    }

    /// Timing run with injected device deaths: `deaths[d]` is the time
    /// at which fleet device `d` dies (missing / `None` = healthy).
    /// Without hot spares, a dying card's in-flight shard requeues on
    /// a survivor and its queued shards drain via work-stealing; with
    /// spares, the victim's work drains onto a spare instead (the
    /// elastic path). The run errors only when every card is dead with
    /// shards outstanding.
    pub fn simulate_with_failures(
        &self,
        plan: &PartitionPlan,
        deaths: &[Option<f64>],
    ) -> Result<ClusterReport, String> {
        assert!(!self.fleet.is_empty(), "empty fleet");
        if self.hot_spares > 0 {
            let faults = FaultPlan {
                faults: deaths
                    .iter()
                    .enumerate()
                    .filter_map(|(card, d)| d.map(|seconds| Fault::Kill { card, seconds }))
                    .collect(),
            };
            let config = ElasticConfig {
                hot_spares: self.hot_spares,
                scale_watermark: None,
                max_growth: 0,
                slo: None,
            };
            let outcome = run_elastic_schedule_traced(
                plan,
                self.active_devices(),
                &self.host,
                &self.topology,
                &faults,
                config,
                &self.trace,
                |d, s| self.shard_seconds(d % self.fleet.len(), s),
            )?;
            return Ok(self.report(plan, outcome.schedule, None));
        }
        let outcome = run_schedule_with_failures_traced(
            plan,
            self.fleet.len(),
            &self.host,
            &self.topology,
            deaths,
            &self.trace,
            |d, s| self.shard_seconds(d, s),
        )?;
        Ok(self.report(plan, outcome, None))
    }

    /// Replay a plan against an explicit [`FaultPlan`] with the sim's
    /// hot spares and growth watermark: the fabric heals around dead
    /// cards, their queued and in-flight shards drain onto the
    /// contention-cheapest spare, and the fabric grows when the
    /// queue-depth watermark is crossed. Cards grown past the fleet
    /// reuse the fleet's designs cyclically (`card % fleet.len()`).
    pub fn simulate_elastic(
        &self,
        plan: &PartitionPlan,
        faults: &FaultPlan,
    ) -> Result<ElasticOutcome, String> {
        assert!(!self.fleet.is_empty(), "empty fleet");
        let _scope = crate::trace::profile::scope("cluster.simulate_elastic");
        let config = ElasticConfig {
            hot_spares: self.hot_spares,
            scale_watermark: self.scale_watermark,
            slo: self.slo,
            ..ElasticConfig::default()
        };
        run_elastic_schedule_traced(
            plan,
            self.active_devices(),
            &self.host,
            &self.topology,
            faults,
            config,
            &self.trace,
            |d, s| self.shard_seconds(d % self.fleet.len(), s),
        )
    }

    /// Timing + functional run (small sizes only).
    pub fn simulate_functional(
        &self,
        plan: &PartitionPlan,
        a: &Matrix,
        b: &Matrix,
    ) -> (ClusterReport, Matrix) {
        let report = self.simulate(plan);
        let c = plan.execute_functional(a, b);
        (report, c)
    }

    /// Candidate plans for this fleet's **active** card count (spares
    /// are excluded from placement), one per strategy family, dropping
    /// candidates whose shard set duplicates an earlier one (e.g.
    /// `Summa25D { c: 1 }` degenerates to the 2D grid).
    pub fn candidate_plans(&self, m: u64, k: u64, n: u64) -> Vec<PartitionPlan> {
        let n_dev = self.active_devices() as u64;
        let strategies = [
            PartitionStrategy::Row1D { devices: n_dev },
            PartitionStrategy::auto_grid2d(n_dev),
            PartitionStrategy::auto_summa25d(n_dev),
        ];
        let mut plans: Vec<PartitionPlan> = Vec::new();
        for s in strategies {
            if let Ok(p) = PartitionPlan::new(s, m, k, n) {
                if !plans.iter().any(|q| q.shards == p.shards) {
                    plans.push(p);
                }
            }
        }
        plans
    }

    /// Place (under the sim's [`PlacementStrategy`]) and simulate every
    /// candidate once, returning the placed plan with the smallest
    /// makespan (ties go to fewer bytes moved) together with its
    /// report, so callers need not re-run the schedule.
    pub fn plan_and_report(
        &self,
        m: u64,
        k: u64,
        n: u64,
    ) -> Option<(PartitionPlan, ClusterReport)> {
        self.candidate_plans(m, k, n)
            .into_iter()
            .map(|p| {
                let (placed, placement) = self.place_plan(&p);
                let r = self.simulate_placed(&placed, placement.as_ref());
                (placed, r)
            })
            .min_by(|(pa, ra), (pb, rb)| {
                ra.makespan_seconds
                    .total_cmp(&rb.makespan_seconds)
                    .then(pa.total_bytes_moved().cmp(&pb.total_bytes_moved()))
            })
    }

    /// The best plan by simulated makespan (see [`Self::plan_and_report`]).
    pub fn auto_plan(&self, m: u64, k: u64, n: u64) -> Option<PartitionPlan> {
        self.plan_and_report(m, k, n).map(|(p, _)| p)
    }

    /// Build a [`ClusterReport`] from an elastic outcome's schedule:
    /// cards grown past the fleet are reported as `grownN` entries
    /// reusing the fleet's designs cyclically (mirroring
    /// [`Self::simulate_elastic`]'s timing closure). The
    /// elastic-specific gauges stay on the [`ElasticOutcome`].
    pub fn elastic_report(
        &self,
        plan: &PartitionPlan,
        outcome: &ElasticOutcome,
    ) -> ClusterReport {
        self.report(plan, outcome.schedule.clone(), None)
    }

    fn report(
        &self,
        plan: &PartitionPlan,
        outcome: ScheduleOutcome,
        placement: Option<&PlacementReport>,
    ) -> ClusterReport {
        let makespan = outcome.makespan_seconds;
        let per_device: Vec<DeviceReport> = outcome
            .per_device
            .iter()
            .enumerate()
            .map(|(i, t)| {
                // Cards beyond the fleet were attached by watermark
                // growth; they reuse the fleet's designs cyclically.
                let dev = &self.fleet.devices[i % self.fleet.len()];
                let id = if i < self.fleet.len() {
                    dev.id.clone()
                } else {
                    format!("grown{i}")
                };
                DeviceReport {
                    id,
                    shards: t.shards,
                    stolen: t.stolen,
                    lost: t.lost,
                    transfer_seconds: t.transfer_seconds,
                    compute_seconds: t.compute_seconds,
                    card_seconds: t.card_seconds,
                    finish_seconds: t.finish_seconds,
                    utilization: if makespan > 0.0 { t.compute_seconds / makespan } else { 0.0 },
                    peak_gflops: dev.design.peak_gflops(),
                }
            })
            .collect();
        let effective_gflops =
            flop_count(plan.m, plan.n, plan.k) as f64 / makespan.max(f64::MIN_POSITIVE) / 1e9;
        let aggregate_peak_gflops: f64 = (0..per_device.len().max(1))
            .map(|i| self.fleet.devices[i % self.fleet.len()].design.peak_gflops())
            .sum();
        // Hop-pricing the simulated plan is the placed side of the
        // gauge pair; with no search the identity side equals it.
        let placed_hop_bytes = plan.reduction_hop_bytes(&self.topology);
        let (placement_name, identity_hop_bytes, identity_cost, placed_cost, search_seconds) =
            match placement {
                Some(p) => (
                    p.strategy,
                    p.identity_hop_bytes,
                    p.identity_cost_seconds,
                    p.placed_cost_seconds,
                    p.search_seconds,
                ),
                None => ("identity", placed_hop_bytes, 0.0, 0.0, 0.0),
            };
        ClusterReport {
            strategy: plan.strategy.name(),
            topology: self.topology.name(),
            devices: per_device.len(),
            m: plan.m,
            k: plan.k,
            n: plan.n,
            shards: plan.shards.len(),
            steals: outcome.steals,
            retries: outcome.retries,
            reroutes: outcome.reroutes,
            makespan_seconds: makespan,
            effective_gflops,
            aggregate_peak_gflops,
            cluster_efficiency: effective_gflops / aggregate_peak_gflops,
            host_to_device_bytes: plan.host_to_device_bytes,
            device_to_device_bytes: plan.device_to_device_bytes,
            device_to_host_bytes: plan.device_to_host_bytes,
            reduction_seconds: outcome.reduction_seconds,
            reduction_overlap_seconds: outcome.reduction_overlap_seconds,
            link_busy_seconds: outcome.link_busy_seconds,
            max_link_busy_seconds: outcome.max_link_busy_seconds,
            directed_links: outcome.directed_links,
            placement: placement_name,
            placement_identity_hop_bytes: identity_hop_bytes,
            placement_placed_hop_bytes: placed_hop_bytes,
            placement_identity_cost_seconds: identity_cost,
            placement_placed_cost_seconds: placed_cost,
            placement_search_seconds: search_seconds,
            critical_device: outcome.critical_device(),
            per_device,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::matmul_blocked;

    #[test]
    fn homogeneous_fleet_peaks() {
        let f = Fleet::homogeneous(4, "G").unwrap();
        assert_eq!(f.len(), 4);
        // Design G peak is 3260 GFLOPS (Table I).
        assert!((f.aggregate_peak_gflops() - 4.0 * 3260.4).abs() < 4.0);
        assert!(Fleet::homogeneous(2, "A").is_err(), "A failed the fitter");
        assert!(Fleet::homogeneous(2, "Z").is_err());
    }

    #[test]
    fn mixed_fleet_is_heterogeneous() {
        let f = Fleet::mixed_table1(3);
        assert_eq!(f.len(), 3);
        // Highest-peak design first: F (3673 GFLOPS).
        assert!(f.devices[0].id.starts_with('F'), "{}", f.devices[0].id);
        let d0 = f.devices[0].design.blocking.array;
        let d1 = f.devices[1].design.blocking.array;
        assert_ne!(d0, d1, "fleet should mix designs");
    }

    #[test]
    fn single_device_matches_offchip_sim_magnitude() {
        // One card, one shard: makespan = transfer + compute + writeback,
        // so effective GFLOPS sits below but near the single-card sim.
        let sim = ClusterSim::builder(Fleet::homogeneous(1, "G").unwrap()).build();
        let d = 8192;
        let plan = PartitionPlan::new(PartitionStrategy::Row1D { devices: 1 }, d, d, d).unwrap();
        let report = sim.simulate(&plan);
        let solo = OffchipSim::new(sim.fleet.devices[0].design).simulate(d, d, d);
        assert!(report.makespan_seconds > solo.seconds);
        assert!(report.effective_gflops < solo.gflops);
        assert!(report.effective_gflops > 0.5 * solo.gflops, "{}", report.effective_gflops);
    }

    #[test]
    fn two_cards_scale_past_1_8x() {
        let d = 21504;
        let t1 = {
            let sim = ClusterSim::builder(Fleet::homogeneous(1, "G").unwrap()).build();
            let plan =
                PartitionPlan::new(PartitionStrategy::Row1D { devices: 1 }, d, d, d).unwrap();
            sim.simulate(&plan).makespan_seconds
        };
        let sim = ClusterSim::builder(Fleet::homogeneous(2, "G").unwrap()).build();
        let t2 = sim.plan_and_report(d, d, d).unwrap().1.makespan_seconds;
        assert!(t1 / t2 > 1.8, "2-card speedup {:.2}", t1 / t2);
    }

    #[test]
    fn utilization_and_critical_path_reported() {
        let sim = ClusterSim::builder(Fleet::homogeneous(4, "G").unwrap()).build();
        let (_, r) = sim.plan_and_report(21504, 21504, 21504).unwrap();
        assert_eq!(r.per_device.len(), 4);
        assert!(r.critical_device < 4);
        for d in &r.per_device {
            assert!(d.utilization > 0.5 && d.utilization <= 1.0, "{d:?}");
        }
        assert!(r.cluster_efficiency > 0.4 && r.cluster_efficiency < 1.0);
        let text = r.render();
        assert!(text.contains("critical path"));
    }

    #[test]
    fn candidate_plans_dedupe_degenerate_strategies() {
        // 2 devices: Row1D{2}, Grid2D{2,1} and Summa{2,1,1} all carve
        // the same two row bands -> one candidate survives.
        let sim2 = ClusterSim::builder(Fleet::homogeneous(2, "G").unwrap()).build();
        assert_eq!(sim2.candidate_plans(4096, 4096, 4096).len(), 1);
        // 4 devices: Summa{2,2,1} duplicates Grid2D{2,2} -> two.
        let sim4 = ClusterSim::builder(Fleet::homogeneous(4, "G").unwrap()).build();
        assert_eq!(sim4.candidate_plans(4096, 4096, 4096).len(), 2);
        // 8 devices: all three families are genuinely distinct.
        let sim8 = ClusterSim::builder(Fleet::homogeneous(8, "G").unwrap()).build();
        assert_eq!(sim8.candidate_plans(4096, 4096, 4096).len(), 3);
    }

    #[test]
    fn plan_and_report_returns_winning_report() {
        let sim = ClusterSim::builder(Fleet::homogeneous(4, "G").unwrap()).build();
        let (plan, report) = sim.plan_and_report(21504, 21504, 21504).unwrap();
        let direct = sim.simulate(&plan);
        assert_eq!(report.makespan_seconds, direct.makespan_seconds);
        assert_eq!(report.strategy, direct.strategy);
    }

    #[test]
    fn functional_path_bit_exact() {
        let design = OffchipDesign {
            blocking: crate::blocked::Level1Blocking::new(
                crate::systolic::ArraySize::new(4, 4, 2, 2),
                8,
                8,
            ),
            fmax_mhz: 400.0,
            controller_efficiency: 0.97,
        };
        let sim = ClusterSim::builder(Fleet::uniform(3, "mini", design)).build();
        let a = Matrix::random(19, 23, 1);
        let b = Matrix::random(23, 17, 2);
        let plan = sim.auto_plan(19, 23, 17).unwrap();
        let (report, c) = sim.simulate_functional(&plan, &a, &b);
        assert!(report.makespan_seconds > 0.0);
        assert_eq!(c.data, matmul_blocked(&a, &b).data);
    }

    #[test]
    fn topology_changes_the_simulated_makespan() {
        // The same plane-major 2.5D plan: 4-hop congested reductions on
        // a ring, disjoint 2-hop flows on the torus.
        let d = 21504u64;
        let plan = PartitionPlan::new(PartitionStrategy::auto_summa25d(8), d, d, d).unwrap();
        let ring =
            ClusterSim::builder(Fleet::homogeneous(8, "G").unwrap())
                .topology(Topology::ring(8))
                .build();
        let torus = ClusterSim::builder(Fleet::homogeneous(8, "G").unwrap())
            .topology(Topology::torus2d(4, 2))
            .build();
        let rr = ring.simulate(&plan);
        let rt = torus.simulate(&plan);
        assert_eq!(rr.topology, "ring");
        assert_eq!(rt.topology, "torus");
        assert!(rr.makespan_seconds > rt.makespan_seconds, "{rr:?} vs {rt:?}");
        // Multi-hop routing is visible in the link gauges.
        assert!(rr.link_busy_seconds > rt.link_busy_seconds);
        assert!(rr.link_utilization() > 0.0 && rr.link_utilization() <= 1.0);
        assert!(rr.max_link_utilization() >= rr.link_utilization());
        assert!(rr.render().contains("fabric ring"));
    }

    #[test]
    fn plan_and_report_places_reduction_plans() {
        let d = 8192u64;
        let plan = PartitionPlan::new(PartitionStrategy::auto_summa25d(8), d, d, d).unwrap();
        let sim =
            ClusterSim::builder(Fleet::homogeneous(8, "G").unwrap())
                .topology(Topology::ring(8))
                .build();
        // place_plan optimizes reduction-heavy plans strictly on a ring.
        let (placed, rep) = sim.place_plan(&plan);
        let rep = rep.expect("2.5d plan has reduction traffic");
        assert_eq!(rep.strategy, "local-search");
        assert!(
            rep.placed_cost_seconds < rep.identity_cost_seconds,
            "placed {} vs identity {}",
            rep.placed_cost_seconds,
            rep.identity_cost_seconds
        );
        assert_eq!(placed.reduction_hop_bytes(&sim.topology), rep.placed_hop_bytes);
        // The placed schedule's report carries the gauge pair.
        let r = sim.simulate_placed(&placed, Some(&rep));
        assert_eq!(r.placement, "local-search");
        assert!(r.placement_placed_hop_bytes <= r.placement_identity_hop_bytes);
        assert!(r.placement_gain() > 1.0);
        assert!(r.render().contains("placement local-search"));
        // Identity strategy and reduction-free plans skip the search.
        let mut id_sim = sim.clone();
        id_sim.placement = PlacementStrategy::Identity;
        assert!(id_sim.place_plan(&plan).1.is_none());
        let grid = PartitionPlan::new(PartitionStrategy::auto_grid2d(8), d, d, d).unwrap();
        assert!(sim.place_plan(&grid).1.is_none());
        // plan_and_report's winner keeps the gauges coherent whichever
        // candidate wins.
        let (_, win) = sim.plan_and_report(d, d, d).unwrap();
        assert!(win.placement_placed_hop_bytes <= win.placement_identity_hop_bytes);
    }

    #[test]
    fn overlap_report_from_the_sim() {
        let sim = ClusterSim::builder(Fleet::homogeneous(8, "G").unwrap())
            .topology(Topology::ring(8))
            .build();
        let plan = PartitionPlan::new(
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 8 },
            8192,
            8192,
            8192,
        )
        .unwrap();
        let r = sim.overlap_report(&plan, Some(crate::fabric::ReduceAlgo::Direct));
        assert!(r.overlapped_makespan_seconds <= r.barrier_makespan_seconds + 1e-9);
        assert!(r.reduction_seconds > 0.0);
        assert_eq!(r.timelines.len(), 8);
    }

    #[test]
    fn spared_sim_excludes_spares_until_a_death() {
        use crate::cluster::elastic::{FaultPlan, FleetEvent};
        // 4 active design-G cards + 1 hot spare spliced into the fabric.
        let sim = ClusterSim::builder(Fleet::homogeneous(5, "G").unwrap()).spares(1).build();
        assert_eq!(sim.active_devices(), 4);
        assert_eq!(sim.topology.cards, 5);
        // Plans carve over the active cards only; the placement search
        // steps aside (it would move live work onto the spare).
        let plans = sim.candidate_plans(8192, 8192, 8192);
        assert!(plans.iter().all(|p| p.devices <= 4), "{plans:?}");
        // A k-split plan (real reduction traffic) still skips the
        // bijective search while spares are wired.
        let plan = PartitionPlan::new(
            PartitionStrategy::Summa25D { p: 2, q: 1, c: 2 },
            8192,
            8192,
            8192,
        )
        .unwrap();
        assert!(plan.device_to_device_bytes > 0);
        assert!(sim.place_plan(&plan).1.is_none());
        // Healthy: the spare idles through a plain simulate.
        let healthy = sim.simulate(&plan);
        assert_eq!(healthy.per_device[4].shards, 0);
        assert_eq!(healthy.retries, 0);
        // Death: the elastic path drains onto the spare.
        let first = plan.shards.iter().find(|s| s.device == 0).unwrap();
        let t_die = sim.host.seconds_for_bytes(first.input_bytes())
            + 0.5 * sim.shard_seconds(0, first);
        let out = sim.simulate_elastic(&plan, &FaultPlan::kill(0, t_die)).unwrap();
        assert_eq!(out.spare_activations, 1);
        assert_eq!(out.drains_completed, 1);
        assert!(out.schedule.per_device[4].shards >= 1, "{:?}", out.schedule.per_device);
        assert!(out
            .events
            .iter()
            .any(|e| matches!(e, FleetEvent::SpareActivated { spare: 4, replaces: 0, .. })));
        // simulate_with_failures routes through the same drain path
        // and reports the spare's work in the ClusterReport.
        let rep = sim.simulate_with_failures(&plan, &[Some(t_die)]).unwrap();
        assert!(rep.per_device[4].shards >= 1);
        assert_eq!(rep.retries, 1);
    }

    #[test]
    fn shard_padding_times_irregular_extents() {
        let sim = ClusterSim::builder(Fleet::homogeneous(1, "G").unwrap()).build();
        let shard = Shard { device: 0, row0: 0, rows: 700, col0: 0, cols: 900, k0: 0, ks: 333 };
        // Pads to (1024, 1024, 334) for design G's (512, 512, 2) grid.
        let t = sim.shard_seconds(0, &shard);
        let padded = OffchipSim::new(sim.fleet.devices[0].design).simulate(1024, 1024, 334);
        assert_eq!(t, padded.seconds);
    }
}
