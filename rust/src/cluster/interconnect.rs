//! Cluster interconnect: the links that move shards and partials.
//!
//! Two link classes, both modeled with the [`crate::memory::DdrChannel`]
//! idiom (peak rate × controller/protocol efficiency):
//!
//! * **host link** — PCIe Gen3 x8, the 520N's host interface: 8 GT/s ×
//!   8 lanes × 128b/130b ≈ 7.88 GB/s raw, derated by a protocol
//!   efficiency for TLP/flow-control overhead.
//! * **card link** — one QSFP28 100 Gb serial port (the 520N carries
//!   four); partial-C reductions ride it without a host round trip.
//!
//! Each device owns one host link; transfers on different devices
//! proceed in parallel, transfers on one link serialize. The card
//! ports are wired into an explicit multi-hop
//! [`crate::fabric::Topology`] — [`Link::qsfp28_100g`] is the lane
//! model every fabric edge multiplies. The flat [`Interconnect`] pair
//! survives as the legacy all-to-all view for callers that only need
//! link rates.

use crate::memory::DdrChannel;

/// A point-to-point link: peak throughput derated by efficiency.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Peak theoretical throughput in MB/s (10^6 bytes).
    pub peak_mb_s: f64,
    /// Protocol efficiency in (0, 1].
    pub efficiency: f64,
}

impl Link {
    /// PCIe Gen3 x8: 7880 MB/s raw, ~85% effective after TLP overhead.
    pub fn pcie_gen3_x8() -> Self {
        Self { peak_mb_s: 7_880.0, efficiency: 0.85 }
    }

    /// One QSFP28 100 Gb port: 12500 MB/s raw, ~90% after framing.
    pub fn qsfp28_100g() -> Self {
        Self { peak_mb_s: 12_500.0, efficiency: 0.90 }
    }

    pub fn effective_bytes_per_s(&self) -> f64 {
        // Reuse the DDR channel arithmetic so every link in the stack
        // derates identically.
        DdrChannel { peak_mb_s: self.peak_mb_s }.effective_bytes_per_s(self.efficiency)
    }

    /// Seconds to move `bytes` over this link.
    pub fn seconds_for_bytes(&self, bytes: u64) -> f64 {
        DdrChannel { peak_mb_s: self.peak_mb_s }.seconds_for_bytes(self.efficiency, bytes)
    }
}

/// The fleet fabric: per-device host and card links (symmetric).
#[derive(Clone, Copy, Debug)]
pub struct Interconnect {
    pub host: Link,
    pub card: Link,
}

impl Interconnect {
    /// The default 520N cluster fabric: PCIe host links, one QSFP28
    /// card↔card link per device.
    pub fn pcie_cluster() -> Self {
        Self { host: Link::pcie_gen3_x8(), card: Link::qsfp28_100g() }
    }

    pub fn host_seconds(&self, bytes: u64) -> f64 {
        self.host.seconds_for_bytes(bytes)
    }

    pub fn card_seconds(&self, bytes: u64) -> f64 {
        self.card.seconds_for_bytes(bytes)
    }
}

impl Default for Interconnect {
    fn default() -> Self {
        Self::pcie_cluster()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcie_rates() {
        let l = Link::pcie_gen3_x8();
        // ~6.7 GB/s effective.
        let gb_s = l.effective_bytes_per_s() / 1e9;
        assert!((gb_s - 6.698).abs() < 0.01, "{gb_s}");
        // A 1 GiB transfer takes ~0.16 s.
        let t = l.seconds_for_bytes(1 << 30);
        assert!(t > 0.15 && t < 0.17, "{t}");
    }

    #[test]
    fn card_link_faster_than_host() {
        let ic = Interconnect::pcie_cluster();
        let bytes = 256u64 << 20;
        assert!(ic.card_seconds(bytes) < ic.host_seconds(bytes));
    }

    #[test]
    fn host_link_slower_than_one_ddr_channel() {
        // The ordering the whole cluster layer leans on: PCIe feeds at
        // less than half a DDR4 channel, so shard transfers matter.
        let pcie = Link::pcie_gen3_x8().effective_bytes_per_s();
        let ddr = DdrChannel::ddr4_2400().effective_bytes_per_s(0.97);
        assert!(pcie < ddr / 2.0, "pcie {pcie} vs ddr {ddr}");
    }

    #[test]
    fn seconds_scale_linearly() {
        let l = Link::qsfp28_100g();
        let one = l.seconds_for_bytes(1_000_000);
        let ten = l.seconds_for_bytes(10_000_000);
        assert!((ten / one - 10.0).abs() < 1e-9);
    }
}
