//! Multi-FPGA cluster layer: shard one large GEMM across a fleet of
//! simulated 520N cards.
//!
//! One Stratix 10 saturates at ~3 TFLOPS (Table I); serving
//! production-scale traffic means going multi-device. This subsystem
//! models that next level of the hierarchy with the same
//! simulate-first discipline as the single-card stack:
//!
//! * [`partition`] — 1D-row, 2D-grid and communication-avoiding
//!   2.5D/SUMMA partitioners (Shen et al.; de Fine Licht et al.) that
//!   emit per-device sub-GEMM [`Shard`]s plus the host↔device and
//!   device↔device transfer volumes each plan implies.
//! * [`interconnect`] — PCIe Gen3 x8 host links and the QSFP28 lane
//!   model, in the [`crate::memory::DdrChannel`] peak-times-efficiency
//!   idiom. The card↔card wiring itself is a
//!   [`crate::fabric::Topology`] (ring / torus / mesh / fat-tree under
//!   the 4-port budget) with congestion-aware multi-hop routing.
//! * [`scheduler`] — per-device work queues with work-stealing and
//!   double-buffered overlap of shard DMA with compute; every shard is
//!   timed by the device's [`crate::blocked::OffchipSim`], and the
//!   partial-C reductions route over the fabric's shortest live paths
//!   (the outcome reports link utilization and how much reduction time
//!   hid under compute). Device deaths are survivable: an in-flight
//!   shard bumps its attempt counter and requeues on a surviving card,
//!   a dead card's queue drains through the stealing path, and the
//!   fabric heals around its downed links
//!   ([`scheduler::run_schedule_with_failures`]).
//! * [`elastic`] — the fleet is no longer fixed at service start:
//!   [`FleetController`] keeps hot-spare cards wired into the topology
//!   but out of placement, drains a dying card's queued and in-flight
//!   shards onto the contention-cheapest spare (a placement search
//!   over the amended device→card map, after the fabric heals), and
//!   grows the fabric with [`crate::fabric::Topology::attach_card`]
//!   when the queue-depth watermark is crossed — re-carving the
//!   not-yet-started k-slices over the grown fleet. Faults (kill /
//!   slow-link / spike-queue) are explicit, seedable [`FaultPlan`]
//!   data, replayed deterministically by the chaos harness.
//! * [`fleet`] — N (possibly heterogeneous Table-I) designs and the
//!   [`ClusterSim`] front door producing a [`ClusterReport`]
//!   (per-device utilization, critical path, effective TFLOPS vs.
//!   N·single-card peak). The sim carries a
//!   [`crate::placement::PlacementStrategy`]: `plan_and_report` maps
//!   every candidate plan's devices onto cards with the topology-aware
//!   placement optimizer before simulating it, so reduction-heavy 2.5D
//!   plans stop paying identity-layout prices on narrow fabrics.
//!
//! Functional mode reduces k-split partial C tiles by *continuing* the
//! blocked accumulation in ascending-k order, so sharded results are
//! bit-exact against [`crate::gemm::matmul_blocked`].

pub mod elastic;
pub mod fleet;
pub mod interconnect;
pub mod partition;
pub mod scheduler;

pub use elastic::{
    run_elastic_schedule, run_elastic_schedule_traced, ElasticConfig, ElasticOutcome, Fault,
    FaultPlan, FleetController, FleetEvent,
};
pub use crate::observe::slo::SloPolicy;
pub use fleet::{ClusterDevice, ClusterReport, ClusterSim, ClusterSimBuilder, DeviceReport, Fleet};
pub use interconnect::{Interconnect, Link};
pub use partition::{PartitionPlan, PartitionStrategy, Shard};
pub use scheduler::{
    run_schedule, run_schedule_traced, run_schedule_with_failures,
    run_schedule_with_failures_traced, DeviceTrace, ScheduleOutcome,
};
