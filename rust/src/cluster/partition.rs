//! GEMM partitioners: split one (m × k) · (k × n) problem into
//! per-device sub-GEMM shards, plus the transfer volumes each plan
//! implies.
//!
//! Three families, in increasing communication sophistication:
//!
//! * **1D row** — each device owns a band of C rows; B is broadcast to
//!   every device. Trivially correct, but the broadcast makes
//!   host↔device traffic grow linearly with the device count.
//! * **2D grid** — a p × q device grid; device (i, j) owns C tile
//!   (i, j), receiving one A row-band (replicated across its grid row)
//!   and one B column-band (replicated down its grid column). This is
//!   the classical SUMMA owner-computes layout.
//! * **2.5D / SUMMA-c** — additionally splits the contraction dimension
//!   into c slices (the "replication depth" of communication-avoiding
//!   GEMM, de Fine Licht et al.): device (i, j, l) computes a *partial*
//!   C tile over k slice l, and the c partials per tile are reduced over
//!   the card↔card fabric. Replication trades a smaller host broadcast
//!   for device↔device reduction traffic — the communication lower
//!   bound favours it once the fleet outgrows a near-square grid.
//!   Device placement is **plane-major**: the c replication layers map
//!   to contiguous p × q planes of the fleet (the stacked-plane layout
//!   of 2.5D algorithms), so the cross-plane reduction is real
//!   multi-hop traffic on narrow fabrics — and
//!   [`PartitionPlan::reduction_hop_bytes`] prices a plan against a
//!   concrete [`crate::fabric::Topology`] (the same 2.5D plan scores
//!   lower on a torus than on a ring).
//!
//! Every partitioner handles extents that do not divide evenly: the
//! remainder is spread one row/column/slice at a time over the leading
//! parts, and empty parts are dropped.
//!
//! Functional semantics: [`PartitionPlan::execute_functional`] reduces
//! k-split partials by *continuing* the blocked accumulation
//! ([`crate::gemm::matmul_blocked_into`]) in ascending-k order, so the
//! sharded result is **bit-exact** against the dense
//! [`crate::gemm::matmul_blocked`] for every strategy and shape.

use crate::gemm::{matmul_blocked_into, Matrix};

const F32_BYTES: u64 = 4;

/// How to carve the iteration space over the fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Split C rows over all devices; broadcast B.
    Row1D { devices: u64 },
    /// p × q owner-computes grid.
    Grid2D { p: u64, q: u64 },
    /// p × q grid with the contraction split into c slices.
    Summa25D { p: u64, q: u64, c: u64 },
}

impl PartitionStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            PartitionStrategy::Row1D { .. } => "1d-row",
            PartitionStrategy::Grid2D { .. } => "2d-grid",
            PartitionStrategy::Summa25D { .. } => "2.5d-summa",
        }
    }

    /// Devices the strategy wants (actual plans may use fewer when an
    /// extent is smaller than the grid).
    pub fn device_count(&self) -> u64 {
        match *self {
            PartitionStrategy::Row1D { devices } => devices,
            PartitionStrategy::Grid2D { p, q } => p * q,
            PartitionStrategy::Summa25D { p, q, c } => p * q * c,
        }
    }

    /// Near-square p × q factorization of `devices`.
    pub fn auto_grid2d(devices: u64) -> Self {
        let (p, q) = near_square(devices);
        PartitionStrategy::Grid2D { p, q }
    }

    /// 2.5D with the replication depth c chosen as the divisor of
    /// `devices` closest to (but not above) its cube root, the grid
    /// near-square over the rest.
    pub fn auto_summa25d(devices: u64) -> Self {
        // f64::cbrt is not correctly rounded; nudge up so perfect
        // cubes (8 -> 2, 27 -> 3) never floor one short.
        let mut cbrt = (devices as f64).cbrt().floor() as u64;
        while (cbrt + 1).pow(3) <= devices {
            cbrt += 1;
        }
        let c = (1..=cbrt.max(1)).rev().find(|c| devices % c == 0).unwrap_or(1);
        let (p, q) = near_square(devices / c);
        PartitionStrategy::Summa25D { p, q, c }
    }
}

/// Factor n as p·q with p ≥ q and p − q minimal.
fn near_square(n: u64) -> (u64, u64) {
    let n = n.max(1);
    let root = (n as f64).sqrt().floor() as u64;
    let q = (1..=root.max(1)).rev().find(|d| n % d == 0).unwrap_or(1);
    (n / q, q)
}

/// Split `extent` into at most `parts` contiguous nonempty (offset, len)
/// ranges, spreading the remainder over the leading parts.
pub fn split_extent(extent: u64, parts: u64) -> Vec<(u64, u64)> {
    let parts = parts.max(1).min(extent.max(1));
    let base = extent / parts;
    let rem = extent % parts;
    let mut out = Vec::with_capacity(parts as usize);
    let mut off = 0;
    for i in 0..parts {
        let len = base + u64::from(i < rem);
        if len == 0 {
            break;
        }
        out.push((off, len));
        off += len;
    }
    out
}

/// One device's sub-GEMM: C tile rows × cols over k range
/// [k0, k0 + ks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// Initial device assignment (the scheduler may steal it away).
    pub device: usize,
    pub row0: u64,
    pub rows: u64,
    pub col0: u64,
    pub cols: u64,
    pub k0: u64,
    pub ks: u64,
}

impl Shard {
    /// MAC-based FLOP count of the sub-GEMM (2mnk convention — partial
    /// products count multiply+add even for the paper's 2k−1 formula,
    /// which only applies to a full contraction).
    pub fn flops(&self) -> u64 {
        2 * self.rows * self.cols * self.ks
    }

    pub fn a_bytes(&self) -> u64 {
        self.rows * self.ks * F32_BYTES
    }

    pub fn b_bytes(&self) -> u64 {
        self.ks * self.cols * F32_BYTES
    }

    pub fn c_bytes(&self) -> u64 {
        self.rows * self.cols * F32_BYTES
    }

    /// Host→device bytes this shard pulls before computing.
    pub fn input_bytes(&self) -> u64 {
        self.a_bytes() + self.b_bytes()
    }

    /// C-tile identity (shards of one tile share it; k-split plans have
    /// several shards per tile).
    pub fn tile(&self) -> (u64, u64) {
        (self.row0, self.col0)
    }
}

/// A complete sharding of one GEMM, with its communication bill.
#[derive(Clone, Debug)]
pub struct PartitionPlan {
    pub strategy: PartitionStrategy,
    pub m: u64,
    pub k: u64,
    pub n: u64,
    /// Distinct devices actually used.
    pub devices: usize,
    pub shards: Vec<Shard>,
    /// A and B traffic into the fleet (replication included).
    pub host_to_device_bytes: u64,
    /// Partial-C reduction traffic over the card↔card link.
    pub device_to_device_bytes: u64,
    /// C written back to the host.
    pub device_to_host_bytes: u64,
}

impl PartitionPlan {
    pub fn new(strategy: PartitionStrategy, m: u64, k: u64, n: u64) -> Result<Self, String> {
        if m == 0 || k == 0 || n == 0 {
            return Err(format!("degenerate GEMM ({m} x {k}) * ({k} x {n})"));
        }
        let shards = match strategy {
            PartitionStrategy::Row1D { devices } => {
                if devices == 0 {
                    return Err("Row1D needs at least one device".into());
                }
                split_extent(m, devices)
                    .into_iter()
                    .enumerate()
                    .map(|(d, (row0, rows))| Shard {
                        device: d,
                        row0,
                        rows,
                        col0: 0,
                        cols: n,
                        k0: 0,
                        ks: k,
                    })
                    .collect::<Vec<_>>()
            }
            PartitionStrategy::Grid2D { p, q } => {
                if p == 0 || q == 0 {
                    return Err("Grid2D needs a nonempty grid".into());
                }
                let rows = split_extent(m, p);
                let cols = split_extent(n, q);
                let q_used = cols.len();
                let mut out = Vec::with_capacity(rows.len() * cols.len());
                for (i, &(row0, r)) in rows.iter().enumerate() {
                    for (j, &(col0, cl)) in cols.iter().enumerate() {
                        out.push(Shard {
                            device: i * q_used + j,
                            row0,
                            rows: r,
                            col0,
                            cols: cl,
                            k0: 0,
                            ks: k,
                        });
                    }
                }
                out
            }
            PartitionStrategy::Summa25D { p, q, c } => {
                if p == 0 || q == 0 || c == 0 {
                    return Err("Summa25D needs a nonempty grid".into());
                }
                let rows = split_extent(m, p);
                let cols = split_extent(n, q);
                let slices = split_extent(k, c);
                let (p_used, q_used) = (rows.len(), cols.len());
                let mut out = Vec::with_capacity(p_used * q_used * slices.len());
                for (i, &(row0, r)) in rows.iter().enumerate() {
                    for (j, &(col0, cl)) in cols.iter().enumerate() {
                        for (l, &(k0, ks)) in slices.iter().enumerate() {
                            out.push(Shard {
                                // Plane-major: slice l owns the l-th
                                // contiguous p × q plane of devices.
                                device: (l * p_used + i) * q_used + j,
                                row0,
                                rows: r,
                                col0,
                                cols: cl,
                                k0,
                                ks,
                            });
                        }
                    }
                }
                out
            }
        };

        let devices = shards.iter().map(|s| s.device).max().map_or(0, |d| d + 1);
        let host_to_device_bytes = shards.iter().map(Shard::input_bytes).sum();
        // Reduction traffic: every non-first shard of a k-split tile
        // ships one partial C tile over the card link.
        let mut tiles: std::collections::BTreeMap<(u64, u64), (u64, u64)> =
            std::collections::BTreeMap::new();
        for s in &shards {
            let e = tiles.entry(s.tile()).or_insert((0, s.c_bytes()));
            e.0 += 1;
        }
        let device_to_device_bytes = tiles.values().map(|&(cnt, bytes)| (cnt - 1) * bytes).sum();
        let device_to_host_bytes = m * n * F32_BYTES;

        let plan = Self {
            strategy,
            m,
            k,
            n,
            devices,
            shards,
            host_to_device_bytes,
            device_to_device_bytes,
            device_to_host_bytes,
        };
        plan.validate_cover()?;
        Ok(plan)
    }

    /// All bytes the plan moves across any link.
    pub fn total_bytes_moved(&self) -> u64 {
        self.host_to_device_bytes + self.device_to_device_bytes + self.device_to_host_bytes
    }

    /// Total FLOP over all shards (2mnk convention).
    pub fn total_flops(&self) -> u64 {
        self.shards.iter().map(Shard::flops).sum()
    }

    /// Arithmetic intensity of the plan in FLOP per byte moved — the
    /// figure of merit communication-avoiding blocking maximizes.
    pub fn flops_per_byte(&self) -> f64 {
        self.total_flops() as f64 / self.total_bytes_moved() as f64
    }

    /// Per tile, the k range start and planned device of its k-first
    /// shard — the reduction home. Every consumer of home identity
    /// (the scheduler's reduction bookkeeping, the overlap replay,
    /// hop-aware pricing) derives it from this one map so they cannot
    /// diverge.
    pub fn tile_homes(&self) -> std::collections::BTreeMap<(u64, u64), (u64, usize)> {
        let mut homes: std::collections::BTreeMap<(u64, u64), (u64, usize)> = Default::default();
        for s in &self.shards {
            let e = homes.entry(s.tile()).or_insert((s.k0, s.device));
            if s.k0 < e.0 {
                *e = (s.k0, s.device);
            }
        }
        homes
    }

    /// The plan's partial-C reduction sends with devices folded onto
    /// `cards` physical cards the way the scheduler folds them
    /// (`device % cards`): one `(src, dst, bytes)` triple per non-home
    /// partial, in plan order. Hop pricing and the placement optimizer
    /// ([`crate::placement`]) both consume this list so their view of
    /// the reduction traffic cannot diverge.
    pub fn reduction_sends(&self, cards: usize) -> Vec<(usize, usize, u64)> {
        let cards = cards.max(1);
        let homes = self.tile_homes();
        let mut sends = Vec::new();
        for s in &self.shards {
            let (min_k0, home) = homes[&s.tile()];
            if s.k0 == min_k0 {
                continue;
            }
            sends.push((s.device % cards, home % cards, s.c_bytes()));
        }
        sends
    }

    /// Reduction traffic weighted by fabric distance: Σ over non-home
    /// partials of `c_bytes · hops(sender, home)`, with plan devices
    /// folded onto the fabric's cards the way the scheduler folds them
    /// (`device % cards`). This is the hop-aware half of plan pricing:
    /// `device_to_device_bytes` is topology-blind, this is not — the
    /// same 2.5D plan scores lower on a torus than on a ring.
    pub fn reduction_hop_bytes(&self, topology: &crate::fabric::Topology) -> u64 {
        let mut total = 0u64;
        for (src, dst, bytes) in self.reduction_sends(topology.cards) {
            if src == dst {
                continue;
            }
            total += bytes * u64::from(topology.hops(src, dst).unwrap_or(0));
        }
        total
    }

    /// Re-carve the same GEMM for a fleet that grew (or shrank) to
    /// `devices` cards, staying in the plan's strategy family: a 1D
    /// carve stays 1D, grids and 2.5D carves re-run their `auto_*`
    /// factorization at the new count. The elastic-fleet controller
    /// applies this at the next k-slice boundary after a
    /// [`crate::fabric::Topology::attach_card`] growth — in-flight
    /// shards finish under the old carve, subsequent work uses the new
    /// one — and functional results stay bit-exact either way (every
    /// carve reduces k-ascending per tile).
    pub fn recarve(&self, devices: u64) -> Result<Self, String> {
        if devices == 0 {
            return Err("cannot recarve onto zero devices".into());
        }
        let strategy = match self.strategy {
            PartitionStrategy::Row1D { .. } => PartitionStrategy::Row1D { devices },
            PartitionStrategy::Grid2D { .. } => PartitionStrategy::auto_grid2d(devices),
            PartitionStrategy::Summa25D { .. } => PartitionStrategy::auto_summa25d(devices),
        };
        Self::new(strategy, self.m, self.k, self.n)
    }

    /// Check the shards tile the m × n × k iteration space exactly:
    /// every C tile's k ranges are contiguous [0, k), the tiles cover
    /// the C plane without overlap, and the FLOP total matches.
    pub fn validate_cover(&self) -> Result<(), String> {
        let mut tiles: std::collections::BTreeMap<(u64, u64), Vec<&Shard>> = Default::default();
        for s in &self.shards {
            if s.row0 + s.rows > self.m || s.col0 + s.cols > self.n || s.k0 + s.ks > self.k {
                return Err(format!("shard out of bounds: {s:?}"));
            }
            if s.rows == 0 || s.cols == 0 || s.ks == 0 {
                return Err(format!("empty shard: {s:?}"));
            }
            tiles.entry(s.tile()).or_default().push(s);
        }
        let mut area = 0u64;
        for ((row0, col0), group) in &tiles {
            let (rows, cols) = (group[0].rows, group[0].cols);
            if group.iter().any(|s| s.rows != rows || s.cols != cols) {
                return Err(format!("tile ({row0},{col0}) has inconsistent extents"));
            }
            area += rows * cols;
            let mut ranges: Vec<(u64, u64)> = group.iter().map(|s| (s.k0, s.ks)).collect();
            ranges.sort_unstable();
            let mut next = 0;
            for (k0, ks) in ranges {
                if k0 != next {
                    return Err(format!(
                        "tile ({row0},{col0}): k gap/overlap at {next} (saw k0={k0})"
                    ));
                }
                next = k0 + ks;
            }
            if next != self.k {
                return Err(format!("tile ({row0},{col0}): k covered to {next} of {}", self.k));
            }
        }
        if area != self.m * self.n {
            return Err(format!("tiles cover {area} of {} C elements", self.m * self.n));
        }
        if self.total_flops() != 2 * self.m * self.n * self.k {
            return Err("shard FLOP total does not match the dense problem".into());
        }
        Ok(())
    }

    /// Execute the plan functionally: per C tile, fold its k-shards in
    /// ascending-k order through the accumulating blocked GEMM. The
    /// result is bit-exact against `matmul_blocked(a, b)` because every
    /// output element sees the same scalar addition chain (k strictly
    /// ascending) regardless of how the plan carved the space.
    pub fn execute_functional(&self, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!((a.rows as u64, a.cols as u64), (self.m, self.k), "A shape");
        assert_eq!((b.rows as u64, b.cols as u64), (self.k, self.n), "B shape");
        let mut tiles: std::collections::BTreeMap<(u64, u64), Vec<&Shard>> = Default::default();
        for s in &self.shards {
            tiles.entry(s.tile()).or_default().push(s);
        }
        let mut c = Matrix::zeros(self.m as usize, self.n as usize);
        for ((row0, col0), mut group) in tiles {
            group.sort_by_key(|s| s.k0);
            let (rows, cols) = (group[0].rows as usize, group[0].cols as usize);
            let mut acc = Matrix::zeros(rows, cols);
            for s in group {
                let a_blk = a.submatrix(s.row0 as usize, s.k0 as usize, rows, s.ks as usize);
                let b_blk = b.submatrix(s.k0 as usize, s.col0 as usize, s.ks as usize, cols);
                matmul_blocked_into(&mut acc, &a_blk, &b_blk);
            }
            c.write_submatrix(row0 as usize, col0 as usize, &acc);
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::{matmul_blocked, Matrix};

    #[test]
    fn split_extent_spreads_remainder() {
        assert_eq!(split_extent(10, 3), vec![(0, 4), (4, 3), (7, 3)]);
        assert_eq!(split_extent(4, 4), vec![(0, 1), (1, 1), (2, 1), (3, 1)]);
        // More parts than extent: empty parts dropped.
        assert_eq!(split_extent(2, 5), vec![(0, 1), (1, 1)]);
        assert_eq!(split_extent(7, 1), vec![(0, 7)]);
    }

    #[test]
    fn near_square_factorizations() {
        assert_eq!(near_square(8), (4, 2));
        assert_eq!(near_square(16), (4, 4));
        assert_eq!(near_square(7), (7, 1));
        assert_eq!(near_square(12), (4, 3));
        assert_eq!(near_square(1), (1, 1));
    }

    #[test]
    fn auto_strategies() {
        assert_eq!(PartitionStrategy::auto_grid2d(6), PartitionStrategy::Grid2D { p: 3, q: 2 });
        assert_eq!(
            PartitionStrategy::auto_summa25d(8),
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 }
        );
        assert_eq!(
            PartitionStrategy::auto_summa25d(4),
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 1 }
        );
    }

    #[test]
    fn row1d_byte_accounting() {
        // 8 devices, square d: A once + B broadcast 8x.
        let d = 1024u64;
        let plan = PartitionPlan::new(PartitionStrategy::Row1D { devices: 8 }, d, d, d).unwrap();
        assert_eq!(plan.devices, 8);
        assert_eq!(plan.host_to_device_bytes, (d * d + 8 * d * d) * 4);
        assert_eq!(plan.device_to_device_bytes, 0);
        assert_eq!(plan.device_to_host_bytes, d * d * 4);
    }

    #[test]
    fn summa25d_moves_fewer_bytes_than_row1d_at_scale() {
        // The acceptance-criterion comparison, at the paper's largest
        // problem: a d=21504 square GEMM on 8 cards.
        let d = 21504u64;
        let row = PartitionPlan::new(PartitionStrategy::Row1D { devices: 8 }, d, d, d).unwrap();
        let summa =
            PartitionPlan::new(PartitionStrategy::auto_summa25d(8), d, d, d).unwrap();
        // 1D moves (1+8+1)·d² floats; 2.5D (2+2+1+1)·d².
        assert_eq!(row.total_bytes_moved(), 10 * d * d * 4);
        assert_eq!(summa.total_bytes_moved(), 6 * d * d * 4);
        assert!(summa.flops_per_byte() > 1.6 * row.flops_per_byte());
    }

    #[test]
    fn grid2d_replication_volumes() {
        let (m, k, n) = (100u64, 60, 80);
        let plan =
            PartitionPlan::new(PartitionStrategy::Grid2D { p: 2, q: 3 }, m, k, n).unwrap();
        assert_eq!(plan.devices, 6);
        // A replicated q times, B replicated p times.
        assert_eq!(plan.host_to_device_bytes, (3 * m * k + 2 * k * n) * 4);
        assert_eq!(plan.device_to_device_bytes, 0);
    }

    #[test]
    fn summa_reduction_traffic() {
        let (m, k, n) = (64u64, 90, 32);
        let plan = PartitionPlan::new(
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 3 },
            m,
            k,
            n,
        )
        .unwrap();
        assert_eq!(plan.devices, 12);
        // Each of the 4 tiles has 3 partials -> 2 sends of its C bytes.
        assert_eq!(plan.device_to_device_bytes, 2 * m * n * 4);
    }

    #[test]
    fn summa_plane_major_and_hop_pricing() {
        use crate::fabric::Topology;
        let plan = PartitionPlan::new(
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 },
            64,
            64,
            64,
        )
        .unwrap();
        // Plane-major: slice 0 occupies devices 0..4, slice 1 devices 4..8.
        for s in &plan.shards {
            if s.k0 == 0 {
                assert!(s.device < 4, "{s:?}");
            } else {
                assert!(s.device >= 4, "{s:?}");
            }
        }
        // The cross-plane combine is 2 hops on a (4,2) torus, 4 on a ring.
        let ring = plan.reduction_hop_bytes(&Topology::ring(8));
        let torus = plan.reduction_hop_bytes(&Topology::torus2d(4, 2));
        assert!(torus < ring, "torus {torus} vs ring {ring}");
        assert_eq!(torus * 2, ring);
        // Plans without a k split ship nothing.
        let grid =
            PartitionPlan::new(PartitionStrategy::Grid2D { p: 2, q: 2 }, 64, 64, 64).unwrap();
        assert_eq!(grid.reduction_hop_bytes(&Topology::ring(4)), 0);
    }

    #[test]
    fn reduction_sends_match_byte_accounting() {
        let plan = PartitionPlan::new(
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 3 },
            64,
            90,
            32,
        )
        .unwrap();
        // One send per non-home partial, summing to the plan's d2d bill.
        let sends = plan.reduction_sends(plan.devices);
        assert_eq!(sends.len(), 8, "4 tiles x 2 non-home partials");
        let total: u64 = sends.iter().map(|&(_, _, b)| b).sum();
        assert_eq!(total, plan.device_to_device_bytes);
        // Folding onto fewer cards keeps the list (sends may become
        // local, but the accounting stays per-partial).
        assert_eq!(plan.reduction_sends(4).len(), 8);
        // Plans without a k split ship nothing.
        let grid =
            PartitionPlan::new(PartitionStrategy::Grid2D { p: 2, q: 2 }, 64, 64, 64).unwrap();
        assert!(grid.reduction_sends(4).is_empty());
    }

    #[test]
    fn recarve_scales_the_strategy_family() {
        let plan =
            PartitionPlan::new(PartitionStrategy::auto_summa25d(8), 128, 128, 128).unwrap();
        let grown = plan.recarve(12).unwrap();
        assert_eq!(grown.strategy, PartitionStrategy::auto_summa25d(12));
        assert_eq!((grown.m, grown.k, grown.n), (plan.m, plan.k, plan.n));
        grown.validate_cover().unwrap();
        // Functional results agree bit-for-bit across the re-carve.
        let a = Matrix::random(128, 128, 51);
        let b = Matrix::random(128, 128, 52);
        assert_eq!(plan.execute_functional(&a, &b).data, grown.execute_functional(&a, &b).data);
        // 1D stays 1D; zero devices is a clean error.
        let row = PartitionPlan::new(PartitionStrategy::Row1D { devices: 4 }, 64, 64, 64).unwrap();
        assert_eq!(row.recarve(6).unwrap().strategy, PartitionStrategy::Row1D { devices: 6 });
        assert!(row.recarve(0).is_err());
    }

    #[test]
    fn uneven_shapes_cover_exactly() {
        for strategy in [
            PartitionStrategy::Row1D { devices: 3 },
            PartitionStrategy::Grid2D { p: 3, q: 2 },
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 3 },
        ] {
            let plan = PartitionPlan::new(strategy, 17, 23, 11).unwrap();
            plan.validate_cover().unwrap();
        }
    }

    #[test]
    fn more_devices_than_rows_degrades_gracefully() {
        let plan = PartitionPlan::new(PartitionStrategy::Row1D { devices: 16 }, 5, 8, 8).unwrap();
        assert_eq!(plan.shards.len(), 5);
        assert_eq!(plan.devices, 5);
        plan.validate_cover().unwrap();
    }

    #[test]
    fn functional_bit_exact_all_strategies() {
        let (m, k, n) = (33usize, 57, 21);
        let a = Matrix::random(m, k, 91);
        let b = Matrix::random(k, n, 92);
        let dense = matmul_blocked(&a, &b);
        for strategy in [
            PartitionStrategy::Row1D { devices: 4 },
            PartitionStrategy::Grid2D { p: 2, q: 3 },
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 4 },
        ] {
            let plan =
                PartitionPlan::new(strategy, m as u64, k as u64, n as u64).unwrap();
            let got = plan.execute_functional(&a, &b);
            assert_eq!(got.data, dense.data, "{}", strategy.name());
        }
    }
}
