//! Per-device work queues, work-stealing, and transfer/compute overlap.
//!
//! The scheduler replays a [`super::partition::PartitionPlan`] against a
//! fleet at event granularity (one event per shard), tracking four
//! resources per device:
//!
//! * the **host link, inbound** (shard DMA in) and **outbound** (C
//!   tiles back to the host) — PCIe is full duplex, so the two
//!   directions are independent resources,
//! * the **compute engine** (the device's `OffchipSim` timing),
//! * the **card fabric** (partial-C reduction sends, 2.5D plans only):
//!   every send routes over the [`crate::fabric::Topology`]'s shortest
//!   live path under the circuit-style contention model of
//!   [`crate::fabric::FabricState`] — multi-hop flows reserve every
//!   link they cross, so reduction traffic congests on narrow
//!   topologies and parallelizes on wide ones.
//!
//! Transfers are double-buffered: the DMA for a device's task *i* may
//! start as soon as the link is free and task *i−2*'s compute has
//! drained its staging buffer — so transfer of the next shard overlaps
//! compute of the current one, exactly like the on-chip Phase-2 overlap
//! of §V one level up the hierarchy. Reduction sends ride the DMA
//! engines, not the compute engine, so a tile whose partials are done
//! reduces *while* the remaining shards compute; the outcome reports
//! how much of the reduction time was hidden that way.
//!
//! Work-stealing: a device with an empty queue takes a shard from the
//! back of the longest remaining queue. With heterogeneous fleets this
//! lets a fast Table-I design finish its band and absorb a slow
//! neighbour's tail instead of idling.
//!
//! Determinism: every scheduling choice (next DMA device, steal
//! victim, retry survivor) breaks ties explicitly on the device id,
//! never on iterator order — so the same plan replays to a
//! bit-identical [`ScheduleOutcome`], including plans relabeled by the
//! placement optimizer ([`crate::placement`]) with a fixed seed.
//!
//! Failure/retry: [`run_schedule_with_failures`] takes a per-device
//! death time. A dying card loses whatever shard is in flight (DMA or
//! compute crossing the death instant); the shard's attempt counter is
//! bumped and it requeues on the least-loaded survivor, while the dead
//! card's still-queued shards drain through the normal stealing path.
//! The fabric heals too: a dead card's links go down, its routes are
//! invalidated, and reduction steps in flight across it re-route
//! around the gap (a ring heals into a line). A tile whose reduction
//! home died re-homes onto the next device that completes one of its
//! partials; completed results are treated as checkpointed (they
//! already reached DDR/host). If the death cuts the fabric between a
//! sender and its home, the partial bounces via the host at 2× PCIe
//! cost. Only when *every* device is dead with shards outstanding does
//! the schedule fail.

//!
//! Observability: the `_traced` entry points thread a
//! [`crate::trace::Tracer`] through the event loop — DMA, compute,
//! reduction-circuit and writeback spans on per-card lanes, per-link
//! circuit spans, steal and death instants — at zero cost when the
//! sink is off (the plain entry points pass [`crate::trace::Tracer::off`]).

use super::interconnect::Link;
use super::partition::{PartitionPlan, Shard};
use crate::fabric::{FabricState, Topology};
use crate::trace::{Category, Tracer, Track};
use std::collections::{BTreeMap, VecDeque};

/// Per-device accounting after a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeviceTrace {
    /// Shards this device computed.
    pub shards: usize,
    /// Of those, how many it stole from another queue.
    pub stolen: usize,
    /// Shards lost in flight when this device died (each one retried
    /// elsewhere).
    pub lost: usize,
    /// Host-link busy seconds, both directions (shard DMA + C writeback).
    pub transfer_seconds: f64,
    /// Compute-engine busy seconds.
    pub compute_seconds: f64,
    /// Fabric circuit-hold seconds of this device's reduction sends.
    pub card_seconds: f64,
    /// When this device went fully idle.
    pub finish_seconds: f64,
}

/// The schedule of one plan over one fleet.
#[derive(Clone, Debug)]
pub struct ScheduleOutcome {
    pub per_device: Vec<DeviceTrace>,
    /// End-to-end latency: last resource to go idle.
    pub makespan_seconds: f64,
    /// Total steals across the fleet.
    pub steals: usize,
    /// Shard attempts lost to device deaths and re-executed elsewhere.
    pub retries: usize,
    /// Reduction steps that aborted on a dying transit card and took a
    /// detour over the healed fabric.
    pub reroutes: usize,
    /// Total circuit-hold seconds of the partial-C reduction steps.
    pub reduction_seconds: f64,
    /// Of those, seconds during which at least one device was
    /// computing — the overlap the DMA-engine pipelining buys.
    pub reduction_overlap_seconds: f64,
    /// Busy seconds summed over all directed fabric links.
    pub link_busy_seconds: f64,
    /// Busy seconds of the hottest directed fabric link.
    pub max_link_busy_seconds: f64,
    /// Directed fabric links (two per cable/trunk).
    pub directed_links: usize,
}

impl ScheduleOutcome {
    /// The device bounding the critical path.
    pub fn critical_device(&self) -> usize {
        self.per_device
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| a.finish_seconds.total_cmp(&b.finish_seconds))
            .map_or(0, |(i, _)| i)
    }

    /// Fraction of the reduction time hidden under compute (0 when the
    /// plan has no reduction traffic).
    pub fn reduction_overlap_fraction(&self) -> f64 {
        if self.reduction_seconds <= 0.0 {
            return 0.0;
        }
        self.reduction_overlap_seconds / self.reduction_seconds
    }
}

struct TileState {
    remaining: usize,
    /// Device holding the reduction state (the plan assigns the k-first
    /// shard's device; deaths may re-home it).
    home: usize,
    /// When all partials (and the home compute) are in place.
    ready: f64,
    c_bytes: u64,
}

/// Seconds of `sends` overlapping the union of `compute` intervals
/// (shared with the elastic scheduler in [`super::elastic`]).
pub(crate) fn overlap_seconds(mut compute: Vec<(f64, f64)>, sends: &[(f64, f64)]) -> f64 {
    compute.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut merged: Vec<(f64, f64)> = Vec::new();
    for (s, e) in compute {
        match merged.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => merged.push((s, e)),
        }
    }
    sends
        .iter()
        .map(|&(s, e)| {
            merged.iter().map(|&(cs, ce)| (e.min(ce) - s.max(cs)).max(0.0)).sum::<f64>()
        })
        .sum()
}

/// Run `plan` over `ndev` healthy devices whose per-shard compute time
/// is given by `compute_seconds(device, shard)`, with reductions routed
/// over `topology`.
pub fn run_schedule(
    plan: &PartitionPlan,
    ndev: usize,
    host: &Link,
    topology: &Topology,
    compute_seconds: impl Fn(usize, &Shard) -> f64,
) -> ScheduleOutcome {
    run_schedule_traced(plan, ndev, host, topology, &Tracer::off(), compute_seconds)
}

/// As [`run_schedule`], recording spans into `tracer`.
pub fn run_schedule_traced(
    plan: &PartitionPlan,
    ndev: usize,
    host: &Link,
    topology: &Topology,
    tracer: &Tracer,
    compute_seconds: impl Fn(usize, &Shard) -> f64,
) -> ScheduleOutcome {
    run_schedule_with_failures_traced(plan, ndev, host, topology, &[], tracer, compute_seconds)
        .expect("a healthy fleet cannot run out of devices")
}

/// As [`run_schedule`], with injected device deaths: `deaths[d]` is the
/// simulated time at which device `d` dies (missing / `None` = healthy).
/// A dying device loses its in-flight shard — the shard's attempt
/// counter is bumped and it requeues on the least-loaded survivor —
/// and takes no further work; its queued shards migrate via stealing
/// and the fabric routes around its downed links. Errors only when
/// every device is dead with shards outstanding.
pub fn run_schedule_with_failures(
    plan: &PartitionPlan,
    ndev: usize,
    host: &Link,
    topology: &Topology,
    deaths: &[Option<f64>],
    compute_seconds: impl Fn(usize, &Shard) -> f64,
) -> Result<ScheduleOutcome, String> {
    run_schedule_with_failures_traced(
        plan,
        ndev,
        host,
        topology,
        deaths,
        &Tracer::off(),
        compute_seconds,
    )
}

/// As [`run_schedule_with_failures`], recording spans into `tracer`.
pub fn run_schedule_with_failures_traced(
    plan: &PartitionPlan,
    ndev: usize,
    host: &Link,
    topology: &Topology,
    deaths: &[Option<f64>],
    tracer: &Tracer,
    compute_seconds: impl Fn(usize, &Shard) -> f64,
) -> Result<ScheduleOutcome, String> {
    assert!(ndev > 0, "empty fleet");
    assert_eq!(topology.cards, ndev, "fabric must wire exactly the fleet's cards");
    let death = |d: usize| deaths.get(d).copied().flatten();
    let mut queues: Vec<VecDeque<Shard>> = vec![VecDeque::new(); ndev];
    for s in &plan.shards {
        queues[s.device % ndev].push_back(*s);
    }

    let mut fabric = FabricState::new(topology.clone());
    let mut link_free = vec![0.0f64; ndev];
    let mut out_free = vec![0.0f64; ndev];
    let mut card_free = vec![0.0f64; ndev];
    let mut compute_free = vec![0.0f64; ndev];
    let mut compute_ends: Vec<Vec<f64>> = vec![Vec::new(); ndev];
    let mut traces = vec![DeviceTrace::default(); ndev];
    let mut dead = vec![false; ndev];
    let mut steals = 0usize;
    let mut retries = 0usize;
    let mut compute_intervals: Vec<(f64, f64)> = Vec::with_capacity(plan.shards.len());
    let mut send_intervals: Vec<(f64, f64)> = Vec::new();
    // Per-shard attempt counters, keyed by the shard's unique
    // (tile, k-range) identity within the plan.
    let mut attempts: BTreeMap<(u64, u64, u64), usize> = BTreeMap::new();

    // The plan statically pins each tile's reduction home to the device
    // assigned its k-first shard (see `PartitionPlan::tile_homes`).
    let homes = plan.tile_homes();
    let mut tiles: BTreeMap<(u64, u64), TileState> = BTreeMap::new();
    for s in &plan.shards {
        let t = tiles.entry(s.tile()).or_insert_with(|| TileState {
            remaining: 0,
            home: homes[&s.tile()].1 % ndev,
            ready: 0.0,
            c_bytes: s.c_bytes(),
        });
        t.remaining += 1;
    }

    let mut pending: usize = plan.shards.len();
    while pending > 0 {
        // The live device whose host link frees first (strictly before
        // its death) starts the next DMA. Every tie here and below
        // breaks on the device id explicitly, so identical inputs —
        // including placement-permuted plans re-run with the same seed
        // — replay to bit-identical outcomes instead of leaning on
        // iterator tie-break accidents.
        let d = (0..ndev)
            .filter(|&d| !dead[d] && death(d).map_or(true, |td| link_free[d] < td))
            .min_by(|&a, &b| link_free[a].total_cmp(&link_free[b]).then(a.cmp(&b)));
        let Some(d) = d else {
            return Err(format!(
                "all {ndev} device(s) dead with {pending} shard(s) outstanding"
            ));
        };
        // Own queue first; otherwise steal from the longest queue
        // (ties toward the lowest device id).
        let (shard, stolen_from) = match queues[d].pop_front() {
            Some(s) => (s, None),
            None => {
                let victim = (0..ndev)
                    .filter(|&v| !queues[v].is_empty())
                    .max_by(|&a, &b| queues[a].len().cmp(&queues[b].len()).then(b.cmp(&a)))
                    .expect("pending > 0 implies a nonempty queue");
                (queues[victim].pop_back().unwrap(), Some(victim))
            }
        };
        pending -= 1;
        if stolen_from.is_some() {
            steals += 1;
            traces[d].stolen += 1;
        }

        // Double-buffered staging: task i waits for task i-2's compute.
        let i = traces[d].shards;
        let gate = if i >= 2 { compute_ends[d][i - 2] } else { 0.0 };
        let xfer = host.seconds_for_bytes(shard.input_bytes());
        let t_start = link_free[d].max(gate);
        let t_end = t_start + xfer;

        let comp = compute_seconds(d, &shard);
        let c_start = compute_free[d].max(t_end);
        let c_end = c_start + comp;

        if let Some(v) = stolen_from {
            tracer.instant(
                Track::CardCompute(d),
                Category::Steal,
                || format!("steal r{} k{} <- card{v}", shard.row0, shard.k0),
                t_start,
            );
        }

        if let Some(td) = death(d) {
            if c_end > td {
                // The device dies with this shard in flight: charge the
                // busy time actually spent, freeze the device at its
                // death instant, down its fabric links, and retry the
                // shard on a survivor.
                dead[d] = true;
                fabric.kill(d);
                traces[d].lost += 1;
                traces[d].transfer_seconds += (td.min(t_end) - t_start).max(0.0);
                traces[d].compute_seconds += (td - c_start).clamp(0.0, comp);
                tracer.instant(Track::Control, Category::Drain, || format!("death card {d}"), td);
                if td.min(t_end) > t_start {
                    tracer.span(
                        Track::CardDma(d),
                        Category::Host,
                        || format!("dma r{} c{} k{} (lost)", shard.row0, shard.col0, shard.k0),
                        t_start,
                        td.min(t_end),
                    );
                }
                if td > c_start {
                    tracer.span(
                        Track::CardCompute(d),
                        Category::Compute,
                        || format!("shard r{} c{} k{} (lost)", shard.row0, shard.col0, shard.k0),
                        c_start,
                        td,
                    );
                }
                link_free[d] = td;
                compute_free[d] = compute_free[d].min(td);
                retries += 1;
                let key = (shard.row0, shard.col0, shard.k0);
                let tries = attempts.entry(key).or_insert(1);
                *tries += 1;
                if *tries > ndev + 1 {
                    return Err(format!("shard {key:?} failed {tries} times"));
                }
                let survivor = (0..ndev)
                    .filter(|&v| !dead[v] && death(v).map_or(true, |tv| link_free[v] < tv))
                    .min_by_key(|&v| (queues[v].len(), v));
                match survivor {
                    Some(v) => {
                        queues[v].push_back(shard);
                        pending += 1;
                    }
                    None => {
                        return Err(format!(
                            "all {ndev} device(s) dead with {} shard(s) outstanding",
                            pending + 1
                        ))
                    }
                }
                continue;
            }
        }

        link_free[d] = t_end;
        traces[d].transfer_seconds += xfer;
        compute_free[d] = c_end;
        compute_ends[d].push(c_end);
        traces[d].compute_seconds += comp;
        traces[d].shards += 1;
        compute_intervals.push((c_start, c_end));
        tracer.span(
            Track::CardDma(d),
            Category::Host,
            || format!("dma r{} c{} k{}", shard.row0, shard.col0, shard.k0),
            t_start,
            t_end,
        );
        tracer.span(
            Track::CardCompute(d),
            Category::Compute,
            || format!("shard r{} c{} k{}", shard.row0, shard.col0, shard.k0),
            c_start,
            c_end,
        );
        // Shard latency (DMA start to compute end): the same gauge the
        // elastic path feeds the observatory's sliding windows.
        tracer.counter("shard_latency_s", c_end, c_end - t_start);

        // Tile bookkeeping: fabric reductions and the final writeback.
        let tile = tiles.get_mut(&shard.tile()).unwrap();
        tile.remaining -= 1;
        let home_doomed =
            dead[tile.home] || death(tile.home).map_or(false, |td| td <= c_end);
        if home_doomed && tile.home != d {
            // The reduction home died: re-home the tile to this device
            // (its partial stays local; earlier arrivals are treated as
            // checkpointed and re-served from the survivors' copies).
            tile.home = d;
        }
        if d == tile.home {
            tile.ready = tile.ready.max(c_end);
        } else {
            let home = tile.home;
            match fabric.send_with_deaths(d, home, tile.c_bytes, c_end, deaths) {
                Some((s_start, s_end)) => {
                    traces[d].card_seconds += s_end - s_start;
                    card_free[d] = card_free[d].max(s_end);
                    send_intervals.push((s_start, s_end));
                    tile.ready = tile.ready.max(s_end);
                    tracer.span(
                        Track::CardFabric(d),
                        Category::Fabric,
                        || format!("reduce r{} c{} -> card{home}", shard.row0, shard.col0),
                        s_start,
                        s_end,
                    );
                    if tracer.is_recording() {
                        if let Some(path) = fabric.route_nodes(d, home) {
                            for w in path.windows(2) {
                                tracer.span(
                                    Track::Link(w[0], w[1]),
                                    Category::Fabric,
                                    || format!("circuit card{d} -> card{home}"),
                                    s_start,
                                    s_end,
                                );
                            }
                        }
                    }
                }
                None => {
                    // Fabric partitioned between sender and home: the
                    // partial bounces via the host (PCIe up + down),
                    // serialized with this device's other reduction
                    // sends so concurrent bounces cannot double-book
                    // its DMA engine.
                    let bounce = 2.0 * host.seconds_for_bytes(tile.c_bytes);
                    let s_start = card_free[d].max(c_end);
                    let s_end = s_start + bounce;
                    traces[d].card_seconds += bounce;
                    card_free[d] = s_end;
                    send_intervals.push((s_start, s_end));
                    tile.ready = tile.ready.max(s_end);
                    tracer.span(
                        Track::CardFabric(d),
                        Category::Host,
                        || format!("bounce r{} c{} via host", shard.row0, shard.col0),
                        s_start,
                        s_end,
                    );
                }
            }
        }
        if tile.remaining == 0 {
            let mut home = tile.home;
            let wb = host.seconds_for_bytes(tile.c_bytes);
            // The reduction home may already be dead, or would die with
            // this writeback in flight: completed partials are
            // checkpointed, so the device finishing the tile inherits
            // the writeback instead (keeping dead cards frozen at their
            // death instant).
            let doomed = dead[home]
                || death(home).map_or(false, |td| out_free[home].max(tile.ready) + wb > td);
            if home != d && doomed {
                home = d;
            }
            let wb_start = out_free[home].max(tile.ready);
            out_free[home] = wb_start + wb;
            traces[home].transfer_seconds += wb;
            tracer.span(
                Track::CardWriteback(home),
                Category::Host,
                || format!("writeback tile r{} c{}", shard.row0, shard.col0),
                wb_start,
                wb_start + wb,
            );
        }
    }

    let mut makespan = 0.0f64;
    for d in 0..ndev {
        let finish = link_free[d].max(out_free[d]).max(compute_free[d]).max(card_free[d]);
        traces[d].finish_seconds = finish;
        makespan = makespan.max(finish);
    }
    let reduction_seconds: f64 = send_intervals.iter().map(|&(s, e)| e - s).sum();
    let reduction_overlap_seconds = overlap_seconds(compute_intervals, &send_intervals);
    Ok(ScheduleOutcome {
        per_device: traces,
        makespan_seconds: makespan,
        steals,
        retries,
        reroutes: fabric.reroutes,
        reduction_seconds,
        reduction_overlap_seconds,
        link_busy_seconds: fabric.busy_seconds_total(),
        max_link_busy_seconds: fabric.max_busy_seconds(),
        directed_links: fabric.directed_links(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::PartitionStrategy;

    fn plan(strategy: PartitionStrategy, d: u64) -> PartitionPlan {
        PartitionPlan::new(strategy, d, d, d).unwrap()
    }

    fn host() -> Link {
        Link::pcie_gen3_x8()
    }

    /// Fixed compute rate: seconds proportional to shard FLOPs.
    fn flat_rate(_: usize, s: &Shard) -> f64 {
        s.flops() as f64 / 3.0e12
    }

    #[test]
    fn two_devices_nearly_halve_makespan() {
        let p1 = plan(PartitionStrategy::Row1D { devices: 1 }, 8192);
        let p2 = plan(PartitionStrategy::Row1D { devices: 2 }, 8192);
        let t1 =
            run_schedule(&p1, 1, &host(), &Topology::auto(1), flat_rate).makespan_seconds;
        let t2 =
            run_schedule(&p2, 2, &host(), &Topology::auto(2), flat_rate).makespan_seconds;
        assert!(t1 / t2 > 1.8, "speedup {}", t1 / t2);
    }

    #[test]
    fn transfer_overlaps_compute() {
        // With many shards per device, the makespan must sit well below
        // the serial sum of transfer + compute.
        let p = plan(PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 }, 8192);
        let out = run_schedule(&p, 2, &host(), &Topology::auto(2), flat_rate);
        for t in &out.per_device {
            let serial = t.transfer_seconds + t.compute_seconds + t.card_seconds;
            assert!(t.finish_seconds < serial, "{t:?}");
        }
    }

    #[test]
    fn idle_device_steals() {
        // 4 shards all pre-assigned to device 0 of a 2-device fleet:
        // device 1 must steal some of them.
        let mut p = plan(PartitionStrategy::Row1D { devices: 4 }, 4096);
        for s in &mut p.shards {
            s.device = 0;
        }
        let out = run_schedule(&p, 2, &host(), &Topology::auto(2), flat_rate);
        assert!(out.steals > 0);
        assert!(out.per_device[1].shards > 0);
        assert_eq!(out.per_device[0].shards + out.per_device[1].shards, 4);
    }

    #[test]
    fn heterogeneous_fleet_balances_by_stealing() {
        // Device 1 computes 3x faster and compute dominates transfers:
        // the double-buffer gate throttles the slow device's DMA, the
        // fast device drains its own queue and then steals the tail.
        let p = plan(PartitionStrategy::Row1D { devices: 8 }, 8192);
        let out = run_schedule(&p, 2, &host(), &Topology::auto(2), |d, s| {
            let slow = s.flops() as f64 / 1.0e12;
            if d == 1 {
                slow / 3.0
            } else {
                slow
            }
        });
        assert!(
            out.per_device[1].shards > out.per_device[0].shards,
            "fast {} vs slow {}",
            out.per_device[1].shards,
            out.per_device[0].shards
        );
    }

    #[test]
    fn failed_shard_retries_on_survivor() {
        // 2 shards, one per device. Device 0 dies mid-compute of its
        // shard: the shard must re-execute on device 1.
        let p = plan(PartitionStrategy::Row1D { devices: 2 }, 4096);
        let dma = host().seconds_for_bytes(p.shards[0].input_bytes());
        let deaths = [Some(dma + 0.5), None];
        let out =
            run_schedule_with_failures(&p, 2, &host(), &Topology::auto(2), &deaths, |_, _| 1.0)
                .unwrap();
        assert_eq!(out.retries, 1);
        assert_eq!(out.per_device[0].shards, 0);
        assert_eq!(out.per_device[0].lost, 1);
        assert_eq!(out.per_device[1].shards, 2);
        assert_eq!(out.per_device[1].lost, 0);
        // The dead device's busy time is truncated at its death.
        assert!(out.per_device[0].finish_seconds <= dma + 0.5 + 1e-12);
        // Healthy baseline is faster than the single-survivor rerun.
        let healthy = run_schedule(&p, 2, &host(), &Topology::auto(2), |_, _| 1.0);
        assert_eq!(healthy.retries, 0);
        assert!(out.makespan_seconds > healthy.makespan_seconds);
    }

    #[test]
    fn dead_device_queue_drains_via_stealing() {
        // Device 0 dead from t=0 never starts work; its whole queue is
        // stolen by device 1 with zero lost attempts.
        let p = plan(PartitionStrategy::Row1D { devices: 4 }, 4096);
        let out = run_schedule_with_failures(
            &p,
            2,
            &host(),
            &Topology::auto(2),
            &[Some(0.0), None],
            flat_rate,
        )
        .unwrap();
        assert_eq!(out.retries, 0);
        assert_eq!(out.per_device[0].shards, 0);
        assert_eq!(out.per_device[1].shards, 4);
        assert!(out.per_device[1].stolen >= 2, "{out:?}");
    }

    #[test]
    fn all_devices_dead_is_a_clean_error() {
        let p = plan(PartitionStrategy::Row1D { devices: 2 }, 2048);
        let err = run_schedule_with_failures(
            &p,
            2,
            &host(),
            &Topology::auto(2),
            &[Some(0.0), Some(0.0)],
            flat_rate,
        )
        .unwrap_err();
        assert!(err.contains("dead"), "{err}");
    }

    #[test]
    fn no_deaths_matches_plain_schedule() {
        let p = plan(PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 }, 8192);
        let topo = Topology::auto(8);
        let a = run_schedule(&p, 8, &host(), &topo, flat_rate);
        let b =
            run_schedule_with_failures(&p, 8, &host(), &topo, &[None; 8], flat_rate).unwrap();
        assert_eq!(a.makespan_seconds, b.makespan_seconds);
        assert_eq!(a.steals, b.steals);
        assert_eq!(b.retries, 0);
        assert_eq!(b.reroutes, 0);
    }

    #[test]
    fn repeated_runs_are_bit_identical() {
        // The tie-breaks are explicit (device id), so two replays of
        // the same schedule agree to the last bit.
        let p = plan(PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 }, 8192);
        let topo = Topology::ring(8);
        let a = run_schedule(&p, 8, &host(), &topo, flat_rate);
        let b = run_schedule(&p, 8, &host(), &topo, flat_rate);
        assert_eq!(a.makespan_seconds.to_bits(), b.makespan_seconds.to_bits());
        assert_eq!(a.steals, b.steals);
        assert_eq!(a.reduction_seconds.to_bits(), b.reduction_seconds.to_bits());
        assert_eq!(a.link_busy_seconds.to_bits(), b.link_busy_seconds.to_bits());
        for (x, y) in a.per_device.iter().zip(&b.per_device) {
            assert_eq!(x.shards, y.shards);
            assert_eq!(x.stolen, y.stolen);
            assert_eq!(x.transfer_seconds.to_bits(), y.transfer_seconds.to_bits());
            assert_eq!(x.compute_seconds.to_bits(), y.compute_seconds.to_bits());
            assert_eq!(x.finish_seconds.to_bits(), y.finish_seconds.to_bits());
        }
    }

    #[test]
    fn makespan_includes_reduction_and_writeback() {
        let p = plan(PartitionStrategy::Summa25D { p: 1, q: 1, c: 2 }, 2048);
        let out = run_schedule(&p, 2, &host(), &Topology::auto(2), flat_rate);
        // The non-home device must have shipped one partial.
        let card: f64 = out.per_device.iter().map(|t| t.card_seconds).sum();
        assert!(card > 0.0);
        assert!(out.reduction_seconds > 0.0);
        assert!(out.link_busy_seconds > 0.0);
        // Makespan covers the home device's final writeback.
        let crit = out.critical_device();
        assert!(out.makespan_seconds >= out.per_device[crit].finish_seconds);
    }

    #[test]
    fn reductions_route_multi_hop_and_congest() {
        // Plane-major 2.5D on a ring: the cross-plane partials are
        // multi-hop flows, so the same plan finishes later than on the
        // all-1-hop full mesh built from the same card count.
        let p = plan(PartitionStrategy::Summa25D { p: 2, q: 1, c: 2 }, 8192);
        let ring = run_schedule(&p, 4, &host(), &Topology::ring(4), flat_rate);
        let mesh = run_schedule(&p, 4, &host(), &Topology::full_mesh(4), flat_rate);
        assert!(ring.reduction_seconds > mesh.reduction_seconds, "{ring:?}");
        assert!(ring.makespan_seconds >= mesh.makespan_seconds);
        // Both report link-utilization gauge bases.
        assert!(ring.max_link_busy_seconds > 0.0);
        assert!(ring.directed_links == 8 && mesh.directed_links == 12);
        // Overlap gauge stays within [0, reduction_seconds].
        assert!(ring.reduction_overlap_seconds >= 0.0);
        assert!(ring.reduction_overlap_seconds <= ring.reduction_seconds + 1e-12);
        assert!(ring.reduction_overlap_fraction() <= 1.0);
    }

    #[test]
    fn overlap_gauge_sees_hidden_reductions() {
        // Two k-planes, two tiles per card: the first tile's partial
        // ships while the second tile still computes.
        let p = plan(PartitionStrategy::Summa25D { p: 2, q: 1, c: 2 }, 8192);
        let mut q = p.clone();
        // Fold the 4 plan devices onto 2 cards block-wise: plane 0
        // (devices 0, 1) -> card 0, plane 1 (devices 2, 3) -> card 1,
        // so cross-plane partials still cross the fabric.
        for s in &mut q.shards {
            s.device /= 2;
        }
        let out = run_schedule(&q, 2, &host(), &Topology::auto(2), flat_rate);
        assert!(out.reduction_seconds > 0.0);
        assert!(out.reduction_overlap_fraction() > 0.0, "{out:?}");
    }
}
