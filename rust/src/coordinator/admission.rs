//! Admission control for the serving front end: bounded ingress with
//! load-shedding, priority lanes, and per-tenant weighted fair share.
//!
//! The paper's designs peak above 3 TFLOPS, but a serving stack at
//! production scale is decided earlier in the pipe: *which* requests
//! reach the accelerator, in *what order*, and which are turned away
//! while the answer can still be "try elsewhere" instead of a blown
//! deadline. This module is that front door, shared by the threaded
//! [`crate::coordinator::GemmService`] (bounded ingress + shed
//! responses) and the open-loop virtual-time harness in
//! [`crate::coordinator::serve`] (the full pipeline):
//!
//! * **Bounded ingress** — [`IngressQueue`] holds at most
//!   `queue_capacity` jobs; beyond that, arrivals are shed with
//!   [`ShedReason::QueueFull`] unless a strictly lower-priority victim
//!   can be evicted in their place (the priority lanes' point).
//! * **Doomed shedding** — with [`AdmissionPolicy::shed_doomed`], a
//!   request whose *predicted* queue wait already exceeds its deadline
//!   slack is shed at the door ([`ShedReason::Doomed`]): serving it
//!   late would burn fleet time for zero goodput and push every later
//!   request past its own deadline. The prediction is lane-aware —
//!   only backlog in the request's own lane and above counts, because
//!   lower-priority work behind it cannot delay it. This is the lever
//!   that lets the deadline-aware pipeline beat FIFO on goodput under
//!   overload.
//! * **Weighted fair share** — classic deficit round-robin over
//!   per-tenant queues: each visit funds a tenant's deficit counter in
//!   proportion to its weight, and a tenant dispatches only while its
//!   deficit covers the work. Backlogged tenants converge to service
//!   shares proportional to their weights regardless of arrival order.
//! * **Priority lanes** — [`Priority::High`] lanes drain strictly
//!   before [`Priority::Normal`] before [`Priority::Low`]; DRR applies
//!   within a lane.

use std::collections::VecDeque;

/// Number of priority lanes ([`Priority`] variants).
pub const LANES: usize = 3;

/// Request priority: a strict lane ordering (High drains first), not a
/// weight. Within a lane, tenants share via deficit round-robin.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    /// Lane index (0 drains first).
    pub fn lane(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn from_lane(lane: usize) -> Self {
        match lane {
            0 => Priority::High,
            1 => Priority::Normal,
            _ => Priority::Low,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

/// Why a request was turned away at admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShedReason {
    /// Ingress queue at capacity with no lower-priority victim.
    QueueFull,
    /// Predicted queue wait already exceeds the request's deadline
    /// slack — serving it would deliver zero goodput.
    Doomed,
    /// Evicted from the queue by an arriving higher-priority request.
    Evicted,
}

impl ShedReason {
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::Doomed => "doomed",
            ShedReason::Evicted => "evicted",
        }
    }
}

/// Admission knobs, grouped so [`crate::coordinator::ServiceConfig`]
/// carries one sub-struct instead of a growing pile of loose fields.
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionPolicy {
    /// Bounded ingress capacity: jobs queued (or in flight on the
    /// engine) beyond this are shed, never silently enqueued.
    pub queue_capacity: usize,
    /// Shed requests whose predicted wait already exceeds their
    /// deadline slack (off: FIFO semantics — everything admitted runs,
    /// however late).
    pub shed_doomed: bool,
    /// Deadline applied to requests that carry none (seconds from
    /// arrival); None leaves them deadline-free.
    pub default_deadline_s: Option<f64>,
    /// Latency target handed to the batcher: a forming batch closes
    /// when the oldest member's slack runs out instead of waiting out
    /// the fixed window (see [`crate::coordinator::Batcher::close_by`]).
    pub latency_target_s: Option<f64>,
    /// Per-tenant DRR weights; tenants not listed here weigh 1.
    pub tenant_weights: Vec<(String, u32)>,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        Self {
            queue_capacity: 4096,
            shed_doomed: false,
            default_deadline_s: None,
            latency_target_s: None,
            tenant_weights: Vec::new(),
        }
    }
}

impl AdmissionPolicy {
    /// DRR weight for a tenant (1 when unlisted).
    pub fn weight_for(&self, tenant: &str) -> u32 {
        self.tenant_weights
            .iter()
            .find(|(name, _)| name == tenant)
            .map_or(1, |(_, w)| (*w).max(1))
    }

    /// The deadline-aware profile the overload demos run: doomed
    /// shedding on, batches close against the target.
    pub fn deadline_aware(latency_target_s: f64) -> Self {
        Self {
            shed_doomed: true,
            latency_target_s: Some(latency_target_s),
            ..Self::default()
        }
    }
}

/// Per-request admission verdict, attached to every
/// [`crate::coordinator::GemmResponse`] and to the harness records.
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionReport {
    pub tenant: String,
    /// Queue class the request rode (its priority lane).
    pub lane: Priority,
    /// None = admitted; Some = turned away and why.
    pub shed: Option<ShedReason>,
    /// Ingress depth observed at the admission decision.
    pub queue_depth: usize,
    /// `deadline − (queue + host)` seconds at completion — negative
    /// means the deadline was missed; None when the request carried no
    /// deadline (or was shed before execution).
    pub deadline_slack_s: Option<f64>,
}

impl AdmissionReport {
    pub fn admitted(tenant: impl Into<String>, lane: Priority, queue_depth: usize) -> Self {
        Self { tenant: tenant.into(), lane, shed: None, queue_depth, deadline_slack_s: None }
    }

    pub fn rejected(
        tenant: impl Into<String>,
        lane: Priority,
        reason: ShedReason,
        queue_depth: usize,
    ) -> Self {
        Self {
            tenant: tenant.into(),
            lane,
            shed: Some(reason),
            queue_depth,
            deadline_slack_s: None,
        }
    }

    pub fn is_admitted(&self) -> bool {
        self.shed.is_none()
    }
}

/// One queued job in the virtual-time pipeline (the open-loop harness
/// prices work in estimated service seconds; the threaded service uses
/// wall clocks instead).
#[derive(Clone, Debug, PartialEq)]
pub struct QueuedJob {
    pub id: u64,
    /// Index into the tenant table the queue was built with.
    pub tenant: usize,
    /// Priority lane index (see [`Priority::lane`]).
    pub lane: usize,
    /// Arrival instant, seconds.
    pub arrival_s: f64,
    /// Absolute deadline instant; None = no deadline.
    pub deadline_s: Option<f64>,
    /// Estimated cost in seconds of one card's time — compute plus
    /// whatever share of dispatch overhead the caller amortizes in.
    pub service_s: f64,
    /// FLOPs the job carries (goodput accounting).
    pub flops: u64,
    /// Shape key for batching: same-shape neighbours share a dispatch.
    pub shape: (usize, usize, usize),
}

/// Outcome of offering a job to the bounded queue.
#[derive(Clone, Debug, PartialEq)]
pub enum Offer {
    /// Job queued; a lower-priority victim may have been evicted to
    /// make room (the caller records it as shed).
    Admitted { evicted: Option<QueuedJob> },
    Shed(ShedReason),
}

/// Bounded multi-tenant ingress: `LANES` priority lanes × one FIFO per
/// tenant, drained by deficit round-robin within the highest non-empty
/// lane.
#[derive(Clone, Debug)]
pub struct IngressQueue {
    capacity: usize,
    shed_doomed: bool,
    weights: Vec<u32>,
    /// `lanes[lane][tenant]` — arrival order within each queue.
    lanes: Vec<Vec<VecDeque<QueuedJob>>>,
    /// Queued service seconds per lane (doomed prediction is
    /// lane-aware: only same-or-higher-priority backlog delays a job).
    lane_service: [f64; LANES],
    /// DRR deficit per tenant, in service seconds.
    deficit: Vec<f64>,
    cursor: usize,
    depth: usize,
    queued_service_s: f64,
}

impl IngressQueue {
    pub fn new(weights: &[u32], capacity: usize, shed_doomed: bool) -> Self {
        assert!(!weights.is_empty(), "at least one tenant");
        assert!(weights.iter().all(|&w| w > 0), "weights must be positive");
        let tenants = weights.len();
        Self {
            capacity,
            shed_doomed,
            weights: weights.to_vec(),
            lanes: (0..LANES).map(|_| vec![VecDeque::new(); tenants]).collect(),
            lane_service: [0.0; LANES],
            deficit: vec![0.0; tenants],
            cursor: 0,
            depth: 0,
            queued_service_s: 0.0,
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Total estimated service seconds queued.
    pub fn queued_service_s(&self) -> f64 {
        self.queued_service_s
    }

    /// Queue pressure: seconds of backlog per active card — the sample
    /// the serving harness feeds the burn monitor.
    pub fn pressure(&self, servers: usize) -> f64 {
        self.queued_service_s / servers.max(1) as f64
    }

    /// Predicted queue wait for a job entering `lane`: backlog in its
    /// own and higher-priority lanes, per active card. Work in lower
    /// lanes drains after it and cannot delay it.
    pub fn lane_wait_s(&self, lane: usize, servers: usize) -> f64 {
        self.lane_service[..=lane.min(LANES - 1)].iter().sum::<f64>() / servers.max(1) as f64
    }

    /// Offer one job. Sheds when doomed (predicted wait past the
    /// deadline slack) or when the queue is full and no strictly
    /// lower-priority victim exists.
    pub fn offer(&mut self, job: QueuedJob, now: f64, servers: usize) -> Offer {
        assert!(job.tenant < self.weights.len(), "unknown tenant index");
        assert!(job.lane < LANES, "lane out of range");
        if self.shed_doomed {
            if let Some(d) = job.deadline_s {
                let predicted = now + self.lane_wait_s(job.lane, servers) + job.service_s;
                if predicted > d {
                    return Offer::Shed(ShedReason::Doomed);
                }
            }
        }
        let mut evicted = None;
        if self.depth >= self.capacity {
            match self.evict_below(job.lane) {
                Some(victim) => evicted = Some(victim),
                None => return Offer::Shed(ShedReason::QueueFull),
            }
        }
        self.depth += 1;
        self.queued_service_s += job.service_s;
        self.lane_service[job.lane] += job.service_s;
        self.lanes[job.lane][job.tenant].push_back(job);
        Offer::Admitted { evicted }
    }

    /// Evict the youngest job from the lowest-priority non-empty lane
    /// strictly below `lane` (i.e. a *higher* lane index), longest
    /// tenant queue first. None when no such victim exists.
    fn evict_below(&mut self, lane: usize) -> Option<QueuedJob> {
        for l in (lane + 1..LANES).rev() {
            if let Some(t) = (0..self.weights.len())
                .filter(|&t| !self.lanes[l][t].is_empty())
                .max_by_key(|&t| self.lanes[l][t].len())
            {
                let victim = self.lanes[l][t].pop_back().expect("non-empty");
                self.depth -= 1;
                self.queued_service_s -= victim.service_s;
                self.lane_service[l] -= victim.service_s;
                return Some(victim);
            }
        }
        None
    }

    /// The oldest queued job (by arrival), across all lanes and
    /// tenants — the member whose slack decides when a forming batch
    /// must close.
    pub fn oldest(&self) -> Option<&QueuedJob> {
        self.lanes
            .iter()
            .flatten()
            .filter_map(|q| q.front())
            .min_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s))
    }

    /// Does any tenant queue hold a full same-shape batch at its head?
    /// (If so there is nothing to wait for — dispatch immediately.)
    pub fn has_full_batch(&self, max_batch: usize) -> bool {
        self.lanes.iter().flatten().any(|q| {
            match q.front() {
                Some(head) => {
                    q.iter().take(max_batch).take_while(|j| j.shape == head.shape).count()
                        >= max_batch
                }
                None => false,
            }
        })
    }

    /// Pop the next batch under deficit round-robin: the highest
    /// non-empty lane is scanned round-robin; each visit funds the
    /// tenant's deficit by `quantum × weight`, and the first tenant
    /// whose deficit covers its head job dispatches its same-shape
    /// head run (up to `max_batch`, while the deficit lasts). Empty
    /// result only when the queue is empty.
    pub fn next_batch(&mut self, max_batch: usize) -> Vec<QueuedJob> {
        assert!(max_batch >= 1);
        if self.depth == 0 {
            return Vec::new();
        }
        let tenants = self.weights.len();
        for lane in 0..LANES {
            if self.lanes[lane].iter().all(|q| q.is_empty()) {
                continue;
            }
            // Quantum = the cheapest head job in the lane: one full
            // round always funds at least that queue, so the scan
            // terminates, and shares stay weight-proportional because
            // every tenant is funded the same number of rounds.
            let quantum = self.lanes[lane]
                .iter()
                .filter_map(|q| q.front())
                .map(|j| j.service_s)
                .fold(f64::INFINITY, f64::min)
                .max(1e-9);
            loop {
                for _ in 0..tenants {
                    let t = self.cursor % tenants;
                    self.cursor += 1;
                    if self.lanes[lane][t].is_empty() {
                        // Classic DRR: an idle tenant's credit resets —
                        // fairness applies to backlogged tenants only.
                        self.deficit[t] = 0.0;
                        continue;
                    }
                    self.deficit[t] += quantum * self.weights[t] as f64;
                    let head_cost = self.lanes[lane][t].front().expect("non-empty").service_s;
                    if self.deficit[t] + 1e-12 < head_cost {
                        continue;
                    }
                    let shape = self.lanes[lane][t].front().expect("non-empty").shape;
                    let mut batch = Vec::new();
                    while batch.len() < max_batch {
                        match self.lanes[lane][t].front() {
                            Some(j)
                                if j.shape == shape
                                    && (batch.is_empty()
                                        || self.deficit[t] + 1e-12 >= j.service_s) =>
                            {
                                let j = self.lanes[lane][t].pop_front().expect("non-empty");
                                self.deficit[t] -= j.service_s;
                                self.depth -= 1;
                                self.queued_service_s -= j.service_s;
                                self.lane_service[lane] -= j.service_s;
                                batch.push(j);
                            }
                            _ => break,
                        }
                    }
                    if self.lanes[lane][t].is_empty() {
                        self.deficit[t] = 0.0;
                    }
                    return batch;
                }
            }
        }
        unreachable!("depth > 0 implies a non-empty lane");
    }

    /// Put a killed server's in-flight batch back at the front of its
    /// queues (order preserved) — the chaos path's no-job-lost
    /// guarantee.
    pub fn requeue_front(&mut self, jobs: Vec<QueuedJob>) {
        for job in jobs.into_iter().rev() {
            self.depth += 1;
            self.queued_service_s += job.service_s;
            self.lane_service[job.lane] += job.service_s;
            self.lanes[job.lane][job.tenant].push_front(job);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, tenant: usize, lane: usize, arrival: f64) -> QueuedJob {
        QueuedJob {
            id,
            tenant,
            lane,
            arrival_s: arrival,
            deadline_s: None,
            service_s: 0.01,
            flops: 1000,
            shape: (64, 64, 64),
        }
    }

    #[test]
    fn bounded_queue_sheds_at_capacity() {
        let mut q = IngressQueue::new(&[1], 2, false);
        assert!(matches!(q.offer(job(0, 0, 1, 0.0), 0.0, 1), Offer::Admitted { evicted: None }));
        assert!(matches!(q.offer(job(1, 0, 1, 0.1), 0.1, 1), Offer::Admitted { evicted: None }));
        assert_eq!(q.offer(job(2, 0, 1, 0.2), 0.2, 1), Offer::Shed(ShedReason::QueueFull));
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn high_priority_evicts_low_when_full() {
        let mut q = IngressQueue::new(&[1], 2, false);
        q.offer(job(0, 0, 2, 0.0), 0.0, 1);
        q.offer(job(1, 0, 2, 0.1), 0.1, 1);
        // A High arrival evicts the youngest Low job instead of being
        // shed itself.
        match q.offer(job(2, 0, 0, 0.2), 0.2, 1) {
            Offer::Admitted { evicted: Some(v) } => assert_eq!(v.id, 1),
            other => panic!("expected eviction, got {other:?}"),
        }
        assert_eq!(q.depth(), 2);
        // But a Low arrival cannot evict anything at its own level.
        assert_eq!(q.offer(job(3, 0, 2, 0.3), 0.3, 1), Offer::Shed(ShedReason::QueueFull));
    }

    #[test]
    fn doomed_requests_are_shed_at_the_door() {
        let mut q = IngressQueue::new(&[1], 64, true);
        // 10 jobs × 10 ms backlog on one server = 100 ms wait.
        for i in 0..10 {
            q.offer(job(i, 0, 1, 0.0), 0.0, 1);
        }
        let mut doomed = job(10, 0, 1, 0.0);
        doomed.deadline_s = Some(0.05); // 50 ms deadline < 100 ms wait
        assert_eq!(q.offer(doomed, 0.0, 1), Offer::Shed(ShedReason::Doomed));
        let mut viable = job(11, 0, 1, 0.0);
        viable.deadline_s = Some(0.5);
        assert!(matches!(q.offer(viable, 0.0, 1), Offer::Admitted { .. }));
        // More servers shrink the predicted wait: the same deadline
        // admits on a 4-card fleet.
        let mut q4 = IngressQueue::new(&[1], 64, true);
        for i in 0..10 {
            q4.offer(job(i, 0, 1, 0.0), 0.0, 4);
        }
        let mut tight = job(10, 0, 1, 0.0);
        tight.deadline_s = Some(0.05);
        assert!(matches!(q4.offer(tight, 0.0, 4), Offer::Admitted { .. }));
    }

    #[test]
    fn doomed_prediction_is_lane_aware() {
        let mut q = IngressQueue::new(&[1], 256, true);
        // 20 Low jobs: 0.2 s of backlog, all of it behind the High lane.
        for i in 0..20 {
            q.offer(job(i, 0, 2, 0.0), 0.0, 1);
        }
        // A High arrival with a tight deadline ignores Low backlog...
        let mut hi = job(20, 0, 0, 0.0);
        hi.deadline_s = Some(0.02);
        assert!(matches!(q.offer(hi, 0.0, 1), Offer::Admitted { .. }));
        assert!((q.lane_wait_s(0, 1) - 0.01).abs() < 1e-12);
        // ...while a Low arrival with the same deadline drowns in it.
        let mut lo = job(21, 0, 2, 0.0);
        lo.deadline_s = Some(0.02);
        assert_eq!(q.offer(lo, 0.0, 1), Offer::Shed(ShedReason::Doomed));
    }

    #[test]
    fn priority_lanes_drain_strictly_in_order() {
        let mut q = IngressQueue::new(&[1], 64, false);
        q.offer(job(0, 0, 2, 0.0), 0.0, 1);
        q.offer(job(1, 0, 1, 0.1), 0.1, 1);
        q.offer(job(2, 0, 0, 0.2), 0.2, 1);
        assert_eq!(q.next_batch(1)[0].id, 2, "High first");
        assert_eq!(q.next_batch(1)[0].id, 1, "then Normal");
        assert_eq!(q.next_batch(1)[0].id, 0, "then Low");
        assert!(q.next_batch(1).is_empty());
    }

    #[test]
    fn drr_serves_weight_proportional_shares() {
        // Tenants weighted 3:2:1, all saturated with identical jobs:
        // served service seconds must track the weights closely.
        let weights = [3u32, 2, 1];
        let mut q = IngressQueue::new(&weights, 10_000, false);
        for i in 0..900 {
            q.offer(job(i, (i % 3) as usize, 1, 0.0), 0.0, 1);
        }
        let mut served = [0.0f64; 3];
        let mut dispatched = 0;
        while dispatched < 600 {
            let batch = q.next_batch(4);
            assert!(!batch.is_empty());
            for j in &batch {
                served[j.tenant] += j.service_s;
                dispatched += 1;
            }
        }
        let total: f64 = served.iter().sum();
        for (t, &w) in weights.iter().enumerate() {
            let share = served[t] / total;
            let fair = w as f64 / 6.0;
            assert!(
                (share - fair).abs() / fair < 0.15,
                "tenant {t}: share {share:.3} vs fair {fair:.3}"
            );
        }
    }

    #[test]
    fn batches_group_same_shape_head_runs() {
        let mut q = IngressQueue::new(&[1], 64, false);
        for i in 0..3 {
            q.offer(job(i, 0, 1, i as f64), 0.0, 1);
        }
        let mut odd = job(3, 0, 1, 3.0);
        odd.shape = (128, 128, 128);
        q.offer(odd, 0.0, 1);
        assert!(q.has_full_batch(3));
        assert!(!q.has_full_batch(4), "shape break caps the head run");
        let b = q.next_batch(8);
        assert_eq!(b.len(), 3, "same-shape head run only");
        assert_eq!(q.next_batch(8)[0].id, 3);
    }

    #[test]
    fn requeue_front_restores_order_and_accounting() {
        let mut q = IngressQueue::new(&[1], 64, false);
        for i in 0..4 {
            q.offer(job(i, 0, 1, i as f64), 0.0, 1);
        }
        let depth_before = q.depth();
        let service_before = q.queued_service_s();
        let batch = q.next_batch(2);
        assert_eq!(batch.iter().map(|j| j.id).collect::<Vec<_>>(), vec![0, 1]);
        q.requeue_front(batch);
        assert_eq!(q.depth(), depth_before);
        assert!((q.queued_service_s() - service_before).abs() < 1e-12);
        let again = q.next_batch(4);
        assert_eq!(again.iter().map(|j| j.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn oldest_tracks_the_batch_close_driver() {
        let mut q = IngressQueue::new(&[2, 1], 64, false);
        q.offer(job(0, 1, 1, 5.0), 5.0, 1);
        q.offer(job(1, 0, 0, 3.0), 5.0, 1);
        assert_eq!(q.oldest().expect("non-empty").id, 1);
    }

    #[test]
    fn policy_weight_lookup_defaults_to_one() {
        let p = AdmissionPolicy {
            tenant_weights: vec![("gold".into(), 3), ("silver".into(), 2)],
            ..Default::default()
        };
        assert_eq!(p.weight_for("gold"), 3);
        assert_eq!(p.weight_for("walk-in"), 1);
        let aware = AdmissionPolicy::deadline_aware(0.05);
        assert!(aware.shed_doomed);
        assert_eq!(aware.latency_target_s, Some(0.05));
    }

    #[test]
    fn report_constructors_round_trip() {
        let ok = AdmissionReport::admitted("t0", Priority::High, 3);
        assert!(ok.is_admitted());
        assert_eq!(ok.lane, Priority::High);
        let no = AdmissionReport::rejected("t1", Priority::Low, ShedReason::QueueFull, 9);
        assert!(!no.is_admitted());
        assert_eq!(no.shed, Some(ShedReason::QueueFull));
        assert_eq!(Priority::from_lane(Priority::Low.lane()), Priority::Low);
        assert_eq!(ShedReason::Doomed.name(), "doomed");
    }
}
