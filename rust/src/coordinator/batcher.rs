//! Shape-keyed request batching.
//!
//! Requests arriving within a batching window that share an execution
//! route are grouped so the engine thread dispatches them back-to-back
//! against one cached executable — the dynamic-batching shape every
//! serving stack uses, scaled to this workload (same-shape GEMMs
//! amortize executable lookup and keep the instruction cache hot; on a
//! real accelerator they would share one device context).

use std::collections::HashMap;

/// A batch of request ids sharing a route key.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Batch<T> {
    pub key: String,
    pub items: Vec<T>,
}

/// Groups items by key preserving arrival order within groups, emitting
/// batches capped at `max_batch`.
#[derive(Clone, Debug)]
pub struct Batcher {
    pub max_batch: usize,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Self {
        assert!(max_batch >= 1);
        Self { max_batch }
    }

    pub fn group<T>(&self, items: Vec<(String, T)>) -> Vec<Batch<T>> {
        let mut order: Vec<String> = Vec::new();
        let mut groups: HashMap<String, Vec<T>> = HashMap::new();
        for (key, item) in items {
            if !groups.contains_key(&key) {
                order.push(key.clone());
            }
            groups.entry(key).or_default().push(item);
        }
        let mut out = Vec::new();
        for key in order {
            let mut items = groups.remove(&key).unwrap();
            while items.len() > self.max_batch {
                let rest = items.split_off(self.max_batch);
                out.push(Batch { key: key.clone(), items });
                items = rest;
            }
            out.push(Batch { key, items });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_by_key_preserving_order() {
        let b = Batcher::new(10);
        let batches = b.group(vec![
            ("a".into(), 1),
            ("b".into(), 2),
            ("a".into(), 3),
            ("b".into(), 4),
        ]);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].key, "a");
        assert_eq!(batches[0].items, vec![1, 3]);
        assert_eq!(batches[1].items, vec![2, 4]);
    }

    #[test]
    fn splits_oversize_batches() {
        let b = Batcher::new(2);
        let batches = b.group(vec![
            ("a".into(), 1),
            ("a".into(), 2),
            ("a".into(), 3),
            ("a".into(), 4),
            ("a".into(), 5),
        ]);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].items, vec![1, 2]);
        assert_eq!(batches[2].items, vec![5]);
    }

    #[test]
    fn empty_input_empty_output() {
        let b = Batcher::new(4);
        assert!(b.group(Vec::<(String, u32)>::new()).is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_batch_rejected() {
        Batcher::new(0);
    }
}
