//! Shape-keyed request batching.
//!
//! Requests arriving within a batching window that share an execution
//! route are grouped so the engine thread dispatches them back-to-back
//! against one cached executable — the dynamic-batching shape every
//! serving stack uses, scaled to this workload (same-shape GEMMs
//! amortize executable lookup and keep the instruction cache hot; on a
//! real accelerator they would share one device context).
//!
//! Optional padding-based bucketing ([`Batcher::with_bucketing`],
//! toggled by `ServiceConfig::bucket_shapes`): instead of exact-shape
//! keys, shapes bucket up to the next blocking-compatible padded
//! extents (multiples of d_i1/d_j1/d_k0). On the accelerator a 500³ and
//! a 512³ job run the *same* padded kernel launch, so splitting them
//! into separate batches only fragments the stream.

use crate::blocked::Level1Blocking;
use std::collections::HashMap;

/// A batch of request ids sharing a route key.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Batch<T> {
    pub key: String,
    pub items: Vec<T>,
}

/// Groups items by key preserving arrival order within groups, emitting
/// batches capped at `max_batch`.
#[derive(Clone, Debug)]
pub struct Batcher {
    pub max_batch: usize,
    /// When set, [`Self::shape_key`] buckets shapes to this blocking's
    /// padded extents instead of exact extents.
    pub bucket: Option<Level1Blocking>,
    /// Latency target, seconds: a forming batch closes when the oldest
    /// member's slack against this target (or its own deadline) runs
    /// out, instead of waiting out the fixed window — see
    /// [`Self::close_by`]. None keeps the fixed-window rule.
    pub latency_target: Option<f64>,
}

impl Batcher {
    pub fn new(max_batch: usize) -> Self {
        assert!(max_batch >= 1);
        Self { max_batch, bucket: None, latency_target: None }
    }

    /// Exact-shape grouping replaced by padded-extent bucketing.
    pub fn with_bucketing(max_batch: usize, blocking: Level1Blocking) -> Self {
        assert!(max_batch >= 1);
        Self { max_batch, bucket: Some(blocking), latency_target: None }
    }

    /// Same batcher closing batches against a latency target (builder
    /// style).
    pub fn with_latency_target(mut self, target_s: f64) -> Self {
        assert!(target_s > 0.0, "latency target must be positive");
        self.latency_target = Some(target_s);
        self
    }

    /// The instant a forming batch must close, given its oldest
    /// member: the fixed window end, pulled earlier by the latency
    /// target and by the member's own absolute deadline (both leave
    /// `est_exec_s` of execution slack). Never before the member's
    /// enqueue instant — a batch already out of slack closes
    /// immediately rather than in the past.
    pub fn close_by(
        &self,
        oldest_enqueue_s: f64,
        window_s: f64,
        est_exec_s: f64,
        deadline_s: Option<f64>,
    ) -> f64 {
        let mut close = oldest_enqueue_s + window_s;
        if let Some(target) = self.latency_target {
            close = close.min(oldest_enqueue_s + (target - est_exec_s).max(0.0));
        }
        if let Some(d) = deadline_s {
            close = close.min(d - est_exec_s);
        }
        close.max(oldest_enqueue_s)
    }

    /// Shape component of a route key for an (m × k)·(k × n) job:
    /// exact extents, or the blocking-padded bucket when bucketing is
    /// enabled.
    pub fn shape_key(&self, m: usize, k: usize, n: usize) -> String {
        match &self.bucket {
            Some(b) => {
                let (pi, pj, pk) = b.pad_offchip(m as u64, n as u64, k as u64);
                format!("{pi}x{pk}x{pj}")
            }
            None => format!("{m}x{k}x{n}"),
        }
    }

    pub fn group<T>(&self, items: Vec<(String, T)>) -> Vec<Batch<T>> {
        let mut order: Vec<String> = Vec::new();
        let mut groups: HashMap<String, Vec<T>> = HashMap::new();
        for (key, item) in items {
            if !groups.contains_key(&key) {
                order.push(key.clone());
            }
            groups.entry(key).or_default().push(item);
        }
        let mut out = Vec::new();
        for key in order {
            let mut items = groups.remove(&key).unwrap();
            while items.len() > self.max_batch {
                let rest = items.split_off(self.max_batch);
                out.push(Batch { key: key.clone(), items });
                items = rest;
            }
            out.push(Batch { key, items });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_by_key_preserving_order() {
        let b = Batcher::new(10);
        let batches = b.group(vec![
            ("a".into(), 1),
            ("b".into(), 2),
            ("a".into(), 3),
            ("b".into(), 4),
        ]);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].key, "a");
        assert_eq!(batches[0].items, vec![1, 3]);
        assert_eq!(batches[1].items, vec![2, 4]);
    }

    #[test]
    fn splits_oversize_batches() {
        let b = Batcher::new(2);
        let batches = b.group(vec![
            ("a".into(), 1),
            ("a".into(), 2),
            ("a".into(), 3),
            ("a".into(), 4),
            ("a".into(), 5),
        ]);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].items, vec![1, 2]);
        assert_eq!(batches[2].items, vec![5]);
    }

    #[test]
    fn empty_input_empty_output() {
        let b = Batcher::new(4);
        assert!(b.group(Vec::<(String, u32)>::new()).is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_batch_rejected() {
        Batcher::new(0);
    }

    fn g_blocking() -> crate::blocked::Level1Blocking {
        crate::blocked::Level1Blocking::new(
            crate::systolic::ArraySize::new(64, 32, 2, 2),
            512,
            512,
        )
    }

    #[test]
    fn exact_shape_keys_without_bucketing() {
        let b = Batcher::new(4);
        assert_eq!(b.shape_key(100, 200, 300), "100x200x300");
        assert_ne!(b.shape_key(100, 200, 300), b.shape_key(101, 200, 300));
    }

    #[test]
    fn fixed_window_close_without_a_target() {
        let b = Batcher::new(4);
        // No target, no deadline: the fixed window rules.
        assert_eq!(b.close_by(10.0, 0.002, 0.001, None), 10.002);
        // A deadline pulls the close earlier, leaving execution slack.
        assert_eq!(b.close_by(10.0, 0.002, 0.0005, Some(10.001)), 10.0005);
    }

    #[test]
    fn latency_target_closes_on_the_oldest_members_slack() {
        let b = Batcher::new(4).with_latency_target(0.010);
        // Target 10 ms, est exec 4 ms: close 6 ms after enqueue even
        // though the fixed window would wait 50 ms.
        let close = b.close_by(1.0, 0.050, 0.004, None);
        assert!((close - 1.006).abs() < 1e-12, "{close}");
        // The tighter of target and deadline wins.
        let close = b.close_by(1.0, 0.050, 0.004, Some(1.007));
        assert!((close - 1.003).abs() < 1e-12, "{close}");
        // Slack already gone: close immediately, never in the past.
        assert_eq!(b.close_by(1.0, 0.050, 0.020, None), 1.0);
        assert_eq!(b.close_by(1.0, 0.050, 0.004, Some(0.5)), 1.0);
    }

    #[test]
    #[should_panic(expected = "latency target must be positive")]
    fn zero_latency_target_rejected() {
        Batcher::new(1).with_latency_target(0.0);
    }

    #[test]
    fn bucketing_groups_blocking_compatible_shapes() {
        let b = Batcher::with_bucketing(4, g_blocking());
        // 100³ and 500³ both pad to the 512-multiple bucket (k pads to
        // the d_k0 = 2 grid).
        assert_eq!(b.shape_key(100, 100, 100), "512x100x512");
        assert_eq!(b.shape_key(500, 99, 500), b.shape_key(100, 99, 300));
        assert_eq!(b.shape_key(512, 512, 512), "512x512x512");
        // Shapes a blocking period apart stay distinct.
        assert_ne!(b.shape_key(512, 512, 512), b.shape_key(513, 512, 512));
    }
}
