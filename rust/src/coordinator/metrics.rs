//! Service metrics: lock-free counters + a bounded latency histogram.

use crate::trace::CriticalPath;
use crate::util::stats::LogHistogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// A poisoned metrics mutex means some recorder thread panicked while
/// holding the lock. The guarded values are append-only bucket
/// counters (a `LogHistogram` is never left half-merged by `record`),
/// so the worst case is one lost sample — recover the guard and keep
/// the scrape path alive instead of cascading the panic into every
/// caller that ever reads a latency gauge.
fn unpoisoned<T>(lock: &Mutex<T>) -> MutexGuard<'_, T> {
    lock.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Saturating seconds→microseconds conversion for the `u64` gauges.
/// A plain `(x * 1e6) as u64` is UB-adjacent on non-finite input and
/// silently clamps huge values architecture-dependently; this pins the
/// edge cases: NaN / negative → 0, +∞ / overflow → `u64::MAX`.
pub(crate) fn saturating_us(seconds: f64) -> u64 {
    let us = seconds * 1e6;
    if !us.is_finite() || us <= 0.0 {
        if us == f64::INFINITY {
            u64::MAX
        } else {
            0
        }
    } else if us >= u64::MAX as f64 {
        u64::MAX
    } else {
        us as u64
    }
}

/// Shared metrics handle (cheap to clone via Arc at the service level).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub artifact_hits: AtomicU64,
    pub fallbacks: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// Total FLOPs served (paper convention).
    pub flops: AtomicU64,
    /// Requests routed to the multi-FPGA cluster.
    pub sharded_jobs: AtomicU64,
    /// Sub-GEMM shards executed across the fleet.
    pub shards_executed: AtomicU64,
    /// Shards migrated between devices by work-stealing.
    pub cluster_steals: AtomicU64,
    /// Simulated fleet compute-busy time, in microseconds (gauge base
    /// for cluster utilization).
    pub cluster_busy_us: AtomicU64,
    /// Simulated cluster makespan total, in microseconds.
    pub cluster_makespan_us: AtomicU64,
    /// Circuit-hold time of partial-C reduction steps on the card
    /// fabric, in microseconds.
    pub fabric_reduction_us: AtomicU64,
    /// Of that, time hidden under some device's compute (gauge pair:
    /// divide by `fabric_reduction_us` for the overlap fraction).
    pub fabric_reduction_overlap_us: AtomicU64,
    /// Busy time summed over all directed fabric links, in
    /// microseconds.
    pub fabric_link_busy_us: AtomicU64,
    /// Capacity base for link utilization: makespan × directed links,
    /// in microseconds.
    pub fabric_link_capacity_us: AtomicU64,
    /// Reduction hop-bytes the recorded cluster plans would have paid
    /// under identity placement (gauge pair with
    /// `placement_placed_hop_bytes` — the saving the topology-aware
    /// placement optimizer banked).
    pub placement_identity_hop_bytes: AtomicU64,
    /// Reduction hop-bytes the recorded cluster plans actually paid as
    /// placed (≤ the identity gauge).
    pub placement_placed_hop_bytes: AtomicU64,
    /// Host time spent searching placements, in microseconds.
    pub placement_search_us: AtomicU64,
    /// Hot spares activated for dead cards across elastic runs.
    pub elastic_spare_activations: AtomicU64,
    /// Drains whose last shard re-executed (pairs with
    /// `elastic_spare_activations`; a gap means a run ended mid-drain,
    /// which the chaos suite asserts never happens).
    pub elastic_drains_completed: AtomicU64,
    /// Σ (drain-complete − spare-activation) spans, in microseconds.
    pub elastic_drain_us: AtomicU64,
    /// Cards attached by watermark growth across elastic runs.
    pub elastic_grown_cards: AtomicU64,
    /// Remaining reduction hop-bytes observed just before each growth
    /// rebalance (gauge pair with `post_grow_placed_hop_bytes`: the
    /// post-grow placement delta).
    pub post_grow_identity_hop_bytes: AtomicU64,
    /// Same, after the rebalance placed the queued shards.
    pub post_grow_placed_hop_bytes: AtomicU64,
    /// Requests served by the Strassen route.
    pub strassen_jobs: AtomicU64,
    /// Histogram of chosen recursion depths: bucket i counts depth-i
    /// jobs, the last bucket absorbing anything deeper.
    pub strassen_depths: [AtomicU64; 4],
    /// Accumulated effective-vs-peak throughput ratio across Strassen
    /// jobs, in parts-per-million (divide by `strassen_jobs · 1e6` for
    /// the mean; > 1.0 means the DSP-bound eq. 5 peak was beaten).
    pub strassen_eff_vs_peak_ppm: AtomicU64,
    /// Critical-path seconds per attribution bucket, in microseconds,
    /// accumulated from every traced run fed to
    /// [`Self::record_critical_path`] (indexed like
    /// [`crate::trace::critical::BUCKETS`]).
    pub critical_bucket_us: [AtomicU64; 5],
    /// Requests accepted by admission control into the ingress queue.
    pub admitted: AtomicU64,
    /// Requests turned away (queue full, doomed, or evicted).
    pub shed: AtomicU64,
    /// Served requests that met their deadline (deadline-free requests
    /// count as met — an answer in time is an answer in time).
    pub deadline_met: AtomicU64,
    /// Served requests that blew their deadline.
    pub deadline_missed: AtomicU64,
    /// FLOPs of deadline-met work — the goodput numerator; divide by
    /// wall time for deadline-met FLOP/s.
    pub goodput_flops: AtomicU64,
    /// Request latencies, log-bucketed: fixed memory under sustained
    /// traffic (the old reservoir was an unbounded `Vec<f64>`).
    latencies: Mutex<LogHistogram>,
    /// Per-tenant latency histograms, first-come slotted: the first
    /// [`TENANT_GAUGE_SLOTS`] distinct tenant names each get a slot,
    /// later names fold into the last slot so memory stays fixed no
    /// matter how many tenants traffic claims.
    tenant_latencies: Mutex<Vec<(String, LogHistogram)>>,
}

/// Fixed number of per-tenant latency gauges exported by the scrape
/// path (the snapshot is `Copy`, so the arrays are fixed-size).
pub const TENANT_GAUGE_SLOTS: usize = 4;

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, seconds: f64) {
        unpoisoned(&self.latencies).record(seconds);
    }

    /// Fold one traced run's critical-path attribution into the
    /// per-bucket gauges (microseconds; bucket order follows
    /// [`crate::trace::critical::BUCKETS`]).
    pub fn record_critical_path(&self, path: &CriticalPath) {
        for (slot, bucket) in self.critical_bucket_us.iter().zip(crate::trace::critical::BUCKETS)
        {
            let secs = path.bucket_seconds.get(bucket).copied().unwrap_or(0.0);
            slot.fetch_add(saturating_us(secs), Ordering::Relaxed);
        }
    }

    /// Share of accumulated critical-path time attributed to `bucket`
    /// (0.0 before the first traced run or for an unknown bucket).
    pub fn critical_share(&self, bucket: &str) -> f64 {
        let total: u64 =
            self.critical_bucket_us.iter().map(|c| c.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0.0;
        }
        match crate::trace::critical::BUCKETS.iter().position(|b| *b == bucket) {
            Some(i) => self.critical_bucket_us[i].load(Ordering::Relaxed) as f64 / total as f64,
            None => 0.0,
        }
    }

    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Record one request latency against its tenant's histogram slot.
    /// The first [`TENANT_GAUGE_SLOTS`] distinct names get their own
    /// slot; anything later lands in the last slot ("overflow"), so a
    /// tenant-name cardinality explosion cannot grow the gauge set.
    pub fn record_tenant_latency(&self, tenant: &str, seconds: f64) {
        let mut slots = unpoisoned(&self.tenant_latencies);
        if let Some((_, h)) = slots.iter_mut().find(|(name, _)| name == tenant) {
            h.record(seconds);
            return;
        }
        if slots.len() < TENANT_GAUGE_SLOTS {
            let mut h = LogHistogram::new();
            h.record(seconds);
            slots.push((tenant.to_string(), h));
        } else {
            slots.last_mut().expect("slots full").1.record(seconds);
        }
    }

    /// Tenant names currently holding gauge slots, in claim order.
    pub fn tenant_names(&self) -> Vec<String> {
        unpoisoned(&self.tenant_latencies).iter().map(|(n, _)| n.clone()).collect()
    }

    /// Fraction of offered requests shed (0.0 before any admission
    /// decision).
    pub fn shed_rate(&self) -> f64 {
        let shed = self.shed.load(Ordering::Relaxed) as f64;
        let admitted = self.admitted.load(Ordering::Relaxed) as f64;
        if shed + admitted == 0.0 {
            return 0.0;
        }
        shed / (shed + admitted)
    }

    pub fn add_flops(&self, f: u64) {
        self.flops.fetch_add(f, Ordering::Relaxed);
    }

    /// Record one cluster run's gauges from its report. Does not touch
    /// `sharded_jobs` — a chained request runs two cluster legs but is
    /// one job; the service increments the job counter per request.
    pub fn record_cluster(&self, report: &crate::cluster::ClusterReport) {
        self.shards_executed.fetch_add(report.shards as u64, Ordering::Relaxed);
        self.cluster_steals.fetch_add(report.steals as u64, Ordering::Relaxed);
        let busy: f64 = report.per_device.iter().map(|d| d.compute_seconds).sum();
        self.cluster_busy_us.fetch_add(saturating_us(busy), Ordering::Relaxed);
        self.cluster_makespan_us
            .fetch_add(saturating_us(report.makespan_seconds), Ordering::Relaxed);
        self.fabric_reduction_us
            .fetch_add(saturating_us(report.reduction_seconds), Ordering::Relaxed);
        self.fabric_reduction_overlap_us
            .fetch_add(saturating_us(report.reduction_overlap_seconds), Ordering::Relaxed);
        self.fabric_link_busy_us
            .fetch_add(saturating_us(report.link_busy_seconds), Ordering::Relaxed);
        let capacity = report.makespan_seconds * report.directed_links as f64;
        self.fabric_link_capacity_us.fetch_add(saturating_us(capacity), Ordering::Relaxed);
        self.placement_identity_hop_bytes
            .fetch_add(report.placement_identity_hop_bytes, Ordering::Relaxed);
        self.placement_placed_hop_bytes
            .fetch_add(report.placement_placed_hop_bytes, Ordering::Relaxed);
        self.placement_search_us
            .fetch_add(saturating_us(report.placement_search_seconds), Ordering::Relaxed);
    }

    /// Record one elastic run's controller gauges (spare activations,
    /// drain spans, growth, the post-grow placement delta). The
    /// schedule-level numbers travel through [`Self::record_cluster`]
    /// when the caller builds a `ClusterReport` from the same run.
    pub fn record_elastic(&self, outcome: &crate::cluster::ElasticOutcome) {
        self.elastic_spare_activations
            .fetch_add(outcome.spare_activations as u64, Ordering::Relaxed);
        self.elastic_drains_completed
            .fetch_add(outcome.drains_completed as u64, Ordering::Relaxed);
        self.elastic_drain_us
            .fetch_add(saturating_us(outcome.drain_seconds), Ordering::Relaxed);
        // Watermark- and SLO-burn-grown cards land in the same gauge:
        // both attach a card the plan did not start with.
        self.elastic_grown_cards.fetch_add(
            (outcome.grown_cards + outcome.slo_grown_cards) as u64,
            Ordering::Relaxed,
        );
        self.post_grow_identity_hop_bytes
            .fetch_add(outcome.post_grow_identity_hop_bytes, Ordering::Relaxed);
        self.post_grow_placed_hop_bytes
            .fetch_add(outcome.post_grow_placed_hop_bytes, Ordering::Relaxed);
    }

    /// Fraction of pre-growth reduction hop-bytes the elastic
    /// rebalance removed across recorded runs (0.0 before the first
    /// growth; negative when balancing queue depth cost hops).
    pub fn post_grow_hop_saving(&self) -> f64 {
        let identity = self.post_grow_identity_hop_bytes.load(Ordering::Relaxed) as f64;
        let placed = self.post_grow_placed_hop_bytes.load(Ordering::Relaxed) as f64;
        if identity == 0.0 {
            return 0.0;
        }
        1.0 - placed / identity
    }

    /// Fraction of identity-placement hop-bytes the placement
    /// optimizer removed across recorded cluster runs (0.0 before the
    /// first reduction-carrying plan).
    pub fn placement_hop_saving(&self) -> f64 {
        let identity = self.placement_identity_hop_bytes.load(Ordering::Relaxed) as f64;
        let placed = self.placement_placed_hop_bytes.load(Ordering::Relaxed) as f64;
        if identity == 0.0 {
            return 0.0;
        }
        1.0 - placed / identity
    }

    /// Mean directed-link utilization of the card fabric across all
    /// recorded cluster runs (0.0 before the first one).
    pub fn fabric_link_utilization(&self) -> f64 {
        let busy = self.fabric_link_busy_us.load(Ordering::Relaxed) as f64;
        let capacity = self.fabric_link_capacity_us.load(Ordering::Relaxed) as f64;
        if capacity == 0.0 {
            return 0.0;
        }
        busy / capacity
    }

    /// Fraction of recorded reduction time that was hidden under
    /// compute (0.0 when no reduction traffic has been recorded).
    pub fn reduction_overlap_fraction(&self) -> f64 {
        let total = self.fabric_reduction_us.load(Ordering::Relaxed) as f64;
        let overlapped = self.fabric_reduction_overlap_us.load(Ordering::Relaxed) as f64;
        if total == 0.0 {
            return 0.0;
        }
        overlapped / total
    }

    /// Record one Strassen-routed job: depth histogram bucket plus the
    /// effective-vs-peak gauge. Also counts the job itself (the route
    /// match in the service does not double-increment).
    pub fn record_strassen(&self, report: &crate::strassen::StrassenReport) {
        Self::inc(&self.strassen_jobs);
        let bucket = (report.depth as usize).min(self.strassen_depths.len() - 1);
        Self::inc(&self.strassen_depths[bucket]);
        self.strassen_eff_vs_peak_ppm
            .fetch_add(saturating_us(report.effective_vs_peak()), Ordering::Relaxed);
    }

    /// Mean effective-vs-peak ratio over all Strassen jobs (0.0 before
    /// the first one). Values above 1.0 are the subsystem's point:
    /// effective throughput past the DSP-bound peak.
    pub fn strassen_mean_eff_vs_peak(&self) -> f64 {
        let jobs = self.strassen_jobs.load(Ordering::Relaxed);
        if jobs == 0 {
            return 0.0;
        }
        self.strassen_eff_vs_peak_ppm.load(Ordering::Relaxed) as f64 / jobs as f64 / 1e6
    }

    /// Mean fleet utilization across all recorded cluster runs
    /// (compute-busy seconds over device-seconds of makespan).
    pub fn cluster_utilization(&self, fleet_size: u64) -> f64 {
        let busy = self.cluster_busy_us.load(Ordering::Relaxed) as f64;
        let span = self.cluster_makespan_us.load(Ordering::Relaxed) as f64;
        if span == 0.0 || fleet_size == 0 {
            return 0.0;
        }
        busy / (span * fleet_size as f64)
    }

    /// Point-in-time copy of the latency histogram (fixed size, so the
    /// clone is cheap and the lock is held briefly).
    pub fn latency_histogram(&self) -> LogHistogram {
        unpoisoned(&self.latencies).clone()
    }

    /// `p50/p99/p999` one-liner for the serve CLI and examples.
    pub fn latency_report_line(&self) -> String {
        unpoisoned(&self.latencies).report_line("request latency")
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latency_histogram();
        let (tenant_requests, tenant_p99_us) = {
            let slots = unpoisoned(&self.tenant_latencies);
            let mut counts = [0u64; TENANT_GAUGE_SLOTS];
            let mut p99s = [0u64; TENANT_GAUGE_SLOTS];
            for (i, (_, h)) in slots.iter().take(TENANT_GAUGE_SLOTS).enumerate() {
                counts[i] = h.count();
                p99s[i] = if h.is_empty() { 0 } else { saturating_us(h.quantile(0.99)) };
            }
            (counts, p99s)
        };
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            artifact_hits: self.artifact_hits.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            flops: self.flops.load(Ordering::Relaxed),
            sharded_jobs: self.sharded_jobs.load(Ordering::Relaxed),
            shards_executed: self.shards_executed.load(Ordering::Relaxed),
            cluster_steals: self.cluster_steals.load(Ordering::Relaxed),
            cluster_busy_us: self.cluster_busy_us.load(Ordering::Relaxed),
            cluster_makespan_us: self.cluster_makespan_us.load(Ordering::Relaxed),
            fabric_reduction_us: self.fabric_reduction_us.load(Ordering::Relaxed),
            fabric_reduction_overlap_us: self
                .fabric_reduction_overlap_us
                .load(Ordering::Relaxed),
            fabric_link_busy_us: self.fabric_link_busy_us.load(Ordering::Relaxed),
            fabric_link_capacity_us: self.fabric_link_capacity_us.load(Ordering::Relaxed),
            placement_identity_hop_bytes: self
                .placement_identity_hop_bytes
                .load(Ordering::Relaxed),
            placement_placed_hop_bytes: self.placement_placed_hop_bytes.load(Ordering::Relaxed),
            placement_search_us: self.placement_search_us.load(Ordering::Relaxed),
            elastic_spare_activations: self.elastic_spare_activations.load(Ordering::Relaxed),
            elastic_drains_completed: self.elastic_drains_completed.load(Ordering::Relaxed),
            elastic_drain_us: self.elastic_drain_us.load(Ordering::Relaxed),
            elastic_grown_cards: self.elastic_grown_cards.load(Ordering::Relaxed),
            post_grow_identity_hop_bytes: self
                .post_grow_identity_hop_bytes
                .load(Ordering::Relaxed),
            post_grow_placed_hop_bytes: self.post_grow_placed_hop_bytes.load(Ordering::Relaxed),
            strassen_jobs: self.strassen_jobs.load(Ordering::Relaxed),
            strassen_depths: std::array::from_fn(|i| {
                self.strassen_depths[i].load(Ordering::Relaxed)
            }),
            strassen_eff_vs_peak_ppm: self.strassen_eff_vs_peak_ppm.load(Ordering::Relaxed),
            // Explicitly zero when no sample has been recorded — the
            // quantile of an empty histogram must never surface as a
            // garbage reading — and saturating otherwise.
            latency_p50_us: if lat.is_empty() { 0 } else { saturating_us(lat.quantile(0.50)) },
            latency_p99_us: if lat.is_empty() { 0 } else { saturating_us(lat.quantile(0.99)) },
            latency_p999_us: if lat.is_empty() {
                0
            } else {
                saturating_us(lat.quantile(0.999))
            },
            latency_count: lat.count(),
            critical_bucket_us: std::array::from_fn(|i| {
                self.critical_bucket_us[i].load(Ordering::Relaxed)
            }),
            admitted: self.admitted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            deadline_met: self.deadline_met.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
            goodput_flops: self.goodput_flops.load(Ordering::Relaxed),
            tenant_requests,
            tenant_p99_us,
        }
    }
}

/// Point-in-time copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub artifact_hits: u64,
    pub fallbacks: u64,
    pub batches: u64,
    pub errors: u64,
    pub flops: u64,
    pub sharded_jobs: u64,
    pub shards_executed: u64,
    pub cluster_steals: u64,
    pub cluster_busy_us: u64,
    pub cluster_makespan_us: u64,
    pub fabric_reduction_us: u64,
    pub fabric_reduction_overlap_us: u64,
    pub fabric_link_busy_us: u64,
    pub fabric_link_capacity_us: u64,
    pub placement_identity_hop_bytes: u64,
    pub placement_placed_hop_bytes: u64,
    pub placement_search_us: u64,
    pub elastic_spare_activations: u64,
    pub elastic_drains_completed: u64,
    pub elastic_drain_us: u64,
    pub elastic_grown_cards: u64,
    pub post_grow_identity_hop_bytes: u64,
    pub post_grow_placed_hop_bytes: u64,
    pub strassen_jobs: u64,
    pub strassen_depths: [u64; 4],
    pub strassen_eff_vs_peak_ppm: u64,
    /// Request-latency quantiles from the log-bucketed histogram.
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
    pub latency_p999_us: u64,
    pub latency_count: u64,
    /// Accumulated critical-path attribution, in microseconds, indexed
    /// like [`crate::trace::critical::BUCKETS`]
    /// (compute/fabric/host/drain/idle).
    pub critical_bucket_us: [u64; 5],
    /// Admission-control outcomes.
    pub admitted: u64,
    pub shed: u64,
    /// Deadline outcomes over served requests.
    pub deadline_met: u64,
    pub deadline_missed: u64,
    /// FLOPs of deadline-met work (goodput numerator).
    pub goodput_flops: u64,
    /// Per-tenant-slot request counts (slot order = claim order; slot
    /// names via [`Metrics::tenant_names`]).
    pub tenant_requests: [u64; TENANT_GAUGE_SLOTS],
    /// Per-tenant-slot p99 latency, microseconds.
    pub tenant_p99_us: [u64; TENANT_GAUGE_SLOTS],
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::new();
        Metrics::inc(&m.requests);
        Metrics::inc(&m.requests);
        Metrics::inc(&m.fallbacks);
        m.add_flops(1000);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.fallbacks, 1);
        assert_eq!(s.flops, 1000);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn cluster_gauges() {
        use crate::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
        let m = Metrics::new();
        let sim = ClusterSim::builder(Fleet::homogeneous(2, "G").unwrap()).build();
        let plan =
            PartitionPlan::new(PartitionStrategy::Row1D { devices: 2 }, 4096, 4096, 4096)
                .unwrap();
        let report = sim.simulate(&plan);
        Metrics::inc(&m.sharded_jobs);
        m.record_cluster(&report);
        let s = m.snapshot();
        assert_eq!(s.sharded_jobs, 1);
        assert_eq!(s.shards_executed, 2);
        assert!(s.cluster_makespan_us > 0);
        let u = m.cluster_utilization(2);
        assert!(u > 0.0 && u <= 1.0, "{u}");
        // A 1D plan has no reduction traffic, but the capacity base of
        // the link-utilization gauge still accumulates.
        assert_eq!(s.fabric_reduction_us, 0);
        assert!(s.fabric_link_capacity_us > 0);
        assert_eq!(m.reduction_overlap_fraction(), 0.0);
        assert_eq!(m.fabric_link_utilization(), 0.0);
    }

    #[test]
    fn fabric_gauges_accumulate_reductions() {
        use crate::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
        use crate::fabric::Topology;
        let m = Metrics::new();
        let sim = ClusterSim::builder(Fleet::homogeneous(4, "G").unwrap())
            .topology(Topology::ring(4))
            .build();
        let plan = PartitionPlan::new(
            PartitionStrategy::Summa25D { p: 2, q: 1, c: 2 },
            8192,
            8192,
            8192,
        )
        .unwrap();
        m.record_cluster(&sim.simulate(&plan));
        let s = m.snapshot();
        assert!(s.fabric_reduction_us > 0);
        assert!(s.fabric_link_busy_us > 0);
        let u = m.fabric_link_utilization();
        assert!(u > 0.0 && u <= 1.0, "{u}");
        assert!(m.reduction_overlap_fraction() <= 1.0);
    }

    #[test]
    fn placement_gauges_accumulate_savings() {
        use crate::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
        use crate::fabric::Topology;
        let m = Metrics::new();
        assert_eq!(m.placement_hop_saving(), 0.0);
        let sim = ClusterSim::builder(Fleet::homogeneous(8, "G").unwrap())
            .topology(Topology::ring(8))
            .build();
        let plan = PartitionPlan::new(
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 },
            8192,
            8192,
            8192,
        )
        .unwrap();
        let (placed, rep) = sim.place_plan(&plan);
        let rep = rep.expect("2.5d plan has reduction traffic");
        m.record_cluster(&sim.simulate_placed(&placed, Some(&rep)));
        let s = m.snapshot();
        assert!(s.placement_identity_hop_bytes > 0);
        assert!(s.placement_placed_hop_bytes <= s.placement_identity_hop_bytes);
        let saving = m.placement_hop_saving();
        assert!(saving > 0.0 && saving < 1.0, "{saving}");
    }

    #[test]
    fn elastic_gauges_accumulate_drains() {
        use crate::cluster::{ClusterSim, FaultPlan, Fleet, PartitionPlan, PartitionStrategy};
        let m = Metrics::new();
        assert_eq!(m.post_grow_hop_saving(), 0.0);
        let sim = ClusterSim::builder(Fleet::homogeneous(3, "G").unwrap()).spares(1).build();
        let plan =
            PartitionPlan::new(PartitionStrategy::Row1D { devices: 2 }, 4096, 4096, 4096)
                .unwrap();
        let first = &plan.shards[0];
        let t_die =
            sim.host.seconds_for_bytes(first.input_bytes()) + 0.5 * sim.shard_seconds(0, first);
        let out = sim.simulate_elastic(&plan, &FaultPlan::kill(0, t_die)).unwrap();
        m.record_elastic(&out);
        let s = m.snapshot();
        assert_eq!(s.elastic_spare_activations, 1);
        assert_eq!(s.elastic_drains_completed, 1);
        assert!(s.elastic_drain_us > 0);
        assert_eq!(s.elastic_grown_cards, 0);
    }

    #[test]
    fn strassen_gauges() {
        let m = Metrics::new();
        assert_eq!(m.strassen_mean_eff_vs_peak(), 0.0);
        let report = crate::strassen::StrassenReport {
            depth: 1,
            leaves: 7,
            simulated_seconds: 1.0,
            effective_gflops: 3300.0,
            peak_gflops: 3260.0,
            speedup_vs_classical: 1.05,
            rel_fro_error: None,
        };
        m.record_strassen(&report);
        m.record_strassen(&crate::strassen::StrassenReport { depth: 2, ..report.clone() });
        // Depths past the histogram clamp into the last bucket.
        m.record_strassen(&crate::strassen::StrassenReport { depth: 9, ..report });
        let s = m.snapshot();
        assert_eq!(s.strassen_jobs, 3);
        assert_eq!(s.strassen_depths, [0, 1, 1, 1]);
        let mean = m.strassen_mean_eff_vs_peak();
        assert!((mean - 3300.0 / 3260.0).abs() < 1e-3, "{mean}");
        assert!(mean > 1.0, "the gauge must be able to sit above peak");
    }

    #[test]
    fn latency_quantiles_reach_the_snapshot() {
        let m = Metrics::new();
        // 1..=1000 ms uniform: p50 ≈ 500 ms, p99 ≈ 990 ms, p999 ≈ 999 ms.
        for i in 1..=1000 {
            m.record_latency(i as f64 * 1e-3);
        }
        let h = m.latency_histogram();
        assert_eq!(h.count(), 1000);
        assert!((h.quantile(0.5) - 0.5).abs() / 0.5 < 0.04);
        let s = m.snapshot();
        assert_eq!(s.latency_count, 1000);
        assert!((s.latency_p50_us as f64 - 500_000.0).abs() < 0.04 * 500_000.0);
        assert!((s.latency_p99_us as f64 - 990_000.0).abs() < 0.04 * 990_000.0);
        assert!((s.latency_p999_us as f64 - 999_000.0).abs() < 0.04 * 999_000.0);
        assert!(s.latency_p50_us <= s.latency_p99_us && s.latency_p99_us <= s.latency_p999_us);
        assert!(m.latency_report_line().contains("p999"));
    }

    #[test]
    fn admission_and_tenant_gauges() {
        let m = Metrics::new();
        assert_eq!(m.shed_rate(), 0.0);
        Metrics::add(&m.admitted, 8);
        Metrics::add(&m.shed, 2);
        Metrics::add(&m.deadline_met, 7);
        Metrics::inc(&m.deadline_missed);
        Metrics::add(&m.goodput_flops, 1_000_000);
        assert!((m.shed_rate() - 0.2).abs() < 1e-12);
        // First four distinct tenants claim slots; the fifth folds into
        // the last slot instead of growing the gauge set.
        for name in ["gold", "silver", "bronze", "free", "overflow"] {
            m.record_tenant_latency(name, 0.010);
        }
        m.record_tenant_latency("gold", 0.020);
        assert_eq!(m.tenant_names(), ["gold", "silver", "bronze", "free"]);
        let s = m.snapshot();
        assert_eq!(s.admitted, 8);
        assert_eq!(s.shed, 2);
        assert_eq!(s.deadline_met, 7);
        assert_eq!(s.deadline_missed, 1);
        assert_eq!(s.goodput_flops, 1_000_000);
        assert_eq!(s.tenant_requests, [2, 1, 1, 2], "overflow folds into the last slot");
        assert!(s.tenant_p99_us[0] >= 19_000, "gold p99 sees the 20 ms sample");
        assert!(s.tenant_p99_us[1] > 0 && s.tenant_p99_us[3] > 0);
    }

    #[test]
    fn saturating_us_pins_the_edge_cases() {
        assert_eq!(saturating_us(0.0), 0);
        assert_eq!(saturating_us(-1.0), 0);
        assert_eq!(saturating_us(f64::NAN), 0);
        assert_eq!(saturating_us(f64::NEG_INFINITY), 0);
        assert_eq!(saturating_us(f64::INFINITY), u64::MAX);
        assert_eq!(saturating_us(1e300), u64::MAX, "overflow saturates, never wraps");
        assert_eq!(saturating_us(1.5), 1_500_000);
        assert_eq!(saturating_us(2.5e-6), 2);
    }

    #[test]
    fn empty_latency_snapshot_reports_zero_not_garbage() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.latency_count, 0);
        assert_eq!((s.latency_p50_us, s.latency_p99_us, s.latency_p999_us), (0, 0, 0));
        // One sample: all quantiles collapse onto it.
        m.record_latency(0.002);
        let s = m.snapshot();
        assert_eq!(s.latency_count, 1);
        assert_eq!(s.latency_p50_us, 2_000);
        assert_eq!(s.latency_p99_us, 2_000);
        // A non-finite latency cannot poison the gauges.
        m.record_latency(f64::INFINITY);
        let s = m.snapshot();
        assert_eq!(s.latency_count, 2);
        assert!(s.latency_p999_us < u64::MAX);
    }

    #[test]
    fn poisoned_locks_recover_and_scrapes_keep_working() {
        let m = Metrics::new();
        m.record_latency(0.001);
        m.record_tenant_latency("gold", 0.002);
        // Panic while holding both guards — exactly what a panicking
        // recorder thread does to the mutexes.
        let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _lat = m.latencies.lock().unwrap();
            let _ten = m.tenant_latencies.lock().unwrap();
            panic!("recorder died mid-scrape");
        }));
        assert!(poisoned.is_err());
        assert!(m.latencies.lock().is_err(), "the mutex really is poisoned");
        // Every lock path must shrug the poison off: record, histogram
        // copy, report line, tenant names, and the full snapshot.
        m.record_latency(0.003);
        m.record_tenant_latency("gold", 0.004);
        assert_eq!(m.latency_histogram().count(), 2);
        assert!(m.latency_report_line().contains("p999"));
        assert_eq!(m.tenant_names(), ["gold"]);
        let s = m.snapshot();
        assert_eq!(s.latency_count, 2);
        assert_eq!(s.tenant_requests[0], 2);
        assert!(s.tenant_p99_us[0] > 0);
    }

    #[test]
    fn critical_path_shares_accumulate() {
        use crate::trace::{Category, Tracer, Track};
        let m = Metrics::new();
        assert_eq!(m.critical_share("compute"), 0.0);
        let t = Tracer::recording();
        t.span(Track::CardCompute(0), Category::Compute, || "c".into(), 0.0, 3.0);
        t.span(Track::CardFabric(0), Category::Fabric, || "f".into(), 3.0, 4.0);
        m.record_critical_path(&crate::trace::critical_path(&t.take()));
        let s = m.snapshot();
        assert_eq!(s.critical_bucket_us[0], 3_000_000); // compute
        assert_eq!(s.critical_bucket_us[1], 1_000_000); // fabric
        assert!((m.critical_share("compute") - 0.75).abs() < 1e-9);
        assert_eq!(m.critical_share("nonsense"), 0.0);
    }
}
