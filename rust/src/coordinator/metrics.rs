//! Service metrics: lock-free counters + latency reservoir.

use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics handle (cheap to clone via Arc at the service level).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub artifact_hits: AtomicU64,
    pub fallbacks: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// Total FLOPs served (paper convention).
    pub flops: AtomicU64,
    latencies: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, seconds: f64) {
        self.latencies.lock().unwrap().push(seconds);
    }

    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_flops(&self, f: u64) {
        self.flops.fetch_add(f, Ordering::Relaxed);
    }

    pub fn latency_summary(&self) -> Summary {
        Summary::from_samples("request latency", self.latencies.lock().unwrap().clone())
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            artifact_hits: self.artifact_hits.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            flops: self.flops.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub artifact_hits: u64,
    pub fallbacks: u64,
    pub batches: u64,
    pub errors: u64,
    pub flops: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::new();
        Metrics::inc(&m.requests);
        Metrics::inc(&m.requests);
        Metrics::inc(&m.fallbacks);
        m.add_flops(1000);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.fallbacks, 1);
        assert_eq!(s.flops, 1000);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn latency_summary() {
        let m = Metrics::new();
        for v in [0.1, 0.2, 0.3] {
            m.record_latency(v);
        }
        let s = m.latency_summary();
        assert_eq!(s.samples.len(), 3);
        assert!((s.median() - 0.2).abs() < 1e-12);
    }
}
