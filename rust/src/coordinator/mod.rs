//! L3 coordinator: a GEMM service in the shape the paper motivates —
//! matrix-multiplication jobs dispatched to a (simulated) FPGA
//! accelerator card, with results that can chain into further multiplies
//! without host-side reordering (the paper's §VI argument against the
//! Intel SDK design).
//!
//! Architecture (Python never runs here):
//!
//! ```text
//! clients ──submit──▶ [Batcher] ──per-shape batches──▶ [Engine thread]
//!                        │        (exact or padded-bucketed keys)
//!                        │                               PJRT CPU exec
//!                        │                               (AOT artifacts)
//!                        └──────────▶ [Router]: artifact | fallback |
//!                                              sharded | strassen
//!                                        + FPGA design for timing sim
//!                                        + multi-FPGA cluster for jobs
//!                                          too large for one card
//!                                        + Strassen planner for shapes
//!                                          past the crossover (depth
//!                                          capped by the request's
//!                                          error budget)
//! ```
//!
//! Every response carries both the *functional* result (via the XLA
//! artifact or the in-process GEMM fallback) and the *simulated* FPGA
//! execution report (cycles/seconds/e_D on the selected Table-I design),
//! so the serving path exercises the whole stack on every request.

pub mod admission;
pub mod batcher;
pub mod metrics;
pub mod router;
pub mod serve;
pub mod service;
pub mod workload;

pub use admission::{AdmissionPolicy, AdmissionReport, IngressQueue, Priority, ShedReason};
pub use batcher::{Batch, Batcher};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{Route, Router};
pub use serve::{simulate_serve, simulate_serve_trace, ServeConfig, ServeOutcome};
pub use service::{GemmRequest, GemmResponse, GemmService, ServiceConfig};
pub use workload::{ArrivalModel, TenantSpec, TraceEntry, WorkloadGen};
