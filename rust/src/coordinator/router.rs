//! Shape → execution-route and timing-design selection.

use crate::blocked::{Level1Blocking, OffchipDesign};
use crate::dse::configs::{fitted_designs, DesignSpec};
use crate::fpga::device::Stratix10;
use crate::runtime::Manifest;
use crate::strassen::{self, StrassenConfig, StrassenMode, StrassenPlan};

/// Smallest dimension at which a blocking-incompatible shape is worth
/// sharding over the cluster instead of the CPU fallback.
const MIN_SHARD_DIM: u64 = 1024;

/// Smallest dimension at which the Auto-mode Strassen planner is even
/// consulted. The crossover sits at ≥16384 for every Table-I design
/// (see `examples/strassen_crossover.rs`), so below this bound the
/// sweep is guaranteed wasted work — routing small requests must stay
/// an index lookup, not four cost-model evaluations.
const MIN_STRASSEN_AUTO_DIM: u64 = 4096;

/// How a request's functional result will be computed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Route {
    /// A compiled AOT artifact matches the shape exactly.
    Artifact(String),
    /// No artifact: compute with the in-process blocked GEMM.
    Fallback,
    /// Too large for one card (DDR capacity, or no Table-I blocking at
    /// cluster-worthy size): shard over the multi-FPGA cluster.
    Sharded,
    /// The Strassen planner predicts a win within the error budget:
    /// recurse instead of running the classical schedule.
    Strassen,
}

/// The router: owns the manifest index and the design catalog.
#[derive(Clone, Debug)]
pub struct Router {
    /// (m, k, n) → 2-input matmul artifact.
    artifact_index: Vec<(usize, usize, usize, String)>,
    /// (m, k, n, p) → 3-input chained artifact ((A·B)·C).
    chain_index: Vec<(usize, usize, usize, usize, String)>,
    designs: Vec<DesignSpec>,
    /// Single-card DDR capacity in bytes (routing bound).
    card_ddr_bytes: u64,
    /// Strassen planner knobs (mode, max depth, default error budget).
    strassen: StrassenConfig,
}

impl Router {
    pub fn new(manifest: Option<&Manifest>) -> Self {
        let mut artifact_index = Vec::new();
        let mut chain_index = Vec::new();
        if let Some(m) = manifest {
            for a in &m.artifacts {
                match a.kind {
                    crate::runtime::ArtifactKind::Matmul if a.inputs.len() == 2 => {
                        artifact_index.push((
                            a.inputs[0].0,
                            a.inputs[0].1,
                            a.inputs[1].1,
                            a.name.clone(),
                        ));
                    }
                    crate::runtime::ArtifactKind::Chain if a.inputs.len() == 3 => {
                        chain_index.push((
                            a.inputs[0].0,
                            a.inputs[0].1,
                            a.inputs[1].1,
                            a.inputs[2].1,
                            a.name.clone(),
                        ));
                    }
                    _ => {}
                }
            }
        }
        Self {
            artifact_index,
            chain_index,
            designs: fitted_designs(),
            card_ddr_bytes: Stratix10::gx2800_520n().ddr_capacity_bytes(),
            strassen: StrassenConfig::default(),
        }
    }

    /// Replace the Strassen planner configuration (service wiring).
    pub fn with_strassen(mut self, config: StrassenConfig) -> Self {
        self.strassen = config;
        self
    }

    /// Replace the design catalog — a test hook for injecting
    /// degenerate entries (NaN f_max) the selection must survive.
    #[cfg(test)]
    fn with_designs(mut self, designs: Vec<DesignSpec>) -> Self {
        self.designs = designs;
        self
    }

    /// Functional route for an (m, k, n) problem. Capacity overflow
    /// wins (the cluster is the only place the problem fits); then the
    /// Strassen planner gets a look; classical fallback last.
    pub fn route(&self, m: usize, k: usize, n: usize) -> Route {
        if let Some((_, _, _, name)) =
            self.artifact_index.iter().find(|(am, ak, an, _)| (*am, *ak, *an) == (m, k, n))
        {
            return Route::Artifact(name.clone());
        }
        if self.should_shard(m as u64, k as u64, n as u64) {
            return Route::Sharded;
        }
        if self.strassen_plan(m as u64, k as u64, n as u64, None).is_some() {
            return Route::Strassen;
        }
        Route::Fallback
    }

    /// Strassen plan for the shape, with an optional per-request error
    /// budget overriding the configured default. `Some` only when the
    /// planner settles on a depth ≥ 1 — i.e. the recursion is predicted
    /// to win (or is forced) *and* the budget admits it.
    pub fn strassen_plan(
        &self,
        m: u64,
        k: u64,
        n: u64,
        budget: Option<f64>,
    ) -> Option<StrassenPlan> {
        if self.strassen.mode == StrassenMode::Off {
            return None;
        }
        // Auto mode never wins below the crossover scale: skip the
        // sweep entirely so small-request routing stays cheap. Force
        // mode (a test/benchmark hook) still plans any shape.
        if self.strassen.mode == StrassenMode::Auto
            && m.min(k).min(n) < MIN_STRASSEN_AUTO_DIM
        {
            return None;
        }
        let mut config = self.strassen;
        if let Some(b) = budget {
            config.error_budget = b;
        }
        let design = self.timing_design(m, k, n).or_else(|| self.best_padded_design())?;
        let plan = strassen::plan(design, m, k, n, &config);
        (plan.depth >= 1).then_some(plan)
    }

    /// Highest-peak fitted design, for shapes no blocking accepts
    /// exactly: Strassen pads its leaves up to the blocking anyway, so
    /// the planner just needs *a* calibrated design to price against.
    fn best_padded_design(&self) -> Option<OffchipDesign> {
        // A corrupt catalog entry (NaN f_max) must lose, not panic or —
        // `total_cmp` ranks NaN above every finite peak — win the max.
        self.designs
            .iter()
            .filter_map(|d| {
                Some(OffchipDesign {
                    blocking: d.level1()?,
                    fmax_mhz: d.fmax_mhz.filter(|f| f.is_finite())?,
                    controller_efficiency: 0.97,
                })
            })
            .max_by(|a, b| a.peak_gflops().total_cmp(&b.peak_gflops()))
    }

    /// Functional route for a chained (A·B)·C problem with shapes
    /// (m × k)·(k × n)·(n × p).
    pub fn route_chain(&self, m: usize, k: usize, n: usize, p: usize) -> Route {
        if let Some((.., name)) = self
            .chain_index
            .iter()
            .find(|(am, ak, an, ap, _)| (*am, *ak, *an, *ap) == (m, k, n, p))
        {
            return Route::Artifact(name.clone());
        }
        // Chains shard leg by leg; either leg exceeding one card — the
        // first (m × k)·(k × n) or the second (m × n)·(n × p) — sends
        // the whole chain to the cluster.
        if self.should_shard(m as u64, k as u64, n as u64)
            || self.should_shard(m as u64, n as u64, p as u64)
        {
            return Route::Sharded;
        }
        Route::Fallback
    }

    /// A problem leaves the single-card path when its working set
    /// exceeds the 520N's DDR, or when no Table-I blocking accepts the
    /// shape and it is big enough that the blocked-CPU fallback would be
    /// the bottleneck.
    pub fn should_shard(&self, m: u64, k: u64, n: u64) -> bool {
        let footprint = (m * k + k * n + m * n) * 4;
        if footprint > self.card_ddr_bytes {
            return true;
        }
        self.timing_design(m, k, n).is_none()
            && m.min(k).min(n) >= MIN_SHARD_DIM
    }

    /// Pick the FPGA design whose blocking constraints the shape
    /// satisfies, preferring highest peak throughput (F > G > …); the
    /// request is timed on that design's simulator.
    pub fn timing_design(&self, m: u64, k: u64, n: u64) -> Option<OffchipDesign> {
        // Non-finite f_max entries are screened out before the sort:
        // `total_cmp` never panics, but it orders NaN above every
        // finite peak, so a NaN entry left in would win the catalog.
        let mut candidates: Vec<(&DesignSpec, Level1Blocking)> = self
            .designs
            .iter()
            .filter_map(|d| d.level1().map(|b| (d, b)))
            .filter(|(d, b)| {
                b.validate_offchip(m, n, k).is_ok()
                    && d.fmax_mhz.is_some_and(|f| f.is_finite())
            })
            .collect();
        candidates.sort_by(|(da, a), (db, b)| {
            let pa = 2.0 * a.array.dsps() as f64 * da.fmax_mhz.unwrap();
            let pb = 2.0 * b.array.dsps() as f64 * db.fmax_mhz.unwrap();
            pb.total_cmp(&pa)
        });
        candidates.first().map(|(d, b)| OffchipDesign {
            blocking: *b,
            fmax_mhz: d.fmax_mhz.unwrap(),
            controller_efficiency: 0.97,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::Path;

    fn manifest() -> Manifest {
        let doc = r#"{
          "format": "hlo-text-v1",
          "artifacts": [
            {"name": "mm_h_64", "file": "a.hlo.txt", "kind": "matmul",
             "inputs": [[64, 64], [64, 64]],
             "tile": {"di0":32,"dj0":32,"dk0":4,"dp":4,"di1":64,"dj1":64}},
            {"name": "chain_tpu_256", "file": "c.hlo.txt", "kind": "chain",
             "inputs": [[256,256],[256,256],[256,256]],
             "tile": {"di0":64,"dj0":64,"dk0":64,"dp":32,"di1":128,"dj1":128}}
          ]}"#;
        Manifest::parse(doc, Path::new("/tmp")).unwrap()
    }

    #[test]
    fn routes_exact_artifact_match() {
        let r = Router::new(Some(&manifest()));
        assert_eq!(r.route(64, 64, 64), Route::Artifact("mm_h_64".into()));
        assert_eq!(r.route(64, 64, 128), Route::Fallback);
        // Chain artifacts never route plain matmuls.
        assert_eq!(r.route(256, 256, 256), Route::Fallback);
    }

    #[test]
    fn routes_chain_artifacts() {
        let r = Router::new(Some(&manifest()));
        assert_eq!(
            r.route_chain(256, 256, 256, 256),
            Route::Artifact("chain_tpu_256".into())
        );
        assert_eq!(r.route_chain(256, 256, 256, 128), Route::Fallback);
        assert_eq!(r.route_chain(64, 64, 64, 64), Route::Fallback);
        // A chain whose *second* leg is cluster-worthy shards even when
        // the first leg fits a single card: (2048³ fits design G, but
        // the (2048 × 2048)·(2048 × 1100) leg matches no blocking).
        assert_eq!(r.route_chain(1100, 1100, 1100, 1100), Route::Sharded);
        assert_eq!(r.route_chain(2048, 2048, 2048, 1100), Route::Sharded);
    }

    #[test]
    fn routes_without_manifest() {
        let r = Router::new(None);
        assert_eq!(r.route(64, 64, 64), Route::Fallback);
        assert_eq!(r.route_chain(256, 256, 256, 256), Route::Fallback);
    }

    #[test]
    fn large_blocking_incompatible_shapes_shard() {
        let r = Router::new(None);
        // No Table-I blocking divides 1100, and it's cluster-worthy.
        assert!(r.timing_design(1100, 1100, 1100).is_none());
        assert_eq!(r.route(1100, 1100, 1100), Route::Sharded);
        // Small incompatible shapes stay on the CPU fallback.
        assert_eq!(r.route(100, 100, 100), Route::Fallback);
    }

    #[test]
    fn capacity_overflow_shards_even_when_blocking_fits() {
        let r = Router::new(None);
        // 65536³ divides design G's blocking but needs 48 GiB > 32 GiB.
        assert!(r.timing_design(65536, 65536, 65536).is_some());
        assert_eq!(r.route(65536, 65536, 65536), Route::Sharded);
        // The paper's largest problem (21504³, 5.5 GB) stays single-card
        // — past the Strassen crossover, so the algorithmic route wins.
        assert_eq!(r.route(21504, 21504, 21504), Route::Strassen);
    }

    #[test]
    fn strassen_routing_decisions() {
        let r = Router::new(None);
        // Past the crossover the planner predicts a win (depth >= 1).
        let plan = r.strassen_plan(21504, 21504, 21504, None).expect("plan");
        assert!(plan.depth >= 1);
        assert!(plan.speedup_vs_classical() > 1.0);
        assert_eq!(r.route(16384, 16384, 16384), Route::Strassen);
        // Below the crossover the classical schedule stays faster.
        assert!(r.strassen_plan(8192, 8192, 8192, None).is_none());
        assert_eq!(r.route(8192, 8192, 8192), Route::Fallback);
        assert_eq!(r.route(512, 512, 512), Route::Fallback);
        // Sharding (capacity / no blocking at scale) still wins first.
        assert_eq!(r.route(65536, 65536, 65536), Route::Sharded);
        assert_eq!(r.route(1100, 1100, 1100), Route::Sharded);
        // A hopeless per-request budget disables the plan.
        assert!(r.strassen_plan(21504, 21504, 21504, Some(1e-12)).is_none());
    }

    #[test]
    fn strassen_mode_off_and_force() {
        use crate::strassen::{StrassenConfig, StrassenMode};
        let off = Router::new(None)
            .with_strassen(StrassenConfig { mode: StrassenMode::Off, ..Default::default() });
        assert_eq!(off.route(21504, 21504, 21504), Route::Fallback);
        // Force routes even tiny blocking-incompatible shapes (the
        // planner prices them on the highest-peak design, padded).
        let force = Router::new(None)
            .with_strassen(StrassenConfig { mode: StrassenMode::Force(2), ..Default::default() });
        assert_eq!(force.route(96, 96, 96), Route::Strassen);
        let p = force.strassen_plan(96, 96, 96, None).unwrap();
        assert_eq!(p.depth, 2);
        assert_eq!(p.chosen().leaves, 49);
    }

    #[test]
    fn timing_design_prefers_highest_peak() {
        let r = Router::new(None);
        // 20160³ satisfies C (672) and E (576) but not G–N (512) or F
        // (dj1=640 ∤ 20160): C's 3462 GFLOPS peak beats E's 3391.
        let d = r.timing_design(20160, 20160, 20160).unwrap();
        assert_eq!(d.blocking.array.di0, 28, "expected design C, got {d:?}");
        // (4480, 4480, 4480): only F's rectangular (560, 640) blocking
        // divides both extents (4480 = 8·560 = 7·640).
        let d = r.timing_design(4480, 4480, 4480).unwrap();
        assert_eq!(d.blocking.array.di0, 70, "expected design F, got {d:?}");
        // 512-cube: only the d1=512 designs qualify; best is H (408 MHz).
        let d = r.timing_design(512, 512, 512).unwrap();
        assert_eq!((d.blocking.array.di0, d.blocking.array.dj0), (32, 32));
        assert_eq!(d.fmax_mhz, 408.0);
    }

    #[test]
    fn timing_design_none_for_odd_shapes() {
        let r = Router::new(None);
        assert!(r.timing_design(100, 100, 100).is_none());
    }

    #[test]
    fn degenerate_fmax_entries_are_screened_not_sorted() {
        use crate::dse::configs::fitted_designs;
        use crate::systolic::ArraySize;
        let mut designs = fitted_designs();
        // Corrupt entries with more DSPs than any real design. Under
        // the old `partial_cmp(..).unwrap()` sort the NaN panicked;
        // under a bare `total_cmp` sort NaN (and +inf) would rank
        // above every finite peak and win the whole catalog.
        for fmax in [f64::NAN, f64::INFINITY] {
            designs.push(DesignSpec {
                id: "corrupt",
                array: ArraySize::new(64, 64, 8, 8),
                fmax_mhz: Some(fmax),
                blocking: Some((512, 512)),
                sweep: &[],
            });
        }
        let r = Router::new(None).with_designs(designs);
        // 512-cube: the corrupt entries accept the shape but must be
        // screened out; the finite winner stays design H (408 MHz).
        let d = r.timing_design(512, 512, 512).expect("finite design");
        assert_eq!((d.blocking.array.di0, d.blocking.array.dj0), (32, 32));
        assert_eq!(d.fmax_mhz, 408.0);
        // The padded-design fallback (nothing fits 96 exactly) screens
        // the same way instead of panicking in its max_by.
        let force = r.with_strassen(StrassenConfig {
            mode: StrassenMode::Force(1),
            ..Default::default()
        });
        let p = force.strassen_plan(96, 96, 96, None).expect("padded plan");
        assert!(p.depth >= 1);
        assert!(p.design.fmax_mhz.is_finite(), "picked {:?}", p.design);
    }
}
