//! Shape → execution-route and timing-design selection.

use crate::blocked::{Level1Blocking, OffchipDesign};
use crate::dse::configs::{fitted_designs, DesignSpec};
use crate::runtime::Manifest;

/// How a request's functional result will be computed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Route {
    /// A compiled AOT artifact matches the shape exactly.
    Artifact(String),
    /// No artifact: compute with the in-process blocked GEMM.
    Fallback,
}

/// The router: owns the manifest index and the design catalog.
#[derive(Clone, Debug)]
pub struct Router {
    artifact_index: Vec<(usize, usize, usize, String)>,
    designs: Vec<DesignSpec>,
}

impl Router {
    pub fn new(manifest: Option<&Manifest>) -> Self {
        let mut artifact_index = Vec::new();
        if let Some(m) = manifest {
            for a in &m.artifacts {
                if a.kind == crate::runtime::ArtifactKind::Matmul && a.inputs.len() == 2 {
                    artifact_index.push((
                        a.inputs[0].0,
                        a.inputs[0].1,
                        a.inputs[1].1,
                        a.name.clone(),
                    ));
                }
            }
        }
        Self { artifact_index, designs: fitted_designs() }
    }

    /// Functional route for an (m, k, n) problem.
    pub fn route(&self, m: usize, k: usize, n: usize) -> Route {
        self.artifact_index
            .iter()
            .find(|(am, ak, an, _)| (*am, *ak, *an) == (m, k, n))
            .map(|(_, _, _, name)| Route::Artifact(name.clone()))
            .unwrap_or(Route::Fallback)
    }

    /// Pick the FPGA design whose blocking constraints the shape
    /// satisfies, preferring highest peak throughput (F > G > …); the
    /// request is timed on that design's simulator.
    pub fn timing_design(&self, m: u64, k: u64, n: u64) -> Option<OffchipDesign> {
        let mut candidates: Vec<(&DesignSpec, Level1Blocking)> = self
            .designs
            .iter()
            .filter_map(|d| d.level1().map(|b| (d, b)))
            .filter(|(d, b)| {
                b.validate_offchip(m, n, k).is_ok() && d.fmax_mhz.is_some()
            })
            .collect();
        candidates.sort_by(|(da, a), (db, b)| {
            let pa = 2.0 * a.array.dsps() as f64 * da.fmax_mhz.unwrap();
            let pb = 2.0 * b.array.dsps() as f64 * db.fmax_mhz.unwrap();
            pb.partial_cmp(&pa).unwrap()
        });
        candidates.first().map(|(d, b)| OffchipDesign {
            blocking: *b,
            fmax_mhz: d.fmax_mhz.unwrap(),
            controller_efficiency: 0.97,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::Path;

    fn manifest() -> Manifest {
        let doc = r#"{
          "format": "hlo-text-v1",
          "artifacts": [
            {"name": "mm_h_64", "file": "a.hlo.txt", "kind": "matmul",
             "inputs": [[64, 64], [64, 64]],
             "tile": {"di0":32,"dj0":32,"dk0":4,"dp":4,"di1":64,"dj1":64}},
            {"name": "chain_tpu_256", "file": "c.hlo.txt", "kind": "chain",
             "inputs": [[256,256],[256,256],[256,256]],
             "tile": {"di0":64,"dj0":64,"dk0":64,"dp":32,"di1":128,"dj1":128}}
          ]}"#;
        Manifest::parse(doc, Path::new("/tmp")).unwrap()
    }

    #[test]
    fn routes_exact_artifact_match() {
        let r = Router::new(Some(&manifest()));
        assert_eq!(r.route(64, 64, 64), Route::Artifact("mm_h_64".into()));
        assert_eq!(r.route(64, 64, 128), Route::Fallback);
        // Chain artifacts never route plain matmuls.
        assert_eq!(r.route(256, 256, 256), Route::Fallback);
    }

    #[test]
    fn routes_without_manifest() {
        let r = Router::new(None);
        assert_eq!(r.route(64, 64, 64), Route::Fallback);
    }

    #[test]
    fn timing_design_prefers_highest_peak() {
        let r = Router::new(None);
        // 20160³ satisfies C (672) and E (576) but not G–N (512) or F
        // (dj1=640 ∤ 20160): C's 3462 GFLOPS peak beats E's 3391.
        let d = r.timing_design(20160, 20160, 20160).unwrap();
        assert_eq!(d.blocking.array.di0, 28, "expected design C, got {d:?}");
        // (4480, 4480, 4480): only F's rectangular (560, 640) blocking
        // divides both extents (4480 = 8·560 = 7·640).
        let d = r.timing_design(4480, 4480, 4480).unwrap();
        assert_eq!(d.blocking.array.di0, 70, "expected design F, got {d:?}");
        // 512-cube: only the d1=512 designs qualify; best is H (408 MHz).
        let d = r.timing_design(512, 512, 512).unwrap();
        assert_eq!((d.blocking.array.di0, d.blocking.array.dj0), (32, 32));
        assert_eq!(d.fmax_mhz, 408.0);
    }

    #[test]
    fn timing_design_none_for_odd_shapes() {
        let r = Router::new(None);
        assert!(r.timing_design(100, 100, 100).is_none());
    }
}
