//! Open-loop serving harness: virtual-time replay of a workload trace
//! through the admission pipeline onto a simulated card fleet.
//!
//! Closed-loop benchmarks (submit, wait, submit) can never overload
//! anything — the client self-throttles. This harness is open-loop:
//! arrivals come from a [`WorkloadGen`] trace at their own rate
//! (Poisson / bursty / diurnal), regardless of whether the fleet keeps
//! up, which is what "heavy traffic from millions of users" actually
//! looks like at the front door. Time is simulated seconds, so a 2×
//! overload minute replays in milliseconds and every run is
//! bit-reproducible from the workload seed.
//!
//! The pipeline per arrival: bounded-ingress admission (shed or admit,
//! possibly evicting lower priority; [`IngressQueue`]), deficit
//! round-robin batch formation with deadline-aware close
//! ([`Batcher::close_by`]), execution on the earliest-free card under
//! a flops/throughput + dispatch-overhead cost model, and queue-
//! pressure samples into a [`BurnMonitor`] whose sustained burn
//! activates a hot spare or grows the fleet — the same
//! watermark-style elastic loop the cluster layer runs, now driven by
//! user traffic. Chaos kills requeue in-flight batches; no admitted
//! request is ever lost.

use super::admission::{AdmissionPolicy, IngressQueue, Offer, QueuedJob, ShedReason};
use super::batcher::Batcher;
use super::metrics::Metrics;
use super::workload::{TenantSpec, TraceEntry, WorkloadGen};
use crate::observe::slo::{BurnMonitor, SloPolicy};
use crate::perfmodel::flop_count;
use crate::util::stats::LogHistogram;

/// Serving-harness configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Cards serving at trace start.
    pub servers: usize,
    /// Hot spares: pressure growth (and emergency replacement of dead
    /// cards) activates these before attaching brand-new cards.
    pub hot_spares: usize,
    /// Effective per-card throughput of the cost model, GFLOP/s
    /// (design G sustains ~85% of its 3260 GFLOP/s eq. 5 peak).
    pub card_gflops: f64,
    /// Fixed per-dispatch overhead, seconds — the launch/DMA cost a
    /// batch amortizes over its members.
    pub dispatch_overhead_s: f64,
    pub max_batch: usize,
    /// Fixed batching window, seconds (the baseline close rule).
    pub batch_window_s: f64,
    /// Full pipeline (priority lanes + DRR + doomed shedding +
    /// deadline-aware close) vs the FIFO/fixed-window baseline.
    pub deadline_aware: bool,
    pub policy: AdmissionPolicy,
    /// Queue-pressure watermark, seconds of backlog per active card:
    /// sustained pressure above it (both burn windows of `slo`) grows
    /// the fleet. None disables pressure growth.
    pub pressure_watermark: Option<f64>,
    /// Burn windows / threshold / growth budget for pressure growth
    /// (`p99_latency_s` is overridden by the watermark).
    pub slo: SloPolicy,
    /// Chaos kills: (time, server index at trace start).
    pub kills: Vec<(f64, usize)>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            servers: 4,
            hot_spares: 0,
            card_gflops: 2770.0,
            dispatch_overhead_s: 5e-4,
            max_batch: 8,
            batch_window_s: 2e-3,
            deadline_aware: true,
            policy: AdmissionPolicy::default(),
            pressure_watermark: None,
            slo: SloPolicy::default(),
            kills: Vec::new(),
        }
    }
}

/// One completed request.
#[derive(Clone, Debug, PartialEq)]
pub struct ServedRequest {
    pub id: u64,
    /// Tenant index in the workload's tenant table.
    pub tenant: usize,
    pub flops: u64,
    pub latency_s: f64,
    /// Deadline met (true when the request carried none).
    pub met: bool,
    pub finish_s: f64,
}

/// One shed request.
#[derive(Clone, Debug, PartialEq)]
pub struct ShedRecord {
    pub id: u64,
    pub tenant: usize,
    pub reason: ShedReason,
    pub at_s: f64,
}

/// Per-tenant rollup.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantStat {
    pub name: String,
    pub weight: u32,
    pub completed: u64,
    pub shed: u64,
    pub deadline_met: u64,
    /// Service seconds delivered (the DRR fair-share currency).
    pub served_service_s: f64,
    pub p99_s: f64,
}

/// What one open-loop run delivered.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeOutcome {
    pub offered: usize,
    pub served: Vec<ServedRequest>,
    pub shed: Vec<ShedRecord>,
    pub tenants: Vec<TenantStat>,
    pub batches: u64,
    pub spare_activations: usize,
    pub grown_cards: usize,
    pub deadline_met: u64,
    pub deadline_missed: u64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub makespan_s: f64,
    /// Goodput: FLOP/s of deadline-met requests over the makespan.
    pub goodput_flops_per_s: f64,
    /// All served FLOP/s (late answers included).
    pub served_flops_per_s: f64,
    pub offered_flops_per_s: f64,
    /// Peak queue pressure observed (seconds of backlog per card).
    pub pressure_peak: f64,
    /// Kill / growth narrative, deterministic.
    pub events: Vec<String>,
}

impl ServeOutcome {
    /// Fraction of offered requests turned away.
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.shed.len() as f64 / self.offered as f64
    }

    /// Weighted fair-share deviation: max over tenants of the relative
    /// gap between the tenant's served-service share and its weight
    /// share. 0.0 with fewer than two tenants or no service.
    pub fn fairness_bound(&self) -> f64 {
        if self.tenants.len() < 2 {
            return 0.0;
        }
        let total_service: f64 = self.tenants.iter().map(|t| t.served_service_s).sum();
        let total_weight: f64 = self.tenants.iter().map(|t| t.weight.max(1) as f64).sum();
        if total_service <= 0.0 {
            return 0.0;
        }
        self.tenants
            .iter()
            .map(|t| {
                let share = t.served_service_s / total_service;
                let fair = t.weight.max(1) as f64 / total_weight;
                (share - fair).abs() / fair
            })
            .fold(0.0, f64::max)
    }

    /// Fold the run into the service gauges: admitted/shed/goodput
    /// counters, the latency histogram, and the per-tenant latency
    /// gauges — so a harness run scrapes exactly like live traffic.
    pub fn record_into(&self, m: &Metrics) {
        Metrics::add(&m.admitted, self.served.len() as u64);
        Metrics::add(&m.shed, self.shed.len() as u64);
        Metrics::add(&m.deadline_met, self.deadline_met);
        Metrics::add(&m.deadline_missed, self.deadline_missed);
        for r in &self.served {
            m.record_latency(r.latency_s);
            if let Some(t) = self.tenants.get(r.tenant) {
                m.record_tenant_latency(&t.name, r.latency_s);
            }
            if r.met {
                Metrics::add(&m.goodput_flops, r.flops);
            }
            m.add_flops(r.flops);
        }
    }

    /// Human summary for the CLI and examples.
    pub fn render(&self) -> String {
        let mut out = format!(
            "served {}/{} ({} shed, {:.1}%), {} batches over {:.3} s\n\
             goodput {:.1} GFLOP/s of {:.1} offered ({:.1} served); \
             deadlines {} met / {} missed\n\
             latency p50 {:.2} ms, p99 {:.2} ms; peak pressure {:.3} s/card; \
             +{} spare(s), +{} grown card(s)\n",
            self.served.len(),
            self.offered,
            self.shed.len(),
            100.0 * self.shed_rate(),
            self.batches,
            self.makespan_s,
            self.goodput_flops_per_s / 1e9,
            self.offered_flops_per_s / 1e9,
            self.served_flops_per_s / 1e9,
            self.deadline_met,
            self.deadline_missed,
            self.p50_s * 1e3,
            self.p99_s * 1e3,
            self.pressure_peak,
            self.spare_activations,
            self.grown_cards,
        );
        for t in &self.tenants {
            out.push_str(&format!(
                "  tenant {:<8} w{} — {} served / {} shed, {} met, p99 {:.2} ms\n",
                t.name,
                t.weight,
                t.completed,
                t.shed,
                t.deadline_met,
                t.p99_s * 1e3
            ));
        }
        for e in &self.events {
            out.push_str(&format!("  {e}\n"));
        }
        out
    }
}

struct Card {
    free_at: f64,
    kill_at: Option<f64>,
    dead: bool,
}

/// Replay `count` requests from `gen` through the admission pipeline.
/// Deterministic from the workload seed and the config.
pub fn simulate_serve(gen: &WorkloadGen, count: u64, cfg: &ServeConfig) -> ServeOutcome {
    let trace = gen.trace(count);
    simulate_serve_trace(&trace, &gen.tenants, cfg)
}

/// Replay an explicit trace (the lower-level entry the property tests
/// drive directly). `tenants` may be empty: one anonymous tenant.
pub fn simulate_serve_trace(
    trace: &[TraceEntry],
    tenants: &[TenantSpec],
    cfg: &ServeConfig,
) -> ServeOutcome {
    let table: Vec<TenantSpec> = if tenants.is_empty() {
        vec![TenantSpec::new("default", 1, super::admission::Priority::Normal, None)]
    } else {
        tenants.to_vec()
    };
    let aware = cfg.deadline_aware;
    // The FIFO baseline folds every tenant into one strict
    // arrival-order queue on the Normal lane: no lanes, no fair share,
    // no doomed shedding, fixed-window close.
    let weights: Vec<u32> =
        if aware { table.iter().map(|t| t.weight.max(1)).collect() } else { vec![1] };
    let mut queue = IngressQueue::new(
        &weights,
        cfg.policy.queue_capacity,
        aware && cfg.policy.shed_doomed,
    );
    let mut batcher = Batcher::new(cfg.max_batch.max(1));
    if aware {
        if let Some(t) = cfg.policy.latency_target_s {
            batcher = batcher.with_latency_target(t);
        }
    }

    let mut cards: Vec<Card> = (0..cfg.servers.max(1))
        .map(|i| Card {
            free_at: 0.0,
            kill_at: cfg.kills.iter().find(|(_, s)| *s == i).map(|(t, _)| *t),
            dead: false,
        })
        .collect();
    let mut spares_left = cfg.hot_spares;
    let mut spare_activations = 0usize;
    let mut grown_cards = 0usize;
    let mut pressure_grown = 0usize;
    let mut monitor = cfg
        .pressure_watermark
        .map(|w| BurnMonitor::new(SloPolicy { p99_latency_s: w, ..cfg.slo }));
    let mut last_growth = f64::NEG_INFINITY;

    let mut served: Vec<ServedRequest> = Vec::new();
    let mut shed: Vec<ShedRecord> = Vec::new();
    let mut events: Vec<String> = Vec::new();
    let mut batches = 0u64;
    let mut pressure_peak = 0.0f64;

    let job_of = |e: &TraceEntry| -> QueuedJob {
        let mut flops = flop_count(e.m as u64, e.n as u64, e.k as u64);
        if e.chained {
            flops *= 2;
        }
        // Price the job at its amortized cost of one card's time —
        // compute plus a full-batch share of dispatch overhead — so
        // queued service seconds predict wall waits accurately.
        let service_s = flops as f64 / (cfg.card_gflops.max(1e-9) * 1e9)
            + cfg.dispatch_overhead_s / cfg.max_batch.max(1) as f64;
        let deadline_s = e
            .deadline_s
            .or(cfg.policy.default_deadline_s)
            .map(|d| e.arrival_s + d);
        QueuedJob {
            id: e.id,
            tenant: if aware { e.tenant.min(weights.len() - 1) } else { 0 },
            lane: if aware { e.priority.lane() } else { 1 },
            arrival_s: e.arrival_s,
            deadline_s,
            service_s,
            flops,
            shape: (e.m, e.k, e.n),
        }
    };

    let mut i = 0usize;
    let mut now = 0.0f64;
    loop {
        let next_arrival = trace.get(i).map(|e| e.arrival_s);
        if queue.depth() == 0 {
            match next_arrival {
                Some(_) => {
                    let e = &trace[i];
                    i += 1;
                    now = now.max(e.arrival_s);
                    arrive(
                        e,
                        &job_of(e),
                        trace,
                        &mut queue,
                        &cards,
                        &mut shed,
                        &mut pressure_peak,
                    );
                    grow_on_pressure(
                        e.arrival_s,
                        &queue,
                        &mut monitor,
                        cfg,
                        &mut last_growth,
                        &mut pressure_grown,
                        &mut cards,
                        &mut spares_left,
                        &mut spare_activations,
                        &mut grown_cards,
                        &mut events,
                    );
                    continue;
                }
                None => break,
            }
        }
        // Earliest-free living card; if the whole fleet is dead, the
        // controller replaces capacity on the spot (spare first) — the
        // queue must drain, chaos or not.
        let Some((cidx, cfree)) = cards
            .iter()
            .enumerate()
            .filter(|(_, c)| !c.dead)
            .map(|(idx, c)| (idx, c.free_at))
            .min_by(|a, b| a.1.total_cmp(&b.1))
        else {
            add_card(
                now,
                "fleet dead; emergency replacement",
                &mut cards,
                &mut spares_left,
                &mut spare_activations,
                &mut grown_cards,
                &mut events,
            );
            continue;
        };
        let ready = cfree.max(now);
        // Batch close: a full same-shape batch (or a saturated queue)
        // dispatches immediately; otherwise hold for the window,
        // clipped by the latency target / oldest member's deadline
        // slack when deadline-aware.
        let close = if queue.has_full_batch(cfg.max_batch) || queue.depth() >= cfg.max_batch {
            ready
        } else {
            let oldest = queue.oldest().expect("depth > 0");
            batcher.close_by(
                oldest.arrival_s,
                cfg.batch_window_s,
                oldest.service_s + cfg.dispatch_overhead_s,
                if aware { oldest.deadline_s } else { None },
            )
        };
        let start = ready.max(close);
        if let Some(t) = next_arrival {
            if t < start {
                let e = &trace[i];
                i += 1;
                now = now.max(t);
                arrive(
                    e,
                    &job_of(e),
                    trace,
                    &mut queue,
                    &cards,
                    &mut shed,
                    &mut pressure_peak,
                );
                grow_on_pressure(
                    e.arrival_s,
                    &queue,
                    &mut monitor,
                    cfg,
                    &mut last_growth,
                    &mut pressure_grown,
                    &mut cards,
                    &mut spares_left,
                    &mut spare_activations,
                    &mut grown_cards,
                    &mut events,
                );
                continue;
            }
        }
        now = start;
        let mut batch = queue.next_batch(cfg.max_batch);
        if aware && cfg.policy.shed_doomed {
            // A job whose deadline passed while it queued can no
            // longer produce goodput: drop it at dispatch instead of
            // spending card time confirming the miss.
            batch.retain(|j| {
                let live = j.deadline_s.is_none_or(|d| start <= d + 1e-12);
                if !live {
                    shed.push(ShedRecord {
                        id: j.id,
                        tenant: trace[j.id as usize].tenant,
                        reason: ShedReason::Doomed,
                        at_s: start,
                    });
                }
                live
            });
            if batch.is_empty() {
                continue;
            }
        }
        // Each member's service_s carries overhead/max_batch already;
        // the remainder charges an underfull batch its real share.
        let exec = cfg.dispatch_overhead_s
            * (1.0 - batch.len() as f64 / cfg.max_batch.max(1) as f64)
            + batch.iter().map(|j| j.service_s).sum::<f64>();
        if let Some(kt) = cards[cidx].kill_at.filter(|&kt| kt < start + exec) {
            // The card dies before this batch completes: nothing is
            // lost — the batch goes back to the front of its queues.
            cards[cidx].dead = true;
            cards[cidx].kill_at = None;
            events.push(format!(
                "t={kt:.4}s card {cidx} killed; {} in-flight job(s) requeued",
                batch.len()
            ));
            queue.requeue_front(batch);
            continue;
        }
        let finish = start + exec;
        cards[cidx].free_at = finish;
        batches += 1;
        for j in batch {
            let met = j.deadline_s.is_none_or(|d| finish <= d + 1e-12);
            served.push(ServedRequest {
                id: j.id,
                tenant: trace[j.id as usize].tenant,
                flops: j.flops,
                latency_s: finish - j.arrival_s,
                met,
                finish_s: finish,
            });
        }
    }

    // Rollups.
    let makespan_s = served
        .iter()
        .map(|r| r.finish_s)
        .fold(trace.last().map_or(0.0, |e| e.arrival_s), f64::max)
        .max(1e-9);
    let mut hist = LogHistogram::new();
    let mut tenant_hists: Vec<LogHistogram> = vec![LogHistogram::new(); table.len()];
    let mut stats: Vec<TenantStat> = table
        .iter()
        .map(|t| TenantStat {
            name: t.name.clone(),
            weight: t.weight.max(1),
            completed: 0,
            shed: 0,
            deadline_met: 0,
            served_service_s: 0.0,
            p99_s: 0.0,
        })
        .collect();
    let mut met_flops = 0u64;
    let mut all_flops = 0u64;
    let (mut deadline_met, mut deadline_missed) = (0u64, 0u64);
    for r in &served {
        hist.record(r.latency_s);
        all_flops += r.flops;
        if r.met {
            deadline_met += 1;
            met_flops += r.flops;
        } else {
            deadline_missed += 1;
        }
        if let Some(s) = stats.get_mut(r.tenant) {
            s.completed += 1;
            if r.met {
                s.deadline_met += 1;
            }
            s.served_service_s += r.flops as f64 / (cfg.card_gflops.max(1e-9) * 1e9);
            tenant_hists[r.tenant].record(r.latency_s);
        }
    }
    for rec in &shed {
        if let Some(s) = stats.get_mut(rec.tenant) {
            s.shed += 1;
        }
    }
    for (s, h) in stats.iter_mut().zip(&tenant_hists) {
        s.p99_s = if h.is_empty() { 0.0 } else { h.quantile(0.99) };
    }
    ServeOutcome {
        offered: trace.len(),
        deadline_met,
        deadline_missed,
        p50_s: if hist.is_empty() { 0.0 } else { hist.quantile(0.50) },
        p99_s: if hist.is_empty() { 0.0 } else { hist.quantile(0.99) },
        makespan_s,
        goodput_flops_per_s: met_flops as f64 / makespan_s,
        served_flops_per_s: all_flops as f64 / makespan_s,
        offered_flops_per_s: WorkloadGen::offered_flops(trace),
        pressure_peak,
        served,
        shed,
        tenants: stats,
        batches,
        spare_activations,
        grown_cards,
        events,
    }
}

/// Offer one arrival to the queue, recording sheds and evictions.
fn arrive(
    e: &TraceEntry,
    job: &QueuedJob,
    trace: &[TraceEntry],
    queue: &mut IngressQueue,
    cards: &[Card],
    shed: &mut Vec<ShedRecord>,
    pressure_peak: &mut f64,
) {
    let alive = cards.iter().filter(|c| !c.dead).count();
    match queue.offer(job.clone(), e.arrival_s, alive) {
        Offer::Admitted { evicted } => {
            if let Some(v) = evicted {
                shed.push(ShedRecord {
                    id: v.id,
                    tenant: trace[v.id as usize].tenant,
                    reason: ShedReason::Evicted,
                    at_s: e.arrival_s,
                });
            }
        }
        Offer::Shed(reason) => {
            shed.push(ShedRecord { id: e.id, tenant: e.tenant, reason, at_s: e.arrival_s });
        }
    }
    *pressure_peak = pressure_peak.max(queue.pressure(alive));
}

/// Feed the queue-pressure sample to the burn monitor and grow the
/// fleet on sustained burn (spares first), under cooldown and budget.
#[allow(clippy::too_many_arguments)]
fn grow_on_pressure(
    at: f64,
    queue: &IngressQueue,
    monitor: &mut Option<BurnMonitor>,
    cfg: &ServeConfig,
    last_growth: &mut f64,
    pressure_grown: &mut usize,
    cards: &mut Vec<Card>,
    spares_left: &mut usize,
    spare_activations: &mut usize,
    grown_cards: &mut usize,
    events: &mut Vec<String>,
) {
    let Some(mon) = monitor.as_mut() else { return };
    let alive = cards.iter().filter(|c| !c.dead).count();
    mon.record(at, queue.pressure(alive));
    if *pressure_grown >= cfg.slo.max_growth || at - *last_growth < cfg.slo.window_s {
        return;
    }
    if let Some((short, long)) = mon.evaluate(at) {
        *pressure_grown += 1;
        *last_growth = at;
        add_card(
            at,
            &format!("queue pressure burning (short {short:.2}, long {long:.2})"),
            cards,
            spares_left,
            spare_activations,
            grown_cards,
            events,
        );
    }
}

/// Add serving capacity at `at`: activate a hot spare when one
/// remains, otherwise attach a new card.
fn add_card(
    at: f64,
    why: &str,
    cards: &mut Vec<Card>,
    spares_left: &mut usize,
    spare_activations: &mut usize,
    grown_cards: &mut usize,
    events: &mut Vec<String>,
) {
    let what = if *spares_left > 0 {
        *spares_left -= 1;
        *spare_activations += 1;
        "spare activated"
    } else {
        *grown_cards += 1;
        "card grown"
    };
    events.push(format!("t={at:.4}s {why} -> {what} (card {})", cards.len()));
    cards.push(Card { free_at: at, kill_at: None, dead: false });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::workload::ArrivalModel;

    /// Overload knob: offered FLOP/s ≈ `factor` × fleet capacity.
    fn overload_gen(seed: u64, cfg: &ServeConfig, factor: f64) -> WorkloadGen {
        // multi_tenant serves 256³ jobs: flops per request is fixed.
        let flops = flop_count(256, 256, 256) as f64;
        // Per-batch overhead caps per-card job rate at full batches.
        let per_job_s =
            flops / (cfg.card_gflops * 1e9) + cfg.dispatch_overhead_s / cfg.max_batch as f64;
        let capacity_hz = cfg.servers as f64 / per_job_s;
        WorkloadGen::multi_tenant(seed, factor * capacity_hz)
    }

    #[test]
    fn underload_serves_everything_on_time() {
        let cfg = ServeConfig::default();
        let gen = overload_gen(1, &cfg, 0.3);
        let out = simulate_serve(&gen, 500, &cfg);
        assert_eq!(out.served.len(), 500);
        assert!(out.shed.is_empty());
        assert_eq!(out.deadline_missed, 0, "30% load must meet every deadline");
        assert!(out.p99_s < 0.05, "p99 {:.4}", out.p99_s);
        assert!(out.goodput_flops_per_s > 0.0);
        assert!(out.fairness_bound() >= 0.0);
        assert!(out.render().contains("tenant gold"));
    }

    #[test]
    fn deadline_aware_beats_fifo_on_goodput_under_overload() {
        let mut aware = ServeConfig {
            policy: AdmissionPolicy {
                shed_doomed: true,
                latency_target_s: Some(0.05),
                // Deep enough that FIFO's backlog is never clipped by
                // drop-tail: its collapse must come from bufferbloat,
                // not from an accidental admission bound.
                queue_capacity: 65_536,
                ..Default::default()
            },
            ..Default::default()
        };
        // 40k requests at 2x capacity: the trace spans ~0.37 s, so
        // FIFO queueing delay grows far past every deadline tier.
        let gen = overload_gen(7, &aware, 2.0);
        let out_aware = simulate_serve(&gen, 40_000, &aware);
        aware.deadline_aware = false;
        let out_fifo = simulate_serve(&gen, 40_000, &aware);
        assert!(
            out_aware.goodput_flops_per_s > out_fifo.goodput_flops_per_s,
            "aware {:.2e} must beat fifo {:.2e}",
            out_aware.goodput_flops_per_s,
            out_fifo.goodput_flops_per_s
        );
        assert!(!out_aware.shed.is_empty(), "overload must shed");
        assert!(
            out_aware.p99_s < out_fifo.p99_s,
            "shedding holds p99: {:.3} vs {:.3}",
            out_aware.p99_s,
            out_fifo.p99_s
        );
    }

    #[test]
    fn sustained_pressure_grows_the_fleet() {
        let cfg = ServeConfig {
            servers: 2,
            hot_spares: 1,
            pressure_watermark: Some(0.002),
            slo: SloPolicy {
                window_s: 0.005,
                long_windows: 4,
                burn_threshold: 0.5,
                max_growth: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let gen = overload_gen(3, &cfg, 3.0);
        let out = simulate_serve(&gen, 3000, &cfg);
        assert!(
            out.spare_activations + out.grown_cards > 0,
            "sustained overload must grow: {:?}",
            out.events
        );
        assert_eq!(out.spare_activations, 1, "the hot spare goes first");
        assert!(out.events.iter().any(|e| e.contains("spare activated")), "{:?}", out.events);
    }

    #[test]
    fn kills_requeue_without_losing_admitted_jobs() {
        let cfg = ServeConfig {
            servers: 2,
            kills: vec![(0.005, 0)],
            ..Default::default()
        };
        let gen = overload_gen(5, &cfg, 0.8);
        let out = simulate_serve(&gen, 800, &cfg);
        assert_eq!(
            out.served.len() + out.shed.len(),
            800,
            "every request accounted for"
        );
        assert!(out.events.iter().any(|e| e.contains("killed")), "{:?}", out.events);
        // All admitted requests completed despite the kill.
        assert_eq!(out.served.len(), 800 - out.shed.len());
    }

    #[test]
    fn whole_fleet_death_triggers_emergency_replacement() {
        let cfg = ServeConfig {
            servers: 1,
            hot_spares: 1,
            kills: vec![(0.001, 0)],
            ..Default::default()
        };
        let gen = overload_gen(9, &cfg, 0.5);
        let out = simulate_serve(&gen, 300, &cfg);
        assert_eq!(out.served.len() + out.shed.len(), 300);
        assert_eq!(out.spare_activations, 1, "the spare replaces the dead fleet");
    }

    #[test]
    fn replay_is_deterministic_from_the_seed() {
        let cfg = ServeConfig {
            pressure_watermark: Some(0.001),
            kills: vec![(0.01, 1)],
            ..Default::default()
        };
        let gen = overload_gen(11, &cfg, 1.5)
            .with_arrival(ArrivalModel::Bursty { factor: 4.0, on_s: 0.01, off_s: 0.03 });
        let a = simulate_serve(&gen, 1200, &cfg);
        let b = simulate_serve(&gen, 1200, &cfg);
        assert_eq!(a, b, "same seed, same config -> identical outcome");
    }

    #[test]
    fn outcome_records_into_metrics() {
        let cfg = ServeConfig::default();
        let gen = overload_gen(13, &cfg, 0.5);
        let out = simulate_serve(&gen, 200, &cfg);
        let m = Metrics::new();
        out.record_into(&m);
        let s = m.snapshot();
        assert_eq!(s.admitted, out.served.len() as u64);
        assert_eq!(s.shed, out.shed.len() as u64);
        assert_eq!(s.deadline_met, out.deadline_met);
        assert_eq!(s.latency_count, out.served.len() as u64);
        assert!(s.goodput_flops > 0);
        assert!(s.tenant_requests.iter().sum::<u64>() >= out.served.len() as u64);
    }
}
