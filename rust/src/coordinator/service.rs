//! The GEMM service: ingest → batch → route → execute → respond.
//!
//! Threading: one **engine thread** owns the PJRT client (the `xla`
//! crate's client is `Rc`-based and must not cross threads) and the
//! GEMM fallback; an **ingress thread** runs the batching loop. Clients
//! submit over an mpsc sender and receive on a per-request channel.

use super::admission::{AdmissionPolicy, AdmissionReport, Priority, ShedReason};
use super::batcher::Batcher;
use super::metrics::Metrics;
use super::router::{Route, Router};
use crate::blocked::{OffchipSim, SimReport};
use crate::cluster::{ClusterReport, ClusterSim, FaultPlan, Fleet, SloPolicy};
use crate::fabric::Topology;
use crate::gemm::{matmul_blocked, Matrix};
use crate::perfmodel::flop_count;
use crate::placement::PlacementStrategy;
use crate::strassen::{strassen_matmul, StrassenConfig, StrassenReport};
use crate::trace::{critical_path, CriticalPath, Tracer};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Largest m·k·n (MAC count) for which a Strassen-routed request also
/// runs the dense blocked GEMM to *measure* `rel_fro_error`; larger
/// problems report only the planner's a-priori bound (the dense check
/// would double their functional cost).
const STRASSEN_VERIFY_MACS: u64 = 1 << 26;

/// A matrix-multiplication job, built fluently:
///
/// ```
/// # use systo3d::coordinator::{GemmRequest, Priority};
/// # use systo3d::gemm::Matrix;
/// # use std::time::Duration;
/// let req = GemmRequest::new(Matrix::random(8, 8, 1), Matrix::random(8, 8, 2))
///     .id(7)
///     .tenant("gold")
///     .priority(Priority::High)
///     .deadline(Duration::from_millis(50));
/// assert_eq!(req.tenant.as_deref(), Some("gold"));
/// ```
///
/// Every knob defaults off: a bare `new(a, b)` is the anonymous,
/// best-effort, Normal-lane request the earlier struct-literal API
/// produced.
#[derive(Clone, Debug)]
pub struct GemmRequest {
    pub id: u64,
    pub a: Matrix,
    pub b: Matrix,
    /// Optional third operand: compute (A·B)·C — the chained-multiply
    /// path that needs no host reordering on this architecture.
    pub chain: Option<Matrix>,
    /// Per-request relative-Frobenius error budget for the Strassen
    /// route (None = the service default). The planner caps recursion
    /// depth so its predicted error stays inside the budget; a budget
    /// no depth satisfies downgrades the request to the exact
    /// classical path.
    pub error_budget: Option<f64>,
    /// Tenant the request bills to (fair-share accounting and the
    /// per-tenant latency gauges). None = anonymous.
    pub tenant: Option<String>,
    /// Admission lane.
    pub priority: Priority,
    /// Deadline from submission; a response later than this counts
    /// against the deadline-missed gauge (and under a deadline-aware
    /// batcher pulls the batch close earlier). None falls back to
    /// [`AdmissionPolicy::default_deadline_s`], or best-effort.
    pub deadline: Option<Duration>,
}

impl GemmRequest {
    /// A · B with every serving knob at its default.
    pub fn new(a: Matrix, b: Matrix) -> Self {
        Self {
            id: 0,
            a,
            b,
            chain: None,
            error_budget: None,
            tenant: None,
            priority: Priority::default(),
            deadline: None,
        }
    }

    pub fn id(mut self, id: u64) -> Self {
        self.id = id;
        self
    }

    /// Chain a third operand: (A·B)·C.
    pub fn chain(mut self, c: Matrix) -> Self {
        self.chain = Some(c);
        self
    }

    pub fn error_budget(mut self, budget: f64) -> Self {
        self.error_budget = Some(budget);
        self
    }

    pub fn tenant(mut self, tenant: &str) -> Self {
        self.tenant = Some(tenant.to_string());
        self
    }

    pub fn priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// The service's answer.
#[derive(Debug)]
pub struct GemmResponse {
    pub id: u64,
    pub result: Result<Matrix, String>,
    /// Which route computed the functional result.
    pub route: Route,
    /// Host wall-clock from dequeue to result.
    pub host_seconds: f64,
    /// Queueing delay before execution started.
    pub queue_seconds: f64,
    /// Simulated FPGA execution on the routed Table-I design (None if no
    /// design's blocking accepts the shape).
    pub fpga_sim: Option<SimReport>,
    /// Simulated multi-FPGA execution, one report per sharded GEMM leg
    /// (two for a chained request; empty unless the route is Sharded).
    pub cluster: Vec<ClusterReport>,
    /// Strassen execution report (depth, effective-vs-peak throughput,
    /// numerics); Some exactly when the route is Strassen.
    pub strassen: Option<StrassenReport>,
    /// What admission control decided: queue class, shed/admitted, and
    /// (for served deadline-carrying requests) the remaining slack.
    pub admission: AdmissionReport,
}

impl GemmResponse {
    /// The answer a shed request gets: an error result carrying the
    /// admission verdict, no execution artifacts.
    pub fn shed(id: u64, admission: AdmissionReport) -> Self {
        let reason =
            admission.shed.map_or("shed", |r| r.name());
        Self {
            id,
            result: Err(format!("shed by admission control ({reason})")),
            route: Route::Fallback,
            host_seconds: 0.0,
            queue_seconds: 0.0,
            fpga_sim: None,
            cluster: Vec::new(),
            strassen: None,
            admission,
        }
    }
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Artifact directory; None disables the PJRT path (pure fallback).
    pub artifact_dir: Option<PathBuf>,
    pub max_batch: usize,
    /// Batching window: how long the ingress loop waits to fill a batch.
    pub batch_window: Duration,
    /// Active cards in the sharded route's simulated fleet (design G).
    pub cluster_devices: usize,
    /// Card fabric of the **active** fleet; None = [`Topology::auto`]
    /// (full mesh while the 4-port budget lasts, then a near-square
    /// torus). Hot spares are attached on top. A topology whose card
    /// count disagrees with `cluster_devices` is rejected at start.
    pub cluster_topology: Option<Topology>,
    /// Hot-spare cards wired into the fabric but excluded from
    /// placement: a dying card's queued and in-flight shards drain
    /// onto a spare instead of requeueing on survivors (see
    /// [`crate::cluster::elastic`]).
    pub hot_spares: usize,
    /// Queue-depth watermark for elastic fabric growth (pending shards
    /// per live card; None keeps the fleet fixed).
    pub scale_watermark: Option<f64>,
    /// Latency SLO for the sharded route's fleet: sustained p99
    /// burn-rate alerts grow the fabric even when raw queue depth
    /// never crosses the watermark (see [`crate::observe::slo`]).
    /// None disables burn-driven growth.
    pub slo: Option<SloPolicy>,
    /// Device→card placement the sharded route's planner applies to
    /// reduction-carrying plans before simulating them (identity
    /// disables the optimizer; the default is the seeded local
    /// search). Functional results are placement-invariant — this only
    /// moves where partials live on the fabric.
    pub placement: PlacementStrategy,
    /// Attach a flight recorder to the sharded route's fleet: every
    /// simulated shard, DMA, fabric circuit, and elastic event lands in
    /// the service's shared [`Tracer`] (see [`GemmService::trace`]).
    /// Off by default — the no-op sink costs nothing.
    pub trace: bool,
    /// Strassen planner knobs (mode, max depth, default error budget).
    pub strassen: StrassenConfig,
    /// Bucket fallback/Strassen batches by blocking-padded shape
    /// instead of exact shape (see [`Batcher::with_bucketing`]).
    pub bucket_shapes: bool,
    /// Admission control: ingress bound (shed instead of queueing
    /// without limit), default deadline, and the latency target that
    /// pulls batch closes earlier than the fixed window.
    pub admission: AdmissionPolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            artifact_dir: Some(PathBuf::from("artifacts")),
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            cluster_devices: 4,
            cluster_topology: None,
            hot_spares: 0,
            scale_watermark: None,
            slo: None,
            placement: PlacementStrategy::default(),
            trace: false,
            strassen: StrassenConfig::default(),
            bucket_shapes: false,
            admission: AdmissionPolicy::default(),
        }
    }
}

enum Ingress {
    /// (request, reply channel, enqueue instant, queue depth at admit).
    Job(Box<GemmRequest>, mpsc::Sender<GemmResponse>, Instant, usize),
    Shutdown,
}

/// Handle to a running service.
pub struct GemmService {
    tx: mpsc::Sender<Ingress>,
    pub metrics: Arc<Metrics>,
    /// Jobs admitted but not yet answered — the ingress bound
    /// admission control sheds against.
    inflight: Arc<AtomicU64>,
    admission: AdmissionPolicy,
    /// Fleet size of the sharded route (pairs with
    /// [`Metrics::cluster_utilization`]).
    pub cluster_devices: usize,
    /// The sharded route's flight recorder; shares its buffer with the
    /// engine thread's cluster, so snapshot it any time. A no-op sink
    /// unless [`ServiceConfig::trace`] was set.
    pub trace: Tracer,
    worker: Option<JoinHandle<()>>,
}

impl GemmService {
    /// Start the service threads.
    pub fn start(config: ServiceConfig) -> anyhow::Result<Self> {
        let metrics = Arc::new(Metrics::new());
        let cluster_devices = config.cluster_devices.max(1);
        if let Some(t) = &config.cluster_topology {
            anyhow::ensure!(
                t.cards == cluster_devices,
                "cluster_topology wires {} card(s) but cluster_devices (active) is {}",
                t.cards,
                cluster_devices
            );
        }
        let (tx, rx) = mpsc::channel::<Ingress>();
        let m = Arc::clone(&metrics);
        let trace = if config.trace { Tracer::recording() } else { Tracer::off() };
        let t = trace.clone();
        let inflight = Arc::new(AtomicU64::new(0));
        let inf = Arc::clone(&inflight);
        let admission = config.admission.clone();
        let worker = std::thread::Builder::new()
            .name("gemm-engine".into())
            .spawn(move || Self::engine_loop(config, rx, m, t, inf))
            .expect("spawn engine thread");
        Ok(Self { tx, metrics, inflight, admission, cluster_devices, trace, worker: Some(worker) })
    }

    /// Fold the flight recorder's current critical path into the
    /// service gauges ([`Metrics::critical_share`]) and return it.
    /// `None` when tracing is off or nothing has been recorded yet.
    pub fn record_trace_critical_path(&self) -> Option<CriticalPath> {
        if !self.trace.is_recording() {
            return None;
        }
        let log = self.trace.snapshot();
        if log.spans.is_empty() {
            return None;
        }
        let path = critical_path(&log);
        self.metrics.record_critical_path(&path);
        Some(path)
    }

    /// Scrape the service gauges in the Prometheus text exposition
    /// format (see [`crate::observe::prometheus_text`]).
    pub fn prometheus_text(&self) -> String {
        crate::observe::prometheus_text(&self.metrics.snapshot())
    }

    /// The same gauges as one stable JSON object (see
    /// [`crate::observe::json_snapshot`]).
    pub fn json_snapshot(&self) -> String {
        crate::observe::json_snapshot(&self.metrics.snapshot())
    }

    /// Submit a job; returns the receiver for its response.
    ///
    /// Admission happens here, at the door: when the in-flight count
    /// sits at [`AdmissionPolicy::queue_capacity`], the request is
    /// **shed** — a [`GemmResponse::shed`] answer lands on the
    /// receiver immediately instead of the job queueing without bound.
    pub fn submit(&self, mut req: GemmRequest) -> mpsc::Receiver<GemmResponse> {
        let (rtx, rrx) = mpsc::channel();
        Metrics::inc(&self.metrics.requests);
        let depth = self.inflight.load(Ordering::Acquire) as usize;
        if depth >= self.admission.queue_capacity {
            Metrics::inc(&self.metrics.shed);
            let tenant = req.tenant.as_deref().unwrap_or("default");
            let report =
                AdmissionReport::rejected(tenant, req.priority, ShedReason::QueueFull, depth);
            let _ = rtx.send(GemmResponse::shed(req.id, report));
            return rrx;
        }
        if req.deadline.is_none() {
            req.deadline = self.admission.default_deadline_s.map(Duration::from_secs_f64);
        }
        Metrics::inc(&self.metrics.admitted);
        self.inflight.fetch_add(1, Ordering::AcqRel);
        self.tx
            .send(Ingress::Job(Box::new(req), rtx, Instant::now(), depth))
            .expect("engine thread alive");
        rrx
    }

    /// Submit and wait. Under a saturated ingress this observes the
    /// shed response like any other answer — it never blocks on a
    /// request admission control already turned away.
    pub fn submit_sync(&self, req: GemmRequest) -> GemmResponse {
        self.submit(req).recv().expect("engine thread alive")
    }

    fn engine_loop(
        config: ServiceConfig,
        rx: mpsc::Receiver<Ingress>,
        metrics: Arc<Metrics>,
        trace: Tracer,
        inflight: Arc<AtomicU64>,
    ) {
        // The engine (and its PJRT client) lives on this thread only.
        let mut engine = config
            .artifact_dir
            .as_deref()
            .and_then(|dir| match crate::runtime::Engine::new(dir) {
                Ok(e) => Some(e),
                Err(err) => {
                    eprintln!("warning: artifact engine unavailable ({err}); falling back to CPU GEMM");
                    None
                }
            });
        let router =
            Router::new(engine.as_ref().map(|e| &e.manifest)).with_strassen(config.strassen);
        // The sharded route's fleet: design-G cards (design G is always
        // fitted, so this cannot fail) on the configured fabric, with
        // the hot spares wired in on top of the active cards.
        let fleet =
            Fleet::homogeneous(config.cluster_devices.max(1) + config.hot_spares, "G")
                .expect("design G in the fitted catalog");
        let mut builder = ClusterSim::builder(fleet)
            .spares(config.hot_spares)
            .placement(config.placement)
            .watermark(config.scale_watermark)
            .slo(config.slo)
            .trace(trace);
        if let Some(t) = config.cluster_topology.clone() {
            builder = builder.topology(t);
        }
        let cluster = builder.build();
        let mut batcher = if config.bucket_shapes {
            // Bucket to the fleet design's blocking-padded extents.
            Batcher::with_bucketing(config.max_batch, cluster.fleet.devices[0].design.blocking)
        } else {
            Batcher::new(config.max_batch)
        };
        if let Some(target) = config.admission.latency_target_s {
            batcher = batcher.with_latency_target(target);
        }

        loop {
            // Block for the first job, then drain the window.
            let first = match rx.recv() {
                Ok(Ingress::Job(r, tx, t, d)) => (r, tx, t, d),
                Ok(Ingress::Shutdown) | Err(_) => return,
            };
            let mut pending = vec![first];
            // Adaptive batching (EXPERIMENTS.md §Perf L3-2): first drain
            // whatever is already queued without sleeping; only hold the
            // window open when a batch is actually forming. Idle clients
            // pay zero window latency, loaded streams still coalesce.
            while pending.len() < config.max_batch {
                match rx.try_recv() {
                    Ok(Ingress::Job(r, tx, t, d)) => pending.push((r, tx, t, d)),
                    Ok(Ingress::Shutdown) | Err(mpsc::TryRecvError::Disconnected) => break,
                    Err(mpsc::TryRecvError::Empty) => break,
                }
            }
            if pending.len() >= 2 {
                // Deadline-aware close: the fixed window shrinks to
                // whatever slack the oldest member has left against the
                // latency target / its own deadline (Batcher::close_by
                // on the oldest member's timeline).
                let oldest = pending
                    .iter()
                    .min_by_key(|(_, _, t, _)| *t)
                    .map(|(r, _, t, _)| (*t, r.deadline.map(|d| d.as_secs_f64())))
                    .expect("pending non-empty");
                let close_rel = batcher.close_by(
                    0.0,
                    config.batch_window.as_secs_f64(),
                    0.0,
                    oldest.1,
                );
                let window_end = oldest.0 + Duration::from_secs_f64(close_rel.max(0.0));
                while pending.len() < config.max_batch {
                    let now = Instant::now();
                    if now >= window_end {
                        break;
                    }
                    match rx.recv_timeout(window_end - now) {
                        Ok(Ingress::Job(r, tx, t, d)) => pending.push((r, tx, t, d)),
                        Ok(Ingress::Shutdown) => break,
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
            }
            // Priority lanes drain first within the cohort (stable, so
            // arrival order holds inside a lane).
            pending.sort_by_key(|(r, _, _, _)| r.priority.lane());

            // Group by route key and execute.
            let keyed: Vec<(String, _)> = pending
                .into_iter()
                .map(|(req, tx, t, d)| {
                    // Key by the same routing decision execute_one makes.
                    let route = match &req.chain {
                        Some(c) => {
                            router.route_chain(req.a.rows, req.a.cols, req.b.cols, c.cols)
                        }
                        None => router.route(req.a.rows, req.a.cols, req.b.cols),
                    };
                    let key = match route {
                        Route::Artifact(name) => format!("artifact:{name}"),
                        Route::Fallback => {
                            if req.chain.is_some() {
                                "fallback-chain".to_string()
                            } else {
                                // Shape-keyed (exact or padded-bucketed):
                                // same-shape jobs share one kernel launch.
                                format!(
                                    "fallback:{}",
                                    batcher.shape_key(req.a.rows, req.a.cols, req.b.cols)
                                )
                            }
                        }
                        Route::Sharded => "sharded".to_string(),
                        Route::Strassen => format!(
                            "strassen:{}",
                            batcher.shape_key(req.a.rows, req.a.cols, req.b.cols)
                        ),
                    };
                    (key, (req, tx, t, d))
                })
                .collect();
            for batch in batcher.group(keyed) {
                Metrics::inc(&metrics.batches);
                for (req, tx, enqueued, depth) in batch.items {
                    let queue_seconds = enqueued.elapsed().as_secs_f64();
                    let id = req.id;
                    let tenant = req.tenant.clone();
                    let lane = req.priority;
                    // One malformed job must not take the engine down:
                    // contain panics (e.g. shape assertions in the GEMM
                    // fallback) and answer with an error instead.
                    let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        Self::execute_one(
                            &router,
                            engine.as_mut(),
                            &cluster,
                            *req,
                            queue_seconds,
                            depth,
                            &metrics,
                        )
                    }))
                    .unwrap_or_else(|payload| {
                        Metrics::inc(&metrics.errors);
                        let msg = payload
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "request panicked".into());
                        GemmResponse {
                            id,
                            result: Err(msg),
                            route: Route::Fallback,
                            host_seconds: 0.0,
                            queue_seconds,
                            fpga_sim: None,
                            cluster: Vec::new(),
                            strassen: None,
                            admission: AdmissionReport::admitted(
                                tenant.as_deref().unwrap_or("default"),
                                lane,
                                depth,
                            ),
                        }
                    });
                    let _ = tx.send(resp);
                    inflight.fetch_sub(1, Ordering::AcqRel);
                }
            }
        }
    }

    /// One A·B leg through the cluster: auto-plan (reusing the planner's
    /// own timing run), functional execute, record gauges. Falls back to
    /// the blocked GEMM when the fleet cannot produce a plan (degenerate
    /// extents).
    fn cluster_leg(
        cluster: &ClusterSim,
        a: &Matrix,
        b: &Matrix,
        metrics: &Metrics,
    ) -> (Matrix, Option<ClusterReport>) {
        match cluster.plan_and_report(a.rows as u64, a.cols as u64, b.cols as u64) {
            Some((plan, mut report)) => {
                // Elastic fleets: replay the winning plan through the
                // elastic scheduler — hot spares wired, growth
                // watermark armed — so a backlog that crosses the
                // watermark grows the fabric in the reported makespan
                // and the elastic gauges accumulate.
                if cluster.hot_spares > 0
                    || cluster.scale_watermark.is_some()
                    || cluster.slo.is_some()
                {
                    if let Ok(out) = cluster.simulate_elastic(&plan, &FaultPlan::none()) {
                        metrics.record_elastic(&out);
                        report = cluster.elastic_report(&plan, &out);
                    }
                }
                let c = plan.execute_functional(a, b);
                metrics.record_cluster(&report);
                (c, Some(report))
            }
            None => (matmul_blocked(a, b), None),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_one(
        router: &Router,
        mut engine: Option<&mut crate::runtime::Engine>,
        cluster: &ClusterSim,
        req: GemmRequest,
        queue_seconds: f64,
        admit_depth: usize,
        metrics: &Metrics,
    ) -> GemmResponse {
        let t0 = Instant::now();
        let (m, k, n) = (req.a.rows, req.a.cols, req.b.cols);
        let mut cluster_reports = Vec::new();
        let mut strassen_report: Option<StrassenReport> = None;

        // Chained jobs route through the chain-artifact index.
        let (mut route, result): (Route, Result<Matrix, String>) =
            if let Some(chain_c) = &req.chain {
                let route = router.route_chain(m, k, n, chain_c.cols);
                match (&route, engine.as_mut()) {
                    (Route::Artifact(name), Some(eng)) => {
                        let r = eng
                            .execute(name, &[&req.a, &req.b, chain_c])
                            .map(|(m, _)| m)
                            .map_err(|e| e.to_string());
                        (route, r)
                    }
                    (Route::Sharded, _) => {
                        // Shard leg by leg; no host reordering between
                        // legs (the §VI argument, one level up).
                        let (ab, rep1) = Self::cluster_leg(cluster, &req.a, &req.b, metrics);
                        let (abc, rep2) = Self::cluster_leg(cluster, &ab, chain_c, metrics);
                        cluster_reports.extend(rep1);
                        cluster_reports.extend(rep2);
                        (Route::Sharded, Ok(abc))
                    }
                    _ => {
                        let ab = matmul_blocked(&req.a, &req.b);
                        (Route::Fallback, Ok(matmul_blocked(&ab, chain_c)))
                    }
                }
            } else {
                let route = router.route(m, k, n);
                match (&route, engine.as_mut()) {
                    (Route::Artifact(name), Some(eng)) => {
                        let r = eng
                            .execute(name, &[&req.a, &req.b])
                            .map(|(m, _)| m)
                            .map_err(|e| e.to_string());
                        (route, r)
                    }
                    (Route::Sharded, _) => {
                        let (c, rep) = Self::cluster_leg(cluster, &req.a, &req.b, metrics);
                        cluster_reports.extend(rep);
                        (Route::Sharded, Ok(c))
                    }
                    (Route::Strassen, _) => {
                        // Re-plan under the request's own error budget
                        // (the routing pass used the service default).
                        match router.strassen_plan(m as u64, k as u64, n as u64, req.error_budget)
                        {
                            Some(plan) => {
                                let c = strassen_matmul(&req.a, &req.b, plan.depth);
                                // Numerics tracking: measure against the
                                // dense blocked result when that is cheap.
                                let rel_fro_error = ((m as u64) * (k as u64) * (n as u64)
                                    <= STRASSEN_VERIFY_MACS)
                                    .then(|| c.rel_fro_error(&matmul_blocked(&req.a, &req.b)));
                                let chosen = plan.chosen();
                                let report = StrassenReport {
                                    depth: plan.depth,
                                    leaves: chosen.leaves,
                                    simulated_seconds: chosen.seconds,
                                    effective_gflops: chosen.effective_gflops,
                                    peak_gflops: plan.peak_gflops,
                                    speedup_vs_classical: plan.speedup_vs_classical(),
                                    rel_fro_error,
                                };
                                metrics.record_strassen(&report);
                                strassen_report = Some(report);
                                (Route::Strassen, Ok(c))
                            }
                            // The request's budget admits no depth: run
                            // the exact classical path instead.
                            None => (Route::Fallback, Ok(matmul_blocked(&req.a, &req.b))),
                        }
                    }
                    _ => (Route::Fallback, Ok(matmul_blocked(&req.a, &req.b))),
                }
            };
        // A sharded request whose fleet produced no plan for any leg
        // fell back entirely. (A Strassen request whose budget admitted
        // no depth was already downgraded inside its match arm.)
        if route == Route::Sharded && cluster_reports.is_empty() {
            route = Route::Fallback;
        }

        match &route {
            Route::Artifact(_) => Metrics::inc(&metrics.artifact_hits),
            Route::Fallback => Metrics::inc(&metrics.fallbacks),
            Route::Sharded => Metrics::inc(&metrics.sharded_jobs),
            // record_strassen already counted the job.
            Route::Strassen => {}
        }
        if result.is_err() {
            Metrics::inc(&metrics.errors);
        }
        let mut req_flops = flop_count(m as u64, n as u64, k as u64);
        if let Some(chain_c) = &req.chain {
            // Second leg of the chain: (m × n)·(n × p).
            req_flops += flop_count(m as u64, chain_c.cols as u64, n as u64);
        }
        metrics.add_flops(req_flops);

        // FPGA timing on the routed design (chain = two passes). Sharded
        // requests carry the cluster report instead — a single-card
        // SimReport would be fiction for a problem that left one card —
        // and Strassen requests carry their own report (the classical
        // single-card schedule is exactly what the recursion replaced).
        let fpga_sim = if route == Route::Sharded || route == Route::Strassen {
            None
        } else {
            router.timing_design(m as u64, k as u64, n as u64).map(|d| {
                let sim = OffchipSim::new(d);
                sim.simulate(m as u64, n as u64, k as u64)
            })
        };

        let host_seconds = t0.elapsed().as_secs_f64();
        metrics.record_latency(host_seconds);
        let tenant = req.tenant.as_deref().unwrap_or("default");
        metrics.record_tenant_latency(tenant, host_seconds);
        // Deadline accounting on the full queue+execute span. Goodput
        // counts the FLOPs of answers that arrived in time (errors are
        // not good work, whatever the clock says).
        let slack = req.deadline.map(|d| d.as_secs_f64() - (queue_seconds + host_seconds));
        let met = slack.is_none_or(|s| s >= 0.0);
        if met {
            Metrics::inc(&metrics.deadline_met);
            if result.is_ok() {
                Metrics::add(&metrics.goodput_flops, req_flops);
            }
        } else {
            Metrics::inc(&metrics.deadline_missed);
        }
        let mut admission = AdmissionReport::admitted(tenant, req.priority, admit_depth);
        admission.deadline_slack_s = slack;
        GemmResponse {
            id: req.id,
            result,
            route,
            host_seconds,
            queue_seconds,
            fpga_sim,
            cluster: cluster_reports,
            strassen: strassen_report,
            admission,
        }
    }
}

impl Drop for GemmService {
    fn drop(&mut self) {
        let _ = self.tx.send(Ingress::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_artifact_config() -> ServiceConfig {
        ServiceConfig {
            artifact_dir: None,
            max_batch: 4,
            batch_window: Duration::from_millis(1),
            ..Default::default()
        }
    }

    #[test]
    fn fallback_service_computes_correctly() {
        let svc = GemmService::start(no_artifact_config()).unwrap();
        let a = Matrix::random(32, 16, 1);
        let b = Matrix::random(16, 24, 2);
        let want = crate::gemm::matmul(&a, &b);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(7));
        assert_eq!(resp.id, 7);
        assert_eq!(resp.route, Route::Fallback);
        let got = resp.result.unwrap();
        assert!(got.rel_fro_error(&want) < 1e-5);
    }

    #[test]
    fn chained_request_no_reordering() {
        let svc = GemmService::start(no_artifact_config()).unwrap();
        let a = Matrix::random(16, 16, 3);
        let b = Matrix::random(16, 16, 4);
        let c = Matrix::random(16, 16, 5);
        let want = crate::gemm::matmul(&crate::gemm::matmul(&a, &b), &c);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(1).chain(c));
        assert!(resp.result.unwrap().rel_fro_error(&want) < 1e-4);
    }

    #[test]
    fn sim_timing_attached_for_conforming_shapes() {
        let svc = GemmService::start(no_artifact_config()).unwrap();
        let a = Matrix::random(512, 512, 6);
        let b = Matrix::random(512, 512, 7);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(2));
        let sim = resp.fpga_sim.expect("512-cube matches design H blocking");
        assert!(sim.gflops > 1000.0);
        assert!(sim.e_d > 0.3 && sim.e_d < 1.0);
    }

    #[test]
    fn sharded_route_end_to_end() {
        let svc = GemmService::start(no_artifact_config()).unwrap();
        // 1025³: no Table-I blocking divides it, and every dimension is
        // cluster-worthy -> Route::Sharded over the 4-card fleet.
        let a = Matrix::random(1025, 1025, 8);
        let b = Matrix::random(1025, 1025, 9);
        let want = matmul_blocked(&a, &b);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(3));
        assert_eq!(resp.route, Route::Sharded);
        assert_eq!(resp.cluster.len(), 1, "one report per sharded leg");
        let rep = &resp.cluster[0];
        assert_eq!(rep.devices, 4);
        assert!(rep.makespan_seconds > 0.0);
        assert!(resp.fpga_sim.is_none(), "no single-card design fits 1025");
        // Bit-exact against the dense blocked GEMM.
        assert_eq!(resp.result.unwrap().data, want.data);
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.sharded_jobs, 1);
        assert!(snap.shards_executed >= 4);
        assert!(svc.metrics.cluster_utilization(svc.cluster_devices as u64) > 0.0);
    }

    #[test]
    fn sharded_route_on_explicit_topology() {
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            cluster_topology: Some(Topology::ring(4)),
            ..Default::default()
        })
        .unwrap();
        let a = Matrix::random(1025, 1025, 21);
        let b = Matrix::random(1025, 1025, 22);
        let want = matmul_blocked(&a, &b);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(9));
        assert_eq!(resp.route, Route::Sharded);
        assert_eq!(resp.cluster[0].topology, "ring");
        assert_eq!(resp.result.unwrap().data, want.data);
        // A fabric that wires the wrong card count is rejected at start.
        let bad = GemmService::start(ServiceConfig {
            artifact_dir: None,
            cluster_topology: Some(Topology::ring(3)),
            ..Default::default()
        });
        assert!(bad.is_err());
    }

    #[test]
    fn spared_service_keeps_results_bit_exact() {
        // Hot spares ride along in the fleet: plans still carve over
        // the active cards, the spare idles while healthy, and the
        // functional answer is untouched.
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            cluster_devices: 4,
            hot_spares: 1,
            scale_watermark: Some(64.0),
            ..Default::default()
        })
        .unwrap();
        let a = Matrix::random(1025, 1025, 61);
        let b = Matrix::random(1025, 1025, 62);
        let want = matmul_blocked(&a, &b);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(11));
        assert_eq!(resp.route, Route::Sharded);
        let rep = &resp.cluster[0];
        assert_eq!(rep.devices, 5, "4 active + 1 wired spare");
        assert_eq!(rep.per_device[4].shards, 0, "spare idles while healthy");
        assert_eq!(resp.result.unwrap().data, want.data);
        // A fabric sized to active + spare (instead of active) is
        // rejected at start, like any card-count mismatch.
        let bad = GemmService::start(ServiceConfig {
            artifact_dir: None,
            cluster_devices: 4,
            hot_spares: 1,
            cluster_topology: Some(Topology::ring(5)),
            ..Default::default()
        });
        assert!(bad.is_err());
    }

    #[test]
    fn watermark_grows_the_sharded_fleet() {
        // 2 active cards against a 0.5 queue-depth watermark: the
        // elastic replay attaches its growth budget, the response
        // report covers the grown cards, and the gauges accumulate.
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            cluster_devices: 2,
            scale_watermark: Some(0.5),
            ..Default::default()
        })
        .unwrap();
        let a = Matrix::random(1025, 1025, 71);
        let b = Matrix::random(1025, 1025, 72);
        let want = matmul_blocked(&a, &b);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(12));
        assert_eq!(resp.route, Route::Sharded);
        let rep = &resp.cluster[0];
        assert!(rep.devices > 2, "the watermark must grow the fleet: {}", rep.devices);
        assert!(rep.per_device.iter().skip(2).any(|d| d.id.starts_with("grown")));
        assert_eq!(resp.result.unwrap().data, want.data);
        let snap = svc.metrics.snapshot();
        assert!(snap.elastic_grown_cards > 0);
        assert_eq!(snap.elastic_spare_activations, 0, "healthy run: growth only");
    }

    #[test]
    fn placement_knob_keeps_results_bit_exact() {
        // The optimizer only relabels where partials live; the service
        // answer must be bit-identical with it on or off, and the
        // placed hop-byte gauge must never exceed the identity gauge.
        for placement in [PlacementStrategy::Identity, PlacementStrategy::default()] {
            let svc = GemmService::start(ServiceConfig {
                artifact_dir: None,
                cluster_devices: 8,
                placement,
                ..Default::default()
            })
            .unwrap();
            let a = Matrix::random(1025, 1025, 41);
            let b = Matrix::random(1025, 1025, 42);
            let want = matmul_blocked(&a, &b);
            let resp = svc.submit_sync(GemmRequest::new(a, b).id(6));
            assert_eq!(resp.route, Route::Sharded);
            assert_eq!(resp.result.unwrap().data, want.data);
            let snap = svc.metrics.snapshot();
            assert!(snap.placement_placed_hop_bytes <= snap.placement_identity_hop_bytes);
        }
    }

    #[test]
    fn traced_service_records_the_sharded_legs() {
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            trace: true,
            ..Default::default()
        })
        .unwrap();
        assert!(svc.trace.is_recording());
        let a = Matrix::random(1025, 1025, 81);
        let b = Matrix::random(1025, 1025, 82);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(13));
        assert_eq!(resp.route, Route::Sharded);
        let log = svc.trace.snapshot();
        assert!(log.spans.iter().any(|s| s.name.starts_with("shard r")), "compute spans");
        assert_eq!(log.open_spans(), 0, "every begun span ended");
        let path = svc.record_trace_critical_path().expect("critical path");
        assert!(path.makespan > 0.0);
        let snap = svc.metrics.snapshot();
        assert!(snap.critical_bucket_us.iter().sum::<u64>() > 0);
        assert!(snap.latency_count >= 1, "histogram saw the request");
    }

    #[test]
    fn service_exposes_prometheus_and_json_scrapes() {
        let svc = GemmService::start(no_artifact_config()).unwrap();
        let a = Matrix::random(32, 16, 31);
        let b = Matrix::random(16, 24, 32);
        svc.submit_sync(GemmRequest::new(a, b).id(20)).result.unwrap();
        let text = svc.prometheus_text();
        assert!(text.contains("systo3d_requests_total 1\n"));
        assert!(text.contains("systo3d_fallbacks_total 1\n"));
        assert!(text.contains("# TYPE systo3d_latency_p99_us gauge"));
        let json = svc.json_snapshot();
        assert!(json.contains("\"requests\":1"));
        assert!(json.contains("\"latency_count\":1"));
    }

    #[test]
    fn slo_configured_service_stays_bit_exact() {
        // The burn monitor only moves where shards run; the functional
        // answer is untouched and the elastic gauges accumulate.
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            cluster_devices: 2,
            slo: Some(SloPolicy::default()),
            ..Default::default()
        })
        .unwrap();
        let a = Matrix::random(1025, 1025, 91);
        let b = Matrix::random(1025, 1025, 92);
        let want = matmul_blocked(&a, &b);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(14));
        assert_eq!(resp.route, Route::Sharded);
        assert_eq!(resp.result.unwrap().data, want.data);
    }

    #[test]
    fn strassen_route_end_to_end() {
        use crate::strassen::{StrassenConfig, StrassenMode};
        // Force depth 2 so a test-sized job exercises the full path.
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            strassen: StrassenConfig { mode: StrassenMode::Force(2), ..Default::default() },
            ..Default::default()
        })
        .unwrap();
        let a = Matrix::random(96, 64, 11);
        let b = Matrix::random(64, 80, 12);
        let want = matmul_blocked(&a, &b);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(4));
        assert_eq!(resp.route, Route::Strassen);
        assert!(resp.fpga_sim.is_none(), "Strassen carries its own report");
        let rep = resp.strassen.expect("Strassen report");
        assert_eq!(rep.depth, 2);
        assert_eq!(rep.leaves, 49);
        assert!(rep.peak_gflops > 0.0 && rep.simulated_seconds > 0.0);
        let measured = rep.rel_fro_error.expect("small problem is verified");
        assert!(measured < 1e-5, "rel err {measured}");
        assert!(resp.result.unwrap().rel_fro_error(&want) < 1e-5);
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.strassen_jobs, 1);
        assert_eq!(snap.strassen_depths, [0, 0, 1, 0]);
        assert!(svc.metrics.strassen_mean_eff_vs_peak() > 0.0);
    }

    #[test]
    fn request_error_budget_downgrades_to_exact_path() {
        use crate::strassen::{StrassenConfig, StrassenMode};
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            strassen: StrassenConfig { mode: StrassenMode::Force(2), ..Default::default() },
            ..Default::default()
        })
        .unwrap();
        let a = Matrix::random(64, 64, 13);
        let b = Matrix::random(64, 64, 14);
        let want = matmul_blocked(&a, &b);
        // A budget no recursion depth can promise: exact classical path.
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(5).error_budget(1e-12));
        assert_eq!(resp.route, Route::Fallback);
        assert!(resp.strassen.is_none());
        // Bit-exact: the downgrade ran the dense blocked GEMM.
        assert_eq!(resp.result.unwrap().data, want.data);
        assert_eq!(svc.metrics.snapshot().strassen_jobs, 0);
    }

    #[test]
    fn bucketed_batching_serves_odd_shapes() {
        // The toggle must not change results — only batch keys.
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            bucket_shapes: true,
            ..Default::default()
        })
        .unwrap();
        let mut rxs = Vec::new();
        for (i, (m, k, n)) in [(100, 60, 90), (97, 60, 85), (512, 60, 512)].iter().enumerate() {
            let a = Matrix::random(*m, *k, i as u64);
            let b = Matrix::random(*k, *n, 100 + i as u64);
            let want = matmul_blocked(&a, &b);
            rxs.push((want, svc.submit(GemmRequest::new(a, b).id(i as u64))));
        }
        for (want, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.result.unwrap().data, want.data);
        }
        assert_eq!(svc.metrics.snapshot().errors, 0);
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let svc = Arc::new(GemmService::start(no_artifact_config()).unwrap());
        let mut rxs = Vec::new();
        for i in 0..20 {
            let a = Matrix::random(16, 16, i);
            let b = Matrix::random(16, 16, i + 100);
            rxs.push((i, svc.submit(GemmRequest::new(a, b).id(i))));
        }
        for (i, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.id, i);
            assert!(resp.result.is_ok());
        }
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.requests, 20);
        assert!(snap.batches >= 1);
        assert_eq!(snap.errors, 0);
    }

    #[test]
    fn saturated_ingress_sheds_instead_of_blocking() {
        // Regression: submit_sync on a saturated ingress used to block
        // forever waiting for capacity that never came. With the bound
        // at 0 every request sheds, and the call must return.
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            admission: AdmissionPolicy { queue_capacity: 0, ..Default::default() },
            ..Default::default()
        })
        .unwrap();
        let a = Matrix::random(16, 16, 1);
        let b = Matrix::random(16, 16, 2);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(41).tenant("gold"));
        assert!(resp.result.is_err());
        assert_eq!(resp.admission.shed, Some(ShedReason::QueueFull));
        assert!(!resp.admission.is_admitted());
        assert_eq!(resp.admission.tenant, "gold");
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.shed, 1);
        assert_eq!(snap.admitted, 0);
    }

    #[test]
    fn admission_report_rides_served_responses() {
        let svc = GemmService::start(no_artifact_config()).unwrap();
        let a = Matrix::random(16, 16, 6);
        let b = Matrix::random(16, 16, 7);
        let resp = svc.submit_sync(
            GemmRequest::new(a, b)
                .id(42)
                .tenant("gold")
                .priority(Priority::High)
                .deadline(Duration::from_secs(30)),
        );
        assert!(resp.result.is_ok());
        assert!(resp.admission.is_admitted());
        assert_eq!(resp.admission.tenant, "gold");
        assert_eq!(resp.admission.lane, Priority::High);
        let slack = resp.admission.deadline_slack_s.expect("deadline was set");
        assert!(slack > 0.0, "a 30 s deadline on a 16-cube cannot miss: {slack}");
        let snap = svc.metrics.snapshot();
        assert_eq!(snap.admitted, 1);
        assert_eq!(snap.deadline_met, 1);
        assert_eq!(snap.deadline_missed, 0);
        assert!(snap.goodput_flops > 0);
        assert_eq!(snap.tenant_requests[0], 1, "tenant gold claimed the first gauge slot");
    }

    #[test]
    fn policy_default_deadline_applies_when_unset() {
        let svc = GemmService::start(ServiceConfig {
            artifact_dir: None,
            admission: AdmissionPolicy { default_deadline_s: Some(30.0), ..Default::default() },
            ..Default::default()
        })
        .unwrap();
        let a = Matrix::random(16, 16, 8);
        let b = Matrix::random(16, 16, 9);
        let resp = svc.submit_sync(GemmRequest::new(a, b).id(43));
        assert!(resp.result.is_ok());
        assert!(resp.admission.deadline_slack_s.is_some(), "policy default deadline applied");
    }
}
