//! Synthetic serving workloads: deterministic request traces with
//! Poisson / bursty / diurnal arrivals, a configurable shape mix, and
//! a multi-tenant overlay (weights, priorities, deadlines) — the
//! inference-style GEMM streams the paper's introduction motivates.
//!
//! Used by the end-to-end example, the serve bench, the open-loop
//! admission harness ([`crate::coordinator::serve`]) and the
//! backpressure tests; deterministic from the seed so every run is
//! reproducible.

use crate::coordinator::admission::Priority;
use crate::util::rng::Xoshiro256;

/// One entry of a request trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEntry {
    pub id: u64,
    /// Arrival offset from trace start, seconds.
    pub arrival_s: f64,
    /// Problem shape (m, k, n).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Chained (A·B)·C request.
    pub chained: bool,
    /// Index into the generator's tenant table (0 when single-tenant).
    pub tenant: usize,
    /// Priority lane the issuing tenant rides.
    pub priority: Priority,
    /// Deadline, seconds *from arrival*; None = no deadline.
    pub deadline_s: Option<f64>,
}

/// Shape mix entry: (m, k, n, weight, chained).
#[derive(Clone, Copy, Debug)]
pub struct ShapeMix {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub weight: u32,
    pub chained: bool,
}

/// One tenant of the serving mix.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// DRR fair-share weight.
    pub weight: u32,
    pub priority: Priority,
    /// Deadline stamped on this tenant's requests, seconds from
    /// arrival; None = best-effort.
    pub deadline_s: Option<f64>,
}

impl TenantSpec {
    pub fn new(name: &str, weight: u32, priority: Priority, deadline_s: Option<f64>) -> Self {
        Self { name: name.into(), weight, priority, deadline_s }
    }
}

/// Arrival process shaping the instantaneous rate around the mean.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalModel {
    /// Memoryless exponential gaps at the base rate.
    Poisson,
    /// On/off modulated Poisson: `factor`× the base rate for `on_s`
    /// seconds, then base/`factor` for `off_s` — the flash-crowd shape
    /// that stresses admission control hardest.
    Bursty { factor: f64, on_s: f64, off_s: f64 },
    /// Sinusoidal day-cycle: rate(t) = base · (1 + depth·sin(2πt/T)).
    /// `depth` in [0, 1); the trough keeps the rate positive.
    Diurnal { period_s: f64, depth: f64 },
}

impl ArrivalModel {
    /// Instantaneous rate at `t` for a base rate.
    pub fn rate_at(&self, base_hz: f64, t: f64) -> f64 {
        match *self {
            ArrivalModel::Poisson => base_hz,
            ArrivalModel::Bursty { factor, on_s, off_s } => {
                assert!(factor >= 1.0 && on_s > 0.0 && off_s > 0.0, "bursty params");
                let phase = t % (on_s + off_s);
                if phase < on_s {
                    base_hz * factor
                } else {
                    base_hz / factor
                }
            }
            ArrivalModel::Diurnal { period_s, depth } => {
                assert!(period_s > 0.0 && (0.0..1.0).contains(&depth), "diurnal params");
                base_hz * (1.0 + depth * (std::f64::consts::TAU * t / period_s).sin())
            }
        }
    }
}

/// Trace generator.
#[derive(Clone, Debug)]
pub struct WorkloadGen {
    pub seed: u64,
    /// Mean arrival rate (requests/second).
    pub rate_hz: f64,
    pub mix: Vec<ShapeMix>,
    pub arrival: ArrivalModel,
    /// Tenant table; empty = one anonymous best-effort tenant.
    pub tenants: Vec<TenantSpec>,
}

impl WorkloadGen {
    /// The default serving mix: artifact-backed 256³/512³/64³ jobs, a
    /// slice of chained multiplies, and a tail of odd fallback shapes.
    pub fn serving_default(seed: u64, rate_hz: f64) -> Self {
        Self {
            seed,
            rate_hz,
            mix: vec![
                ShapeMix { m: 256, k: 256, n: 256, weight: 4, chained: false },
                ShapeMix { m: 512, k: 512, n: 512, weight: 2, chained: false },
                ShapeMix { m: 64, k: 64, n: 64, weight: 2, chained: false },
                ShapeMix { m: 256, k: 256, n: 256, weight: 1, chained: true },
                ShapeMix { m: 96, k: 96, n: 96, weight: 1, chained: false },
            ],
            arrival: ArrivalModel::Poisson,
            tenants: Vec::new(),
        }
    }

    /// The multi-tenant overload mix the serving demos run: three
    /// tenants weighted 3:2:1 with tiered priorities and deadlines,
    /// over a single batched shape so the fair-share arithmetic is
    /// legible.
    pub fn multi_tenant(seed: u64, rate_hz: f64) -> Self {
        Self {
            seed,
            rate_hz,
            mix: vec![ShapeMix { m: 256, k: 256, n: 256, weight: 1, chained: false }],
            arrival: ArrivalModel::Poisson,
            tenants: vec![
                TenantSpec::new("gold", 3, Priority::High, Some(0.05)),
                TenantSpec::new("silver", 2, Priority::Normal, Some(0.10)),
                TenantSpec::new("bronze", 1, Priority::Low, Some(0.20)),
            ],
        }
    }

    /// Same generator with a different arrival process.
    pub fn with_arrival(mut self, arrival: ArrivalModel) -> Self {
        self.arrival = arrival;
        self
    }

    /// Generate `count` requests with (rate-modulated) exponential
    /// inter-arrival gaps.
    pub fn trace(&self, count: u64) -> Vec<TraceEntry> {
        assert!(self.rate_hz > 0.0, "rate must be positive");
        let total_weight: u32 = self.mix.iter().map(|m| m.weight).sum();
        assert!(total_weight > 0, "mix must have weight");
        let tenant_weight: u32 = self.tenants.iter().map(|t| t.weight.max(1)).sum();
        let mut rng = Xoshiro256::seed_from_u64(self.seed);
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(count as usize);
        for id in 0..count {
            // Exponential inter-arrival: -ln(U)/rate(t), the thinning-
            // free piecewise approximation (rate sampled at the gap's
            // start — exact for Poisson, faithful at workload scales
            // for the modulated processes).
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / self.arrival.rate_at(self.rate_hz, t);
            // Weighted shape draw.
            let mut pick = rng.next_below(total_weight as u64) as u32;
            let mut chosen = self.mix[0];
            for m in &self.mix {
                if pick < m.weight {
                    chosen = *m;
                    break;
                }
                pick -= m.weight;
            }
            // Weighted tenant draw (no RNG spent when single-tenant,
            // so single-tenant traces are stable across this change).
            let tenant = if self.tenants.len() > 1 {
                let mut pick = rng.next_below(tenant_weight as u64) as u32;
                let mut idx = 0;
                for (i, spec) in self.tenants.iter().enumerate() {
                    if pick < spec.weight.max(1) {
                        idx = i;
                        break;
                    }
                    pick -= spec.weight.max(1);
                }
                idx
            } else {
                0
            };
            let (priority, deadline_s) = self
                .tenants
                .get(tenant)
                .map_or((Priority::Normal, None), |s| (s.priority, s.deadline_s));
            out.push(TraceEntry {
                id,
                arrival_s: t,
                m: chosen.m,
                k: chosen.k,
                n: chosen.n,
                chained: chosen.chained,
                tenant,
                priority,
                deadline_s,
            });
        }
        out
    }

    /// Offered load in FLOP/s for a trace (paper FLOP convention).
    ///
    /// The span is measured from the trace origin (t = 0) to the last
    /// arrival — the same clock the serve sim charges utilization
    /// against — not from the first arrival. (The old `last − first`
    /// span overstated load whenever the first arrival landed late,
    /// and disagreed with every consumer that divides by
    /// `last.arrival_s`.) Empty and singleton traces offer 0.0 rather
    /// than panicking or dividing by a zero span.
    pub fn offered_flops(trace: &[TraceEntry]) -> f64 {
        if trace.len() < 2 {
            return 0.0;
        }
        let span = trace.last().unwrap().arrival_s;
        let flops: f64 = trace
            .iter()
            .map(|e| {
                let f = crate::perfmodel::flop_count(e.m as u64, e.n as u64, e.k as u64) as f64;
                if e.chained {
                    2.0 * f
                } else {
                    f
                }
            })
            .sum();
        flops / span.max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let g = WorkloadGen::serving_default(42, 100.0);
        assert_eq!(g.trace(50), g.trace(50));
        let g2 = WorkloadGen::serving_default(43, 100.0);
        assert_ne!(g.trace(50), g2.trace(50));
    }

    #[test]
    fn arrivals_monotone_and_rate_ish() {
        let g = WorkloadGen::serving_default(1, 200.0);
        let trace = g.trace(2000);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        // Mean rate within 10% of nominal over 2000 arrivals.
        let span = trace.last().unwrap().arrival_s;
        let rate = 2000.0 / span;
        assert!((rate - 200.0).abs() / 200.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn mix_respected() {
        let g = WorkloadGen::serving_default(7, 100.0);
        let trace = g.trace(4000);
        let n512 = trace.iter().filter(|e| e.m == 512).count() as f64;
        let n256 = trace.iter().filter(|e| e.m == 256 && !e.chained).count() as f64;
        // weights 2 vs 4 -> ratio ~0.5 (loose band).
        let ratio = n512 / n256;
        assert!((0.3..0.8).contains(&ratio), "ratio {ratio}");
        assert!(trace.iter().any(|e| e.chained));
        assert!(trace.iter().any(|e| e.m == 96));
    }

    #[test]
    fn offered_load_positive() {
        let g = WorkloadGen::serving_default(3, 50.0);
        let trace = g.trace(500);
        let f = WorkloadGen::offered_flops(&trace);
        assert!(f > 0.0);
        // ~50 req/s of ~33 MFLOP avg -> order 1e9; sanity band.
        assert!(f > 1e8 && f < 1e12, "{f}");
    }

    #[test]
    fn offered_load_spans_from_origin_and_survives_tiny_traces() {
        let entry = |id: u64, arrival_s: f64| TraceEntry {
            id,
            arrival_s,
            m: 256,
            k: 256,
            n: 256,
            chained: false,
            tenant: 0,
            priority: Priority::Normal,
            deadline_s: None,
        };
        // Degenerate traces offer nothing — no panic, no 0/0.
        assert_eq!(WorkloadGen::offered_flops(&[]), 0.0);
        assert_eq!(WorkloadGen::offered_flops(&[entry(0, 3.0)]), 0.0);
        // Two arrivals with a late start: the span runs from t = 0 to
        // the last arrival (4 s), matching the serve sim's clock — not
        // the 2 s first-to-last gap, which would double the load.
        let trace = [entry(0, 2.0), entry(1, 4.0)];
        let per = crate::perfmodel::flop_count(256, 256, 256) as f64;
        let got = WorkloadGen::offered_flops(&trace);
        assert_eq!(got, 2.0 * per / 4.0, "span must be origin-to-last");
    }

    #[test]
    #[should_panic(expected = "rate must be positive")]
    fn zero_rate_rejected() {
        WorkloadGen {
            seed: 1,
            rate_hz: 0.0,
            mix: vec![],
            arrival: ArrivalModel::Poisson,
            tenants: vec![],
        }
        .trace(1);
    }

    #[test]
    fn bursty_arrivals_cluster() {
        let base = WorkloadGen::serving_default(11, 100.0);
        let bursty = base
            .clone()
            .with_arrival(ArrivalModel::Bursty { factor: 8.0, on_s: 0.5, off_s: 2.0 });
        let trace = bursty.trace(2000);
        assert_eq!(trace, bursty.trace(2000), "deterministic");
        // Coefficient of variation of the gaps must exceed the Poisson
        // baseline's (CV ≈ 1): bursts pack tiny gaps, off-phases huge.
        let cv = |t: &[TraceEntry]| {
            let gaps: Vec<f64> = t.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var.sqrt() / mean
        };
        let cv_poisson = cv(&base.trace(2000));
        let cv_bursty = cv(&trace);
        assert!(
            cv_bursty > cv_poisson * 1.5,
            "bursty CV {cv_bursty:.2} vs poisson {cv_poisson:.2}"
        );
    }

    #[test]
    fn diurnal_rate_swings_through_the_cycle() {
        let m = ArrivalModel::Diurnal { period_s: 100.0, depth: 0.8 };
        assert!((m.rate_at(10.0, 25.0) - 18.0).abs() < 1e-9, "peak at T/4");
        assert!((m.rate_at(10.0, 75.0) - 2.0).abs() < 1e-9, "trough at 3T/4");
        let g = WorkloadGen::serving_default(5, 50.0).with_arrival(m);
        let trace = g.trace(3000);
        // Peak half-cycles must hold more arrivals than troughs.
        let period = 100.0;
        let peak = trace.iter().filter(|e| (e.arrival_s % period) < period / 2.0).count();
        let trough = trace.len() - peak;
        assert!(peak as f64 > 1.5 * trough as f64, "peak {peak} trough {trough}");
    }

    #[test]
    fn tenants_draw_by_weight_with_tiered_deadlines() {
        let g = WorkloadGen::multi_tenant(17, 500.0);
        let trace = g.trace(6000);
        assert_eq!(trace, g.trace(6000), "deterministic");
        let count = |t: usize| trace.iter().filter(|e| e.tenant == t).count() as f64;
        let (gold, silver, bronze) = (count(0), count(1), count(2));
        assert!((gold / bronze - 3.0).abs() < 0.5, "3:1 ratio, got {}", gold / bronze);
        assert!((silver / bronze - 2.0).abs() < 0.4, "2:1 ratio, got {}", silver / bronze);
        let first_gold = trace.iter().find(|e| e.tenant == 0).unwrap();
        assert_eq!(first_gold.priority, Priority::High);
        assert_eq!(first_gold.deadline_s, Some(0.05));
        // Single-tenant traces stay anonymous / best-effort.
        let single = WorkloadGen::serving_default(17, 500.0).trace(10);
        assert!(single.iter().all(|e| e.tenant == 0 && e.deadline_s.is_none()));
    }
}
