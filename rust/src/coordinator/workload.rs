//! Synthetic serving workloads: deterministic request traces with
//! Poisson-ish arrivals and a configurable shape mix — the
//! inference-style GEMM streams the paper's introduction motivates.
//!
//! Used by the end-to-end example, the serve bench and the backpressure
//! tests; deterministic from the seed so every run is reproducible.

use crate::util::rng::Xoshiro256;

/// One entry of a request trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEntry {
    pub id: u64,
    /// Arrival offset from trace start, seconds.
    pub arrival_s: f64,
    /// Problem shape (m, k, n).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Chained (A·B)·C request.
    pub chained: bool,
}

/// Shape mix entry: (m, k, n, weight, chained).
#[derive(Clone, Copy, Debug)]
pub struct ShapeMix {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub weight: u32,
    pub chained: bool,
}

/// Trace generator.
#[derive(Clone, Debug)]
pub struct WorkloadGen {
    pub seed: u64,
    /// Mean arrival rate (requests/second).
    pub rate_hz: f64,
    pub mix: Vec<ShapeMix>,
}

impl WorkloadGen {
    /// The default serving mix: artifact-backed 256³/512³/64³ jobs, a
    /// slice of chained multiplies, and a tail of odd fallback shapes.
    pub fn serving_default(seed: u64, rate_hz: f64) -> Self {
        Self {
            seed,
            rate_hz,
            mix: vec![
                ShapeMix { m: 256, k: 256, n: 256, weight: 4, chained: false },
                ShapeMix { m: 512, k: 512, n: 512, weight: 2, chained: false },
                ShapeMix { m: 64, k: 64, n: 64, weight: 2, chained: false },
                ShapeMix { m: 256, k: 256, n: 256, weight: 1, chained: true },
                ShapeMix { m: 96, k: 96, n: 96, weight: 1, chained: false },
            ],
        }
    }

    /// Generate `count` requests with exponential inter-arrival gaps.
    pub fn trace(&self, count: u64) -> Vec<TraceEntry> {
        assert!(self.rate_hz > 0.0, "rate must be positive");
        let total_weight: u32 = self.mix.iter().map(|m| m.weight).sum();
        assert!(total_weight > 0, "mix must have weight");
        let mut rng = Xoshiro256::seed_from_u64(self.seed);
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(count as usize);
        for id in 0..count {
            // Exponential inter-arrival: -ln(U)/rate.
            let u = rng.next_f64().max(1e-12);
            t += -u.ln() / self.rate_hz;
            // Weighted shape draw.
            let mut pick = rng.next_below(total_weight as u64) as u32;
            let mut chosen = self.mix[0];
            for m in &self.mix {
                if pick < m.weight {
                    chosen = *m;
                    break;
                }
                pick -= m.weight;
            }
            out.push(TraceEntry {
                id,
                arrival_s: t,
                m: chosen.m,
                k: chosen.k,
                n: chosen.n,
                chained: chosen.chained,
            });
        }
        out
    }

    /// Offered load in FLOP/s for a trace (paper FLOP convention).
    pub fn offered_flops(trace: &[TraceEntry]) -> f64 {
        if trace.len() < 2 {
            return 0.0;
        }
        let span = trace.last().unwrap().arrival_s - trace[0].arrival_s;
        let flops: f64 = trace
            .iter()
            .map(|e| {
                let f = crate::perfmodel::flop_count(e.m as u64, e.n as u64, e.k as u64) as f64;
                if e.chained {
                    2.0 * f
                } else {
                    f
                }
            })
            .sum();
        flops / span.max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let g = WorkloadGen::serving_default(42, 100.0);
        assert_eq!(g.trace(50), g.trace(50));
        let g2 = WorkloadGen::serving_default(43, 100.0);
        assert_ne!(g.trace(50), g2.trace(50));
    }

    #[test]
    fn arrivals_monotone_and_rate_ish() {
        let g = WorkloadGen::serving_default(1, 200.0);
        let trace = g.trace(2000);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        // Mean rate within 10% of nominal over 2000 arrivals.
        let span = trace.last().unwrap().arrival_s;
        let rate = 2000.0 / span;
        assert!((rate - 200.0).abs() / 200.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn mix_respected() {
        let g = WorkloadGen::serving_default(7, 100.0);
        let trace = g.trace(4000);
        let n512 = trace.iter().filter(|e| e.m == 512).count() as f64;
        let n256 = trace.iter().filter(|e| e.m == 256 && !e.chained).count() as f64;
        // weights 2 vs 4 -> ratio ~0.5 (loose band).
        let ratio = n512 / n256;
        assert!((0.3..0.8).contains(&ratio), "ratio {ratio}");
        assert!(trace.iter().any(|e| e.chained));
        assert!(trace.iter().any(|e| e.m == 96));
    }

    #[test]
    fn offered_load_positive() {
        let g = WorkloadGen::serving_default(3, 50.0);
        let trace = g.trace(500);
        let f = WorkloadGen::offered_flops(&trace);
        assert!(f > 0.0);
        // ~50 req/s of ~33 MFLOP avg -> order 1e9; sanity band.
        assert!(f > 1e8 && f < 1e12, "{f}");
    }

    #[test]
    #[should_panic(expected = "rate must be positive")]
    fn zero_rate_rejected() {
        WorkloadGen { seed: 1, rate_hz: 0.0, mix: vec![] }.trace(1);
    }
}
