//! Ablation studies over the design choices the paper argues for.
//!
//! Each ablation flips exactly one design decision and quantifies the
//! effect through the calibrated models, making the paper's qualitative
//! claims (§III-C, §V, §VI) measurable:
//!
//! 1. **Read/Compute overlap** (§V): sequential phases vs the paper's
//!    double-buffered overlap.
//! 2. **The third dimension** (§III): d_k0 sweep at constant #DSP —
//!    on-chip vs register-chain throughput balancing.
//! 3. **Register chains** (§III-C): register-chained vs broadcast
//!    interconnect through the fitter (what the Intel SDK design pays).
//! 4. **Reuse ratio** (§IV): blocking below the eq. 14 minimum — the
//!    stall penalty of an undersized level-1 block.

use crate::blocked::{Level1Blocking, OffchipDesign, OffchipSim};
use crate::fpga::{Fitter, InterconnectStyle, PlacementRequest};
use crate::systolic::ArraySize;

/// Outcome of one ablation arm.
#[derive(Clone, Debug)]
pub struct AblationArm {
    pub label: String,
    pub gflops: f64,
    pub e_d: f64,
    pub note: String,
}

/// A two-or-more-arm ablation result.
#[derive(Clone, Debug)]
pub struct Ablation {
    pub name: String,
    pub arms: Vec<AblationArm>,
}

impl Ablation {
    /// Ratio of the first arm (the paper's choice) to the second.
    pub fn advantage(&self) -> f64 {
        self.arms[0].gflops / self.arms[1].gflops
    }
}

fn design_g() -> OffchipDesign {
    OffchipDesign {
        blocking: Level1Blocking::new(ArraySize::new(64, 32, 2, 2), 512, 512),
        fmax_mhz: 398.0,
        controller_efficiency: 0.97,
    }
}

/// 1 — Read/Compute overlap vs fully sequential phases.
pub fn ablate_overlap(d2: u64) -> Ablation {
    let design = design_g();
    let sim = OffchipSim::new(design);
    let with = sim.simulate(d2, d2, d2);

    // Sequential arm: every slab pays read THEN compute (no double
    // buffering): per-slab cost = read + compute instead of max(·,·).
    let sched = design.schedule();
    let counts = sched.counts(d2);
    let read = counts.initial_read;
    let compute = counts.final_compute;
    let slabs = counts.overlapped_slabs + 1;
    let seq_total = slabs * (read + compute) + counts.write;
    let blocks = (d2 / design.blocking.di1 as u64) * (d2 / design.blocking.dj1 as u64);
    let seq_cycles = seq_total * blocks;
    let seq_seconds = seq_cycles as f64 / (design.fmax_mhz * 1e6);
    let seq_gflops =
        crate::perfmodel::flop_count(d2, d2, d2) as f64 / seq_seconds / 1e9;

    Ablation {
        name: format!("read/compute overlap (design G, d2={d2})"),
        arms: vec![
            AblationArm {
                label: "overlapped (paper §V)".into(),
                gflops: with.gflops,
                e_d: with.e_d,
                note: "read slab k+1 while computing slab k".into(),
            },
            AblationArm {
                label: "sequential phases".into(),
                gflops: seq_gflops,
                e_d: seq_gflops / design.peak_gflops(),
                note: "each slab: read, then compute".into(),
            },
        ],
    }
}

/// 2 — d_k0 sweep at constant #DSP (the third dimension's raison d'être).
pub fn ablate_third_dimension(d2: u64) -> Vec<AblationArm> {
    // 4096 DSPs split as (64,32,2), (32,32,4), (32,16,8): Table I's G/H/L
    // family, all at the same frequency to isolate the geometry effect.
    let f = 398.0;
    [(64u32, 32u32, 2u32, 2u32), (32, 32, 4, 4), (32, 16, 8, 8)]
        .iter()
        .map(|&(di, dj, dk, dp)| {
            let array = ArraySize::new(di, dj, dk, dp);
            let blocking = Level1Blocking::derive_min(array, 8);
            let sim = OffchipSim::new(OffchipDesign {
                blocking,
                fmax_mhz: f,
                controller_efficiency: 0.97,
            });
            let (ba, bb) = array.face_throughputs();
            let r = sim.simulate(d2, d2, d2);
            AblationArm {
                label: format!("({di},{dj},{dk},dp={dp})"),
                gflops: r.gflops,
                e_d: r.e_d,
                note: format!(
                    "on-chip throughput B_A+B_B = {} fl/cyc, d1 = ({}, {})",
                    ba + bb,
                    blocking.di1,
                    blocking.dj1
                ),
            }
        })
        .collect()
}

/// 3 — Register chains vs broadcast interconnect: how many DSPs survive
/// the fitter as the array grows.
pub fn ablate_interconnect() -> Vec<(u32, bool, bool)> {
    let fitter = Fitter::default();
    let mut rows = Vec::new();
    for &dsps in &[2048u32, 3072, 3584, 4096, 4480, 4608, 4704] {
        // A representative dp=2 partition of the DSP budget.
        let pes = dsps / 2;
        let chained = fitter
            .place(&PlacementRequest {
                dsps,
                dp: 2,
                pes,
                style: InterconnectStyle::RegisterChained,
            })
            .fits();
        let broadcast = fitter
            .place(&PlacementRequest {
                dsps,
                dp: 2,
                pes,
                style: InterconnectStyle::Broadcast,
            })
            .fits();
        rows.push((dsps, chained, broadcast));
    }
    rows
}

/// 4 — Undersized reuse: blocking below the eq. 14 minimum stalls the
/// pipeline (eq. 2 ⇒ eq. 3).
pub fn ablate_reuse(d2: u64) -> Ablation {
    let array = ArraySize::new(64, 32, 2, 2);
    let good = Level1Blocking::new(array, 512, 512); // r = (16, 8): rates = 8 fl/cyc
    let starved = Level1Blocking::new(array, 256, 256); // r = (8, 4): wants 16 fl/cyc

    let run = |blocking: Level1Blocking| {
        let sim = OffchipSim::new(OffchipDesign {
            blocking,
            fmax_mhz: 398.0,
            controller_efficiency: 0.97,
        });
        sim.simulate(d2, d2, d2)
    };
    let g = run(good);
    let s = run(starved);
    let (ga, _gb, _) = OffchipDesign {
        blocking: starved,
        fmax_mhz: 398.0,
        controller_efficiency: 0.97,
    }
    .global_rates();
    Ablation {
        name: format!("reuse ratio (design G, d2={d2})"),
        arms: vec![
            AblationArm {
                label: "d1=512 (eq. 18 sizing)".into(),
                gflops: g.gflops,
                e_d: g.e_d,
                note: "global rate 8 fl/cyc == LSU ceiling: no stall".into(),
            },
            AblationArm {
                label: "d1=256 (half the minimum)".into(),
                gflops: s.gflops,
                e_d: s.e_d,
                note: format!(
                    "wants 16 fl/cyc, LSU ceiling caps at {ga:.0}: read paces every slab"
                ),
            },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_wins_and_bounds() {
        let a = ablate_overlap(4096);
        // Overlap roughly halves the read+compute span: advantage in
        // (1.2x, 2.0x) once the un-overlapped write is accounted.
        let adv = a.advantage();
        assert!(adv > 1.2 && adv < 2.0, "advantage {adv}");
        assert!(a.arms[0].e_d > a.arms[1].e_d);
    }

    #[test]
    fn third_dimension_tradeoff_visible() {
        let arms = ablate_third_dimension(4096);
        assert_eq!(arms.len(), 3);
        // All three reach comparable sustained throughput (the paper's
        // point: the third dimension trades *where* data moves, not how
        // much compute fits) ...
        let g: Vec<f64> = arms.iter().map(|a| a.gflops).collect();
        let spread = (g.iter().cloned().fold(f64::MIN, f64::max)
            - g.iter().cloned().fold(f64::MAX, f64::min))
            / g[0];
        assert!(spread < 0.1, "spread {spread}");
        // ... while the on-chip memory throughput differs by 4x between
        // the extremes (visible in the notes).
        assert!(arms[0].note.contains("192 fl/cyc"));
        assert!(arms[2].note.contains("384 fl/cyc"));
    }

    #[test]
    fn chains_extend_the_fit_frontier() {
        let rows = ablate_interconnect();
        // Broadcast dies earlier than register-chained.
        let chained_max = rows.iter().filter(|r| r.1).map(|r| r.0).max().unwrap();
        let broadcast_max = rows.iter().filter(|r| r.2).map(|r| r.0).max().unwrap();
        assert!(chained_max > broadcast_max, "{chained_max} vs {broadcast_max}");
        assert_eq!(chained_max, 4480); // design F
    }

    #[test]
    fn starved_reuse_halves_throughput() {
        let a = ablate_reuse(4096);
        let adv = a.advantage();
        // Reads take twice as long per slab: compute fully paced by
        // memory, ~2x at large k; the exposed write damps it slightly.
        assert!(adv > 1.5 && adv < 2.2, "advantage {adv}");
    }
}
