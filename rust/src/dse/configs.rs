//! The paper's design catalog: every synthesis attempt of Table I, with
//! the published outcomes and, for the fitted designs, the level-1
//! blocking used by the Tables II–V evaluations.

use crate::blocked::Level1Blocking;
use crate::systolic::ArraySize;

/// One catalog entry.
#[derive(Clone, Debug)]
pub struct DesignSpec {
    pub id: &'static str,
    pub array: ArraySize,
    /// Published f_max in MHz; `None` == fitter failed.
    pub fmax_mhz: Option<f64>,
    /// Level-1 blocking from the table captions (fitted designs only).
    pub blocking: Option<(u32, u32)>,
    /// Matrix-size sweep (d² values) of the design's evaluation table.
    pub sweep: &'static [u64],
}

impl DesignSpec {
    pub fn level1(&self) -> Option<Level1Blocking> {
        self.blocking
            .map(|(di1, dj1)| Level1Blocking::new(self.array, di1, dj1))
    }

    /// d_j2 values of the sweep (design F is rectangular: d_j2 scales by
    /// d_j1/d_i1 = 640/560).
    pub fn sweep_dj2(&self) -> Vec<u64> {
        match self.blocking {
            Some((di1, dj1)) if di1 != dj1 => self
                .sweep
                .iter()
                .map(|d| d * dj1 as u64 / di1 as u64)
                .collect(),
            _ => self.sweep.to_vec(),
        }
    }
}

/// Table I, in row order.
pub fn paper_catalog() -> Vec<DesignSpec> {
    const S672: &[u64] = &[672, 1344, 2688, 5376, 10752, 21504];
    const S576: &[u64] = &[576, 1152, 2304, 4608, 9216, 18432];
    const S560: &[u64] = &[560, 1120, 2240, 4480, 8960, 17920];
    const S512: &[u64] = &[512, 1024, 2048, 4096, 8192, 16384];
    vec![
        DesignSpec {
            id: "A",
            array: ArraySize::new(28, 28, 6, 3),
            fmax_mhz: None,
            blocking: None,
            sweep: &[],
        },
        DesignSpec {
            id: "B",
            array: ArraySize::new(28, 28, 6, 2),
            fmax_mhz: None,
            blocking: None,
            sweep: &[],
        },
        DesignSpec {
            id: "C",
            array: ArraySize::new(28, 28, 6, 1),
            fmax_mhz: Some(368.0),
            blocking: Some((672, 672)),
            sweep: S672,
        },
        DesignSpec {
            id: "D",
            array: ArraySize::new(72, 32, 2, 2),
            fmax_mhz: None,
            blocking: None,
            sweep: &[],
        },
        DesignSpec {
            id: "E",
            array: ArraySize::new(72, 32, 2, 1),
            fmax_mhz: Some(368.0),
            blocking: Some((576, 576)),
            sweep: S576,
        },
        DesignSpec {
            id: "F",
            array: ArraySize::new(70, 32, 2, 2),
            fmax_mhz: Some(410.0),
            blocking: Some((560, 640)),
            sweep: S560,
        },
        DesignSpec {
            id: "G",
            array: ArraySize::new(64, 32, 2, 2),
            fmax_mhz: Some(398.0),
            blocking: Some((512, 512)),
            sweep: S512,
        },
        DesignSpec {
            id: "H",
            array: ArraySize::new(32, 32, 4, 4),
            fmax_mhz: Some(408.0),
            blocking: Some((512, 512)),
            sweep: S512,
        },
        DesignSpec {
            id: "I",
            array: ArraySize::new(32, 32, 4, 2),
            fmax_mhz: Some(396.0),
            blocking: Some((512, 512)),
            sweep: S512,
        },
        DesignSpec {
            id: "L",
            array: ArraySize::new(32, 16, 8, 8),
            fmax_mhz: Some(391.0),
            blocking: Some((512, 512)),
            sweep: S512,
        },
        DesignSpec {
            id: "M",
            array: ArraySize::new(32, 16, 8, 4),
            fmax_mhz: Some(363.0),
            blocking: Some((512, 512)),
            sweep: S512,
        },
        DesignSpec {
            id: "N",
            array: ArraySize::new(32, 16, 8, 2),
            fmax_mhz: Some(381.0),
            blocking: Some((512, 512)),
            sweep: S512,
        },
    ]
}

/// The fitted (usable) designs, in Table order.
pub fn fitted_designs() -> Vec<DesignSpec> {
    paper_catalog().into_iter().filter(|d| d.fmax_mhz.is_some()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_has_all_table1_rows() {
        let cat = paper_catalog();
        assert_eq!(cat.len(), 12);
        let failed: Vec<&str> =
            cat.iter().filter(|d| d.fmax_mhz.is_none()).map(|d| d.id).collect();
        assert_eq!(failed, vec!["A", "B", "D"]);
    }

    #[test]
    fn catalog_dsps_match_table1() {
        for d in paper_catalog() {
            let dsps = d.array.dsps();
            match d.id {
                "A" | "B" | "C" => assert_eq!(dsps, 4704),
                "D" | "E" => assert_eq!(dsps, 4608),
                "F" => assert_eq!(dsps, 4480),
                _ => assert_eq!(dsps, 4096),
            }
        }
    }

    #[test]
    fn blockings_valid_and_match_captions() {
        for d in fitted_designs() {
            let b = d.level1().expect("fitted design must have blocking");
            assert!(b.validate().is_ok(), "{}", d.id);
            // Every sweep size obeys the caption constraint d² % d¹ == 0.
            for &d2 in d.sweep {
                assert_eq!(d2 % b.di1 as u64, 0, "{}: {d2}", d.id);
            }
        }
    }

    #[test]
    fn design_f_rectangular_sweep() {
        let f = paper_catalog().into_iter().find(|d| d.id == "F").unwrap();
        let dj2 = f.sweep_dj2();
        assert_eq!(dj2[0], 640);
        assert_eq!(dj2[5], 20480);
    }

    #[test]
    fn reuse_rates_never_exceed_lsu_ceiling() {
        // Every published blocking implies global rates <= 8 floats/cycle
        // (the eq. 4 ceiling above 300 MHz — all designs run above it).
        for d in fitted_designs() {
            let b = d.level1().unwrap();
            let (ga, gb) = b.implied_global_rates();
            assert!(ga <= 8.0 + 1e-9, "{}: ga={ga}", d.id);
            assert!(gb <= 8.0 + 1e-9, "{}: gb={gb}", d.id);
        }
    }
}
