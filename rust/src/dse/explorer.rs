//! The design-space explorer: plays the role of "the user interacting
//! with the HLS tool" (§III-C) over the calibrated synthesis models.

use crate::blocked::{Level1Blocking, OffchipDesign, OffchipSim};
use crate::fpga::{FitOutcome, Fitter, FmaxModel, InterconnectStyle, PlacementRequest, Stratix10};
use crate::hls::lsu::max_floats_per_cycle;
use crate::hls::report::SynthesisReport;
use crate::systolic::ArraySize;

/// One evaluated design point.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub array: ArraySize,
    pub outcome: FitOutcome,
    /// f_max in MHz when fitted.
    pub fmax_mhz: Option<f64>,
    /// Whether f_max came from a measured calibration point.
    pub fmax_measured: bool,
    /// Peak GFLOPS (eq. 5) when fitted.
    pub tpeak_gflops: Option<f64>,
    /// Sustained GFLOPS at the given evaluation size (folds in eq. 19).
    pub sustained_gflops: Option<f64>,
}

impl DesignPoint {
    pub fn report(&self, id: &str, device: &Stratix10) -> SynthesisReport {
        SynthesisReport {
            design_id: id.to_string(),
            pes: self.array.pes() as u32,
            di0: self.array.di0,
            dj0: self.array.dj0,
            dk0: self.array.dk0,
            dp: self.array.dp,
            dsps: self.array.dsps() as u32,
            dsp_pct_available: self.array.dsps() as f64 / device.kernel_dsps as f64 * 100.0,
            fmax_mhz: self.fmax_mhz,
            tpeak_gflops: self.tpeak_gflops,
        }
    }
}

/// The explorer.
#[derive(Clone, Debug)]
pub struct Explorer {
    pub device: Stratix10,
    pub fitter: Fitter,
    pub fmax: FmaxModel,
    /// d² used when ranking by sustained throughput.
    pub eval_d2: u64,
}

impl Default for Explorer {
    fn default() -> Self {
        Self {
            device: Stratix10::gx2800_520n(),
            fitter: Fitter::default(),
            fmax: FmaxModel::calibrated(),
            eval_d2: 8192,
        }
    }
}

impl Explorer {
    /// Evaluate one candidate through fitter + f_max + (optionally) the
    /// off-chip simulator.
    pub fn evaluate(&self, array: ArraySize) -> DesignPoint {
        let req = PlacementRequest {
            dsps: array.dsps() as u32,
            dp: array.dp,
            pes: array.pes() as u32,
            style: InterconnectStyle::RegisterChained,
        };
        let outcome = self.fitter.place(&req);
        if !outcome.fits() {
            return DesignPoint {
                array,
                outcome,
                fmax_mhz: None,
                fmax_measured: false,
                tpeak_gflops: None,
                sustained_gflops: None,
            };
        }
        let key = (array.di0, array.dj0, array.dk0, array.dp, InterconnectStyle::RegisterChained);
        let u = self.device.dsp_utilization(array.dsps() as u32);
        let f = self.fmax.fmax(&key, u, true);
        let tpeak = self.device.peak_gflops(array.dsps() as u32, f.mhz);

        // Sustained throughput at eval_d2: needs a valid blocking; derive
        // the minimal one at the eq. 4 rate for this f_max.
        let rate = max_floats_per_cycle(f.mhz) as u32;
        let blocking = Level1Blocking::derive_min(array, rate);
        let sustained = if self.eval_d2 % blocking.di1 as u64 == 0
            && self.eval_d2 % blocking.dj1 as u64 == 0
            && self.eval_d2 % array.dk0 as u64 == 0
        {
            let sim = OffchipSim::new(OffchipDesign {
                blocking,
                fmax_mhz: f.mhz,
                controller_efficiency: 0.97,
            });
            Some(sim.simulate(self.eval_d2, self.eval_d2, self.eval_d2).gflops)
        } else {
            None
        };

        DesignPoint {
            array,
            outcome,
            fmax_mhz: Some(f.mhz),
            fmax_measured: f.measured,
            tpeak_gflops: Some(tpeak),
            sustained_gflops: sustained,
        }
    }

    /// Enumerate a constrained sweep of candidates: d_i0 ∈ `dis`,
    /// d_j0 ∈ `djs`, d_k0 ∈ `dks`, all valid d_p divisors.
    pub fn sweep(&self, dis: &[u32], djs: &[u32], dks: &[u32]) -> Vec<DesignPoint> {
        let mut out = Vec::new();
        for &di in dis {
            for &dj in djs {
                for &dk in dks {
                    for dp in 1..=dk {
                        if dk % dp != 0 {
                            continue;
                        }
                        let array = ArraySize { di0: di, dj0: dj, dk0: dk, dp };
                        if array.dsps() > self.device.kernel_dsps as u64 {
                            continue;
                        }
                        out.push(self.evaluate(array));
                    }
                }
            }
        }
        out
    }

    /// The best fitted design by sustained throughput.
    pub fn best<'a>(&self, points: &'a [DesignPoint]) -> Option<&'a DesignPoint> {
        points
            .iter()
            .filter(|p| p.outcome.fits())
            .max_by(|a, b| {
                // NaN throughput (degenerate model input) must lose the
                // max, so screen it to 0.0 before the total order.
                let key = |p: &DesignPoint| {
                    p.sustained_gflops
                        .or(p.tpeak_gflops)
                        .filter(|g| g.is_finite())
                        .unwrap_or(0.0)
                };
                key(a).total_cmp(&key(b))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::configs::paper_catalog;

    #[test]
    fn catalog_outcomes_reproduced() {
        // The explorer must reproduce every Table I row: fit/fail AND,
        // for fitted rows, the measured f_max (via calibration).
        let ex = Explorer::default();
        for spec in paper_catalog() {
            let p = ex.evaluate(spec.array);
            assert_eq!(p.outcome.fits(), spec.fmax_mhz.is_some(), "design {}", spec.id);
            if let Some(f) = spec.fmax_mhz {
                assert_eq!(p.fmax_mhz, Some(f), "design {}", spec.id);
                assert!(p.fmax_measured);
            }
        }
    }

    #[test]
    fn sweep_covers_dp_divisors() {
        let ex = Explorer::default();
        let points = ex.sweep(&[32], &[32], &[4]);
        // dp in {1, 2, 4}.
        assert_eq!(points.len(), 3);
    }

    #[test]
    fn best_design_beats_siblings() {
        let ex = Explorer::default();
        let points = ex.sweep(&[32, 64], &[16, 32], &[2, 4, 8]);
        let best = ex.best(&points).expect("some design fits");
        assert!(best.outcome.fits());
        assert!(best.tpeak_gflops.unwrap() > 2000.0);
    }

    #[test]
    fn unseen_points_use_predictor() {
        let ex = Explorer::default();
        let p = ex.evaluate(ArraySize::new(16, 16, 4, 2));
        assert!(p.outcome.fits());
        assert!(!p.fmax_measured);
        // Small design, low utilization: near base frequency.
        assert!(p.fmax_mhz.unwrap() > 400.0);
    }

    #[test]
    fn sustained_ranking_prefers_high_fmax_high_dsp() {
        // F (4480 DSPs @ 410 MHz) must rank above L (4096 @ 391) on
        // sustained throughput, mirroring Table IV vs Table V.
        // 20160 = lcm(560, 576): divisible by F's derived blocking.
        let ex = Explorer { eval_d2: 20160, ..Default::default() };
        let f = ex.evaluate(ArraySize::new(70, 32, 2, 2));
        let ex512 = Explorer { eval_d2: 8192, ..Default::default() };
        let l = ex512.evaluate(ArraySize::new(32, 16, 8, 8));
        match (f.sustained_gflops, l.sustained_gflops) {
            (Some(sf), Some(sl)) => assert!(sf > sl, "{sf} vs {sl}"),
            other => panic!("expected sustained numbers, got {other:?}"),
        }
    }
}
