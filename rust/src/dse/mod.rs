//! Design-space exploration (the paper's §III-C closing remark: the
//! systolic sizes are "a parameter useful in design space exploration").
//!
//! * [`configs`] — the paper's design catalog (Table I rows A–N with
//!   their Level-1 blockings from the Table II–V captions).
//! * [`explorer`] — enumerate candidate (d_i0, d_j0, d_k0, d_p) points,
//!   run the fitter + f_max models, and rank by peak and by *sustained*
//!   throughput (which folds in eq. 19) — reproducing Table I and
//!   extending beyond it.

pub mod ablation;
pub mod configs;
pub mod explorer;

pub use ablation::{ablate_interconnect, ablate_overlap, ablate_reuse, ablate_third_dimension};
pub use configs::{paper_catalog, DesignSpec};
pub use explorer::{DesignPoint, Explorer};
