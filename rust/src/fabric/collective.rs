//! Collective reduction schedules for the partial-C combine.
//!
//! A 2.5D plan leaves `c` partial C tiles spread over `c` cards; the
//! combine must land the sum on the tile's home card before writeback.
//! Three schedules, all expressed as rounds of [`Flow`]s and priced
//! per-step over the routed links of a [`FabricState`]:
//!
//! * **direct** — every partial ships whole to the home in one round;
//!   `(c−1)·B` bytes converge on the home's ingress links.
//! * **tree** — partials pair-reduce in ⌈log₂ c⌉ rounds of `B` bytes;
//!   the long hauls parallelize but every round still moves full
//!   tiles.
//! * **ring** — reduce-scatter then gather: `c−1` rounds in which each
//!   participant passes a `B/c` slice to its ring successor, then one
//!   gather round of `c−1` slices into the home. Per participant this
//!   moves `2·(c−1)/c · B ≈ 2B` bytes of *slices*, the classic
//!   bandwidth-optimal schedule
//!   ([`crate::perfmodel::ring_reduce_seconds`] is the closed form the
//!   tests check against).
//!
//! [`CollectiveSchedule::cheapest`] prices all three under an O(1)
//! occupancy checkpoint (rolled back after each candidate, so the real
//! links are left untouched) and picks the winner — on a congested
//! ring the slice-sized flows win, on a roomy mesh direct sends do.

use super::routing::FabricState;

/// One point-to-point transfer of a schedule round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Flow {
    pub src: usize,
    pub dst: usize,
    pub bytes: u64,
}

/// Which schedule family built the rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceAlgo {
    Direct,
    Tree,
    Ring,
}

impl ReduceAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            ReduceAlgo::Direct => "direct",
            ReduceAlgo::Tree => "tree",
            ReduceAlgo::Ring => "ring-rs",
        }
    }
}

/// A reduction of one tile's partials onto its home card.
#[derive(Clone, Debug)]
pub struct CollectiveSchedule {
    pub algo: ReduceAlgo,
    pub home: usize,
    /// Rounds run in order; flows within a round are concurrent under
    /// the link-contention model.
    pub rounds: Vec<Vec<Flow>>,
}

impl CollectiveSchedule {
    /// Every non-home partial ships whole to the home, one round.
    pub fn direct(home: usize, others: &[usize], bytes: u64) -> Self {
        let round: Vec<Flow> =
            others.iter().map(|&src| Flow { src, dst: home, bytes }).collect();
        let rounds = if round.is_empty() { Vec::new() } else { vec![round] };
        Self { algo: ReduceAlgo::Direct, home, rounds }
    }

    /// Binary pair-reduction toward the home, ⌈log₂ c⌉ rounds.
    pub fn tree(home: usize, others: &[usize], bytes: u64) -> Self {
        let mut active = Vec::with_capacity(others.len() + 1);
        active.push(home);
        active.extend_from_slice(others);
        let mut rounds = Vec::new();
        while active.len() > 1 {
            let mut round = Vec::new();
            let mut survivors = Vec::with_capacity(active.len().div_ceil(2));
            for pair in active.chunks(2) {
                survivors.push(pair[0]);
                if pair.len() == 2 {
                    round.push(Flow { src: pair[1], dst: pair[0], bytes });
                }
            }
            rounds.push(round);
            active = survivors;
        }
        Self { algo: ReduceAlgo::Tree, home, rounds }
    }

    /// Ring reduce-scatter over all participants, then a gather of the
    /// reduced slices into the home.
    pub fn ring(home: usize, others: &[usize], bytes: u64) -> Self {
        let mut members = Vec::with_capacity(others.len() + 1);
        members.push(home);
        members.extend_from_slice(others);
        let c = members.len();
        let mut rounds = Vec::new();
        if c > 1 {
            let slice = bytes.div_ceil(c as u64);
            for _ in 0..c - 1 {
                rounds.push(
                    (0..c)
                        .map(|i| Flow {
                            src: members[i],
                            dst: members[(i + 1) % c],
                            bytes: slice,
                        })
                        .collect(),
                );
            }
            rounds.push(
                members[1..].iter().map(|&src| Flow { src, dst: home, bytes: slice }).collect(),
            );
        }
        Self { algo: ReduceAlgo::Ring, home, rounds }
    }

    pub fn build(algo: ReduceAlgo, home: usize, others: &[usize], bytes: u64) -> Self {
        match algo {
            ReduceAlgo::Direct => Self::direct(home, others, bytes),
            ReduceAlgo::Tree => Self::tree(home, others, bytes),
            ReduceAlgo::Ring => Self::ring(home, others, bytes),
        }
    }

    /// Bytes the schedule puts on the fabric (hop count excluded).
    pub fn bytes_on_fabric(&self) -> u64 {
        self.rounds.iter().flatten().map(|f| f.bytes).sum()
    }

    /// Run the rounds over the fabric, mutating link occupancy.
    /// `ready[card]` carries each participant's data-availability time
    /// in and its completion time out. Returns the home's finish time,
    /// or None when the fabric is partitioned.
    pub fn run(&self, fabric: &mut FabricState, ready: &mut [f64]) -> Option<f64> {
        self.run_traced(fabric, ready).map(|(finish, _)| finish)
    }

    /// As [`Self::run`], also returning every flow's (src, start, end)
    /// so callers can draw busy timelines.
    pub fn run_traced(
        &self,
        fabric: &mut FabricState,
        ready: &mut [f64],
    ) -> Option<(f64, Vec<(usize, f64, f64)>)> {
        let mut trace = Vec::new();
        for round in &self.rounds {
            // Rounds have barrier semantics on the *data*: a flow sends
            // what its source held at the start of the round.
            let snapshot: Vec<f64> = ready.to_vec();
            for f in round {
                let (start, end) = fabric.send(f.src, f.dst, f.bytes, snapshot[f.src])?;
                ready[f.dst] = ready[f.dst].max(end);
                trace.push((f.src, start, end));
            }
        }
        Some((ready[self.home], trace))
    }

    /// Price the schedule without changing the fabric's observable
    /// occupancy: the rounds run under an O(1)
    /// [`FabricState::checkpoint`] and roll back afterwards — same
    /// numbers a clone-and-run would produce, minus the per-candidate
    /// O(n²) route-table clone the profiler used to watch here.
    pub fn price(&self, fabric: &mut FabricState, ready: &[f64]) -> Option<f64> {
        let _scope = crate::trace::profile::scope("collective.price");
        let cp = fabric.checkpoint();
        let mut r = ready.to_vec();
        let t = self.run(fabric, &mut r);
        fabric.rollback(cp);
        t
    }

    /// Build all three schedules, price each on the current occupancy,
    /// and return the cheapest (ties break direct < tree < ring).
    pub fn cheapest(
        fabric: &mut FabricState,
        home: usize,
        others: &[usize],
        bytes: u64,
        ready: &[f64],
    ) -> CollectiveSchedule {
        let _scope = crate::trace::profile::scope("collective.cheapest");
        let candidates = [
            Self::direct(home, others, bytes),
            Self::tree(home, others, bytes),
            Self::ring(home, others, bytes),
        ];
        let mut best: Option<(f64, CollectiveSchedule)> = None;
        for c in candidates {
            if let Some(t) = c.price(fabric, ready) {
                if best.as_ref().map_or(true, |(bt, _)| t < *bt) {
                    best = Some((t, c));
                }
            }
        }
        best.map(|(_, c)| c).unwrap_or_else(|| Self::direct(home, others, bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::topology::Topology;

    #[test]
    fn schedule_shapes() {
        let direct = CollectiveSchedule::direct(0, &[1, 2, 3], 1200);
        assert_eq!(direct.rounds.len(), 1);
        assert_eq!(direct.rounds[0].len(), 3);
        assert_eq!(direct.bytes_on_fabric(), 3600);

        let tree = CollectiveSchedule::tree(0, &[1, 2, 3], 1200);
        assert_eq!(tree.rounds.len(), 2);
        assert_eq!(tree.bytes_on_fabric(), 3600);

        // Ring over c=4: 3 reduce-scatter rounds of 4 slice flows plus
        // one 3-flow gather; 15 slices of 300 B total.
        let ring = CollectiveSchedule::ring(0, &[1, 2, 3], 1200);
        assert_eq!(ring.rounds.len(), 4);
        assert_eq!(ring.bytes_on_fabric(), 15 * 300);

        // Single participant: nothing to move.
        assert!(CollectiveSchedule::ring(0, &[], 1200).rounds.is_empty());
        assert!(CollectiveSchedule::direct(0, &[], 1200).rounds.is_empty());
    }

    #[test]
    fn ring_matches_closed_form_on_uncongested_links() {
        // 4 participants on a 4-card ring: every flow is one hop and
        // the rounds pipeline with no contention, so the priced time
        // matches the perfmodel closed form up to hop latency and the
        // slice rounding.
        let mut fabric = FabricState::new(Topology::ring(4));
        let bytes = 400_000_000u64;
        let sched = CollectiveSchedule::ring(0, &[1, 2, 3], bytes);
        let t = sched.price(&mut fabric, &[0.0; 4]).unwrap();
        assert_eq!(fabric.busy_seconds_total(), 0.0, "pricing must roll back");
        let bw = fabric.lane().effective_bytes_per_s();
        let want = crate::perfmodel::ring_reduce_seconds(4, bytes, bw);
        // The closed form serializes the gather through one home
        // ingress link; the routed schedule can use both ring
        // directions, so it prices at or below the formula but above
        // the reduce-scatter phase alone ((c−1)/c · B/bw).
        assert!(t <= want * 1.001, "priced {t} vs closed form {want}");
        assert!(t >= 0.5 * want, "priced {t} vs closed form {want}");
    }

    #[test]
    fn ring_beats_direct_on_a_ring_fabric() {
        // 8 partials converging on one home over a ring: the home's two
        // ingress links serialize the direct sends, while the
        // reduce-scatter slices pipeline around the ring.
        let mut fabric = FabricState::new(Topology::ring(8));
        let others: Vec<usize> = (1..8).collect();
        let bytes = 100_000_000u64;
        let ready = [0.0; 8];
        let direct =
            CollectiveSchedule::direct(0, &others, bytes).price(&mut fabric, &ready).unwrap();
        let ring = CollectiveSchedule::ring(0, &others, bytes).price(&mut fabric, &ready).unwrap();
        assert!(ring < direct, "ring {ring} vs direct {direct}");
        let best = CollectiveSchedule::cheapest(&mut fabric, 0, &others, bytes, &ready);
        assert_eq!(best.algo, ReduceAlgo::Ring);
    }

    #[test]
    fn direct_wins_on_a_full_mesh_pair() {
        // Two participants: direct is one send; tree is identical; ring
        // pays two rounds of slices. Cheapest must not pick ring.
        let mut fabric = FabricState::new(Topology::full_mesh(4));
        let best = CollectiveSchedule::cheapest(&mut fabric, 0, &[1], 100_000_000, &[0.0; 4]);
        assert_eq!(best.algo, ReduceAlgo::Direct);
    }

    #[test]
    fn run_respects_participant_readiness() {
        let mut fabric = FabricState::new(Topology::full_mesh(3));
        let mut ready = [0.0, 5.0, 0.0];
        let sched = CollectiveSchedule::direct(0, &[1, 2], 1_000_000);
        let finish = sched.run(&mut fabric, &mut ready).unwrap();
        // Card 1's partial only exists at t=5: the home cannot finish
        // before that.
        assert!(finish > 5.0, "{finish}");
    }
}
