//! Card-fabric layer: multi-hop 520N topologies, congestion-aware
//! routing, and compute-overlapped collective reductions.
//!
//! The cluster layer's original interconnect put every card one QSFP
//! hop from every other — fine for a handful of cards, fiction past
//! that. This subsystem makes the fabric explicit:
//!
//! * [`topology`] — port-constrained graphs. **The port budget**: a
//!   520N carries four QSFP28 ports ([`CARD_PORTS`]), so a card
//!   terminates at most 4 point-to-point links. A ring spends 2, a 2D
//!   torus all 4, a full mesh is only buildable up to 5 cards (beyond
//!   that the constructor degrades to the densest 4-regular chordal
//!   ring), and a fat tree spends 1 port per card on a leaf-switch
//!   uplink, buying bisection from switch trunks instead of card
//!   ports.
//! * [`routing`] — BFS shortest-path route tables over the live
//!   fabric, with a circuit-style contention model: a flow reserves
//!   every directed link on its path for `B/(w·bw) + h·λ` seconds, so
//!   concurrent flows on one link serialize while flows on disjoint
//!   links proceed in parallel. Card deaths invalidate routes and
//!   in-flight steps re-route around the gap. What-if replays —
//!   placement candidates, collective pricing, drain-target selection —
//!   snapshot occupancy in O(1) via [`FabricState::checkpoint`] /
//!   [`FabricState::rollback`] and replay over [`PathCache`]-compiled
//!   routes instead of resetting and re-walking the route table.
//! * [`collective`] — schedules for the 2.5D partial-C combine.
//!   **The reduce-scatter cost formula**: a ring reduce over `c`
//!   participants moves `c−1` rounds of `B/c`-byte slices, then
//!   gathers `c−1` reduced slices into the home, so on uncongested
//!   1-hop links
//!
//!   ```text
//!   T_ring ≈ 2·(c−1)/c · B / bw_qsfp        (eq. RS)
//!   ```
//!
//!   versus `(c−1)·B / bw_ingress` for direct sends — the ring wins
//!   whenever the home's ingress degree is the bottleneck, which is
//!   exactly the narrow-topology case
//!   ([`crate::perfmodel::ring_reduce_seconds`] is the closed form).
//! * [`overlap`] — pipelined schedules that launch a tile's reduction
//!   the moment its last partial exists, hiding the combine under the
//!   leaf compute still running on other cards, with per-card
//!   busy/idle timelines.
//!
//! The cluster scheduler routes its reduction bookkeeping through
//! [`FabricState`], `ClusterSim` carries a [`Topology`] instead of a
//! flat interconnect, and the `fabric` CLI subcommand plus
//! `examples/fabric_topology_sweep.rs` sweep fleet sizes across
//! topologies.
//!
//! Since the elastic-fleet layer ([`crate::cluster::elastic`]) the
//! fabric also **grows**: [`Topology::attach_card`] splices a card
//! into a built graph within the port budget (hot spares and
//! watermark growth both use it), [`RouteTable::attach`] patches only
//! the routes the splice invalidated, and [`FabricState::slow_link`]
//! models degraded cables for the chaos harness.

pub mod collective;
pub mod overlap;
pub mod routing;
pub mod topology;

pub use collective::{CollectiveSchedule, Flow, ReduceAlgo};
pub use overlap::{
    pipeline_schedule, pipeline_schedule_traced, timelines_from_trace, Activity, CardTimeline,
    OverlapReport, Segment,
};
pub use routing::{CachedPath, FabricCheckpoint, FabricState, PathCache, RouteTable, HOP_LATENCY_S};
pub use topology::{AttachReport, FabricEdge, Topology, TopologyKind, CARD_PORTS};
