//! Compute-overlapped collective reductions: pipeline the partial-C
//! combine of finished k-slices under the leaf compute that is still
//! running.
//!
//! [`pipeline_schedule`] replays a partition plan two ways over the
//! same fabric and fleet timing:
//!
//! * **barrier** — every card computes all its shards, then the tile
//!   reductions run after the last card drains (the naive
//!   phase-ordered schedule).
//! * **overlapped** — a tile's reduction launches the moment its last
//!   partial exists, sharing fabric links with reductions of other
//!   tiles while the remaining compute proceeds (card DMA engines own
//!   the QSFP ports, so sends never block the compute engine).
//!
//! Plans with more shards than cards are folded block-wise
//! (`card = device · cards / plan_devices`) so a k-replication plane
//! keeps landing on a distinct card group and tiles finish in waves —
//! the stagger the overlap exploits. The report carries both makespans
//! plus per-card busy/idle timelines of the overlapped run.
//!
//! Both replays emit flight-recorder spans ([`crate::trace`]):
//! [`pipeline_schedule_traced`] records the overlapped run and the
//! barrier counterfactual into separate sinks, and the ASCII timelines
//! are built *from the event stream* ([`timelines_from_trace`]) — the
//! trace is the single source of truth for what each card was doing
//! when, which is what `examples/trace_critical_path.rs` exploits to
//! show the overlap shrinking the critical path's fabric share.

use super::collective::{CollectiveSchedule, ReduceAlgo};
use super::routing::FabricState;
use super::topology::Topology;
use crate::cluster::partition::{PartitionPlan, Shard};
use crate::trace::{Category, TraceLog, Tracer, Track};

/// What a timeline segment spent its wall-clock on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activity {
    Compute,
    Reduce,
}

/// One busy interval of a card.
#[derive(Clone, Copy, Debug)]
pub struct Segment {
    pub start: f64,
    pub end: f64,
    pub activity: Activity,
}

/// Busy intervals of one card over the overlapped run.
#[derive(Clone, Debug)]
pub struct CardTimeline {
    pub card: usize,
    pub segments: Vec<Segment>,
}

impl CardTimeline {
    pub fn busy_seconds(&self) -> f64 {
        self.segments.iter().map(|s| s.end - s.start).sum()
    }

    /// ASCII busy/idle strip: '#' compute, 'r' reduce, '.' idle.
    pub fn render(&self, makespan: f64, cols: usize) -> String {
        let cols = cols.max(1);
        let mut strip = vec!['.'; cols];
        for s in &self.segments {
            let lo = ((s.start / makespan) * cols as f64).floor() as usize;
            let hi = ((s.end / makespan) * cols as f64).ceil() as usize;
            let glyph = match s.activity {
                Activity::Compute => '#',
                Activity::Reduce => 'r',
            };
            for slot in strip.iter_mut().take(hi.min(cols)).skip(lo.min(cols)) {
                if *slot == '.' || glyph == 'r' {
                    *slot = glyph;
                }
            }
        }
        strip.into_iter().collect()
    }
}

/// Outcome of the two replays.
#[derive(Clone, Debug)]
pub struct OverlapReport {
    /// Collective the run used — the most frequently chosen one when
    /// cheapest-per-tile selection mixed algorithms.
    pub algo: ReduceAlgo,
    pub overlapped_makespan_seconds: f64,
    pub barrier_makespan_seconds: f64,
    /// Fabric circuit-hold seconds of the overlapped run's reductions.
    pub reduction_seconds: f64,
    pub timelines: Vec<CardTimeline>,
}

impl OverlapReport {
    /// Fraction of the barrier makespan the overlap removes.
    pub fn saving_fraction(&self) -> f64 {
        if self.barrier_makespan_seconds <= 0.0 {
            return 0.0;
        }
        1.0 - self.overlapped_makespan_seconds / self.barrier_makespan_seconds
    }

    /// Timeline strips plus the makespan comparison.
    pub fn render(&self) -> String {
        let span = self.overlapped_makespan_seconds.max(f64::MIN_POSITIVE);
        let mut out = format!(
            "reduction overlap ({}): {:.4} s overlapped vs {:.4} s barrier ({:.1}% saved)\n",
            self.algo.name(),
            self.overlapped_makespan_seconds,
            self.barrier_makespan_seconds,
            self.saving_fraction() * 100.0,
        );
        for t in &self.timelines {
            out.push_str(&format!("  card {:>2} |{}|\n", t.card, t.render(span, 64)));
        }
        out
    }
}

struct TileJob {
    home: usize,
    /// (card, partial-ready time) per participating card.
    parts: Vec<(usize, f64)>,
    bytes: u64,
}

/// Per-card busy timelines rebuilt from a recorded event stream:
/// compute-lane spans become [`Activity::Compute`] segments, fabric
/// (reduction) lane spans become [`Activity::Reduce`]. This is the
/// single code path the ASCII strips render through.
pub fn timelines_from_trace(log: &TraceLog, cards: usize) -> Vec<CardTimeline> {
    let mut timelines: Vec<CardTimeline> =
        (0..cards).map(|card| CardTimeline { card, segments: Vec::new() }).collect();
    for s in &log.spans {
        let (card, activity) = match s.track {
            Track::CardCompute(c) => (c, Activity::Compute),
            Track::CardFabric(c) => (c, Activity::Reduce),
            _ => continue,
        };
        if card < cards {
            timelines[card].segments.push(Segment { start: s.start, end: s.end, activity });
        }
    }
    for t in &mut timelines {
        t.segments.sort_by(|a, b| a.start.total_cmp(&b.start));
    }
    timelines
}

/// Replay `plan` on `topology` with per-shard compute times from
/// `compute_seconds(card, shard)`, reducing each tile with `algo`
/// (None = cheapest per tile). Host DMA is assumed double-buffered
/// away, isolating the compute↔reduction interplay.
pub fn pipeline_schedule(
    plan: &PartitionPlan,
    topology: &Topology,
    algo: Option<ReduceAlgo>,
    compute_seconds: impl Fn(usize, &Shard) -> f64,
) -> OverlapReport {
    pipeline_schedule_traced(plan, topology, algo, &Tracer::off(), &Tracer::off(), compute_seconds)
}

/// As [`pipeline_schedule`], recording both replays: the overlapped
/// run's compute and collective-flow spans go into `overlapped`, the
/// phase-ordered counterfactual's into `barrier` (the compute spans
/// are identical — only the reductions move). The report's timelines
/// always render from the overlapped event stream, whether or not the
/// caller's sinks record.
pub fn pipeline_schedule_traced(
    plan: &PartitionPlan,
    topology: &Topology,
    algo: Option<ReduceAlgo>,
    overlapped: &Tracer,
    barrier: &Tracer,
    compute_seconds: impl Fn(usize, &Shard) -> f64,
) -> OverlapReport {
    let cards = topology.cards;
    assert!(cards > 0, "empty fabric");
    let devices = plan.devices.max(1);
    let fold = |dev: usize| if devices <= cards { dev } else { dev * cards / devices };
    // The overlapped replay records into a private sink so the
    // timelines can render from the event stream even when the
    // caller's tracer is off; the spans are copied out at the end.
    let rec = Tracer::recording();

    // Per-tile reduction home: the k-first shard's planned device,
    // folded onto its card (same source of truth as the scheduler).
    let homes = plan.tile_homes();

    // Serial per-card compute in plan order.
    let mut compute_free = vec![0.0f64; cards];
    let mut tiles: std::collections::BTreeMap<(u64, u64), TileJob> = Default::default();
    for s in &plan.shards {
        let card = fold(s.device);
        let start = compute_free[card];
        let end = start + compute_seconds(card, s);
        compute_free[card] = end;
        rec.span(
            Track::CardCompute(card),
            Category::Compute,
            || format!("shard r{} c{} k{}", s.row0, s.col0, s.k0),
            start,
            end,
        );
        barrier.span(
            Track::CardCompute(card),
            Category::Compute,
            || format!("shard r{} c{} k{}", s.row0, s.col0, s.k0),
            start,
            end,
        );
        let job = tiles.entry(s.tile()).or_insert_with(|| TileJob {
            home: fold(homes[&s.tile()].1),
            parts: Vec::new(),
            bytes: s.c_bytes(),
        });
        match job.parts.iter_mut().find(|(c, _)| *c == card) {
            Some(p) => p.1 = p.1.max(end),
            None => job.parts.push((card, end)),
        }
    }
    let compute_end = compute_free.iter().fold(0.0f64, |m, &t| m.max(t));

    // Tiles reduce in the order their last partial lands (stable sort
    // over the key-ordered map keeps ties deterministic).
    let mut jobs: Vec<((u64, u64), TileJob)> = tiles.into_iter().collect();
    jobs.sort_by(|a, b| {
        let ra = a.1.parts.iter().fold(0.0f64, |m, &(_, t)| m.max(t));
        let rb = b.1.parts.iter().fold(0.0f64, |m, &(_, t)| m.max(t));
        ra.total_cmp(&rb)
    });

    // Overlapped replay: reductions start at partial readiness.
    let mut fabric = FabricState::new(topology.clone());
    let mut overlapped_makespan = compute_end;
    let mut chosen: Vec<CollectiveSchedule> = Vec::with_capacity(jobs.len());
    for (tkey, job) in &jobs {
        let others: Vec<usize> =
            job.parts.iter().map(|&(c, _)| c).filter(|&c| c != job.home).collect();
        let mut ready = vec![0.0f64; cards];
        for &(c, t) in &job.parts {
            ready[c] = t;
        }
        let sched = match algo {
            Some(a) => CollectiveSchedule::build(a, job.home, &others, job.bytes),
            None => {
                CollectiveSchedule::cheapest(&mut fabric, job.home, &others, job.bytes, &ready)
            }
        };
        let (finish, flows) =
            sched.run_traced(&mut fabric, &mut ready).expect("healthy fabric is connected");
        for (src, f_start, f_end) in flows {
            rec.span(
                Track::CardFabric(src),
                Category::Collective,
                || format!("collective r{} c{} -> card{}", tkey.0, tkey.1, job.home),
                f_start,
                f_end,
            );
        }
        overlapped_makespan = overlapped_makespan.max(finish);
        chosen.push(sched);
    }
    let reduction_seconds = fabric.busy_seconds_total();
    // Report the modal pick (cheapest-per-tile may mix collectives).
    let report_algo = [ReduceAlgo::Direct, ReduceAlgo::Tree, ReduceAlgo::Ring]
        .into_iter()
        .max_by_key(|&a| chosen.iter().filter(|s| s.algo == a).count())
        .filter(|_| !chosen.is_empty())
        .unwrap_or_else(|| algo.unwrap_or(ReduceAlgo::Direct));

    // Barrier replay: identical schedules, but nothing moves before the
    // last card finishes computing.
    let mut barrier_fabric = FabricState::new(topology.clone());
    let mut barrier_makespan = compute_end;
    for (sched, (tkey, job)) in chosen.iter().zip(&jobs) {
        let mut ready = vec![compute_end; cards];
        let (finish, flows) = sched
            .run_traced(&mut barrier_fabric, &mut ready)
            .expect("healthy fabric is connected");
        for (src, f_start, f_end) in flows {
            barrier.span(
                Track::CardFabric(src),
                Category::Collective,
                || format!("collective r{} c{} -> card{}", tkey.0, tkey.1, job.home),
                f_start,
                f_end,
            );
        }
        barrier_makespan = barrier_makespan.max(finish);
    }

    // Hand the overlapped stream to the caller and build the report's
    // timelines from it.
    let log = rec.take();
    if overlapped.is_recording() {
        for s in &log.spans {
            overlapped.span(s.track, s.category, || s.name.clone(), s.start, s.end);
        }
    }
    let timelines = timelines_from_trace(&log, cards);
    OverlapReport {
        algo: report_algo,
        overlapped_makespan_seconds: overlapped_makespan,
        barrier_makespan_seconds: barrier_makespan,
        reduction_seconds,
        timelines,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::PartitionStrategy;

    fn flat_rate(_: usize, s: &Shard) -> f64 {
        s.flops() as f64 / 3.0e12
    }

    #[test]
    fn overlap_never_loses_to_the_barrier() {
        for strategy in [
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 4 },
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 8 },
            PartitionStrategy::Grid2D { p: 2, q: 4 },
        ] {
            let plan = PartitionPlan::new(strategy, 8192, 8192, 8192).unwrap();
            for topo in [Topology::ring(8), Topology::torus2d(4, 2)] {
                let r = pipeline_schedule(&plan, &topo, Some(ReduceAlgo::Direct), flat_rate);
                assert!(
                    r.overlapped_makespan_seconds <= r.barrier_makespan_seconds + 1e-9,
                    "{strategy:?} on {}: {r:?}",
                    topo.name(),
                );
                assert!(r.saving_fraction() >= 0.0);
            }
        }
    }

    #[test]
    fn staggered_waves_overlap_materially() {
        // 32 shards folded onto 8 ring cards: tiles complete in four
        // waves and the early waves' reductions hide under the
        // remaining compute.
        let plan =
            PartitionPlan::new(PartitionStrategy::Summa25D { p: 2, q: 2, c: 8 }, 8192, 8192, 8192)
                .unwrap();
        let topo = Topology::ring(8);
        let r = pipeline_schedule(&plan, &topo, Some(ReduceAlgo::Direct), flat_rate);
        assert!(r.reduction_seconds > 0.0);
        assert!(
            r.saving_fraction() > 0.05,
            "expected material overlap, got {:.3} ({r:?})",
            r.saving_fraction()
        );
    }

    #[test]
    fn grid_plan_has_nothing_to_reduce() {
        let plan =
            PartitionPlan::new(PartitionStrategy::Grid2D { p: 2, q: 2 }, 4096, 4096, 4096).unwrap();
        let r = pipeline_schedule(&plan, &Topology::full_mesh(4), None, flat_rate);
        assert_eq!(r.reduction_seconds, 0.0);
        assert!((r.saving_fraction()).abs() < 1e-12);
        assert_eq!(r.overlapped_makespan_seconds, r.barrier_makespan_seconds);
    }

    #[test]
    fn timelines_cover_compute_and_reduce() {
        let plan =
            PartitionPlan::new(PartitionStrategy::Summa25D { p: 1, q: 2, c: 2 }, 2048, 2048, 2048)
                .unwrap();
        let topo = Topology::full_mesh(4);
        let r = pipeline_schedule(&plan, &topo, Some(ReduceAlgo::Direct), flat_rate);
        let compute: usize = r
            .timelines
            .iter()
            .flat_map(|t| &t.segments)
            .filter(|s| s.activity == Activity::Compute)
            .count();
        assert_eq!(compute, 4, "one compute segment per shard");
        let reduce: usize = r
            .timelines
            .iter()
            .flat_map(|t| &t.segments)
            .filter(|s| s.activity == Activity::Reduce)
            .count();
        assert_eq!(reduce, 2, "one direct send per non-home partial");
        let text = r.render();
        assert!(text.contains("overlapped"));
    }

    #[test]
    fn traced_replays_feed_the_timelines_and_the_critical_path() {
        use crate::trace::{critical_path, Tracer};
        let plan =
            PartitionPlan::new(PartitionStrategy::Summa25D { p: 2, q: 2, c: 8 }, 8192, 8192, 8192)
                .unwrap();
        let topo = Topology::ring(8);
        let over = Tracer::recording();
        let barr = Tracer::recording();
        let r = pipeline_schedule_traced(
            &plan,
            &topo,
            Some(ReduceAlgo::Direct),
            &over,
            &barr,
            flat_rate,
        );
        let olog = over.take();
        let blog = barr.take();
        // The report's timelines and the exported stream agree segment
        // for segment: one code path.
        let rebuilt = timelines_from_trace(&olog, topo.cards);
        for (a, b) in r.timelines.iter().zip(&rebuilt) {
            assert_eq!(a.segments.len(), b.segments.len());
        }
        // The traces cover the two makespans exactly...
        let co = critical_path(&olog);
        let cb = critical_path(&blog);
        assert!((co.makespan - r.overlapped_makespan_seconds).abs() < 1e-9, "{co:?}");
        assert!((cb.makespan - r.barrier_makespan_seconds).abs() < 1e-9, "{cb:?}");
        // ...and the overlap hides fabric time from the critical path.
        assert!(
            co.share("fabric") < cb.share("fabric"),
            "overlapped fabric share {:.3} vs barrier {:.3}",
            co.share("fabric"),
            cb.share("fabric")
        );
    }
}
