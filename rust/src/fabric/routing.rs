//! Shortest-path route tables and the per-link contention model.
//!
//! Routes are BFS shortest paths with a deterministic tie-break (edge
//! construction order), recomputed whenever a card dies so the
//! surviving fabric heals — a ring with one dead card routes around
//! the gap as a line instead of deadlocking.
//!
//! Transfers are circuit-style: a flow of B bytes over an h-hop path
//! reserves every directed link on the path for
//!
//! ```text
//! t = B / (w_min · bw_qsfp) + h · HOP_LATENCY_S
//! ```
//!
//! where `w_min` is the narrowest trunk width on the path and
//! `bw_qsfp` the derated QSFP28 rate (the [`Link`] peak × efficiency
//! idiom from [`crate::cluster::interconnect`]). Concurrent flows on
//! one directed link therefore serialize, while flows on disjoint
//! links proceed in parallel — exactly the congestion the 2.5D
//! reduction traffic has to negotiate on narrow topologies.

use super::topology::{AttachReport, Topology};
use crate::cluster::interconnect::Link;

/// Store-and-forward latency charged per link traversed.
pub const HOP_LATENCY_S: f64 = 1.0e-6;

/// One source's BFS predecessor row over the live fabric.
fn bfs_row(topology: &Topology, dead: &[bool], src: usize) -> Vec<Option<usize>> {
    let is_dead = |v: usize| dead.get(v).copied().unwrap_or(false);
    let mut prev = vec![None; topology.nodes];
    if is_dead(src) {
        return prev;
    }
    let mut seen = vec![false; topology.nodes];
    seen[src] = true;
    let mut queue = std::collections::VecDeque::from([src]);
    while let Some(v) = queue.pop_front() {
        for &(w, _) in topology.neighbors(v) {
            if !seen[w] && !is_dead(w) {
                seen[w] = true;
                prev[w] = Some(v);
                queue.push_back(w);
            }
        }
    }
    prev
}

/// Hop count of `row`'s src→dst path (None when unreachable).
fn row_hops(row: &[Option<usize>], src: usize, dst: usize) -> Option<u32> {
    if src == dst {
        return Some(0);
    }
    let mut hops = 0;
    let mut v = dst;
    while v != src {
        v = (*row.get(v)?)?;
        hops += 1;
    }
    Some(hops)
}

/// All-pairs shortest-path predecessors over the live fabric.
#[derive(Clone, Debug)]
pub struct RouteTable {
    /// prev[src][v]: predecessor of v on a shortest src→v path.
    prev: Vec<Vec<Option<usize>>>,
}

impl RouteTable {
    pub fn new(topology: &Topology) -> Self {
        Self::avoiding(topology, &[])
    }

    /// Routes that detour around dead cards (switches never die;
    /// `dead` may be shorter than the node count).
    pub fn avoiding(topology: &Topology, dead: &[bool]) -> Self {
        let prev = (0..topology.nodes).map(|src| bfs_row(topology, dead, src)).collect();
        Self { prev }
    }

    /// Patch the table for a fabric grown by a non-structural
    /// [`Topology::attach_card`]: the new node sits at
    /// `topology.nodes - 1` and `spliced` names the card cable it was
    /// spliced into (None when the new card got fresh cables only).
    /// Only rows whose shortest-path tree crossed the spliced cable are
    /// re-run; every other row keeps its paths verbatim and just
    /// learns how to reach the new node through its nearest live
    /// neighbor. Returns how many existing rows were rebuilt.
    pub fn attach(
        &mut self,
        topology: &Topology,
        dead: &[bool],
        spliced: Option<(usize, usize)>,
    ) -> usize {
        let n = topology.nodes;
        let new = n - 1;
        let is_dead = |v: usize| dead.get(v).copied().unwrap_or(false);
        let mut rebuilt = 0;
        for src in 0..self.prev.len() {
            let row = &mut self.prev[src];
            // A tree contains undirected edge (a, b) iff one endpoint
            // is the other's predecessor; only those rows lost a path.
            // A row where exactly one splice endpoint was reachable
            // gains paths (the new card bridges a dead-card partition)
            // and is re-run too.
            let used = spliced.is_some_and(|(a, b)| {
                row[b] == Some(a)
                    || row[a] == Some(b)
                    || (row_hops(row, src, a).is_some() != row_hops(row, src, b).is_some())
            });
            if used {
                *row = bfs_row(topology, dead, src);
                rebuilt += 1;
                continue;
            }
            row.resize(n, None);
            if is_dead(src) {
                continue;
            }
            // Splicing never shortens a surviving path (a detour via
            // the new degree-2 node re-enters through its neighbors),
            // so the old rows stay shortest; the new node hangs off
            // its nearest live neighbor, ties toward the lowest id.
            let best = topology
                .neighbors(new)
                .iter()
                .filter(|&&(nb, _)| !is_dead(nb))
                .filter_map(|&(nb, _)| row_hops(row, src, nb).map(|h| (h, nb)))
                .min();
            row[new] = best.map(|(_, nb)| nb);
        }
        self.prev.push(bfs_row(topology, dead, new));
        rebuilt
    }

    /// Node sequence src..=dst of a shortest live path, None when
    /// unreachable.
    pub fn node_path(&self, src: usize, dst: usize) -> Option<Vec<usize>> {
        if src == dst {
            return Some(vec![src]);
        }
        let mut path = vec![dst];
        let mut v = dst;
        while v != src {
            v = self.prev[src][v]?;
            path.push(v);
        }
        path.reverse();
        Some(path)
    }

    pub fn hops(&self, src: usize, dst: usize) -> Option<u32> {
        self.node_path(src, dst).map(|p| (p.len() - 1) as u32)
    }
}

/// Link occupancy of one fabric during one simulated schedule.
#[derive(Clone, Debug)]
pub struct FabricState {
    pub topology: Topology,
    routes: RouteTable,
    dead: Vec<bool>,
    /// Per undirected edge, free times for the a→b and b→a directions.
    free: Vec<[f64; 2]>,
    busy: Vec<[f64; 2]>,
    /// Per undirected edge, a ≥ 1.0 slowdown factor (degraded cable —
    /// the chaos harness's slow-link fault). Both directions slow.
    slow: Vec<f64>,
    /// Busy seconds carried over from edges retired by a structural
    /// re-trunk ([`Self::attach_card`] on a fat tree), so the
    /// utilization gauges survive fabric growth.
    retired_busy_seconds: f64,
    retired_max_busy_seconds: f64,
    lane: Link,
    /// Sends that aborted mid-flight on a dying transit card and took a
    /// detour.
    pub reroutes: usize,
    /// Undo journal: prior `(free, busy)` of each directed link a send
    /// touched while a checkpoint was outstanding. Empty (and free)
    /// whenever no checkpoint is open.
    journal: Vec<(u32, u8, f64, f64)>,
    open_checkpoints: usize,
}

/// O(1) occupancy snapshot of a [`FabricState`].
///
/// [`FabricState::checkpoint`] hands one out after recording only a
/// journal mark and the scalar gauges; [`FabricState::rollback`] then
/// unwinds the per-link undo journal back to that mark. What-if
/// replays — placement candidates, collective pricing, drain-target
/// selection — pay O(links touched) to undo instead of the O(edges)
/// sweep of [`FabricState::reset_occupancy`] or an O(n²) route-table
/// clone.
///
/// The snapshot covers occupancy only (free/busy times, reroute count,
/// retired-busy gauges). Structural mutations — [`FabricState::kill`],
/// [`FabricState::attach_card`], [`FabricState::slow_link`] — are not
/// journaled and must not happen while a checkpoint is open.
#[derive(Clone, Copy, Debug)]
pub struct FabricCheckpoint {
    mark: usize,
    reroutes: usize,
    retired_busy_seconds: f64,
    retired_max_busy_seconds: f64,
}

/// One compiled route: the directed links, narrowest trunk, slowest
/// cable, and hop count of a card pair's shortest path, precomputed so
/// replay-heavy callers skip the per-send BFS backtrack and neighbor
/// scans. Valid until the fabric changes structurally (kill / attach /
/// slow-link); see [`PathCache`].
#[derive(Clone, Debug)]
pub struct CachedPath {
    /// Directed links `(edge, direction)` in path order.
    links: Vec<(u32, u8)>,
    w_min: u32,
    slow_max: f64,
    hops: u32,
}

impl CachedPath {
    pub fn hops(&self) -> u32 {
        self.hops
    }

    /// Directed links `(edge, direction)` the path reserves, in order.
    pub fn directed_links(&self) -> &[(u32, u8)] {
        &self.links
    }

    /// Uncontended circuit-holding time of `bytes` over this path —
    /// bit-identical to the duration [`FabricState::send`] computes.
    pub fn duration(&self, fabric: &FabricState, bytes: u64) -> f64 {
        self.slow_max * fabric.transfer_seconds(bytes, self.hops, self.w_min)
    }
}

/// All-pairs compiled routes over a frozen fabric.
///
/// Built once per search (placement optimization replays thousands of
/// candidate maps over an immutable topology); [`FabricState::send_cached`]
/// then reproduces [`FabricState::send`]'s contention arithmetic — same
/// float operations in the same order — without re-walking the route
/// table. The cache goes stale if the fabric is killed, grown, or
/// slowed after construction; callers own that invariant.
#[derive(Clone, Debug)]
pub struct PathCache {
    cards: usize,
    paths: Vec<Option<CachedPath>>,
}

impl PathCache {
    pub fn new(fabric: &FabricState) -> Self {
        let cards = fabric.topology.cards;
        let mut paths = Vec::with_capacity(cards * cards);
        for src in 0..cards {
            for dst in 0..cards {
                paths.push(fabric.compile_path(src, dst));
            }
        }
        Self { cards, paths }
    }

    /// Compiled src→dst path (None when unroutable or `src == dst`).
    pub fn get(&self, src: usize, dst: usize) -> Option<&CachedPath> {
        self.paths[src * self.cards + dst].as_ref()
    }
}

impl FabricState {
    pub fn new(topology: Topology) -> Self {
        let routes = RouteTable::new(&topology);
        let edges = topology.edges.len();
        Self {
            dead: vec![false; topology.cards],
            topology,
            routes,
            free: vec![[0.0; 2]; edges],
            busy: vec![[0.0; 2]; edges],
            slow: vec![1.0; edges],
            retired_busy_seconds: 0.0,
            retired_max_busy_seconds: 0.0,
            lane: Link::qsfp28_100g(),
            reroutes: 0,
            journal: Vec::new(),
            open_checkpoints: 0,
        }
    }

    /// Open an O(1) occupancy snapshot. Sends made while the
    /// checkpoint is outstanding journal the prior state of every
    /// directed link they touch; [`Self::rollback`] unwinds them.
    /// Checkpoints nest — roll back in LIFO order.
    pub fn checkpoint(&mut self) -> FabricCheckpoint {
        self.open_checkpoints += 1;
        FabricCheckpoint {
            mark: self.journal.len(),
            reroutes: self.reroutes,
            retired_busy_seconds: self.retired_busy_seconds,
            retired_max_busy_seconds: self.retired_max_busy_seconds,
        }
    }

    /// Unwind the undo journal back to `cp`, restoring every touched
    /// link's `(free, busy)` bit-exactly, and close the checkpoint.
    /// Cost is O(links touched since the checkpoint), not O(edges).
    pub fn rollback(&mut self, cp: FabricCheckpoint) {
        assert!(self.open_checkpoints > 0, "rollback without an open checkpoint");
        while self.journal.len() > cp.mark {
            let (e, d, free, busy) = self.journal.pop().expect("journal shorter than mark");
            self.free[e as usize][d as usize] = free;
            self.busy[e as usize][d as usize] = busy;
        }
        self.reroutes = cp.reroutes;
        self.retired_busy_seconds = cp.retired_busy_seconds;
        self.retired_max_busy_seconds = cp.retired_max_busy_seconds;
        self.open_checkpoints -= 1;
    }

    /// Journal the pre-write state of a send's links while any
    /// checkpoint is open (no-op — one branch — otherwise).
    #[inline]
    fn journal_links(&mut self, links: &[(usize, usize)]) {
        if self.open_checkpoints > 0 {
            for &(e, d) in links {
                self.journal.push((e as u32, d as u8, self.free[e][d], self.busy[e][d]));
            }
        }
    }

    /// Grow the fabric by one card (see [`Topology::attach_card`]).
    /// Splices patch the route table incrementally — the spliced
    /// cable's link state stays with its surviving half and only routes
    /// that crossed it are rebuilt; a structural fat-tree re-trunk
    /// rebuilds routes wholesale and retires the old edges' busy totals
    /// into the aggregate gauges. Slow-link factors apply to cables, so
    /// a re-trunk (which replaces every cable) clears them.
    pub fn attach_card(&mut self) -> AttachReport {
        let _scope = crate::trace::profile::scope("fabric.attach");
        let report = self.topology.attach_card();
        self.dead.push(false);
        let edges = self.topology.edges.len();
        if report.structural {
            self.retired_busy_seconds += self.busy.iter().map(|b| b[0] + b[1]).sum::<f64>();
            self.retired_max_busy_seconds = self.max_busy_seconds();
            self.free = vec![[0.0; 2]; edges];
            self.busy = vec![[0.0; 2]; edges];
            self.slow = vec![1.0; edges];
            self.routes = RouteTable::avoiding(&self.topology, &self.dead);
        } else {
            self.free.resize(edges, [0.0; 2]);
            self.busy.resize(edges, [0.0; 2]);
            self.slow.resize(edges, 1.0);
            self.routes.attach(&self.topology, &self.dead, report.spliced_edge);
        }
        report
    }

    /// Degrade the cable between `a` and `b` by `factor` (≥ 1.0 slows,
    /// exactly like a flapping QSFP renegotiating a lower rate). Both
    /// directions slow; factors compound multiplicatively. Returns
    /// false when no such cable exists.
    pub fn slow_link(&mut self, a: usize, b: usize, factor: f64) -> bool {
        assert!(factor >= 1.0, "slow factor must be >= 1.0");
        let found = self
            .topology
            .edges
            .iter()
            .position(|e| (e.a, e.b) == (a, b) || (e.a, e.b) == (b, a));
        match found {
            Some(e) => {
                self.slow[e] *= factor;
                true
            }
            None => false,
        }
    }

    /// Accumulated slowdown factor of the cable between `a` and `b`
    /// (1.0 for a healthy cable, None when no such cable exists). The
    /// observatory's link telemetry reports `1 / cable_slow` as the
    /// cable's negotiated line-rate fraction.
    pub fn cable_slow(&self, a: usize, b: usize) -> Option<f64> {
        self.topology
            .edges
            .iter()
            .position(|e| (e.a, e.b) == (a, b) || (e.a, e.b) == (b, a))
            .map(|e| self.slow[e])
    }

    /// One QSFP28 lane (the unit every edge width multiplies).
    pub fn lane(&self) -> Link {
        self.lane
    }

    pub fn is_dead(&self, card: usize) -> bool {
        self.dead.get(card).copied().unwrap_or(false)
    }

    /// Kill a card: its links go down and every route table entry that
    /// crossed it is rebuilt over the survivors.
    pub fn kill(&mut self, card: usize) {
        if card < self.dead.len() && !self.dead[card] {
            // The n² route rebuild is the fleet-scale healing hot spot
            // the host profiler watches.
            let _scope = crate::trace::profile::scope("fabric.heal");
            self.dead[card] = true;
            self.routes = RouteTable::avoiding(&self.topology, &self.dead);
        }
    }

    /// Current live hop count between two cards.
    pub fn hops(&self, src: usize, dst: usize) -> Option<u32> {
        self.routes.hops(src, dst)
    }

    /// Node path (cards and switches) a send between two cards takes
    /// over the current route tables — what the flight recorder turns
    /// into per-directed-link circuit spans.
    pub fn route_nodes(&self, src: usize, dst: usize) -> Option<Vec<usize>> {
        self.routes.node_path(src, dst)
    }

    /// Forget all link occupancy (free times, busy accounting, reroute
    /// count) while keeping the topology, route tables, and dead-card
    /// state. Lets a caller replay many what-if schedules — the
    /// placement search prices thousands of candidate maps — on one
    /// instance instead of cloning the n² route table per replay.
    /// Fault state — dead cards and slow-link factors — survives the
    /// reset, exactly like the route tables.
    pub fn reset_occupancy(&mut self) {
        debug_assert_eq!(self.open_checkpoints, 0, "reset_occupancy under an open checkpoint");
        for f in &mut self.free {
            *f = [0.0; 2];
        }
        for b in &mut self.busy {
            *b = [0.0; 2];
        }
        self.retired_busy_seconds = 0.0;
        self.retired_max_busy_seconds = 0.0;
        self.reroutes = 0;
        self.journal.clear();
        self.open_checkpoints = 0;
    }

    /// Price of an uncontended h-hop transfer at trunk width `w_min`.
    pub fn transfer_seconds(&self, bytes: u64, hops: u32, w_min: u32) -> f64 {
        self.lane.seconds_for_bytes(bytes) / w_min.max(1) as f64
            + hops as f64 * HOP_LATENCY_S
    }

    fn sweep_deaths(&mut self, now: f64, deaths: &[Option<f64>]) {
        for (card, d) in deaths.iter().enumerate() {
            if let Some(td) = d {
                if *td <= now && !self.is_dead(card) {
                    self.kill(card);
                }
            }
        }
    }

    /// Route `bytes` from card `src` to card `dst`, starting no earlier
    /// than `ready`. Returns the (start, finish) the contention model
    /// assigns, or None when no live path exists (fabric partitioned —
    /// the caller decides whether to bounce via the host).
    pub fn send(&mut self, src: usize, dst: usize, bytes: u64, ready: f64) -> Option<(f64, f64)> {
        self.send_with_deaths(src, dst, bytes, ready, &[])
    }

    /// As [`Self::send`], re-routing around scheduled card deaths: a
    /// transit card dying mid-flight aborts the step at its death
    /// instant (the occupied links are released then) and the step
    /// retries over the healed route table.
    pub fn send_with_deaths(
        &mut self,
        src: usize,
        dst: usize,
        bytes: u64,
        ready: f64,
        deaths: &[Option<f64>],
    ) -> Option<(f64, f64)> {
        if src == dst {
            return Some((ready, ready));
        }
        let mut ready = ready;
        loop {
            self.sweep_deaths(ready, deaths);
            let nodes = self.routes.node_path(src, dst)?;
            // Directed links along the path, the narrowest trunk, and
            // the slowest (degraded) cable.
            let mut links: Vec<(usize, usize)> = Vec::with_capacity(nodes.len() - 1);
            let mut w_min = u32::MAX;
            let mut slow_max = 1.0f64;
            for pair in nodes.windows(2) {
                let e = self
                    .topology
                    .neighbors(pair[0])
                    .iter()
                    .find(|&&(w, _)| w == pair[1])
                    .map(|&(_, e)| e)
                    .expect("route table path follows edges");
                let dir = usize::from(self.topology.edges[e].a != pair[0]);
                w_min = w_min.min(self.topology.edges[e].width);
                slow_max = slow_max.max(self.slow[e]);
                links.push((e, dir));
            }
            let start = links.iter().fold(ready, |t, &(e, d)| t.max(self.free[e][d]));
            let dur = slow_max * self.transfer_seconds(bytes, (nodes.len() - 1) as u32, w_min);
            let end = start + dur;
            // A transit card dying inside [ready, end) aborts the step.
            let transit_death = nodes[1..nodes.len() - 1]
                .iter()
                .filter(|&&v| v < self.topology.cards)
                .filter_map(|&v| deaths.get(v).copied().flatten())
                .filter(|&td| td < end)
                .fold(f64::INFINITY, f64::min);
            if transit_death.is_finite() {
                if transit_death > start {
                    // Charge the progress lost with the dying card.
                    self.journal_links(&links);
                    for &(e, d) in &links {
                        self.free[e][d] = self.free[e][d].max(transit_death);
                        self.busy[e][d] += transit_death - start;
                    }
                }
                self.reroutes += 1;
                ready = ready.max(transit_death);
                continue;
            }
            self.journal_links(&links);
            for &(e, d) in &links {
                self.free[e][d] = end;
                self.busy[e][d] += dur;
            }
            return Some((start, end));
        }
    }

    /// Compile the current src→dst shortest path into a [`CachedPath`]
    /// (the same link walk [`Self::send`] performs, done once).
    fn compile_path(&self, src: usize, dst: usize) -> Option<CachedPath> {
        if src == dst {
            return None;
        }
        let nodes = self.routes.node_path(src, dst)?;
        let mut links = Vec::with_capacity(nodes.len() - 1);
        let mut w_min = u32::MAX;
        let mut slow_max = 1.0f64;
        for pair in nodes.windows(2) {
            let e = self
                .topology
                .neighbors(pair[0])
                .iter()
                .find(|&&(w, _)| w == pair[1])
                .map(|&(_, e)| e)
                .expect("route table path follows edges");
            let dir = u8::from(self.topology.edges[e].a != pair[0]);
            w_min = w_min.min(self.topology.edges[e].width);
            slow_max = slow_max.max(self.slow[e]);
            links.push((e as u32, dir));
        }
        Some(CachedPath { links, w_min, slow_max, hops: (nodes.len() - 1) as u32 })
    }

    /// Route `bytes` over a precompiled path — bit-identical contention
    /// arithmetic to [`Self::send`] (same float operations in the same
    /// order) without the per-send route-table backtrack. The caller
    /// guarantees the [`PathCache`] was built against this fabric's
    /// current structural state.
    pub fn send_cached(&mut self, path: &CachedPath, bytes: u64, ready: f64) -> (f64, f64) {
        let start = path
            .links
            .iter()
            .fold(ready, |t, &(e, d)| t.max(self.free[e as usize][d as usize]));
        let dur = path.slow_max * self.transfer_seconds(bytes, path.hops, path.w_min);
        let end = start + dur;
        if self.open_checkpoints > 0 {
            for &(e, d) in &path.links {
                let (e, d) = (e as usize, d as usize);
                self.journal.push((e as u32, d as u8, self.free[e][d], self.busy[e][d]));
            }
        }
        for &(e, d) in &path.links {
            let (e, d) = (e as usize, d as usize);
            self.free[e][d] = end;
            self.busy[e][d] += dur;
        }
        (start, end)
    }

    /// Directed links in the fabric (two per undirected edge).
    pub fn directed_links(&self) -> usize {
        2 * self.topology.edges.len()
    }

    /// Total busy seconds over all directed links (including links
    /// retired by structural fabric growth).
    pub fn busy_seconds_total(&self) -> f64 {
        self.retired_busy_seconds + self.busy.iter().map(|b| b[0] + b[1]).sum::<f64>()
    }

    /// Busy seconds of the hottest directed link (including links
    /// retired by structural fabric growth).
    pub fn max_busy_seconds(&self) -> f64 {
        self.busy
            .iter()
            .flatten()
            .fold(self.retired_max_busy_seconds, |m, &b| m.max(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shortest_paths_deterministic() {
        let t = Topology::ring(8);
        let r = RouteTable::new(&t);
        assert_eq!(r.node_path(0, 0), Some(vec![0]));
        assert_eq!(r.hops(0, 3), Some(3));
        assert_eq!(r.hops(0, 5), Some(3));
        // 8 nodes, distance 4 both ways: the tie-break is stable.
        let p = r.node_path(0, 4).unwrap();
        assert_eq!(p.len(), 5);
        assert_eq!(r.node_path(0, 4).unwrap(), p);
    }

    #[test]
    fn disjoint_flows_parallel_shared_flows_serialize() {
        let mut f = FabricState::new(Topology::ring(4));
        let bytes = 100_000_000;
        let lone = f.transfer_seconds(bytes, 1, 1);
        // 0→1 and 2→3 touch disjoint links: both finish in one step.
        let (_, e1) = f.send(0, 1, bytes, 0.0).unwrap();
        let (_, e2) = f.send(2, 3, bytes, 0.0).unwrap();
        assert!((e1 - lone).abs() < 1e-12, "{e1} vs {lone}");
        assert!((e2 - lone).abs() < 1e-12);
        // A second 0→1 flow shares the directed link: it queues.
        let (s3, e3) = f.send(0, 1, bytes, 0.0).unwrap();
        assert!((s3 - e1).abs() < 1e-12);
        assert!((e3 - 2.0 * lone).abs() < 1e-11);
        // The reverse direction is an independent resource.
        let (s4, _) = f.send(1, 0, bytes, 0.0).unwrap();
        assert_eq!(s4, 0.0);
    }

    #[test]
    fn multi_hop_reserves_every_link() {
        let mut f = FabricState::new(Topology::ring(8));
        let bytes = 50_000_000;
        // 0→2 crosses 0→1→2; a later 1→2 flow waits for it.
        let (_, e1) = f.send(0, 2, bytes, 0.0).unwrap();
        let (s2, _) = f.send(1, 2, bytes, 0.0).unwrap();
        assert!((s2 - e1).abs() < 1e-12, "{s2} vs {e1}");
        // Hop latency is visible on top of the serialization time.
        assert!(e1 > f.transfer_seconds(bytes, 1, 1));
    }

    #[test]
    fn reset_occupancy_forgets_traffic_not_topology() {
        let mut f = FabricState::new(Topology::ring(4));
        let bytes = 100_000_000;
        let (_, first) = f.send(0, 1, bytes, 0.0).unwrap();
        let (queued, _) = f.send(0, 1, bytes, 0.0).unwrap();
        assert!(queued >= first, "second flow queued behind the first");
        assert!(f.busy_seconds_total() > 0.0);
        f.reset_occupancy();
        assert_eq!(f.busy_seconds_total(), 0.0);
        // A fresh replay starts at t=0 again, on the same routes.
        let (start, end) = f.send(0, 1, bytes, 0.0).unwrap();
        assert_eq!(start, 0.0);
        assert!((end - first).abs() < 1e-12);
        // Dead-card state survives the reset.
        f.kill(1);
        f.reset_occupancy();
        assert!(f.is_dead(1));
        assert_eq!(f.hops(0, 1), None);
    }

    #[test]
    fn checkpoint_rollback_restores_occupancy_bit_exact() {
        let mut f = FabricState::new(Topology::ring(8));
        let bytes = 50_000_000;
        f.send(0, 2, bytes, 0.0).unwrap();
        f.send(1, 2, bytes, 0.0).unwrap();
        let busy = f.busy_seconds_total();
        let peak = f.max_busy_seconds();
        // What a 3→5 send would report from exactly this state.
        let probe = {
            let mut clone = f.clone();
            clone.send(3, 5, bytes, 0.25).unwrap()
        };
        let cp = f.checkpoint();
        f.send(3, 5, bytes, 0.25).unwrap();
        f.send(0, 2, bytes, 0.0).unwrap();
        f.send(7, 1, bytes, 1.0).unwrap();
        assert!(f.busy_seconds_total() > busy);
        f.rollback(cp);
        assert_eq!(f.busy_seconds_total(), busy, "busy totals round-trip exactly");
        assert_eq!(f.max_busy_seconds(), peak);
        // A replay after rollback sees exactly the pre-checkpoint state.
        assert_eq!(f.send(3, 5, bytes, 0.25).unwrap(), probe);
    }

    #[test]
    fn nested_checkpoints_unwind_in_lifo_order() {
        let mut f = FabricState::new(Topology::ring(4));
        let bytes = 100_000_000;
        f.send(0, 1, bytes, 0.0).unwrap();
        let after_one = f.busy_seconds_total();
        let outer = f.checkpoint();
        f.send(1, 2, bytes, 0.0).unwrap();
        let after_two = f.busy_seconds_total();
        let inner = f.checkpoint();
        f.send(2, 3, bytes, 0.0).unwrap();
        f.send(1, 2, bytes, 0.5).unwrap();
        f.rollback(inner);
        assert_eq!(f.busy_seconds_total(), after_two);
        f.rollback(outer);
        assert_eq!(f.busy_seconds_total(), after_one);
    }

    #[test]
    fn cached_sends_match_routed_sends_bit_for_bit() {
        for topology in [Topology::ring(8), Topology::torus2d(4, 2), Topology::fat_tree(8)] {
            let mut routed = FabricState::new(topology);
            let mut cached = routed.clone();
            let cache = PathCache::new(&routed);
            for (s, d, bytes, ready) in [
                (0usize, 5usize, 100_000_000u64, 0.0f64),
                (1, 5, 50_000_000, 0.1),
                (0, 3, 75_000_000, 0.0),
                (5, 0, 100_000_000, 0.05),
                (0, 5, 25_000_000, 0.0),
            ] {
                let want = routed.send(s, d, bytes, ready).unwrap();
                let got = cached.send_cached(cache.get(s, d).unwrap(), bytes, ready);
                assert_eq!(want, got, "{s}->{d}");
            }
            assert_eq!(routed.busy_seconds_total(), cached.busy_seconds_total());
            assert_eq!(routed.max_busy_seconds(), cached.max_busy_seconds());
        }
    }

    #[test]
    fn ring_heals_into_line() {
        let mut f = FabricState::new(Topology::ring(4));
        assert_eq!(f.hops(2, 0), Some(2));
        f.kill(1);
        // 2→0 detours over 3: still 2 hops on the surviving line.
        let p = f.routes.node_path(2, 0).unwrap();
        assert_eq!(p, vec![2, 3, 0]);
        assert!(f.send(2, 0, 1000, 0.0).is_some());
        // Killing 3 as well cuts 2 off from 0.
        f.kill(3);
        assert!(f.send(2, 0, 1000, 0.0).is_none());
        assert_eq!(f.hops(2, 0), None);
    }

    #[test]
    fn midflight_transit_death_reroutes() {
        let mut f = FabricState::new(Topology::ring(4));
        let bytes = 200_000_000u64;
        let dur = f.transfer_seconds(bytes, 2, 1);
        // Card 1 dies halfway through a 2→1→0 transfer.
        let deaths = [None, Some(0.5 * dur), None, None];
        let (start, end) = f.send_with_deaths(2, 0, bytes, 0.0, &deaths).unwrap();
        assert_eq!(f.reroutes, 1);
        assert!(f.is_dead(1));
        // The retry starts at the death instant and pays the full cost
        // again over the detour.
        assert!((start - 0.5 * dur).abs() < 1e-12, "{start}");
        assert!((end - (0.5 * dur + dur)).abs() < 1e-9, "{end}");
    }

    #[test]
    fn attach_rebuilds_only_rows_that_crossed_the_splice() {
        let mut topo = Topology::ring(8);
        let mut routes = RouteTable::new(&topo);
        let rep = topo.attach_card();
        let rebuilt = routes.attach(&topo, &[], rep.spliced_edge);
        // Only some of the 8 old rows routed over the wrap cable.
        assert!(rebuilt > 0 && rebuilt < 8, "rebuilt {rebuilt}");
        // The patched table agrees hop-for-hop with a full rebuild.
        let fresh = RouteTable::new(&topo);
        for a in 0..topo.nodes {
            for b in 0..topo.nodes {
                assert_eq!(routes.hops(a, b), fresh.hops(a, b), "{a}->{b}");
            }
        }
    }

    #[test]
    fn fabric_attach_keeps_occupancy_and_dead_state() {
        let mut f = FabricState::new(Topology::ring(4));
        let bytes = 100_000_000;
        f.send(0, 1, bytes, 0.0).unwrap();
        let busy_before = f.busy_seconds_total();
        f.kill(2);
        let rep = f.attach_card();
        assert_eq!(rep.card, 4);
        assert!(f.busy_seconds_total() >= busy_before);
        assert!(f.is_dead(2));
        // The new card is reachable and routes still avoid the corpse.
        assert!(f.hops(0, 4).is_some());
        let (_, end) = f.send(1, 4, bytes, 0.0).unwrap();
        assert!(end > 0.0);
    }

    #[test]
    fn structural_attach_retires_busy_into_the_gauges() {
        let mut f = FabricState::new(Topology::fat_tree(8));
        let bytes = 100_000_000;
        f.send(0, 5, bytes, 0.0).unwrap();
        let total = f.busy_seconds_total();
        let peak = f.max_busy_seconds();
        assert!(total > 0.0);
        let rep = f.attach_card();
        assert!(rep.structural);
        assert_eq!(f.topology.cards, 9);
        assert_eq!(f.busy_seconds_total(), total, "re-trunk must not drop busy time");
        assert_eq!(f.max_busy_seconds(), peak);
        assert!(f.send(0, 8, bytes, 0.0).is_some());
    }

    #[test]
    fn slow_link_stretches_flows_by_the_worst_cable() {
        let mut f = FabricState::new(Topology::ring(4));
        let bytes = 200_000_000u64;
        let (_, lone) = f.send(0, 2, bytes, 0.0).unwrap();
        assert_eq!(f.cable_slow(1, 2), Some(1.0), "healthy cable reads 1.0");
        assert!(f.slow_link(1, 2, 3.0), "cable exists");
        assert!(!f.slow_link(0, 2, 2.0), "no such cable on a 4-ring");
        assert_eq!(f.cable_slow(1, 2), Some(3.0));
        assert_eq!(f.cable_slow(2, 1), Some(3.0), "order-insensitive lookup");
        assert_eq!(f.cable_slow(0, 2), None);
        f.reset_occupancy();
        // 0->1->2 crosses the degraded cable: the whole circuit holds 3x.
        let (_, slowed) = f.send(0, 2, bytes, 0.0).unwrap();
        assert!((slowed / lone - 3.0).abs() < 1e-6, "{slowed} vs {lone}");
        // A path avoiding the cable is unaffected.
        let (_, clean) = f.send(0, 3, bytes, 0.0).unwrap();
        assert!(clean < slowed);
    }

    #[test]
    fn trunk_width_speeds_fat_tree() {
        let f = FabricState::new(Topology::fat_tree(8));
        let bytes = 100_000_000;
        // Cross-leaf: 4 hops, but the card uplink (width 1) governs.
        let cross = f.transfer_seconds(bytes, 4, 1);
        let lone = f.transfer_seconds(bytes, 1, 1);
        assert!(cross > lone && cross < lone * 1.01);
        // A pure trunk hop at width 4 moves the bytes 4x faster.
        let trunk = f.transfer_seconds(bytes, 1, 4);
        assert!((lone / trunk) > 3.9 && (lone / trunk) < 4.1);
    }
}
