//! Shortest-path route tables and the per-link contention model.
//!
//! Routes are BFS shortest paths with a deterministic tie-break (edge
//! construction order), recomputed whenever a card dies so the
//! surviving fabric heals — a ring with one dead card routes around
//! the gap as a line instead of deadlocking.
//!
//! Transfers are circuit-style: a flow of B bytes over an h-hop path
//! reserves every directed link on the path for
//!
//! ```text
//! t = B / (w_min · bw_qsfp) + h · HOP_LATENCY_S
//! ```
//!
//! where `w_min` is the narrowest trunk width on the path and
//! `bw_qsfp` the derated QSFP28 rate (the [`Link`] peak × efficiency
//! idiom from [`crate::cluster::interconnect`]). Concurrent flows on
//! one directed link therefore serialize, while flows on disjoint
//! links proceed in parallel — exactly the congestion the 2.5D
//! reduction traffic has to negotiate on narrow topologies.

use super::topology::Topology;
use crate::cluster::interconnect::Link;

/// Store-and-forward latency charged per link traversed.
pub const HOP_LATENCY_S: f64 = 1.0e-6;

/// All-pairs shortest-path predecessors over the live fabric.
#[derive(Clone, Debug)]
pub struct RouteTable {
    /// prev[src][v]: predecessor of v on a shortest src→v path.
    prev: Vec<Vec<Option<usize>>>,
}

impl RouteTable {
    pub fn new(topology: &Topology) -> Self {
        Self::avoiding(topology, &[])
    }

    /// Routes that detour around dead cards (switches never die;
    /// `dead` may be shorter than the node count).
    pub fn avoiding(topology: &Topology, dead: &[bool]) -> Self {
        let n = topology.nodes;
        let is_dead = |v: usize| dead.get(v).copied().unwrap_or(false);
        let mut prev = vec![vec![None; n]; n];
        for src in 0..n {
            if is_dead(src) {
                continue;
            }
            let mut seen = vec![false; n];
            seen[src] = true;
            let mut queue = std::collections::VecDeque::from([src]);
            while let Some(v) = queue.pop_front() {
                for &(w, _) in topology.neighbors(v) {
                    if !seen[w] && !is_dead(w) {
                        seen[w] = true;
                        prev[src][w] = Some(v);
                        queue.push_back(w);
                    }
                }
            }
        }
        Self { prev }
    }

    /// Node sequence src..=dst of a shortest live path, None when
    /// unreachable.
    pub fn node_path(&self, src: usize, dst: usize) -> Option<Vec<usize>> {
        if src == dst {
            return Some(vec![src]);
        }
        let mut path = vec![dst];
        let mut v = dst;
        while v != src {
            v = self.prev[src][v]?;
            path.push(v);
        }
        path.reverse();
        Some(path)
    }

    pub fn hops(&self, src: usize, dst: usize) -> Option<u32> {
        self.node_path(src, dst).map(|p| (p.len() - 1) as u32)
    }
}

/// Link occupancy of one fabric during one simulated schedule.
#[derive(Clone, Debug)]
pub struct FabricState {
    pub topology: Topology,
    routes: RouteTable,
    dead: Vec<bool>,
    /// Per undirected edge, free times for the a→b and b→a directions.
    free: Vec<[f64; 2]>,
    busy: Vec<[f64; 2]>,
    lane: Link,
    /// Sends that aborted mid-flight on a dying transit card and took a
    /// detour.
    pub reroutes: usize,
}

impl FabricState {
    pub fn new(topology: Topology) -> Self {
        let routes = RouteTable::new(&topology);
        let edges = topology.edges.len();
        Self {
            dead: vec![false; topology.cards],
            topology,
            routes,
            free: vec![[0.0; 2]; edges],
            busy: vec![[0.0; 2]; edges],
            lane: Link::qsfp28_100g(),
            reroutes: 0,
        }
    }

    /// One QSFP28 lane (the unit every edge width multiplies).
    pub fn lane(&self) -> Link {
        self.lane
    }

    pub fn is_dead(&self, card: usize) -> bool {
        self.dead.get(card).copied().unwrap_or(false)
    }

    /// Kill a card: its links go down and every route table entry that
    /// crossed it is rebuilt over the survivors.
    pub fn kill(&mut self, card: usize) {
        if card < self.dead.len() && !self.dead[card] {
            self.dead[card] = true;
            self.routes = RouteTable::avoiding(&self.topology, &self.dead);
        }
    }

    /// Current live hop count between two cards.
    pub fn hops(&self, src: usize, dst: usize) -> Option<u32> {
        self.routes.hops(src, dst)
    }

    /// Forget all link occupancy (free times, busy accounting, reroute
    /// count) while keeping the topology, route tables, and dead-card
    /// state. Lets a caller replay many what-if schedules — the
    /// placement search prices thousands of candidate maps — on one
    /// instance instead of cloning the n² route table per replay.
    pub fn reset_occupancy(&mut self) {
        for f in &mut self.free {
            *f = [0.0; 2];
        }
        for b in &mut self.busy {
            *b = [0.0; 2];
        }
        self.reroutes = 0;
    }

    /// Price of an uncontended h-hop transfer at trunk width `w_min`.
    pub fn transfer_seconds(&self, bytes: u64, hops: u32, w_min: u32) -> f64 {
        self.lane.seconds_for_bytes(bytes) / w_min.max(1) as f64
            + hops as f64 * HOP_LATENCY_S
    }

    fn sweep_deaths(&mut self, now: f64, deaths: &[Option<f64>]) {
        for (card, d) in deaths.iter().enumerate() {
            if let Some(td) = d {
                if *td <= now && !self.is_dead(card) {
                    self.kill(card);
                }
            }
        }
    }

    /// Route `bytes` from card `src` to card `dst`, starting no earlier
    /// than `ready`. Returns the (start, finish) the contention model
    /// assigns, or None when no live path exists (fabric partitioned —
    /// the caller decides whether to bounce via the host).
    pub fn send(&mut self, src: usize, dst: usize, bytes: u64, ready: f64) -> Option<(f64, f64)> {
        self.send_with_deaths(src, dst, bytes, ready, &[])
    }

    /// As [`Self::send`], re-routing around scheduled card deaths: a
    /// transit card dying mid-flight aborts the step at its death
    /// instant (the occupied links are released then) and the step
    /// retries over the healed route table.
    pub fn send_with_deaths(
        &mut self,
        src: usize,
        dst: usize,
        bytes: u64,
        ready: f64,
        deaths: &[Option<f64>],
    ) -> Option<(f64, f64)> {
        if src == dst {
            return Some((ready, ready));
        }
        let mut ready = ready;
        loop {
            self.sweep_deaths(ready, deaths);
            let nodes = self.routes.node_path(src, dst)?;
            // Directed links along the path, and the narrowest trunk.
            let mut links: Vec<(usize, usize)> = Vec::with_capacity(nodes.len() - 1);
            let mut w_min = u32::MAX;
            for pair in nodes.windows(2) {
                let e = self
                    .topology
                    .neighbors(pair[0])
                    .iter()
                    .find(|&&(w, _)| w == pair[1])
                    .map(|&(_, e)| e)
                    .expect("route table path follows edges");
                let dir = usize::from(self.topology.edges[e].a != pair[0]);
                w_min = w_min.min(self.topology.edges[e].width);
                links.push((e, dir));
            }
            let start = links.iter().fold(ready, |t, &(e, d)| t.max(self.free[e][d]));
            let dur = self.transfer_seconds(bytes, (nodes.len() - 1) as u32, w_min);
            let end = start + dur;
            // A transit card dying inside [ready, end) aborts the step.
            let transit_death = nodes[1..nodes.len() - 1]
                .iter()
                .filter(|&&v| v < self.topology.cards)
                .filter_map(|&v| deaths.get(v).copied().flatten())
                .filter(|&td| td < end)
                .fold(f64::INFINITY, f64::min);
            if transit_death.is_finite() {
                if transit_death > start {
                    // Charge the progress lost with the dying card.
                    for &(e, d) in &links {
                        self.free[e][d] = self.free[e][d].max(transit_death);
                        self.busy[e][d] += transit_death - start;
                    }
                }
                self.reroutes += 1;
                ready = ready.max(transit_death);
                continue;
            }
            for &(e, d) in &links {
                self.free[e][d] = end;
                self.busy[e][d] += dur;
            }
            return Some((start, end));
        }
    }

    /// Directed links in the fabric (two per undirected edge).
    pub fn directed_links(&self) -> usize {
        2 * self.topology.edges.len()
    }

    /// Total busy seconds over all directed links.
    pub fn busy_seconds_total(&self) -> f64 {
        self.busy.iter().map(|b| b[0] + b[1]).sum()
    }

    /// Busy seconds of the hottest directed link.
    pub fn max_busy_seconds(&self) -> f64 {
        self.busy.iter().flatten().fold(0.0f64, |m, &b| m.max(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shortest_paths_deterministic() {
        let t = Topology::ring(8);
        let r = RouteTable::new(&t);
        assert_eq!(r.node_path(0, 0), Some(vec![0]));
        assert_eq!(r.hops(0, 3), Some(3));
        assert_eq!(r.hops(0, 5), Some(3));
        // 8 nodes, distance 4 both ways: the tie-break is stable.
        let p = r.node_path(0, 4).unwrap();
        assert_eq!(p.len(), 5);
        assert_eq!(r.node_path(0, 4).unwrap(), p);
    }

    #[test]
    fn disjoint_flows_parallel_shared_flows_serialize() {
        let mut f = FabricState::new(Topology::ring(4));
        let bytes = 100_000_000;
        let lone = f.transfer_seconds(bytes, 1, 1);
        // 0→1 and 2→3 touch disjoint links: both finish in one step.
        let (_, e1) = f.send(0, 1, bytes, 0.0).unwrap();
        let (_, e2) = f.send(2, 3, bytes, 0.0).unwrap();
        assert!((e1 - lone).abs() < 1e-12, "{e1} vs {lone}");
        assert!((e2 - lone).abs() < 1e-12);
        // A second 0→1 flow shares the directed link: it queues.
        let (s3, e3) = f.send(0, 1, bytes, 0.0).unwrap();
        assert!((s3 - e1).abs() < 1e-12);
        assert!((e3 - 2.0 * lone).abs() < 1e-11);
        // The reverse direction is an independent resource.
        let (s4, _) = f.send(1, 0, bytes, 0.0).unwrap();
        assert_eq!(s4, 0.0);
    }

    #[test]
    fn multi_hop_reserves_every_link() {
        let mut f = FabricState::new(Topology::ring(8));
        let bytes = 50_000_000;
        // 0→2 crosses 0→1→2; a later 1→2 flow waits for it.
        let (_, e1) = f.send(0, 2, bytes, 0.0).unwrap();
        let (s2, _) = f.send(1, 2, bytes, 0.0).unwrap();
        assert!((s2 - e1).abs() < 1e-12, "{s2} vs {e1}");
        // Hop latency is visible on top of the serialization time.
        assert!(e1 > f.transfer_seconds(bytes, 1, 1));
    }

    #[test]
    fn reset_occupancy_forgets_traffic_not_topology() {
        let mut f = FabricState::new(Topology::ring(4));
        let bytes = 100_000_000;
        let (_, first) = f.send(0, 1, bytes, 0.0).unwrap();
        let (queued, _) = f.send(0, 1, bytes, 0.0).unwrap();
        assert!(queued >= first, "second flow queued behind the first");
        assert!(f.busy_seconds_total() > 0.0);
        f.reset_occupancy();
        assert_eq!(f.busy_seconds_total(), 0.0);
        // A fresh replay starts at t=0 again, on the same routes.
        let (start, end) = f.send(0, 1, bytes, 0.0).unwrap();
        assert_eq!(start, 0.0);
        assert!((end - first).abs() < 1e-12);
        // Dead-card state survives the reset.
        f.kill(1);
        f.reset_occupancy();
        assert!(f.is_dead(1));
        assert_eq!(f.hops(0, 1), None);
    }

    #[test]
    fn ring_heals_into_line() {
        let mut f = FabricState::new(Topology::ring(4));
        assert_eq!(f.hops(2, 0), Some(2));
        f.kill(1);
        // 2→0 detours over 3: still 2 hops on the surviving line.
        let p = f.routes.node_path(2, 0).unwrap();
        assert_eq!(p, vec![2, 3, 0]);
        assert!(f.send(2, 0, 1000, 0.0).is_some());
        // Killing 3 as well cuts 2 off from 0.
        f.kill(3);
        assert!(f.send(2, 0, 1000, 0.0).is_none());
        assert_eq!(f.hops(2, 0), None);
    }

    #[test]
    fn midflight_transit_death_reroutes() {
        let mut f = FabricState::new(Topology::ring(4));
        let bytes = 200_000_000u64;
        let dur = f.transfer_seconds(bytes, 2, 1);
        // Card 1 dies halfway through a 2→1→0 transfer.
        let deaths = [None, Some(0.5 * dur), None, None];
        let (start, end) = f.send_with_deaths(2, 0, bytes, 0.0, &deaths).unwrap();
        assert_eq!(f.reroutes, 1);
        assert!(f.is_dead(1));
        // The retry starts at the death instant and pays the full cost
        // again over the detour.
        assert!((start - 0.5 * dur).abs() < 1e-12, "{start}");
        assert!((end - (0.5 * dur + dur)).abs() < 1e-9, "{end}");
    }

    #[test]
    fn trunk_width_speeds_fat_tree() {
        let f = FabricState::new(Topology::fat_tree(8));
        let bytes = 100_000_000;
        // Cross-leaf: 4 hops, but the card uplink (width 1) governs.
        let cross = f.transfer_seconds(bytes, 4, 1);
        let lone = f.transfer_seconds(bytes, 1, 1);
        assert!(cross > lone && cross < lone * 1.01);
        // A pure trunk hop at width 4 moves the bytes 4x faster.
        let trunk = f.transfer_seconds(bytes, 1, 4);
        assert!((lone / trunk) > 3.9 && (lone / trunk) < 4.1);
    }
}
