//! Port-constrained card-fabric topologies.
//!
//! Every 520N carries four QSFP28 ports, so a card can terminate at
//! most [`CARD_PORTS`] point-to-point links — the budget every
//! constructor here respects. Switches (fat-tree only) are modeled as
//! high-radix devices outside the budget; their uplinks trunk several
//! QSFP lanes into one logical edge ([`FabricEdge::width`]).
//!
//! Four families:
//!
//! * [`Topology::ring`] — 2 ports/card, diameter ⌊n/2⌋.
//! * [`Topology::torus2d`] — the full 4-port budget, diameter
//!   ⌊p/2⌋ + ⌊q/2⌋; degenerates to a ring when one extent is 1.
//! * [`Topology::full_mesh`] — complete graph while the port budget
//!   lasts (n ≤ 5); beyond that the densest 4-regular fallback, a
//!   chordal ring with offsets {1, 2}.
//! * [`Topology::fat_tree`] — a 2-level switched tree: each card
//!   spends one port on a leaf-switch uplink, leaves trunk 4 lanes to
//!   a root, so bisection grows with the leaf count instead of being
//!   pinned at the 2-link ring cut.
//!
//! Queries: per-card port usage, hop counts (BFS), diameter, and
//! bisection bandwidth (max-flow between the two index halves of the
//! card set, in QSFP-lane units).
//!
//! Growth: [`Topology::attach_card`] adds one card to a built fabric
//! without exceeding any card's port budget — the elastic-fleet layer
//! uses it to wire hot spares and to grow the fabric when the queue
//! watermark is crossed. Switchless families (ring / torus / mesh)
//! splice the new card into an existing cable, so card ids never move
//! and only routes that crossed the spliced cable are invalidated; the
//! fat tree re-trunks its switch layer instead (a structural rebuild,
//! flagged in the [`AttachReport`]).

use crate::cluster::interconnect::Link;

/// QSFP28 ports on one 520N card.
pub const CARD_PORTS: usize = 4;

/// Which constructor built the graph (and its shape parameters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    Ring,
    Torus2D { p: usize, q: usize },
    FullMesh,
    FatTree { leaves: usize },
}

impl TopologyKind {
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::Torus2D { .. } => "torus",
            TopologyKind::FullMesh => "full-mesh",
            TopologyKind::FatTree { .. } => "fat-tree",
        }
    }
}

/// What [`Topology::attach_card`] did to the graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttachReport {
    /// Id of the new card (always the old `cards` value — card ids
    /// never shift).
    pub card: usize,
    /// The card↔card cable the new card was spliced into (its two
    /// halves now meet at the new card). None for structural attaches.
    pub spliced_edge: Option<(usize, usize)>,
    /// True when the switch layer was rebuilt (fat tree): switch ids
    /// and the edge list changed wholesale, so route tables and link
    /// occupancy must be rebuilt rather than patched.
    pub structural: bool,
}

/// One undirected fabric edge; `width` is the number of QSFP lanes
/// trunked into it (1 for card links, 4 for switch uplinks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FabricEdge {
    pub a: usize,
    pub b: usize,
    pub width: u32,
}

/// The card fabric: cards 0..cards, then switches up to `nodes`.
#[derive(Clone, Debug)]
pub struct Topology {
    pub kind: TopologyKind,
    /// Cards (the devices that compute). Card ids are 0..cards.
    pub cards: usize,
    /// Cards plus switches; switch ids start at `cards`.
    pub nodes: usize,
    pub edges: Vec<FabricEdge>,
    /// Per node: (neighbor, edge index), in edge order (BFS tie-break).
    adj: Vec<Vec<(usize, usize)>>,
}

/// Factor n as p·q with p ≥ q and p − q minimal.
fn near_square(n: usize) -> (usize, usize) {
    let n = n.max(1);
    let root = (n as f64).sqrt().floor() as usize;
    let q = (1..=root.max(1)).rev().find(|d| n % d == 0).unwrap_or(1);
    (n / q, q)
}

impl Topology {
    fn build_adj(nodes: usize, edges: &[FabricEdge]) -> Vec<Vec<(usize, usize)>> {
        let mut adj = vec![Vec::new(); nodes];
        for (i, e) in edges.iter().enumerate() {
            adj[e.a].push((e.b, i));
            adj[e.b].push((e.a, i));
        }
        adj
    }

    fn finish(kind: TopologyKind, cards: usize, nodes: usize, edges: Vec<FabricEdge>) -> Self {
        let adj = Self::build_adj(nodes, &edges);
        Self { kind, cards, nodes, edges, adj }
    }

    /// Attach one more card to a built fabric without exceeding any
    /// card's [`CARD_PORTS`] budget. The new card's id is the old
    /// `cards` value; existing card ids never move.
    ///
    /// * Switchless families (ring / torus / mesh) **splice** the new
    ///   card into the highest-index card↔card cable: that cable's two
    ///   halves now meet at the new card (2 ports), and no existing
    ///   card's port count changes. For a ring the spliced cable is the
    ///   wrap edge, so the grown fabric is again a true ring; a grown
    ///   torus keeps every card within budget but is torus-derived
    ///   rather than a perfect p × q grid. Fabrics of ≤ 2 cards gain
    ///   direct cables to every existing card instead (nothing to
    ///   splice). Because the spliced edge keeps its index and the new
    ///   edge appends, per-edge link state stays aligned and only
    ///   routes that crossed the spliced cable are invalidated.
    /// * The **fat tree** re-trunks: the whole switch layer is rebuilt
    ///   for the grown card count (switch ids shift), reported as
    ///   `structural` so callers rebuild route tables and occupancy.
    pub fn attach_card(&mut self) -> AttachReport {
        let new = self.cards;
        if let TopologyKind::FatTree { .. } = self.kind {
            *self = Topology::fat_tree(new + 1);
            return AttachReport { card: new, spliced_edge: None, structural: true };
        }
        let mut spliced = None;
        if self.cards <= 2 {
            for c in 0..self.cards {
                self.edges.push(FabricEdge { a: c, b: new, width: 1 });
            }
        } else {
            let e = (0..self.edges.len())
                .rev()
                .find(|&i| self.edges[i].a < self.cards && self.edges[i].b < self.cards)
                .expect("a multi-card switchless fabric has a card cable");
            let FabricEdge { a, b, width } = self.edges[e];
            self.edges[e] = FabricEdge { a, b: new, width };
            self.edges.push(FabricEdge { a: new, b, width });
            spliced = Some((a, b));
        }
        self.cards += 1;
        self.nodes += 1;
        self.adj = Self::build_adj(self.nodes, &self.edges);
        AttachReport { card: new, spliced_edge: spliced, structural: false }
    }

    /// Bidirectional ring: card i ↔ card i+1 (mod n), each cable's two
    /// directions independent resources. 2 ports/card.
    pub fn ring(n: usize) -> Self {
        assert!(n >= 1, "empty fabric");
        let edges = match n {
            1 => Vec::new(),
            2 => vec![FabricEdge { a: 0, b: 1, width: 1 }],
            _ => (0..n).map(|i| FabricEdge { a: i, b: (i + 1) % n, width: 1 }).collect(),
        };
        Self::finish(TopologyKind::Ring, n, n, edges)
    }

    /// p × q torus (wraparound grid), row-major card ids. Uses the full
    /// 4-port budget; a 1-wide extent degenerates to a ring.
    pub fn torus2d(p: usize, q: usize) -> Self {
        assert!(p >= 1 && q >= 1, "empty torus");
        let id = |r: usize, c: usize| r * q + c;
        let mut set = std::collections::BTreeSet::new();
        for r in 0..p {
            for c in 0..q {
                if p > 1 {
                    let (x, y) = (id(r, c), id((r + 1) % p, c));
                    set.insert((x.min(y), x.max(y)));
                }
                if q > 1 {
                    let (x, y) = (id(r, c), id(r, (c + 1) % q));
                    set.insert((x.min(y), x.max(y)));
                }
            }
        }
        let edges = set.into_iter().map(|(a, b)| FabricEdge { a, b, width: 1 }).collect();
        Self::finish(TopologyKind::Torus2D { p, q }, p * q, p * q, edges)
    }

    /// Complete graph while the port budget lasts (n ≤ 5 with 4 ports);
    /// past that, the densest 4-regular fallback — a chordal ring with
    /// offsets {1, 2}.
    pub fn full_mesh(n: usize) -> Self {
        assert!(n >= 1, "empty fabric");
        let mut set = std::collections::BTreeSet::new();
        if n <= CARD_PORTS + 1 {
            for a in 0..n {
                for b in (a + 1)..n {
                    set.insert((a, b));
                }
            }
        } else {
            for i in 0..n {
                for off in [1usize, 2] {
                    let j = (i + off) % n;
                    set.insert((i.min(j), i.max(j)));
                }
            }
        }
        let edges = set.into_iter().map(|(a, b)| FabricEdge { a, b, width: 1 }).collect();
        Self::finish(TopologyKind::FullMesh, n, n, edges)
    }

    /// 2-level switched fat tree: each card spends one port on its leaf
    /// switch (4 cards per leaf); leaves trunk 4 QSFP lanes up to one
    /// root switch. Switch radix is outside the card port budget.
    pub fn fat_tree(n: usize) -> Self {
        assert!(n >= 1, "empty fabric");
        let leaves = n.div_ceil(CARD_PORTS);
        let mut nodes = n + leaves;
        let mut edges: Vec<FabricEdge> = (0..n)
            .map(|i| FabricEdge { a: i, b: n + i / CARD_PORTS, width: 1 })
            .collect();
        if leaves > 1 {
            let root = nodes;
            nodes += 1;
            for l in 0..leaves {
                edges.push(FabricEdge { a: n + l, b: root, width: CARD_PORTS as u32 });
            }
        }
        Self::finish(TopologyKind::FatTree { leaves }, n, nodes, edges)
    }

    /// Near-square torus over n cards (degenerates to a ring when n is
    /// prime).
    pub fn torus_near_square(n: usize) -> Self {
        let (p, q) = near_square(n);
        Self::torus2d(p, q)
    }

    /// Default fabric for an n-card fleet: complete while the port
    /// budget lasts, a near-square torus beyond that (a ring when n is
    /// prime).
    pub fn auto(n: usize) -> Self {
        if n <= CARD_PORTS + 1 {
            Self::full_mesh(n)
        } else {
            Self::torus_near_square(n)
        }
    }

    pub fn name(&self) -> &'static str {
        self.kind.name()
    }

    /// (neighbor, edge index) pairs of `node`, in construction order.
    pub fn neighbors(&self, node: usize) -> &[(usize, usize)] {
        &self.adj[node]
    }

    /// QSFP ports `card` terminates (undirected incident edges).
    pub fn card_ports(&self, card: usize) -> usize {
        assert!(card < self.cards, "not a card: {card}");
        self.adj[card].len()
    }

    /// BFS hop count between two nodes (links traversed), None when
    /// disconnected.
    pub fn hops(&self, from: usize, to: usize) -> Option<u32> {
        if from == to {
            return Some(0);
        }
        let mut dist = vec![u32::MAX; self.nodes];
        dist[from] = 0;
        let mut queue = std::collections::VecDeque::from([from]);
        while let Some(v) = queue.pop_front() {
            for &(w, _) in &self.adj[v] {
                if dist[w] == u32::MAX {
                    dist[w] = dist[v] + 1;
                    if w == to {
                        return Some(dist[w]);
                    }
                    queue.push_back(w);
                }
            }
        }
        None
    }

    /// Every node reachable from node 0 (true for a 1-node fabric).
    pub fn is_connected(&self) -> bool {
        if self.nodes <= 1 {
            return true;
        }
        (1..self.nodes).all(|v| self.hops(0, v).is_some())
    }

    /// Largest card↔card hop count.
    pub fn diameter_hops(&self) -> u32 {
        let mut d = 0;
        for a in 0..self.cards {
            for b in (a + 1)..self.cards {
                d = d.max(self.hops(a, b).unwrap_or(u32::MAX));
            }
        }
        d
    }

    /// Bisection capacity in QSFP-lane units: the max-flow (= min cut)
    /// between the index halves {0..⌊n/2⌋} and the rest of the cards,
    /// each undirected edge carrying `width` lanes per direction.
    pub fn bisection_lanes(&self) -> u64 {
        let half = self.cards / 2;
        if half == 0 {
            return 0;
        }
        const INF: u64 = u64::MAX / 4;
        let n = self.nodes + 2;
        let (src, snk) = (self.nodes, self.nodes + 1);
        let mut cap = vec![vec![0u64; n]; n];
        for e in &self.edges {
            cap[e.a][e.b] += e.width as u64;
            cap[e.b][e.a] += e.width as u64;
        }
        for c in cap[src].iter_mut().take(half) {
            *c = INF;
        }
        for row in cap.iter_mut().take(self.cards).skip(half) {
            row[snk] = INF;
        }
        // Edmonds-Karp: BFS augmenting paths until none remain.
        let mut flow = 0u64;
        loop {
            let mut prev = vec![usize::MAX; n];
            prev[src] = src;
            let mut queue = std::collections::VecDeque::from([src]);
            while let Some(v) = queue.pop_front() {
                for w in 0..n {
                    if prev[w] == usize::MAX && cap[v][w] > 0 {
                        prev[w] = v;
                        queue.push_back(w);
                    }
                }
            }
            if prev[snk] == usize::MAX {
                return flow;
            }
            let mut bottleneck = INF;
            let mut v = snk;
            while v != src {
                bottleneck = bottleneck.min(cap[prev[v]][v]);
                v = prev[v];
            }
            let mut v = snk;
            while v != src {
                cap[prev[v]][v] -= bottleneck;
                cap[v][prev[v]] += bottleneck;
                v = prev[v];
            }
            flow += bottleneck;
        }
    }

    /// Bisection bandwidth in bytes/s over `lane` (one QSFP28 link).
    pub fn bisection_bytes_per_s(&self, lane: &Link) -> f64 {
        self.bisection_lanes() as f64 * lane.effective_bytes_per_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_shape() {
        let t = Topology::ring(8);
        assert_eq!(t.edges.len(), 8);
        assert!(t.is_connected());
        assert_eq!(t.hops(0, 4), Some(4));
        assert_eq!(t.hops(0, 7), Some(1));
        assert_eq!(t.diameter_hops(), 4);
        assert_eq!(t.bisection_lanes(), 2);
        for c in 0..8 {
            assert_eq!(t.card_ports(c), 2);
        }
        // Tiny rings do not double their edges.
        assert_eq!(Topology::ring(2).edges.len(), 1);
        assert_eq!(Topology::ring(1).edges.len(), 0);
    }

    #[test]
    fn torus_shape() {
        let t = Topology::torus2d(4, 4);
        assert_eq!(t.cards, 16);
        assert_eq!(t.edges.len(), 32);
        assert!(t.is_connected());
        for c in 0..16 {
            assert_eq!(t.card_ports(c), 4);
        }
        // (0,0) to (2,2): two wrapless hops each way.
        assert_eq!(t.hops(0, 10), Some(4));
        assert_eq!(t.diameter_hops(), 4);
        // Row cut crosses q down-links + q wrap links.
        assert_eq!(t.bisection_lanes(), 8);
        // Degenerate extents collapse to a ring, not self-loops.
        let line = Topology::torus2d(5, 1);
        assert_eq!(line.edges.len(), 5);
        assert!(line.edges.iter().all(|e| e.a != e.b));
        // 2-wide extents do not duplicate wrap edges.
        let t22 = Topology::torus2d(2, 2);
        assert_eq!(t22.edges.len(), 4);
    }

    #[test]
    fn full_mesh_respects_port_budget() {
        let k5 = Topology::full_mesh(5);
        assert_eq!(k5.edges.len(), 10);
        assert_eq!(k5.diameter_hops(), 1);
        let big = Topology::full_mesh(12);
        assert!(big.is_connected());
        for c in 0..12 {
            assert!(big.card_ports(c) <= CARD_PORTS, "card {c}");
        }
        // Chordal ring halves the plain ring's diameter.
        assert!(big.diameter_hops() <= Topology::ring(12).diameter_hops().div_ceil(2));
    }

    #[test]
    fn fat_tree_switched() {
        let t = Topology::fat_tree(8);
        assert_eq!(t.cards, 8);
        assert_eq!(t.nodes, 8 + 2 + 1);
        assert!(t.is_connected());
        for c in 0..8 {
            assert_eq!(t.card_ports(c), 1);
        }
        // Same leaf: 2 hops; across the root: 4.
        assert_eq!(t.hops(0, 3), Some(2));
        assert_eq!(t.hops(0, 4), Some(4));
        // The root trunk carries the bisection: one 4-lane uplink each way.
        assert_eq!(t.bisection_lanes(), 4);
        // Single-leaf tree has no root.
        assert_eq!(Topology::fat_tree(4).nodes, 5);
    }

    #[test]
    fn auto_picks_mesh_then_torus() {
        assert_eq!(Topology::auto(4).kind, TopologyKind::FullMesh);
        assert_eq!(Topology::auto(16).kind, TopologyKind::Torus2D { p: 4, q: 4 });
        assert_eq!(Topology::auto(8).kind, TopologyKind::Torus2D { p: 4, q: 2 });
    }

    #[test]
    fn attach_card_splices_a_ring_into_a_bigger_ring() {
        let mut t = Topology::ring(8);
        let rep = t.attach_card();
        assert_eq!(rep.card, 8);
        assert_eq!(rep.spliced_edge, Some((7, 0)), "the wrap cable splits");
        assert!(!rep.structural);
        assert_eq!(t.cards, 9);
        assert_eq!(t.edges.len(), 9);
        assert!(t.is_connected());
        for c in 0..9 {
            assert_eq!(t.card_ports(c), 2, "still a true ring");
        }
        assert_eq!(t.hops(7, 0), Some(2), "7-8-0 replaces the wrap hop");
        assert_eq!(t.hops(8, 0), Some(1));
    }

    #[test]
    fn attach_card_keeps_torus_and_mesh_in_budget() {
        for mut t in [Topology::torus2d(4, 4), Topology::full_mesh(12)] {
            let before: Vec<usize> = (0..t.cards).map(|c| t.card_ports(c)).collect();
            let rep = t.attach_card();
            assert!(!rep.structural);
            assert!(t.is_connected());
            assert_eq!(t.card_ports(rep.card), 2, "a spliced card spends 2 ports");
            for c in 0..t.cards {
                assert!(t.card_ports(c) <= CARD_PORTS, "card {c}");
            }
            // No existing card's port count changed.
            for (c, &p) in before.iter().enumerate() {
                assert_eq!(t.card_ports(c), p, "card {c}");
            }
        }
    }

    #[test]
    fn attach_card_retrunks_the_fat_tree() {
        // 8 cards fill 2 leaves; the 9th forces a third leaf switch.
        let mut t = Topology::fat_tree(8);
        let rep = t.attach_card();
        assert!(rep.structural);
        assert_eq!(t.cards, 9);
        assert_eq!(t.kind, TopologyKind::FatTree { leaves: 3 });
        assert!(t.is_connected());
        assert_eq!(t.card_ports(8), 1);
    }

    #[test]
    fn attach_card_grows_tiny_fabrics() {
        let mut t = Topology::ring(1);
        t.attach_card();
        assert_eq!((t.cards, t.edges.len()), (2, 1));
        t.attach_card();
        assert_eq!((t.cards, t.edges.len()), (3, 3), "2 -> 3 closes the triangle");
        assert!(t.is_connected());
        assert_eq!(t.diameter_hops(), 1);
    }

    #[test]
    fn bisection_orders_topologies() {
        // At 16 cards the ring's 2-lane cut is the clear loser; the
        // chordal mesh, the fat tree's root trunks, and the torus's
        // 2·q wrap cut all widen it (tree and torus tie at 8 lanes).
        let ring = Topology::ring(16).bisection_lanes();
        let mesh = Topology::full_mesh(16).bisection_lanes();
        let tree = Topology::fat_tree(16).bisection_lanes();
        let torus = Topology::torus2d(4, 4).bisection_lanes();
        assert_eq!(ring, 2);
        assert_eq!(mesh, 6);
        assert_eq!(tree, 8);
        assert_eq!(torus, 8);
        assert!(ring < mesh && mesh < tree && tree <= torus);
    }
}
