//! Stratix 10 GX2800 resource ledger, as exposed by the BittWare 520N BSP.
//!
//! Numbers from the paper (§VI) and Intel's published device tables:
//! the GX2800 has 5760 Variable-Precision DSP blocks and 11721 M20K
//! blocks; the board support package (PCIe, DDR controllers, OpenCL
//! infrastructure) reserves part of them, leaving 4713 DSPs for kernel
//! logic (the paper's figure).

use super::dsp::DotProductUnit;

/// One M20K block stores 20 kbit = 2560 bytes.
pub const M20K_BYTES: u64 = 20 * 1024 / 8;

/// Single-precision float size, the paper's only data type.
pub const F32_BYTES: u64 = 4;

/// A Stratix 10 device with a BSP carve-out.
#[derive(Clone, Debug)]
pub struct Stratix10 {
    /// Total Variable-Precision DSP blocks on the die.
    pub total_dsps: u32,
    /// DSPs available to kernel logic after the BSP reservation.
    pub kernel_dsps: u32,
    /// Total M20K on-chip RAM blocks.
    pub total_m20k: u32,
    /// M20Ks available to kernel logic (estimate; the paper reports only
    /// the DSP figure, we reserve a proportional share for the BSP).
    pub kernel_m20k: u32,
    /// Number of DDR4 channels on the card.
    pub ddr_channels: u32,
    /// DDR4 capacity per channel in bytes (520N: 8 GiB modules).
    pub ddr_bytes_per_channel: u64,
    /// QSFP28 network ports on the card (the 520N exposes four 100 Gb
    /// serial links — the cluster layer's card↔card fabric).
    pub serial_links: u32,
}

impl Stratix10 {
    /// The BittWare 520N configuration used throughout the paper.
    pub fn gx2800_520n() -> Self {
        Self {
            total_dsps: 5760,
            kernel_dsps: 4713, // paper §VI: "4713 of 5760 ... available"
            total_m20k: 11_721,
            // BSP reserves ≈10% of M20Ks (Intel BSP floorplans); estimate.
            kernel_m20k: 10_500,
            ddr_channels: 4,
            ddr_bytes_per_channel: 8 << 30,
            serial_links: 4,
        }
    }

    /// Total card DDR4 capacity in bytes (32 GiB on the 520N) — the
    /// bound the router uses to decide a GEMM no longer fits one card.
    pub fn ddr_capacity_bytes(&self) -> u64 {
        self.ddr_channels as u64 * self.ddr_bytes_per_channel
    }

    /// Fraction of kernel-available DSPs used by `n` DSP blocks.
    pub fn dsp_utilization(&self, n: u32) -> f64 {
        n as f64 / self.kernel_dsps as f64
    }

    /// How many M20K blocks a byte requirement occupies (capacity only;
    /// width-driven replication is the memory module's concern).
    pub fn m20k_blocks_for_bytes(&self, bytes: u64) -> u32 {
        crate::util::div_ceil(bytes, M20K_BYTES) as u32
    }

    /// True if `n` DSPs fit the kernel partition at all (necessary, not
    /// sufficient — see [`super::fitter`]).
    pub fn dsps_available(&self, n: u32) -> bool {
        n <= self.kernel_dsps
    }

    /// Peak floating-point throughput of `n` DSPs in FMA mode at `f_mhz`
    /// (paper eq. 5): `T_peak = 2 · #DSP · f_max` in GFLOPS.
    pub fn peak_gflops(&self, n_dsps: u32, f_mhz: f64) -> f64 {
        2.0 * n_dsps as f64 * f_mhz / 1e3
    }

    /// DSP cost of a grid of dot-product units.
    pub fn dsps_for_units(&self, unit: &DotProductUnit, count: u32) -> u32 {
        unit.dsp_blocks() * count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dsp_budget() {
        let dev = Stratix10::gx2800_520n();
        assert_eq!(dev.total_dsps, 5760);
        assert_eq!(dev.kernel_dsps, 4713);
        // Paper: designs use up to 4704 DSPs = 99.8% of available.
        let u = dev.dsp_utilization(4704);
        assert!((u - 0.998).abs() < 5e-4, "u={u}");
    }

    #[test]
    fn table1_utilization_column() {
        // The "% avail." column of Table I.
        let dev = Stratix10::gx2800_520n();
        for (n, pct) in [(4704u32, 99.8), (4608, 97.7), (4480, 95.0), (4096, 86.9)] {
            let got = dev.dsp_utilization(n) * 100.0;
            assert!((got - pct).abs() < 0.15, "{n}: {got} vs {pct}");
        }
    }

    #[test]
    fn peak_gflops_eq5() {
        let dev = Stratix10::gx2800_520n();
        // Design C: 4704 DSPs at 368 MHz -> 3462 GFLOPS (Table I).
        let t = dev.peak_gflops(4704, 368.0);
        assert!((t - 3462.0).abs() < 1.0, "{t}");
        // Design F: 4480 at 410 -> 3673.
        assert!((dev.peak_gflops(4480, 410.0) - 3673.0).abs() < 1.0);
    }

    #[test]
    fn card_capacity_and_links() {
        let dev = Stratix10::gx2800_520n();
        assert_eq!(dev.ddr_capacity_bytes(), 32 << 30);
        assert_eq!(dev.serial_links, 4);
    }

    #[test]
    fn m20k_capacity() {
        let dev = Stratix10::gx2800_520n();
        assert_eq!(M20K_BYTES, 2560);
        assert_eq!(dev.m20k_blocks_for_bytes(0), 0);
        assert_eq!(dev.m20k_blocks_for_bytes(1), 1);
        assert_eq!(dev.m20k_blocks_for_bytes(2560), 1);
        assert_eq!(dev.m20k_blocks_for_bytes(2561), 2);
        // A 512x512 f32 C block = 1 MiB -> 410 blocks.
        assert_eq!(dev.m20k_blocks_for_bytes(512 * 512 * 4), 410);
    }
}
