//! Variable-Precision DSP blocks (paper §II-B).
//!
//! A Stratix 10 VP DSP block natively executes single-precision
//! floating-point operations; in fused multiply-add mode it performs two
//! FLOP per clock (eq. 5). Blocks can be chained into *dot-product units*
//! computing `r = z + Σ v_i·w_i` (eq. 6) with `d_p` blocks, delivering
//! `2·d_p` FLOP/cycle (eq. 7) and requiring `2·d_p + 1` input floats per
//! cycle (eq. 8).
//!
//! The internal-accumulator capability is modelled too — along with the
//! paper's key restriction that it *cannot* be used in an II=1 pipeline
//! (it forces a loop-carried dependency longer than one cycle), which is
//! why Definition 4 re-orders the blocked algorithm instead.

/// Operating mode of one Variable-Precision DSP block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DspMode {
    /// One fp32 multiply per cycle.
    Multiply,
    /// One fp32 add per cycle.
    Add,
    /// Fused multiply-add: two FLOP per cycle.
    FusedMulAdd,
    /// FMA + internal accumulation register across iterations. Cannot
    /// sustain II=1 (the accumulator read-modify-write is loop-carried).
    Accumulate,
}

impl DspMode {
    /// FLOP started per clock cycle in this mode.
    pub fn flop_per_cycle(self) -> u32 {
        match self {
            DspMode::Multiply | DspMode::Add => 1,
            DspMode::FusedMulAdd | DspMode::Accumulate => 2,
        }
    }

    /// Whether a pipeline built around this mode can reach II = 1
    /// (paper §II-B: the internal accumulator cannot).
    pub fn supports_ii1(self) -> bool {
        !matches!(self, DspMode::Accumulate)
    }
}

/// A chained dot-product unit of `d_p` DSP blocks (paper eq. 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DotProductUnit {
    pub dp: u32,
}

/// Latency in cycles of a DSP FMA stage (used to compose `l_dot`).
/// The Intel fp32 DSP pipeline is ~4–5 stages; we use 4 (only relative
/// latencies matter for the loop-body model, see perfmodel::latency).
pub const DSP_FMA_LATENCY: u32 = 4;

impl DotProductUnit {
    pub fn new(dp: u32) -> Self {
        assert!(dp >= 1, "dot-product size must be >= 1");
        Self { dp }
    }

    /// DSP blocks consumed (one per product term).
    pub fn dsp_blocks(&self) -> u32 {
        self.dp
    }

    /// Peak FLOP/cycle in pipeline (paper eq. 7): `2·d_p`.
    pub fn flop_per_cycle(&self) -> u32 {
        2 * self.dp
    }

    /// Input floats needed per cycle to sustain the pipeline (paper
    /// eq. 8): `2·d_p + 1` (the d_p v's, the d_p w's, and z).
    pub fn input_floats_per_cycle(&self) -> u32 {
        2 * self.dp + 1
    }

    /// Latency of one dot-product evaluation: the chained adds traverse
    /// the `d_p` blocks serially after the FMA stage.
    pub fn latency_cycles(&self) -> u32 {
        DSP_FMA_LATENCY + self.dp.saturating_sub(1)
    }

    /// Functional model: `z + Σ v_i w_i`, accumulated in chain order
    /// (left-to-right), matching the hardware adder chain. This is the
    /// rounding order the cycle-accurate simulator reproduces.
    pub fn evaluate(&self, z: f32, v: &[f32], w: &[f32]) -> f32 {
        assert_eq!(v.len(), self.dp as usize, "v length != d_p");
        assert_eq!(w.len(), self.dp as usize, "w length != d_p");
        let mut acc = z;
        for i in 0..self.dp as usize {
            acc += v[i] * w[i];
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_throughput() {
        assert_eq!(DspMode::Multiply.flop_per_cycle(), 1);
        assert_eq!(DspMode::FusedMulAdd.flop_per_cycle(), 2);
    }

    #[test]
    fn accumulate_mode_blocks_ii1() {
        assert!(DspMode::FusedMulAdd.supports_ii1());
        assert!(!DspMode::Accumulate.supports_ii1());
    }

    #[test]
    fn unit_throughput_eq7_eq8() {
        let u = DotProductUnit::new(8);
        assert_eq!(u.flop_per_cycle(), 16);
        assert_eq!(u.input_floats_per_cycle(), 17);
        assert_eq!(u.dsp_blocks(), 8);
    }

    #[test]
    fn unit_evaluate_matches_manual() {
        let u = DotProductUnit::new(3);
        let r = u.evaluate(2.0, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        assert_eq!(r, 2.0 + 4.0 + 10.0 + 18.0);
    }

    #[test]
    fn unit_evaluate_chain_order() {
        // Chain order matters in floating point: ((z+a)+b)+c, not z+(a+(b+c)).
        let u = DotProductUnit::new(2);
        let big = 1e8f32;
        let r = u.evaluate(-big, &[1.0, big], &[1.0, 1.0]);
        // (-1e8 + 1.0) rounds to -1e8 in f32 (ulp at 1e8 is 8), then + 1e8 = 0.
        assert_eq!(r, 0.0);
    }

    #[test]
    fn latency_grows_with_dp() {
        assert!(DotProductUnit::new(8).latency_cycles()
            > DotProductUnit::new(1).latency_cycles());
    }

    #[test]
    #[should_panic(expected = "must be >= 1")]
    fn zero_dp_rejected() {
        DotProductUnit::new(0);
    }
}
