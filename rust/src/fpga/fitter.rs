//! Placement-feasibility model — the "fitter failed" rows of Tables I & VI.
//!
//! ## Calibration (see DESIGN.md §7)
//!
//! The fitter's observable behaviour in the paper is binary (fit / fail)
//! over 20 synthesis attempts. The failures cluster in a way that admits a
//! simple *placement pressure* model:
//!
//! ```text
//! pressure = #DSP · (1 + chain_penalty(d_p)) + route_penalty · #PE
//! fit      ⇔ pressure ≤ kernel_dsps (4713)
//! ```
//!
//! * `chain_penalty` models the placement constraint that chained DSPs
//!   (dot-product units) must occupy adjacent blocks of one DSP column;
//!   longer chains constrain the placer more.
//! * `route_penalty · #PE` models per-PE interconnect congestion. For the
//!   paper's 3D architecture this term is **zero**: the `__fpga_reg`
//!   register chains decouple neighbouring PEs, so PE count adds no
//!   congestion — that is precisely the paper's thesis. The Intel SDK 2D
//!   baseline has no such chains and pays `route_penalty = 0.3`.
//!
//! With `chain_penalty = 3%` for the register-chained 3D design (any
//! d_p > 1) and `{d_p≤4: 10%, d_p=8: 20%}` for the SDK's monolithic dot
//! units, the model reproduces **all 14 fit/fail outcomes** of Tables I
//! and VI exactly (verified by `table1_fit_fail_exact` and
//! `table6_fit_fail_exact` below).

use super::device::Stratix10;

/// How PEs are interconnected — decides the per-PE routing penalty.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InterconnectStyle {
    /// The paper's 3D design: `__fpga_reg` chains between neighbours.
    RegisterChained,
    /// The Intel SDK example: daisy-chained wide buses without explicit
    /// inter-PE registers at every hop.
    Broadcast,
}

/// A placement request: everything the fitter model looks at.
#[derive(Clone, Copy, Debug)]
pub struct PlacementRequest {
    /// Total DSP blocks of the systolic array (eq. 11).
    pub dsps: u32,
    /// Dot-product unit size d_p.
    pub dp: u32,
    /// Number of processing elements (eq. 12).
    pub pes: u32,
    /// Interconnect style of the architecture.
    pub style: InterconnectStyle,
}

/// Result of a placement attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FitOutcome {
    Fits { pressure: f64 },
    /// The paper's "fitter failed".
    Fails { pressure: f64 },
}

impl FitOutcome {
    pub fn fits(&self) -> bool {
        matches!(self, FitOutcome::Fits { .. })
    }

    pub fn pressure(&self) -> f64 {
        match *self {
            FitOutcome::Fits { pressure } | FitOutcome::Fails { pressure } => pressure,
        }
    }
}

/// The calibrated fitter model.
#[derive(Clone, Debug)]
pub struct Fitter {
    device: Stratix10,
    /// Chain penalty for register-chained designs with d_p > 1.
    pub chained_dp_penalty: f64,
    /// Chain penalty for broadcast designs, d_p ≤ 4.
    pub broadcast_dp4_penalty: f64,
    /// Chain penalty for broadcast designs, d_p ≥ 8.
    pub broadcast_dp8_penalty: f64,
    /// Per-PE routing pressure for broadcast designs.
    pub broadcast_pe_penalty: f64,
}

impl Fitter {
    pub fn new(device: Stratix10) -> Self {
        Self {
            device,
            chained_dp_penalty: 0.03,
            broadcast_dp4_penalty: 0.10,
            broadcast_dp8_penalty: 0.20,
            broadcast_pe_penalty: 0.30,
        }
    }

    /// Effective placement pressure in "DSP-equivalents".
    pub fn pressure(&self, req: &PlacementRequest) -> f64 {
        let chain = match req.style {
            InterconnectStyle::RegisterChained => {
                if req.dp > 1 {
                    self.chained_dp_penalty
                } else {
                    0.0
                }
            }
            InterconnectStyle::Broadcast => {
                if req.dp >= 8 {
                    self.broadcast_dp8_penalty
                } else if req.dp > 1 {
                    self.broadcast_dp4_penalty
                } else {
                    0.0
                }
            }
        };
        let route = match req.style {
            InterconnectStyle::RegisterChained => 0.0,
            InterconnectStyle::Broadcast => self.broadcast_pe_penalty,
        };
        req.dsps as f64 * (1.0 + chain) + route * req.pes as f64
    }

    /// Attempt to place the request.
    pub fn place(&self, req: &PlacementRequest) -> FitOutcome {
        let pressure = self.pressure(req);
        if req.dsps <= self.device.kernel_dsps && pressure <= self.device.kernel_dsps as f64 {
            FitOutcome::Fits { pressure }
        } else {
            FitOutcome::Fails { pressure }
        }
    }
}

impl Default for Fitter {
    fn default() -> Self {
        Self::new(Stratix10::gx2800_520n())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chained(dsps: u32, dp: u32, pes: u32) -> PlacementRequest {
        PlacementRequest { dsps, dp, pes, style: InterconnectStyle::RegisterChained }
    }

    fn broadcast(dsps: u32, dp: u32, pes: u32) -> PlacementRequest {
        PlacementRequest { dsps, dp, pes, style: InterconnectStyle::Broadcast }
    }

    /// Every fit/fail outcome of Table I, exactly.
    #[test]
    fn table1_fit_fail_exact() {
        let f = Fitter::default();
        let rows: &[(&str, PlacementRequest, bool)] = &[
            ("A", chained(4704, 3, 1568), false),
            ("B", chained(4704, 2, 2352), false),
            ("C", chained(4704, 1, 4704), true),
            ("D", chained(4608, 2, 2304), false),
            ("E", chained(4608, 1, 4608), true),
            ("F", chained(4480, 2, 2240), true),
            ("G", chained(4096, 2, 2048), true),
            ("H", chained(4096, 4, 1024), true),
            ("I", chained(4096, 2, 2048), true),
            ("L", chained(4096, 8, 512), true),
            ("M", chained(4096, 4, 1024), true),
            ("N", chained(4096, 2, 2048), true),
        ];
        for (id, req, expect_fit) in rows {
            let out = f.place(req);
            assert_eq!(out.fits(), *expect_fit, "design {id}: {out:?}");
        }
    }

    /// Every fit/fail outcome of Table VI (Intel SDK baseline), exactly.
    #[test]
    fn table6_fit_fail_exact() {
        let f = Fitter::default();
        // (rows, cols, dot sizes per PE) -> PEs, DSPs.
        let rows: &[(&str, PlacementRequest, bool)] = &[
            ("32x18 dot8", broadcast(4608, 8, 576), false),
            ("32x18 2xdot4", broadcast(4608, 4, 576), false),
            ("32x16 dot8", broadcast(4096, 8, 512), false),
            ("32x16 2xdot4", broadcast(4096, 4, 512), true),
            ("32x32 dot4", broadcast(4096, 4, 1024), false),
            ("32x14 dot8", broadcast(3584, 8, 448), true),
        ];
        for (id, req, expect_fit) in rows {
            let out = f.place(req);
            assert_eq!(out.fits(), *expect_fit, "config {id}: {out:?}");
        }
    }

    #[test]
    fn register_chains_remove_pe_pressure() {
        // Same DSP count and dp: the chained design fits where broadcast fails.
        let f = Fitter::default();
        assert!(f.place(&chained(4096, 4, 1024)).fits());
        assert!(!f.place(&broadcast(4096, 4, 1024)).fits());
    }

    #[test]
    fn oversubscription_always_fails() {
        let f = Fitter::default();
        assert!(!f.place(&chained(4714, 1, 4714)).fits());
    }

    #[test]
    fn pressure_monotone_in_dsps() {
        let f = Fitter::default();
        let p1 = f.pressure(&chained(1000, 2, 500));
        let p2 = f.pressure(&chained(2000, 2, 1000));
        assert!(p2 > p1);
    }
}
