//! Maximum-frequency model.
//!
//! Quartus timing closure is famously seed-noisy: Table I itself shows a
//! 28 MHz spread (L=391, M=363, N=381) between designs that differ *only*
//! in d_p at identical DSP count. Any smooth model of f_max therefore has
//! an irreducible ±15–25 MHz residual. We handle this honestly with a
//! two-part model (DESIGN.md §7):
//!
//! 1. a **calibration table** holding the paper's measured f_max for the
//!    known synthesis points — table reproduction uses these, exactly;
//! 2. a **smooth analytical predictor** for design-space exploration on
//!    unseen configurations, hand-calibrated on the measured points
//!    (residuals are reported by `systo3d tables --residuals` and in
//!    EXPERIMENTS.md).
//!
//! Predictor shape:
//!
//! ```text
//! f_pred = f_base                      (420 MHz with Hyperflex)
//!        - 30 · max(0, (u-0.85)/0.15)  (global congestion above 85% DSPs)
//!        - 25 · [d_p = 1 ∧ u > 0.95]   (fine-grain PE forest near full chip:
//!                                       C/E-style designs lose a speed bin)
//!        - 3 · (d_k0 − 2)              (deeper arrays: wider on-chip faces,
//!                                       denser partition wiring)
//! ```
//!
//! Design M (32,16,8,d_p=4; measured 363 MHz) sits ~35 MHz below the
//! predictor while its siblings L (391) and N (381) straddle it — a
//! seed outlier by the paper's own evidence; the predictor keeps the
//! trend and the residual is reported, not hidden.
//!
//! Without Hyperflex (the FBLAS / Cannon baselines in §VI) `f_base` drops
//! to 300 MHz — consistent with their reported 216–294 MHz.

use super::fitter::InterconnectStyle;

/// Outcome of the timing model for one design.
#[derive(Clone, Copy, Debug)]
pub struct FmaxResult {
    /// Frequency in MHz.
    pub mhz: f64,
    /// True if the value came from the calibration table (a measured
    /// point) rather than the analytical predictor.
    pub measured: bool,
}

/// Key identifying a synthesis point: (d_i0, d_j0, d_k0, d_p, style).
pub type SynthKey = (u32, u32, u32, u32, InterconnectStyle);

/// The f_max model.
#[derive(Clone, Debug)]
pub struct FmaxModel {
    /// Base frequency with Hyperflex retiming enabled.
    pub f_base_hyperflex: f64,
    /// Base frequency without Hyperflex (legacy baselines).
    pub f_base_plain: f64,
    /// Congestion slope above the utilization knee.
    pub congestion_slope: f64,
    /// Utilization knee where congestion starts to bite.
    pub congestion_knee: f64,
    /// Penalty for d_p = 1 designs above 95% utilization.
    pub fine_grain_penalty: f64,
    /// Per-unit d_k0 depth penalty (MHz per step beyond d_k0 = 2).
    pub depth_slope: f64,
    calibration: Vec<(SynthKey, f64)>,
}

impl FmaxModel {
    pub fn calibrated() -> Self {
        use InterconnectStyle::*;
        Self {
            f_base_hyperflex: 420.0,
            f_base_plain: 300.0,
            congestion_slope: 30.0,
            congestion_knee: 0.85,
            fine_grain_penalty: 25.0,
            depth_slope: 3.0,
            calibration: vec![
                // Table I (3D systolic, register-chained).
                (((28, 28, 6, 1, RegisterChained)), 368.0), // C
                (((72, 32, 2, 1, RegisterChained)), 368.0), // E
                (((70, 32, 2, 2, RegisterChained)), 410.0), // F
                (((64, 32, 2, 2, RegisterChained)), 398.0), // G
                (((32, 32, 4, 4, RegisterChained)), 408.0), // H
                (((32, 32, 4, 2, RegisterChained)), 396.0), // I
                (((32, 16, 8, 8, RegisterChained)), 391.0), // L
                (((32, 16, 8, 4, RegisterChained)), 363.0), // M
                (((32, 16, 8, 2, RegisterChained)), 381.0), // N
                // Table VI (Intel SDK 2D systolic, broadcast style);
                // d_k0 is the per-PE dot width × units, d_p the unit size.
                (((32, 14, 8, 8, Broadcast)), 412.0),
                (((32, 16, 8, 4, Broadcast)), 407.0),
            ],
        }
    }

    /// Measured f_max if this exact point was synthesized in the paper.
    pub fn measured(&self, key: &SynthKey) -> Option<f64> {
        self.calibration.iter().find(|(k, _)| k == key).map(|&(_, f)| f)
    }

    /// Analytical prediction for an arbitrary point.
    ///
    /// `utilization` is DSPs-used / DSPs-available; `dk0` the array
    /// depth; `dp` the dot-unit size; `hyperflex` whether the retiming
    /// optimization is on.
    pub fn predict(&self, utilization: f64, dk0: u32, dp: u32, hyperflex: bool) -> f64 {
        let base = if hyperflex { self.f_base_hyperflex } else { self.f_base_plain };
        let congestion = (utilization - self.congestion_knee).max(0.0)
            / (1.0 - self.congestion_knee);
        let fine_grain = if dp == 1 && utilization > 0.95 {
            self.fine_grain_penalty
        } else {
            0.0
        };
        let depth = self.depth_slope * (dk0.saturating_sub(2)) as f64;
        (base - self.congestion_slope * congestion - fine_grain - depth).max(150.0)
    }

    /// Full query: measured when known, predicted otherwise.
    pub fn fmax(&self, key: &SynthKey, utilization: f64, hyperflex: bool) -> FmaxResult {
        if let Some(mhz) = self.measured(key) {
            FmaxResult { mhz, measured: true }
        } else {
            FmaxResult {
                mhz: self.predict(utilization, key.2, key.3, hyperflex),
                measured: false,
            }
        }
    }

    /// Residuals (predicted − measured) over the calibration set, for the
    /// honesty report in EXPERIMENTS.md.
    pub fn residuals(&self) -> Vec<(SynthKey, f64, f64, f64)> {
        self.calibration
            .iter()
            .map(|&(key, meas)| {
                let (di, dj, dk, dp, _style) = key;
                let u = (di * dj * dk) as f64 / 4713.0;
                let pred = self.predict(u, dk, dp, true);
                (key, meas, pred, pred - meas)
            })
            .collect()
    }
}

impl Default for FmaxModel {
    fn default() -> Self {
        Self::calibrated()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use InterconnectStyle::*;

    #[test]
    fn measured_points_exact() {
        let m = FmaxModel::calibrated();
        assert_eq!(m.measured(&(28, 28, 6, 1, RegisterChained)), Some(368.0));
        assert_eq!(m.measured(&(70, 32, 2, 2, RegisterChained)), Some(410.0));
        assert_eq!(m.measured(&(32, 14, 8, 8, Broadcast)), Some(412.0));
        assert_eq!(m.measured(&(99, 99, 9, 9, RegisterChained)), None);
    }

    #[test]
    fn predictor_within_noise_band_of_measured() {
        // ±26 MHz: the band spanned by the paper's own seed noise.
        // Exception: design M (32,16,8,4) measured 363 MHz between
        // siblings at 391/381 — a documented seed outlier, allowed ±40.
        let m = FmaxModel::calibrated();
        for &(key, meas) in m.calibration.iter() {
            let (di, dj, dk, dp, _style) = key;
            let u = (di * dj * dk) as f64 / 4713.0;
            let pred = m.predict(u, dk, dp, true);
            let band = if key == (32, 16, 8, 4, RegisterChained) { 40.0 } else { 26.0 };
            assert!(
                (pred - meas).abs() <= band,
                "{key:?}: pred {pred} vs meas {meas}"
            );
        }
    }

    #[test]
    fn hyperflex_gap_matches_legacy_baselines() {
        // FBLAS ran at 216 MHz, Cannon at 294 MHz, both without Hyperflex.
        let m = FmaxModel::calibrated();
        let f = m.predict(0.7, 4, 4, false);
        assert!((200.0..=310.0).contains(&f), "plain-mode prediction {f}");
    }

    #[test]
    fn congestion_monotone() {
        let m = FmaxModel::calibrated();
        assert!(m.predict(0.999, 2, 2, true) < m.predict(0.90, 2, 2, true));
        assert!(m.predict(0.90, 2, 2, true) <= m.predict(0.5, 2, 2, true));
    }

    #[test]
    fn fine_grain_penalty_only_near_full() {
        let m = FmaxModel::calibrated();
        // dp=1 at 99.8% loses the penalty; at 50% it does not.
        assert!(m.predict(0.998, 2, 1, true) < m.predict(0.998, 2, 2, true));
        assert_eq!(m.predict(0.5, 2, 1, true), m.predict(0.5, 2, 2, true));
    }

    #[test]
    fn depth_penalty_monotone() {
        let m = FmaxModel::calibrated();
        assert!(m.predict(0.869, 8, 2, true) < m.predict(0.869, 2, 2, true));
    }

    #[test]
    fn fmax_prefers_measured() {
        let m = FmaxModel::calibrated();
        let r = m.fmax(&(32, 16, 8, 4, RegisterChained), 0.869, true);
        assert!(r.measured);
        assert_eq!(r.mhz, 363.0); // design M, a point the predictor misses
        let r = m.fmax(&(16, 16, 4, 4, RegisterChained), 0.2, true);
        assert!(!r.measured);
    }

    #[test]
    fn floor_at_150mhz() {
        let m = FmaxModel::calibrated();
        assert!(m.predict(5.0, 2, 1, false) >= 150.0);
    }
}
