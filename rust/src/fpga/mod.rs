//! FPGA substrate: a model of the Intel Stratix 10 GX2800 (BittWare 520N)
//! as seen through the Intel FPGA SDK for OpenCL tool flow.
//!
//! The paper's evaluation depends on synthesis outcomes only through three
//! observables — DSP count, fit/fail, and f_max — so this module implements
//! exactly those as calibrated models (DESIGN.md §2, §7):
//!
//! * [`device`] — the resource ledger (DSPs, M20Ks, BSP reservation).
//! * [`dsp`] — Variable-Precision DSP blocks and chained dot-product units
//!   (paper eqs. 5–8).
//! * [`fitter`] — placement feasibility ("fitter failed" rows of Tables
//!   I & VI); exact on all 14 calibration points.
//! * [`fmax`] — maximum-frequency model: measured values for the known
//!   synthesis points, a smooth analytical predictor for DSE beyond them.

pub mod device;
pub mod dsp;
pub mod fitter;
pub mod fmax;

pub use device::{Stratix10, M20K_BYTES};
pub use dsp::{DotProductUnit, DspMode};
pub use fitter::{FitOutcome, Fitter, InterconnectStyle, PlacementRequest};
pub use fmax::{FmaxModel, FmaxResult};
