//! Dense row-major f32 matrices and reference GEMM kernels.

use crate::util::rng::Xoshiro256;

/// A dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Deterministic normal-ish random matrix (test/workload data).
    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut m = Self::zeros(rows, cols);
        rng.fill_normal_f32(&mut m.data);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Copy out the `rows × cols` submatrix anchored at `(r0, c0)`.
    pub fn submatrix(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Matrix {
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols, "submatrix out of range");
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..rows {
            let src = &self.data[(r0 + i) * self.cols + c0..][..cols];
            out.data[i * cols..(i + 1) * cols].copy_from_slice(src);
        }
        out
    }

    /// Paste `block` into this matrix with its top-left at `(r0, c0)`.
    pub fn write_submatrix(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(
            r0 + block.rows <= self.rows && c0 + block.cols <= self.cols,
            "write_submatrix out of range"
        );
        for i in 0..block.rows {
            self.data[(r0 + i) * self.cols + c0..][..block.cols]
                .copy_from_slice(&block.data[i * block.cols..(i + 1) * block.cols]);
        }
    }

    /// Elementwise sum (Strassen S/T operand formation and C-quadrant
    /// combination).
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "add shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "sub shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Zero-pad to `rows × cols` with this matrix in the top-left corner
    /// (Strassen odd-extent padding; the blocked simulators pad the same
    /// way for partial edge blocks).
    pub fn padded(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols, "padded extents must not shrink");
        let mut out = Matrix::zeros(rows, cols);
        out.write_submatrix(0, 0, self);
        out
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative Frobenius error ‖a−b‖/‖b‖ (0 when both are zero).
    pub fn rel_fro_error(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        if den == 0.0 {
            if num == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (num / den).sqrt()
        }
    }
}

/// Naive triple-loop reference (ikj order for locality). The inner k
/// accumulation runs in f32 like the FPGA dot chains.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    let mut c = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            let aik = a.at(i, k);
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
            let brow = &b.data[k * b.cols..(k + 1) * b.cols];
            for j in 0..b.cols {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// Cache-blocked GEMM with a vectorizable micro-kernel — the "optimized
/// CPU code on this testbed" measurement path. Block sizes sized for a
/// ~1 MiB L2.
pub fn matmul_blocked(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_blocked_into(&mut c, a, b);
    c
}

/// Accumulating variant: `c += a·b`, with the per-element accumulation
/// running over k in strictly ascending order (continuing from whatever
/// `c` already holds). This is the primitive the cluster layer uses to
/// reduce k-split partial C tiles *bit-exactly*: folding a k range into
/// an existing partial is the same scalar addition chain the dense call
/// performs over the full k extent.
pub fn matmul_blocked_into(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.cols, b.rows, "contraction mismatch");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "accumulator shape mismatch");
    const MB: usize = 64;
    const KB: usize = 256;
    const NB: usize = 256;
    let (m, k, n) = (a.rows, a.cols, b.cols);
    for k0 in (0..k).step_by(KB) {
        let kmax = (k0 + KB).min(k);
        for i0 in (0..m).step_by(MB) {
            let imax = (i0 + MB).min(m);
            for j0 in (0..n).step_by(NB) {
                let jmax = (j0 + NB).min(n);
                for i in i0..imax {
                    let crow = &mut c.data[i * n + j0..i * n + jmax];
                    // NOTE (EXPERIMENTS.md §Perf L3-3): a 4-way k unroll
                    // was tried here and measured 7% SLOWER (register
                    // pressure beats the saved C-row traffic at these
                    // block sizes); the simple rank-1 loop autovectorizes
                    // best. Kept simple deliberately.
                    for kk in k0..kmax {
                        let aik = a.data[i * k + kk];
                        let brow = &b.data[kk * n + j0..kk * n + jmax];
                        for (cj, bj) in crow.iter_mut().zip(brow) {
                            *cj += aik * bj;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_multiplication() {
        let a = Matrix::random(8, 8, 1);
        let c = matmul(&a, &Matrix::identity(8));
        assert_eq!(c.data, a.data);
    }

    #[test]
    fn small_known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn rectangular_shapes() {
        let a = Matrix::random(5, 7, 2);
        let b = Matrix::random(7, 3, 3);
        let c = matmul(&a, &b);
        assert_eq!((c.rows, c.cols), (5, 3));
        // Spot check one element against a manual dot product.
        let mut want = 0.0f32;
        for k in 0..7 {
            want += a.at(2, k) * b.at(k, 1);
        }
        assert!((c.at(2, 1) - want).abs() < 1e-4);
    }

    #[test]
    fn blocked_matches_naive() {
        for (m, k, n) in [(17, 33, 9), (64, 64, 64), (100, 300, 50)] {
            let a = Matrix::random(m, k, m as u64);
            let b = Matrix::random(k, n, n as u64);
            let naive = matmul(&a, &b);
            let blocked = matmul_blocked(&a, &b);
            let err = blocked.rel_fro_error(&naive);
            assert!(err < 1e-5, "({m},{k},{n}): rel err {err}");
        }
    }

    #[test]
    fn submatrix_roundtrip() {
        let m = Matrix::random(7, 9, 5);
        let s = m.submatrix(2, 3, 4, 5);
        assert_eq!((s.rows, s.cols), (4, 5));
        assert_eq!(s.at(0, 0), m.at(2, 3));
        assert_eq!(s.at(3, 4), m.at(5, 7));
        let mut back = Matrix::zeros(7, 9);
        back.write_submatrix(2, 3, &s);
        assert_eq!(back.at(5, 7), m.at(5, 7));
        assert_eq!(back.at(0, 0), 0.0);
    }

    #[test]
    fn k_split_accumulation_is_bit_exact() {
        // Folding a split k range through matmul_blocked_into reproduces
        // the dense result bitwise — the invariant the cluster reduction
        // relies on.
        let (m, k, n) = (13, 97, 11);
        let a = Matrix::random(m, k, 41);
        let b = Matrix::random(k, n, 42);
        let dense = matmul_blocked(&a, &b);
        for split in [1usize, 31, 64, 96] {
            let mut c = Matrix::zeros(m, n);
            matmul_blocked_into(&mut c, &a.submatrix(0, 0, m, split), &b.submatrix(0, 0, split, n));
            matmul_blocked_into(
                &mut c,
                &a.submatrix(0, split, m, k - split),
                &b.submatrix(split, 0, k - split, n),
            );
            assert_eq!(c.data, dense.data, "split at {split}");
        }
    }

    #[test]
    fn add_sub_padded() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![4.0, 3.0, 2.0, 1.0]);
        assert_eq!(a.add(&b).data, vec![5.0; 4]);
        assert_eq!(a.sub(&b).data, vec![-3.0, -1.0, 1.0, 3.0]);
        let p = a.padded(3, 4);
        assert_eq!((p.rows, p.cols), (3, 4));
        assert_eq!(p.at(1, 1), 4.0);
        assert_eq!(p.at(2, 3), 0.0);
        assert_eq!(p.submatrix(0, 0, 2, 2).data, a.data);
    }

    #[test]
    fn error_metrics() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(1, 2, vec![1.0, 2.5]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert!(a.rel_fro_error(&a) == 0.0);
    }

    #[test]
    #[should_panic(expected = "contraction mismatch")]
    fn mismatched_shapes_panic() {
        matmul(&Matrix::zeros(2, 3), &Matrix::zeros(2, 2));
    }
}
