//! Pure-Rust dense SGEMM oracle.
//!
//! Functional ground truth for (a) the cycle-accurate systolic simulator,
//! (b) the event-level off-chip simulator's functional mode, and (c) the
//! PJRT runtime integration tests. Also doubles as the "CPU baseline
//! (this testbed)" measurement when run through the blocked fast path.

pub mod dense;

pub use dense::{matmul, matmul_blocked, matmul_blocked_into, Matrix};
