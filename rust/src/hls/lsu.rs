//! Load/store-unit synthesis model (paper §II-A).
//!
//! The HLS tool turns each global/local memory pointer access in the
//! kernel into an LSU. Key behaviours modelled:
//!
//! * LSU byte widths are **powers of two**: accessing 3 consecutive
//!   floats (12 B) synthesizes a 16 B unit.
//! * Sequential aligned read-or-write-only accesses become
//!   **burst-coalesced** LSUs with controller efficiency `e ≈ 1`;
//!   strided/unaligned ones pay a lower `e`.
//! * A global LSU can request at most `𝓑_ddr` floats/cycle without
//!   stalling, a *frequency-dependent* ceiling (eq. 4): 16 floats/cycle
//!   up to 300 MHz, 8 floats/cycle from 300–600 MHz (the LSU bus narrows
//!   as the clock outruns the DDR interface).

use crate::util::next_pow2;

/// Memory-access pattern of the pointer expression behind an LSU.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessPattern {
    /// Consecutive, aligned, read-only or write-only → burst-coalesced.
    SequentialAligned,
    /// Consecutive but misaligned start.
    SequentialUnaligned,
    /// Constant stride > 1.
    Strided,
    /// Data-dependent addresses.
    Random,
}

/// The kind of LSU the tool instantiates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LsuKind {
    BurstCoalesced,
    Prefetching,
    Pipelined,
}

/// A synthesized load-or-store unit.
#[derive(Clone, Copy, Debug)]
pub struct Lsu {
    /// Width in bytes (always a power of two).
    pub width_bytes: u64,
    pub kind: LsuKind,
    pub pattern: AccessPattern,
}

impl Lsu {
    /// Synthesize an LSU for an access of `request_bytes` consecutive
    /// bytes per iteration with the given pattern.
    pub fn synthesize(request_bytes: u64, pattern: AccessPattern) -> Self {
        assert!(request_bytes > 0, "LSU must move at least one byte");
        let width_bytes = next_pow2(request_bytes);
        let kind = match pattern {
            AccessPattern::SequentialAligned => LsuKind::BurstCoalesced,
            AccessPattern::SequentialUnaligned => LsuKind::BurstCoalesced,
            AccessPattern::Strided => LsuKind::Prefetching,
            AccessPattern::Random => LsuKind::Pipelined,
        };
        Self { width_bytes, kind, pattern }
    }

    /// Floats moved per cycle at full rate.
    pub fn floats_per_cycle(&self) -> u64 {
        self.width_bytes / 4
    }

    /// Memory-controller efficiency `e` for this access type (§II-A:
    /// close to 1 for aligned burst-coalesced accesses; [12]).
    pub fn controller_efficiency(&self) -> f64 {
        match self.pattern {
            AccessPattern::SequentialAligned => 0.97,
            AccessPattern::SequentialUnaligned => 0.85,
            AccessPattern::Strided => 0.55,
            AccessPattern::Random => 0.25,
        }
    }
}

/// Frequency-dependent per-LSU request ceiling (paper eq. 4), in
/// single-precision floats per cycle.
pub fn max_floats_per_cycle(f_mhz: f64) -> u64 {
    if f_mhz <= 300.0 {
        16 // 64 B/cycle
    } else {
        8 // 32 B/cycle, 300 < f <= 600 MHz
    }
}

/// Same ceiling in bytes/cycle.
pub fn max_bytes_per_cycle(f_mhz: f64) -> u64 {
    max_floats_per_cycle(f_mhz) * 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_rounds_to_pow2() {
        // The paper's example: 3 floats = 12 B -> a 16 B LSU.
        let l = Lsu::synthesize(12, AccessPattern::SequentialAligned);
        assert_eq!(l.width_bytes, 16);
        assert_eq!(l.floats_per_cycle(), 4);
        // A single float -> 4 B unit.
        assert_eq!(Lsu::synthesize(4, AccessPattern::SequentialAligned).width_bytes, 4);
    }

    #[test]
    fn aligned_sequential_is_burst_coalesced() {
        let l = Lsu::synthesize(64, AccessPattern::SequentialAligned);
        assert_eq!(l.kind, LsuKind::BurstCoalesced);
        assert!(l.controller_efficiency() > 0.95);
    }

    #[test]
    fn random_access_is_slow() {
        let l = Lsu::synthesize(4, AccessPattern::Random);
        assert!(l.controller_efficiency() < 0.5);
    }

    #[test]
    fn eq4_frequency_ceiling() {
        assert_eq!(max_floats_per_cycle(200.0), 16);
        assert_eq!(max_floats_per_cycle(300.0), 16);
        assert_eq!(max_floats_per_cycle(301.0), 8);
        assert_eq!(max_floats_per_cycle(410.0), 8);
        assert_eq!(max_bytes_per_cycle(410.0), 32);
    }

    #[test]
    #[should_panic(expected = "at least one byte")]
    fn zero_byte_lsu_rejected() {
        Lsu::synthesize(0, AccessPattern::Random);
    }
}
