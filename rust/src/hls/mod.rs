//! HLS tool-flow model: the Intel FPGA SDK for OpenCL abstractions the
//! paper's analysis is written in (§II).
//!
//! * [`pipeline`] — loop pipelines: initiation interval, loop-body
//!   latency, total latency `l_tot = l_body + II·#it`, and throughput
//!   under stalls (eqs. 1, 3).
//! * [`lsu`] — load/store-unit synthesis: power-of-two byte widths,
//!   alignment, burst coalescing, and the per-f_max request ceiling of
//!   eq. 4.
//! * [`report`] — human-readable synthesis summaries mimicking the HLS
//!   tool's `report.html` / `acl_quartus_report.txt` fields that the
//!   paper quotes.

pub mod codegen;
pub mod lsu;
pub mod pipeline;
pub mod report;

pub use codegen::{CodegenStats, KernelCodegen};
pub use lsu::{AccessPattern, Lsu, LsuKind};
pub use pipeline::{LoopPipeline, PipelineThroughput};
pub use report::SynthesisReport;
