//! Loop-pipeline model (paper §II, eqs. 1 and 3).
//!
//! The HLS tool turns a loop body into a pipelined circuit characterized
//! by its loop-body latency `l_body` (cycles for one iteration to
//! traverse the circuit) and initiation interval `II` (cycles between
//! iteration starts). The total latency of `#it` iterations is
//!
//! ```text
//! l_tot = l_body + II · #it        [cycles]
//! ```
//!
//! and the op-throughput of an ideal (II=1, #it >> l_body) pipeline is
//! `T_op = 𝒯_op · f_max` (eq. 1), degraded to `(1-stall)·𝒯_op·f_max`
//! when memory stalls are present (eq. 3).

/// A pipelined loop.
#[derive(Clone, Copy, Debug)]
pub struct LoopPipeline {
    /// Loop-body latency in cycles.
    pub l_body: u64,
    /// Initiation interval (1 = ideal).
    pub ii: u64,
    /// Number of iterations.
    pub iterations: u64,
}

impl LoopPipeline {
    pub fn new(l_body: u64, ii: u64, iterations: u64) -> Self {
        assert!(ii >= 1, "II must be >= 1");
        Self { l_body, ii, iterations }
    }

    /// Total latency `l_tot = l_body + II·#it`.
    pub fn total_latency(&self) -> u64 {
        self.l_body + self.ii * self.iterations
    }

    /// Fraction of cycles doing useful iteration starts — the pipeline
    /// efficiency `II·#it / l_tot`; approaches 1 when `#it >> l_body`.
    pub fn efficiency(&self) -> f64 {
        let total = self.total_latency();
        if total == 0 {
            return 0.0;
        }
        (self.ii * self.iterations) as f64 / total as f64
    }

    /// Wall-clock seconds at `f_mhz`.
    pub fn seconds_at(&self, f_mhz: f64) -> f64 {
        self.total_latency() as f64 / (f_mhz * 1e6)
    }
}

/// Throughput of operations inside a pipelined loop body (eqs. 1 & 3).
#[derive(Clone, Copy, Debug)]
pub struct PipelineThroughput {
    /// 𝒯_op: operations started per cycle in the loop body.
    pub ops_per_cycle: f64,
    /// Stall rate ∈ [0, 1): fraction of issue slots lost to memory.
    pub stall: f64,
}

impl PipelineThroughput {
    pub fn ideal(ops_per_cycle: f64) -> Self {
        Self { ops_per_cycle, stall: 0.0 }
    }

    /// `T_op = (1-stall)·𝒯_op·f_max` in ops/s; `f` in MHz (eq. 3).
    pub fn ops_per_second(&self, f_mhz: f64) -> f64 {
        (1.0 - self.stall) * self.ops_per_cycle * f_mhz * 1e6
    }

    /// Convenience: GFLOPS when `ops_per_cycle` counts FLOPs.
    pub fn gflops(&self, f_mhz: f64) -> f64 {
        self.ops_per_second(f_mhz) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_latency_formula() {
        let p = LoopPipeline::new(100, 1, 1000);
        assert_eq!(p.total_latency(), 1100);
        let p = LoopPipeline::new(100, 2, 1000);
        assert_eq!(p.total_latency(), 2100);
    }

    #[test]
    fn efficiency_approaches_one() {
        let short = LoopPipeline::new(100, 1, 100);
        let long = LoopPipeline::new(100, 1, 1_000_000);
        assert!(short.efficiency() < long.efficiency());
        assert!(long.efficiency() > 0.9999);
    }

    #[test]
    fn ideal_throughput_eq1() {
        // A dot-product unit of size 8: 16 FLOP/cycle at 400 MHz = 6.4 GFLOPS.
        let t = PipelineThroughput::ideal(16.0);
        assert!((t.gflops(400.0) - 6.4).abs() < 1e-9);
    }

    #[test]
    fn stalled_throughput_eq3() {
        let t = PipelineThroughput { ops_per_cycle: 16.0, stall: 0.5 };
        assert!((t.gflops(400.0) - 3.2).abs() < 1e-9);
    }

    #[test]
    fn seconds_at_frequency() {
        let p = LoopPipeline::new(0, 1, 400_000_000);
        assert!((p.seconds_at(400.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "II must be")]
    fn rejects_zero_ii() {
        LoopPipeline::new(1, 0, 1);
    }
}
