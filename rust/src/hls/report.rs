//! Synthesis-report rendering, mimicking the fields of the Intel HLS
//! tool's `report.html` and `acl_quartus_report.txt` that the paper
//! quotes (`Kernel fmax`, DSP counts, utilization).

use std::fmt;

/// One design's synthesis summary — the row shape of Table I.
#[derive(Clone, Debug)]
pub struct SynthesisReport {
    pub design_id: String,
    pub pes: u32,
    pub di0: u32,
    pub dj0: u32,
    pub dk0: u32,
    pub dp: u32,
    pub dsps: u32,
    pub dsp_pct_available: f64,
    /// `None` == "fitter failed".
    pub fmax_mhz: Option<f64>,
    /// Peak GFLOPS (eq. 5); `None` when the fitter failed.
    pub tpeak_gflops: Option<f64>,
}

impl SynthesisReport {
    pub fn fitted(&self) -> bool {
        self.fmax_mhz.is_some()
    }

    /// The `Kernel fmax` field of `acl_quartus_report.txt`.
    pub fn kernel_fmax_field(&self) -> String {
        match self.fmax_mhz {
            Some(f) => format!("Kernel fmax: {f:.0} MHz"),
            None => "Kernel fmax: n/a (fitter failed)".to_string(),
        }
    }

    /// Render the Table-I-style row.
    pub fn table_row(&self) -> String {
        let (fmax, tpeak) = match (self.fmax_mhz, self.tpeak_gflops) {
            (Some(f), Some(t)) => (format!("{f:>5.0}"), format!("{t:>6.0}")),
            _ => ("fitter failed".into(), String::new()),
        };
        format!(
            "{:<3} {:>5}  {:>3} {:>3} {:>2} {:>2}  {:>5} {:>6.1}%  {} {}",
            self.design_id,
            self.pes,
            self.di0,
            self.dj0,
            self.dk0,
            self.dp,
            self.dsps,
            self.dsp_pct_available,
            fmax,
            tpeak
        )
    }
}

impl fmt::Display for SynthesisReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.table_row())
    }
}

/// Header matching [`SynthesisReport::table_row`] columns.
pub fn table_header() -> String {
    format!(
        "{:<3} {:>5}  {:>3} {:>3} {:>2} {:>2}  {:>5} {:>7}  {:>5} {:>6}",
        "ID", "#PEs", "di0", "dj0", "dk", "dp", "#DSP", "%avail", "fmax", "Tpeak"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(fmax: Option<f64>) -> SynthesisReport {
        SynthesisReport {
            design_id: "G".into(),
            pes: 2048,
            di0: 64,
            dj0: 32,
            dk0: 2,
            dp: 2,
            dsps: 4096,
            dsp_pct_available: 86.9,
            fmax_mhz: fmax,
            tpeak_gflops: fmax.map(|f| 2.0 * 4096.0 * f / 1e3),
        }
    }

    #[test]
    fn fitted_row_renders_numbers() {
        let r = report(Some(398.0));
        assert!(r.fitted());
        let row = r.table_row();
        assert!(row.contains("398"));
        assert!(row.contains("3260"));
        assert!(r.kernel_fmax_field().contains("398"));
    }

    #[test]
    fn failed_row_renders_marker() {
        let r = report(None);
        assert!(!r.fitted());
        assert!(r.table_row().contains("fitter failed"));
    }

    #[test]
    fn header_alignment_nonempty() {
        assert!(table_header().contains("#DSP"));
    }
}
