//! # systo3d
//!
//! Reproduction of *"High Level Synthesis Implementation of a
//! Three-dimensional Systolic Array Architecture for Matrix
//! Multiplications on Intel Stratix 10 FPGAs"* (Gorlani & Plessl, 2021)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator and every hardware substrate
//!   the paper depends on, rebuilt as calibrated simulators: the Stratix
//!   10 device/fitter/f_max models ([`fpga`]), the Intel-HLS pipeline and
//!   LSU abstractions ([`hls`]), the 520N memory system ([`memory`]), the
//!   cycle-accurate 2D/3D systolic dataflow ([`systolic`]), the two-level
//!   blocked off-chip algorithm and its event-level simulator
//!   ([`blocked`]), the analytical model (eqs. 1–19, [`perfmodel`]),
//!   design-space exploration ([`dse`]), the paper's comparison baselines
//!   ([`baselines`]), a GEMM service ([`coordinator`]) that executes
//!   requests functionally through AOT-compiled artifacts ([`runtime`])
//!   while timing them on the FPGA simulator, and a **multi-FPGA cluster
//!   layer** ([`cluster`]) that shards GEMMs too large for one card over
//!   a fleet of simulated 520Ns — 1D/2D/2.5D partitioners, PCIe/QSFP
//!   interconnect models, and a work-stealing scheduler that overlaps
//!   shard transfer with compute. The fleet's card↔card wiring is an
//!   explicit **fabric** ([`fabric`]): port-constrained ring / torus /
//!   mesh / fat-tree topologies, congestion-aware multi-hop routing,
//!   and collective reduction schedules that overlap the 2.5D
//!   partial-C combine with leaf compute. A **topology-aware placement
//!   optimizer** ([`placement`]) maps plan devices onto physical cards
//!   (greedy plane-packing plus a seeded local search, scored under
//!   the link-contention model) so the planner's reduction traffic
//!   pays as little for the fabric as the wiring allows. The fleet is
//!   **elastic** ([`cluster::elastic`]): hot spares sit wired into the
//!   topology but out of placement, a dying card's queued and
//!   in-flight shards drain onto the contention-cheapest spare, and
//!   the fabric grows — `Topology::attach_card`, port budget intact —
//!   when the queue-depth watermark is crossed, with seedable fault
//!   plans replayed by a deterministic chaos harness. Requests that exceed a single card's
//!   DDR capacity (or fit no Table-I blocking) route to the cluster
//!   (`Route::Sharded`). A **Strassen recursion layer** ([`strassen`])
//!   sits above both: a planner prices 7^d-leaf recursions against the
//!   classical schedule and an error budget, and winning shapes route
//!   to `Route::Strassen`, pushing *effective* throughput past the
//!   DSP-bound eq. 5 peak (the leaves also map onto the cluster's work
//!   queues, so Strassen and sharding compose). A **flight recorder**
//!   ([`trace`]) threads an opt-in span tracer through every one of
//!   those layers — deterministic sim-time spans per card lane and
//!   directed link, Chrome-trace/Perfetto export, and a critical-path
//!   analyzer that attributes the makespan to compute / fabric / host
//!   / drain buckets. **Differential observability** rides on top:
//!   [`trace::diff`] aligns two recorded runs and attributes the
//!   makespan delta to the spans, cards, and cables that moved (the
//!   attribution sums to the delta by construction), and
//!   [`trace::profile`] is a scoped host-side profiler threaded
//!   through the planner's hot loops with self/total time and a
//!   folded-stack export (`systo3d diff` / `systo3d trend` /
//!   `systo3d perfgate --explain` are the CLI faces).
//!
//! The [`runtime`] engine has two builds: the real PJRT/XLA executor
//! behind the `pjrt` feature, and a default interpreter that replays
//! each artifact's recorded tile through the functional off-chip
//! simulator — same accumulation order, no XLA toolchain needed.
//! * **L2** — `python/compile/model.py`: the blocked matmul as a JAX
//!   graph, AOT-lowered to `artifacts/*.hlo.txt` at build time.
//! * **L1** — `python/compile/kernels/systolic_mm.py`: the 3D systolic
//!   matmul as a Pallas kernel (TPU adaptation of the paper's DSP
//!   dot-product planes).
//!
//! Python never runs at request time; the binary is self-contained once
//! `make artifacts` has produced the HLO text files.

pub mod baselines;
pub mod blocked;
pub mod cluster;
pub mod coordinator;
pub mod dse;
pub mod fabric;
pub mod fpga;
pub mod gemm;
pub mod hls;
pub mod memory;
pub mod observe;
pub mod perfmodel;
pub mod placement;
pub mod runtime;
pub mod solver;
pub mod strassen;
pub mod systolic;
pub mod trace;
pub mod util;

pub mod cli;
pub mod reports;
