//! `systo3d` CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `tables [--residuals]` — regenerate every paper table and figure.
//! * `dse [--eval-d2 N]` — run the design-space explorer sweep.
//! * `simulate --design G --d2 4096` — simulate one off-chip multiply.
//! * `verify [--artifacts DIR]` — execute every AOT artifact through the
//!   PJRT runtime and check it against the GEMM oracle.
//! * `serve [--requests N] [--artifacts DIR]` — run the GEMM service on
//!   a synthetic request stream and print throughput/latency metrics.
//! * `trace [--devices 16] [--out trace.json]` — flight-record a seeded
//!   elastic chaos run, write the Chrome trace, print the critical path.
//! * `top [--devices 8] [--seed 0]` — the live fleet observatory:
//!   sliding-window sparklines, SLO burn-rate alerts, and the anomaly
//!   localizer's verdict for one seeded chaos run.
//! * `diff A.json B.json` — align two flight-recorder traces and print
//!   the makespan-delta attribution and ranked blame report.
//! * `trend` — walk the accumulated `BENCH_pr<N>.json` artifacts and
//!   name the PR where each gated metric last moved.

use systo3d::cli::Args;
use systo3d::coordinator::{GemmRequest, GemmService, ServiceConfig};
use systo3d::dse::{paper_catalog, Explorer};
use systo3d::gemm::{matmul_blocked, Matrix};
use systo3d::reports;
use systo3d::runtime::Engine;
use std::path::PathBuf;
use std::time::Duration;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("tables") => cmd_tables(&args),
        Some("dse") => cmd_dse(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("verify") => cmd_verify(&args),
        Some("serve") => cmd_serve(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("codegen") => cmd_codegen(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("fabric") => cmd_fabric(&args),
        Some("strassen") => cmd_strassen(&args),
        Some("trace") => cmd_trace(&args),
        Some("top") => cmd_top(&args),
        Some("perfgate") => cmd_perfgate(&args),
        Some("diff") => cmd_diff(&args),
        Some("trend") => cmd_trend(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "systo3d — 3D systolic array matmul reproduction\n\
         usage: systo3d <tables|dse|simulate|verify|serve> [options]\n\
         \n\
         tables   [--residuals]              regenerate paper tables/figures\n\
         dse      [--eval-d2 N]              design-space exploration sweep\n\
         simulate [--design G] [--d2 4096]   simulate one off-chip multiply\n\
         verify   [--artifacts DIR]          check artifacts vs GEMM oracle\n\
         serve    [--requests N] [--artifacts DIR]  run the GEMM service demo\n\
                  [--overload] [--factor 3.0] [--servers 2] [--spares 1] [--seed 7]\n\
                  [--arrival poisson|bursty|diurnal] [--capacity 65536]\n\
                  [--latency-target 0.05] [--pressure-watermark 0.002]\n\
                  \x20                         --overload runs the open-loop admission\n\
                  \x20                         drill instead of the closed-loop demo\n\
         ablate   [--d2 4096]                ablation studies (§III-C/§V claims)\n\
         codegen  [--design G]               emit the OpenCL HLS kernel source\n\
         cluster  [--devices 4] [--d2 21504] [--design G] [--strategy auto|1d|2d|2.5d|all]\n\
                  [--mix] [--placement identity|plane|search] [--spares K] [--watermark X]\n\
                  \x20                         shard one GEMM over a simulated fleet\n\
         fabric   [--devices 8] [--d2 21504] [--design G] [--topology all|auto|ring|torus|\n\
                  full|fat-tree] [--overlap] [--placement identity|plane|search]\n\
                  [--spares K] [--watermark X]\n\
                  \x20                         compare card fabrics: plan makespans,\n\
                  \x20                         link utilization, reduction overlap\n\
                  \x20 placement maps plan devices onto cards before pricing: identity\n\
                  \x20 keeps the plane-major layout, plane greedily packs each 2.5D\n\
                  \x20 k-slice's grid onto fabric-adjacent cards, search (the default\n\
                  \x20 planner setting) polishes it with seeded swaps scored under the\n\
                  \x20 link-contention model\n\
                  \x20 elastic fleets: --spares K wires K hot-spare cards into the fabric\n\
                  \x20 (attached within the 4-port budget, excluded from placement); a\n\
                  \x20 dying card's queued and in-flight shards drain onto the\n\
                  \x20 contention-cheapest spare instead of requeueing on survivors.\n\
                  \x20 --watermark X grows the fabric when pending shards per live card\n\
                  \x20 exceed X, re-carving queued work over the new card. Example:\n\
                  \x20   systo3d cluster --devices 16 --spares 1 --watermark 2.0\n\
                  \x20 prints the kill-card-0 drain timeline and the makespan vs the\n\
                  \x20 requeue-on-survivors baseline\n\
         strassen [--design G] [--d2 21504] [--depth auto|0..3] [--budget 1e-3]\n\
                  [--devices 1]              plan/price Strassen recursion vs classical\n\
         trace    [--devices 16] [--spares 2] [--d2 8192] [--design G] [--seed 0]\n\
                  [--out trace.json] [--json METRICS.json]\n\
                  \x20                         flight-record a seeded elastic chaos run\n\
                  \x20                         on a torus fleet and analyze the trace\n\
                  \x20 Reading a fleet trace: the run replays twice and the recorder\n\
                  \x20 must serialize byte-identically (sim-time only, no wall clock);\n\
                  \x20 --out gets Chrome trace-event JSON — load it in Perfetto or\n\
                  \x20 chrome://tracing. Process \"fleet\" holds one row per card (dma,\n\
                  \x20 compute, writeback, control events); process \"fabric\" holds one\n\
                  \x20 row per directed link, where a span is a reserved circuit and\n\
                  \x20 the active_circuits counter sums them. The printed critical path\n\
                  \x20 walks latest-bounding spans backward from the makespan and\n\
                  \x20 attributes every second to compute/fabric/host/drain/idle — the\n\
                  \x20 buckets sum to the makespan by construction, so the shares say\n\
                  \x20 where speedups will (and will not) pay off\n\
         top      [--devices 8] [--spares 1] [--d2 8192] [--design G] [--seed 0]\n\
                  [--width 48]               live fleet observatory for one seeded\n\
                  \x20                         elastic chaos run\n\
                  \x20 Watching a live fleet: `systo3d top` derives the whole dashboard\n\
                  \x20 from the flight recorder's trace — one sparkline per gauge, in\n\
                  \x20 simulated time: per-card compute-busy fraction, per-link circuit\n\
                  \x20 utilization, the controller's queue-depth counter, windowed\n\
                  \x20 goodput (shards/s), and the sliding-window p99 shard latency\n\
                  \x20 (trailing 4 windows merged). Below the sparklines the anomaly\n\
                  \x20 localizer names what the chaos plan degraded (slow cable, stalled\n\
                  \x20 card) from the trace alone, and the SLO line reports burn-rate\n\
                  \x20 alerts: the p99 target is pinned at 2x the healthy run's p99, a\n\
                  \x20 window burns when >25% of its shard latencies violate the target,\n\
                  \x20 and a sustained burn (short AND long window hot) grows the fleet\n\
                  \x20 even when raw queue depth never crosses the watermark. The same\n\
                  \x20 gauges are scrapeable in-process: GemmService::prometheus_text()\n\
                  \x20 emits the Prometheus text format, ::json_snapshot() one JSON\n\
                  \x20 object per scrape\n\
         perfgate [--out BENCH.json] [--baseline rust/benches/baseline.json]\n\
                  [--merge a.json,b.json] [--tolerance 0.10] [--d2 8192]\n\
                  [--explain] [--baseline-trace A.json] [--candidate-trace B.json]\n\
                  \x20                         record headline metrics, write the bench\n\
                  \x20                         trajectory, gate vs the checked-in baseline;\n\
                  \x20                         every violation prints its signed % delta and\n\
                  \x20                         --explain diffs the two traces on failure\n\
         diff     A.json B.json [--top 12] [--json METRICS.json] [--expect-empty]\n\
                  \x20                         align two Chrome traces (as written by\n\
                  \x20                         `systo3d trace --out`), attribute the\n\
                  \x20                         makespan delta, print the blame report\n\
         trend    [--dir .] [--threshold 0.05] [--json METRICS.json]\n\
                  \x20                         walk BENCH_pr<N>.json artifacts and name the\n\
                  \x20                         PR where each metric last moved >threshold\n\
         \n\
         Diagnosing a regression (worked example):\n\
         \x20 1. Reproduce both sides deterministically. The same seed must replay\n\
         \x20    byte-identically, so the diff of a clean pair is empty:\n\
         \x20      systo3d trace --seed 0 --out clean.json\n\
         \x20      systo3d trace --seed 0 --out replay.json\n\
         \x20      systo3d diff clean.json replay.json --expect-empty\n\
         \x20 2. Record the suspect run (a seeded chaos replay with a slow cable,\n\
         \x20    a different PR's binary, ...) to slow.json, then:\n\
         \x20      systo3d diff clean.json slow.json\n\
         \x20    The bucket table splits the makespan delta across compute/fabric/\n\
         \x20    host/drain/idle (it sums to the delta by construction); the track\n\
         \x20    rows localize it to a card or cable; the blame lines rank the\n\
         \x20    span-duration changes — a degraded link reads like\n\
         \x20      +0.8000 s grew [fabric] link 2->3 reduce 96x96 (x14)\n\
         \x20 3. If the delta sits in the host bucket, profile the host loops:\n\
         \x20    examples/trace_diff writes a folded-stack profile (one\n\
         \x20    'path;to;scope weight' line per call path — load it in speedscope\n\
         \x20    or inferno) whose top self-time entry names the hottest inner\n\
         \x20    loop, e.g. placement.optimize;placement.candidate.\n\
         \x20 4. To find when it started, point trend at the CI artifacts:\n\
         \x20      systo3d trend --dir bench-history\n\
         \x20    which names the PR where each gated metric last moved >5%.\n\
         \n\
         Serving a million users (worked example):\n\
         \x20 A closed-loop benchmark (submit, wait, repeat) can never overload the\n\
         \x20 service — the client self-throttles. Real front-door traffic is\n\
         \x20 open-loop: requests arrive at their own rate whether or not the fleet\n\
         \x20 keeps up. Drill that regime, deterministically, in simulated time:\n\
         \x20   systo3d serve --overload --factor 3.0 --arrival diurnal --seed 7\n\
         \x20 replays a seeded three-tenant trace (gold w3/High/50ms, silver\n\
         \x20 w2/Normal/100ms, bronze w1/Low/200ms) at 3x fleet capacity. At the\n\
         \x20 door, bounded-ingress admission sheds instead of queueing without\n\
         \x20 limit: queue-full rejections under burst, doomed requests (predicted\n\
         \x20 wait already past the deadline slack) immediately, lowest-priority\n\
         \x20 evictions when a High-lane job meets a full queue. Admitted work\n\
         \x20 drains by deficit round robin weighted by tenant share, and the\n\
         \x20 batcher closes early when the oldest member's slack runs out rather\n\
         \x20 than always waiting the fixed window. The run prints both pipelines\n\
         \x20 on the same trace: deadline-aware admission beats the FIFO baseline\n\
         \x20 on goodput (deadline-met FLOP/s) while holding p99 flat, because a\n\
         \x20 shed answer costs one request and a 2x backlog costs every deadline\n\
         \x20 behind it. Sustained queue pressure above --pressure-watermark\n\
         \x20 burns the SLO monitor and grows the fleet (hot spare first), so\n\
         \x20 overload recovers without a human in the loop. In process, the same\n\
         \x20 pipeline guards GemmService::submit: build requests with\n\
         \x20   GemmRequest::new(a, b).tenant(\"gold\").priority(Priority::High)\n\
         \x20       .deadline(Duration::from_millis(50))\n\
         \x20 and read the verdict from response.admission (lane, shed reason,\n\
         \x20 queue depth, deadline slack); goodput, shed rate, and per-tenant\n\
         \x20 p99 land in the Prometheus/JSON scrape like every other gauge.\n\
         \n\
         Scaling the simulator (worked example):\n\
         \x20 The fleet sim is fast enough that CI property-sweeps a 256-card\n\
         \x20 fabric. Three mechanisms, all bit-identical to the slow paths they\n\
         \x20 replaced (tests/fastsim.rs is the proof):\n\
         \x20 1. Speculative pricing uses occupancy checkpoints instead of full\n\
         \x20    replays. To price a what-if without paying O(edges) resets:\n\
         \x20      let cp = fabric.checkpoint();\n\
         \x20      fabric.send(src, dst, bytes, ready);   // speculate freely\n\
         \x20      fabric.rollback(cp);                   // O(touched links)\n\
         \x20    Collective pricing, elastic drain-target selection, and the\n\
         \x20    placement search all ride this (structural mutations — kill,\n\
         \x20    attach, slow_link — are not journaled; keep them outside).\n\
         \x20 2. The placement local search prices swap candidates incrementally:\n\
         \x20    exact hop-byte deltas and per-link duration lower bounds refute\n\
         \x20    most candidates without touching the fabric, and survivors replay\n\
         \x20    over compiled route caches with an early exit at the incumbent\n\
         \x20    cost. Same maps, same bits, ~10x+ less host time at n=256:\n\
         \x20      systo3d fabric --devices 256 --topology torus --placement search\n\
         \x20 3. Seeded property sweeps fan across threads. SYSTO3D_TEST_THREADS\n\
         \x20    caps the workers (default: all cores); results merge in seed\n\
         \x20    order, so a parallel run is byte-identical to a single-threaded one:\n\
         \x20      SYSTO3D_CHAOS_SEEDS=128 SYSTO3D_TEST_THREADS=8 cargo test\n\
         \x20 The speedups are gated in CI (sim_speedup_placement_n256 >= 10x,\n\
         \x20 chaos_suite_speedup >= 4x; benches/fast_sim.rs)."
    );
}

fn cmd_ablate(args: &Args) -> anyhow::Result<()> {
    use systo3d::dse::{ablate_interconnect, ablate_overlap, ablate_reuse, ablate_third_dimension};
    let d2 = args.get_u64("d2", 4096).map_err(anyhow::Error::msg)?;

    for ablation in [ablate_overlap(d2), ablate_reuse(d2)] {
        println!("--- {} ---", ablation.name);
        for arm in &ablation.arms {
            println!(
                "  {:<28} {:>7.0} GFLOPS  e_D {:.2}   ({})",
                arm.label, arm.gflops, arm.e_d, arm.note
            );
        }
        println!("  advantage: {:.2}x\n", ablation.advantage());
    }

    println!("--- third dimension at constant #DSP (d2={d2}) ---");
    for arm in ablate_third_dimension(d2) {
        println!(
            "  {:<18} {:>7.0} GFLOPS  e_D {:.2}   ({})",
            arm.label, arm.gflops, arm.e_d, arm.note
        );
    }

    println!("\n--- interconnect style vs fit frontier (dp=2) ---");
    println!("  {:>6} {:>16} {:>12}", "#DSP", "register-chained", "broadcast");
    for (dsps, chained, broadcast) in ablate_interconnect() {
        println!(
            "  {:>6} {:>16} {:>12}",
            dsps,
            if chained { "fits" } else { "FAILS" },
            if broadcast { "fits" } else { "FAILS" }
        );
    }
    Ok(())
}

/// Parse the shared elastic CLI knobs: `--spares K --watermark X`.
fn elastic_args(args: &Args) -> anyhow::Result<(usize, Option<f64>)> {
    let spares = args.get_usize("spares", 0).map_err(anyhow::Error::msg)?;
    let watermark = match args.get("watermark") {
        None => None,
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--watermark expects a float, got {v:?}"))?,
        ),
    };
    Ok((spares, watermark))
}

/// Kill active card 0 mid-first-compute and replay the plan through
/// the elastic scheduler — the worked example behind `--spares` /
/// `--watermark` on the `cluster` and `fabric` subcommands.
fn elastic_demo(
    sim: &systo3d::cluster::ClusterSim,
    plan: &systo3d::cluster::PartitionPlan,
) -> anyhow::Result<systo3d::cluster::ElasticOutcome> {
    use systo3d::cluster::FaultPlan;
    let first = plan
        .shards
        .iter()
        .find(|s| s.device % sim.active_devices() == 0)
        .ok_or_else(|| anyhow::anyhow!("plan has no shard on card 0"))?;
    let t_die =
        sim.host.seconds_for_bytes(first.input_bytes()) + 0.5 * sim.shard_seconds(0, first);
    sim.simulate_elastic(plan, &FaultPlan::kill(0, t_die)).map_err(anyhow::Error::msg)
}

fn cmd_cluster(args: &Args) -> anyhow::Result<()> {
    use systo3d::cluster::{ClusterSim, Fleet, PartitionPlan, PartitionStrategy};
    use systo3d::placement::PlacementStrategy;

    let devices = args.get_usize("devices", 4).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(devices >= 1, "--devices must be at least 1");
    let d2 = args.get_u64("d2", 21504).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let strategy = args.get_str("strategy", "auto").to_lowercase();
    let placement = PlacementStrategy::parse(args.get_str("placement", "search"))
        .map_err(anyhow::Error::msg)?;
    let (spares, watermark) = elastic_args(args)?;

    let fleet = if args.flag("mix") {
        Fleet::mixed_table1(devices + spares)
    } else {
        Fleet::homogeneous(devices + spares, &id).map_err(anyhow::Error::msg)?
    };
    let sim = ClusterSim::builder(fleet)
        .spares(spares)
        .placement(placement)
        .watermark(watermark)
        .build();

    let n = devices as u64;
    let runs: Vec<(PartitionPlan, systo3d::cluster::ClusterReport)> = if strategy == "auto" {
        // The planner simulates every candidate; reuse its winning report.
        vec![sim
            .plan_and_report(d2, d2, d2)
            .ok_or_else(|| anyhow::anyhow!("no partition plan for d2={d2}"))?]
    } else {
        let plans = match strategy.as_str() {
            "1d" => vec![PartitionPlan::new(PartitionStrategy::Row1D { devices: n }, d2, d2, d2)
                .map_err(anyhow::Error::msg)?],
            "2d" => vec![PartitionPlan::new(PartitionStrategy::auto_grid2d(n), d2, d2, d2)
                .map_err(anyhow::Error::msg)?],
            "2.5d" => vec![PartitionPlan::new(PartitionStrategy::auto_summa25d(n), d2, d2, d2)
                .map_err(anyhow::Error::msg)?],
            "all" => sim.candidate_plans(d2, d2, d2),
            other => anyhow::bail!("unknown --strategy {other} (auto|1d|2d|2.5d|all)"),
        };
        plans
            .into_iter()
            .map(|p| {
                // Explicit strategies go through the same placement
                // pass the auto planner applies.
                let (placed, rep) = sim.place_plan(&p);
                let r = sim.simulate_placed(&placed, rep.as_ref());
                (placed, r)
            })
            .collect()
    };

    for (plan, report) in &runs {
        println!("{}", report.render());
        println!(
            "  plan moves {:.2} GB total ({:.2} FLOP/byte)\n",
            plan.total_bytes_moved() as f64 / 1e9,
            plan.flops_per_byte()
        );
    }

    if spares > 0 || watermark.is_some() {
        let (plan, _) = &runs[0];
        println!(
            "--- elastic: kill card 0 mid-first-compute ({spares} spare(s), watermark {}) ---",
            watermark.map_or("off".to_string(), |w| format!("{w:.1}")),
        );
        let out = elastic_demo(&sim, plan)?;
        print!("{}", out.render());
        if spares > 0 {
            // Requeue-on-survivors baseline: the same actives with no
            // spare wired, same death instant.
            let base_fleet = if args.flag("mix") {
                Fleet::mixed_table1(devices)
            } else {
                Fleet::homogeneous(devices, &id).map_err(anyhow::Error::msg)?
            };
            let base = ClusterSim::builder(base_fleet)
                .placement(PlacementStrategy::Identity)
                .build();
            let first = plan
                .shards
                .iter()
                .find(|s| s.device % devices == 0)
                .ok_or_else(|| anyhow::anyhow!("plan has no shard on card 0"))?;
            let t_die = base.host.seconds_for_bytes(first.input_bytes())
                + 0.5 * base.shard_seconds(0, first);
            let requeue = base
                .simulate_with_failures(plan, &[Some(t_die)])
                .map_err(anyhow::Error::msg)?;
            println!(
                "drain-to-spare {:.4} s vs requeue-on-survivors {:.4} s ({:.2}x)",
                out.schedule.makespan_seconds,
                requeue.makespan_seconds,
                requeue.makespan_seconds / out.schedule.makespan_seconds,
            );
        }
    }
    Ok(())
}

fn cmd_fabric(args: &Args) -> anyhow::Result<()> {
    use systo3d::cluster::{ClusterSim, Fleet, Link};
    use systo3d::fabric::{ReduceAlgo, Topology};
    use systo3d::placement::PlacementStrategy;

    let devices = args.get_usize("devices", 8).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(devices >= 1, "--devices must be at least 1");
    let d2 = args.get_u64("d2", 21504).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let wanted = args.get_str("topology", "all").to_lowercase();
    let placement = PlacementStrategy::parse(args.get_str("placement", "search"))
        .map_err(anyhow::Error::msg)?;
    let (spares, watermark) = elastic_args(args)?;

    let topologies: Vec<Topology> = match wanted.as_str() {
        "all" => vec![
            Topology::ring(devices),
            Topology::torus_near_square(devices),
            Topology::full_mesh(devices),
            Topology::fat_tree(devices),
        ],
        "auto" => vec![Topology::auto(devices)],
        "ring" => vec![Topology::ring(devices)],
        "torus" => vec![Topology::torus_near_square(devices)],
        "full" => vec![Topology::full_mesh(devices)],
        "fat-tree" | "fat" => vec![Topology::fat_tree(devices)],
        other => anyhow::bail!(
            "unknown --topology {other} (all|auto|ring|torus|full|fat-tree)"
        ),
    };

    let lane = Link::qsfp28_100g();
    for topology in topologies {
        let max_ports = (0..topology.cards).map(|c| topology.card_ports(c)).max().unwrap_or(0);
        println!(
            "--- {}: {} card(s), {} cable(s)/trunk(s), <= {} ports/card, \
             diameter {} hop(s), bisection {:.1} GB/s ---",
            topology.name(),
            topology.cards,
            topology.edges.len(),
            max_ports,
            topology.diameter_hops(),
            topology.bisection_bytes_per_s(&lane) / 1e9,
        );
        let fleet = Fleet::homogeneous(devices + spares, &id).map_err(anyhow::Error::msg)?;
        let sim = ClusterSim::builder(fleet)
            .topology(topology)
            .spares(spares)
            .placement(placement)
            .watermark(watermark)
            .build();
        for plan in sim.candidate_plans(d2, d2, d2) {
            let (placed, rep) = sim.place_plan(&plan);
            let r = sim.simulate_placed(&placed, rep.as_ref());
            println!(
                "  {:>11}: {:.4} s makespan, {:>8.2} TFLOPS, link util {:>5.1}% mean \
                 {:>5.1}% peak, reduction {:.4} s ({:.0}% overlapped)",
                r.strategy,
                r.makespan_seconds,
                r.effective_gflops / 1e3,
                r.link_utilization() * 100.0,
                r.max_link_utilization() * 100.0,
                r.reduction_seconds,
                r.reduction_overlap() * 100.0,
            );
            if r.placement != "identity" {
                println!(
                    "               placement {}: reduction drain {:.4} s -> {:.4} s \
                     ({:.2}x), hop-bytes -{:.0}%",
                    r.placement,
                    r.placement_identity_cost_seconds,
                    r.placement_placed_cost_seconds,
                    r.placement_gain(),
                    r.placement_hop_saving() * 100.0,
                );
            }
        }
        // The overlap story on the 2.5D plan (the one with partials to
        // combine), when the fleet admits one.
        if let Ok(plan) = systo3d::cluster::PartitionPlan::new(
            systo3d::cluster::PartitionStrategy::auto_summa25d(devices as u64),
            d2,
            d2,
            d2,
        ) {
            if plan.device_to_device_bytes > 0 {
                let rep = sim.overlap_report(&plan, Some(ReduceAlgo::Direct));
                println!(
                    "  2.5d reduction overlap: {:.4} s overlapped vs {:.4} s barrier \
                     ({:.1}% saved); cheapest collective saves {:.1}%",
                    rep.overlapped_makespan_seconds,
                    rep.barrier_makespan_seconds,
                    rep.saving_fraction() * 100.0,
                    sim.overlap_report(&plan, None).saving_fraction() * 100.0,
                );
                if args.flag("overlap") {
                    print!("{}", rep.render());
                }
            }
        }
        if spares > 0 || watermark.is_some() {
            if let Some(plan) = sim.candidate_plans(d2, d2, d2).into_iter().next() {
                let out = elastic_demo(&sim, &plan)?;
                println!(
                    "  elastic: kill card 0 -> makespan {:.4} s, {} spare(s) activated, \
                     {} drain(s) in {:.4} s, {} card(s) grown",
                    out.schedule.makespan_seconds,
                    out.spare_activations,
                    out.drains_completed,
                    out.drain_seconds,
                    out.grown_cards,
                );
            }
        }
        println!();
    }
    Ok(())
}

fn cmd_strassen(args: &Args) -> anyhow::Result<()> {
    use systo3d::blocked::OffchipDesign;
    use systo3d::cluster::{ClusterSim, Fleet};
    use systo3d::strassen::{self, StrassenConfig, StrassenMode, TaskDag};

    let id = args.get_str("design", "G").to_uppercase();
    let d2 = args.get_u64("d2", 21504).map_err(anyhow::Error::msg)?;
    let devices = args.get_usize("devices", 1).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(d2 >= 1, "--d2 must be at least 1");
    let budget: f64 = match args.get("budget") {
        None => StrassenConfig::default().error_budget,
        Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--budget expects a float, got {v:?}"))?,
    };
    let mode = match args.get_str("depth", "auto") {
        "auto" => StrassenMode::Auto,
        v => StrassenMode::Force(
            v.parse().map_err(|_| anyhow::anyhow!("--depth expects auto or 0..3, got {v:?}"))?,
        ),
    };
    let spec = paper_catalog()
        .into_iter()
        .find(|d| d.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown design {id}"))?;
    let design = OffchipDesign {
        blocking: spec
            .level1()
            .ok_or_else(|| anyhow::anyhow!("design {id} failed the fitter; nothing to plan"))?,
        fmax_mhz: spec.fmax_mhz.unwrap(),
        controller_efficiency: 0.97,
    };

    let config = StrassenConfig { mode, error_budget: budget, ..Default::default() };
    let plan = strassen::plan(design, d2, d2, d2, &config);
    println!("design {id}, error budget {budget:.1e}");
    println!("{}", plan.render());

    if devices > 1 {
        // Compose with the cluster layer: the chosen depth's leaves on
        // the fleet's work queues.
        let dag = TaskDag::build(d2, d2, d2, plan.depth);
        let sim =
            ClusterSim::builder(Fleet::homogeneous(devices, &id).map_err(anyhow::Error::msg)?)
                .build();
        let (report, total) = dag
            .fleet_seconds(&sim)
            .ok_or_else(|| anyhow::anyhow!("no leaf plan for d2={d2}"))?;
        let flop = systo3d::perfmodel::flop_count(d2, d2, d2) as f64;
        println!(
            "depth-{} leaves over {} card(s): {:.4} s end-to-end \
             ({:.0} effective GFLOPS, {:.2}x one card's eq. 5 peak)",
            plan.depth,
            devices,
            total,
            flop / total / 1e9,
            flop / total / 1e9 / plan.peak_gflops,
        );
        println!("{}", report.render());
    }
    Ok(())
}

fn cmd_codegen(args: &Args) -> anyhow::Result<()> {
    use systo3d::hls::KernelCodegen;
    let id = args.get_str("design", "G").to_uppercase();
    let spec = paper_catalog()
        .into_iter()
        .find(|d| d.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown design {id}"))?;
    let blocking = spec
        .level1()
        .ok_or_else(|| anyhow::anyhow!("design {id} failed the fitter; no code to emit"))?;
    let gen = KernelCodegen::new(blocking);
    println!("{}", gen.source());
    let stats = gen.stats();
    eprintln!(
        "// {} lines, {} unroll pragmas, {} __fpga_reg sites",
        stats.lines, stats.unroll_pragmas, stats.fpga_reg_sites
    );
    Ok(())
}

fn cmd_tables(args: &Args) -> anyhow::Result<()> {
    println!("{}", reports::table1());
    if args.flag("residuals") {
        println!("{}", reports::table1_residuals());
    }
    for id in ["C", "E", "F"] {
        if let Some(t) = reports::table_design_sweep(id) {
            println!("{t}");
        }
    }
    println!("{}", reports::table5());
    println!("{}", reports::table6());
    println!("{}", reports::table7_8());
    println!("{}", reports::figure1());
    println!("{}", reports::figure2());
    println!("{}", reports::figure3(2048));
    println!("{}", reports::eq19_curve());
    Ok(())
}

fn cmd_dse(args: &Args) -> anyhow::Result<()> {
    let eval_d2 = args.get_u64("eval-d2", 8192).map_err(anyhow::Error::msg)?;
    let ex = Explorer { eval_d2, ..Default::default() };
    let points = ex.sweep(
        &[16, 28, 32, 64, 70, 72],
        &[16, 28, 32],
        &[2, 4, 6, 8],
    );
    println!("design-space sweep: {} candidates (eval d2 = {eval_d2})", points.len());
    println!(
        "{:>3}x{:>3}x{:>2} dp={:>2} | {:>5} | {:>8} | {:>6} | {:>9} | {:>9}",
        "di", "dj", "dk", "dp", "#DSP", "fit", "fmax", "Tpeak", "sustained"
    );
    let mut shown = 0;
    for p in &points {
        if !p.outcome.fits() {
            continue;
        }
        shown += 1;
        println!(
            "{:>3}x{:>3}x{:>2} dp={:>2} | {:>5} | {:>8} | {:>6.0} | {:>9.0} | {:>9}",
            p.array.di0,
            p.array.dj0,
            p.array.dk0,
            p.array.dp,
            p.array.dsps(),
            "fits",
            p.fmax_mhz.unwrap_or(0.0),
            p.tpeak_gflops.unwrap_or(0.0),
            p.sustained_gflops.map(|g| format!("{g:.0}")).unwrap_or_else(|| "-".into()),
        );
    }
    println!("({} fitted / {} total)", shown, points.len());
    if let Some(best) = ex.best(&points) {
        println!(
            "best: ({},{},{},dp={}) — sustained {:?} GFLOPS",
            best.array.di0, best.array.dj0, best.array.dk0, best.array.dp,
            best.sustained_gflops.map(|g| g.round())
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let id = args.get_str("design", "G").to_uppercase();
    let d2 = args.get_u64("d2", 4096).map_err(anyhow::Error::msg)?;
    let spec = paper_catalog()
        .into_iter()
        .find(|d| d.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown design {id}"))?;
    let blocking = spec
        .level1()
        .ok_or_else(|| anyhow::anyhow!("design {id} failed the fitter in the paper"))?;
    let sim = systo3d::blocked::OffchipSim::new(systo3d::blocked::OffchipDesign {
        blocking,
        fmax_mhz: spec.fmax_mhz.unwrap(),
        controller_efficiency: 0.97,
    });
    let dj2 = blocking.scale_dj2(d2);
    let r = sim.simulate(d2, dj2, d2);
    println!(
        "design {id}: ({d2} x {d2}) · ({d2} x {dj2})\n\
         cycles:            {}\n\
         kernel time:       {:.4} s @ {} MHz\n\
         throughput:        {:.0} GFLOPS\n\
         DSP efficiency:    {:.3}\n\
         compute fraction:  {:.3} (eq. 19 analogue)",
        r.cycles, r.seconds, spec.fmax_mhz.unwrap(), r.gflops, r.e_d, r.compute_fraction
    );
    Ok(())
}

fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let mut engine = Engine::new(&dir)?;
    println!("PJRT platform: {}", engine.platform());
    let names: Vec<String> = engine.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
    let mut failures = 0;
    for name in names {
        let meta = engine.manifest.by_name(&name).unwrap().clone();
        let inputs: Vec<Matrix> = meta
            .inputs
            .iter()
            .enumerate()
            .map(|(i, &(m, n))| Matrix::random(m, n, 1000 + i as u64))
            .collect();
        let refs: Vec<&Matrix> = inputs.iter().collect();
        let (got, stats) = engine.execute(&name, &refs)?;
        // Oracle: fold the inputs left-to-right with blocked GEMM.
        let mut want = matmul_blocked(&inputs[0], &inputs[1]);
        for extra in &inputs[2..] {
            want = matmul_blocked(&want, extra);
        }
        let err = got.rel_fro_error(&want);
        let ok = err < 1e-4;
        if !ok {
            failures += 1;
        }
        println!(
            "{:<16} {:>9.3} ms  rel err {:.2e}  {}",
            name,
            stats.exec_seconds * 1e3,
            err,
            if ok { "OK" } else { "FAIL" }
        );
    }
    anyhow::ensure!(failures == 0, "{failures} artifact(s) disagree with the oracle");
    println!("all artifacts verified against the GEMM oracle");
    Ok(())
}

/// Flight-record one seeded elastic chaos run (active torus fleet, hot
/// spares, growth watermark, `FaultPlan::seeded`), prove the event
/// stream deterministic by replaying it — the two Chrome serializations
/// must match byte for byte — then write the trace and print the
/// critical path with its per-category attribution. `--json` emits the
/// gateable metrics for the CI perf gate.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use std::collections::BTreeMap;
    use systo3d::cluster::{ClusterSim, ElasticOutcome, FaultPlan, Fleet};
    use systo3d::cluster::{PartitionPlan, PartitionStrategy};
    use systo3d::fabric::Topology;
    use systo3d::trace::{chrome_trace_json, critical_path, TraceLog, Tracer};

    let devices = args.get_usize("devices", 16).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(devices >= 2, "--devices must be at least 2");
    let spares = args.get_usize("spares", 2).map_err(anyhow::Error::msg)?;
    let d2 = args.get_u64("d2", 8192).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 0).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let out = args.get_str("out", "trace.json");

    let plan = PartitionPlan::new(PartitionStrategy::auto_summa25d(devices as u64), d2, d2, d2)
        .map_err(anyhow::Error::msg)?;
    let build = || -> anyhow::Result<ClusterSim> {
        let fleet = Fleet::homogeneous(devices + spares, &id).map_err(anyhow::Error::msg)?;
        Ok(ClusterSim::builder(fleet)
            .topology(Topology::torus_near_square(devices))
            .spares(spares)
            .watermark(Some(2.0))
            .build())
    };
    // Fault horizon from an untraced healthy run (the chaos suite's
    // convention), so the seeded kills land mid-schedule.
    let horizon = build()?.simulate(&plan).makespan_seconds;
    let faults = FaultPlan::seeded(seed, devices + spares, horizon);
    let run = || -> anyhow::Result<(String, TraceLog, ElasticOutcome)> {
        let mut sim = build()?;
        sim.trace = Tracer::recording();
        let outcome = sim.simulate_elastic(&plan, &faults).map_err(anyhow::Error::msg)?;
        let log = sim.trace.snapshot();
        Ok((chrome_trace_json(&log), log, outcome))
    };
    let (json, log, outcome) = run()?;
    let (replay, _, _) = run()?;
    anyhow::ensure!(
        json == replay,
        "flight recorder drifted: two replays of seed {seed} serialized differently"
    );
    std::fs::write(out, &json).map_err(|e| anyhow::anyhow!("write {out}: {e}"))?;

    println!(
        "seed {seed} on a {}-card torus (+{spares} spare(s)): {} span(s), {} instant(s), \
         {} counter sample(s) across {} track(s)",
        devices,
        log.spans.len(),
        log.instants.len(),
        log.counters.len(),
        log.tracks().len(),
    );
    println!(
        "chaos outcome: {} spare activation(s), {} drain(s) in {:.4} s, {} card(s) grown, \
         makespan {:.4} s",
        outcome.spare_activations,
        outcome.drains_completed,
        outcome.drain_seconds,
        outcome.grown_cards,
        outcome.schedule.makespan_seconds,
    );
    println!("replay check passed: both runs serialized to identical {}-byte JSON", json.len());
    println!("wrote Chrome trace to {out} — load it in Perfetto or chrome://tracing\n");

    let path = critical_path(&log);
    let drift = (path.total_seconds() - path.makespan).abs();
    anyhow::ensure!(
        drift <= 1e-6,
        "critical-path buckets drift {drift} s from the {} s makespan",
        path.makespan
    );
    print!("{}", path.render(12));
    for (name, (count, secs)) in &log.host_profile {
        println!("  host-profile {name}: {count} event(s), {secs:.6} s wall");
    }

    if let Some(p) = args.get("json") {
        let mut metrics: BTreeMap<String, f64> = BTreeMap::new();
        metrics.insert("trace_critical_coverage".into(), path.total_seconds() / path.makespan);
        metrics.insert("trace_span_count".into(), log.spans.len() as f64);
        metrics.insert("trace_compute_share".into(), path.share("compute"));
        metrics.insert("trace_fabric_share".into(), path.share("fabric"));
        systo3d::util::json::write_metrics(p, &metrics)?;
        println!("wrote {} metric(s) to {p}", metrics.len());
    }
    Ok(())
}

/// The live fleet observatory for one seeded elastic chaos run:
/// sliding-window sparklines derived from the flight recorder's trace,
/// the anomaly localizer's verdict on what the chaos plan degraded,
/// and the SLO burn-rate alerts that drove growth. The p99 target is
/// pinned at 2x the healthy run's p99 so the dashboard is meaningful
/// at any problem size.
fn cmd_top(args: &Args) -> anyhow::Result<()> {
    use systo3d::cluster::{ClusterSim, FaultPlan, Fleet, SloPolicy};
    use systo3d::cluster::{PartitionPlan, PartitionStrategy};
    use systo3d::fabric::Topology;
    use systo3d::observe::{anomaly, Observatory};
    use systo3d::trace::Tracer;

    let devices = args.get_usize("devices", 8).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(devices >= 2, "--devices must be at least 2");
    let spares = args.get_usize("spares", 1).map_err(anyhow::Error::msg)?;
    let d2 = args.get_u64("d2", 8192).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 0).map_err(anyhow::Error::msg)?;
    let id = args.get_str("design", "G").to_uppercase();
    let width = args.get_usize("width", 48).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(width >= 8, "--width must be at least 8");

    let plan = PartitionPlan::new(PartitionStrategy::auto_summa25d(devices as u64), d2, d2, d2)
        .map_err(anyhow::Error::msg)?;
    let build = |slo: Option<SloPolicy>| -> anyhow::Result<ClusterSim> {
        let fleet = Fleet::homogeneous(devices + spares, &id).map_err(anyhow::Error::msg)?;
        Ok(ClusterSim::builder(fleet)
            .topology(Topology::torus_near_square(devices))
            .spares(spares)
            .watermark(Some(2.0))
            .slo(slo)
            .trace(Tracer::recording())
            .build())
    };

    // Healthy run first: the horizon the fault plan is seeded against
    // and the baseline p99 the SLO target is pinned to.
    let healthy = build(None)?;
    let healthy_out =
        healthy.simulate_elastic(&plan, &FaultPlan::none()).map_err(anyhow::Error::msg)?;
    let horizon = healthy_out.schedule.makespan_seconds;
    let healthy_obs =
        Observatory::from_trace(&healthy.trace.snapshot(), (horizon / 24.0).max(1e-6));
    let healthy_p99 = healthy_obs.latency_p99.max().unwrap_or(horizon);
    let policy = SloPolicy {
        p99_latency_s: 2.0 * healthy_p99,
        window_s: (horizon / 12.0).max(1e-6),
        long_windows: 4,
        burn_threshold: 0.25,
        max_growth: 2,
    };

    let faults = FaultPlan::seeded(seed, devices + spares, horizon);
    let sim = build(Some(policy))?;
    let outcome = sim.simulate_elastic(&plan, &faults).map_err(anyhow::Error::msg)?;
    let log = sim.trace.snapshot();
    let obs =
        Observatory::from_trace(&log, (outcome.schedule.makespan_seconds / 24.0).max(1e-6));

    println!(
        "seed {seed} on a {devices}-card torus (+{spares} spare(s)); SLO: p99 <= {:.4} s \
         (2x healthy), burn windows {:.4} s / {:.4} s, threshold 25%",
        policy.p99_latency_s,
        policy.window_s,
        policy.window_s * policy.long_windows as f64,
    );
    print!("{}", obs.render_dashboard(width));
    print!("{}", anomaly::localize(&log, 0.1 * horizon).render());
    if outcome.slo_alerts.is_empty() {
        println!(
            "slo: no sustained burn (final burn {:.2}/{:.2})",
            outcome.slo_final_burn.0, outcome.slo_final_burn.1
        );
    } else {
        println!(
            "slo: {} sustained-burn instant(s), first at {:.4} s; grew {} card(s); \
             final burn {:.2}/{:.2}",
            outcome.slo_alerts.len(),
            outcome.slo_alerts[0],
            outcome.slo_grown_cards,
            outcome.slo_final_burn.0,
            outcome.slo_final_burn.1,
        );
    }
    println!(
        "chaos outcome: {} spare activation(s), {} drain(s), {} watermark-grown card(s), \
         makespan {:.4} s (healthy {:.4} s)",
        outcome.spare_activations,
        outcome.drains_completed,
        outcome.grown_cards,
        outcome.schedule.makespan_seconds,
        horizon,
    );
    Ok(())
}

/// Record the headline simulated metrics, merge the example-emitted
/// JSON files, write the bench-trajectory artifact, and gate against
/// the checked-in baseline: a "higher" metric fails below
/// `value · (1 − tolerance)`, a "lower" metric above
/// `value · (1 + tolerance)`. Every metric lands in the output file;
/// only keys present in the baseline are gated, so the artifact is the
/// trajectory future PRs ratchet the baseline from. The gate collects
/// every violation (name, baseline, candidate, signed % delta) before
/// failing, and `--explain` additionally diffs `--baseline-trace`
/// against `--candidate-trace` on failure so the regression report
/// names the spans that moved, not just the metric that tripped.
fn cmd_perfgate(args: &Args) -> anyhow::Result<()> {
    use std::collections::BTreeMap;
    use systo3d::blocked::{OffchipDesign, OffchipSim};
    use systo3d::dse::configs::fitted_designs;
    use systo3d::util::json::{write_metrics, Json};

    let out = args.get_str("out", "BENCH_pr9.json");
    let baseline_path = args.get_str("baseline", "rust/benches/baseline.json");
    let d2 = args.get_u64("d2", 8192).map_err(anyhow::Error::msg)?;
    let tolerance: f64 = match args.get("tolerance") {
        None => 0.10,
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("--tolerance expects a float, got {v:?}"))?,
    };

    // Per-design simulated throughput: deterministic, so it gates
    // cleanly (wall-clock bench numbers go to the artifact logs only).
    let mut metrics: BTreeMap<String, f64> = BTreeMap::new();
    for spec in fitted_designs() {
        let design = OffchipDesign {
            blocking: spec.level1().expect("fitted design has a blocking"),
            fmax_mhz: spec.fmax_mhz.unwrap(),
            controller_efficiency: 0.97,
        };
        let dj2 = design.blocking.scale_dj2(d2);
        let (pi, pj, pk) = design.blocking.pad_offchip(d2, dj2, d2);
        let r = OffchipSim::new(design).simulate(pi, pj, pk);
        metrics.insert(format!("design_{}_gflops", spec.id), r.gflops);
        metrics.insert(format!("design_{}_e_d", spec.id), r.e_d);
    }

    // Fold in whatever the example sweeps emitted with --json.
    if let Some(list) = args.get("merge") {
        for path in list.split(',').filter(|p| !p.is_empty()) {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
            let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let obj = doc
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("{path}: expected a JSON object"))?;
            for (key, value) in obj {
                let n = value
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("{path}: {key} is not a number"))?;
                metrics.insert(key.clone(), n);
            }
        }
    }

    write_metrics(out, &metrics)?;
    println!("recorded {} metric(s) to {out}", metrics.len());

    let text = std::fs::read_to_string(baseline_path)
        .map_err(|e| anyhow::anyhow!("read baseline {baseline_path}: {e}"))?;
    let baseline =
        Json::parse(&text).map_err(|e| anyhow::anyhow!("{baseline_path}: {e}"))?;
    let entries = baseline
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("{baseline_path}: expected a JSON object"))?;
    // Without --merge only the in-process design metrics exist; the
    // example-emitted baseline keys are then reported as skipped
    // instead of failing, so a bare `systo3d perfgate` stays useful
    // for a quick local design-throughput check. CI always passes
    // --merge, which makes a missing baseline metric a hard failure.
    let strict = args.get("merge").is_some();
    let mut failures: Vec<String> = Vec::new();
    let mut gated = 0usize;
    for (key, entry) in entries {
        let value = entry
            .get("value")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("{baseline_path}: {key} has no numeric value"))?;
        let higher = match entry.get("direction").and_then(Json::as_str) {
            Some("higher") | None => true,
            Some("lower") => false,
            Some(other) => {
                anyhow::bail!("{baseline_path}: {key} direction {other:?} (higher|lower)")
            }
        };
        match metrics.get(key.as_str()) {
            None if strict => {
                gated += 1;
                failures.push(format!("{key}: baseline metric missing from this run"));
            }
            None => println!("SKIP {key}: not recorded in this run (no --merge)"),
            Some(&cur) => {
                gated += 1;
                let (ok, bound) = if higher {
                    (cur >= value * (1.0 - tolerance), value * (1.0 - tolerance))
                } else {
                    (cur <= value * (1.0 + tolerance), value * (1.0 + tolerance))
                };
                let delta_pct = if value.abs() > f64::EPSILON {
                    (cur - value) / value.abs() * 100.0
                } else if cur.abs() > f64::EPSILON {
                    f64::INFINITY
                } else {
                    0.0
                };
                println!(
                    "{} {key}: {cur:.4} vs baseline {value:.4} ({delta_pct:+.1}%, {} bound \
                     {bound:.4})",
                    if ok { "PASS" } else { "FAIL" },
                    if higher { "lower" } else { "upper" },
                );
                if !ok {
                    failures.push(format!(
                        "{key}: baseline {value:.4}, candidate {cur:.4} ({delta_pct:+.1}%) \
                         past the {:.0}% band",
                        tolerance * 100.0
                    ));
                }
            }
        }
    }
    if !failures.is_empty() {
        // One pass collects every failing metric — a regression report
        // that names half the problem forces a second CI round trip.
        if args.flag("explain") {
            explain_failures(args, &failures)?;
        }
        anyhow::bail!(
            "perf gate: {} regression(s):\n  {}",
            failures.len(),
            failures.join("\n  ")
        );
    }
    println!("perf gate passed: {gated} gated of {} recorded metric(s)", metrics.len());
    Ok(())
}

/// The `perfgate --explain` path: on a floor violation, load the
/// baseline and candidate flight-recorder traces, run the trace diff,
/// print the attribution, and leave the blame report in
/// `perfgate_blame.txt` for the CI failure artifact.
fn explain_failures(args: &Args, failures: &[String]) -> anyhow::Result<()> {
    use systo3d::trace::{diff, parse_chrome_trace};

    let base_path = args.get_str("baseline-trace", "trace_baseline.json");
    let cand_path = args.get_str("candidate-trace", "trace_candidate.json");
    let load = |path: &str| -> anyhow::Result<systo3d::trace::TraceLog> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("--explain: read trace {path}: {e}"))?;
        parse_chrome_trace(&text).map_err(|e| anyhow::anyhow!("--explain: {path}: {e}"))
    };
    match (load(base_path), load(cand_path)) {
        (Ok(base), Ok(cand)) => {
            let d = diff(&base, &cand);
            let mut report = format!(
                "perf gate failed; trace attribution {base_path} -> {cand_path}:\n\n{}",
                d.render(12)
            );
            report.push_str("\nfailing metrics:\n");
            for f in failures {
                report.push_str(&format!("  {f}\n"));
            }
            print!("{report}");
            std::fs::write("perfgate_blame.txt", &report)
                .map_err(|e| anyhow::anyhow!("write perfgate_blame.txt: {e}"))?;
            println!("wrote blame report to perfgate_blame.txt");
        }
        (base, cand) => {
            // Traces are best-effort context: their absence must not
            // mask the underlying metric regression.
            for r in [base, cand] {
                if let Err(e) = r {
                    eprintln!("warning: {e:#}");
                }
            }
        }
    }
    Ok(())
}

/// Align two flight-recorder traces (Chrome trace-event JSON as
/// written by `systo3d trace --out`) and print the differential
/// report: makespan delta, critical-path bucket and track attribution
/// (each summing to the delta by construction), and the ranked
/// span-level blame. `--expect-empty` turns any non-empty diff into an
/// error — the CI determinism gate diffs two same-seed replays with
/// it.
fn cmd_diff(args: &Args) -> anyhow::Result<()> {
    use std::collections::BTreeMap;
    use systo3d::trace::{diff, parse_chrome_trace, TraceLog};

    anyhow::ensure!(
        args.positional.len() == 2,
        "usage: systo3d diff BASELINE.json CANDIDATE.json [--top K] [--json METRICS.json] \
         [--expect-empty]"
    );
    let top = args.get_usize("top", 12).map_err(anyhow::Error::msg)?;
    let load = |path: &str| -> anyhow::Result<TraceLog> {
        let text =
            std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("read trace {path}: {e}"))?;
        parse_chrome_trace(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    };
    let base = load(&args.positional[0])?;
    let cand = load(&args.positional[1])?;
    let d = diff(&base, &cand);
    print!("{}", d.render(top));
    anyhow::ensure!(
        d.attribution_residual() <= 1e-6,
        "bucket attribution drifted {} s from the makespan delta",
        d.attribution_residual()
    );
    if args.flag("expect-empty") {
        anyhow::ensure!(
            d.is_empty(),
            "traces differ: makespan delta {:+.6} s, {} blame entr{} ({} appeared, {} vanished)",
            d.makespan_delta(),
            d.blame.len(),
            if d.blame.len() == 1 { "y" } else { "ies" },
            d.appeared_spans,
            d.vanished_spans,
        );
        println!("expect-empty check passed: traces are equivalent");
    }
    if let Some(p) = args.get("json") {
        let mut metrics: BTreeMap<String, f64> = BTreeMap::new();
        metrics.insert("diff_makespan_delta_s".into(), d.makespan_delta());
        for bucket in systo3d::trace::critical::BUCKETS {
            metrics.insert(format!("diff_bucket_{bucket}_delta_s"), d.bucket_delta(bucket));
        }
        metrics.insert("diff_blame_entries".into(), d.blame.len() as f64);
        metrics.insert("diff_matched_spans".into(), d.matched_spans as f64);
        metrics.insert("diff_appeared_spans".into(), d.appeared_spans as f64);
        metrics.insert("diff_vanished_spans".into(), d.vanished_spans as f64);
        systo3d::util::json::write_metrics(p, &metrics)?;
        println!("wrote {} metric(s) to {p}", metrics.len());
    }
    Ok(())
}

/// Walk the accumulated `BENCH_pr<N>.json` perf-gate artifacts in a
/// directory and print each metric's trajectory, naming the PR where
/// it last moved by more than the threshold — the "when did this
/// start?" half of a regression hunt, answered without opening a
/// single trace.
fn cmd_trend(args: &Args) -> anyhow::Result<()> {
    use std::collections::BTreeMap;
    use systo3d::observe::trend::{analyze, collect_bench_files, parse_metrics, render};

    let dir = args.get_str("dir", ".");
    let threshold: f64 = match args.get("threshold") {
        None => 0.05,
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("--threshold expects a float, got {v:?}"))?,
    };
    let files = collect_bench_files(std::path::Path::new(dir))
        .map_err(|e| anyhow::anyhow!("scan {dir}: {e}"))?;
    anyhow::ensure!(
        !files.is_empty(),
        "no BENCH_pr<N>.json artifacts under {dir} — download the CI bench artifacts there \
         first, or record one locally with `systo3d perfgate`"
    );
    let mut runs = Vec::with_capacity(files.len());
    for (pr, path) in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let metrics =
            parse_metrics(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        runs.push((*pr, metrics));
    }
    let trends = analyze(&runs);
    print!("{}", render(&trends, threshold));
    if let Some(p) = args.get("json") {
        let mut metrics: BTreeMap<String, f64> = BTreeMap::new();
        metrics.insert("trend_artifacts".into(), files.len() as f64);
        metrics.insert("trend_metrics".into(), trends.len() as f64);
        let moved = trends.iter().filter(|t| t.last_move(threshold).is_some()).count();
        metrics.insert("trend_moved_metrics".into(), moved as f64);
        systo3d::util::json::write_metrics(p, &metrics)?;
        println!("wrote {} metric(s) to {p}", metrics.len());
    }
    Ok(())
}

/// Parse an optional float option with a default.
fn get_f64(args: &Args, name: &str, default: f64) -> anyhow::Result<f64> {
    match args.get(name) {
        None => Ok(default),
        Some(v) => {
            v.parse::<f64>().map_err(|_| anyhow::anyhow!("--{name} expects a float, got {v:?}"))
        }
    }
}

/// Open-loop overload drill (`serve --overload`): replay a seeded
/// multi-tenant trace at a multiple of fleet capacity through the
/// admission pipeline, once deadline-aware and once as the FIFO /
/// fixed-window baseline, and print goodput, shed rate, fairness, and
/// the elastic-growth narrative.
fn cmd_serve_overload(args: &Args) -> anyhow::Result<()> {
    use systo3d::coordinator::{
        simulate_serve, AdmissionPolicy, ArrivalModel, Metrics, ServeConfig, WorkloadGen,
    };
    use systo3d::observe::slo::SloPolicy;
    use systo3d::perfmodel::flop_count;

    let requests = args.get_u64("requests", 40_000).map_err(anyhow::Error::msg)?;
    let servers = args.get_usize("servers", 2).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(servers >= 1, "--servers must be at least 1");
    let spares = args.get_usize("spares", 1).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 7).map_err(anyhow::Error::msg)?;
    let factor = get_f64(args, "factor", 3.0)?;
    let capacity = args.get_usize("capacity", 65_536).map_err(anyhow::Error::msg)?;
    let target = get_f64(args, "latency-target", 0.05)?;
    let watermark = get_f64(args, "pressure-watermark", 0.002)?;

    let cfg = ServeConfig {
        servers,
        hot_spares: spares,
        policy: AdmissionPolicy {
            queue_capacity: capacity,
            shed_doomed: true,
            latency_target_s: Some(target),
            ..Default::default()
        },
        pressure_watermark: Some(watermark),
        slo: SloPolicy {
            window_s: 0.005,
            long_windows: 4,
            burn_threshold: 0.5,
            max_growth: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    // Offered load: `factor` × what the fleet can serve (multi_tenant
    // offers fixed 256³ jobs, so capacity is closed-form).
    let per_job_s = flop_count(256, 256, 256) as f64 / (cfg.card_gflops * 1e9)
        + cfg.dispatch_overhead_s / cfg.max_batch as f64;
    let rate_hz = factor * servers as f64 / per_job_s;
    let mut gen = WorkloadGen::multi_tenant(seed, rate_hz);
    gen = match args.get_str("arrival", "poisson") {
        "poisson" => gen,
        "bursty" => gen.with_arrival(ArrivalModel::Bursty {
            factor: 4.0,
            on_s: 0.01,
            off_s: 0.03,
        }),
        "diurnal" => gen.with_arrival(ArrivalModel::Diurnal { period_s: 0.1, depth: 0.8 }),
        other => anyhow::bail!("--arrival must be poisson|bursty|diurnal, got {other:?}"),
    };

    println!(
        "open-loop overload drill: {requests} requests at {factor:.1}x capacity \
         ({rate_hz:.0} req/s) on {servers} card(s) + {spares} spare(s), seed {seed}\n"
    );
    let aware = simulate_serve(&gen, requests, &cfg);
    println!("deadline-aware admission (DRR fair share, doomed shed, SLO-pulled closes):");
    print!("{}", aware.render());
    let fifo_cfg = ServeConfig { deadline_aware: false, ..cfg.clone() };
    let fifo = simulate_serve(&gen, requests, &fifo_cfg);
    println!("\nFIFO / fixed-window baseline (same trace, same fleet):");
    print!("{}", fifo.render());

    let gain = aware.goodput_flops_per_s / fifo.goodput_flops_per_s.max(1.0);
    println!(
        "\ngoodput gain {gain:.2}x; shed rate {:.1}% vs {:.1}%; \
         p99 {:.2} ms vs {:.2} ms; fairness bound {:.3}",
        100.0 * aware.shed_rate(),
        100.0 * fifo.shed_rate(),
        aware.p99_s * 1e3,
        fifo.p99_s * 1e3,
        aware.fairness_bound(),
    );

    // The run scrapes like live traffic: fold it into the service
    // gauges and print the stable JSON snapshot.
    let metrics = Metrics::new();
    aware.record_into(&metrics);
    println!("\nscrape: {}", systo3d::observe::json_snapshot(&metrics.snapshot()));
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if args.flag("overload") {
        return cmd_serve_overload(args);
    }
    let n = args.get_u64("requests", 32).map_err(anyhow::Error::msg)?;
    let dir = args.get_str("artifacts", "artifacts");
    let config = ServiceConfig {
        artifact_dir: Some(PathBuf::from(dir)),
        max_batch: 8,
        batch_window: Duration::from_millis(2),
        ..Default::default()
    };
    let svc = GemmService::start(config)?;
    let sizes = [64usize, 256, 512];
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n {
        let s = sizes[(i % sizes.len() as u64) as usize];
        let a = Matrix::random(s, s, i * 2);
        let b = Matrix::random(s, s, i * 2 + 1);
        rxs.push(svc.submit(GemmRequest::new(a, b).id(i)));
    }
    let mut sim_seconds = 0.0;
    for rx in rxs {
        let resp = rx.recv()?;
        resp.result.map_err(anyhow::Error::msg)?;
        if let Some(sim) = resp.fpga_sim {
            sim_seconds += sim.seconds;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = svc.metrics.snapshot();
    let lat = svc.metrics.latency_report_line();
    println!(
        "served {} requests in {:.3} s ({:.1} req/s)\n\
         routes: {} artifact, {} fallback; {} batches; {} errors\n\
         host throughput: {:.2} GFLOPS (functional path)\n\
         simulated FPGA time for conforming shapes: {:.4} s\n\
         latency: {}",
        snap.requests,
        wall,
        snap.requests as f64 / wall,
        snap.artifact_hits,
        snap.fallbacks,
        snap.batches,
        snap.errors,
        snap.flops as f64 / wall / 1e9,
        sim_seconds,
        lat
    );
    Ok(())
}
