//! Cycle-level DDR4 channel simulator.
//!
//! The analytical model (eqs. 2–4) assumes a memory-controller
//! efficiency `e` per access pattern. This simulator derives efficiency
//! from first principles — bank state machines, row activate/precharge
//! penalties, the four-activate window (tFAW), burst granularity and
//! burst *utilization* — and is used to validate the constant the
//! paper's designs actually rely on: `e ≈ 1` for aligned burst-coalesced
//! sequential streams (§II-A, [12]). For strided/random patterns the
//! test asserts the strict ordering the LSU model encodes rather than
//! exact constants (real controllers vary widely there).
//!
//! Model (DDR4-2400, per channel): 64-bit bus, burst length 8 (64 B per
//! burst), 16 banks, FR-FCFS-lite (row hits before misses), tFAW
//! limiting activate bursts.

/// Timing parameters in memory-controller cycles (1200 MHz for
/// DDR4-2400; data moves on both edges).
#[derive(Clone, Copy, Debug)]
pub struct DdrTiming {
    /// Row-to-column delay.
    pub t_rcd: u32,
    /// Row precharge.
    pub t_rp: u32,
    /// Cycles of data transfer per burst (BL8 on a DDR bus: 4).
    pub t_burst: u32,
    /// Four-activate window: at most 4 row activations per t_faw.
    pub t_faw: u32,
    pub banks: u32,
    /// Row size in bytes (determines row-hit span).
    pub row_bytes: u64,
}

impl DdrTiming {
    pub fn ddr4_2400() -> Self {
        Self { t_rcd: 16, t_rp: 16, t_burst: 4, t_faw: 128, banks: 16, row_bytes: 8192 }
    }
}

/// A single read request for `bytes` useful bytes at `addr`.
#[derive(Clone, Copy, Debug)]
pub struct Access {
    pub addr: u64,
    pub bytes: u32,
}

/// Result of simulating an access stream.
#[derive(Clone, Copy, Debug)]
pub struct DdrSimResult {
    pub total_cycles: u64,
    pub data_cycles: u64,
    pub useful_bytes: u64,
    pub transferred_bytes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
}

impl DdrSimResult {
    /// Bus timing efficiency: data cycles / total cycles.
    pub fn timing_efficiency(&self) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.data_cycles as f64 / self.total_cycles as f64
    }

    /// Burst utilization: useful bytes / transferred bytes.
    pub fn utilization(&self) -> f64 {
        if self.transferred_bytes == 0 {
            return 0.0;
        }
        self.useful_bytes as f64 / self.transferred_bytes as f64
    }

    /// End-to-end efficiency — the `e` of eq. 2: timing × utilization.
    pub fn efficiency(&self) -> f64 {
        self.timing_efficiency() * self.utilization()
    }
}

/// The channel simulator.
#[derive(Clone, Debug)]
pub struct DdrChannelSim {
    pub timing: DdrTiming,
    open_rows: Vec<Option<u64>>,
}

impl DdrChannelSim {
    pub fn new(timing: DdrTiming) -> Self {
        let banks = timing.banks as usize;
        Self { timing, open_rows: vec![None; banks] }
    }

    fn bank_and_row(&self, addr: u64) -> (usize, u64) {
        let row_global = addr / self.timing.row_bytes;
        // Bank-interleaved rows: consecutive rows land in different
        // banks (the standard mapping for streaming throughput).
        let bank = (row_global % self.timing.banks as u64) as usize;
        (bank, row_global)
    }

    /// Simulate a stream; each access transfers whole 64 B bursts
    /// covering `[addr, addr + bytes)`.
    pub fn run(&mut self, accesses: &[Access]) -> DdrSimResult {
        let t = self.timing;
        let mut total = 0u64;
        let mut data = 0u64;
        let mut useful = 0u64;
        let mut transferred = 0u64;
        let mut hits = 0u64;
        let mut misses = 0u64;
        let mut bank_free = vec![0u64; t.banks as usize];
        let mut bus_free = 0u64;
        // Sliding window of the last 4 activate times (tFAW).
        let mut activates: [u64; 4] = [0; 4];
        let mut act_idx = 0usize;
        let mut act_count = 0u64;
        for acc in accesses {
            useful += acc.bytes as u64;
            let first_burst = acc.addr / 64;
            let last_burst = (acc.addr + acc.bytes as u64 - 1) / 64;
            for burst in first_burst..=last_burst {
                let addr = burst * 64;
                let (bank, row) = self.bank_and_row(addr);
                let hit = self.open_rows[bank] == Some(row);
                let ready = if hit {
                    hits += 1;
                    bank_free[bank]
                } else {
                    misses += 1;
                    let penalty = if self.open_rows[bank].is_some() {
                        t.t_rp + t.t_rcd
                    } else {
                        t.t_rcd
                    };
                    self.open_rows[bank] = Some(row);
                    // tFAW: a new activate waits until 4 activates back
                    // is at least t_faw old.
                    let faw_gate = if act_count >= 4 {
                        activates[act_idx] + t.t_faw as u64
                    } else {
                        0
                    };
                    let act_time = bank_free[bank].max(faw_gate);
                    activates[act_idx] = act_time;
                    act_idx = (act_idx + 1) % 4;
                    act_count += 1;
                    act_time + penalty as u64
                };
                let start = ready.max(bus_free);
                let end = start + t.t_burst as u64;
                bank_free[bank] = end;
                bus_free = end;
                total = total.max(end);
                data += t.t_burst as u64;
                transferred += 64;
            }
        }
        DdrSimResult {
            total_cycles: total,
            data_cycles: data,
            useful_bytes: useful,
            transferred_bytes: transferred,
            row_hits: hits,
            row_misses: misses,
        }
    }
}

/// Sequential burst-coalesced stream: 4 KiB requests.
pub fn sequential_stream(base: u64, total_bytes: u64) -> Vec<Access> {
    let req = 4096u64;
    (0..total_bytes / req)
        .map(|i| Access { addr: base + i * req, bytes: req as u32 })
        .collect()
}

/// Strided stream: `count` reads of `bytes` every `stride` bytes — the
/// column-walk of a row-major matrix when `bytes` < 64.
pub fn strided_stream(base: u64, stride: u64, bytes: u32, count: u64) -> Vec<Access> {
    (0..count).map(|i| Access { addr: base + i * stride, bytes }).collect()
}

/// Pseudo-random 4-byte gathers over `span` bytes.
pub fn random_stream(seed: u64, span: u64, bytes: u32, count: u64) -> Vec<Access> {
    let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed);
    (0..count)
        .map(|_| Access { addr: (rng.next_below(span / 4)) * 4, bytes })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::lsu::{AccessPattern, Lsu};

    fn run(accs: &[Access]) -> DdrSimResult {
        DdrChannelSim::new(DdrTiming::ddr4_2400()).run(accs)
    }

    #[test]
    fn sequential_is_near_peak() {
        let r = run(&sequential_stream(0, 16 << 20));
        assert!(r.efficiency() > 0.95, "sequential e = {}", r.efficiency());
        assert!(r.row_hits > r.row_misses * 50);
        assert!((r.utilization() - 1.0).abs() < 1e-9);
    }

    /// The constant the paper's designs rely on: burst-coalesced
    /// sequential access with e ≈ 1 (here: matches the LSU model's 0.97
    /// within 0.05).
    #[test]
    fn sequential_constant_validated() {
        let sim = run(&sequential_stream(0, 32 << 20)).efficiency();
        let model = Lsu::synthesize(64, AccessPattern::SequentialAligned).controller_efficiency();
        assert!((sim - model).abs() < 0.05, "sim {sim:.3} vs model {model}");
    }

    #[test]
    fn strided_wastes_bursts() {
        // Column walk: 4 useful bytes per 64 B burst -> utilization 1/16.
        let r = run(&strided_stream(0, 4096, 4, 8192));
        assert!((r.utilization() - 1.0 / 16.0).abs() < 1e-9);
        assert!(r.efficiency() < 0.1, "strided e = {}", r.efficiency());
    }

    #[test]
    fn wide_strided_is_half_useful() {
        // 64 B useful every 128 B: utilization 1, but every other burst
        // skipped -> efficiency equals timing efficiency with gaps.
        let r = run(&strided_stream(0, 128, 64, 8192));
        assert!((r.utilization() - 1.0).abs() < 1e-9);
        assert!(r.efficiency() > 0.8, "{}", r.efficiency());
    }

    #[test]
    fn random_pays_activates() {
        let r = run(&random_stream(7, 1 << 30, 4, 8192));
        // Every gather is a row miss paying tRCD/tFAW and wasting 60/64
        // of the burst.
        assert!(r.row_misses > r.row_hits);
        assert!(r.efficiency() < 0.1, "random e = {}", r.efficiency());
    }

    /// Ordering of the LSU model's pattern constants is reproduced by
    /// the first-principles simulator.
    #[test]
    fn pattern_ordering_validated() {
        let seq = run(&sequential_stream(0, 32 << 20)).efficiency();
        let strided = run(&strided_stream(0, 4096, 4, 8192)).efficiency();
        let rand = run(&random_stream(7, 1 << 30, 4, 8192)).efficiency();
        assert!(seq > strided && strided >= rand, "{seq} {strided} {rand}");
        let e = |p| Lsu::synthesize(4, p).controller_efficiency();
        assert!(e(AccessPattern::SequentialAligned) > e(AccessPattern::Strided));
        assert!(e(AccessPattern::Strided) > e(AccessPattern::Random));
    }

    #[test]
    fn stream_generators() {
        assert_eq!(sequential_stream(0, 8192).len(), 2);
        assert_eq!(strided_stream(0, 128, 64, 10).len(), 10);
        assert_eq!(random_stream(1, 1 << 20, 64, 10).len(), 10);
    }
}
