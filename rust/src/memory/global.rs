//! Global-memory model: 4× DDR4-2400 channels behind dedicated
//! controllers (paper §II-A).
//!
//! Each channel provides a peak of `B_ddr = 19200 MB/s`. An LSU clocked
//! at `f_max` requesting `𝓑_r` bytes/cycle stalls iff
//!
//! ```text
//! 𝓑_r · f_max > e · B_ddr                       (eq. 2)
//! stall = 1 − e·B_ddr / (𝓑_r·f_max)             (when stalled)
//! ```
//!
//! and the stall degrades loop throughput linearly (eq. 3). The *reuse
//! ratio* (eq. 14) is the factor by which on-chip reuse must multiply a
//! channel's delivery rate to match the array's appetite.

/// One DDR4 memory module + controller.
#[derive(Clone, Copy, Debug)]
pub struct DdrChannel {
    /// Peak theoretical throughput in MB/s (10^6 bytes).
    pub peak_mb_s: f64,
}

impl DdrChannel {
    /// DDR4@2400 MT/s with a 64-bit interface: 19200 MB/s.
    pub fn ddr4_2400() -> Self {
        Self { peak_mb_s: 19_200.0 }
    }

    /// Bytes per second at controller efficiency `e`.
    pub fn effective_bytes_per_s(&self, e: f64) -> f64 {
        e * self.peak_mb_s * 1e6
    }

    /// Floats the channel can deliver per kernel cycle at `f_mhz`.
    pub fn floats_per_cycle(&self, e: f64, f_mhz: f64) -> f64 {
        self.effective_bytes_per_s(e) / (f_mhz * 1e6) / 4.0
    }

    /// Seconds to move `bytes` at controller efficiency `e` — the
    /// transfer-time primitive the cluster interconnect reuses.
    pub fn seconds_for_bytes(&self, e: f64, bytes: u64) -> f64 {
        bytes as f64 / self.effective_bytes_per_s(e)
    }
}

/// Outcome of the stall analysis for one LSU↔channel pairing.
#[derive(Clone, Copy, Debug)]
pub struct StallAnalysis {
    /// Requested bytes/cycle (𝓑_r).
    pub request_bytes_per_cycle: f64,
    /// Deliverable bytes/cycle at this f_max and efficiency.
    pub supply_bytes_per_cycle: f64,
    /// Stall rate ∈ [0,1); 0 when the channel keeps up.
    pub stall: f64,
}

impl StallAnalysis {
    pub fn stalled(&self) -> bool {
        self.stall > 0.0
    }
}

/// The full card memory: several channels.
#[derive(Clone, Debug)]
pub struct GlobalMemory {
    pub channels: Vec<DdrChannel>,
}

impl GlobalMemory {
    /// The 520N: four DDR4-2400 modules (76800 MB/s aggregate).
    pub fn bittware_520n() -> Self {
        Self { channels: vec![DdrChannel::ddr4_2400(); 4] }
    }

    pub fn aggregate_mb_s(&self) -> f64 {
        self.channels.iter().map(|c| c.peak_mb_s).sum()
    }

    /// Stall analysis for an LSU requesting `bytes_per_cycle` from one
    /// channel at `f_mhz` with controller efficiency `e` (eqs. 2–3).
    pub fn analyze_stall(
        &self,
        channel: usize,
        bytes_per_cycle: f64,
        f_mhz: f64,
        e: f64,
    ) -> StallAnalysis {
        let ch = &self.channels[channel];
        let supply = ch.effective_bytes_per_s(e) / (f_mhz * 1e6);
        let stall = if bytes_per_cycle * f_mhz * 1e6 > ch.effective_bytes_per_s(e) {
            1.0 - supply / bytes_per_cycle
        } else {
            0.0
        };
        StallAnalysis {
            request_bytes_per_cycle: bytes_per_cycle,
            supply_bytes_per_cycle: supply,
            stall,
        }
    }

    /// Reuse ratio r = 𝓑_array / 𝓑_global (eq. 14), rounded up to the
    /// next integer (a datum cannot be reused a fractional number of
    /// times by the blocked schedule).
    pub fn reuse_ratio(array_floats_per_cycle: f64, global_floats_per_cycle: f64) -> u32 {
        assert!(global_floats_per_cycle > 0.0);
        (array_floats_per_cycle / global_floats_per_cycle).ceil() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn card_aggregate_bandwidth() {
        let m = GlobalMemory::bittware_520n();
        assert_eq!(m.channels.len(), 4);
        assert!((m.aggregate_mb_s() - 76_800.0).abs() < 1e-9);
    }

    #[test]
    fn no_stall_when_supply_sufficient() {
        let m = GlobalMemory::bittware_520n();
        // 32 B/cycle at 400 MHz = 12.8 GB/s < 0.97·19.2 GB/s -> no stall.
        let a = m.analyze_stall(0, 32.0, 400.0, 0.97);
        assert!(!a.stalled(), "{a:?}");
    }

    #[test]
    fn stall_rate_formula_eq2() {
        let m = GlobalMemory::bittware_520n();
        // 64 B/cycle at 400 MHz = 25.6 GB/s > 19.2 GB/s (e=1):
        // stall = 1 - 19200/25600 = 0.25.
        let a = m.analyze_stall(0, 64.0, 400.0, 1.0);
        assert!((a.stall - 0.25).abs() < 1e-12, "{a:?}");
    }

    #[test]
    fn boundary_no_stall() {
        let m = GlobalMemory::bittware_520n();
        // Exactly at the limit: 48 B/cycle · 400 MHz = 19.2 GB/s (e=1).
        let a = m.analyze_stall(0, 48.0, 400.0, 1.0);
        assert_eq!(a.stall, 0.0);
    }

    #[test]
    fn channel_floats_per_cycle() {
        let ch = DdrChannel::ddr4_2400();
        // At 400 MHz, e=1: 19200e6/400e6/4 = 12 floats/cycle.
        assert!((ch.floats_per_cycle(1.0, 400.0) - 12.0).abs() < 1e-12);
    }

    #[test]
    fn reuse_ratio_eq14() {
        // Design G: B_A = di0*dk0 = 128 floats/cycle; a channel supplies
        // B_gA = 8 floats/cycle at ~400 MHz -> r_A = 16.
        assert_eq!(GlobalMemory::reuse_ratio(128.0, 8.0), 16);
        // Fractional demand rounds up.
        assert_eq!(GlobalMemory::reuse_ratio(100.0, 8.0), 13);
    }
}
