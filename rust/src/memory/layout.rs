//! Matrix storage layouts and host-side reordering costs (§V, §VI).
//!
//! The implemented design wants A column-major (accessed by block
//! columns) and B/C row-major, so the only host transform ever needed is
//! one transposition of A — and C keeps B's format, so a product can
//! chain into the next multiply with **zero** host reordering. The Intel
//! SDK baseline instead needs block-wise reordering of A, transposition +
//! block-wise reordering of B, and a two-level reverse reordering of C —
//! modelled here so the end-to-end comparison can charge it.

/// Storage order of a dense matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    RowMajor,
    ColMajor,
    /// Block-reordered with the given block shape (SDK operand format).
    Blocked { bi: u32, bj: u32 },
    /// Two-level blocked (SDK result format).
    TwoLevelBlocked { bi: u32, bj: u32 },
}

impl Layout {
    /// Whether converting `from -> to` is the identity.
    pub fn same(from: Layout, to: Layout) -> bool {
        from == to
    }
}

/// A host-side reorder pass over an (m × n) f32 matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HostReorder {
    pub from: Layout,
    pub to: Layout,
    pub m: u64,
    pub n: u64,
}

/// Host memory bandwidth assumed for reorder cost accounting (bytes/s).
/// A single-socket Xeon with DDR4-2666: ~20 GB/s effective for a
/// read+write permutation pass.
pub const HOST_REORDER_BYTES_PER_S: f64 = 20e9;

impl HostReorder {
    /// Bytes moved: a permutation touches each element once in, once out.
    pub fn bytes_moved(&self) -> u64 {
        if Layout::same(self.from, self.to) {
            0
        } else {
            2 * self.m * self.n * 4
        }
    }

    /// Seconds on the host.
    pub fn seconds(&self) -> f64 {
        self.bytes_moved() as f64 / HOST_REORDER_BYTES_PER_S
    }
}

/// Transpose a row-major matrix in place of layout metadata (functional
/// helper used by the coordinator to prepare A in column-major form).
pub fn transpose_f32(src: &[f32], m: usize, n: usize) -> Vec<f32> {
    assert_eq!(src.len(), m * n);
    let mut out = vec![0.0f32; m * n];
    // Cache-blocked transpose: 32x32 tiles keep both streams resident.
    const T: usize = 32;
    for i0 in (0..m).step_by(T) {
        for j0 in (0..n).step_by(T) {
            for i in i0..(i0 + T).min(m) {
                for j in j0..(j0 + T).min(n) {
                    out[j * m + i] = src[i * n + j];
                }
            }
        }
    }
    out
}

/// Reorder a row-major (m×n) matrix into block order: all elements of
/// block (0,0) first (row-major within the block), then block (0,1), …
/// Used to model (and test) the Intel SDK operand format.
pub fn block_reorder_f32(src: &[f32], m: usize, n: usize, bi: usize, bj: usize) -> Vec<f32> {
    assert_eq!(src.len(), m * n);
    assert!(m % bi == 0 && n % bj == 0, "matrix not divisible by block");
    let mut out = Vec::with_capacity(m * n);
    for bi0 in (0..m).step_by(bi) {
        for bj0 in (0..n).step_by(bj) {
            for i in bi0..bi0 + bi {
                for j in bj0..bj0 + bj {
                    out.push(src[i * n + j]);
                }
            }
        }
    }
    out
}

/// Inverse of [`block_reorder_f32`].
pub fn block_unorder_f32(src: &[f32], m: usize, n: usize, bi: usize, bj: usize) -> Vec<f32> {
    assert_eq!(src.len(), m * n);
    assert!(m % bi == 0 && n % bj == 0);
    let mut out = vec![0.0f32; m * n];
    let mut it = src.iter();
    for bi0 in (0..m).step_by(bi) {
        for bj0 in (0..n).step_by(bj) {
            for i in bi0..bi0 + bi {
                for j in bj0..bj0 + bj {
                    out[i * n + j] = *it.next().unwrap();
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_reorder_is_free() {
        let r = HostReorder { from: Layout::RowMajor, to: Layout::RowMajor, m: 1024, n: 1024 };
        assert_eq!(r.bytes_moved(), 0);
        assert_eq!(r.seconds(), 0.0);
    }

    #[test]
    fn transpose_cost_scales() {
        let r = HostReorder { from: Layout::RowMajor, to: Layout::ColMajor, m: 1024, n: 1024 };
        assert_eq!(r.bytes_moved(), 2 * 1024 * 1024 * 4);
        assert!(r.seconds() > 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = 5;
        let n = 7;
        let src: Vec<f32> = (0..m * n).map(|x| x as f32).collect();
        let t = transpose_f32(&src, m, n);
        assert_eq!(t[0 * m + 0], src[0]);
        assert_eq!(t[3 * m + 2], src[2 * n + 3]); // (i=2,j=3) -> (j=3,i=2)
        let tt = transpose_f32(&t, n, m);
        assert_eq!(tt, src);
    }

    #[test]
    fn transpose_large_blocked_path() {
        let m = 70;
        let n = 65; // exercises partial tiles
        let src: Vec<f32> = (0..m * n).map(|x| (x % 997) as f32).collect();
        let tt = transpose_f32(&transpose_f32(&src, m, n), n, m);
        assert_eq!(tt, src);
    }

    #[test]
    fn block_reorder_roundtrip() {
        let m = 8;
        let n = 12;
        let src: Vec<f32> = (0..m * n).map(|x| x as f32).collect();
        let b = block_reorder_f32(&src, m, n, 4, 4);
        assert_ne!(b, src);
        // First block is the top-left 4x4 in row-major order.
        assert_eq!(&b[..4], &src[..4]);
        assert_eq!(b[4], src[n]); // second row of block (0,0)
        let back = block_unorder_f32(&b, m, n, 4, 4);
        assert_eq!(back, src);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn block_reorder_requires_divisibility() {
        block_reorder_f32(&vec![0.0; 6], 2, 3, 2, 2);
    }
}
