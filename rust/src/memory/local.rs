//! On-chip (local) memory systems: M20K/MLAB-backed *mapped* and *FIFO*
//! systems with user-controlled partitioning (paper §II-C, §V).
//!
//! Partitioning is the paper's key lever: many small partitions, each
//! with its own LSU, distribute data throughput across the chip right
//! next to the DSPs that consume it. In the implemented design:
//!
//! * the A mapped system has `d_i0 · d_k0` partitions (one per register
//!   chain entering the array's A face), double-buffered so Read can
//!   overlap Compute;
//! * the B mapped system likewise has `d_k0 · d_j0` partitions;
//! * the C FIFO system has `d_i0 · d_j0` FIFOs of depth
//!   `(d_i1/d_i0)·(d_j1/d_j0)` holding the block being accumulated.

use crate::fpga::device::{M20K_BYTES, F32_BYTES};
use crate::util::div_ceil;

/// A partitioned, memory-mapped on-chip system.
#[derive(Clone, Debug)]
pub struct MappedSystem {
    pub name: String,
    /// Number of independent partitions (each gets a private LSU).
    pub partitions: u32,
    /// Floats stored per partition.
    pub floats_per_partition: u64,
    /// Replication factor for double buffering (2 = ping/pong).
    pub buffers: u32,
}

impl MappedSystem {
    /// The A-matrix staging memory for a (d_i0, d_k0) array face fed by
    /// level-1 blocks of height `d_i1`.
    pub fn for_a(di0: u32, dk0: u32, di1: u32) -> Self {
        assert!(di1 % di0 == 0);
        Self {
            name: "A".into(),
            partitions: di0 * dk0,
            // Each partition holds the column of its (i,k) lane through
            // all d_i1/d_i0 second-level blocks.
            floats_per_partition: (di1 / di0) as u64,
            buffers: 2,
        }
    }

    /// The B-matrix staging memory for a (d_k0, d_j0) array face fed by
    /// level-1 blocks of width `d_j1`.
    pub fn for_b(dk0: u32, dj0: u32, dj1: u32) -> Self {
        assert!(dj1 % dj0 == 0);
        Self {
            name: "B".into(),
            partitions: dk0 * dj0,
            floats_per_partition: (dj1 / dj0) as u64,
            buffers: 2,
        }
    }

    /// Total floats stored.
    pub fn total_floats(&self) -> u64 {
        self.partitions as u64 * self.floats_per_partition * self.buffers as u64
    }

    /// Load units exposed to the datapath (one per partition).
    pub fn load_units(&self) -> u32 {
        self.partitions
    }

    /// Aggregate read throughput in floats/cycle (each partition's LSU
    /// reads one float per cycle — §III-C).
    pub fn read_floats_per_cycle(&self) -> u64 {
        self.partitions as u64
    }

    /// M20K blocks consumed. Every partition occupies at least one block
    /// (physical granularity) — this is why fine partitioning trades
    /// block-count for bandwidth.
    pub fn m20k_blocks(&self) -> u32 {
        let per_partition_bytes = self.floats_per_partition * F32_BYTES * self.buffers as u64;
        self.partitions * div_ceil(per_partition_bytes.max(1), M20K_BYTES) as u32
    }
}

/// A collection of FIFOs (the C accumulation store of §V).
#[derive(Clone, Debug)]
pub struct FifoSystem {
    pub name: String,
    pub fifos: u32,
    /// Depth of each FIFO in elements.
    pub depth: u64,
}

impl FifoSystem {
    /// The C block store: `d_i0·d_j0` FIFOs of depth
    /// `(d_i1/d_i0)·(d_j1/d_j0)`.
    pub fn for_c(di0: u32, dj0: u32, di1: u32, dj1: u32) -> Self {
        assert!(di1 % di0 == 0 && dj1 % dj0 == 0);
        Self {
            name: "C".into(),
            fifos: di0 * dj0,
            depth: ((di1 / di0) as u64) * ((dj1 / dj0) as u64),
        }
    }

    pub fn total_floats(&self) -> u64 {
        self.fifos as u64 * self.depth
    }

    pub fn m20k_blocks(&self) -> u32 {
        let per_fifo_bytes = self.depth * F32_BYTES;
        self.fifos * div_ceil(per_fifo_bytes.max(1), M20K_BYTES) as u32
    }
}

/// A software-simulated FIFO with FPGA-like semantics, used by the
/// cycle-accurate simulator (bounded, single-cycle enqueue/dequeue).
#[derive(Clone, Debug)]
pub struct SimFifo<T> {
    buf: std::collections::VecDeque<T>,
    capacity: usize,
}

impl<T> SimFifo<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { buf: std::collections::VecDeque::with_capacity(capacity), capacity }
    }

    pub fn push(&mut self, v: T) -> Result<(), T> {
        if self.buf.len() == self.capacity {
            Err(v) // full — hardware would stall the producer
        } else {
            self.buf.push_back(v);
            Ok(())
        }
    }

    pub fn pop(&mut self) -> Option<T> {
        self.buf.pop_front()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.buf.len() == self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a_system_partition_count_matches_paper() {
        // §V: the A mapped system has d_i0·d_k0 partitions.
        let a = MappedSystem::for_a(64, 2, 512);
        assert_eq!(a.partitions, 128);
        assert_eq!(a.load_units(), 128);
        assert_eq!(a.read_floats_per_cycle(), 128); // = B_A of eq. 10
        // Double-buffered column of 8 blocks.
        assert_eq!(a.floats_per_partition, 8);
        assert_eq!(a.total_floats(), 128 * 8 * 2);
    }

    #[test]
    fn b_system_symmetry() {
        let b = MappedSystem::for_b(2, 32, 512);
        assert_eq!(b.partitions, 64);
        assert_eq!(b.read_floats_per_cycle(), 64); // = B_B = dk0*dj0
    }

    #[test]
    fn c_fifo_geometry() {
        // Design G with d1=512: 64·32 FIFOs of depth 8·16=128.
        let c = FifoSystem::for_c(64, 32, 512, 512);
        assert_eq!(c.fifos, 2048);
        assert_eq!(c.depth, 128);
        assert_eq!(c.total_floats(), 512 * 512);
    }

    #[test]
    fn m20k_block_floor_one_per_partition() {
        // Tiny partitions still take a whole block each.
        let a = MappedSystem::for_a(8, 2, 16);
        assert_eq!(a.m20k_blocks(), 16);
    }

    #[test]
    fn sim_fifo_bounded() {
        let mut f = SimFifo::new(2);
        assert!(f.push(1).is_ok());
        assert!(f.push(2).is_ok());
        assert!(f.is_full());
        assert_eq!(f.push(3), Err(3));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), None);
        assert!(f.is_empty());
    }
}
