//! Memory-system substrate: the BittWare 520N's global DDR4 memory and
//! the Stratix 10 on-chip memory (M20K/MLAB), as the paper models them.
//!
//! * [`global`] — DDR4 channels, controller efficiency, the stall
//!   condition/rate of eqs. 2–3, and the reuse-ratio arithmetic of
//!   eq. 14.
//! * [`local`] — on-chip mapped and FIFO memory systems with user
//!   partitioning (§II-C): partition counts, block usage, per-partition
//!   LSUs.
//! * [`layout`] — matrix storage layouts (row/column-major, one- and
//!   two-level blocked) and the host-side reordering costs that §VI
//!   charges against the Intel SDK baseline.

pub mod ddr_sim;
pub mod global;
pub mod layout;
pub mod local;

pub use ddr_sim::{DdrChannelSim, DdrSimResult, DdrTiming};
pub use global::{DdrChannel, GlobalMemory, StallAnalysis};
pub use layout::{HostReorder, Layout};
pub use local::{FifoSystem, MappedSystem};
