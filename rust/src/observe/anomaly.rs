//! Anomaly localization: name the degraded cable or stalled card
//! from a recorded trace, without being told the fault plan.
//!
//! Two detectors, each keyed to a fault family the chaos harness
//! injects:
//!
//! * **Slow links** — the elastic controller samples a
//!   `link_rate a<->b` counter whenever a cable renegotiates (value =
//!   relative rate, 1.0 nominal). A cable whose observed rate ever
//!   drops below [`SLOW_LINK_RATE_THRESHOLD`] is flagged. Injected
//!   slow-link factors are ≥ 1.5 (rate ≤ 0.67), so the 0.75 threshold
//!   separates them from nominal cables with margin on both sides.
//! * **Stalled cards** — a queue spike holds a card's compute engine,
//!   which shows up as an interior gap between consecutive compute
//!   spans on that card's lane. The detector flags the card when its
//!   largest gap reaches the caller's threshold; a healthy pipelined
//!   card's gaps are ~0 (compute-bound) or one DMA (transfer-bound),
//!   both far under any sensible threshold.
//!
//! The z-score and EWMA helpers are the generic versions of the same
//! idea for gauges without a crisp physical threshold; the chaos
//! validation in `rust/tests/observe.rs` holds `localize` to exact
//! set equality against the injected faults — 100% recall and
//! precision — across seeds and topologies.

use crate::trace::{Track, TraceLog};

/// Cables whose observed relative rate drops below this are flagged.
pub const SLOW_LINK_RATE_THRESHOLD: f64 = 0.75;

/// A cable running below nominal rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkAnomaly {
    pub a: usize,
    pub b: usize,
    /// Worst observed relative rate (1.0 = nominal).
    pub rate: f64,
}

/// A card whose compute lane went quiet mid-run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CardAnomaly {
    pub card: usize,
    /// Largest interior gap between consecutive compute spans.
    pub gap_seconds: f64,
}

/// Everything the detectors flagged on one trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Anomalies {
    pub slow_links: Vec<LinkAnomaly>,
    pub stalled_cards: Vec<CardAnomaly>,
}

impl Anomalies {
    pub fn is_clean(&self) -> bool {
        self.slow_links.is_empty() && self.stalled_cards.is_empty()
    }

    /// Human-readable lines for the dashboard.
    pub fn render(&self) -> String {
        if self.is_clean() {
            return "anomalies: none\n".to_string();
        }
        let mut out = String::from("anomalies:\n");
        for l in &self.slow_links {
            out.push_str(&format!(
                "  slow link {}<->{} at {:.0}% of nominal rate\n",
                l.a,
                l.b,
                l.rate * 100.0
            ));
        }
        for c in &self.stalled_cards {
            out.push_str(&format!(
                "  card {} stalled for {:.2} s mid-run\n",
                c.card, c.gap_seconds
            ));
        }
        out
    }
}

/// Parse a `link_rate a<->b` counter name.
fn parse_link_rate(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix("link_rate ")?;
    let (a, b) = rest.split_once("<->")?;
    let (a, b) = (a.trim().parse().ok()?, b.trim().parse().ok()?);
    Some(if a <= b { (a, b) } else { (b, a) })
}

/// Run both detectors over a recorded trace. `gap_threshold_s` is the
/// stall detector's sensitivity — gaps at or above it flag the card.
pub fn localize(log: &TraceLog, gap_threshold_s: f64) -> Anomalies {
    use std::collections::BTreeMap;
    // Slow links: worst observed rate per (normalized) cable.
    let mut worst: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    for c in &log.counters {
        if let Some(key) = parse_link_rate(&c.name) {
            let w = worst.entry(key).or_insert(f64::INFINITY);
            *w = w.min(c.value);
        }
    }
    let slow_links = worst
        .into_iter()
        .filter(|&(_, rate)| rate < SLOW_LINK_RATE_THRESHOLD)
        .map(|((a, b), rate)| LinkAnomaly { a, b, rate })
        .collect();
    // Stalled cards: largest interior gap on each compute lane.
    let mut stalled_cards = Vec::new();
    for track in log.tracks() {
        let Track::CardCompute(card) = track else { continue };
        let spans = log.spans_on(track);
        let mut gap = 0.0f64;
        for w in spans.windows(2) {
            gap = gap.max(w[1].start - w[0].end);
        }
        if gap >= gap_threshold_s {
            stalled_cards.push(CardAnomaly { card, gap_seconds: gap });
        }
    }
    Anomalies { slow_links, stalled_cards }
}

/// Z-scores of `values` against their own mean and population
/// standard deviation (all zeros when the spread is zero).
pub fn zscores(values: &[f64]) -> Vec<f64> {
    if values.is_empty() {
        return Vec::new();
    }
    let n = values.len() as f64;
    let mean = values.iter().sum::<f64>() / n;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    let std = var.sqrt();
    if std <= 0.0 {
        return vec![0.0; values.len()];
    }
    values.iter().map(|v| (v - mean) / std).collect()
}

/// Exponentially weighted moving average with smoothing `alpha`
/// (higher = more reactive).
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0, 1]");
        Self { alpha, value: None }
    }

    /// Fold in one observation and return the smoothed value.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Category, Tracer};

    #[test]
    fn link_rate_names_parse_and_normalize() {
        assert_eq!(parse_link_rate("link_rate 3<->7"), Some((3, 7)));
        assert_eq!(parse_link_rate("link_rate 7<->3"), Some((3, 7)));
        assert_eq!(parse_link_rate("queue_depth"), None);
        assert_eq!(parse_link_rate("link_rate x<->3"), None);
    }

    #[test]
    fn localize_names_the_injected_cable_and_card() {
        let t = Tracer::recording();
        // Card 0: healthy back-to-back spans. Card 1: a 2 s hole.
        t.span(Track::CardCompute(0), Category::Compute, || "a".into(), 0.0, 1.0);
        t.span(Track::CardCompute(0), Category::Compute, || "b".into(), 1.0, 2.0);
        t.span(Track::CardCompute(1), Category::Compute, || "a".into(), 0.0, 1.0);
        t.span(Track::CardCompute(1), Category::Compute, || "b".into(), 3.0, 4.0);
        t.counter("link_rate 0<->1", 0.5, 0.4);
        t.counter("link_rate 1<->2", 0.6, 0.95);
        let log = t.take();
        let found = localize(&log, 1.0);
        assert_eq!(found.slow_links, vec![LinkAnomaly { a: 0, b: 1, rate: 0.4 }]);
        assert_eq!(found.stalled_cards, vec![CardAnomaly { card: 1, gap_seconds: 2.0 }]);
        assert!(!found.is_clean());
        let text = found.render();
        assert!(text.contains("slow link 0<->1"));
        assert!(text.contains("card 1 stalled"));
    }

    #[test]
    fn clean_trace_raises_nothing() {
        let t = Tracer::recording();
        t.span(Track::CardCompute(0), Category::Compute, || "a".into(), 0.0, 1.0);
        t.span(Track::CardCompute(0), Category::Compute, || "b".into(), 1.1, 2.1);
        t.counter("link_rate 0<->1", 0.5, 1.0);
        let log = t.take();
        let found = localize(&log, 1.0);
        assert!(found.is_clean());
        assert_eq!(found.render(), "anomalies: none\n");
    }

    #[test]
    fn zscore_and_ewma_flag_the_outlier() {
        let z = zscores(&[1.0, 1.0, 1.0, 1.0, 9.0]);
        assert!(z[4] > 1.9, "the spike stands out: {z:?}");
        assert!(z[0] < 0.0);
        assert_eq!(zscores(&[2.0, 2.0, 2.0]), vec![0.0, 0.0, 0.0]);
        assert!(zscores(&[]).is_empty());
        let mut e = Ewma::new(0.5);
        assert_eq!(e.value(), None);
        assert_eq!(e.update(4.0), 4.0, "first observation seeds the average");
        assert_eq!(e.update(8.0), 6.0);
        assert!(e.value().unwrap() > 4.0);
    }
}
