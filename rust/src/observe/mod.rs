//! Live fleet observatory: sliding-window telemetry, SLO burn-rate
//! alerts, and chaos-validated anomaly localization.
//!
//! The flight recorder ([`crate::trace`]) answers "what happened" after
//! a run; this module answers "what is happening" while one unfolds,
//! and exposes it three ways:
//!
//! * **Series** ([`series`]) — bounded ring-buffer time series sampled
//!   in *simulated* time from the recorder's spans and counters:
//!   per-card busy fraction, per-link utilization, queue depth,
//!   windowed goodput, and sliding-window latency quantiles built by
//!   merging per-window [`LogHistogram`]s. [`Observatory::from_trace`]
//!   derives the whole registry from any recorded [`TraceLog`], so the
//!   same dashboard works on a live controller's tracer or a replayed
//!   seed.
//! * **SLOs** ([`slo`]) — declarative objectives evaluated as
//!   multi-window burn rates. The online form ([`slo::BurnMonitor`])
//!   rides inside [`crate::cluster::FleetController`]: sustained p99
//!   burn grows the fleet even when raw queue depth looks healthy.
//! * **Anomalies** ([`anomaly`]) — detectors that *name* the degraded
//!   cable or stalled card from the trace alone, held to exact set
//!   equality against injected [`crate::cluster::FaultPlan`] faults by
//!   the chaos validation suite.
//!
//! Exposition rounds it out: [`prometheus_text`] and [`json_snapshot`]
//! render a [`MetricsSnapshot`] in Prometheus text format / JSON for
//! scraping, and `systo3d top` draws the ASCII dashboard. Across
//! runs, [`trend`] reads the `BENCH_pr<N>.json` artifacts CI uploads
//! and reports each gated metric's per-PR trajectory (`systo3d
//! trend`).

pub mod anomaly;
pub mod series;
pub mod slo;
pub mod trend;

use crate::coordinator::metrics::MetricsSnapshot;
use crate::trace::{Track, TraceLog};
use crate::util::stats::LogHistogram;
use series::Series;

/// Windows merged for the sliding latency quantile.
const SLIDE_WINDOWS: usize = 4;

/// The derived time-series registry of one run.
#[derive(Clone, Debug)]
pub struct Observatory {
    /// Sampling window in simulated seconds.
    pub window_s: f64,
    /// Makespan of the trace the registry was derived from.
    pub makespan_s: f64,
    /// Per-card compute-busy fraction per window (index = card id;
    /// cards that never computed — idle spares — hold empty series).
    pub card_busy: Vec<Series>,
    /// Per directed link (a→b): circuit-hold fraction per window.
    pub link_util: Vec<((usize, usize), Series)>,
    /// The controller's `queue_depth` counter, sample for sample.
    pub queue_depth: Series,
    /// Shards completed per second, per window.
    pub goodput: Series,
    /// One latency histogram per window (from the `shard_latency_s`
    /// counter), the base for sliding quantiles.
    pub latency_windows: Vec<LogHistogram>,
    /// p99 over the last [`SLIDE_WINDOWS`] windows, one sample per
    /// window that saw traffic.
    pub latency_p99: Series,
}

impl Observatory {
    /// Derive the registry from a recorded trace, binned into
    /// `window_s`-wide windows of simulated time.
    pub fn from_trace(log: &TraceLog, window_s: f64) -> Self {
        assert!(window_s > 0.0, "window must be positive");
        let makespan_s = log.makespan();
        let windows = ((makespan_s / window_s).ceil() as usize).max(1);
        let bin_of = |at: f64| ((at / window_s) as usize).min(windows - 1);
        let bin_end = |w: usize| (w + 1) as f64 * window_s;

        // Per-card busy and per-link utilization: span overlap per bin.
        let mut max_card = None;
        for t in log.tracks() {
            if let Track::CardCompute(c) = t {
                max_card = Some(max_card.map_or(c, |m: usize| m.max(c)));
            }
        }
        let cards = max_card.map_or(0, |m| m + 1);
        let mut card_busy: Vec<Series> =
            (0..cards).map(|c| Series::new(format!("card{c}_busy"), windows)).collect();
        let mut link_util: Vec<((usize, usize), Series)> = Vec::new();
        for track in log.tracks() {
            let (fractions, target): (Vec<f64>, &mut Series) = match track {
                Track::CardCompute(c) => {
                    (binned_overlap(log, track, window_s, windows), &mut card_busy[c])
                }
                Track::Link(a, b) => {
                    link_util.push((
                        (a, b),
                        Series::new(format!("link{a}->{b}_util"), windows),
                    ));
                    let s = &mut link_util.last_mut().expect("just pushed").1;
                    (binned_overlap(log, track, window_s, windows), s)
                }
                _ => continue,
            };
            for (w, f) in fractions.into_iter().enumerate() {
                target.push(bin_end(w), f / window_s);
            }
        }

        // Counters: queue depth verbatim, latencies into per-window
        // histograms.
        let n_depth = log.counters.iter().filter(|c| c.name == "queue_depth").count();
        let mut queue_depth = Series::new("queue_depth", n_depth.max(1));
        let mut latency_windows = vec![LogHistogram::new(); windows];
        for c in &log.counters {
            match c.name.as_str() {
                "queue_depth" => queue_depth.push(c.at, c.value),
                "shard_latency_s" => latency_windows[bin_of(c.at)].record(c.value),
                _ => {}
            }
        }

        // Goodput: compute-span completions per second, per window.
        let mut done = vec![0usize; windows];
        for track in log.tracks() {
            if let Track::CardCompute(_) = track {
                for s in log.spans_on(track) {
                    done[bin_of(s.end)] += 1;
                }
            }
        }
        let mut goodput = Series::new("goodput_shards_per_s", windows);
        for (w, &n) in done.iter().enumerate() {
            goodput.push(bin_end(w), n as f64 / window_s);
        }

        // Sliding p99: merge the trailing SLIDE_WINDOWS histograms.
        let mut latency_p99 = Series::new("latency_p99_s", windows);
        for w in 0..windows {
            let mut merged = LogHistogram::new();
            for h in &latency_windows[w.saturating_sub(SLIDE_WINDOWS - 1)..=w] {
                merged.merge(h);
            }
            if !merged.is_empty() {
                latency_p99.push(bin_end(w), merged.quantile(0.99));
            }
        }

        Self {
            window_s,
            makespan_s,
            card_busy,
            link_util,
            queue_depth,
            goodput,
            latency_windows,
            latency_p99,
        }
    }

    /// Sliding quantile `q` over the trailing `k` windows (the p99
    /// field is this with `q = 0.99`, `k = SLIDE_WINDOWS`).
    pub fn sliding_quantile(&self, q: f64, k: usize) -> Series {
        let k = k.max(1);
        let mut out = Series::new(format!("latency_q{q}_s"), self.latency_windows.len().max(1));
        for w in 0..self.latency_windows.len() {
            let mut merged = LogHistogram::new();
            for h in &self.latency_windows[w.saturating_sub(k - 1)..=w] {
                merged.merge(h);
            }
            if !merged.is_empty() {
                out.push((w + 1) as f64 * self.window_s, merged.quantile(q));
            }
        }
        out
    }

    /// Windowed throughput in GFLOPS given the FLOPs one shard
    /// carries (goodput is shape-agnostic; the caller knows the plan).
    pub fn gflops(&self, flops_per_shard: f64) -> Series {
        let mut out = Series::new("gflops", self.goodput.len().max(1));
        for (at, v) in self.goodput.iter() {
            out.push(at, v * flops_per_shard / 1e9);
        }
        out
    }

    /// The ASCII dashboard `systo3d top` renders: one sparkline per
    /// gauge, `width` cells wide.
    pub fn render_dashboard(&self, width: usize) -> String {
        let mut out = format!(
            "fleet observatory: makespan {:.3} s, {} window(s) of {:.3} s\n",
            self.makespan_s,
            self.latency_windows.len(),
            self.window_s
        );
        let line = |name: &str, s: &Series, unit: &str| match s.latest() {
            Some((_, v)) => format!("  {name:<14} |{}| last {v:.3}{unit}\n", s.sparkline(width)),
            None => format!("  {name:<14} |{}| (no samples)\n", s.sparkline(width)),
        };
        for (c, s) in self.card_busy.iter().enumerate() {
            out.push_str(&line(&format!("card {c} busy"), s, ""));
        }
        for ((a, b), s) in &self.link_util {
            out.push_str(&line(&format!("link {a}->{b}"), s, ""));
        }
        out.push_str(&line("queue depth", &self.queue_depth, ""));
        out.push_str(&line("goodput", &self.goodput, " shard/s"));
        out.push_str(&line("latency p99", &self.latency_p99, " s"));
        out
    }
}

/// Seconds of `track`'s spans overlapping each window.
fn binned_overlap(log: &TraceLog, track: Track, window_s: f64, windows: usize) -> Vec<f64> {
    let mut acc = vec![0.0f64; windows];
    for s in log.spans_on(track) {
        let lo = ((s.start / window_s) as usize).min(windows - 1);
        let hi = ((s.end / window_s) as usize).min(windows - 1);
        for (w, slot) in acc.iter_mut().enumerate().take(hi + 1).skip(lo) {
            let bin = (w as f64 * window_s, (w + 1) as f64 * window_s);
            *slot += (s.end.min(bin.1) - s.start.max(bin.0)).max(0.0);
        }
    }
    acc
}

/// Render a metrics snapshot in the Prometheus text exposition
/// format: `# HELP` / `# TYPE` preamble per family, no timestamps
/// (the scraper stamps).
///
/// The exposition is **deterministic by construction**: families are
/// collected first, then emitted in sorted family-name order with the
/// samples of each labeled family sorted by label string — so two
/// renders of the same [`MetricsSnapshot`] are byte-identical (the
/// test below compares the bytes), and exposition diffs in scrape
/// archives always mean the metrics moved, never the iteration order.
pub fn prometheus_text(s: &MetricsSnapshot) -> String {
    // (family name, type, help, samples as (label-suffix, value)).
    let mut families: Vec<(&'static str, &'static str, &'static str, Vec<(String, u64)>)> =
        Vec::new();
    let mut counter = |name: &'static str, help: &'static str, value: u64| {
        families.push((name, "counter", help, vec![(String::new(), value)]));
    };
    counter("requests_total", "GEMM requests served", s.requests);
    counter("artifact_hits_total", "requests served by an AOT artifact", s.artifact_hits);
    counter("fallbacks_total", "requests served by the in-process fallback", s.fallbacks);
    counter("batches_total", "engine batches executed", s.batches);
    counter("errors_total", "requests that failed", s.errors);
    counter("flops_total", "FLOPs served (paper convention)", s.flops);
    counter("sharded_jobs_total", "requests routed to the cluster", s.sharded_jobs);
    counter("shards_executed_total", "sub-GEMM shards executed", s.shards_executed);
    counter("cluster_steals_total", "shards migrated by work-stealing", s.cluster_steals);
    counter("cluster_busy_us_total", "fleet compute-busy time (us)", s.cluster_busy_us);
    counter("cluster_makespan_us_total", "cluster makespan total (us)", s.cluster_makespan_us);
    counter("fabric_reduction_us_total", "reduction circuit time (us)", s.fabric_reduction_us);
    counter(
        "fabric_reduction_overlap_us_total",
        "reduction time hidden under compute (us)",
        s.fabric_reduction_overlap_us,
    );
    counter("fabric_link_busy_us_total", "directed-link busy time (us)", s.fabric_link_busy_us);
    counter(
        "fabric_link_capacity_us_total",
        "directed-link capacity base (us)",
        s.fabric_link_capacity_us,
    );
    counter(
        "placement_identity_hop_bytes_total",
        "reduction hop-bytes under identity placement",
        s.placement_identity_hop_bytes,
    );
    counter(
        "placement_placed_hop_bytes_total",
        "reduction hop-bytes as placed",
        s.placement_placed_hop_bytes,
    );
    counter("placement_search_us_total", "placement search time (us)", s.placement_search_us);
    counter(
        "elastic_spare_activations_total",
        "hot spares activated for dead cards",
        s.elastic_spare_activations,
    );
    counter("elastic_drains_completed_total", "drains completed", s.elastic_drains_completed);
    counter("elastic_drain_us_total", "activation-to-drain spans (us)", s.elastic_drain_us);
    counter("elastic_grown_cards_total", "cards attached by growth", s.elastic_grown_cards);
    counter(
        "post_grow_identity_hop_bytes_total",
        "queued hop-bytes before growth rebalance",
        s.post_grow_identity_hop_bytes,
    );
    counter(
        "post_grow_placed_hop_bytes_total",
        "queued hop-bytes after growth rebalance",
        s.post_grow_placed_hop_bytes,
    );
    counter("strassen_jobs_total", "requests served by the Strassen route", s.strassen_jobs);
    counter("admitted_total", "requests admitted by admission control", s.admitted);
    counter("shed_total", "requests shed by admission control", s.shed);
    counter("deadline_met_total", "served requests that met their deadline", s.deadline_met);
    counter("deadline_missed_total", "served requests past their deadline", s.deadline_missed);
    counter("goodput_flops_total", "FLOPs of deadline-met work", s.goodput_flops);
    counter(
        "strassen_eff_vs_peak_ppm_total",
        "accumulated effective-vs-peak ratio (ppm)",
        s.strassen_eff_vs_peak_ppm,
    );
    families.push((
        "strassen_depth_jobs",
        "counter",
        "Strassen jobs by recursion depth",
        s.strassen_depths
            .iter()
            .enumerate()
            .map(|(d, &n)| (format!("{{depth=\"{d}\"}}"), n))
            .collect(),
    ));
    families.push((
        "critical_path_us",
        "counter",
        "Critical-path attribution by bucket (us)",
        crate::trace::critical::BUCKETS
            .iter()
            .zip(s.critical_bucket_us)
            .map(|(bucket, us)| (format!("{{bucket=\"{bucket}\"}}"), us))
            .collect(),
    ));
    families.push((
        "tenant_requests_total",
        "counter",
        "requests per tenant gauge slot",
        s.tenant_requests
            .iter()
            .enumerate()
            .map(|(i, &n)| (format!("{{slot=\"{i}\"}}"), n))
            .collect(),
    ));
    families.push((
        "tenant_p99_us",
        "gauge",
        "per-tenant-slot latency p99 (us, 0 when unsampled)",
        s.tenant_p99_us
            .iter()
            .enumerate()
            .map(|(i, &us)| (format!("{{slot=\"{i}\"}}"), us))
            .collect(),
    ));
    let mut gauge = |name: &'static str, help: &'static str, value: u64| {
        families.push((name, "gauge", help, vec![(String::new(), value)]));
    };
    gauge("latency_p50_us", "request latency p50 (us, 0 when unsampled)", s.latency_p50_us);
    gauge("latency_p99_us", "request latency p99 (us, 0 when unsampled)", s.latency_p99_us);
    gauge("latency_p999_us", "request latency p99.9 (us, 0 when unsampled)", s.latency_p999_us);
    gauge("latency_count", "latency samples recorded", s.latency_count);

    families.sort_by(|a, b| a.0.cmp(b.0));
    let mut out = String::with_capacity(4096);
    for (name, kind, help, mut samples) in families {
        out.push_str(&format!(
            "# HELP systo3d_{name} {help}\n# TYPE systo3d_{name} {kind}\n"
        ));
        samples.sort_by(|a, b| a.0.cmp(&b.0));
        for (labels, value) in samples {
            out.push_str(&format!("systo3d_{name}{labels} {value}\n"));
        }
    }
    out
}

/// Render a metrics snapshot as one stable JSON object (hand-rolled:
/// u64 fields and fixed arrays only, so no escaping is ever needed).
pub fn json_snapshot(s: &MetricsSnapshot) -> String {
    let arr = |xs: &[u64]| {
        let inner: Vec<String> = xs.iter().map(u64::to_string).collect();
        format!("[{}]", inner.join(","))
    };
    let fields: Vec<(&str, String)> = vec![
        ("requests", s.requests.to_string()),
        ("artifact_hits", s.artifact_hits.to_string()),
        ("fallbacks", s.fallbacks.to_string()),
        ("batches", s.batches.to_string()),
        ("errors", s.errors.to_string()),
        ("flops", s.flops.to_string()),
        ("sharded_jobs", s.sharded_jobs.to_string()),
        ("shards_executed", s.shards_executed.to_string()),
        ("cluster_steals", s.cluster_steals.to_string()),
        ("cluster_busy_us", s.cluster_busy_us.to_string()),
        ("cluster_makespan_us", s.cluster_makespan_us.to_string()),
        ("fabric_reduction_us", s.fabric_reduction_us.to_string()),
        ("fabric_reduction_overlap_us", s.fabric_reduction_overlap_us.to_string()),
        ("fabric_link_busy_us", s.fabric_link_busy_us.to_string()),
        ("fabric_link_capacity_us", s.fabric_link_capacity_us.to_string()),
        ("placement_identity_hop_bytes", s.placement_identity_hop_bytes.to_string()),
        ("placement_placed_hop_bytes", s.placement_placed_hop_bytes.to_string()),
        ("placement_search_us", s.placement_search_us.to_string()),
        ("elastic_spare_activations", s.elastic_spare_activations.to_string()),
        ("elastic_drains_completed", s.elastic_drains_completed.to_string()),
        ("elastic_drain_us", s.elastic_drain_us.to_string()),
        ("elastic_grown_cards", s.elastic_grown_cards.to_string()),
        ("post_grow_identity_hop_bytes", s.post_grow_identity_hop_bytes.to_string()),
        ("post_grow_placed_hop_bytes", s.post_grow_placed_hop_bytes.to_string()),
        ("strassen_jobs", s.strassen_jobs.to_string()),
        ("strassen_depths", arr(&s.strassen_depths)),
        ("strassen_eff_vs_peak_ppm", s.strassen_eff_vs_peak_ppm.to_string()),
        ("latency_p50_us", s.latency_p50_us.to_string()),
        ("latency_p99_us", s.latency_p99_us.to_string()),
        ("latency_p999_us", s.latency_p999_us.to_string()),
        ("latency_count", s.latency_count.to_string()),
        ("critical_bucket_us", arr(&s.critical_bucket_us)),
        ("admitted", s.admitted.to_string()),
        ("shed", s.shed.to_string()),
        ("deadline_met", s.deadline_met.to_string()),
        ("deadline_missed", s.deadline_missed.to_string()),
        ("goodput_flops", s.goodput_flops.to_string()),
        ("tenant_requests", arr(&s.tenant_requests)),
        ("tenant_p99_us", arr(&s.tenant_p99_us)),
    ];
    let inner: Vec<String> =
        fields.into_iter().map(|(k, v)| format!("\"{k}\":{v}")).collect();
    format!("{{{}}}", inner.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use crate::trace::{Category, Tracer};

    fn sample_trace() -> TraceLog {
        let t = Tracer::recording();
        // Card 0 computes 0.0-1.0 and 1.5-2.0; card 1 only 0.5-1.0.
        t.span(Track::CardCompute(0), Category::Compute, || "s0".into(), 0.0, 1.0);
        t.span(Track::CardCompute(0), Category::Compute, || "s1".into(), 1.5, 2.0);
        t.span(Track::CardCompute(1), Category::Compute, || "s2".into(), 0.5, 1.0);
        t.span(Track::Link(0, 1), Category::Fabric, || "c".into(), 0.0, 0.5);
        t.counter("queue_depth", 0.0, 3.0);
        t.counter("queue_depth", 1.0, 1.0);
        t.counter("shard_latency_s", 0.9, 1.0);
        t.counter("shard_latency_s", 2.0, 0.5);
        t.take()
    }

    #[test]
    fn observatory_bins_spans_and_counters_into_windows() {
        let obs = Observatory::from_trace(&sample_trace(), 1.0);
        assert_eq!(obs.latency_windows.len(), 2, "2 s makespan, 1 s windows");
        // Card 0: fully busy in window 0, half busy in window 1.
        let w: Vec<(f64, f64)> = obs.card_busy[0].iter().collect();
        assert_eq!(w.len(), 2);
        assert!((w[0].1 - 1.0).abs() < 1e-9, "{w:?}");
        assert!((w[1].1 - 0.5).abs() < 1e-9, "{w:?}");
        // Card 1 was half busy then idle.
        let w: Vec<(f64, f64)> = obs.card_busy[1].iter().collect();
        assert!((w[0].1 - 0.5).abs() < 1e-9 && w[1].1 == 0.0, "{w:?}");
        // The link held a circuit for half of window 0.
        assert_eq!(obs.link_util.len(), 1);
        assert_eq!(obs.link_util[0].0, (0, 1));
        let (_, v) = obs.link_util[0].1.iter().next().unwrap();
        assert!((v - 0.5).abs() < 1e-9);
        // Counters land sample-for-sample / window-for-window.
        assert_eq!(obs.queue_depth.len(), 2);
        assert_eq!(obs.queue_depth.latest(), Some((1.0, 1.0)));
        assert_eq!(obs.latency_windows[0].count(), 1);
        assert_eq!(obs.latency_windows[1].count(), 1);
        // Goodput: 2 spans end in window 0 (ends 1.0 bins into window
        // 0? no — bin_of(1.0) = 1), so check totals instead.
        let total: f64 = obs.goodput.iter().map(|(_, v)| v).sum::<f64>() * obs.window_s;
        assert!((total - 3.0).abs() < 1e-9, "all three spans complete");
        // Sliding p99 merges both windows at the end.
        let (_, p99) = obs.latency_p99.latest().expect("latency sampled");
        assert!(p99 >= 0.9, "p99 tracks the slow window: {p99}");
        let dash = obs.render_dashboard(16);
        assert!(dash.contains("card 0 busy"));
        assert!(dash.contains("queue depth"));
        assert!(dash.contains("latency p99"));
        // GFLOPS is goodput scaled by per-shard FLOPs.
        let g = obs.gflops(2e9);
        assert!(g.max().unwrap() > 0.0);
    }

    #[test]
    fn exposition_renders_every_field_once() {
        let m = Metrics::new();
        Metrics::inc(&m.requests);
        m.add_flops(12345);
        m.record_latency(0.002);
        let s = m.snapshot();
        let text = prometheus_text(&s);
        assert!(text.contains("# TYPE systo3d_requests_total counter"));
        assert!(text.contains("systo3d_requests_total 1\n"));
        assert!(text.contains("systo3d_flops_total 12345\n"));
        assert!(text.contains("systo3d_latency_p99_us 2000\n"));
        assert!(text.contains("systo3d_strassen_depth_jobs{depth=\"0\"} 0\n"));
        assert!(text.contains("systo3d_critical_path_us{bucket=\"compute\"} 0\n"));
        // Every line is either a comment or `name[{labels}] value`.
        for line in text.lines() {
            assert!(
                line.starts_with("# ") || line.starts_with("systo3d_"),
                "malformed line {line:?}"
            );
        }
        let json = json_snapshot(&s);
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"requests\":1"));
        assert!(json.contains("\"flops\":12345"));
        assert!(json.contains("\"strassen_depths\":[0,0,0,0]"));
        assert!(json.contains("\"latency_count\":1"));
        assert_eq!(json.matches("\"latency_p99_us\":").count(), 1);
    }

    #[test]
    fn exposition_carries_the_serving_gauges() {
        let m = Metrics::new();
        Metrics::add(&m.admitted, 9);
        Metrics::add(&m.shed, 1);
        Metrics::add(&m.deadline_met, 8);
        Metrics::inc(&m.deadline_missed);
        Metrics::add(&m.goodput_flops, 777);
        m.record_tenant_latency("gold", 0.003);
        m.record_tenant_latency("bronze", 0.030);
        let s = m.snapshot();
        let text = prometheus_text(&s);
        assert!(text.contains("systo3d_admitted_total 9\n"));
        assert!(text.contains("systo3d_shed_total 1\n"));
        assert!(text.contains("systo3d_deadline_met_total 8\n"));
        assert!(text.contains("systo3d_deadline_missed_total 1\n"));
        assert!(text.contains("systo3d_goodput_flops_total 777\n"));
        assert!(text.contains("systo3d_tenant_requests_total{slot=\"0\"} 1\n"));
        assert!(text.contains("systo3d_tenant_requests_total{slot=\"2\"} 0\n"));
        assert!(text.contains("systo3d_tenant_p99_us{slot=\"1\"}"));
        let json = json_snapshot(&s);
        assert!(json.contains("\"admitted\":9"));
        assert!(json.contains("\"shed\":1"));
        assert!(json.contains("\"goodput_flops\":777"));
        assert!(json.contains("\"tenant_requests\":[1,1,0,0]"));
    }

    #[test]
    fn exposition_is_byte_identical_and_sorted() {
        let m = Metrics::new();
        Metrics::inc(&m.requests);
        m.add_flops(999);
        m.record_latency(0.004);
        let s = m.snapshot();
        // Two renders of the same snapshot are byte-identical.
        assert_eq!(prometheus_text(&s).into_bytes(), prometheus_text(&s).into_bytes());
        // Families are emitted in sorted name order…
        let text = prometheus_text(&s);
        let names: Vec<&str> = text
            .lines()
            .filter_map(|l| l.strip_prefix("# TYPE systo3d_"))
            .map(|l| l.split_whitespace().next().unwrap())
            .collect();
        assert!(!names.is_empty());
        assert!(names.windows(2).all(|w| w[0] < w[1]), "unsorted families: {names:?}");
        // …and labeled samples in sorted label order within a family.
        let buckets: Vec<&str> =
            text.lines().filter(|l| l.starts_with("systo3d_critical_path_us{")).collect();
        assert_eq!(buckets.len(), 5);
        assert!(buckets.windows(2).all(|w| w[0] < w[1]), "unsorted labels: {buckets:?}");
    }
}
