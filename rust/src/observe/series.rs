//! Fixed-capacity time series: the ring buffers behind the fleet
//! observatory.
//!
//! A [`Series`] holds the most recent `cap` (time, value) samples of
//! one gauge — per-card busy fraction, per-link utilization, queue
//! depth, windowed goodput. Memory is bounded by construction: when
//! the ring is full the oldest sample falls off and a drop counter
//! ticks, so a dashboard can say "showing the last N windows" rather
//! than silently truncating. Rendering is deliberately dumb ASCII —
//! [`Series::sparkline`] maps the series onto a fixed character ramp
//! so `systo3d top` works on any terminal.

use std::collections::VecDeque;

/// Density ramp for sparklines, lightest to darkest.
const RAMP: &[u8] = b" .:-=+*#%@";

/// One bounded gauge history.
#[derive(Clone, Debug)]
pub struct Series {
    name: String,
    cap: usize,
    samples: VecDeque<(f64, f64)>,
    dropped: usize,
}

impl Series {
    pub fn new(name: impl Into<String>, cap: usize) -> Self {
        assert!(cap > 0, "a series needs capacity for at least one sample");
        Self { name: name.into(), cap, samples: VecDeque::with_capacity(cap), dropped: 0 }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Samples the ring has forgotten.
    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// Append a sample, evicting the oldest when full.
    pub fn push(&mut self, at: f64, value: f64) {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
            self.dropped += 1;
        }
        self.samples.push_back((at, value));
    }

    /// Oldest-to-newest iteration.
    pub fn iter(&self) -> impl Iterator<Item = (f64, f64)> + '_ {
        self.samples.iter().copied()
    }

    pub fn latest(&self) -> Option<(f64, f64)> {
        self.samples.back().copied()
    }

    pub fn min(&self) -> Option<f64> {
        self.samples.iter().map(|&(_, v)| v).reduce(f64::min)
    }

    pub fn max(&self) -> Option<f64> {
        self.samples.iter().map(|&(_, v)| v).reduce(f64::max)
    }

    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let sum: f64 = self.samples.iter().map(|&(_, v)| v).sum();
        Some(sum / self.samples.len() as f64)
    }

    /// Render the series as `width` ramp characters: each cell is the
    /// mean of the samples that fall into its share of the ring (by
    /// position, not wall time — the observatory samples on a fixed
    /// cadence, so position is time). A flat series renders as the
    /// middle ramp character; an empty one as spaces.
    pub fn sparkline(&self, width: usize) -> String {
        if width == 0 {
            return String::new();
        }
        if self.samples.is_empty() {
            return " ".repeat(width);
        }
        let (lo, hi) = (self.min().expect("nonempty"), self.max().expect("nonempty"));
        let n = self.samples.len();
        let mut out = String::with_capacity(width);
        for cell in 0..width {
            let a = cell * n / width;
            let b = ((cell + 1) * n / width).max(a + 1).min(n);
            let mean: f64 =
                self.samples.range(a..b).map(|&(_, v)| v).sum::<f64>() / (b - a) as f64;
            let idx = if hi > lo {
                let norm = ((mean - lo) / (hi - lo)).clamp(0.0, 1.0);
                (norm * (RAMP.len() - 1) as f64).round() as usize
            } else {
                RAMP.len() / 2
            };
            out.push(RAMP[idx] as char);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let mut s = Series::new("g", 3);
        for i in 0..5 {
            s.push(i as f64, i as f64 * 10.0);
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.dropped(), 2);
        let kept: Vec<f64> = s.iter().map(|(at, _)| at).collect();
        assert_eq!(kept, vec![2.0, 3.0, 4.0]);
        assert_eq!(s.latest(), Some((4.0, 40.0)));
        assert_eq!(s.min(), Some(20.0));
        assert_eq!(s.max(), Some(40.0));
        assert_eq!(s.mean(), Some(30.0));
        assert_eq!(s.name(), "g");
    }

    #[test]
    fn empty_series_reads_as_absent_not_zero() {
        let s = Series::new("empty", 4);
        assert!(s.is_empty());
        assert_eq!(s.latest(), None);
        assert_eq!(s.min(), None);
        assert_eq!(s.max(), None);
        assert_eq!(s.mean(), None);
        assert_eq!(s.sparkline(5), "     ");
    }

    #[test]
    fn sparkline_ramps_with_the_data() {
        let mut s = Series::new("ramp", 16);
        for i in 0..16 {
            s.push(i as f64, i as f64);
        }
        let line = s.sparkline(8);
        assert_eq!(line.len(), 8);
        assert!(line.starts_with(' '), "lowest cell uses the lightest glyph: {line:?}");
        assert!(line.ends_with('@'), "highest cell uses the darkest glyph: {line:?}");
        let ramp = |c: char| RAMP.iter().position(|&r| r as char == c).unwrap();
        let idxs: Vec<usize> = line.chars().map(ramp).collect();
        assert!(idxs.windows(2).all(|w| w[0] <= w[1]), "monotone data renders monotone: {line:?}");
        // Flat data renders flat at the middle of the ramp.
        let mut flat = Series::new("flat", 4);
        for i in 0..4 {
            flat.push(i as f64, 7.0);
        }
        let mid = RAMP[RAMP.len() / 2] as char;
        assert_eq!(flat.sparkline(4), mid.to_string().repeat(4));
        // Width larger than the sample count still fills every cell.
        assert_eq!(flat.sparkline(9).len(), 9);
    }
}
