//! Declarative service-level objectives evaluated as multi-window
//! burn rates.
//!
//! Two consumers share the same arithmetic:
//!
//! * **Online** — [`BurnMonitor`] rides inside the elastic controller:
//!   the scheduler records every shard's latency at commit time and
//!   the monitor answers "is the p99 objective burning in both the
//!   short and the long window right now?" at each scheduling
//!   instant. A sustained burn (both windows over threshold) is the
//!   alert that drives spare activation or fabric growth — the point
//!   of the two-window rule is the classic one: the short window
//!   catches the onset fast, the long window stops a single straggler
//!   from paging the fleet.
//! * **Offline** — [`SloSpec::alerts`] replays the same rule over any
//!   recorded [`Series`] (latency, goodput, queue depth), so the
//!   observatory can grade a finished trace against the objectives it
//!   would have alerted on live.
//!
//! Burn here is the *fraction of samples violating the objective*
//! inside a window — for a p99 objective a window is burning when
//! more than `burn_threshold` of its samples exceed the target, i.e.
//! the error budget (1% for p99) is being spent `burn_threshold/1%`
//! times too fast.

use super::series::Series;

/// The latency SLO the elastic controller grows against.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloPolicy {
    /// Per-shard latency target (DMA start to compute end) the p99
    /// objective holds.
    pub p99_latency_s: f64,
    /// Short evaluation window; also the cooldown between two growth
    /// actions.
    pub window_s: f64,
    /// The long window spans `long_windows` short windows.
    pub long_windows: usize,
    /// A window burns when the violating fraction reaches this.
    pub burn_threshold: f64,
    /// Cards the controller may add on SLO alerts across the run
    /// (spares activated and cards attached both count).
    pub max_growth: usize,
}

impl Default for SloPolicy {
    fn default() -> Self {
        Self {
            p99_latency_s: 1.0,
            window_s: 1.0,
            long_windows: 4,
            burn_threshold: 0.25,
            max_growth: 2,
        }
    }
}

/// Sliding-window burn evaluator over (time, latency) samples.
#[derive(Clone, Debug)]
pub struct BurnMonitor {
    policy: SloPolicy,
    samples: Vec<(f64, f64)>,
    high_water: f64,
}

impl BurnMonitor {
    pub fn new(policy: SloPolicy) -> Self {
        Self { policy, samples: Vec::new(), high_water: f64::NEG_INFINITY }
    }

    pub fn policy(&self) -> SloPolicy {
        self.policy
    }

    /// Span of the long window in seconds.
    pub fn long_span_s(&self) -> f64 {
        self.policy.window_s * self.policy.long_windows.max(1) as f64
    }

    /// Record one sample: the shard finished at `at` after
    /// `latency_s`.
    pub fn record(&mut self, at: f64, latency_s: f64) {
        self.samples.push((at, latency_s));
    }

    /// Violating fraction over samples in `(from, to]`, None when the
    /// window holds no samples.
    fn window_burn(&self, from: f64, to: f64) -> Option<f64> {
        let mut total = 0u64;
        let mut bad = 0u64;
        for &(at, latency) in &self.samples {
            if at > from && at <= to {
                total += 1;
                if latency > self.policy.p99_latency_s {
                    bad += 1;
                }
            }
        }
        if total == 0 {
            None
        } else {
            Some(bad as f64 / total as f64)
        }
    }

    /// (short, long) burn at `now` without pruning — missing windows
    /// read 0.0. Used for the end-of-run gauge.
    pub fn burn_at(&self, now: f64) -> (f64, f64) {
        let short = self.window_burn(now - self.policy.window_s, now).unwrap_or(0.0);
        let long = self.window_burn(now - self.long_span_s(), now).unwrap_or(0.0);
        (short, long)
    }

    /// Evaluate at `now`, aging out samples the long window can never
    /// see again. Some((short, long)) when both windows hold samples
    /// and both burn fractions reach the threshold.
    pub fn evaluate(&mut self, now: f64) -> Option<(f64, f64)> {
        self.high_water = self.high_water.max(now);
        let horizon = self.high_water - self.long_span_s();
        self.samples.retain(|&(at, _)| at > horizon);
        let short = self.window_burn(now - self.policy.window_s, now)?;
        let long = self.window_burn(now - self.long_span_s(), now)?;
        if short >= self.policy.burn_threshold && long >= self.policy.burn_threshold {
            Some((short, long))
        } else {
            None
        }
    }
}

/// What an offline objective holds a series to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Sample values (latencies) must stay at or below `seconds`.
    P99LatencyBelow { seconds: f64 },
    /// Sample values (a throughput gauge) must stay at or above
    /// `gflops`.
    MinGflops { gflops: f64 },
    /// Sample values (a depth gauge) must stay at or below `depth`.
    MaxQueueDepth { depth: f64 },
}

impl Objective {
    /// Does `value` violate the objective?
    pub fn violated_by(&self, value: f64) -> bool {
        match *self {
            Objective::P99LatencyBelow { seconds } => value > seconds,
            Objective::MinGflops { gflops } => value < gflops,
            Objective::MaxQueueDepth { depth } => value > depth,
        }
    }
}

/// A named objective plus its burn windows.
#[derive(Clone, Debug)]
pub struct SloSpec {
    pub name: String,
    pub objective: Objective,
    pub window_s: f64,
    pub long_windows: usize,
    pub burn_threshold: f64,
}

/// One sustained-burn instant: both windows over threshold at `at`.
#[derive(Clone, Debug, PartialEq)]
pub struct Alert {
    pub slo: String,
    pub at: f64,
    pub short_burn: f64,
    pub long_burn: f64,
}

impl SloSpec {
    fn burn(&self, series: &Series, from: f64, to: f64) -> Option<f64> {
        let mut total = 0u64;
        let mut bad = 0u64;
        for (at, value) in series.iter() {
            if at > from && at <= to {
                total += 1;
                if self.objective.violated_by(value) {
                    bad += 1;
                }
            }
        }
        if total == 0 {
            None
        } else {
            Some(bad as f64 / total as f64)
        }
    }

    /// Replay the burn rule over a recorded series: one alert per
    /// sample instant at which both windows burn.
    pub fn alerts(&self, series: &Series) -> Vec<Alert> {
        let long_span = self.window_s * self.long_windows.max(1) as f64;
        let mut out = Vec::new();
        for (at, _) in series.iter() {
            let Some(short) = self.burn(series, at - self.window_s, at) else { continue };
            let Some(long) = self.burn(series, at - long_span, at) else { continue };
            if short >= self.burn_threshold && long >= self.burn_threshold {
                out.push(Alert { slo: self.name.clone(), at, short_burn: short, long_burn: long });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> SloPolicy {
        SloPolicy {
            p99_latency_s: 1.0,
            window_s: 2.0,
            long_windows: 2,
            burn_threshold: 0.5,
            max_growth: 2,
        }
    }

    #[test]
    fn monitor_stays_quiet_with_no_samples_or_healthy_ones() {
        let mut m = BurnMonitor::new(policy());
        assert_eq!(m.evaluate(1.0), None, "empty windows never alert");
        for i in 0..10 {
            m.record(i as f64 * 0.5, 0.3);
        }
        assert_eq!(m.evaluate(5.0), None);
        assert_eq!(m.burn_at(5.0), (0.0, 0.0));
    }

    #[test]
    fn monitor_needs_both_windows_burning() {
        // Long window healthy, short window hot: a fresh spike alone
        // must not alert under a 0.5 threshold on the long window.
        let mut m = BurnMonitor::new(policy());
        for i in 0..8 {
            m.record(i as f64 * 0.5, 0.3); // 0.0..3.5 healthy
        }
        m.record(3.8, 5.0);
        m.record(3.9, 5.0);
        // short (1.9, 3.9]: samples 2.0..3.5 healthy (4) + 2 hot = 2/6
        // < 0.5; long also diluted.
        assert_eq!(m.evaluate(3.9), None);
        // Sustained burn: hot samples dominate both windows.
        let mut m = BurnMonitor::new(policy());
        for i in 0..8 {
            m.record(i as f64 * 0.5, 5.0);
        }
        let (short, long) = m.evaluate(3.5).expect("sustained burn alerts");
        assert_eq!(short, 1.0);
        assert_eq!(long, 1.0);
    }

    #[test]
    fn monitor_prunes_only_what_the_long_window_left_behind() {
        let mut m = BurnMonitor::new(policy());
        for i in 0..100 {
            m.record(i as f64 * 0.1, 2.0); // 0.0..9.9, all violating
        }
        m.evaluate(9.9);
        // Samples at or before 9.9 - 4.0 = 5.9 are gone; the rest burn.
        assert_eq!(m.burn_at(9.9), (1.0, 1.0));
        assert_eq!(m.evaluate(9.9), Some((1.0, 1.0)));
        // Evaluating earlier than the high-water mark must not panic
        // or resurrect pruned data.
        assert_eq!(m.evaluate(3.0), None, "window older than retained data is empty");
    }

    #[test]
    fn offline_spec_replays_the_same_rule_over_a_series() {
        let mut s = Series::new("latency", 64);
        for i in 0..8 {
            s.push(i as f64 * 0.5, 0.3);
        }
        for i in 8..16 {
            s.push(i as f64 * 0.5, 3.0);
        }
        let spec = SloSpec {
            name: "p99-latency".into(),
            objective: Objective::P99LatencyBelow { seconds: 1.0 },
            window_s: 2.0,
            long_windows: 2,
            burn_threshold: 0.5,
        };
        let alerts = spec.alerts(&s);
        assert!(!alerts.is_empty(), "the sustained tail burns");
        // Alerts only fire once the long window is at least half hot
        // (earliest at t = 5.5: 4 hot of 8 in the long window).
        assert!(alerts.iter().all(|a| a.at >= 5.5), "{alerts:?}");
        assert!(alerts.iter().all(|a| a.short_burn >= 0.5 && a.long_burn >= 0.5));
        // Gauge objectives invert the comparison.
        assert!(Objective::MinGflops { gflops: 10.0 }.violated_by(5.0));
        assert!(!Objective::MinGflops { gflops: 10.0 }.violated_by(15.0));
        assert!(Objective::MaxQueueDepth { depth: 4.0 }.violated_by(5.0));
        assert!(!Objective::MaxQueueDepth { depth: 4.0 }.violated_by(3.0));
    }
}
