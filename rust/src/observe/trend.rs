//! Bench-trajectory analysis over `BENCH_pr<N>.json` artifacts.
//!
//! Every CI run folds its gated metrics into one flat
//! `BENCH_pr<N>.json` object (see `systo3d perfgate --merge`), and the
//! artifacts accumulate one per PR. This module turns that pile into a
//! per-metric history: [`collect_bench_files`] finds and orders the
//! artifacts by PR number, [`analyze`] pivots them into
//! [`MetricTrend`]s, and [`MetricTrend::last_move`] names the PR where
//! a metric last moved by more than a threshold fraction — the first
//! question a regression hunt asks ("when did this start?") answered
//! without opening a single trace. `systo3d trend` is the CLI face.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One metric's value at one PR.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrendPoint {
    pub pr: u64,
    pub value: f64,
}

/// One metric's history across the collected artifacts, PR-ascending.
#[derive(Clone, Debug)]
pub struct MetricTrend {
    pub name: String,
    pub points: Vec<TrendPoint>,
}

impl MetricTrend {
    /// The latest PR whose value moved more than `threshold`
    /// (fractional, e.g. 0.05 = 5%) relative to the previous point,
    /// with the signed fractional change. `None` when the metric never
    /// moved that much (or has fewer than two points).
    pub fn last_move(&self, threshold: f64) -> Option<(u64, f64)> {
        self.points
            .windows(2)
            .rev()
            .find_map(|w| {
                let (prev, cur) = (w[0].value, w[1].value);
                let change = if prev.abs() > f64::EPSILON {
                    (cur - prev) / prev.abs()
                } else if cur.abs() > f64::EPSILON {
                    f64::INFINITY
                } else {
                    0.0
                };
                (change.abs() > threshold).then_some((w[1].pr, change))
            })
    }

    /// Latest recorded value.
    pub fn latest(&self) -> Option<TrendPoint> {
        self.points.last().copied()
    }
}

/// Find `BENCH_pr<N>.json` files directly under `dir`, sorted by PR
/// number. Files that match the name pattern but carry no parseable
/// number are skipped (they cannot be ordered).
pub fn collect_bench_files(dir: &Path) -> std::io::Result<Vec<(u64, PathBuf)>> {
    let mut found = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if let Some(pr) = name
            .strip_prefix("BENCH_pr")
            .and_then(|r| r.strip_suffix(".json"))
            .and_then(|n| n.parse::<u64>().ok())
        {
            found.push((pr, path));
        }
    }
    found.sort();
    Ok(found)
}

/// Parse one artifact's top-level numeric fields (non-numeric fields
/// are ignored — the artifacts are flat metric objects by contract).
pub fn parse_metrics(text: &str) -> Result<BTreeMap<String, f64>, String> {
    let doc = Json::parse(text).map_err(|e| format!("bench artifact: {e}"))?;
    let obj = doc.as_obj().ok_or("bench artifact: not a JSON object")?;
    Ok(obj.iter().filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f))).collect())
}

/// Pivot per-PR metric maps into per-metric histories, name-sorted.
pub fn analyze(runs: &[(u64, BTreeMap<String, f64>)]) -> Vec<MetricTrend> {
    let mut trends: BTreeMap<&str, Vec<TrendPoint>> = BTreeMap::new();
    for (pr, metrics) in runs {
        for (name, &value) in metrics {
            trends.entry(name).or_default().push(TrendPoint { pr: *pr, value });
        }
    }
    trends
        .into_iter()
        .map(|(name, mut points)| {
            points.sort_by_key(|p| p.pr);
            MetricTrend { name: name.to_string(), points }
        })
        .collect()
}

/// The `systo3d trend` report: one line per metric with its value
/// history and the PR of its last >`threshold` move.
pub fn render(trends: &[MetricTrend], threshold: f64) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "bench trajectory: {} metric(s), move threshold {:.0}%\n",
        trends.len(),
        threshold * 100.0
    ));
    for t in trends {
        let history: Vec<String> =
            t.points.iter().map(|p| format!("{:.4} (pr{})", p.value, p.pr)).collect();
        let moved = match t.last_move(threshold) {
            Some((pr, change)) if change.is_finite() => {
                format!("last move: PR {pr} ({:+.1}%)", change * 100.0)
            }
            Some((pr, _)) => format!("last move: PR {pr} (from zero)"),
            None => "steady".to_string(),
        };
        out.push_str(&format!("  {:<40} {}  | {moved}\n", t.name, history.join(" -> ")));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(pr: u64, pairs: &[(&str, f64)]) -> (u64, BTreeMap<String, f64>) {
        (pr, pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect())
    }

    #[test]
    fn analyze_pivots_and_orders_by_pr() {
        // Deliberately unordered input: analyze must sort by PR.
        let runs = vec![
            run(7, &[("a", 1.2), ("b", 3.0)]),
            run(4, &[("a", 1.0)]),
            run(6, &[("a", 1.1), ("b", 3.0)]),
        ];
        let trends = analyze(&runs);
        assert_eq!(trends.len(), 2);
        assert_eq!(trends[0].name, "a");
        let prs: Vec<u64> = trends[0].points.iter().map(|p| p.pr).collect();
        assert_eq!(prs, vec![4, 6, 7]);
        // Metric "b" only appears from PR 6 on.
        assert_eq!(trends[1].points.len(), 2);
        assert_eq!(trends[1].latest(), Some(TrendPoint { pr: 7, value: 3.0 }));
    }

    #[test]
    fn last_move_names_the_latest_big_change() {
        let runs = vec![
            run(4, &[("m", 1.0)]),
            run(5, &[("m", 2.0)]),  // +100%
            run(6, &[("m", 2.02)]), // +1%: below threshold
            run(7, &[("m", 2.04)]), // +1%: below threshold
        ];
        let t = &analyze(&runs)[0];
        let (pr, change) = t.last_move(0.05).expect("PR 5 doubled the metric");
        assert_eq!(pr, 5);
        assert!((change - 1.0).abs() < 1e-9);
        // A tighter threshold blames the most recent wiggle instead.
        assert_eq!(t.last_move(0.005).unwrap().0, 7);
        // A huge threshold finds nothing.
        assert!(t.last_move(2.0).is_none());
    }

    #[test]
    fn last_move_handles_zero_baselines() {
        let runs = vec![run(1, &[("z", 0.0)]), run(2, &[("z", 0.0)]), run(3, &[("z", 0.5)])];
        let t = &analyze(&runs)[0];
        let (pr, change) = t.last_move(0.05).unwrap();
        assert_eq!(pr, 3);
        assert!(change.is_infinite());
        // A single point can never move.
        let single = &analyze(&[run(1, &[("s", 9.0)])])[0];
        assert!(single.last_move(0.0).is_none());
    }

    #[test]
    fn parse_metrics_keeps_only_numbers() {
        let m = parse_metrics(r#"{"a": 1.5, "note": "text", "b": 2}"#).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m["a"], 1.5);
        assert_eq!(m["b"], 2.0);
        assert!(parse_metrics("[1,2]").is_err());
        assert!(parse_metrics("nonsense").is_err());
    }

    #[test]
    fn collect_orders_artifacts_by_pr_number() {
        let dir = std::env::temp_dir()
            .join(format!("systo3d_trend_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for name in ["BENCH_pr10.json", "BENCH_pr4.json", "BENCH_pr8.json", "other.json"] {
            std::fs::write(dir.join(name), "{}").unwrap();
        }
        std::fs::write(dir.join("BENCH_prX.json"), "{}").unwrap(); // unordered: skipped
        let files = collect_bench_files(&dir).unwrap();
        let prs: Vec<u64> = files.iter().map(|(pr, _)| *pr).collect();
        assert_eq!(prs, vec![4, 8, 10]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn render_reports_history_and_moves() {
        let runs = vec![run(4, &[("placement_gain", 1.0)]), run(5, &[("placement_gain", 1.5)])];
        let text = render(&analyze(&runs), 0.05);
        assert!(text.contains("placement_gain"));
        assert!(text.contains("last move: PR 5 (+50.0%)"));
        assert!(text.contains("1.0000 (pr4) -> 1.5000 (pr5)"));
    }
}
