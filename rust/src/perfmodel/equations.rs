//! Paper equations (1)–(19), in order, with the paper's symbol names.

/// eq. (1): `T_op = 𝒯_op · f_max` — ideal-pipeline op throughput.
/// `t_op_per_cycle` in op/cycle, `f_mhz` in MHz; result in op/s.
pub fn eq1_throughput(t_op_per_cycle: f64, f_mhz: f64) -> f64 {
    t_op_per_cycle * f_mhz * 1e6
}

/// eq. (2): stall condition — `𝓑_r · f_max > e · B_ddr`.
/// `b_r` in bytes/cycle, `f_mhz` in MHz, `b_ddr_mb_s` in MB/s.
pub fn eq2_stalls(b_r: f64, f_mhz: f64, e: f64, b_ddr_mb_s: f64) -> bool {
    b_r * f_mhz * 1e6 > e * b_ddr_mb_s * 1e6
}

/// Stall rate (unnumbered, after eq. 2): `1 − e·B_ddr / (𝓑_r·f_max)`,
/// zero if eq. 2 does not hold.
pub fn stall_rate(b_r: f64, f_mhz: f64, e: f64, b_ddr_mb_s: f64) -> f64 {
    if eq2_stalls(b_r, f_mhz, e, b_ddr_mb_s) {
        1.0 - (e * b_ddr_mb_s) / (b_r * f_mhz)
    } else {
        0.0
    }
}

/// eq. (3): `T_op = (1-stall)·𝒯_op·f_max` — throughput under stalls.
pub fn eq3_stalled_throughput(stall: f64, t_op_per_cycle: f64, f_mhz: f64) -> f64 {
    (1.0 - stall) * eq1_throughput(t_op_per_cycle, f_mhz)
}

/// eq. (4): per-LSU request ceiling in sp-floats/cycle as a function of
/// f_max (the LSU bus narrows past 300 MHz).
pub fn eq4_lsu_ceiling_floats(f_mhz: f64) -> u32 {
    if f_mhz <= 300.0 {
        16
    } else {
        8
    }
}

/// eq. (5): `T_peak = 2·#DSP·f_max` [FLOPS]; `f_mhz` in MHz.
pub fn eq5_peak_flops(n_dsp: u32, f_mhz: f64) -> f64 {
    2.0 * n_dsp as f64 * f_mhz * 1e6
}

/// eq. (7): dot-product-unit throughput `𝒯_flop = 2·d_p` [FLOP/cycle].
pub fn eq7_dot_unit_flop_per_cycle(dp: u32) -> u32 {
    2 * dp
}

/// eq. (8): dot-product-unit input appetite `𝓑_in = 2·d_p + 1`
/// [sp-floats/cycle].
pub fn eq8_dot_unit_input_floats(dp: u32) -> u32 {
    2 * dp + 1
}

/// eq. (9): array throughput `𝒯_flop = 2·d_i0·d_j0·d_k0` [FLOP/cycle].
pub fn eq9_array_flop_per_cycle(di0: u32, dj0: u32, dk0: u32) -> u64 {
    2 * di0 as u64 * dj0 as u64 * dk0 as u64
}

/// eq. (10): input-face data throughputs `𝓑_A = d_i0·d_k0`,
/// `𝓑_B = d_k0·d_j0` [sp-floats/cycle].
pub fn eq10_face_throughputs(di0: u32, dj0: u32, dk0: u32) -> (u64, u64) {
    (di0 as u64 * dk0 as u64, dk0 as u64 * dj0 as u64)
}

/// eq. (11): `#DSP = d_i0·d_j0·d_k0`.
pub fn eq11_dsp_count(di0: u32, dj0: u32, dk0: u32) -> u64 {
    di0 as u64 * dj0 as u64 * dk0 as u64
}

/// eq. (12): `#PE = d_i0·d_j0·d_k0/d_p`.
pub fn eq12_pe_count(di0: u32, dj0: u32, dk0: u32, dp: u32) -> u64 {
    assert!(dk0 % dp == 0, "d_p must divide d_k0");
    eq11_dsp_count(di0, dj0, dk0) / dp as u64
}

/// eq. (13): ideal loop-body latency of the systolic function,
/// `l_body = d_i0 + d_j0 − 1 + (d_k0/d_p)·l_dot(d_p)` [cycles].
pub fn eq13_loop_body_latency(di0: u32, dj0: u32, dk0: u32, dp: u32, l_dot: u32) -> u64 {
    di0 as u64 + dj0 as u64 - 1 + (dk0 / dp) as u64 * l_dot as u64
}

/// Definition 1 total latency:
/// `l_tot = d_i0 + d_j0 + K − 1 + l_MAC` (classical 2D array).
pub fn def1_total_latency(di0: u32, dj0: u32, k: u64, l_mac: u32) -> u64 {
    di0 as u64 + dj0 as u64 + k - 1 + l_mac as u64
}

/// Definition 2 total latency:
/// `l_tot = d_i0 + d_j0 + K/d_k0 − 1 + (d_k0/d_p)·l_dot` (3D array).
pub fn def2_total_latency(di0: u32, dj0: u32, k: u64, dk0: u32, dp: u32, l_dot: u32) -> u64 {
    assert!(k % dk0 as u64 == 0);
    di0 as u64 + dj0 as u64 + k / dk0 as u64 - 1 + (dk0 / dp) as u64 * l_dot as u64
}

/// eq. (14): reuse ratios `r_A = 𝓑_A/𝓑_gA`, `r_B = 𝓑_B/𝓑_gB`.
pub fn eq14_reuse_ratios(b_a: u64, b_b: u64, b_ga: u64, b_gb: u64) -> (u64, u64) {
    assert!(b_ga > 0 && b_gb > 0);
    (
        crate::util::div_ceil(b_a, b_ga),
        crate::util::div_ceil(b_b, b_gb),
    )
}

/// eq. (18): level-1 block sizes from the reuse ratios:
/// `d_i1 = r_B·d_i0`, `d_j1 = r_A·d_j0`.
pub fn eq18_level1_sizes(r_a: u64, r_b: u64, di0: u32, dj0: u32) -> (u64, u64) {
    (r_b * di0 as u64, r_a * dj0 as u64)
}

/// eq. (19): compute fraction
/// `c_% ≈ (d_k2/d_k0) / (1 + d_k2/d_k0 + d_i0·d_j0/𝓑_ddr)`.
///
/// The three summands are the pipeline fills of Phase 1 (initial read),
/// the `d_k2/d_k0` overlapped read+compute slabs, and the exposed Write
/// phase (d_i1·d_j1 values at 𝓑_ddr floats/cycle, normalized per slab
/// by the same d_i1·d_j1/(d_i0·d_j0) factor — hence the d_i0·d_j0/𝓑_ddr
/// term).
pub fn eq19_compute_fraction(dk2: u64, dk0: u32, di0: u32, dj0: u32, b_ddr_floats: u32) -> f64 {
    let slabs = dk2 as f64 / dk0 as f64;
    slabs / (1.0 + slabs + (di0 as f64 * dj0 as f64) / b_ddr_floats as f64)
}

/// Total FLOP of an (m×k)·(k×n) matmul as the paper counts it:
/// `#FLOP = d_i2·d_j2·(2·d_k2 − 1)`.
pub fn flop_count(m: u64, n: u64, k: u64) -> u64 {
    m * n * (2 * k - 1)
}

/// Measured-throughput helper: `T_flops = #FLOP / t` (FLOPS).
pub fn measured_flops(flop: u64, seconds: f64) -> f64 {
    flop as f64 / seconds
}

/// DSP efficiency `e_D = T_flops / T_peak`.
pub fn dsp_efficiency(t_flops: f64, t_peak: f64) -> f64 {
    t_flops / t_peak
}

/// Multi-device scaling efficiency: `(t_1 / t_n) / n` — 1.0 is perfect
/// linear scaling of an n-card cluster over the single-card time `t_1`.
pub fn scaling_efficiency(n: u64, t1_seconds: f64, tn_seconds: f64) -> f64 {
    assert!(n > 0 && t1_seconds > 0.0 && tn_seconds > 0.0);
    (t1_seconds / tn_seconds) / n as f64
}

/// Strassen work ratio: a depth-d recursion performs `(7/8)^d` of the
/// classical multiplications. Its inverse bounds how far *effective*
/// throughput (classical `flop_count` over measured time) can exceed
/// the eq. 5 DSP peak: `(8/7)^d` at zero add/sub overhead.
pub fn strassen_flop_ratio(depth: u32) -> f64 {
    (7.0f64 / 8.0).powi(depth as i32)
}

/// Closed form of the ring reduce-to-one collective (reduce-scatter
/// then gather, see [`crate::fabric::collective`]): `c` participants
/// cycle `c−1` rounds of `B/c`-byte slices, then the home gathers the
/// `c−1` reduced slices, so on uncongested 1-hop links
///
/// ```text
/// T_ring = 2·(c−1)/c · B / bw
/// ```
///
/// The routed schedule prices at or below this (gather arrivals can
/// use several home ingress links), which is what the collective
/// tests check.
pub fn ring_reduce_seconds(participants: u64, bytes: u64, link_bytes_per_s: f64) -> f64 {
    assert!(participants > 0 && link_bytes_per_s > 0.0);
    if participants == 1 {
        return 0.0;
    }
    let c = participants as f64;
    2.0 * (c - 1.0) / c * bytes as f64 / link_bytes_per_s
}

/// Lower bound on any phase that moves `bytes` across the fabric's
/// bisection: no schedule beats the cut's aggregate bandwidth
/// ([`crate::fabric::Topology::bisection_bytes_per_s`]).
pub fn bisection_bound_seconds(bytes: u64, bisection_bytes_per_s: f64) -> f64 {
    assert!(bisection_bytes_per_s > 0.0);
    bytes as f64 / bisection_bytes_per_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_eq3_consistency() {
        let t = eq1_throughput(16.0, 400.0);
        assert_eq!(t, 6.4e9);
        assert_eq!(eq3_stalled_throughput(0.0, 16.0, 400.0), t);
        assert_eq!(eq3_stalled_throughput(0.25, 16.0, 400.0), 0.75 * t);
    }

    #[test]
    fn eq2_stall_examples() {
        // §II-B: global memory alone sustains only ~10 GFLOPS worth of
        // dot-product inputs. 64 B/cycle at 400 MHz > 19.2 GB/s -> stall.
        assert!(eq2_stalls(64.0, 400.0, 1.0, 19_200.0));
        assert!(!eq2_stalls(32.0, 400.0, 1.0, 19_200.0));
        assert!((stall_rate(64.0, 400.0, 1.0, 19_200.0) - 0.25).abs() < 1e-12);
        assert_eq!(stall_rate(32.0, 400.0, 1.0, 19_200.0), 0.0);
    }

    #[test]
    fn eq4_bins() {
        assert_eq!(eq4_lsu_ceiling_floats(150.1), 16);
        assert_eq!(eq4_lsu_ceiling_floats(300.0), 16);
        assert_eq!(eq4_lsu_ceiling_floats(300.1), 8);
        assert_eq!(eq4_lsu_ceiling_floats(600.0), 8);
    }

    #[test]
    fn eq5_table1_tpeak_column() {
        // Every (DSPs, fmax, Tpeak) triple in Table I.
        let rows = [
            (4704u32, 368.0, 3462.0), // C
            (4608, 368.0, 3391.0),    // E
            (4480, 410.0, 3673.0),    // F
            (4096, 398.0, 3260.0),    // G
            (4096, 408.0, 3342.0),    // H
            (4096, 396.0, 3244.0),    // I
            (4096, 391.0, 3203.0),    // L
            (4096, 363.0, 2973.0),    // M
            (4096, 381.0, 3121.0),    // N
        ];
        for (dsp, f, gflops) in rows {
            let got = eq5_peak_flops(dsp, f) / 1e9;
            assert!((got - gflops).abs() < 1.0, "{dsp}@{f}: {got} vs {gflops}");
        }
    }

    #[test]
    fn eq5_table6_tpeak_column() {
        assert!((eq5_peak_flops(3584, 412.0) / 1e9 - 2953.0).abs() < 1.0);
        assert!((eq5_peak_flops(4096, 407.0) / 1e9 - 3334.0).abs() < 1.0);
    }

    #[test]
    fn eq7_to_eq12_geometry() {
        assert_eq!(eq7_dot_unit_flop_per_cycle(8), 16);
        assert_eq!(eq8_dot_unit_input_floats(8), 17);
        assert_eq!(eq9_array_flop_per_cycle(64, 32, 2), 8192);
        assert_eq!(eq10_face_throughputs(64, 32, 2), (128, 64));
        assert_eq!(eq11_dsp_count(28, 28, 6), 4704);
        assert_eq!(eq12_pe_count(28, 28, 6, 3), 1568);
        assert_eq!(eq12_pe_count(28, 28, 6, 2), 2352);
        assert_eq!(eq12_pe_count(32, 16, 8, 8), 512);
    }

    #[test]
    fn latency_formulas() {
        // Def. 1 with K=100, l_MAC=4 on an 8x8 grid.
        assert_eq!(def1_total_latency(8, 8, 100, 4), 8 + 8 + 100 - 1 + 4);
        // Def. 2 reduces iteration count by d_k0.
        let l3d = def2_total_latency(8, 8, 100 * 4, 4, 2, 5);
        assert_eq!(l3d, 8 + 8 + 100 - 1 + 2 * 5);
        // eq. 13 is Def. 2 without the K/d_k0 iterations term's K part.
        assert_eq!(eq13_loop_body_latency(8, 8, 4, 2, 5), 8 + 8 - 1 + 10);
    }

    #[test]
    fn eq14_eq18_blocking_chain() {
        // Design G at 398 MHz: B_A=128, B_B=64; channels deliver 8
        // floats/cycle (eq. 4 past 300 MHz) -> r_A=16, r_B=8.
        let (b_a, b_b) = eq10_face_throughputs(64, 32, 2);
        let (r_a, r_b) = eq14_reuse_ratios(b_a, b_b, 8, 8);
        assert_eq!((r_a, r_b), (16, 8));
        let (di1, dj1) = eq18_level1_sizes(r_a, r_b, 64, 32);
        // Table V caption: d1 = 512 for designs G–N.
        assert_eq!((di1, dj1), (512, 512));
    }

    #[test]
    fn eq14_eq18_design_c() {
        // Design C (28,28,6) at 368 MHz: B_A = B_B = 168; 8 floats/cycle
        // -> r = 21 -> d1 = 588? The paper reports d1 = 672 = 24·28:
        // it provisioned for 𝓑_g = 7 floats/cycle (r = 24), leaving
        // headroom. Our model computes the *minimum*; 672 satisfies it.
        let (b_a, _) = eq10_face_throughputs(28, 28, 6);
        let (r_a, _) = eq14_reuse_ratios(b_a, b_a, 8, 8);
        let (di1_min, _) = eq18_level1_sizes(r_a, r_a, 28, 28);
        assert!(672 >= di1_min);
        assert_eq!(672 % 28, 0);
    }

    #[test]
    fn eq19_asymptotics() {
        // c_% -> 1 as d_k2 -> inf.
        let big = eq19_compute_fraction(1 << 40, 2, 64, 32, 8);
        assert!(big > 0.999);
        // Rises monotonically with d_k2.
        let mut last = 0.0;
        for dk2 in [512u64, 1024, 2048, 4096, 8192, 16384] {
            let c = eq19_compute_fraction(dk2, 2, 64, 32, 8);
            assert!(c > last);
            last = c;
        }
    }

    #[test]
    fn eq19_matches_measured_efficiency_shape() {
        // Design G (Table V): e_D at d2=512..16384 is
        // 0.45, 0.65, 0.80, 0.89, 0.94, 0.97. eq. 19 should track within
        // a few points (the paper: "measured DSP efficiencies are close
        // to their evaluations shown in (19)").
        let meas = [0.45, 0.65, 0.80, 0.89, 0.94, 0.97];
        for (i, d2) in [512u64, 1024, 2048, 4096, 8192, 16384].iter().enumerate() {
            let c = eq19_compute_fraction(*d2, 2, 64, 32, 8);
            assert!(
                (c - meas[i]).abs() < 0.06,
                "d2={d2}: eq19={c:.3} vs measured {}",
                meas[i]
            );
        }
    }

    #[test]
    fn flop_count_paper_formula() {
        assert_eq!(flop_count(2, 2, 2), 2 * 2 * 3);
        // d2=672 cube: 672^2·(2·672-1).
        assert_eq!(flop_count(672, 672, 672), 672 * 672 * 1343);
    }

    #[test]
    fn efficiency_helpers() {
        let t = measured_flops(1_000_000_000, 0.5);
        assert_eq!(t, 2e9);
        assert!((dsp_efficiency(t, 4e9) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn strassen_ratio_bounds_effective_throughput() {
        assert_eq!(strassen_flop_ratio(0), 1.0);
        assert!((strassen_flop_ratio(1) - 0.875).abs() < 1e-12);
        // Depth 3 removes ~33% of the multiplications: the zero-overhead
        // effective ceiling is ~1.49x the DSP peak.
        let ceiling = 1.0 / strassen_flop_ratio(3);
        assert!((ceiling - 1.4927).abs() < 1e-3, "{ceiling}");
    }

    #[test]
    fn scaling_efficiency_bounds() {
        // Perfect halving at n=2 is 1.0; no speedup at n=2 is 0.5.
        assert!((scaling_efficiency(2, 1.0, 0.5) - 1.0).abs() < 1e-12);
        assert!((scaling_efficiency(2, 1.0, 1.0) - 0.5).abs() < 1e-12);
        assert!((scaling_efficiency(4, 1.0, 0.3) - 1.0 / 1.2).abs() < 1e-12);
    }

    #[test]
    fn ring_reduce_closed_form() {
        // One participant: nothing to move.
        assert_eq!(ring_reduce_seconds(1, 1 << 30, 1e9), 0.0);
        // Two participants: the 2·(c−1)/c factor is exactly 1.
        assert!((ring_reduce_seconds(2, 1_000_000_000, 1e9) - 1.0).abs() < 1e-12);
        // The factor saturates toward 2B/bw as c grows.
        let t4 = ring_reduce_seconds(4, 1_000_000_000, 1e9);
        let t64 = ring_reduce_seconds(64, 1_000_000_000, 1e9);
        assert!((t4 - 1.5).abs() < 1e-12);
        assert!(t4 < t64 && t64 < 2.0);
    }

    #[test]
    fn bisection_bound_scales() {
        let t = bisection_bound_seconds(2_000_000_000, 1e9);
        assert!((t - 2.0).abs() < 1e-12);
    }
}
