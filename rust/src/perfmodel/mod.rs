//! The paper's analytical performance model: every numbered equation as a
//! documented, unit-tested function.
//!
//! These are the closed forms that the event-level simulator
//! ([`crate::blocked::offchip`]) must agree with on small cases where the
//! cycle-accurate simulator ([`crate::systolic`]) provides ground truth —
//! the three layers of validation described in DESIGN.md §2.

pub mod equations;

pub use equations::*;
