//! The bijective device→card map and its application to a plan.

use crate::cluster::partition::PartitionPlan;

/// A bijective map from logical plan devices onto physical cards.
///
/// Devices beyond the card count fold modulo first, exactly like the
/// scheduler's queue assignment (`device % cards`), so a placement for
/// an N-card fabric is always a permutation of `0..N`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Placement {
    map: Vec<usize>,
}

impl Placement {
    /// The do-nothing baseline: device i runs on card i.
    pub fn identity(cards: usize) -> Self {
        Self { map: (0..cards.max(1)).collect() }
    }

    /// Wrap an explicit map; it must be a permutation of `0..map.len()`.
    pub fn from_map(map: Vec<usize>) -> Result<Self, String> {
        let n = map.len();
        if n == 0 {
            return Err("empty placement".into());
        }
        let mut seen = vec![false; n];
        for &c in &map {
            if c >= n {
                return Err(format!("card {c} out of range for {n} card(s)"));
            }
            if seen[c] {
                return Err(format!("card {c} assigned twice"));
            }
            seen[c] = true;
        }
        Ok(Self { map })
    }

    /// Cards the map covers.
    pub fn cards(&self) -> usize {
        self.map.len()
    }

    /// Physical card of plan device `device`.
    pub fn card(&self, device: usize) -> usize {
        self.map[device % self.map.len()]
    }

    /// The raw device→card permutation.
    pub fn as_slice(&self) -> &[usize] {
        &self.map
    }

    pub fn is_identity(&self) -> bool {
        self.map.iter().enumerate().all(|(i, &c)| i == c)
    }

    /// Swap the cards of devices `a` and `b` — the local-search move.
    pub fn swap(&mut self, a: usize, b: usize) {
        self.map.swap(a, b);
    }

    /// Re-home a plan onto the placed cards: every shard's device folds
    /// onto the card count and maps through the permutation. The tile
    /// carve is untouched, so functional results stay bit-exact; only
    /// where partials live — and therefore what the reduction traffic
    /// costs on the fabric — changes. Each tile's reduction home (its
    /// k-first shard) moves with its shard, so the scheduler's home
    /// bookkeeping and death re-homing work unchanged on placed plans.
    pub fn apply_to(&self, plan: &PartitionPlan) -> PartitionPlan {
        let mut placed = plan.clone();
        for s in &mut placed.shards {
            s.device = self.card(s.device);
        }
        placed.devices = placed.shards.iter().map(|s| s.device).max().map_or(0, |d| d + 1);
        placed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::PartitionStrategy;
    use crate::fabric::Topology;
    use crate::gemm::{matmul_blocked, Matrix};

    #[test]
    fn identity_and_validation() {
        let id = Placement::identity(4);
        assert!(id.is_identity());
        assert_eq!(id.cards(), 4);
        assert_eq!(id.card(6), 2, "devices fold modulo the card count");
        assert!(Placement::from_map(vec![1, 0, 3, 2]).is_ok());
        assert!(Placement::from_map(vec![]).is_err());
        assert!(Placement::from_map(vec![0, 0, 1]).is_err());
        assert!(Placement::from_map(vec![0, 3]).is_err());
    }

    #[test]
    fn apply_preserves_carve_and_moves_homes() {
        let plan = PartitionPlan::new(
            PartitionStrategy::Summa25D { p: 2, q: 2, c: 2 },
            64,
            64,
            64,
        )
        .unwrap();
        // Pair each plane-0 device with its plane-1 partner: 0<->4 etc.
        let placement = Placement::from_map(vec![0, 2, 4, 6, 1, 3, 5, 7]).unwrap();
        let placed = placement.apply_to(&plan);
        placed.validate_cover().unwrap();
        assert_eq!(placed.devices, 8);
        assert_eq!(placed.device_to_device_bytes, plan.device_to_device_bytes);
        // The cross-plane combine drops from 4 ring hops to 1.
        let ring = Topology::ring(8);
        assert!(placed.reduction_hop_bytes(&ring) < plan.reduction_hop_bytes(&ring));
        // Functional results are untouched by the relabeling.
        let a = Matrix::random(64, 64, 3);
        let b = Matrix::random(64, 64, 4);
        assert_eq!(placed.execute_functional(&a, &b).data, matmul_blocked(&a, &b).data);
    }
}
