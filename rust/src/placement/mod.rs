//! Topology-aware placement: map plan devices onto physical cards so
//! the 2.5D partial-C reduction pays as little for the fabric as the
//! wiring allows.
//!
//! The partitioners emit *logical* device ids (plane-major for 2.5D:
//! slice `l` owns the `l`-th contiguous p × q plane), and until now the
//! fleet ran plans with the identity device→card map. On a narrow
//! fabric that is expensive: at N = 16 on a ring, every cross-plane
//! partial crosses half the ring and every flow shares links with
//! every other. PR 3's own sweep concluded a placement optimizer would
//! buy more than another partitioner — the same communication-avoiding
//! insight that drives de Fine Licht et al.'s HLS matmul
//! (arXiv 1912.06526) and the multi-array scale-out of Shen et al.
//! (arXiv 1803.03790): move the *layout*, not more bytes.
//!
//! Three strategies, all returning a bijective device→card
//! [`Placement`]:
//!
//! * **identity** — the baseline every optimizer is scored against.
//! * **plane-packed** — a greedy packer over the plan's reduction
//!   demand graph: devices are placed one at a time, each onto the
//!   free card minimizing demand-weighted hops to the devices already
//!   placed. For plane-major 2.5D plans the dominant demands are the
//!   cross-plane tile columns, so each k-slice's p × q plane lands on
//!   fabric-adjacent cards.
//! * **local-search** — seeded swap moves (deterministic
//!   [`crate::util::rng::Xoshiro256`] draws, no wall-clock randomness)
//!   polishing the better of identity and plane-packed.
//!
//! Candidates are scored by the plan's reduction sends **replayed
//! under the PR-3 contention model** ([`crate::fabric::FabricState`]):
//! every flow reserves each directed link on its path, so shared links
//! serialize and disjoint links parallelize — the score is the instant
//! the last partial drains, not a hop count. The replay itself is no
//! longer the inner loop: [`optimize`] prices swap candidates
//! incrementally (exact hop-byte deltas + a per-link occupancy lower
//! bound over [`crate::fabric::PathCache`]-compiled routes) and proves
//! each decision identical to the full replay, which survives as
//! [`optimize_reference`], the equivalence oracle. Plain hop-bytes
//! ([`crate::cluster::PartitionPlan::reduction_hop_bytes`]) is the
//! tie-break, and the optimizer never returns a map whose hop-bytes
//! exceed identity's (the dominance property the integration tests
//! check).
//!
//! Wiring: [`crate::cluster::ClusterSim`] carries a
//! [`PlacementStrategy`] (`plan_and_report` places every candidate
//! plan before simulating it; card deaths re-home reductions through
//! the scheduler's existing path), `ServiceConfig::placement` exposes
//! the knob to the service, the `cluster`/`fabric` CLI subcommands
//! take `--placement`, and [`crate::coordinator::Metrics`] gains
//! placed-vs-identity hop-byte and search-time gauges.

pub mod map;
pub mod search;

pub use map::Placement;
pub use search::{
    optimize, optimize_reference, optimize_traced, PlacementReport, PlacementStrategy,
    DEFAULT_SEED,
};
