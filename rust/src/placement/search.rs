//! Strategy selection: identity, the greedy plane-packer, and the
//! seeded local search, all scored by replaying the plan's reduction
//! sends under the link-contention model.
//!
//! The local search no longer replays every send per candidate.
//! [`optimize`] prices a swap incrementally — exact hop-byte deltas
//! over the two touched cards' send index, plus a per-directed-link
//! occupancy lower bound that refutes most candidates outright — and
//! falls back to an exact replay (over [`PathCache`]-compiled routes,
//! undone by [`FabricState::rollback`]) only when the bound cannot
//! decide. Every accept/reject decision is provably identical to the
//! full-replay scorer, so the returned `Placement` and costs are
//! bit-for-bit those of [`optimize_reference`] — the property tests in
//! `tests/fastsim.rs` pin that equivalence across seeds, topologies,
//! and fleet sizes.

use super::map::Placement;
use crate::cluster::partition::PartitionPlan;
use crate::fabric::{FabricState, PathCache, Topology};
use crate::trace::{profile, Tracer};
use crate::util::rng::Xoshiro256;

/// Relative safety margin on the occupancy lower bound. The bound and
/// the replay makespan are sums/maxes of the same f64 durations, so
/// their relative disagreement is ~n·ε ≈ 1e-12; pruning only when the
/// bound clears the incumbent by 1e-9 keeps every prune decision
/// identical to what the exact replay would have concluded.
const LB_MARGIN: f64 = 1e-9;

/// Default local-search seed (any fixed value works — determinism is
/// the point, not the number).
pub const DEFAULT_SEED: u64 = 0x5EED_CA8D;

/// How to map plan devices onto physical cards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Device i runs on card i — the baseline.
    Identity,
    /// Greedy packer over the reduction demand graph.
    PlanePacked,
    /// Seeded swap local search from the better of identity and
    /// plane-packed. Deterministic: the same seed always returns the
    /// same map.
    LocalSearch { seed: u64 },
}

impl Default for PlacementStrategy {
    fn default() -> Self {
        PlacementStrategy::LocalSearch { seed: DEFAULT_SEED }
    }
}

impl PlacementStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementStrategy::Identity => "identity",
            PlacementStrategy::PlanePacked => "plane-packed",
            PlacementStrategy::LocalSearch { .. } => "local-search",
        }
    }

    /// Parse a CLI spelling (`--placement identity|plane|search`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "identity" | "id" => Ok(PlacementStrategy::Identity),
            "plane" | "plane-packed" | "packed" => Ok(PlacementStrategy::PlanePacked),
            "search" | "local-search" => Ok(PlacementStrategy::default()),
            other => Err(format!("unknown placement {other:?} (identity|plane|search)")),
        }
    }
}

/// What the optimizer found, with the identity baseline it was scored
/// against.
#[derive(Clone, Debug)]
pub struct PlacementReport {
    /// Strategy that ran (the map may still be identity when nothing
    /// beat it).
    pub strategy: &'static str,
    pub placement: Placement,
    /// Contention-priced drain of the reduction sends under the
    /// identity map: every flow launches at t = 0 and shared links
    /// serialize; this is when the last partial lands (s).
    pub identity_cost_seconds: f64,
    /// Same replay under the chosen map (≤ identity by construction).
    pub placed_cost_seconds: f64,
    /// Σ bytes · hops under identity (the topology-blind half of plan
    /// pricing made hop-aware).
    pub identity_hop_bytes: u64,
    /// Σ bytes · hops under the chosen map (never above identity's).
    pub placed_hop_bytes: u64,
    /// Candidate maps priced while searching.
    pub evaluations: usize,
    /// Host wall-clock of the search — a gauge only, never fed back
    /// into simulated time.
    pub search_seconds: f64,
}

impl PlacementReport {
    /// identity/placed contention cost (> 1 means the optimizer won;
    /// 1.0 when there was nothing to reduce).
    pub fn gain(&self) -> f64 {
        if self.placed_cost_seconds <= 0.0 {
            return 1.0;
        }
        self.identity_cost_seconds / self.placed_cost_seconds
    }

    /// Fraction of identity hop-bytes the placement removed.
    pub fn hop_byte_saving(&self) -> f64 {
        if self.identity_hop_bytes == 0 {
            return 0.0;
        }
        1.0 - self.placed_hop_bytes as f64 / self.identity_hop_bytes as f64
    }
}

/// All-pairs card hop counts (BFS per source, computed once per
/// optimize call).
fn hop_matrix(topology: &Topology) -> Vec<Vec<u32>> {
    let n = topology.cards;
    (0..n)
        .map(|a| (0..n).map(|b| topology.hops(a, b).unwrap_or(0)).collect())
        .collect()
}

/// Price `sends` under `placement` on `fabric`: every send launches at
/// t = 0 in plan order, shared directed links serialize (the
/// [`FabricState`] circuit model), and the cost is the instant the
/// last flow drains. Unroutable pairs price as infinity. The fabric's
/// occupancy is reset before the replay, so one instance serves every
/// candidate the search prices (no per-candidate route-table clone).
fn contention_cost(
    fabric: &mut FabricState,
    sends: &[(usize, usize, u64)],
    placement: &Placement,
) -> f64 {
    // The placement-search inner loop: every candidate map replays all
    // reduction sends through the circuit model. This is where the
    // host profiler expects the search's self time to land.
    let _scope = profile::scope("placement.candidate");
    fabric.reset_occupancy();
    let mut last = 0.0f64;
    for &(src, dst, bytes) in sends {
        let (s, d) = (placement.card(src), placement.card(dst));
        if s == d {
            continue;
        }
        match fabric.send(s, d, bytes, 0.0) {
            Some((_, end)) => last = last.max(end),
            None => return f64::INFINITY,
        }
    }
    last
}

/// Σ bytes · hops of `sends` under `placement`.
fn hop_bytes(hops: &[Vec<u32>], sends: &[(usize, usize, u64)], placement: &Placement) -> u64 {
    let mut total = 0u64;
    for &(src, dst, bytes) in sends {
        let (s, d) = (placement.card(src), placement.card(dst));
        if s != d {
            total += bytes * u64::from(hops[s][d]);
        }
    }
    total
}

/// Incremental swap pricer, decision-equivalent to the full replay.
///
/// Three layers, cheapest first:
/// 1. **Exact hop-byte delta** — only the sends touching the two
///    swapped devices change, so the candidate's Σ bytes·hops is exact
///    u64 arithmetic over the per-device send index. A candidate above
///    the identity ceiling is rejected without touching the fabric.
/// 2. **Occupancy lower bound** — flows sharing a directed link
///    serialize, so each link's summed circuit durations lower-bounds
///    the replay makespan. The sums are maintained per candidate by
///    delta (and rebuilt exactly on every accepted swap, capping float
///    drift); a bound above the incumbent (with [`LB_MARGIN`] safety)
///    proves the exact replay would reject too.
/// 3. **Exact bounded replay** — survivors replay all sends over
///    [`PathCache`]-compiled routes (bit-identical arithmetic to
///    [`FabricState::send`]), undone via checkpoint/rollback. The
///    makespan is a running max, so the replay exits early the moment
///    it provably exceeds the incumbent.
struct SwapScorer<'a> {
    fabric: FabricState,
    cache: PathCache,
    sends: &'a [(usize, usize, u64)],
    hops: &'a [Vec<u32>],
    /// Send indices touching each device (as src or dst; same-device
    /// sends never contribute and are omitted).
    touch: Vec<Vec<u32>>,
    /// Σ circuit durations per directed link under the current map.
    link_sum: Vec<[f64; 2]>,
    /// Hottest link sum and its identity, for the global bound.
    max_sum: f64,
    max_link: (u32, u8),
    /// Revert journal for candidate link-sum deltas.
    scratch: Vec<(u32, u8, f64)>,
}

impl<'a> SwapScorer<'a> {
    fn new(topology: &Topology, sends: &'a [(usize, usize, u64)], hops: &'a [Vec<u32>]) -> Self {
        let fabric = FabricState::new(topology.clone());
        let cache = PathCache::new(&fabric);
        let cards = topology.cards.max(1);
        let mut touch = vec![Vec::new(); cards];
        for (i, &(s, d, _)) in sends.iter().enumerate() {
            if s == d {
                continue;
            }
            touch[s].push(i as u32);
            touch[d].push(i as u32);
        }
        let edges = fabric.topology.edges.len();
        Self {
            fabric,
            cache,
            sends,
            hops,
            touch,
            link_sum: vec![[0.0; 2]; edges],
            max_sum: 0.0,
            max_link: (0, 0),
            scratch: Vec::new(),
        }
    }

    /// Exact replay of every send under `card_of`, launched at t = 0 in
    /// plan order — bit-identical to the reference scorer — rolled back
    /// afterwards. Returns +∞ the moment the running makespan exceeds
    /// `cutoff` (the makespan is a running max, so a prefix already
    /// above the incumbent rejects the candidate exactly as the full
    /// replay would) or when any pair is unroutable.
    fn replay(&mut self, card_of: &dyn Fn(usize) -> usize, cutoff: f64) -> f64 {
        let cp = self.fabric.checkpoint();
        let mut last = 0.0f64;
        for &(src, dst, bytes) in self.sends {
            let (s, d) = (card_of(src), card_of(dst));
            if s == d {
                continue;
            }
            match self.cache.get(s, d) {
                Some(path) => {
                    let (_, end) = self.fabric.send_cached(path, bytes, 0.0);
                    last = last.max(end);
                    if last > cutoff {
                        self.fabric.rollback(cp);
                        return f64::INFINITY;
                    }
                }
                None => {
                    self.fabric.rollback(cp);
                    return f64::INFINITY;
                }
            }
        }
        self.fabric.rollback(cp);
        last
    }

    /// Recompute the per-link duration sums and hottest link for
    /// `card_of` from scratch — exact, run at every accepted swap so
    /// candidate deltas never accumulate float drift.
    fn rebuild_sums(&mut self, card_of: &dyn Fn(usize) -> usize) {
        for s in &mut self.link_sum {
            *s = [0.0; 2];
        }
        for &(src, dst, bytes) in self.sends {
            let (s, d) = (card_of(src), card_of(dst));
            if s == d {
                continue;
            }
            if let Some(path) = self.cache.get(s, d) {
                let dur = path.duration(&self.fabric, bytes);
                for &(e, dir) in path.directed_links() {
                    self.link_sum[e as usize][dir as usize] += dur;
                }
            }
        }
        self.max_sum = 0.0;
        self.max_link = (0, 0);
        for (e, sums) in self.link_sum.iter().enumerate() {
            for (dir, &s) in sums.iter().enumerate() {
                if s > self.max_sum {
                    self.max_sum = s;
                    self.max_link = (e as u32, dir as u8);
                }
            }
        }
    }

    /// Price the swap `(a, b)` against the current map without a
    /// replay: the exact hop-byte total of the candidate, and an
    /// occupancy lower bound on its replay makespan (`None` when some
    /// affected pair is unroutable — the caller must fall back to the
    /// exact replay, which prices it +∞ in send order).
    fn swap_delta(
        &mut self,
        cur: &Placement,
        a: usize,
        b: usize,
        cur_hop: u64,
    ) -> (u64, Option<f64>) {
        debug_assert!(self.scratch.is_empty());
        let mut hop = cur_hop as i128;
        let mut routable = true;
        // Affected sends: touch[a] ∪ touch[b]; sends touching both are
        // visited once (skipped in b's pass).
        for side in 0..2 {
            let dev = if side == 0 { a } else { b };
            // Index loop: the body mutates `link_sum`/`scratch`, so an
            // iterator over `touch[dev]` would hold `self` borrowed.
            let mut k = 0;
            while k < self.touch[dev].len() {
                let i = self.touch[dev][k] as usize;
                k += 1;
                let (src, dst, bytes) = self.sends[i];
                if side == 1 && (src == a || dst == a) {
                    continue;
                }
                let swapped = |v: usize| if v == a { b } else if v == b { a } else { v };
                let (os, od) = (cur.card(src), cur.card(dst));
                let (ns, nd) = (cur.card(swapped(src)), cur.card(swapped(dst)));
                hop -= bytes as i128 * self.hops[os][od] as i128;
                hop += bytes as i128 * self.hops[ns][nd] as i128;
                match self.cache.get(os, od) {
                    Some(path) => {
                        let dur = path.duration(&self.fabric, bytes);
                        for &(e, dir) in path.directed_links() {
                            let (ei, di) = (e as usize, dir as usize);
                            self.scratch.push((e, dir, self.link_sum[ei][di]));
                            self.link_sum[ei][di] -= dur;
                        }
                    }
                    None => routable = false,
                }
                match self.cache.get(ns, nd) {
                    Some(path) => {
                        let dur = path.duration(&self.fabric, bytes);
                        for &(e, dir) in path.directed_links() {
                            let (ei, di) = (e as usize, dir as usize);
                            self.scratch.push((e, dir, self.link_sum[ei][di]));
                            self.link_sum[ei][di] += dur;
                        }
                    }
                    None => routable = false,
                }
            }
        }
        // The bound: hottest touched link after the deltas, plus the
        // global maximum whenever the deltas left it untouched (if
        // they did touch it, its post-delta value is already read
        // through the journal).
        let mut lb = 0.0f64;
        let mut max_untouched = true;
        for &(e, dir, _) in &self.scratch {
            if (e, dir) == self.max_link {
                max_untouched = false;
            }
            lb = lb.max(self.link_sum[e as usize][dir as usize]);
        }
        if max_untouched {
            lb = lb.max(self.max_sum);
        }
        // Revert the deltas bit-exactly (journaled pre-values, LIFO).
        while let Some((e, dir, prev)) = self.scratch.pop() {
            self.link_sum[e as usize][dir as usize] = prev;
        }
        debug_assert!(hop >= 0);
        (hop as u64, if routable { Some(lb) } else { None })
    }
}

/// Greedy packer: treat the folded reduction sends as a demand graph
/// and place devices one at a time, each onto the free card minimizing
/// demand-weighted hops to the devices already placed (ties toward the
/// lowest ids, so the construction is deterministic). For plane-major
/// 2.5D plans the dominant demands are the cross-plane tile columns,
/// so each k-slice's p × q plane lands on fabric-adjacent cards.
fn plane_packed(cards: usize, sends: &[(usize, usize, u64)], hops: &[Vec<u32>]) -> Placement {
    let _scope = profile::scope("placement.plane_pack");
    let mut demand = vec![vec![0u64; cards]; cards];
    let mut total = vec![0u64; cards];
    for &(src, dst, bytes) in sends {
        if src != dst {
            demand[src][dst] += bytes;
            demand[dst][src] += bytes;
            total[src] += bytes;
            total[dst] += bytes;
        }
    }
    let mut card_of = vec![usize::MAX; cards];
    let mut card_free = vec![true; cards];
    let mut placed: Vec<usize> = Vec::with_capacity(cards);
    for _ in 0..cards {
        // Next device: the unplaced one most attached to the placed
        // set; a fresh demand component seeds by total demand. The
        // Reverse breaks every tie toward the lowest device id.
        let attach = |dev: usize| -> u64 { placed.iter().map(|&p| demand[dev][p]).sum() };
        let next = (0..cards)
            .filter(|&dev| card_of[dev] == usize::MAX)
            .max_by_key(|&dev| (attach(dev), total[dev], std::cmp::Reverse(dev)))
            .expect("the loop runs exactly once per device");
        // Its card: the free one minimizing demand-weighted hops to
        // the placed devices (ties toward the lowest card id).
        let cost = |card: usize| -> u64 {
            placed.iter().map(|&p| demand[next][p] * u64::from(hops[card][card_of[p]])).sum()
        };
        let card = (0..cards)
            .filter(|&c| card_free[c])
            .min_by_key(|&c| (cost(c), c))
            .expect("free cards remain while devices do");
        card_of[next] = card;
        card_free[card] = false;
        placed.push(next);
    }
    Placement::from_map(card_of).expect("greedy assigns every device exactly one free card")
}

/// Search device→card maps for `plan` on `topology` under `strategy`.
///
/// Invariants, regardless of strategy:
/// * the returned map is a bijection over the topology's cards,
/// * `placed_cost_seconds ≤ identity_cost_seconds`, and
/// * `placed_hop_bytes ≤ identity_hop_bytes` (a candidate that trades
///   hop-bytes upward is rejected even if it prices lower — the
///   dominance the property tests pin down).
///
/// Plans with no reduction traffic (1D/2D carves) return the identity
/// map untouched.
///
/// Scoring is incremental (see [`SwapScorer`]) but every decision —
/// and therefore the returned map, costs, and evaluation count — is
/// bit-for-bit identical to [`optimize_reference`], which replays all
/// sends per candidate.
pub fn optimize(
    plan: &PartitionPlan,
    topology: &Topology,
    strategy: PlacementStrategy,
) -> PlacementReport {
    let _scope = profile::scope("placement.optimize");
    let t0 = std::time::Instant::now();
    let cards = topology.cards.max(1);
    let sends = plan.reduction_sends(cards);
    let identity = Placement::identity(cards);
    let hops = hop_matrix(topology);
    let mut scorer = SwapScorer::new(topology, &sends, &hops);
    let id_cost = {
        let _scope = profile::scope("placement.candidate");
        scorer.replay(&|dev| identity.card(dev), f64::INFINITY)
    };
    let id_hop = hop_bytes(&hops, &sends, &identity);
    let mut evaluations = 1usize;

    let mut best = identity;
    let mut best_cost = id_cost;
    let mut best_hop = id_hop;
    // Strict lexicographic improvement under the identity hop-byte
    // ceiling.
    let better = |cost: f64, hop: u64, ref_cost: f64, ref_hop: u64| {
        hop <= id_hop && (cost < ref_cost || (cost == ref_cost && hop < ref_hop))
    };

    if !sends.is_empty() && cards > 1 && !matches!(strategy, PlacementStrategy::Identity) {
        let packed = plane_packed(cards, &sends, &hops);
        let p_cost = {
            let _scope = profile::scope("placement.candidate");
            scorer.replay(&|dev| packed.card(dev), f64::INFINITY)
        };
        let p_hop = hop_bytes(&hops, &sends, &packed);
        evaluations += 1;
        if better(p_cost, p_hop, best_cost, best_hop) {
            best = packed;
            best_cost = p_cost;
            best_hop = p_hop;
        }
        if let PlacementStrategy::LocalSearch { seed } = strategy {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let iters = (cards * cards * 4).clamp(128, 4096);
            let mut cur = best.clone();
            let (mut cur_cost, mut cur_hop) = (best_cost, best_hop);
            // One span for the whole candidate loop: a pruned
            // candidate is ~100 ns of delta work now, so per-candidate
            // spans would dominate the armed cost the profiler-overhead
            // gate bounds.
            let _scope = profile::scope("placement.candidate");
            scorer.rebuild_sums(&|dev| cur.card(dev));
            for _ in 0..iters {
                let a = rng.next_below(cards as u64) as usize;
                let b = rng.next_below(cards as u64) as usize;
                if a == b {
                    continue;
                }
                evaluations += 1;
                let (c_hop, bound) = scorer.swap_delta(&cur, a, b, cur_hop);
                // Reference-identical rejections, no replay needed:
                // above the identity hop ceiling `better` is false for
                // any cost; a bound beyond the incumbent proves the
                // replay would land beyond it too.
                if c_hop > id_hop {
                    continue;
                }
                if let Some(lb) = bound {
                    if lb > cur_cost * (1.0 + LB_MARGIN) {
                        continue;
                    }
                }
                let c_cost = scorer.replay(
                    &|dev| {
                        let dev = if dev == a {
                            b
                        } else if dev == b {
                            a
                        } else {
                            dev
                        };
                        cur.card(dev)
                    },
                    cur_cost,
                );
                if better(c_cost, c_hop, cur_cost, cur_hop) {
                    cur.swap(a, b);
                    cur_cost = c_cost;
                    cur_hop = c_hop;
                    scorer.rebuild_sums(&|dev| cur.card(dev));
                }
            }
            if better(cur_cost, cur_hop, best_cost, best_hop) {
                best = cur;
                best_cost = cur_cost;
                best_hop = cur_hop;
            }
        }
    }

    PlacementReport {
        strategy: strategy.name(),
        placement: best,
        identity_cost_seconds: id_cost,
        placed_cost_seconds: best_cost,
        identity_hop_bytes: id_hop,
        placed_hop_bytes: best_hop,
        evaluations,
        search_seconds: t0.elapsed().as_secs_f64(),
    }
}

/// The full-replay scorer [`optimize`] is proven against: every
/// candidate map replays all reduction sends through
/// [`FabricState::send`] after an occupancy reset. Kept as the
/// equivalence oracle for the `tests/fastsim.rs` property tests and
/// the denominator of the `sim_speedup_placement_n256` perfgate floor
/// (`benches/fast_sim.rs`).
pub fn optimize_reference(
    plan: &PartitionPlan,
    topology: &Topology,
    strategy: PlacementStrategy,
) -> PlacementReport {
    let _scope = profile::scope("placement.optimize");
    let t0 = std::time::Instant::now();
    let cards = topology.cards.max(1);
    let sends = plan.reduction_sends(cards);
    let identity = Placement::identity(cards);
    let mut fabric = FabricState::new(topology.clone());
    let hops = hop_matrix(topology);
    let id_cost = contention_cost(&mut fabric, &sends, &identity);
    let id_hop = hop_bytes(&hops, &sends, &identity);
    let mut evaluations = 1usize;

    let mut best = identity;
    let mut best_cost = id_cost;
    let mut best_hop = id_hop;
    let better = |cost: f64, hop: u64, ref_cost: f64, ref_hop: u64| {
        hop <= id_hop && (cost < ref_cost || (cost == ref_cost && hop < ref_hop))
    };

    if !sends.is_empty() && cards > 1 && !matches!(strategy, PlacementStrategy::Identity) {
        let packed = plane_packed(cards, &sends, &hops);
        let p_cost = contention_cost(&mut fabric, &sends, &packed);
        let p_hop = hop_bytes(&hops, &sends, &packed);
        evaluations += 1;
        if better(p_cost, p_hop, best_cost, best_hop) {
            best = packed;
            best_cost = p_cost;
            best_hop = p_hop;
        }
        if let PlacementStrategy::LocalSearch { seed } = strategy {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let iters = (cards * cards * 4).clamp(128, 4096);
            let mut cur = best.clone();
            let (mut cur_cost, mut cur_hop) = (best_cost, best_hop);
            for _ in 0..iters {
                let a = rng.next_below(cards as u64) as usize;
                let b = rng.next_below(cards as u64) as usize;
                if a == b {
                    continue;
                }
                let mut cand = cur.clone();
                cand.swap(a, b);
                let c_cost = contention_cost(&mut fabric, &sends, &cand);
                let c_hop = hop_bytes(&hops, &sends, &cand);
                evaluations += 1;
                if better(c_cost, c_hop, cur_cost, cur_hop) {
                    cur = cand;
                    cur_cost = c_cost;
                    cur_hop = c_hop;
                }
            }
            if better(cur_cost, cur_hop, best_cost, best_hop) {
                best = cur;
                best_cost = cur_cost;
                best_hop = cur_hop;
            }
        }
    }

    PlacementReport {
        strategy: strategy.name(),
        placement: best,
        identity_cost_seconds: id_cost,
        placed_cost_seconds: best_cost,
        identity_hop_bytes: id_hop,
        placed_hop_bytes: best_hop,
        evaluations,
        search_seconds: t0.elapsed().as_secs_f64(),
    }
}

/// As [`optimize`], folding the search's host wall-clock and candidate
/// count into the tracer's host-profile side channel
/// ([`crate::trace::TraceLog::host_profile`]). Host time never enters
/// the deterministic sim-time event stream — `trace.json` stays
/// bit-identical across replays — but the `systo3d trace` summary can
/// still report what the search cost.
pub fn optimize_traced(
    plan: &PartitionPlan,
    topology: &Topology,
    strategy: PlacementStrategy,
    tracer: &Tracer,
) -> PlacementReport {
    let report = optimize(plan, topology, strategy);
    tracer.profile("placement.search", 1, report.search_seconds);
    tracer.profile("placement.candidates", report.evaluations as u64, report.search_seconds);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::PartitionStrategy;

    fn summa_plan(p: u64, q: u64, c: u64, d: u64) -> PartitionPlan {
        PartitionPlan::new(PartitionStrategy::Summa25D { p, q, c }, d, d, d).unwrap()
    }

    #[test]
    fn identity_strategy_is_a_no_op() {
        let plan = summa_plan(2, 2, 2, 4096);
        let rep = optimize(&plan, &Topology::ring(8), PlacementStrategy::Identity);
        assert!(rep.placement.is_identity());
        assert_eq!(rep.strategy, "identity");
        assert_eq!(rep.placed_cost_seconds, rep.identity_cost_seconds);
        assert_eq!(rep.placed_hop_bytes, rep.identity_hop_bytes);
        assert_eq!(rep.evaluations, 1);
        assert_eq!(rep.gain(), 1.0);
    }

    #[test]
    fn plans_without_reductions_stay_identity() {
        let plan = PartitionPlan::new(PartitionStrategy::Grid2D { p: 2, q: 2 }, 512, 512, 512)
            .unwrap();
        let rep = optimize(&plan, &Topology::ring(4), PlacementStrategy::default());
        assert!(rep.placement.is_identity());
        assert_eq!(rep.identity_cost_seconds, 0.0);
        assert_eq!(rep.gain(), 1.0);
        assert_eq!(rep.hop_byte_saving(), 0.0);
    }

    #[test]
    fn local_search_beats_identity_on_a_ring() {
        // Plane-major 2.5D on a 16-ring: every cross-plane partial
        // crosses 8 hops under identity; pairing the planes makes the
        // combine 1-hop disjoint flows.
        let plan = summa_plan(4, 2, 2, 8192);
        let topology = Topology::ring(16);
        let rep = optimize(&plan, &topology, PlacementStrategy::default());
        assert!(
            rep.placed_cost_seconds < rep.identity_cost_seconds,
            "placed {} vs identity {}",
            rep.placed_cost_seconds,
            rep.identity_cost_seconds
        );
        assert!(rep.placed_hop_bytes < rep.identity_hop_bytes);
        assert!(rep.gain() > 2.0, "gain {}", rep.gain());
        assert!(rep.evaluations > 2);
        // The reported hop-bytes match re-pricing the applied plan.
        let placed = rep.placement.apply_to(&plan);
        assert_eq!(placed.reduction_hop_bytes(&topology), rep.placed_hop_bytes);
        assert_eq!(plan.reduction_hop_bytes(&topology), rep.identity_hop_bytes);
    }

    #[test]
    fn plane_packer_alone_already_helps() {
        let plan = summa_plan(2, 2, 2, 4096);
        let rep = optimize(&plan, &Topology::ring(8), PlacementStrategy::PlanePacked);
        assert_eq!(rep.strategy, "plane-packed");
        assert!(rep.placed_cost_seconds <= rep.identity_cost_seconds);
        assert!(rep.placed_hop_bytes < rep.identity_hop_bytes);
    }

    #[test]
    fn same_seed_same_map() {
        let plan = summa_plan(4, 2, 2, 4096);
        let topology = Topology::torus_near_square(16);
        let a = optimize(&plan, &topology, PlacementStrategy::LocalSearch { seed: 42 });
        let b = optimize(&plan, &topology, PlacementStrategy::LocalSearch { seed: 42 });
        assert_eq!(a.placement, b.placement);
        assert_eq!(a.placed_cost_seconds.to_bits(), b.placed_cost_seconds.to_bits());
        assert_eq!(a.evaluations, b.evaluations);
    }

    #[test]
    fn incremental_scorer_matches_reference_bit_for_bit() {
        let plan = summa_plan(4, 2, 2, 8192);
        for topology in [Topology::ring(16), Topology::torus_near_square(16)] {
            for seed in [7u64, 42] {
                let strat = PlacementStrategy::LocalSearch { seed };
                let inc = optimize(&plan, &topology, strat);
                let full = optimize_reference(&plan, &topology, strat);
                assert_eq!(inc.placement, full.placement);
                assert_eq!(inc.placed_cost_seconds.to_bits(), full.placed_cost_seconds.to_bits());
                assert_eq!(
                    inc.identity_cost_seconds.to_bits(),
                    full.identity_cost_seconds.to_bits()
                );
                assert_eq!(inc.placed_hop_bytes, full.placed_hop_bytes);
                assert_eq!(inc.identity_hop_bytes, full.identity_hop_bytes);
                assert_eq!(inc.evaluations, full.evaluations);
            }
        }
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(PlacementStrategy::parse("identity"), Ok(PlacementStrategy::Identity));
        assert_eq!(PlacementStrategy::parse("plane"), Ok(PlacementStrategy::PlanePacked));
        assert_eq!(
            PlacementStrategy::parse("search"),
            Ok(PlacementStrategy::LocalSearch { seed: DEFAULT_SEED })
        );
        assert!(PlacementStrategy::parse("bogus").is_err());
    }
}
