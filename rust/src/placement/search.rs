//! Strategy selection: identity, the greedy plane-packer, and the
//! seeded local search, all scored by replaying the plan's reduction
//! sends under the link-contention model.

use super::map::Placement;
use crate::cluster::partition::PartitionPlan;
use crate::fabric::{FabricState, Topology};
use crate::trace::{profile, Tracer};
use crate::util::rng::Xoshiro256;

/// Default local-search seed (any fixed value works — determinism is
/// the point, not the number).
pub const DEFAULT_SEED: u64 = 0x5EED_CA8D;

/// How to map plan devices onto physical cards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Device i runs on card i — the baseline.
    Identity,
    /// Greedy packer over the reduction demand graph.
    PlanePacked,
    /// Seeded swap local search from the better of identity and
    /// plane-packed. Deterministic: the same seed always returns the
    /// same map.
    LocalSearch { seed: u64 },
}

impl Default for PlacementStrategy {
    fn default() -> Self {
        PlacementStrategy::LocalSearch { seed: DEFAULT_SEED }
    }
}

impl PlacementStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementStrategy::Identity => "identity",
            PlacementStrategy::PlanePacked => "plane-packed",
            PlacementStrategy::LocalSearch { .. } => "local-search",
        }
    }

    /// Parse a CLI spelling (`--placement identity|plane|search`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "identity" | "id" => Ok(PlacementStrategy::Identity),
            "plane" | "plane-packed" | "packed" => Ok(PlacementStrategy::PlanePacked),
            "search" | "local-search" => Ok(PlacementStrategy::default()),
            other => Err(format!("unknown placement {other:?} (identity|plane|search)")),
        }
    }
}

/// What the optimizer found, with the identity baseline it was scored
/// against.
#[derive(Clone, Debug)]
pub struct PlacementReport {
    /// Strategy that ran (the map may still be identity when nothing
    /// beat it).
    pub strategy: &'static str,
    pub placement: Placement,
    /// Contention-priced drain of the reduction sends under the
    /// identity map: every flow launches at t = 0 and shared links
    /// serialize; this is when the last partial lands (s).
    pub identity_cost_seconds: f64,
    /// Same replay under the chosen map (≤ identity by construction).
    pub placed_cost_seconds: f64,
    /// Σ bytes · hops under identity (the topology-blind half of plan
    /// pricing made hop-aware).
    pub identity_hop_bytes: u64,
    /// Σ bytes · hops under the chosen map (never above identity's).
    pub placed_hop_bytes: u64,
    /// Candidate maps priced while searching.
    pub evaluations: usize,
    /// Host wall-clock of the search — a gauge only, never fed back
    /// into simulated time.
    pub search_seconds: f64,
}

impl PlacementReport {
    /// identity/placed contention cost (> 1 means the optimizer won;
    /// 1.0 when there was nothing to reduce).
    pub fn gain(&self) -> f64 {
        if self.placed_cost_seconds <= 0.0 {
            return 1.0;
        }
        self.identity_cost_seconds / self.placed_cost_seconds
    }

    /// Fraction of identity hop-bytes the placement removed.
    pub fn hop_byte_saving(&self) -> f64 {
        if self.identity_hop_bytes == 0 {
            return 0.0;
        }
        1.0 - self.placed_hop_bytes as f64 / self.identity_hop_bytes as f64
    }
}

/// All-pairs card hop counts (BFS per source, computed once per
/// optimize call).
fn hop_matrix(topology: &Topology) -> Vec<Vec<u32>> {
    let n = topology.cards;
    (0..n)
        .map(|a| (0..n).map(|b| topology.hops(a, b).unwrap_or(0)).collect())
        .collect()
}

/// Price `sends` under `placement` on `fabric`: every send launches at
/// t = 0 in plan order, shared directed links serialize (the
/// [`FabricState`] circuit model), and the cost is the instant the
/// last flow drains. Unroutable pairs price as infinity. The fabric's
/// occupancy is reset before the replay, so one instance serves every
/// candidate the search prices (no per-candidate route-table clone).
fn contention_cost(
    fabric: &mut FabricState,
    sends: &[(usize, usize, u64)],
    placement: &Placement,
) -> f64 {
    // The placement-search inner loop: every candidate map replays all
    // reduction sends through the circuit model. This is where the
    // host profiler expects the search's self time to land.
    let _scope = profile::scope("placement.candidate");
    fabric.reset_occupancy();
    let mut last = 0.0f64;
    for &(src, dst, bytes) in sends {
        let (s, d) = (placement.card(src), placement.card(dst));
        if s == d {
            continue;
        }
        match fabric.send(s, d, bytes, 0.0) {
            Some((_, end)) => last = last.max(end),
            None => return f64::INFINITY,
        }
    }
    last
}

/// Σ bytes · hops of `sends` under `placement`.
fn hop_bytes(hops: &[Vec<u32>], sends: &[(usize, usize, u64)], placement: &Placement) -> u64 {
    let mut total = 0u64;
    for &(src, dst, bytes) in sends {
        let (s, d) = (placement.card(src), placement.card(dst));
        if s != d {
            total += bytes * u64::from(hops[s][d]);
        }
    }
    total
}

/// Greedy packer: treat the folded reduction sends as a demand graph
/// and place devices one at a time, each onto the free card minimizing
/// demand-weighted hops to the devices already placed (ties toward the
/// lowest ids, so the construction is deterministic). For plane-major
/// 2.5D plans the dominant demands are the cross-plane tile columns,
/// so each k-slice's p × q plane lands on fabric-adjacent cards.
fn plane_packed(cards: usize, sends: &[(usize, usize, u64)], hops: &[Vec<u32>]) -> Placement {
    let _scope = profile::scope("placement.plane_pack");
    let mut demand = vec![vec![0u64; cards]; cards];
    let mut total = vec![0u64; cards];
    for &(src, dst, bytes) in sends {
        if src != dst {
            demand[src][dst] += bytes;
            demand[dst][src] += bytes;
            total[src] += bytes;
            total[dst] += bytes;
        }
    }
    let mut card_of = vec![usize::MAX; cards];
    let mut card_free = vec![true; cards];
    let mut placed: Vec<usize> = Vec::with_capacity(cards);
    for _ in 0..cards {
        // Next device: the unplaced one most attached to the placed
        // set; a fresh demand component seeds by total demand. The
        // Reverse breaks every tie toward the lowest device id.
        let attach = |dev: usize| -> u64 { placed.iter().map(|&p| demand[dev][p]).sum() };
        let next = (0..cards)
            .filter(|&dev| card_of[dev] == usize::MAX)
            .max_by_key(|&dev| (attach(dev), total[dev], std::cmp::Reverse(dev)))
            .expect("the loop runs exactly once per device");
        // Its card: the free one minimizing demand-weighted hops to
        // the placed devices (ties toward the lowest card id).
        let cost = |card: usize| -> u64 {
            placed.iter().map(|&p| demand[next][p] * u64::from(hops[card][card_of[p]])).sum()
        };
        let card = (0..cards)
            .filter(|&c| card_free[c])
            .min_by_key(|&c| (cost(c), c))
            .expect("free cards remain while devices do");
        card_of[next] = card;
        card_free[card] = false;
        placed.push(next);
    }
    Placement::from_map(card_of).expect("greedy assigns every device exactly one free card")
}

/// Search device→card maps for `plan` on `topology` under `strategy`.
///
/// Invariants, regardless of strategy:
/// * the returned map is a bijection over the topology's cards,
/// * `placed_cost_seconds ≤ identity_cost_seconds`, and
/// * `placed_hop_bytes ≤ identity_hop_bytes` (a candidate that trades
///   hop-bytes upward is rejected even if it prices lower — the
///   dominance the property tests pin down).
///
/// Plans with no reduction traffic (1D/2D carves) return the identity
/// map untouched.
pub fn optimize(
    plan: &PartitionPlan,
    topology: &Topology,
    strategy: PlacementStrategy,
) -> PlacementReport {
    let _scope = profile::scope("placement.optimize");
    let t0 = std::time::Instant::now();
    let cards = topology.cards.max(1);
    let sends = plan.reduction_sends(cards);
    let identity = Placement::identity(cards);
    let mut fabric = FabricState::new(topology.clone());
    let hops = hop_matrix(topology);
    let id_cost = contention_cost(&mut fabric, &sends, &identity);
    let id_hop = hop_bytes(&hops, &sends, &identity);
    let mut evaluations = 1usize;

    let mut best = identity;
    let mut best_cost = id_cost;
    let mut best_hop = id_hop;
    // Strict lexicographic improvement under the identity hop-byte
    // ceiling.
    let better = |cost: f64, hop: u64, ref_cost: f64, ref_hop: u64| {
        hop <= id_hop && (cost < ref_cost || (cost == ref_cost && hop < ref_hop))
    };

    if !sends.is_empty() && cards > 1 && !matches!(strategy, PlacementStrategy::Identity) {
        let packed = plane_packed(cards, &sends, &hops);
        let p_cost = contention_cost(&mut fabric, &sends, &packed);
        let p_hop = hop_bytes(&hops, &sends, &packed);
        evaluations += 1;
        if better(p_cost, p_hop, best_cost, best_hop) {
            best = packed;
            best_cost = p_cost;
            best_hop = p_hop;
        }
        if let PlacementStrategy::LocalSearch { seed } = strategy {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let iters = (cards * cards * 4).clamp(128, 4096);
            let mut cur = best.clone();
            let (mut cur_cost, mut cur_hop) = (best_cost, best_hop);
            for _ in 0..iters {
                let a = rng.next_below(cards as u64) as usize;
                let b = rng.next_below(cards as u64) as usize;
                if a == b {
                    continue;
                }
                let mut cand = cur.clone();
                cand.swap(a, b);
                let c_cost = contention_cost(&mut fabric, &sends, &cand);
                let c_hop = hop_bytes(&hops, &sends, &cand);
                evaluations += 1;
                if better(c_cost, c_hop, cur_cost, cur_hop) {
                    cur = cand;
                    cur_cost = c_cost;
                    cur_hop = c_hop;
                }
            }
            if better(cur_cost, cur_hop, best_cost, best_hop) {
                best = cur;
                best_cost = cur_cost;
                best_hop = cur_hop;
            }
        }
    }

    PlacementReport {
        strategy: strategy.name(),
        placement: best,
        identity_cost_seconds: id_cost,
        placed_cost_seconds: best_cost,
        identity_hop_bytes: id_hop,
        placed_hop_bytes: best_hop,
        evaluations,
        search_seconds: t0.elapsed().as_secs_f64(),
    }
}

/// As [`optimize`], folding the search's host wall-clock and candidate
/// count into the tracer's host-profile side channel
/// ([`crate::trace::TraceLog::host_profile`]). Host time never enters
/// the deterministic sim-time event stream — `trace.json` stays
/// bit-identical across replays — but the `systo3d trace` summary can
/// still report what the search cost.
pub fn optimize_traced(
    plan: &PartitionPlan,
    topology: &Topology,
    strategy: PlacementStrategy,
    tracer: &Tracer,
) -> PlacementReport {
    let report = optimize(plan, topology, strategy);
    tracer.profile("placement.search", 1, report.search_seconds);
    tracer.profile("placement.candidates", report.evaluations as u64, report.search_seconds);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::partition::PartitionStrategy;

    fn summa_plan(p: u64, q: u64, c: u64, d: u64) -> PartitionPlan {
        PartitionPlan::new(PartitionStrategy::Summa25D { p, q, c }, d, d, d).unwrap()
    }

    #[test]
    fn identity_strategy_is_a_no_op() {
        let plan = summa_plan(2, 2, 2, 4096);
        let rep = optimize(&plan, &Topology::ring(8), PlacementStrategy::Identity);
        assert!(rep.placement.is_identity());
        assert_eq!(rep.strategy, "identity");
        assert_eq!(rep.placed_cost_seconds, rep.identity_cost_seconds);
        assert_eq!(rep.placed_hop_bytes, rep.identity_hop_bytes);
        assert_eq!(rep.evaluations, 1);
        assert_eq!(rep.gain(), 1.0);
    }

    #[test]
    fn plans_without_reductions_stay_identity() {
        let plan = PartitionPlan::new(PartitionStrategy::Grid2D { p: 2, q: 2 }, 512, 512, 512)
            .unwrap();
        let rep = optimize(&plan, &Topology::ring(4), PlacementStrategy::default());
        assert!(rep.placement.is_identity());
        assert_eq!(rep.identity_cost_seconds, 0.0);
        assert_eq!(rep.gain(), 1.0);
        assert_eq!(rep.hop_byte_saving(), 0.0);
    }

    #[test]
    fn local_search_beats_identity_on_a_ring() {
        // Plane-major 2.5D on a 16-ring: every cross-plane partial
        // crosses 8 hops under identity; pairing the planes makes the
        // combine 1-hop disjoint flows.
        let plan = summa_plan(4, 2, 2, 8192);
        let topology = Topology::ring(16);
        let rep = optimize(&plan, &topology, PlacementStrategy::default());
        assert!(
            rep.placed_cost_seconds < rep.identity_cost_seconds,
            "placed {} vs identity {}",
            rep.placed_cost_seconds,
            rep.identity_cost_seconds
        );
        assert!(rep.placed_hop_bytes < rep.identity_hop_bytes);
        assert!(rep.gain() > 2.0, "gain {}", rep.gain());
        assert!(rep.evaluations > 2);
        // The reported hop-bytes match re-pricing the applied plan.
        let placed = rep.placement.apply_to(&plan);
        assert_eq!(placed.reduction_hop_bytes(&topology), rep.placed_hop_bytes);
        assert_eq!(plan.reduction_hop_bytes(&topology), rep.identity_hop_bytes);
    }

    #[test]
    fn plane_packer_alone_already_helps() {
        let plan = summa_plan(2, 2, 2, 4096);
        let rep = optimize(&plan, &Topology::ring(8), PlacementStrategy::PlanePacked);
        assert_eq!(rep.strategy, "plane-packed");
        assert!(rep.placed_cost_seconds <= rep.identity_cost_seconds);
        assert!(rep.placed_hop_bytes < rep.identity_hop_bytes);
    }

    #[test]
    fn same_seed_same_map() {
        let plan = summa_plan(4, 2, 2, 4096);
        let topology = Topology::torus_near_square(16);
        let a = optimize(&plan, &topology, PlacementStrategy::LocalSearch { seed: 42 });
        let b = optimize(&plan, &topology, PlacementStrategy::LocalSearch { seed: 42 });
        assert_eq!(a.placement, b.placement);
        assert_eq!(a.placed_cost_seconds.to_bits(), b.placed_cost_seconds.to_bits());
        assert_eq!(a.evaluations, b.evaluations);
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(PlacementStrategy::parse("identity"), Ok(PlacementStrategy::Identity));
        assert_eq!(PlacementStrategy::parse("plane"), Ok(PlacementStrategy::PlanePacked));
        assert_eq!(
            PlacementStrategy::parse("search"),
            Ok(PlacementStrategy::LocalSearch { seed: DEFAULT_SEED })
        );
        assert!(PlacementStrategy::parse("bogus").is_err());
    }
}
